"""Checkpointing with bloom-clock lineage, async writes, elastic restore.

Layout per checkpoint:  <dir>/step_<N>/
  - state.npz        flattened pytree leaves (params / opt / clock / step)
  - manifest.json    step, run_id, clock snapshot (compressed §4 form),
                     param-table hash, mesh shape at save time

Fault-tolerance behaviors:
  - **async save**: the host snapshot (device_get) happens synchronously
    (cheap, it's a copy), the file write runs on a background thread;
    ``wait()`` drains before the next save (double buffering).
  - **atomic publish**: writes go to ``.tmp-step_<N>`` then os.rename.
  - **lineage-checked restore**: ``restore()`` hands back the stored clock;
    callers gate on ``ClockRuntime.admit_restore`` — restoring a checkpoint
    whose clock is CONCURRENT with the live run (fork/split brain) is
    refused at the runtime layer.
  - **elastic reshard**: restore is mesh-agnostic (leaves land on host,
    then ``jax.device_put`` with the *new* mesh's shardings), so scale-up/
    scale-down = restore under a different mesh. The bloom clock needs no
    resize on membership change — the paper's core advantage.
  - **GC**: keep the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, run_id: str = "run0"):
        self.dir = directory
        self.keep = keep
        self.run_id = run_id
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, clock_snapshot: dict,
             extra: Optional[dict] = None, block: bool = False) -> str:
        """Snapshot now, write async. Returns the final path."""
        self.wait()  # double buffer: at most one write in flight
        state_host = jax.tree.map(lambda x: np.asarray(x), state)
        flat = _flatten(state_host)
        manifest = {
            "step": int(step),
            "run_id": self.run_id,
            "clock": {
                "cells": [int(v) for v in clock_snapshot["cells"]],
                "base": int(clock_snapshot["base"]),
                "k": int(clock_snapshot["k"]),
            },
            "n_leaves": len(flat),
            **(extra or {}),
        }
        final = os.path.join(self.dir, f"step_{step}")
        tmp = os.path.join(self.dir, f".tmp-step_{step}")

        def _write():
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "state.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if block:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        return final

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.list_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def list_steps(self) -> list:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def clock_manifests(self) -> list:
        """[(step, manifest)] for every checkpoint, sorted by step.

        Reads only the manifest.json files (clock snapshots are a few KB
        in §4 wire form) — this is what ``ClockRuntime.
        classify_checkpoints`` feeds to one ``classify_vs_many`` call to
        lineage-check a whole directory without touching state tensors.
        """
        self.wait()
        out = []
        for step in self.list_steps():
            path = os.path.join(self.dir, f"step_{step}", "manifest.json")
            with open(path) as f:
                out.append((step, json.load(f)))
        return out

    def restore(self, step: Optional[int] = None,
                target_structure=None, shardings=None):
        """Returns (state, manifest). With ``shardings`` (a pytree matching
        the state), leaves are device_put with those shardings — this is the
        elastic-reshard path (any mesh shape, any host count)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat = dict(np.load(os.path.join(path, "state.npz")))
        if target_structure is None:
            state = flat
        else:
            leaves_paths = jax.tree_util.tree_flatten_with_path(target_structure)
            keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in kp)
                    for kp, _ in leaves_paths[0]]
            missing = [k for k in keys if k not in flat]
            if missing:
                raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")
            leaves = [flat[k] for k in keys]
            state = jax.tree_util.tree_unflatten(leaves_paths[1], leaves)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state, manifest
