"""Typed results of the unified causality API.

The paper's contract is one sentence: compare two timestamps, get a
partial order plus an Eq. 3 false-positive rate.  These classes ARE
that contract — every compare engine (int32 fallback, packed triangle,
MXU thermometer, promoted-row overlay, sharded ring) returns one of
them through the ``CausalEngine`` front-door, and every consumer applies
the Eq. 3 confidence gate through the same ``.confident(threshold)``
accessor instead of re-implementing ``fp <= threshold`` by hand.

All three classes are registered pytrees (jit / vmap / device_put safe;
the dispatch metadata rides along as static aux data) and keep the
array leaves the engines produced — accessors never re-derive flags, so
values stay bit-identical to the raw kernel outputs.

``ComparisonMatrix`` and ``ClassifyResult`` also answer the legacy
mapping protocol (``res["a_le_b"]``, ``.items()``) with the exact key
set the pre-front-door dicts used, so downstream numpy plumbing keeps
working during migration.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Comparison", "ComparisonMatrix", "ClassifyResult"]


def _where(cond, a, b):
    """Backend-preserving select: numpy leaves (a host-side
    ``device_get`` result) stay numpy — no device round-trip from a
    pure accessor — while traced/jax leaves stay jax."""
    if isinstance(cond, np.ndarray):
        return np.where(cond, a, b)
    return jnp.where(cond, a, b)


class _MappingMixin:
    """Legacy dict-style access over the old result-dict key set."""

    _KEYS: tuple = ()

    def __getitem__(self, key):
        try:
            return getattr(self, f"_k_{key}")()
        except AttributeError:
            raise KeyError(key) from None

    def keys(self):
        return iter(self._KEYS)

    def items(self):
        return ((k, self[k]) for k in self._KEYS)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Comparison:
    """Pairwise (or batched-pairwise) comparison of clocks A vs B.

    Leaves broadcast over any batch shape; produced by
    ``repro.causal.compare`` and jit/vmap-composable.
    """

    a_le_b: jax.Array          # bool[...]: A cell-wise dominated by B
    b_le_a: jax.Array
    fp_ab: jax.Array           # float32[...]: Eq. 3 fp of "A -> B"
    fp_ba: jax.Array
    sum_a: jax.Array           # float32[...]: total increments
    sum_b: jax.Array

    def tree_flatten(self):
        return ((self.a_le_b, self.b_le_a, self.fp_ab, self.fp_ba,
                 self.sum_a, self.sum_b), None)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)

    # ---- accessors ----
    def before(self):
        """The claim "A happened-before B" (dominance; includes equal)."""
        return self.a_le_b

    def after(self):
        """The claim "B happened-before A"."""
        return self.b_le_a

    def equal(self):
        return self.a_le_b & self.b_le_a

    def concurrent(self):
        """Neither dominates — *exact*, no false negatives (paper §3)."""
        return ~(self.a_le_b | self.b_le_a)

    def confident(self, threshold: float):
        """The uniform decision rule: "A -> B" holds AND its Eq. 3 fp is
        within ``threshold`` — the gate every runtime admit path uses."""
        return self.a_le_b & (self.fp_ab <= threshold)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ComparisonMatrix(_MappingMixin):
    """All-pairs comparison: [N, M] flag/fp matrices + per-row/col sums.

    ``conc`` is carried as a leaf (not derived): engines mask dead slots
    to all-False across ALL flag kinds, which ``~(le | ge)`` could not
    represent.
    """

    le: jax.Array              # bool[N, M]: row clock ≼ col clock
    ge: jax.Array              # bool[N, M]
    conc: jax.Array            # bool[N, M]: exact concurrency
    fp: jax.Array              # float32[N, M]: Eq. 3 fp of "row -> col"
    row_sums: jax.Array        # float32[N]
    col_sums: jax.Array        # float32[M]
    engine: Optional[str] = None      # dispatch metadata (static)
    blocks: Optional[tuple] = None    # resolved block shapes (static)

    _KEYS = ("a_le_b", "b_le_a", "concurrent", "fp", "row_sums", "col_sums")

    def tree_flatten(self):
        return ((self.le, self.ge, self.conc, self.fp,
                 self.row_sums, self.col_sums), (self.engine, self.blocks))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)

    @classmethod
    def from_dict(cls, d: dict, *, engine: str | None = None,
                  blocks: tuple | None = None) -> "ComparisonMatrix":
        """Wrap a raw engine result dict (leaves adopted, not copied)."""
        return cls(le=d["a_le_b"], ge=d["b_le_a"], conc=d["concurrent"],
                   fp=d["fp"], row_sums=d["row_sums"],
                   col_sums=d["col_sums"], engine=engine, blocks=blocks)

    # ---- accessors ----
    def before(self):
        return self.le

    def after(self):
        return self.ge

    def concurrent(self):
        return self.conc

    def equal(self):
        return self.le & self.ge

    def confident(self, threshold: float):
        """"row -> col" claims whose Eq. 3 fp is within ``threshold``."""
        return self.le & (self.fp <= threshold)

    # legacy dict keys
    def _k_a_le_b(self):
        return self.le

    def _k_b_le_a(self):
        return self.ge

    def _k_concurrent(self):
        return self.conc

    def _k_fp(self):
        return self.fp

    def _k_row_sums(self):
        return self.row_sums

    def _k_col_sums(self):
        return self.col_sums


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ClassifyResult(_MappingMixin):
    """One-vs-many classification of a query clock against N peers."""

    q_le_p: jax.Array          # bool[N]: query ≼ peer (peer is ahead)
    p_le_q: jax.Array          # bool[N]: peer ≼ query (peer in our past)
    sum_q: jax.Array           # float32 scalar
    sum_p: jax.Array           # float32[N]
    fp_q_before_p: jax.Array   # float32[N]: Eq. 3 fp of "query -> peer"
    fp_p_before_q: jax.Array
    engine: Optional[str] = None      # dispatch metadata (static)
    blocks: Optional[tuple] = None    # resolved block shapes (static)

    _KEYS = ("q_le_p", "p_le_q", "sum_q", "sum_p",
             "fp_q_before_p", "fp_p_before_q")

    def tree_flatten(self):
        return ((self.q_le_p, self.p_le_q, self.sum_q, self.sum_p,
                 self.fp_q_before_p, self.fp_p_before_q),
                (self.engine, self.blocks))

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)

    @classmethod
    def from_dict(cls, d: dict, *, engine: str | None = None,
                  blocks: tuple | None = None) -> "ClassifyResult":
        return cls(q_le_p=d["q_le_p"], p_le_q=d["p_le_q"], sum_q=d["sum_q"],
                   sum_p=d["sum_p"], fp_q_before_p=d["fp_q_before_p"],
                   fp_p_before_q=d["fp_p_before_q"], engine=engine,
                   blocks=blocks)

    # ---- accessors ----
    def before(self):
        """Per-peer claim "query happened-before peer"."""
        return self.q_le_p

    def after(self):
        """Per-peer claim "peer happened-before query"."""
        return self.p_le_q

    def equal(self):
        return self.q_le_p & self.p_le_q

    def concurrent(self):
        return ~(self.q_le_p | self.p_le_q)

    def fp_before(self):
        """fp of "query -> peer"; exact (0) where the clocks are equal."""
        return _where(self.equal(), 0.0, self.fp_q_before_p)

    def fp_after(self):
        """fp of "peer -> query"; exact (0) where the clocks are equal."""
        return _where(self.equal(), 0.0, self.fp_p_before_q)

    def claimed_fp(self):
        """fp of the direction actually claimed per peer; SAME and
        FORKED verdicts are exact (paper §3) and report 0."""
        fp = _where(self.p_le_q, self.fp_p_before_q, self.fp_q_before_p)
        return _where(self.equal() | self.concurrent(), 0.0, fp)

    def confident(self, threshold: float):
        """The uniform Eq. 3 gate over the claimed direction (exact
        verdicts are always confident)."""
        return self.claimed_fp() <= threshold

    # legacy dict keys
    def _k_q_le_p(self):
        return self.q_le_p

    def _k_p_le_q(self):
        return self.p_le_q

    def _k_sum_q(self):
        return self.sum_q

    def _k_sum_p(self):
        return self.sum_p

    def _k_fp_q_before_p(self):
        return self.fp_q_before_p

    def _k_fp_p_before_q(self):
        return self.fp_p_before_q
