"""CausalPolicy: the one source of truth for causality decisions.

Before this existed, every caller re-decided three things by hand on
every call: which compare engine to run (packed triangle / full rect /
MXU thermometer / int32 fallback), what Eq. 3 confidence to demand, and
whether/how the peer slab is sharded over a mesh.  The policy bundles
those choices into one frozen dataclass that is threaded through
``ClockRuntime``, ``ClockRegistry``, gossip, serving and the launch
entry points, and consumed by ``CausalEngine`` — the single dispatch
front-door.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.sharding import FLEET_AXIS

__all__ = ["CausalPolicy"]

_ENGINES = (None, "tri", "full", "mxu", "i32")


@dataclasses.dataclass(frozen=True)
class CausalPolicy:
    """Dispatch + confidence policy for all causality comparisons.

    fp_threshold   Eq. 3 confidence gate every admit/merge decision uses
                   (``results.*.confident(policy.fp_threshold)``).
    engine         engine preference: None = measured auto-dispatch;
                   "tri" / "full" / "mxu" force a packed engine,
                   "i32" forces the legacy int32 kernel.
    pack           pack int32 inputs on the fly when the value span fits
                   a byte (False pins the int32 kernel path).
    mesh / axis    when a mesh is set, slab comparisons run sharded
                   (shard_map'd one-vs-many, ppermute all-pairs ring)
                   over ``axis``; results stay bit-identical to the
                   single-device engines for every shard count.
    bi/bj/bm/bn    explicit kernel block-shape overrides (None = let the
                   measured autotune table / per-backend defaults pick).
    autotune       consult the measured engine/block-shape table
                   (``kernels.autotune``); False = built-in defaults.
    interpret      force Pallas interpret mode (None = auto: interpret
                   off-TPU so the same kernel bodies run on CPU).
    observer       ``repro.obs.Observer`` riding the policy: every
                   consumer (engine, registry, gossip, runtime,
                   serving) instruments itself through it.  None (the
                   default) means null sinks — near-zero cost.
                   Observers hash/compare by identity, so the policy
                   stays hashable and usable as a cache key.
    """

    fp_threshold: float = 1e-4
    engine: Optional[str] = None
    pack: bool = True
    mesh: Any = None
    axis: str = FLEET_AXIS
    bi: Optional[int] = None
    bj: Optional[int] = None
    bm: Optional[int] = None
    bn: Optional[int] = None
    autotune: bool = True
    interpret: Optional[bool] = None
    observer: Any = None

    def __post_init__(self):
        if self.engine not in _ENGINES:
            raise ValueError(
                f"unknown engine {self.engine!r}; pick one of {_ENGINES}")

    @property
    def sharded(self) -> bool:
        return self.mesh is not None

    @property
    def shards(self) -> int:
        return 1 if self.mesh is None else self.mesh.shape[self.axis]

    def merged(self, **overrides) -> "CausalPolicy":
        """Policy with the non-None overrides applied (per-call knobs)."""
        kept = {k: v for k, v in overrides.items() if v is not None}
        return dataclasses.replace(self, **kept) if kept else self

    def label(self) -> str:
        """Compact human/JSON descriptor (bench records, dashboards)."""
        parts = [f"fp<={self.fp_threshold:g}"]
        parts.append(f"engine={self.engine or 'auto'}")
        if not self.pack:
            parts.append("pack=off")
        if not self.autotune:
            parts.append("autotune=off")
        if self.mesh is not None:
            parts.append(f"shards={self.shards}:{self.axis}")
        blocks = {k: v for k, v in
                  (("bi", self.bi), ("bj", self.bj),
                   ("bm", self.bm), ("bn", self.bn)) if v is not None}
        if blocks:
            parts.append(",".join(f"{k}{v}" for k, v in blocks.items()))
        return " ".join(parts)
