"""CausalEngine: the single dispatch front-door over all compare engines.

Two verbs, every engine behind them:

    engine = CausalEngine(CausalPolicy(...))
    engine.classify(query, peers)   # one-vs-many -> ClassifyResult
    engine.pairs(clocks)            # all-pairs   -> ComparisonMatrix

Internally the front-door handles everything callers used to hand-roll
at eight different entry points: pack-on-the-fly vs the int32 fallback,
MXU-thermometer viability, the promoted-row overlay/rim for slab rows
whose value span outgrew a byte, alive-slot compaction and dead-slot
masking, and single-device vs shard_map'd sharded execution — all
consulting the measured autotune table through one resolution path and
reporting the choice it made in the result's ``engine`` metadata.

Inputs: a ``PackedSlab`` (the registry's quantized u8 layout, promoted
rows included), an ``[N, m]`` int32 logical-cell slab, or a batched
``BloomClock``.  Outputs are the typed pytrees in ``causal.results``;
their values are bit-identical to the pre-front-door entry points (the
``ops.*`` shims), which delegate to the same implementations.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.causal.policy import CausalPolicy
from repro.causal.results import ClassifyResult, Comparison, ComparisonMatrix
from repro.core import clock as bc
from repro.kernels import autotune, ops, pack
from repro.obs.observer import resolve

__all__ = ["CausalEngine", "PackedSlab", "compare"]


def compare(a: bc.BloomClock, b: bc.BloomClock) -> Comparison:
    """Pairwise (broadcast/batched) typed comparison of two clocks.

    The reference partial-order + Eq. 3 math from ``repro.core.clock``,
    returned as a ``Comparison`` pytree; jit/vmap composable.
    """
    o = bc.ordering(a, b)
    return Comparison(a_le_b=o.a_le_b, b_le_a=o.b_le_a,
                      fp_ab=o.fp_a_before_b, fp_ba=o.fp_b_before_a,
                      sum_a=bc.clock_sum(a), sum_b=bc.clock_sum(b))


@dataclasses.dataclass
class PackedSlab:
    """Packed peer-clock slab view handed to the front-door.

    The §4 quantized layout (``kernels.pack``): u8 window residuals
    plus a per-slot int32 base.  ``wide`` carries promoted rows — slots
    whose residual span outgrew a byte — as host int32 logical rows;
    the engine overlays them through the exact int32 kernel so they
    never sink the bulk to the fallback.  ``base_host`` (optional) lets
    the engine probe base uniformity without a device sync.
    """

    cells_u8: jax.Array                       # [N, m] uint8 residuals
    base: jax.Array                           # [N] int32 offsets
    base_host: Optional[np.ndarray] = None    # host copy of ``base``
    wide: dict = dataclasses.field(default_factory=dict)

    @property
    def capacity(self) -> int:
        return self.cells_u8.shape[0]

    @property
    def m(self) -> int:
        return self.cells_u8.shape[1]

    @property
    def packed(self) -> bool:
        return not self.wide


def _dispatch_label(fallback: str) -> tuple[str, tuple | None]:
    """(engine, blocks) metadata from the most recent ops dispatch."""
    d = ops.LAST_DISPATCH
    if not d:
        return fallback, None
    blocks = tuple((k, v) for k, v in sorted(d.items())
                   if k not in ("op", "engine"))
    return d.get("engine", fallback), blocks


def _as_cells(clocks) -> jax.Array:
    """int32 logical cells from a BloomClock (any batch shape) or array."""
    if isinstance(clocks, bc.BloomClock):
        return clocks.logical_cells().astype(jnp.int32)
    return jnp.asarray(clocks, jnp.int32)


class CausalEngine:
    """The two-verb causality front-door (see module docstring)."""

    def __init__(self, policy: CausalPolicy | None = None):
        self.policy = policy or CausalPolicy()
        # instrumentation rides the policy; null sinks when absent
        self.obs = resolve(getattr(self.policy, "observer", None))

    def _record_dispatch(self, verb: str, res, n: int, span,
                         tune0: tuple[int, int]) -> None:
        """Span attrs + dispatch counters for one front-door call."""
        obs = self.obs
        span.set(engine=res.engine, n=n,
                 blocks=dict(res.blocks) if res.blocks else None,
                 shards=self.policy.shards)
        obs.metrics.counter("engine_dispatch", verb=verb,
                            engine=res.engine).inc()
        hits = autotune.CACHE_STATS["hit"] - tune0[0]
        misses = autotune.CACHE_STATS["miss"] - tune0[1]
        if hits:
            obs.metrics.counter("autotune_cache", outcome="hit").inc(hits)
        if misses:
            obs.metrics.counter("autotune_cache", outcome="miss").inc(misses)

    # ------------------------------------------------------------------
    # verb 1: one-vs-many classify
    # ------------------------------------------------------------------
    def classify(self, query, peers, *, bn: int | None = None,
                 bm: int | None = None,
                 interpret: bool | None = None) -> ClassifyResult:
        """Classify one query clock against N peers in one device call.

        ``query``: a ``BloomClock`` or ``[m]`` int32 logical cells.
        ``peers``: a ``PackedSlab`` (u8 kernel, shard_map'd when the
        policy carries a mesh, promoted rows overlaid exactly) or an
        ``[N, m]`` int32 slab / batched ``BloomClock`` (int32 kernel).
        """
        obs = self.obs
        if not obs:
            return self._classify(query, peers, bn=bn, bm=bm,
                                  interpret=interpret)
        tune0 = (autotune.CACHE_STATS["hit"], autotune.CACHE_STATS["miss"])
        n = peers.capacity if isinstance(peers, PackedSlab) else -1
        with obs.trace.span("causal.classify",
                            pack="slab" if isinstance(peers, PackedSlab)
                            else "i32") as sp:
            res = self._classify(query, peers, bn=bn, bm=bm,
                                 interpret=interpret)
            if n < 0:
                n = int(np.shape(res.sum_p)[-1])
            self._record_dispatch("classify", res, n, sp, tune0)
        return res

    def _classify(self, query, peers, *, bn, bm, interpret) -> ClassifyResult:
        pol = self.policy
        q = _as_cells(query)
        bn = bn if bn is not None else pol.bn
        bm = bm if bm is not None else pol.bm
        interpret = interpret if interpret is not None else pol.interpret
        ops.LAST_DISPATCH.clear()
        if isinstance(peers, PackedSlab):
            hot_meta = getattr(peers, "hot_meta", None)
            if hot_meta is not None and np.shape(hot_meta)[0] > 0:
                return self._classify_hybrid(q, peers, bn, bm, interpret)
            if pol.mesh is not None:
                out = ops._classify_vs_many_packed_sharded(
                    q, peers.cells_u8, peers.base, mesh=pol.mesh,
                    axis=pol.axis, bn=bn, bm=bm, interpret=interpret,
                    use_autotune=pol.autotune)
            else:
                out = ops._classify_vs_many_packed(
                    q, peers.cells_u8, peers.base, bn=bn, bm=bm,
                    interpret=interpret, use_autotune=pol.autotune)
            engine, blocks = _dispatch_label("packed")
            if peers.wide:
                widx = sorted(peers.wide)
                out = ops._overlay_wide_classify(
                    out, q, widx,
                    jnp.asarray(np.stack([peers.wide[s] for s in widx])),
                    interpret=interpret)
                engine += "+wide_overlay"
            return ClassifyResult.from_dict(out, engine=engine,
                                            blocks=blocks)
        cells = _as_cells(peers)
        kw = {}
        if bn is not None:
            kw["bn"] = bn
        if bm is not None:
            kw["bm"] = bm
        out = ops._classify_vs_many(q, cells, interpret=interpret, **kw)
        return ClassifyResult.from_dict(out, engine="i32")

    def _classify_hybrid(self, q, peers, bn, bm, interpret) -> ClassifyResult:
        """Hot-carrying slab (``repro.hybrid.HybridSlab``-shaped, duck
        typed on ``hot_meta``): ONE fused kernel sweep covers the exact
        hot rows and the packed bloom tail — hot verdicts come back with
        fp ≡ 0, tail verdicts bit-identical to a flat packed slab at the
        same blocks.  Result rows are hot-first: [0, H) hot, then the
        tail.  The hot set is a handful of metadata rows, so the sweep
        stays unsharded even under a mesh policy (the tail-sharded
        variant is a ROADMAP item)."""
        pol = self.policy
        out = ops._classify_hybrid(
            q, int(peers.local_version), peers.hot_meta, peers.hot_sums,
            peers.cells_u8, peers.base, bn=bn, bm=bm, interpret=interpret,
            use_autotune=pol.autotune)
        engine, blocks = _dispatch_label("hybrid")
        if peers.wide:
            # wide keys index TAIL slots; result rows shift by the hot
            # block, and the overlay must patch the shifted positions
            H = int(np.shape(peers.hot_meta)[0])
            widx = sorted(peers.wide)
            out = ops._overlay_wide_classify(
                out, q, [H + s for s in widx],
                jnp.asarray(np.stack([peers.wide[s] for s in widx])),
                interpret=interpret)
            engine += "+wide_overlay"
        return ClassifyResult.from_dict(out, engine=engine, blocks=blocks)

    # ------------------------------------------------------------------
    # verb 2: all-pairs compare
    # ------------------------------------------------------------------
    def pairs(self, clocks, cols=None, *, alive: np.ndarray | None = None,
              alive_dev: jax.Array | None = None,
              engine: str | None = None, bi: int | None = None,
              bj: int | None = None, bm: int | None = None,
              uniform_base: bool | None = None,
              interpret: bool | None = None) -> ComparisonMatrix:
        """All-pairs partial order + Eq. 3 fp over a batch of clocks.

        ``clocks``: a ``PackedSlab`` (symmetric; honors ``alive`` slot
        masking, promoted-row rims and the policy mesh) or an
        ``[N, m]`` int32 slab / batched ``BloomClock`` — optionally vs
        a second ``cols`` slab — where the engine packs on the fly when
        the value span fits a byte and falls back to the int32 kernel
        otherwise.

        ``alive``: host bool mask over slab slots; dead slots cost no
        compute (alive-compacted unsharded / masked sharded) and report
        all-False flags, zero fp and zero sums.  ``alive_dev`` is an
        optional pre-placed device copy (a sharded registry passes its
        mesh-placed mask so masking never re-uploads).
        """
        obs = self.obs
        if not obs:
            return self._pairs(clocks, cols, alive=alive,
                               alive_dev=alive_dev, engine=engine, bi=bi,
                               bj=bj, bm=bm, uniform_base=uniform_base,
                               interpret=interpret)
        tune0 = (autotune.CACHE_STATS["hit"], autotune.CACHE_STATS["miss"])
        with obs.trace.span("causal.pairs",
                            pack="slab" if isinstance(clocks, PackedSlab)
                            else "i32") as sp:
            res = self._pairs(clocks, cols, alive=alive,
                              alive_dev=alive_dev, engine=engine, bi=bi,
                              bj=bj, bm=bm, uniform_base=uniform_base,
                              interpret=interpret)
            self._record_dispatch("pairs", res, int(np.shape(res.le)[0]),
                                  sp, tune0)
        return res

    def _pairs(self, clocks, cols=None, *, alive=None, alive_dev=None,
               engine=None, bi=None, bj=None, bm=None, uniform_base=None,
               interpret=None) -> ComparisonMatrix:
        pol = self.policy
        engine = engine if engine is not None else pol.engine
        bi = bi if bi is not None else pol.bi
        bj = bj if bj is not None else pol.bj
        bm = bm if bm is not None else pol.bm
        interpret = interpret if interpret is not None else pol.interpret
        ops.LAST_DISPATCH.clear()
        if isinstance(clocks, PackedSlab):
            if getattr(clocks, "hot_meta", None) is not None:
                raise ValueError(
                    "hot-carrying slabs are classify-only here; use "
                    "repro.hybrid.HybridEngine.pairs for the fused "
                    "all-pairs sweep")
            if cols is not None:
                raise ValueError(
                    "PackedSlab pairs are symmetric; cols is not supported")
            return self._pairs_slab(clocks, alive, alive_dev, engine,
                                    bi, bj, bm, uniform_base, interpret)
        if alive is not None or alive_dev is not None:
            raise ValueError("alive masking needs a PackedSlab input")
        rows = _as_cells(clocks)
        if engine is None and not pol.pack:
            engine = "i32"
        cols_c = rows if cols is None else _as_cells(cols)
        out = ops._compare_matrix(
            rows, cols_c, engine=engine, bi=bi, bj=bj, bm=bm,
            interpret=interpret, use_autotune=pol.autotune)
        eng, blocks = _dispatch_label(engine or "auto")
        return ComparisonMatrix.from_dict(out, engine=eng, blocks=blocks)

    # ---- packed-slab assembly (compaction, promoted rims, masking) ----
    def _pairs_slab(self, slab: PackedSlab, alive, alive_dev, engine,
                    bi, bj, bm, uniform_base, interpret) -> ComparisonMatrix:
        pol = self.policy
        cap = slab.capacity
        alive = (np.ones(cap, bool) if alive is None
                 else np.asarray(alive, bool))
        aidx = np.flatnonzero(alive)
        kw = dict(engine=engine, bi=bi, bj=bj, bm=bm, interpret=interpret)
        if aidx.size == 0:
            false = jnp.zeros((cap, cap), bool)
            return ComparisonMatrix(
                le=false, ge=false, conc=false,
                fp=jnp.zeros((cap, cap), jnp.float32),
                row_sums=jnp.zeros((cap,), jnp.float32),
                col_sums=jnp.zeros((cap,), jnp.float32), engine="empty")
        if uniform_base is None:
            uniform_base = self._uniform_base(slab, alive)
        if pol.mesh is not None:
            # mesh placement only matters when the bulk is combined with
            # sharded masks/overlays below; the fully-alive packed fast
            # path returns it as-is, so the replicated strategy may skip
            # its output reshard
            bulk = ops._compare_matrix_packed_sharded(
                slab.cells_u8, slab.base, mesh=pol.mesh, axis=pol.axis,
                uniform_base=uniform_base, use_autotune=pol.autotune,
                mesh_outputs=not (aidx.size == cap and slab.packed), **kw)
            eng, blocks = _dispatch_label("ring_full")
            if aidx.size == cap and slab.packed:
                return ComparisonMatrix.from_dict(bulk, engine=eng,
                                                  blocks=blocks)
            if not slab.packed:
                # promoted rows: patch the O(P * A) int32 rim into the
                # bulk ON DEVICE — the [cap, cap] matrices stay sharded
                bulk = self._device_wide_overlay(slab, bulk, aidx, **kw)
                eng += "+wide_rim"
            # dead slots report nothing; masking is device-side too, so
            # a huge sharded fleet never materializes flags on host
            al = alive_dev if alive_dev is not None else jnp.asarray(alive)
            return ComparisonMatrix.from_dict(
                _mask_dead_pairs(bulk, al), engine=eng, blocks=blocks)
        if aidx.size == cap and slab.packed:
            out = ops._compare_matrix_packed(
                slab.cells_u8, slab.base, uniform_base=uniform_base,
                use_autotune=pol.autotune, **kw)
            eng, blocks = _dispatch_label("tri")
            return ComparisonMatrix.from_dict(out, engine=eng, blocks=blocks)
        if slab.packed:
            # gather the alive rows into a dense sub-slab: dead slots
            # cost no compute, results scatter back to full capacity
            jidx = jnp.asarray(aidx)
            sub = ops._compare_matrix_packed(
                jnp.take(slab.cells_u8, jidx, axis=0),
                jnp.take(slab.base, jidx),
                uniform_base=uniform_base, use_autotune=pol.autotune, **kw)
            eng, blocks = _dispatch_label("tri")
            return ComparisonMatrix.from_dict(
                _expand_alive(sub, jidx, cap), engine=eng, blocks=blocks)
        return self._host_pairs(slab, alive, aidx, **kw)

    @staticmethod
    def _uniform_base(slab: PackedSlab, alive: np.ndarray) -> bool | None:
        """Host-side base-uniformity probe over the alive rows; None
        (device probe in the impl) when no host base copy is carried."""
        if slab.base_host is None:
            return None
        b = np.asarray(slab.base_host)[alive]
        return bool(b.size == 0 or (b == b[0]).all())

    @staticmethod
    def _alive_widx(slab: PackedSlab, aidx: np.ndarray) -> np.ndarray:
        """Promoted slots restricted to the given alive index set."""
        keep = set(int(s) for s in aidx)
        return np.asarray(
            sorted(s for s in slab.wide if s in keep), np.int64)

    def _wide_rim(self, slab: PackedSlab, aidx: np.ndarray,
                  widx: np.ndarray, **kw) -> dict:
        """Exact int32 compare of the promoted rows vs every alive row
        ([P, A]).  Unpacks ONLY the gathered alive rows — never the
        full-capacity slab — and patches the promoted rows' true values
        over their clipped residuals.

        Known scale limit (ROADMAP): the gathered [A, m] int32 operand
        is placed by the gather, so on a mesh-sharded slab the rim
        still concentrates ~4x the alive u8 bytes on one device; a
        shard-wise rim (wide rows replicated vs each row shard under
        shard_map) would remove that.  Promoted rows contradict the §4
        moving-window premise, so fleets sharded for scale should treat
        them as an eviction signal, not steady state."""
        # interpret/block-shape overrides carry over; a packed-engine
        # hint does not (it can't run on overflowed rows) — and since a
        # promoted row's span exceeds a byte BY DEFINITION, name the
        # int32 engine outright and skip the futile span probe
        rim_kw = {kk: v for kk, v in kw.items()
                  if kk in ("interpret", "bi", "bj", "bm") and v is not None}
        rim_kw["engine"] = "i32"
        wide_rows = jnp.asarray(
            np.stack([slab.wide[int(s)] for s in widx]))
        jaidx = jnp.asarray(aidx)
        alive_i32 = pack.unpack_rows(
            jnp.take(slab.cells_u8, jaidx, axis=0),
            jnp.take(slab.base, jaidx))
        wpos = {int(s): i for i, s in enumerate(aidx)}
        alive_i32 = alive_i32.at[
            jnp.asarray([wpos[int(s)] for s in widx])].set(wide_rows)
        return ops._compare_matrix(wide_rows, alive_i32,
                                   use_autotune=self.policy.autotune,
                                   **rim_kw)

    def _device_wide_overlay(self, slab: PackedSlab, bulk: dict,
                             aidx: np.ndarray, **kw) -> dict:
        """Patch the promoted rows'/cols' flags into the sharded bulk and
        re-finalize fp from corrected sums, entirely ON DEVICE — the
        [cap, cap] matrices stay sharded, so even a promoted row on a
        fleet too large for one device costs only the O(P * cap) rim."""
        cap, m = slab.capacity, slab.m
        widx = self._alive_widx(slab, aidx)
        if widx.size == 0:
            return bulk
        rim = self._wide_rim(slab, aidx, widx, **kw)
        jw = jnp.asarray(widx)
        jaidx = jnp.asarray(aidx)
        P = int(widx.size)

        def patch(mat, row_pa, col_pa):
            rows_full = jnp.zeros((P, cap), bool).at[:, jaidx].set(row_pa)
            cols_full = jnp.zeros((P, cap), bool).at[:, jaidx].set(col_pa)
            mat = jnp.asarray(mat, bool).at[jw, :].set(rows_full)
            return mat.at[:, jw].set(cols_full.T)

        le = patch(bulk["a_le_b"], rim["a_le_b"], rim["b_le_a"])
        ge = patch(bulk["b_le_a"], rim["b_le_a"], rim["a_le_b"])
        sums = jnp.asarray(bulk["row_sums"]).at[jw].set(rim["row_sums"])
        return {
            "a_le_b": le, "b_le_a": ge,
            "concurrent": jnp.logical_not(jnp.logical_or(le, ge)),
            # same jitted Eq. 3 expression as every engine finalize, over
            # the corrected sums -> bit-identical to the unsharded path
            "fp": ops.eq3_outer(sums, sums, m),
            "row_sums": sums, "col_sums": sums,
        }

    def _host_pairs(self, slab: PackedSlab, alive: np.ndarray,
                    aidx: np.ndarray, **kw) -> ComparisonMatrix:
        """Unsharded sparse promoted-row assembly: packed engines over
        the still-packed alive rows plus the exact int32 rim for the
        promoted handful, stitched on host (the slab already lives on
        one device here — the sharded path patches on device instead,
        see ``_device_wide_overlay``).  fp is re-finalized from the
        corrected sums through the SAME jitted Eq. 3 expression the
        engines use (``ops.eq3_outer``), so values stay bit-identical
        to the single-device int32 fallback this replaces."""
        cap, m = slab.capacity, slab.m
        kw = {kk: v for kk, v in kw.items() if v is not None}
        widx = self._alive_widx(slab, aidx)
        le = np.zeros((cap, cap), bool)
        ge = np.zeros((cap, cap), bool)
        sums = np.zeros(cap, np.float32)
        pidx = np.asarray([s for s in aidx if s not in slab.wide],
                          np.int64)
        eng = "none"
        if pidx.size:
            if slab.base_host is not None:
                b = slab.base_host[pidx]
                uniform = bool((b == b[0]).all())
            else:
                uniform = None     # no host copy: let the impl probe
            sub = jax.device_get(ops._compare_matrix_packed(
                jnp.take(slab.cells_u8, jnp.asarray(pidx), axis=0),
                jnp.take(slab.base, jnp.asarray(pidx)),
                uniform_base=uniform,
                use_autotune=self.policy.autotune, **kw))
            eng, _ = _dispatch_label("tri")
            le[np.ix_(pidx, pidx)] = sub["a_le_b"]
            ge[np.ix_(pidx, pidx)] = sub["b_le_a"]
            sums[pidx] = sub["row_sums"]
        if widx.size:
            rim = jax.device_get(self._wide_rim(slab, aidx, widx, **kw))
            eng += "+wide_rim"
            le[np.ix_(widx, aidx)] = rim["a_le_b"]
            ge[np.ix_(widx, aidx)] = rim["b_le_a"]
            le[np.ix_(aidx, widx)] = rim["b_le_a"].T
            ge[np.ix_(aidx, widx)] = rim["a_le_b"].T
            sums[widx] = rim["row_sums"]
        le[~alive] = False
        le[:, ~alive] = False
        ge[~alive] = False
        ge[:, ~alive] = False
        sums[~alive] = 0.0
        pair = np.ix_(aidx, aidx)
        conc = np.zeros((cap, cap), bool)
        conc[pair] = ~(le[pair] | ge[pair])
        fp = np.zeros((cap, cap), np.float32)
        fp[pair] = np.asarray(ops.eq3_outer(
            jnp.asarray(sums[aidx]), jnp.asarray(sums[aidx]), m))
        s = jnp.asarray(sums)
        return ComparisonMatrix(
            le=jnp.asarray(le), ge=jnp.asarray(ge), conc=jnp.asarray(conc),
            fp=jnp.asarray(fp), row_sums=s, col_sums=s, engine=eng)


@jax.jit
def _mask_dead_pairs(bulk: dict, alive: jax.Array) -> dict:
    """Device-side dead-slot masking of a full-capacity all-pairs bulk:
    the sharded ring's counterpart of ``_expand_alive`` (same contract —
    dead rows/cols report all-False flags and zero fp / sums)."""
    pair = alive[:, None] & alive[None, :]
    le = jnp.asarray(bulk["a_le_b"], bool) & pair
    ge = jnp.asarray(bulk["b_le_a"], bool) & pair
    sums = jnp.where(alive, bulk["row_sums"], 0.0)
    return {
        "a_le_b": le,
        "b_le_a": ge,
        "concurrent": jnp.logical_not(jnp.logical_or(le, ge)) & pair,
        "fp": jnp.where(pair, bulk["fp"], 0.0),
        "row_sums": sums,
        "col_sums": sums,
    }


def _expand_alive(sub: dict, jidx: jax.Array, cap: int) -> dict:
    """Scatter an alive-compacted result back to [capacity, capacity]."""
    rows = jidx[:, None]
    cols = jidx[None, :]

    def mat(x, fill, dtype):
        return jnp.full((cap, cap), fill, dtype).at[rows, cols].set(x)

    def vec(x):
        return jnp.zeros((cap,), x.dtype).at[jidx].set(x)

    return {
        "a_le_b": mat(sub["a_le_b"], False, bool),
        "b_le_a": mat(sub["b_le_a"], False, bool),
        "concurrent": mat(sub["concurrent"], False, bool),
        "fp": mat(sub["fp"], 0.0, jnp.float32),
        "row_sums": vec(sub["row_sums"]),
        "col_sums": vec(sub["col_sums"]),
    }
