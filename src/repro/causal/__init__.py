"""Unified causality API — the public surface of the reproduction.

The paper's contract ("compare two timestamps, get a partial order plus
an Eq. 3 confidence") behind one policy, two verbs and three typed
results:

    from repro import causal

    policy = causal.CausalPolicy(fp_threshold=1e-4)
    engine = causal.CausalEngine(policy)

    engine.classify(query, peers)   # one-vs-many -> ClassifyResult
    engine.pairs(clocks)            # all-pairs   -> ComparisonMatrix
    causal.compare(a, b)            # pairwise    -> Comparison

Every compare engine (int32 fallback, packed u8 triangle/rectangle, MXU
thermometer, promoted-row overlay, shard_map'd sharded paths) sits
behind the two verbs; results carry ``.before() / .after() /
.concurrent() / .confident(threshold)`` so the Eq. 3 gate is applied
one way everywhere.  The pre-front-door entry points (``kernels.ops.*``
comparison wrappers, ``core.clock.compare``) remain importable as
bit-identical ``DeprecationWarning`` shims.
"""
from repro.causal.engine import CausalEngine, PackedSlab, compare
from repro.causal.policy import CausalPolicy
from repro.causal.results import ClassifyResult, Comparison, ComparisonMatrix

__all__ = [
    "CausalEngine",
    "CausalPolicy",
    "PackedSlab",
    "Comparison",
    "ComparisonMatrix",
    "ClassifyResult",
    "compare",
]
