"""ClockRuntime: the bloom clock wired into the training/serving fleet.

Every process keeps one BloomClock.  Events that tick it:
  - data batches consumed        (event id = hash(run_id, "batch", step))
  - optimizer steps committed    (hash(run_id, "step", step))
  - checkpoints written          (hash(run_id, "ckpt", step))
  - elastic membership changes   (hash(run_id, "scale", epoch, n_new))
  - serving requests admitted    (hash(session, seq_no))

Decisions the runtime takes from clock comparisons (all O(m), independent
of fleet size — the paper's point):
  - **checkpoint lineage**: a restore is legal iff ckpt.clock ≼ live clock
    (or live is empty); a *forked* lineage (concurrent clocks) aborts.
  - **async merge guard**: a peer's update is merged iff its clock is
    comparable with ours within an Eq.-3 fp threshold; concurrent clocks
    mean a missed sync -> the update is quarantined (returned to caller).
  - **straggler detection**: clock sums are monotone progress counters;
    peers lagging more than ``straggler_gap`` ticks are skipped, no
    barrier.

The pairwise receive path (lineage / admit_merge) runs through the fused
``kernels.ops.merge_compare`` Pallas op: one device call and one host
transfer per message.  Fleet-facing paths use ``repro.fleet`` (peer
slab + one-vs-many kernel) via ``classify_fleet``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.causal import CausalEngine, CausalPolicy
from repro.core import clock as bc
from repro.core import history as hist
from repro.core.hashing import stable_event_id
from repro.kernels import ops

__all__ = ["ClockConfig", "ClockRuntime", "LineageStatus", "CheckpointLineage"]


@dataclasses.dataclass(frozen=True)
class ClockConfig:
    m: int = 1024            # cells — 4KB/clock on the wire (int32)
    k: int = 4               # probes/event
    fp_threshold: float = 1e-4
    history_window: int = 32
    straggler_gap: float = 64.0  # clock-sum ticks
    # full causality policy (engine preference, mesh, block shapes, ...);
    # None derives one from fp_threshold.  When set, its fp_threshold is
    # the one the runtime gates on — the single source of truth threaded
    # through registry construction, gossip, serving and checkpoints.
    policy: Optional[CausalPolicy] = None

    def causal_policy(self) -> CausalPolicy:
        return (self.policy if self.policy is not None
                else CausalPolicy(fp_threshold=self.fp_threshold))


class LineageStatus:
    ANCESTOR = "ancestor"        # other ≼ mine: other is in my past (safe)
    SAME = "same"
    DESCENDANT = "descendant"    # mine ≼ other: other is ahead of me
    FORKED = "forked"            # concurrent: split brain / missed sync


@dataclasses.dataclass
class CheckpointLineage:
    """One ``CausalEngine.classify`` call over a checkpoint directory.

    Entries are sorted by step; ``safe`` mirrors ``admit_restore``'s
    decision rule per checkpoint.
    """

    steps: np.ndarray            # int64 [S]
    status: list                 # LineageStatus string per step
    fp: np.ndarray               # float32 [S] Eq. 3 fp of the claim
    safe: np.ndarray             # bool [S] restorable without forking

    def latest_safe(self) -> Optional[int]:
        idx = np.flatnonzero(self.safe)
        return int(self.steps[idx[-1]]) if idx.size else None

    def summary(self) -> str:
        return " ".join(
            f"step_{s}:{st}{'' if ok else '(unsafe)'}"
            for s, st, ok in zip(self.steps, self.status, self.safe))


class ClockRuntime:
    def __init__(self, cfg: ClockConfig, run_id: str = "run0",
                 observer=None):
        self.cfg = cfg
        self.run_id = run_id
        self.policy = cfg.causal_policy()
        if observer is not None:
            # thread the instrumentation rider through the policy: the
            # engine below, every make_registry() slab and every
            # gossip() session inherit it with no further arguments
            self.policy = dataclasses.replace(self.policy,
                                              observer=observer)
        self.causal = CausalEngine(self.policy)
        self.obs = self.causal.obs
        self.clock = bc.zeros(cfg.m, cfg.k)
        self.history = hist.init(cfg.history_window, cfg.m, cfg.k)

    # ---- events ----
    def tick(self, *parts) -> None:
        hi, lo = stable_event_id(self.run_id, *parts)
        self.clock = bc.tick(self.clock, jnp.uint32(hi), jnp.uint32(lo))
        self.history = hist.push(self.history, self.clock)

    def tick_step(self, step: int) -> None:
        self.tick("step", step)

    def tick_batch(self, step: int) -> None:
        self.tick("batch", step)

    def tick_checkpoint(self, step: int) -> None:
        self.tick("ckpt", step)

    def tick_scale_event(self, epoch: int, n_members: int) -> None:
        self.tick("scale", epoch, n_members)

    # ---- comparisons ----
    def _classify(self, other: bc.BloomClock):
        """Fused receive-path compare: ONE device call (merged cells,
        dominance flags, sums, Eq.-3 fp via ``kernels.ops.merge_compare``)
        and ONE host transfer — no per-predicate ``bool()`` round-trips.

        Returns (status, fp, merged_cells[m] int32 host array).
        """
        r = ops.merge_compare(
            other.logical_cells()[None].astype(jnp.int32),
            self.clock.logical_cells()[None].astype(jnp.int32))
        h = jax.device_get(r)
        a_le_b = bool(h["a_le_b"][0])     # other ≼ mine
        b_le_a = bool(h["b_le_a"][0])     # mine ≼ other
        if a_le_b and b_le_a:
            return LineageStatus.SAME, 0.0, h["merged"][0]
        if a_le_b:
            return LineageStatus.ANCESTOR, float(h["fp_a_before_b"][0]), h["merged"][0]
        if b_le_a:
            return LineageStatus.DESCENDANT, float(h["fp_b_before_a"][0]), h["merged"][0]
        # exact — no false negatives (§3)
        return LineageStatus.FORKED, 0.0, h["merged"][0]

    def lineage(self, other: bc.BloomClock) -> tuple[str, float]:
        """Classify another clock against ours + Eq.-3 confidence."""
        status, fp, _ = self._classify(other)
        return status, fp

    def classify_fleet(self, registry):
        """Classify every peer in a ``fleet.ClockRegistry`` against our
        clock in one device call (see registry.classify_all)."""
        return registry.classify_all(self.clock)

    def make_registry(self, capacity: int, *, mesh=None, axis: str | None = None):
        """Fleet registry sized to this runtime's clock config, carrying
        this runtime's CausalPolicy (one source of truth for fp gates
        and engine dispatch).

        Pass a mesh (``launch.mesh.make_fleet_mesh``) to shard the peer
        slab over devices — classify_fleet then runs the shard_map'ed
        kernels transparently, with results bit-identical to the
        single-device slab.
        """
        from repro.fleet.registry import ClockRegistry
        from repro.sharding import FLEET_AXIS
        return ClockRegistry(capacity, m=self.cfg.m, k=self.cfg.k,
                             mesh=mesh, axis=FLEET_AXIS if axis is None else axis,
                             policy=self.policy)

    def gossip(self, registry, cfg=None, transport=None):
        """One anti-entropy session; the merged union becomes the
        runtime clock.

        ``transport`` picks the fabric (``fleet.transport``): default is
        a ``LoopbackTransport`` over ``registry`` — the single-process
        round.  Pass a ``MeshCollectiveTransport`` for a mesh-sharded
        registry or a ``SocketTransport`` to reconcile with real peer
        processes (``registry`` is then the staging replica the wire
        frames sync).  The session gates on this runtime's
        ``CausalPolicy`` unless ``cfg`` overrides it.
        """
        from repro.fleet.gossip import GossipConfig
        from repro.fleet.transport import LoopbackTransport
        from repro.fleet.transport.session import anti_entropy_session
        if cfg is None:
            cfg = GossipConfig(policy=self.policy,
                               straggler_gap=self.cfg.straggler_gap)
        if transport is None:
            transport = LoopbackTransport(registry)
        merged, report = anti_entropy_session(
            registry, self.clock, transport, cfg)
        self.clock = merged
        return report

    def refined_fp(self, other: bc.BloomClock) -> float:
        """§3 history refinement: fp against the closest dominating stored
        timestamp instead of the newest."""
        fp, _ = hist.best_predecessor_fp(self.history, other)
        return float(fp)

    def admit_restore(self, ckpt_clock: bc.BloomClock) -> tuple[bool, str, float]:
        """Is restoring from this checkpoint causally safe?"""
        status, fp = self.lineage(ckpt_clock)
        if status == LineageStatus.FORKED:
            return False, status, fp
        if status == LineageStatus.ANCESTOR:
            fp = min(fp, self.refined_fp(ckpt_clock))
            return fp <= self.policy.fp_threshold or float(bc.clock_sum(self.clock)) == 0.0, status, fp
        return True, status, fp

    def classify_checkpoints(self, manager) -> CheckpointLineage:
        """Classify a WHOLE checkpoint directory against the live clock
        in one ``causal.classify`` device call (manifests only — no
        state tensors are read).

        Replaces the one-``admit_restore``-per-checkpoint loop: one
        kernel sweep over the stacked manifest clocks, then the same
        decision rule.  ANCESTOR candidates that miss the fp gate get
        the §3 history refinement (there are usually zero or one).
        """
        entries = manager.clock_manifests()
        steps = np.asarray([s for s, _ in entries], np.int64)
        if not entries:
            return CheckpointLineage(
                steps=steps, status=[],
                fp=np.zeros(0, np.float32), safe=np.zeros(0, bool))
        clocks = [self.clock_from_snapshot(man["clock"]) for _, man in entries]
        stacked = jnp.stack(
            [c.logical_cells().astype(jnp.int32) for c in clocks])
        res = jax.device_get(self.causal.classify(self.clock, stacked))
        p_le_q, q_le_p = res.after(), res.before()
        thr = self.policy.fp_threshold
        live_empty = float(bc.clock_sum(self.clock)) == 0.0
        status, fp, safe = [], [], []
        for i in range(len(entries)):
            if p_le_q[i] and q_le_p[i]:
                st, f, ok = LineageStatus.SAME, 0.0, True
            elif p_le_q[i]:
                st, f = LineageStatus.ANCESTOR, float(res.fp_p_before_q[i])
                if f > thr and not live_empty:
                    f = min(f, self.refined_fp(clocks[i]))
                ok = f <= thr or live_empty
            elif q_le_p[i]:
                st, f, ok = (LineageStatus.DESCENDANT,
                             float(res.fp_q_before_p[i]), True)
            else:
                st, f, ok = LineageStatus.FORKED, 0.0, False
            status.append(st)
            fp.append(f)
            safe.append(ok)
        return CheckpointLineage(
            steps=steps, status=status,
            fp=np.asarray(fp, np.float32), safe=np.asarray(safe, bool))

    def admit_restore_latest(self, manager) -> tuple[Optional[int], CheckpointLineage]:
        """Newest causally-safe checkpoint step in the directory (or
        None), plus the full per-checkpoint lineage."""
        lineage = self.classify_checkpoints(manager)
        return lineage.latest_safe(), lineage

    def admit_merge(self, peer_clock: bc.BloomClock) -> tuple[bool, str, float]:
        """Async outer-loop guard: merge a peer's update?

        Comparable (either direction) with confident fp -> merge + clock max.
        Concurrent -> quarantine (the peer missed a sync barrier).
        The merged cells come from the SAME fused kernel call as the
        decision — the accept path costs no extra device work.
        """
        status, fp, merged = self._classify(peer_clock)
        ok = status != LineageStatus.FORKED and fp <= self.policy.fp_threshold
        if ok:
            self.clock = bc.compress(bc.BloomClock(
                cells=jnp.asarray(merged, jnp.int32),
                base=jnp.zeros((), jnp.int32),
                k=self.clock.k))
        return ok, status, fp

    # ---- straggler policy ----
    def straggler_mask(self, peer_sums: np.ndarray) -> np.ndarray:
        """True for peers to SKIP this round (too far behind the median)."""
        med = np.median(peer_sums)
        return (med - np.asarray(peer_sums)) > self.cfg.straggler_gap

    # ---- wire format ----
    def snapshot(self) -> dict:
        """Wire/persist form: §4 compression + u8 residual quantization
        when the window fits a byte (see ``core.clock.to_wire``)."""
        return bc.to_wire(self.clock)

    @staticmethod
    def clock_from_snapshot(snap: dict) -> bc.BloomClock:
        return bc.from_wire(snap)
