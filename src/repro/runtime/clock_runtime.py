"""ClockRuntime: the bloom clock wired into the training/serving fleet.

Every process keeps one BloomClock.  Events that tick it:
  - data batches consumed        (event id = hash(run_id, "batch", step))
  - optimizer steps committed    (hash(run_id, "step", step))
  - checkpoints written          (hash(run_id, "ckpt", step))
  - elastic membership changes   (hash(run_id, "scale", epoch, n_new))
  - serving requests admitted    (hash(session, seq_no))

Decisions the runtime takes from clock comparisons (all O(m), independent
of fleet size — the paper's point):
  - **checkpoint lineage**: a restore is legal iff ckpt.clock ≼ live clock
    (or live is empty); a *forked* lineage (concurrent clocks) aborts.
  - **async merge guard**: a peer's update is merged iff its clock is
    comparable with ours within an Eq.-3 fp threshold; concurrent clocks
    mean a missed sync -> the update is quarantined (returned to caller).
  - **straggler detection**: clock sums are monotone progress counters;
    peers lagging more than ``straggler_gap`` ticks are skipped, no
    barrier.

The pairwise receive path (lineage / admit_merge) runs through the fused
``kernels.ops.merge_compare`` Pallas op: one device call and one host
transfer per message.  Fleet-facing paths use ``repro.fleet`` (peer
slab + one-vs-many kernel) via ``classify_fleet``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clock as bc
from repro.core import history as hist
from repro.core.hashing import stable_event_id
from repro.kernels import ops

__all__ = ["ClockConfig", "ClockRuntime", "LineageStatus"]


@dataclasses.dataclass(frozen=True)
class ClockConfig:
    m: int = 1024            # cells — 4KB/clock on the wire (int32)
    k: int = 4               # probes/event
    fp_threshold: float = 1e-4
    history_window: int = 32
    straggler_gap: float = 64.0  # clock-sum ticks


class LineageStatus:
    ANCESTOR = "ancestor"        # other ≼ mine: other is in my past (safe)
    SAME = "same"
    DESCENDANT = "descendant"    # mine ≼ other: other is ahead of me
    FORKED = "forked"            # concurrent: split brain / missed sync


class ClockRuntime:
    def __init__(self, cfg: ClockConfig, run_id: str = "run0"):
        self.cfg = cfg
        self.run_id = run_id
        self.clock = bc.zeros(cfg.m, cfg.k)
        self.history = hist.init(cfg.history_window, cfg.m, cfg.k)

    # ---- events ----
    def tick(self, *parts) -> None:
        hi, lo = stable_event_id(self.run_id, *parts)
        self.clock = bc.tick(self.clock, jnp.uint32(hi), jnp.uint32(lo))
        self.history = hist.push(self.history, self.clock)

    def tick_step(self, step: int) -> None:
        self.tick("step", step)

    def tick_batch(self, step: int) -> None:
        self.tick("batch", step)

    def tick_checkpoint(self, step: int) -> None:
        self.tick("ckpt", step)

    def tick_scale_event(self, epoch: int, n_members: int) -> None:
        self.tick("scale", epoch, n_members)

    # ---- comparisons ----
    def _classify(self, other: bc.BloomClock):
        """Fused receive-path compare: ONE device call (merged cells,
        dominance flags, sums, Eq.-3 fp via ``kernels.ops.merge_compare``)
        and ONE host transfer — no per-predicate ``bool()`` round-trips.

        Returns (status, fp, merged_cells[m] int32 host array).
        """
        r = ops.merge_compare(
            other.logical_cells()[None].astype(jnp.int32),
            self.clock.logical_cells()[None].astype(jnp.int32))
        h = jax.device_get(r)
        a_le_b = bool(h["a_le_b"][0])     # other ≼ mine
        b_le_a = bool(h["b_le_a"][0])     # mine ≼ other
        if a_le_b and b_le_a:
            return LineageStatus.SAME, 0.0, h["merged"][0]
        if a_le_b:
            return LineageStatus.ANCESTOR, float(h["fp_a_before_b"][0]), h["merged"][0]
        if b_le_a:
            return LineageStatus.DESCENDANT, float(h["fp_b_before_a"][0]), h["merged"][0]
        # exact — no false negatives (§3)
        return LineageStatus.FORKED, 0.0, h["merged"][0]

    def lineage(self, other: bc.BloomClock) -> tuple[str, float]:
        """Classify another clock against ours + Eq.-3 confidence."""
        status, fp, _ = self._classify(other)
        return status, fp

    def classify_fleet(self, registry):
        """Classify every peer in a ``fleet.ClockRegistry`` against our
        clock in one device call (see registry.classify_all)."""
        return registry.classify_all(self.clock)

    def refined_fp(self, other: bc.BloomClock) -> float:
        """§3 history refinement: fp against the closest dominating stored
        timestamp instead of the newest."""
        fp, _ = hist.best_predecessor_fp(self.history, other)
        return float(fp)

    def admit_restore(self, ckpt_clock: bc.BloomClock) -> tuple[bool, str, float]:
        """Is restoring from this checkpoint causally safe?"""
        status, fp = self.lineage(ckpt_clock)
        if status == LineageStatus.FORKED:
            return False, status, fp
        if status == LineageStatus.ANCESTOR:
            fp = min(fp, self.refined_fp(ckpt_clock))
            return fp <= self.cfg.fp_threshold or float(bc.clock_sum(self.clock)) == 0.0, status, fp
        return True, status, fp

    def admit_merge(self, peer_clock: bc.BloomClock) -> tuple[bool, str, float]:
        """Async outer-loop guard: merge a peer's update?

        Comparable (either direction) with confident fp -> merge + clock max.
        Concurrent -> quarantine (the peer missed a sync barrier).
        The merged cells come from the SAME fused kernel call as the
        decision — the accept path costs no extra device work.
        """
        status, fp, merged = self._classify(peer_clock)
        ok = status != LineageStatus.FORKED and fp <= self.cfg.fp_threshold
        if ok:
            self.clock = bc.compress(bc.BloomClock(
                cells=jnp.asarray(merged, jnp.int32),
                base=jnp.zeros((), jnp.int32),
                k=self.clock.k))
        return ok, status, fp

    # ---- straggler policy ----
    def straggler_mask(self, peer_sums: np.ndarray) -> np.ndarray:
        """True for peers to SKIP this round (too far behind the median)."""
        med = np.median(peer_sums)
        return (med - np.asarray(peer_sums)) > self.cfg.straggler_gap

    # ---- wire format ----
    def snapshot(self) -> dict:
        c = bc.compress(self.clock)
        return {
            "cells": np.asarray(c.cells),
            "base": int(c.base),
            "k": c.k,
        }

    @staticmethod
    def clock_from_snapshot(snap: dict) -> bc.BloomClock:
        return bc.BloomClock(
            cells=jnp.asarray(snap["cells"], jnp.int32),
            base=jnp.asarray(int(snap["base"]), jnp.int32),
            k=int(snap["k"]),
        )
