"""Async multi-pod training (DiLoCo-style local SGD) with clock-guarded
merges — the flagship integration of the paper's technique.

Topology: P pods each run H local AdamW steps on their own data shard
(no cross-pod traffic), then an *outer* step averages pod deltas under a
Nesterov outer optimizer.  Pods are unreliable: they can straggle
(skip rounds) or fork (restart from a stale checkpoint and miss outer
syncs).  The coordinator decides WHOSE deltas to merge purely from bloom
clocks:

  - every pod ticks per local step and per outer sync it participates in;
  - at sync, a pod's clock must be COMPARABLE with the coordinator's
    (within the Eq.-3 fp threshold).  A forked pod has ticked events the
    coordinator never saw (and vice versa) -> clocks concurrent -> its
    delta is quarantined, exactly the causality-violation detection the
    paper promises — with O(m) state, independent of pod count (vector
    clocks would need O(P) and resizing on elastic events).
  - stragglers are skipped by clock-sum gap, no barrier.

This module runs REAL training (tiny models on CPU in tests/examples; the
same code drives pods at scale) — the pod fleet is simulated in-process,
the decision logic is production-shaped.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clock as bc
from repro.core.hashing import stable_event_id
from repro.fleet.registry import ANCESTOR, DESCENDANT, FORKED, SAME, ClockRegistry
from repro.runtime.clock_runtime import ClockConfig, ClockRuntime, LineageStatus

__all__ = ["AsyncConfig", "PodState", "AsyncCoordinator"]


@dataclasses.dataclass(frozen=True)
class AsyncConfig:
    n_pods: int = 4
    local_steps: int = 8          # H
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    grad_compress: bool = True    # bf16 delta exchange + error feedback


@dataclasses.dataclass
class PodState:
    pod_id: int
    params: dict
    clock: ClockRuntime
    err_feedback: Optional[dict] = None   # compression residual
    alive: bool = True


def _compress_delta(delta: dict, err: Optional[dict]):
    """bf16 wire compression with error feedback (residual carried fwd)."""
    if err is None:
        err = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), delta)
    full = jax.tree.map(lambda d, e: d.astype(jnp.float32) + e, delta, err)
    wire = jax.tree.map(lambda x: x.astype(jnp.bfloat16), full)
    new_err = jax.tree.map(lambda f, w: f - w.astype(jnp.float32), full, wire)
    return wire, new_err


class AsyncCoordinator:
    """Holds the global params + outer optimizer + its own clock."""

    def __init__(self, params: dict, a_cfg: AsyncConfig, c_cfg: ClockConfig,
                 run_id: str = "async0"):
        self.cfg = a_cfg
        self.params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
        self.momentum = jax.tree.map(jnp.zeros_like, self.params)
        self.clock = ClockRuntime(c_cfg, run_id=run_id)
        # fleet registry: one slab row per pod clock; all per-round
        # classification happens in ONE device call against it, under
        # the runtime's CausalPolicy (one source of truth for dispatch)
        self.registry = ClockRegistry(
            capacity=max(16, 4 * a_cfg.n_pods), m=c_cfg.m, k=c_cfg.k,
            policy=self.clock.policy)
        self.run_id = run_id
        self.round = 0
        self.log: list = []

    def add_pods(self, pod_ids: list, c_cfg: ClockConfig) -> list:
        """Elastic membership commit: one scale event for the whole epoch,
        then every (new and existing-via-next-sync) member inherits the
        coordinator's causal history.  Committing per-pod would make pod i
        concurrent with pods spawned after it — the clock itself caught
        that protocol bug in testing."""
        self.clock.tick_scale_event(self.round, len(pod_ids))
        pods = []
        for pid in pod_ids:
            rt = ClockRuntime(c_cfg, run_id=self.run_id)
            rt.clock = bc.merge(rt.clock, self.clock.clock)
            pods.append(PodState(pod_id=pid, params=dict(self.params), clock=rt))
        self.registry.admit_many({p.pod_id: p.clock.clock for p in pods})
        return pods

    def spawn_pod(self, pod_id: int, c_cfg: ClockConfig) -> PodState:
        return self.add_pods([pod_id], c_cfg)[0]

    def outer_step(self, pods: list, deltas: dict) -> dict:
        """One outer sync. deltas: {pod_id: delta pytree}.

        Returns per-pod decisions {pod_id: (merged, status, fp)}.

        The causal gating is fleet-batched: pod clocks are scattered
        into the registry (one device call) and classified against the
        coordinator's clock by the fused one-vs-many kernel (one more) —
        per-pod work is pure host bookkeeping, so the sync cost no
        longer scales with pod count times device round-trips.
        """
        decisions = {}
        # retired pods free their slots: elastic churn through arbitrarily
        # many pod ids must not exhaust the fixed-capacity registry
        current = {p.pod_id for p in pods}
        self.registry.evict_many(
            [pid for pid in self.registry.peer_ids() if pid not in current])
        known = {p.pod_id: p for p in pods if p.pod_id in self.registry}
        late = [p for p in pods if p.pod_id not in self.registry]
        if late:   # pods spawned outside add_pods (elastic joins mid-test)
            self.registry.admit_many({p.pod_id: p.clock.clock for p in late})
            known.update({p.pod_id: p for p in late})
        self.registry.update_many(
            {pid: p.clock.clock for pid, p in known.items()})
        view = self.clock.classify_fleet(self.registry)

        # straggler skip by clock-sum gap, over the participating pods
        slot = {pid: self.registry.slot_of(pid) for pid in known}
        sums = np.array([float(view.sums[slot[p.pod_id]]) for p in pods])
        skip = self.clock.straggler_mask(sums)

        accepted = []
        accept_mask = np.zeros(self.registry.capacity, bool)
        for i, pod in enumerate(pods):
            if pod.pod_id not in deltas or not pod.alive:
                decisions[pod.pod_id] = (False, "dead", 0.0)
                continue
            # fork detection first: a forked pod's delta is never safe, no
            # matter how fresh it looks
            s = slot[pod.pod_id]
            status_code = int(view.status[s])
            fp = float(view.fp[s])
            if status_code == FORKED:
                decisions[pod.pod_id] = (False, LineageStatus.FORKED, fp)
                continue
            if skip[i]:
                decisions[pod.pod_id] = (False, "straggler", 0.0)
                continue
            status = {ANCESTOR: LineageStatus.ANCESTOR,
                      SAME: LineageStatus.SAME,
                      DESCENDANT: LineageStatus.DESCENDANT}[status_code]
            decisions[pod.pod_id] = (True, status, fp)
            accepted.append(pod.pod_id)
            accept_mask[s] = True

        if accepted:
            avg = jax.tree.map(
                lambda *xs: sum(x.astype(jnp.float32) for x in xs) / len(xs),
                *[deltas[p] for p in accepted])
            self.momentum = jax.tree.map(
                lambda m, d: self.cfg.outer_momentum * m + d, self.momentum, avg)
            self.params = jax.tree.map(
                lambda p, m, d: p + self.cfg.outer_lr * (
                    self.cfg.outer_momentum * m + d),  # nesterov
                self.params, self.momentum, avg)

        # commit: the coordinator ABSORBS accepted pods' clocks (paper §3
        # receive rule — merge by max, batched into ONE slab reduction),
        # ticks the round, and publishes the union.  Publishing the union
        # is what lets a skipped straggler catch up: after resync its
        # clock-sum equals the fleet's, so the gap measures only fresh
        # progress, not permanently-missed ticks.
        if accept_mask.any():
            self.clock.clock = self.registry.union(accept_mask, self.clock.clock)
        self.clock.tick("outer", self.round)
        self.clock.clock = bc.compress(self.clock.clock)
        # every accepted pod is ≼ the pre-tick union, so merging with the
        # published clock just yields the published clock: assign it.
        self.registry.broadcast(accept_mask, self.clock.clock)
        for pod in pods:
            if decisions[pod.pod_id][0]:
                pod.clock.clock = self.clock.clock
                pod.params = dict(self.params)
        self.round += 1
        self.log.append({p: d for p, d in decisions.items()})
        return decisions


def run_pod_round(pod: PodState, train_step: Callable, data_fn: Callable,
                  a_cfg: AsyncConfig, base_step: int):
    """H local steps on a pod; returns (delta, pod) with clocks ticked."""
    start = jax.tree.map(lambda x: x.astype(jnp.float32), pod.params)
    params = pod.params
    for h in range(a_cfg.local_steps):
        step_id = base_step + h
        batch = data_fn(pod.pod_id, step_id)
        params, _ = train_step(params, batch)
        pod.clock.tick("pod", pod.pod_id, "step", step_id)
    pod.params = params
    delta = jax.tree.map(lambda p, s: p.astype(jnp.float32) - s, params, start)
    if a_cfg.grad_compress:
        delta, pod.err_feedback = _compress_delta(delta, pod.err_feedback)
    return delta, pod
