"""Train step construction: loss, grads, AdamW, in-graph clock tick.

The bloom clock rides inside the jitted step as part of TrainState (m int32
cells): each committed step ticks it with the batch event id, so the clock
is *part of the replicated training state* — a checkpoint written at step
N carries exactly the causal history of the steps/batches that produced
it, and two checkpoints from diverged runs are provably (Eq. 3) ordered
or provably concurrent.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import clock as bc
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim.adamw import OptConfig, adamw_update, init_opt_state
from repro.runtime.clock_runtime import ClockConfig
from repro.sharding import shard

__all__ = ["TrainState", "init_train_state", "make_train_step", "cross_entropy"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TrainState:
    params: dict
    opt: dict
    clock_cells: jax.Array   # int32[m] — in-graph bloom clock
    step: jax.Array

    def tree_flatten(self):
        return (self.params, self.opt, self.clock_cells, self.step), None

    @classmethod
    def tree_unflatten(cls, _, leaves):
        return cls(*leaves)


def init_train_state(key, cfg: ModelConfig, opt_cfg: OptConfig,
                     clock_cfg: ClockConfig) -> TrainState:
    from repro.models.params import init_params

    params = init_params(key, cfg)
    return TrainState(
        params=params,
        opt=init_opt_state(params, opt_cfg),
        clock_cells=jnp.zeros((clock_cfg.m,), jnp.int32),
        step=jnp.zeros((), jnp.int32),
    )


def cross_entropy(logits: jax.Array, labels: jax.Array, vocab: int,
                  z_loss: float = 1e-4):
    """Stable CE in fp32 with optional z-loss; ignores labels >= vocab."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    ce = lse - gold
    mask = (labels >= 0) & (labels < vocab)
    denom = jnp.maximum(jnp.sum(mask), 1)
    loss = jnp.sum(jnp.where(mask, ce, 0.0)) / denom
    if z_loss:
        loss = loss + z_loss * jnp.sum(jnp.where(mask, jnp.square(lse), 0.0)) / denom
    return loss


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                    clock_cfg: ClockConfig, aux_coef: float = 0.01,
                    num_microbatches: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    batch: tokens/labels [B, S] int32, ev_hi/ev_lo uint32 scalars (bloom
    event id of this batch), optional prefix_embeds / enc_frames stubs.
    Microbatching (grad accumulation) slices the batch dim.
    """

    def loss_fn(params, batch):
        if cfg.ce_chunk:
            # seq-chunked CE: never materialize the full [B, S, V] logits —
            # unembed + logsumexp chunk-by-chunk under lax.scan (the logits
            # of a chunk die before the next chunk is formed)
            from repro.models.layers import unembed

            hidden, aux = T.forward_hidden(
                params, cfg, batch["tokens"],
                prefix_embeds=batch.get("prefix_embeds"),
                enc_frames=batch.get("enc_frames"))
            if cfg.n_prefix:
                hidden = hidden[:, cfg.n_prefix:]
            S = hidden.shape[1]
            C = min(cfg.ce_chunk, S)
            pad = (-S) % C
            labels = batch["labels"]
            if pad:
                hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
                labels = jnp.pad(labels, ((0, 0), (0, pad)),
                                 constant_values=-1)  # masked out
            n_chunks = (S + pad) // C

            def body(carry, i):
                tot, cnt = carry
                h = jax.lax.dynamic_slice_in_dim(hidden, i * C, C, axis=1)
                lb = jax.lax.dynamic_slice_in_dim(labels, i * C, C, axis=1)
                logits = unembed(params, cfg, h).astype(jnp.float32)
                lse = jax.nn.logsumexp(logits, axis=-1)
                gold = jnp.take_along_axis(
                    logits, jnp.maximum(lb, 0)[..., None], axis=-1)[..., 0]
                mask = (lb >= 0) & (lb < cfg.vocab)
                ce = jnp.where(mask, lse - gold + 1e-4 * jnp.square(lse), 0.0)
                return (tot + jnp.sum(ce), cnt + jnp.sum(mask)), None

            (tot, cnt), _ = jax.lax.scan(
                body, (jnp.zeros(()), jnp.zeros((), jnp.int32)),
                jnp.arange(n_chunks))
            loss = tot / jnp.maximum(cnt, 1)
        else:
            logits, aux = T.forward_train(
                params, cfg, batch["tokens"],
                prefix_embeds=batch.get("prefix_embeds"),
                enc_frames=batch.get("enc_frames"),
            )
            if cfg.n_prefix:  # vlm: loss over token region only
                logits = logits[:, cfg.n_prefix:]
            loss = cross_entropy(logits, batch["labels"], cfg.vocab)
        return loss + aux_coef * aux, (loss, aux)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if num_microbatches == 1:
            (tot, (loss, aux)), grads = grad_fn(params, batch)
            return grads, loss, aux
        B = batch["tokens"].shape[0]
        assert B % num_microbatches == 0
        mb = B // num_microbatches

        def mb_slice(x, i):
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

        def body(carry, i):
            g_acc, l_acc, a_acc = carry
            sub_batch = {k: mb_slice(v, i) if hasattr(v, "ndim") and v.ndim >= 1
                         and v.shape[0] == B else v for k, v in batch.items()}
            (tot, (loss, aux)), g = grad_fn(params, sub_batch)
            g_acc = jax.tree.map(jnp.add, g_acc, g)
            return (g_acc, l_acc + loss, a_acc + aux), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g, l, a), _ = jax.lax.scan(
            body, (g0, jnp.zeros(()), jnp.zeros(())),
            jnp.arange(num_microbatches))
        n = float(num_microbatches)
        return jax.tree.map(lambda x: x / n, g), l / n, a / n

    def train_step(state: TrainState, batch: dict):
        grads, loss, aux = compute_grads(state.params, batch)
        params, opt, om = adamw_update(state.params, grads, state.opt, opt_cfg)
        # in-graph clock tick: this step's batch event enters causal history
        clock = bc.BloomClock(state.clock_cells, jnp.zeros((), jnp.int32),
                              clock_cfg.k)
        clock = bc.tick(clock, batch["ev_hi"], batch["ev_lo"])
        new_state = TrainState(params=params, opt=opt,
                               clock_cells=clock.cells + clock.base,
                               step=state.step + 1)
        metrics = {"loss": loss, "aux": aux, **om,
                   "clock_sum": jnp.sum(clock.cells).astype(jnp.float32)}
        return new_state, metrics

    return train_step
