"""Seeded churn driver: million-session serving load with exact truth.

Simulates a serving replica admitting a stream of sessions that arrive,
get queried with Zipf-skewed access, migrate, and expire — the workload
shape the tiered registry exists for — while tracking a vector-clock
ground truth cheap enough to hold for millions of sessions.

Truth model.  The replica's history is a single event chain (R ticks
total).  Each session is minted from a snapshot of the replica taken at
``T_birth`` replica ticks and then given ``P`` private ticks (events the
replica never saw).  The replica only ticks between pipeline ``drain()``
barriers, so every verdict in a step is classified against one known R:

- ``P == 0``            → session ≼ replica (*related*: ancestor/same).
  Bloom dominance is exact, so classifying it FORKED is a false
  negative — the paper's §3 guarantee broken somewhere in the stack
  (tiering, packing, wire, kernel).  The driver asserts ZERO of these.
- ``P > 0, T_birth < R`` → truly concurrent.  Bloom may still report
  "ancestor" when the private ticks collide with cells the replica also
  advanced — that's the §3 false positive Eq. 3 prices; the driver
  reports the measured rate next to the claimed one.

Arrivals are minted from the PREVIOUS step's snapshot, so by the time
they classify the replica has advanced past ``T_birth`` and ``P > 0``
sessions are genuinely concurrent, not merely descendants.  Related
arrivals within a step share one wire frame, which is what makes the
digest cache earn its keep under real load (same cells, same local
clock → one classify, many hits).

``--quick`` runs a small fully-audited configuration and asserts both
zero false negatives and bit-for-bit audit replay (the serve-smoke CI
gate); the big-run defaults keep auditing off so memory stays flat at
millions of sessions.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clock as bc
from repro.core import wire
from repro.causal import CausalPolicy
from repro.serve.pipeline import AdmissionPipeline, PipelineConfig
from repro.serve.tiers import TierConfig, TieredRegistry

__all__ = ["ChurnConfig", "ChurnReport", "run_churn", "main"]


@dataclasses.dataclass(frozen=True)
class ChurnConfig:
    sessions: int = 1_000_000     # total arrivals over the run
    steps: int = 64               # drain barriers (replica ticks between)
    queries_per_step: int = 2048  # Zipf-skewed lookups per step
    migrate_per_step: int = 64    # sessions re-minted from a fresh snapshot
    expire_frac: float = 0.05     # fraction of a step's arrivals released
    concurrent_frac: float = 0.25 # arrivals with private (P>0) ticks
    private_ticks: int = 3        # P for concurrent arrivals
    replica_ticks: int = 4        # replica events per step
    zipf_a: float = 1.3           # access-skew exponent
    m: int = 256
    k: int = 4
    seed: int = 0
    batch_size: int = 256
    hot_capacity: int = 4096
    warm_capacity: int = 65536
    promote_after: int = 3
    fp_threshold: float = 1.0     # admission gate (1.0: admit all related)
    audit: bool = False           # gossip-style audit of every verdict
    trace_dir: Optional[str] = None

    @staticmethod
    def quick(**kw) -> "ChurnConfig":
        """Small, fully audited: the CI serve-smoke configuration."""
        defaults = dict(sessions=3000, steps=12, queries_per_step=256,
                        migrate_per_step=16, m=64, batch_size=64,
                        hot_capacity=128, warm_capacity=512,
                        promote_after=2, audit=True)
        defaults.update(kw)
        return ChurnConfig(**defaults)


@dataclasses.dataclass
class ChurnReport:
    sessions: int = 0             # arrivals submitted
    admitted: int = 0
    rejected: int = 0
    queries: int = 0
    migrations: int = 0
    expiries: int = 0
    fn_violations: int = 0        # related sessions classified forked
    concurrent_seen: int = 0
    measured_fp: float = 0.0      # concurrent classified as related
    claimed_fp_mean: float = 0.0  # mean Eq. 3 claim on those verdicts
    cache_hits: int = 0
    cache_misses: int = 0
    promotions: int = 0
    demotions: int = 0
    spills: int = 0
    tier_counts: dict = dataclasses.field(default_factory=dict)
    p50_ms: float = 0.0
    p95_ms: float = 0.0
    p99_ms: float = 0.0
    qps: float = 0.0              # resolved requests / wall second
    wall_s: float = 0.0
    replay: Optional[dict] = None # audit replay result (when audited)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def ok(self) -> bool:
        good = self.fn_violations == 0
        if self.replay is not None:
            good = good and not self.replay.get("mismatches")
        return good


class _Live:
    """Live-session set with O(1) insert/remove and stable positional
    indexing for Zipf rank sampling (index 0 = oldest survivor)."""

    def __init__(self):
        self.sids: list = []
        self.pos: dict = {}

    def __len__(self):
        return len(self.sids)

    def add(self, sid) -> None:
        self.pos[sid] = len(self.sids)
        self.sids.append(sid)

    def remove(self, sid) -> None:
        i = self.pos.pop(sid)
        last = self.sids.pop()
        if last != sid:
            self.sids[i] = last
            self.pos[last] = i

    def rank(self, r: int):
        return self.sids[(r - 1) % len(self.sids)]


def _mint_concurrent(snap: bc.BloomClock, hi: np.ndarray,
                     lo: np.ndarray) -> np.ndarray:
    """[n, m] int32 cells: snapshot + per-session private ticks.

    hi/lo: [n, P] uint32 event ids.  One batched ``bc.tick`` call per
    step — private cells collide with the replica's exactly as real
    concurrent histories would.
    """
    n = hi.shape[0]
    cells = jnp.broadcast_to(snap.logical_cells().astype(jnp.int32),
                             (n, snap.m))
    batch = bc.BloomClock(cells=cells,
                          base=jnp.zeros((n,), jnp.int32), k=snap.k)
    out = bc.tick(batch, jnp.asarray(hi, jnp.uint32),
                  jnp.asarray(lo, jnp.uint32))
    return np.asarray(jax.device_get(out.logical_cells()), np.int32)


def run_churn(cfg: ChurnConfig = ChurnConfig(),
              observer=None) -> ChurnReport:
    """Run the driver; returns a :class:`ChurnReport` (no asserts — the
    CLI turns report failures into exit codes)."""
    from repro.obs import resolve
    if observer is None and (cfg.audit or cfg.trace_dir):
        from repro.obs import Observer
        observer = Observer.to_dir(cfg.trace_dir) if cfg.trace_dir \
            else Observer()
    obs = resolve(observer)
    if cfg.audit and not obs.audit:
        from repro.obs import Observer
        from repro.obs.audit import AuditTrail
        observer = Observer(trace=obs.trace or None,
                            metrics=obs.metrics or None,
                            audit=AuditTrail(store_frames=True))
        obs = resolve(observer)

    rng = np.random.default_rng(cfg.seed)
    policy = CausalPolicy(fp_threshold=cfg.fp_threshold, observer=observer)
    tiers = TieredRegistry(
        TierConfig(hot_capacity=cfg.hot_capacity,
                   warm_capacity=cfg.warm_capacity,
                   promote_after=cfg.promote_after,
                   # big slabs move in big waves: amortize the device
                   # scatters and keep compiled shapes few
                   demote_batch=max(32, cfg.hot_capacity // 8),
                   spill_batch=max(256, cfg.warm_capacity // 8)),
        m=cfg.m, k=cfg.k, policy=policy)
    replica = [bc.zeros(cfg.m, cfg.k)]
    pipe = AdmissionPipeline(tiers, lambda: replica[0],
                             PipelineConfig(batch_size=cfg.batch_size))

    # truth arrays, indexed by integer session id ("s<idx>")
    cap = cfg.sessions + 1
    t_birth = np.zeros(cap, np.int64)
    private = np.zeros(cap, np.int32)
    next_idx = 0
    live = _Live()
    stored_p = {}             # sid -> P of the clock the tiers hold
    report = ChurnReport()
    conc_related = 0          # concurrent sessions classified related
    conc_claims: list = []
    r_ticks = 0               # replica tick count (== truth R)
    replica_event = 0
    # lagged snapshot: arrivals mint from the clock BEFORE this step's
    # ticks, so concurrent arrivals truly concurrent at classify time
    snap = replica[0]
    snap_ticks = 0

    arrivals_left = cfg.sessions
    per_step = max(1, cfg.sessions // cfg.steps)
    t0 = time.perf_counter()

    for step in range(cfg.steps):
        n_arr = min(per_step if step < cfg.steps - 1 else arrivals_left,
                    arrivals_left)
        arrivals_left -= n_arr
        tickets = []

        # ---- arrivals ----
        conc_mask = rng.random(n_arr) < cfg.concurrent_frac
        idxs = np.arange(next_idx, next_idx + n_arr)
        next_idx += n_arr
        t_birth[idxs] = snap_ticks
        private[idxs] = np.where(conc_mask, cfg.private_ticks, 0)
        shared_frame = wire.encode_clock(bc.to_wire(snap))
        n_conc = int(conc_mask.sum())
        if n_conc:
            ci = idxs[conc_mask]
            hi = np.broadcast_to(ci[:, None] & 0xFFFFFFFF,
                                 (n_conc, cfg.private_ticks)
                                 ).astype(np.uint32)
            lo = np.broadcast_to(
                (np.arange(cfg.private_ticks) * 0x9E370001) & 0xFFFFFFFF,
                (n_conc, cfg.private_ticks)).astype(np.uint32)
            conc_cells = _mint_concurrent(snap, hi, lo)
        conc_at = 0
        admitted_now = set()   # sids with an admit in flight this step
        for j, idx in enumerate(idxs):
            sid = f"s{idx}"
            if conc_mask[j]:
                fr = wire.encode_clock(
                    {"cells": conc_cells[conc_at], "base": 0,
                     "k": cfg.k})
                conc_at += 1
            else:
                fr = shared_frame
            tickets.append((sid, "admit", int(private[idx]),
                            pipe.submit(sid, frame=fr)))
            admitted_now.add(sid)
            live.add(sid)
        report.sessions += n_arr

        # ---- migrations: re-mint live sessions from the snapshot ----
        n_mig = min(cfg.migrate_per_step, len(live))
        if n_mig:
            picks = rng.choice(len(live), size=n_mig, replace=False)
            for sid in [live.sids[p] for p in picks]:
                idx = int(sid[1:])
                t_birth[idx] = snap_ticks
                private[idx] = 0
                tickets.append((sid, "admit", 0,
                                pipe.submit(sid, frame=shared_frame)))
                admitted_now.add(sid)
            report.migrations += n_mig

        # ---- Zipf-skewed queries ----
        n_q = min(cfg.queries_per_step, len(live))
        if n_q:
            for r in rng.zipf(cfg.zipf_a, size=n_q):
                sid = live.rank(int(r))
                tickets.append((sid, "query", None,
                                pipe.submit(sid, kind="query")))
            report.queries += n_q

        pipe.drain()

        # ---- truth check at the barrier ----
        # Admit verdicts classify the request's own frame: always
        # checkable against its P.  Query verdicts classify the STORED
        # clock, whose P is only known once this step's admits settle —
        # so same-step-admitted sids are skipped (their stored clock
        # mid-step depends on batch interleaving).
        for sid, kind, p, ticket in tickets:
            v = ticket.result()
            if v.verdict == "unknown":
                continue      # queried before admission or after expiry
            if kind == "admit":
                if p == 0 and v.verdict == "forked":
                    report.fn_violations += 1
                if p != 0:
                    report.concurrent_seen += 1
                    if v.verdict in ("ancestor", "same"):
                        conc_related += 1
                        conc_claims.append(v.fp)
            elif sid not in admitted_now:
                if stored_p.get(sid) == 0 and v.verdict == "forked":
                    report.fn_violations += 1
        for sid, kind, p, ticket in tickets:
            if kind == "admit" and ticket.result().admitted:
                stored_p[sid] = p

        # ---- expiries (between barriers: tiers are ours to mutate) ----
        n_exp = min(int(cfg.expire_frac * n_arr), max(0, len(live) - 1))
        if n_exp:
            picks = rng.choice(len(live), size=n_exp, replace=False)
            for sid in [live.sids[p] for p in picks]:
                live.remove(sid)
                stored_p.pop(sid, None)
                if sid in tiers:
                    tiers.release(sid)
            report.expiries += n_exp

        # ---- replica advances (next step's arrivals see this lag) ----
        snap = replica[0]
        snap_ticks = r_ticks
        ev = np.arange(replica_event, replica_event + cfg.replica_ticks)
        replica_event += cfg.replica_ticks
        replica[0] = bc.tick(replica[0],
                             jnp.full(cfg.replica_ticks, 0x5EED0001,
                                      jnp.uint32),
                             jnp.asarray(ev & 0xFFFFFFFF, jnp.uint32))
        r_ticks += cfg.replica_ticks

    pipe.drain()
    wall = time.perf_counter() - t0
    total = pipe.n_admitted + pipe.n_rejected + pipe.n_queries

    report.admitted = pipe.n_admitted
    report.rejected = pipe.n_rejected
    report.cache_hits = pipe.cache_hits
    report.cache_misses = pipe.cache_misses
    report.promotions = tiers.promotions
    report.demotions = tiers.demotions
    report.spills = tiers.spills
    from collections import Counter
    report.tier_counts = dict(Counter(tiers._tier_of.values()))
    q = pipe.latency_quantiles()
    report.p50_ms = q["p50"] * 1e3
    report.p95_ms = q["p95"] * 1e3
    report.p99_ms = q["p99"] * 1e3
    report.qps = total / wall if wall > 0 else 0.0
    report.wall_s = wall
    if report.concurrent_seen:
        report.measured_fp = conc_related / report.concurrent_seen
    if conc_claims:
        report.claimed_fp_mean = float(np.mean(conc_claims))

    pipe.close()
    if cfg.audit and obs.audit:
        rep = obs.audit.replay_frames(
            policy=dataclasses.replace(tiers.policy, observer=None))
        report.replay = {"checked": rep.checked, "matched": rep.matched,
                         "stale": rep.stale, "skipped": rep.skipped,
                         "mismatches": [str(x) for x in rep.mismatches]}
    if observer is not None and hasattr(observer, "flush"):
        observer.flush()
    tiers.close()
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="bloom-clock serving churn driver")
    ap.add_argument("--quick", action="store_true",
                    help="small fully-audited CI configuration")
    ap.add_argument("--sessions", type=int, default=None)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--queries", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--m", type=int, default=None)
    ap.add_argument("--hot", type=int, default=None)
    ap.add_argument("--warm", type=int, default=None)
    ap.add_argument("--zipf", type=float, default=None)
    ap.add_argument("--fp-threshold", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--audit", action="store_true")
    ap.add_argument("--trace-dir", default=None)
    ap.add_argument("--json", default=None,
                    help="write the report to this path")
    args = ap.parse_args(argv)

    over = {k: v for k, v in dict(
        sessions=args.sessions, steps=args.steps,
        queries_per_step=args.queries, batch_size=args.batch,
        m=args.m, hot_capacity=args.hot, warm_capacity=args.warm,
        zipf_a=args.zipf, fp_threshold=args.fp_threshold,
    ).items() if v is not None}
    over["seed"] = args.seed
    if args.audit:
        over["audit"] = True
    if args.trace_dir:
        over["trace_dir"] = args.trace_dir
    cfg = ChurnConfig.quick(**over) if args.quick else ChurnConfig(**over)

    report = run_churn(cfg)
    out = report.to_dict()
    print(json.dumps(out, indent=2, default=str))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(out, f, indent=2, default=str)
    if report.fn_violations:
        print(f"FAIL: {report.fn_violations} false negatives "
              "(related session classified forked)", file=sys.stderr)
        return 1
    if report.replay is not None and report.replay["mismatches"]:
        print(f"FAIL: audit replay mismatches: "
              f"{report.replay['mismatches'][:3]}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
