"""Streaming admission pipeline: continuous-batching causality-as-a-service.

The serving engine's ``adopt_many`` classifies request-sized batches
synchronously: stack cells, classify, block, merge, repeat — the device
idles while the host stacks and the host idles while the device
classifies.  This pipeline runs admission as a stream (the offline-
inference loop shape: threaded feeders, device-resident state, overlap
of transfer and compute):

  - any number of host feeder threads ``submit()`` clock updates and
    queries into one bounded queue and get a ticket to wait on;
  - one worker drains the queue into batches and keeps TWO batches in
    flight: while the device classifies batch *t*, the worker stages
    batch *t+1* host-side (frame decode, digest-cache probe, packed
    slab assembly) — JAX's async dispatch provides the overlap, the
    loop just never blocks on results before staging the next batch;
  - a digest cache keyed on the §4 wire-cell CRC (``core.wire``) skips
    re-classifying sessions whose cells — and the local clock — are
    unchanged since their last verdict; hit/miss counters flow through
    ``repro.obs``.  Invalidation rule: an entry is valid only while the
    LOCAL clock's CRC still matches the one stored with it, so any
    local merge/tick implicitly flushes the cache (fp depends on both
    sums, so a stale local clock would report stale confidence).

Verdicts are computed by the same ``CausalEngine`` call, over the same
packed layout, with the same pinned kernel blocks as the tiered
registry (``serve.tiers``) — and every acted-on admission verdict is
audited exactly like gossip verdicts (CRC pair, claimed-direction
Eq. 3 fp, threshold, engine, wire frames), so ``AuditTrail.replay`` /
``replay_frames`` re-derive a serve run bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from array import array
from collections import OrderedDict
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clock as bc
from repro.core import wire
from repro.causal import PackedSlab
from repro.fleet.registry import STATUS_NAMES, _near_wrap
from repro.serve.tiers import TieredRegistry, _fold_i32

__all__ = ["PipelineConfig", "AdmissionVerdict", "AdmissionTicket",
           "AdmissionPipeline"]

#: admission-latency histogram bin edges (milliseconds)
LATENCY_MS_EDGES = (0.5, 1, 2, 5, 10, 20, 50, 100, 250, 1000)


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    batch_size: int = 256         # sessions classified per device call
    queue_depth: int = 2048       # bounded feeder queue (backpressure)
    max_wait_s: float = 0.005     # batch fill window before dispatch
    digest_cache: bool = True
    cache_capacity: int = 65536   # LRU digest-cache entries


@dataclasses.dataclass
class AdmissionVerdict:
    """What one request resolved to."""

    sid: str
    kind: str                 # "admit" | "query"
    verdict: str              # STATUS_NAMES string ("unknown" if absent)
    fp: float                 # claimed-direction Eq. 3 fp
    admitted: bool            # admit requests: did it pass the gate
    cached: bool              # served from the digest cache
    engine: str
    latency_s: float


class AdmissionTicket:
    """Feeder-side handle: ``result()`` blocks until the verdict lands."""

    __slots__ = ("_event", "_verdict")

    def __init__(self):
        self._event = threading.Event()
        self._verdict: Optional[AdmissionVerdict] = None

    def _resolve(self, verdict: AdmissionVerdict) -> None:
        self._verdict = verdict
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> AdmissionVerdict:
        if not self._event.wait(timeout):
            raise TimeoutError("admission verdict not ready")
        return self._verdict


@dataclasses.dataclass
class _Request:
    kind: str
    sid: str
    frame: Optional[bytes]    # encoded clock (admits)
    t_submit: float
    ticket: AdmissionTicket


@dataclasses.dataclass
class _Staged:
    """One in-flight batch: async device work + host-side leftovers."""

    reqs: list                # cache-miss requests, row-aligned
    rows: list                # decoded (cells_np, base) per request
    res: object               # async ClassifyResult (not yet device_get)
    hits: list                # (request, cached-entry, row) cache hits
    unknown: list             # query requests for absent sids
    local: bc.BloomClock
    local_crc: int
    local_sum: float


class AdmissionPipeline:
    """Bounded-queue streaming admission over a ``TieredRegistry``.

    ``local_source`` is a zero-arg callable returning the CURRENT local
    (replica) clock — it is read once per staged batch, so feeders may
    tick it between batches (each batch's verdicts are consistent with
    one local snapshot, and the audit frames pin which one).
    """

    def __init__(self, tiers: TieredRegistry,
                 local_source, cfg: PipelineConfig = PipelineConfig()):
        self.tiers = tiers
        self.cfg = cfg
        self.local_source = local_source
        self.engine = tiers.engine          # pinned blocks ride the policy
        self.policy = tiers.policy
        self.obs = tiers.obs
        self.threshold = float(self.policy.fp_threshold)
        self._queue: queue.Queue = queue.Queue(maxsize=cfg.queue_depth)
        self._cache: OrderedDict = OrderedDict()  # peer_crc -> entry
        self._local_frames: dict[int, bytes] = {}
        self._pending = 0
        self._pending_lock = threading.Condition()
        self._closed = False
        self._error: Optional[BaseException] = None
        self.latencies = array("d")         # per-request submit->verdict s
        self.n_admitted = 0
        self.n_rejected = 0
        self.n_queries = 0
        self.cache_hits = 0
        self.cache_misses = 0
        self.batches = 0
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="admission-pipeline")
        self._worker.start()

    # ---- feeder side ----
    def submit(self, sid: str, clock: bc.BloomClock | None = None,
               frame: bytes | None = None,
               kind: str = "admit") -> AdmissionTicket:
        """Enqueue one request (thread-safe; blocks when the queue is
        full — bounded-queue backpressure).  ``admit`` needs a clock or
        an encoded wire frame; ``query`` classifies the session's
        STORED clock against the local one."""
        if self._closed:
            raise RuntimeError("pipeline is closed")
        if kind == "admit" and frame is None:
            if clock is None:
                raise ValueError("admit needs a clock or a frame")
            frame = wire.encode_clock(bc.to_wire(clock))
        ticket = AdmissionTicket()
        with self._pending_lock:
            self._pending += 1
        self._queue.put(_Request(kind=kind, sid=str(sid), frame=frame,
                                 t_submit=time.perf_counter(),
                                 ticket=ticket))
        return ticket

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted request has resolved."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._pending_lock:
            while self._pending > 0:
                if self._error is not None:
                    raise RuntimeError(
                        "admission worker died") from self._error
                remaining = (None if deadline is None
                             else max(0.0, deadline - time.monotonic()))
                if not self._pending_lock.wait(timeout=remaining):
                    raise TimeoutError(
                        f"{self._pending} requests still in flight")
            if self._error is not None:
                raise RuntimeError(
                    "admission worker died") from self._error

    def close(self) -> None:
        """Drain and stop the worker (idempotent)."""
        if self._closed:
            return
        self._closed = True
        self._worker.join(timeout=60.0)

    # ---- worker side ----
    def _run(self) -> None:
        inflight: Optional[_Staged] = None
        try:
            while True:
                reqs = self._collect()
                staged = self._stage(reqs) if reqs else None
                if inflight is not None:
                    # finalize batch t AFTER dispatching t+1: the device
                    # is already computing t+1 while we device_get t's
                    # results
                    self._finalize(inflight)
                inflight = staged
                if (inflight is None and self._closed
                        and self._queue.empty()):
                    break
        except BaseException as e:   # surface in drain(), don't hang it
            self._error = e
            with self._pending_lock:
                self._pending_lock.notify_all()

    def _collect(self) -> list:
        """Up to ``batch_size`` requests, waiting at most ``max_wait_s``
        past the first one."""
        try:
            first = self._queue.get(timeout=0.02)
        except queue.Empty:
            return []
        reqs = [first]
        deadline = time.perf_counter() + self.cfg.max_wait_s
        while len(reqs) < self.cfg.batch_size:
            left = deadline - time.perf_counter()
            if left <= 0:
                break
            try:
                reqs.append(self._queue.get(timeout=left))
            except queue.Empty:
                break
        return reqs

    def _decode(self, req: _Request):
        """Host-side row for one request: (cells, base) where cells is
        u8 (packed fast path) or an int32 logical row (wide overlay)."""
        if req.frame is not None:
            snap = wire.decode_clock(req.frame)
            return np.asarray(snap["cells"]), int(snap["base"])
        clock = self.tiers.get(req.sid)       # query: stored clock
        cells = np.asarray(clock.logical_cells(), np.int32)
        return cells, 0

    def _stage(self, reqs: list) -> _Staged:
        """Host staging + async device dispatch for one batch."""
        local = self.local_source()
        local_np = np.asarray(local.logical_cells(), np.int32)
        local_crc = wire.cells_crc(local_np)
        local_sum = float(np.asarray(bc.clock_sum(local)))
        hits, misses, rows, unknown = [], [], [], []
        for req in reqs:
            if req.kind == "query" and req.sid not in self.tiers:
                unknown.append(req)
                continue
            cells, base = self._decode(req)
            entry = None
            if self.cfg.digest_cache and req.kind == "admit":
                peer_crc = wire.cells_crc(cells, base)
                entry = self._cache_probe(peer_crc, local_crc)
            if entry is not None:
                hits.append((req, entry, (cells, base)))
            else:
                misses.append(req)
                rows.append((cells, base))
        res = None
        if misses:
            m = self.tiers.m
            # pad ragged tails to batch_size: one compiled kernel shape
            # for the whole stream (pad rows are all-zero u8 — their
            # verdicts are computed and ignored)
            n = max(len(misses), self.cfg.batch_size)
            u8 = np.zeros((n, m), np.uint8)
            base_v = np.zeros(n, np.int64)
            wide: dict[int, np.ndarray] = {}
            for i, (cells, base) in enumerate(rows):
                if (cells.dtype == np.uint8
                        and not _near_wrap(np.asarray([base]))[0]):
                    u8[i] = cells
                    base_v[i] = base
                    continue
                # int32 frame: min-lift into the u8+base layout when the
                # span allows (same split rule as kernels/pack) — the
                # exact-int32 overlay is for genuine rim rows only, its
                # kernel shape varies with the overlay count
                logical = cells.astype(np.int64) + base
                mn = int(logical.min())
                if (0 <= mn and int(logical.max()) - mn <= 255
                        and not _near_wrap(np.asarray([mn]))[0]):
                    u8[i] = (logical - mn).astype(np.uint8)
                    base_v[i] = mn
                else:
                    wide[i] = _fold_i32(logical)
            slab = PackedSlab(jnp.asarray(u8),
                              jnp.asarray(_fold_i32(base_v)),
                              base_host=base_v, wide=wide)
            # async: no device_get here — _finalize blocks on it while
            # the NEXT batch stages
            res = self.engine.classify(local, slab)
        return _Staged(reqs=misses, rows=rows, res=res, hits=hits,
                       unknown=unknown, local=local, local_crc=local_crc,
                       local_sum=local_sum)

    def _cache_probe(self, peer_crc: int, local_crc: int):
        entry = self._cache.get(peer_crc)
        if entry is None or entry["local_crc"] != local_crc:
            return None
        self._cache.move_to_end(peer_crc)
        return entry

    def _cache_store(self, peer_crc: int, local_crc: int, verdict: str,
                     fp: float, admitted: bool, engine: str) -> None:
        self._cache[peer_crc] = {
            "local_crc": local_crc, "verdict": verdict, "fp": fp,
            "admitted": admitted, "engine": engine, "peer_crc": peer_crc}
        self._cache.move_to_end(peer_crc)
        while len(self._cache) > self.cfg.cache_capacity:
            self._cache.popitem(last=False)

    def _finalize(self, staged: _Staged) -> None:
        """Block on batch t's device results, apply + audit + resolve."""
        obs = self.obs
        now = time.perf_counter
        to_admit: dict = {}
        resolved: list = []   # tickets resolve only AFTER tiers apply,
        # so drain() implies every admitted clock is queryable
        if staged.res is not None:
            res = jax.device_get(staged.res)
            after = np.asarray(res.after(), bool)
            equal = np.asarray(res.equal(), bool)
            before = np.asarray(res.before(), bool)
            claimed = np.asarray(res.claimed_fp(), np.float32)
            gate_fp = np.asarray(res.fp_after(), np.float32)
            engine = res.engine or ""
            for i, req in enumerate(staged.reqs):
                verdict = ("same" if equal[i]
                           else "ancestor" if after[i]
                           else "descendant" if before[i]
                           else "forked")
                fp = float(claimed[i])
                if req.kind == "admit":
                    ok = bool(after[i]) and float(gate_fp[i]) <= self.threshold
                    peer_crc = wire.cells_crc(*staged.rows[i])
                    if self.cfg.digest_cache:
                        self._cache_store(peer_crc, staged.local_crc,
                                          verdict, fp, ok, engine)
                    if ok:
                        snap = wire.decode_clock(req.frame)
                        to_admit[req.sid] = bc.from_wire(snap)
                    self._audit(req, staged, verdict, fp, ok, engine,
                                peer_crc)
                    self._count_admit(ok)
                else:
                    self.n_queries += 1
                resolved.append((req, verdict, fp,
                                 req.sid in to_admit, False, engine))
        for req, entry, (cells, base) in staged.hits:
            verdict, fp = entry["verdict"], entry["fp"]
            ok = entry["admitted"]
            if ok:
                snap = wire.decode_clock(req.frame)
                to_admit[req.sid] = bc.from_wire(snap)
            self._audit(req, staged, verdict, fp, ok,
                        "digest_cache", entry["peer_crc"])
            self._count_admit(ok, cached=True)
            resolved.append((req, verdict, fp, ok, True, "digest_cache"))
        for req in staged.unknown:
            self.n_queries += 1
            resolved.append((req, "unknown", 0.0, False, False, ""))
        if to_admit:
            self.tiers.admit_many(to_admit)
        for req, verdict, fp, ok, cached, engine in resolved:
            self._resolve(req, verdict, fp, admitted=ok, cached=cached,
                          engine=engine, now=now())
        self.batches += 1
        if obs:
            obs.metrics.gauge("pipeline_queue_depth").set(
                self._queue.qsize())

    def _count_admit(self, ok: bool, cached: bool = False) -> None:
        if ok:
            self.n_admitted += 1
        else:
            self.n_rejected += 1
        if cached:
            self.cache_hits += 1
        else:
            self.cache_misses += 1
        if self.obs:
            self.obs.metrics.counter(
                "pipeline_admissions",
                outcome="adopted" if ok else "rejected").inc()
            self.obs.metrics.counter(
                "digest_cache",
                outcome="hit" if cached else "miss").inc()

    def _resolve(self, req: _Request, verdict: str, fp: float, *,
                 admitted: bool, cached: bool, engine: str,
                 now: float) -> None:
        latency = now - req.t_submit
        self.latencies.append(latency)
        if self.obs:
            self.obs.metrics.histogram(
                "admission_latency_ms",
                edges=LATENCY_MS_EDGES).observe(latency * 1e3)
        req.ticket._resolve(AdmissionVerdict(
            sid=req.sid, kind=req.kind, verdict=verdict, fp=fp,
            admitted=admitted, cached=cached, engine=engine,
            latency_s=latency))
        with self._pending_lock:
            self._pending -= 1
            if self._pending == 0:
                self._pending_lock.notify_all()

    def _audit(self, req: _Request, staged: _Staged, verdict: str,
               fp: float, ok: bool, engine: str, peer_crc: int) -> None:
        """Audit one acted-on admission verdict, gossip-shaped: replay
        and replay_frames re-derive it bit-for-bit."""
        audit = self.obs.audit
        if not audit:
            return
        frames = {}
        if audit.store_frames:
            lf = self._local_frames.get(staged.local_crc)
            if lf is None:
                lf = wire.encode_clock(bc.to_wire(staged.local))
                self._local_frames[staged.local_crc] = lf
                if len(self._local_frames) > 64:
                    self._local_frames.pop(next(iter(self._local_frames)))
            frames = {"local_frame": lf, "peer_frame": req.frame}
        snap = wire.decode_clock(req.frame)
        peer_sum = float(
            np.asarray(snap["cells"], np.float64).sum()
            + float(snap["base"]) * self.tiers.m)
        audit.record(
            "verdict", req.sid,
            verdict=verdict,
            action="adopt" if ok else "reject",
            fp=fp,
            threshold=self.threshold,
            engine=engine,
            local_crc=staged.local_crc,
            peer_crc=peer_crc,
            local_sum=staged.local_sum,
            peer_sum=peer_sum,
            transport="serve_pipeline",
            **frames)

    # ---- introspection ----
    def latency_quantiles(self) -> dict:
        """p50/p95/p99 submit->verdict latency (seconds)."""
        if not self.latencies:
            return {"p50": 0.0, "p95": 0.0, "p99": 0.0}
        lat = np.asarray(self.latencies)
        return {
            "p50": float(np.quantile(lat, 0.50)),
            "p95": float(np.quantile(lat, 0.95)),
            "p99": float(np.quantile(lat, 0.99)),
        }

    def stats(self) -> dict:
        q = self.latency_quantiles()
        return {
            "admitted": self.n_admitted,
            "rejected": self.n_rejected,
            "queries": self.n_queries,
            "batches": self.batches,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "p50_ms": q["p50"] * 1e3,
            "p95_ms": q["p95"] * 1e3,
            "p99_ms": q["p99"] * 1e3,
        }
