"""Causality-as-a-service: streaming admission over a tiered registry.

- ``tiers``    — hot device slab → warm packed host tier → cold disk
  frames, access-driven promotion/demotion, one ``classify`` front door
  bit-identical to a flat slab;
- ``pipeline`` — bounded-queue continuous-batching admission with a
  double-buffered device slab and a §4-CRC digest cache, every acted-on
  verdict audited gossip-style;
- ``churn``    — seeded million-session arrival/expiry/migration driver
  with Zipf access skew and a vector-clock ground truth.
"""
from repro.serve.churn import ChurnConfig, ChurnReport, run_churn
from repro.serve.pipeline import (
    AdmissionPipeline,
    AdmissionTicket,
    AdmissionVerdict,
    PipelineConfig,
)
from repro.serve.tiers import TierConfig, TieredRegistry, TieredView

__all__ = [
    "TierConfig",
    "TieredRegistry",
    "TieredView",
    "PipelineConfig",
    "AdmissionPipeline",
    "AdmissionTicket",
    "AdmissionVerdict",
    "ChurnConfig",
    "ChurnReport",
    "run_churn",
]
