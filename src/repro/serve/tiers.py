"""Tiered session-clock registry: hot device slab → warm host tier → cold disk.

One flat ``ClockRegistry`` slab caps the session population at whatever
fits a device.  Serving-scale populations are heavy-tailed (a small hot
working set over a long cold tail — the Tree Clocks hierarchy cue), so
the store is split by access frequency:

  hot   a device ``ClockRegistry`` slab — every hot session classifies
        in the one fused one-vs-many kernel call;
  warm  the same §4 packed layout (u8 residuals + i32 base, see
        ``kernels.pack``) in host numpy arrays — no device residency,
        promoted int32 rows ride a side dict exactly like the slab's;
  cold  §4 wire frames (``core.wire.encode_clock``) in one append-only
        spill file with a host offset index — bounded only by disk.

Movement is access-count driven: ``touch``/``get``/``classify`` bump a
session's count; crossing ``promote_after`` promotes it one tier toward
the device.  Demotion happens under pressure: a full hot slab evicts
its least-touched rows (captured losslessly via the registry's
``on_evict`` hook — the §4 packed row moves, never a re-encode) into
warm, and a full warm tier spills its least-touched rows to disk.

``classify(query)`` is the one front door.  Each tier is classified
through the same ``CausalEngine`` the flat slab uses, over the same
packed layout, with the SAME kernel block shapes — resolved ONCE at the
flat-equivalent capacity and pinned for every tier call, because the
in-kernel f32 sum accumulation order (and therefore the Eq. 3 fp bits)
depends on the m-axis block.  The result is bit-identical per session
to one flat oversized ``ClockRegistry`` holding the whole population —
``tests/test_serve_tiers.py`` pins it, promoted int32-rim rows and all.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.causal import CausalEngine, CausalPolicy, PackedSlab
from repro.core import clock as bc
from repro.core import wire
from repro.fleet.registry import (ClockRegistry, EvictedRow, FleetView,
                                  STATUS_NAMES, _near_wrap,
                                  view_from_classify)
from repro.kernels import ops
from repro.obs.observer import resolve

__all__ = ["TierConfig", "TieredRegistry", "TieredView"]

TIERS = ("hot", "warm", "cold")


@dataclasses.dataclass(frozen=True)
class TierConfig:
    """Capacity and movement policy of a ``TieredRegistry``."""

    hot_capacity: int = 256       # device ClockRegistry slab rows
    warm_capacity: int = 4096     # host packed rows
    promote_after: int = 3        # accesses that pull a row one tier up
    demote_batch: int = 32        # hot rows demoted per overflow
    spill_batch: int = 256        # warm rows spilled per overflow
    cold_batch: int = 16384       # cold rows decoded per classify chunk
    spill_dir: Optional[str] = None   # cold file location (tmp when None)
    # hysteresis: without these, two rows straddling a full hot slab
    # can thrash — promote() resets the access count, making the fresh
    # arrival the next eviction's first victim
    min_residency: int = 16       # admissions a promoted row is
                                  # eviction-immune for
    max_migrations_per_window: int = 64   # promotions allowed per window
    window: int = 1024            # touches per hysteresis window


@dataclasses.dataclass
class TieredView:
    """Per-session classification across every tier (host-side).

    Row order follows ``sids``; values are bit-identical to what one
    flat ``ClockRegistry.classify_all`` over the same population
    reports for each session (same status semantics, same claimed-
    direction Eq. 3 fp bits).
    """

    sids: list
    status: np.ndarray        # int8 status code per session
    fp: np.ndarray            # float32 claimed-direction Eq. 3 fp
    sums: np.ndarray          # float32 cached clock sums
    tier: list                # "hot" | "warm" | "cold" per session
    local_sum: float
    engine: str = ""

    def verdict_of(self, sid) -> str:
        return STATUS_NAMES[int(self.status[self.sids.index(sid)])]

    def fp_of(self, sid) -> float:
        return float(self.fp[self.sids.index(sid)])

    def counts(self) -> dict:
        return {name: int(np.sum(self.status == code))
                for code, name in STATUS_NAMES.items()}

    def tier_counts(self) -> dict:
        return {t: self.tier.count(t) for t in TIERS}


def _fold_i32(cells: np.ndarray) -> np.ndarray:
    """Fold int64 logical values onto the int32 mod-2^32 circle."""
    return (np.asarray(cells, np.int64)
            & 0xFFFFFFFF).astype(np.uint32).view(np.int32)


class TieredRegistry:
    """Hot/warm/cold session-clock store behind one classify front door."""

    def __init__(self, cfg: TierConfig = TierConfig(), *, m: int = 64,
                 k: int = 4, policy: CausalPolicy | None = None):
        self.cfg = cfg
        self.m = m
        self.k = k
        base_pol = policy if policy is not None else CausalPolicy()
        if base_pol.mesh is not None:
            # the tier split is a host-level construct; scale-out across
            # devices stays the flat slab's job (ROADMAP carries it)
            base_pol = dataclasses.replace(base_pol, mesh=None)
        # Pin the one-vs-many kernel block shapes ONCE, resolved at the
        # flat-equivalent capacity: Eq. 3 fp bits depend on the m-axis
        # block (f32 accumulation order), and the autotune table is
        # keyed by slab N — per-tier resolution could tile m differently
        # per tier and break the flat-slab bit-identity contract.
        interpret = (base_pol.interpret if base_pol.interpret is not None
                     else not ops._on_tpu())
        bn, bm = ops._one_vs_many_blocks(
            cfg.hot_capacity + cfg.warm_capacity, m, base_pol.bn,
            base_pol.bm, interpret, base_pol.autotune)
        self.policy = dataclasses.replace(base_pol, bn=bn, bm=bm)
        self.blocks = (bn, bm)
        self.hot = ClockRegistry(capacity=cfg.hot_capacity, m=m, k=k,
                                 policy=self.policy)
        self.hot.on_evict = self._ingest_warm
        self.engine: CausalEngine = self.hot.engine
        self.obs = resolve(getattr(self.policy, "observer", None))
        # warm tier: the slab layout, host-side
        W = cfg.warm_capacity
        self._w_u8 = np.zeros((W, m), np.uint8)
        self._w_base = np.zeros(W, np.int64)
        self._w_sums = np.zeros(W, np.float32)
        self._w_alive = np.zeros(W, bool)
        self._w_wide: dict[int, np.ndarray] = {}
        self._w_slot_of: dict = {}
        self._w_free: list[int] = list(range(W - 1, -1, -1))
        # cold tier: append-only frame spill + offset index
        self._spill_dir = cfg.spill_dir or tempfile.mkdtemp(
            prefix="bloomclock_cold_")
        os.makedirs(self._spill_dir, exist_ok=True)
        self._spill_path = os.path.join(self._spill_dir, "cold.bin")
        self._spill_file = None
        self._cold_index: dict = {}       # sid -> (offset, nbytes)
        # movement bookkeeping
        self._tier_of: dict = {}
        self._access: dict = {}
        self._age: dict = {}
        self._age_seq = 0
        self.promotions = 0
        self.demotions = 0
        self.spills = 0
        # hysteresis bookkeeping
        self._promoted_at: dict = {}
        self._window_touches = 0
        self._window_migrations = 0
        self.promotion_deferrals = 0

    # ---- membership ----
    def __len__(self) -> int:
        return len(self._tier_of)

    def __contains__(self, sid) -> bool:
        return sid in self._tier_of

    def tier_of(self, sid) -> str:
        return self._tier_of[sid]

    def sids(self) -> list:
        return list(self._tier_of)

    def occupancy(self) -> dict:
        return {
            "hot": len(self.hot),
            "warm": len(self._w_slot_of),
            "cold": len(self._cold_index),
        }

    def _note_occupancy(self) -> None:
        if self.obs:
            for tier, n in self.occupancy().items():
                self.obs.metrics.gauge("tier_occupancy", tier=tier).set(n)

    # ---- admission ----
    def admit(self, sid, clock: bc.BloomClock) -> None:
        self.admit_many({sid: clock})

    def admit_many(self, clocks: dict) -> None:
        """Admit (or overwrite) sessions into the HOT tier; one scatter
        for the batch.  A full hot slab demotes its least-touched rows
        into warm first (which may cascade a warm spill to cold)."""
        if not clocks:
            return
        items = list(clocks.items())
        # a batch larger than the hot slab lands in capacity-sized
        # waves; earlier waves demote into warm as later ones arrive
        step = max(1, self.hot.capacity // 2)
        for at in range(0, len(items), step):
            batch = dict(items[at:at + step])
            for sid in batch:   # re-admission supersedes the old copy
                if self._tier_of.get(sid) in ("warm", "cold"):
                    self._drop_from_tier(sid)
            fresh = [sid for sid in batch if sid not in self.hot]
            # never demote a row this wave is about to overwrite: the
            # re-admit would then need a slot the eviction just promised
            # to someone else
            self._ensure_hot_room(len(fresh), exclude=batch.keys())
            self.hot.admit_many(batch)
            for sid in batch:
                self._tier_of[sid] = "hot"
                self._access.setdefault(sid, 0)
                self._age[sid] = self._age_seq
                self._age_seq += 1
        self._note_occupancy()

    def release(self, sid) -> None:
        """Forget a session entirely (expiry)."""
        tier = self._tier_of.get(sid)
        if tier is None:
            return
        if tier == "hot":
            # a released row is gone, not demoted
            hook, self.hot.on_evict = self.hot.on_evict, None
            try:
                self.hot.evict(sid)
            finally:
                self.hot.on_evict = hook
        else:
            self._drop_from_tier(sid)
        del self._tier_of[sid]
        self._access.pop(sid, None)
        self._age.pop(sid, None)
        self._promoted_at.pop(sid, None)
        self._note_occupancy()

    # ---- access-driven movement ----
    def touch(self, sid) -> None:
        """Count one access; crossing ``promote_after`` promotes the
        session one tier toward the device — unless this window's
        migration budget is spent (hysteresis: an adversarial access
        pattern at the hot boundary gets a bounded number of
        representation moves per window, not one per touch)."""
        self._window_touches += 1
        if self._window_touches >= self.cfg.window:
            self._window_touches = 0
            self._window_migrations = 0
        self._access[sid] = self._access.get(sid, 0) + 1
        if (self._tier_of.get(sid) in ("warm", "cold")
                and self._access[sid] >= self.cfg.promote_after):
            if self._window_migrations >= self.cfg.max_migrations_per_window:
                self.promotion_deferrals += 1
                if self.obs:
                    self.obs.metrics.counter("tier_promotion_deferred").inc()
                return
            self.promote(sid)

    def promote(self, sid) -> None:
        """Pull a warm/cold session into the hot slab (exact row move:
        the stored clock re-admits bit-identically)."""
        tier = self._tier_of.get(sid)
        if tier not in ("warm", "cold"):
            return
        clock = self.get(sid, count=False)
        self._drop_from_tier(sid)
        self._tier_of.pop(sid, None)
        self.admit_many({sid: clock})
        self._access[sid] = 0          # fresh residency, fresh count
        self._promoted_at[sid] = self._age_seq
        self.promotions += 1
        self._window_migrations += 1
        if self.obs:
            self.obs.metrics.counter("tier_promotions",
                                     src=tier).inc()

    def _victims(self, sids, count: int) -> list:
        """Least-touched first, oldest residency breaking ties.

        Freshly promoted rows (within ``min_residency`` admissions) are
        skipped while alternatives exist: ``promote`` resets the access
        count, so without this immunity the row just pulled up would be
        the very next eviction's first victim — the thrash loop the
        hysteresis tests pin.  When every candidate is fresh the
        eviction still proceeds (room must be made)."""
        fresh = {s for s in sids
                 if self._age_seq - self._promoted_at.get(s, -(1 << 62))
                 < self.cfg.min_residency}
        ranked = sorted(sids, key=lambda s: (s in fresh,
                                             self._access.get(s, 0),
                                             self._age.get(s, 0)))
        return ranked[:count]

    def _ensure_hot_room(self, need: int, exclude=()) -> None:
        free = self.hot.capacity - len(self.hot)
        if free >= need:
            return
        short = need - free
        exclude = set(exclude)
        candidates = [s for s in self.hot.peer_ids() if s not in exclude]
        # round the wave up to a demote_batch multiple: evictions then
        # reuse a handful of compiled gather/scatter shapes instead of
        # recompiling per ad-hoc size
        db = self.cfg.demote_batch
        count = -(-max(short, db) // db) * db
        victims = self._victims(candidates, count)
        self.hot.evict_many(victims)   # on_evict hook lands them in warm

    def _ingest_warm(self, captured: dict) -> None:
        """``ClockRegistry.on_evict`` hook: demoted hot rows arrive in
        the packed representation and land in the warm arrays as-is."""
        self._ensure_warm_room(len(captured))
        for sid, row in captured.items():
            slot = self._w_free.pop()
            self._w_slot_of[sid] = slot
            self._w_u8[slot] = row.cells_u8
            self._w_base[slot] = row.base
            self._w_sums[slot] = row.sum
            self._w_alive[slot] = True
            if row.wide is not None:
                self._w_wide[slot] = row.wide
            else:
                self._w_wide.pop(slot, None)
            self._tier_of[sid] = "warm"
        self.demotions += len(captured)
        if self.obs:
            self.obs.metrics.counter("tier_demotions").inc(len(captured))

    def _ensure_warm_room(self, need: int) -> None:
        if len(self._w_free) >= need:
            return
        short = need - len(self._w_free)
        sb = self.cfg.spill_batch
        victims = self._victims(
            list(self._w_slot_of), -(-max(short, sb) // sb) * sb)
        self._spill(victims)

    def _spill(self, sids: list) -> None:
        """Encode warm rows as §4 wire frames and append them to the
        cold file (promoted rows ship int32; everything else ships
        u8 + base — the exact bytes ``get`` will decode back)."""
        f = self._spill_handle()
        for sid in sids:
            slot = self._w_slot_of.pop(sid)
            if slot in self._w_wide:
                snap = {"cells": self._w_wide.pop(slot),
                        "base": 0, "k": self.k}
            else:
                snap = {"cells": self._w_u8[slot].copy(),
                        "base": int(self._w_base[slot]), "k": self.k}
            frame = wire.encode_clock(snap)
            offset = f.tell()
            f.write(frame)
            self._cold_index[sid] = (offset, len(frame))
            self._w_alive[slot] = False
            self._w_free.append(slot)
            self._tier_of[sid] = "cold"
        f.flush()
        self.spills += len(sids)
        if self.obs:
            self.obs.metrics.counter("tier_spills").inc(len(sids))

    def _spill_handle(self):
        if self._spill_file is None:
            self._spill_file = open(self._spill_path, "a+b")
        self._spill_file.seek(0, os.SEEK_END)
        return self._spill_file

    def _read_frame(self, sid) -> bytes:
        offset, nbytes = self._cold_index[sid]
        f = self._spill_handle()
        f.seek(offset)
        return f.read(nbytes)

    def _drop_from_tier(self, sid) -> None:
        """Remove a session's warm/cold storage (tier map untouched)."""
        tier = self._tier_of.get(sid)
        if tier == "warm":
            slot = self._w_slot_of.pop(sid)
            self._w_alive[slot] = False
            self._w_wide.pop(slot, None)
            self._w_free.append(slot)
        elif tier == "cold":
            # the frame bytes stay orphaned in the append-only file;
            # compaction is an operator job (rewrite to a fresh file)
            self._cold_index.pop(sid, None)

    # ---- retrieval ----
    def get(self, sid, count: bool = True) -> bc.BloomClock:
        """The session's clock from whichever tier holds it (cold rows
        decode their frame).  Counts as an access unless ``count=False``
        — repeated gets promote a tail session toward the device."""
        tier = self._tier_of[sid]
        if count:
            self.touch(sid)
            tier = self._tier_of[sid]   # touch may have promoted it
        if tier == "hot":
            return self.hot.get(sid)
        if tier == "warm":
            slot = self._w_slot_of[sid]
            if slot in self._w_wide:
                return bc.BloomClock(cells=jnp.asarray(self._w_wide[slot]),
                                     base=jnp.zeros((), jnp.int32),
                                     k=self.k)
            return bc.BloomClock(
                cells=jnp.asarray(self._w_u8[slot], jnp.int32),
                base=jnp.asarray(_fold_i32([self._w_base[slot]])[0],
                                 jnp.int32),
                k=self.k)
        return bc.from_wire(wire.decode_clock(self._read_frame(sid)))

    # ---- the classify front door ----
    def classify(self, query: bc.BloomClock,
                 sids: Optional[list] = None) -> TieredView:
        """Classify the query against every stored session (or the given
        subset), composing per-tier ``CausalEngine`` calls — same packed
        layout, same pinned kernel blocks — into one view that is
        bit-identical per session to a flat oversized slab."""
        want = self.sids() if sids is None else list(sids)
        by_tier = {"hot": [], "warm": [], "cold": []}
        for sid in want:
            by_tier[self._tier_of[sid]].append(sid)
        status = np.zeros(len(want), np.int8)
        fp = np.zeros(len(want), np.float32)
        sums = np.zeros(len(want), np.float32)
        pos = {sid: i for i, sid in enumerate(want)}
        engines = []
        local_sum = float(np.asarray(bc.clock_sum(query)))
        with self.obs.trace.span("tiers.classify", n=len(want)) as span:
            if by_tier["hot"]:
                view = self.hot.classify_all(query)
                engines.append(f"hot:{view.engine}")
                for sid in by_tier["hot"]:
                    slot = self.hot.slot_of(sid)
                    i = pos[sid]
                    status[i] = view.status[slot]
                    fp[i] = view.fp[slot]
                    sums[i] = view.sums[slot]
            if by_tier["warm"]:
                view = self._classify_warm(query)
                engines.append(f"warm:{view.engine}")
                for sid in by_tier["warm"]:
                    slot = self._w_slot_of[sid]
                    i = pos[sid]
                    status[i] = view.status[slot]
                    fp[i] = view.fp[slot]
                    sums[i] = view.sums[slot]
            if by_tier["cold"]:
                eng = self._classify_cold(query, by_tier["cold"], pos,
                                          status, fp, sums)
                engines.append(f"cold:{eng}")
            span.set(engine=" ".join(engines))
        tiers = [self._tier_of[s] for s in want]
        if sids is not None:
            # a targeted query is an access (promotion pressure); a
            # full-population sweep (dashboards, replay) is not
            for sid in want:
                self.touch(sid)
        self._note_occupancy()
        return TieredView(
            sids=want, status=status, fp=fp, sums=sums, tier=tiers,
            local_sum=local_sum, engine=" ".join(engines))

    def _classify_warm(self, query: bc.BloomClock) -> FleetView:
        slab = PackedSlab(
            jnp.asarray(self._w_u8),
            jnp.asarray(_fold_i32(self._w_base)),
            base_host=self._w_base, wide=self._w_wide)
        res = jax.device_get(self.engine.classify(
            query, slab, bn=self.blocks[0], bm=self.blocks[1]))
        return view_from_classify(res, self._w_alive, self.cfg.warm_capacity)

    def _classify_cold(self, query, sids, pos, status, fp, sums) -> str:
        """Chunked classify over decoded cold frames: each chunk builds
        a transient packed slab (near-wrap / i32 frames ride the wide
        overlay, same as everywhere else) and runs the same engine call
        with the same pinned blocks."""
        B = self.cfg.cold_batch
        engine = ""
        for at in range(0, len(sids), B):
            chunk = sids[at:at + B]
            # ragged tails pad to the full chunk shape (zero rows are
            # ignored below) so every chunk reuses one compiled kernel
            u8 = np.zeros((B, self.m), np.uint8)
            base = np.zeros(B, np.int64)
            wide: dict[int, np.ndarray] = {}
            for i, sid in enumerate(chunk):
                snap = wire.decode_clock(self._read_frame(sid))
                cells = np.asarray(snap["cells"])
                if (cells.dtype == np.uint8
                        and not _near_wrap(np.asarray([snap["base"]]))[0]):
                    u8[i] = cells
                    base[i] = snap["base"]
                else:
                    wide[i] = _fold_i32(
                        cells.astype(np.int64) + int(snap["base"]))
            slab = PackedSlab(jnp.asarray(u8), jnp.asarray(_fold_i32(base)),
                              base_host=base, wide=wide)
            res = jax.device_get(self.engine.classify(
                query, slab, bn=self.blocks[0], bm=self.blocks[1]))
            alive = np.zeros(B, bool)
            alive[:len(chunk)] = True
            view = view_from_classify(res, alive, B)
            engine = view.engine
            for i, sid in enumerate(chunk):
                j = pos[sid]
                status[j] = view.status[i]
                fp[j] = view.fp[i]
                sums[j] = view.sums[i]
        return engine

    def close(self) -> None:
        if self._spill_file is not None:
            self._spill_file.close()
            self._spill_file = None
