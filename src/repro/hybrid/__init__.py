"""Adaptive hybrid causality engine: exact hot set over the bloom tail.

``HybridEngine`` keeps exact prefix-chain clocks for a bounded hot set
(zero false positives, O(1) verdict math per hot row) layered over the
packed §4 bloom slab for the long tail, fused into ONE kernel sweep per
``classify``.  ``AdaptivePolicy`` closes the loop from the measured
Eq. 3 fp signal back into the tail's (m, k) geometry against a declared
``fp_budget`` — operators set a budget, not clock parameters.
"""
from repro.hybrid.adaptive import (AdaptiveConfig, AdaptivePolicy,
                                   derive_mk, fold_pow2, replay_resize)
from repro.hybrid.engine import (HybridConfig, HybridEngine, HybridSlab,
                                 HybridView)

__all__ = [
    "AdaptiveConfig",
    "AdaptivePolicy",
    "HybridConfig",
    "HybridEngine",
    "HybridSlab",
    "HybridView",
    "derive_mk",
    "fold_pow2",
    "replay_resize",
]
