"""HybridEngine: exact clocks for the hot set over a packed bloom tail.

The serving population is Zipf-skewed: a small hot set absorbs most
classifies while the long tail sits cold.  Every session here is
described EXACTLY by a cheap host-side catalog entry — a prefix length
``v`` into the local event chain plus a handful of private event ids —
and the engine chooses a *representation* per session, not just a
placement (generalizing the tiers' promoted-row int32 overlay):

  hot   the catalog entry itself, shipped to the device as an
        ``[H, 2] (v, n_private)`` row.  Verdicts against the local
        chain at version ``V`` are exact set containment —
        ``query ≼ peer  ⟺  V ≤ v`` and ``peer ≼ query  ⟺  v ≤ V and
        n_private == 0`` — so the claimed AND measured fp is zero,
        and no O(m) cells are read at all;
  tail  the §4 packed bloom row (u8 residuals + i32 base, int32 wide
        rows on the side dict) minted deterministically from the same
        catalog entry, compared by the usual Eq. 3 bloom math.

One ``classify()`` fuses both paths through the generated ``hybrid``
kernel topology (``kernels.template``): hot row-tiles and tail
row-tiles share one grid, so hot rows never fall back to host loops.
Tail verdicts are bit-identical to a flat packed slab at the same
block shapes; hot verdicts come back with fp ≡ 0.0.

Because minting is deterministic (double-hash probes mod m) and probe
indices fold exactly across power-of-two geometry changes
(``(x mod m) mod m' == x mod m'`` when ``m' | m``), demotion re-mints
bit-identically and ``resize_tail`` folds every live row — plus the
local chain — to a smaller ``m`` with per-row audit records that
replay bit-for-bit (``hybrid.adaptive.replay_resize``).

Promotion/demotion is access-count driven with hysteresis: a freshly
promoted row is demotion-immune for ``min_residency`` windows and at
most ``max_migrations_per_window`` representation changes happen per
window, so adversarial alternating access at the hot-set boundary
cannot thrash.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.causal.engine import CausalEngine, PackedSlab
from repro.causal.policy import CausalPolicy
from repro.causal.results import ClassifyResult
from repro.core import clock as bc
from repro.core import wire
from repro.core.hashing import bloom_indices
from repro.obs.audit import NULL_AUDIT
from repro.obs.observer import resolve

__all__ = ["HybridConfig", "HybridEngine", "HybridSlab", "HybridView"]


@dataclasses.dataclass
class HybridSlab(PackedSlab):
    """A ``PackedSlab`` carrying an exact hot set alongside the tail.

    ``cells_u8``/``base``/``wide`` describe the TAIL rows only; the hot
    rows ride as ``(v, n_private)`` metadata plus their (geometry-
    independent) shadow total sums.  ``local_version`` must be the
    chain prefix length of the query clock this slab will be classified
    against — the exact verdicts are containment tests against it.
    Result rows come back hot-first: ``[0, H)`` hot, ``[H, H+T)`` tail.
    """

    hot_meta: Optional[np.ndarray] = None   # [H, 2] int32 (v, n_private)
    hot_sums: Optional[np.ndarray] = None   # [H, 1] float32 shadow sums
    local_version: int = 0

    @property
    def hot_count(self) -> int:
        return 0 if self.hot_meta is None else int(self.hot_meta.shape[0])

    @property
    def rows(self) -> int:
        return self.hot_count + self.capacity


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Geometry and movement policy of a ``HybridEngine``."""

    m: int = 512                  # tail bloom cells (pow2; fold target)
    k: int = 4                    # hash probes per event
    hot_capacity: int = 64        # exact rows kept on device
    tail_capacity: int = 4096     # packed tail slots
    promote_after: int = 3        # window accesses that earn promotion
    min_residency: int = 2        # windows a hot row is demotion-immune
    max_migrations_per_window: int = 8
    window: int = 256             # touches per migration window
    fp_budget: Optional[float] = None  # attach an AdaptivePolicy when set
    interpret: Optional[bool] = None


@dataclasses.dataclass
class HybridView:
    """One fused classify over the whole population (host-side)."""

    sids: list
    hot: np.ndarray               # bool per row: served by the exact path
    q_le_p: np.ndarray
    p_le_q: np.ndarray
    fp_q_before_p: np.ndarray
    fp_p_before_q: np.ndarray
    sum_p: np.ndarray
    sum_q: float
    engine: str = ""

    def _i(self, sid) -> int:
        return self.sids.index(sid)

    def verdict_of(self, sid) -> str:
        i = self._i(sid)
        le, ge = bool(self.q_le_p[i]), bool(self.p_le_q[i])
        if le and ge:
            return "equal"
        if le:
            return "descendant"     # peer is ahead of the query
        if ge:
            return "ancestor"       # peer is in the query's past
        return "concurrent"

    def fp_of(self, sid) -> float:
        """Claimed fp of the strict verdict's direction (0 when none)."""
        i = self._i(sid)
        if bool(self.q_le_p[i]) and not bool(self.p_le_q[i]):
            return float(self.fp_q_before_p[i])
        if bool(self.p_le_q[i]) and not bool(self.q_le_p[i]):
            return float(self.fp_p_before_q[i])
        return 0.0


@dataclasses.dataclass
class _Session:
    """Catalog entry: the exact description every representation of the
    session is derived from."""

    v: int                        # local-chain prefix length
    events: tuple                 # ((hi, lo), ...) private event ids
    access: int = 0
    hot: bool = False
    slot: Optional[int] = None    # tail slot when not hot
    promoted_window: int = -(1 << 30)

    @property
    def n_private(self) -> int:
        return len(self.events)


class HybridEngine:
    """The hybrid front door (see module docstring)."""

    def __init__(self, cfg: HybridConfig = HybridConfig(), *,
                 policy: CausalPolicy | None = None, observer=None,
                 audit=None):
        self.cfg = cfg
        self.m = cfg.m
        self.k = cfg.k
        pol = policy or CausalPolicy(interpret=cfg.interpret)
        self.engine = CausalEngine(pol)
        self.obs = resolve(observer)
        self.audit = audit if audit is not None else NULL_AUDIT
        # local event chain: probe indices per event (k per row).  Probes
        # are stored mod the CURRENT m and fold exactly on resize.
        self._probes = np.zeros((0, cfg.k), np.int64)
        self._local_cells = np.zeros(cfg.m, np.int64)
        self.sessions: dict = {}
        # hot set: insertion-ordered sid -> _Session (values alias
        # ``sessions``; the dict itself is the device row order)
        self._hot: dict = {}
        # tail arrays: the §4 packed layout, host-authoritative with a
        # device mirror rebuilt lazily (``_dirty``)
        T = cfg.tail_capacity
        self._t_u8 = np.zeros((T, cfg.m), np.uint8)
        self._t_base = np.zeros(T, np.int64)
        self._t_sums = np.zeros(T, np.float32)
        self._t_alive = np.zeros(T, bool)
        self._t_wide: dict[int, np.ndarray] = {}
        self._t_free: list[int] = list(range(T - 1, -1, -1))
        self._t_order: list = []        # alive sids in slot-scan order
        self._dirty = True
        self._dev = None                # (cells_u8, base, wide, sids)
        # migration window bookkeeping
        self._window_idx = 0
        self._window_touches = 0
        self._window_migrations = 0
        self.promotions = 0
        self.demotions = 0
        self.resizes = 0
        self.adaptive = None
        if cfg.fp_budget is not None:
            from repro.hybrid.adaptive import AdaptiveConfig, AdaptivePolicy
            self.adaptive = AdaptivePolicy(
                self, AdaptiveConfig(fp_budget=cfg.fp_budget))

    # ------------------------------------------------------------------
    # local chain
    # ------------------------------------------------------------------
    @property
    def local_version(self) -> int:
        return int(self._probes.shape[0])

    def append_local(self, event_hi: int, event_lo: int) -> None:
        """Record one local event: extends the chain every hot verdict
        is a containment test against, and ticks the local clock."""
        probes = self._probe_of(event_hi, event_lo)
        self._probes = np.concatenate([self._probes, probes[None, :]])
        np.add.at(self._local_cells, probes, 1)

    def advance_local(self, count: int = 1) -> None:
        """Append ``count`` fresh deterministic local events."""
        from repro.core.hashing import stable_event_id
        for _ in range(count):
            hi, lo = stable_event_id(b"hybrid/local", self.local_version)
            self.append_local(hi, lo)

    def local_clock(self) -> bc.BloomClock:
        return bc.BloomClock(
            cells=jnp.asarray(_fold_i32(self._local_cells)),
            base=jnp.zeros((), jnp.int32), k=self.k)

    def _probe_of(self, hi, lo) -> np.ndarray:
        idx = bloom_indices(np.uint32(hi), np.uint32(lo), self.k, self.m)
        return np.asarray(idx, np.int64)

    # ------------------------------------------------------------------
    # admission / representation moves
    # ------------------------------------------------------------------
    def admit(self, sid, v: int, events=()) -> None:
        """Register a session from its exact description: a ``v``-long
        prefix of the local chain plus private event ids.  Lands in the
        tail representation; access counters promote it later."""
        if v > self.local_version:
            raise ValueError(
                f"session prefix v={v} exceeds local chain "
                f"length {self.local_version}")
        if sid in self.sessions:
            self.release(sid)
        s = _Session(v=int(v),
                     events=tuple((int(h), int(l)) for h, l in events))
        self.sessions[sid] = s
        self._mint_into_tail(sid, s)

    def release(self, sid) -> None:
        s = self.sessions.pop(sid, None)
        if s is None:
            return
        if s.hot:
            self._hot.pop(sid, None)
        elif s.slot is not None:
            self._free_slot(s)

    def _mint_cells(self, s: _Session) -> np.ndarray:
        """Deterministic logical cells of a session's bloom shadow at
        the CURRENT geometry — a fold of any previous mint."""
        cells = np.zeros(self.m, np.int64)
        if s.v:
            np.add.at(cells, self._probes[:s.v].ravel(), 1)
        for hi, lo in s.events:
            np.add.at(cells, self._probe_of(hi, lo), 1)
        return cells

    def _mint_into_tail(self, sid, s: _Session) -> None:
        if not self._t_free:
            raise RuntimeError("tail slab full; grow tail_capacity")
        slot = self._t_free.pop()
        cells = self._mint_cells(s)
        base = int(cells.min()) if cells.size else 0
        resid = cells - base
        if resid.max(initial=0) <= 255:
            self._t_u8[slot] = resid.astype(np.uint8)
            self._t_base[slot] = base
            self._t_wide.pop(slot, None)
        else:
            self._t_u8[slot] = 0
            self._t_base[slot] = 0
            self._t_wide[slot] = _fold_i32(cells)
        self._t_sums[slot] = np.float32(cells.sum())
        self._t_alive[slot] = True
        s.slot = slot
        s.hot = False
        self._dirty = True

    def _free_slot(self, s: _Session) -> None:
        slot = s.slot
        self._t_alive[slot] = False
        self._t_wide.pop(slot, None)
        self._t_free.append(slot)
        s.slot = None
        self._dirty = True

    def promote(self, sid) -> None:
        """Switch a session to the exact representation."""
        s = self.sessions[sid]
        if s.hot:
            return
        if len(self._hot) >= self.cfg.hot_capacity:
            raise RuntimeError("hot set full; demote first")
        self._free_slot(s)
        s.hot = True
        s.promoted_window = self._window_idx
        self._hot[sid] = s
        self.promotions += 1
        self._window_migrations += 1
        if self.obs:
            self.obs.metrics.counter("hybrid_migrations",
                                     kind="promote").inc()

    def demote(self, sid) -> None:
        """Re-mint a hot session back into the packed tail (bit-identical
        to having always been a tail row: minting is deterministic)."""
        s = self.sessions[sid]
        if not s.hot:
            return
        self._hot.pop(sid)
        self._mint_into_tail(sid, s)
        self.demotions += 1
        self._window_migrations += 1
        if self.obs:
            self.obs.metrics.counter("hybrid_migrations",
                                     kind="demote").inc()

    # ---- access-driven movement with hysteresis ----
    def touch(self, sid) -> None:
        self._window_touches += 1
        if self._window_touches >= self.cfg.window:
            self._roll_window()
        s = self.sessions[sid]
        s.access += 1
        if s.hot or s.access < self.cfg.promote_after:
            return
        # each promotion is 1 migration; promotion-by-swap costs 2
        budget = (self.cfg.max_migrations_per_window
                  - self._window_migrations)
        if len(self._hot) < self.cfg.hot_capacity:
            if budget >= 1:
                self.promote(sid)
            return
        if budget < 2:
            return
        victim = self._demotion_victim(floor=s.access)
        if victim is not None:
            self.demote(victim)
            self.promote(sid)

    def _demotion_victim(self, floor: int) -> Optional[str]:
        """Least-touched residency-expired hot session strictly colder
        than ``floor``, or None — fresh promotions are immune, so an
        adversarial alternating pattern at the boundary cannot thrash."""
        expired = [
            (s.access, sid) for sid, s in self._hot.items()
            if self._window_idx - s.promoted_window >= self.cfg.min_residency
        ]
        if not expired:
            return None
        access, sid = min(expired)
        return sid if access < floor else None

    def _roll_window(self) -> None:
        self._window_idx += 1
        self._window_touches = 0
        self._window_migrations = 0
        for s in self.sessions.values():
            s.access = 0

    # ------------------------------------------------------------------
    # the fused classify front door
    # ------------------------------------------------------------------
    def _device_tail(self):
        """Alive-compacted device mirror of the tail (lazily rebuilt)."""
        if not self._dirty and self._dev is not None:
            return self._dev
        order = [sid for sid, s in self.sessions.items() if not s.hot]
        slots = np.asarray([self.sessions[sid].slot for sid in order],
                           np.int64)
        if slots.size:
            u8 = self._t_u8[slots]
            base = _fold_i32(self._t_base[slots])
        else:
            u8 = np.zeros((0, self.m), np.uint8)
            base = np.zeros(0, np.int32)
        wide = {}
        for i, sid in enumerate(order):
            slot = self.sessions[sid].slot
            if slot in self._t_wide:
                wide[i] = self._t_wide[slot]
        self._dev = (jnp.asarray(u8), jnp.asarray(base), wide, order)
        self._t_order = order
        self._dirty = False
        return self._dev

    def slab(self) -> HybridSlab:
        """The population as one hot-carrying slab (hot rows first)."""
        u8, base, wide, order = self._device_tail()
        hot = list(self._hot.items())
        meta = np.asarray([[s.v, s.n_private] for _, s in hot],
                          np.int32).reshape(len(hot), 2)
        sums = np.asarray([[self.k * (s.v + s.n_private)] for _, s in hot],
                          np.float32).reshape(len(hot), 1)
        return HybridSlab(
            cells_u8=u8, base=base, wide=wide,
            hot_meta=meta, hot_sums=sums,
            local_version=self.local_version)

    def classify(self, *, bn: int | None = None,
                 bm: int | None = None) -> HybridView:
        """Classify the local clock against every session in ONE fused
        device sweep: exact verdicts (fp ≡ 0) for the hot set, packed
        bloom verdicts (bit-identical to a flat slab) for the tail."""
        slab = self.slab()
        hot_sids = list(self._hot)
        tail_sids = self._t_order
        H, T = len(hot_sids), len(tail_sids)
        query = self.local_clock()
        if H and T:
            res = self.engine.classify(query, slab, bn=bn, bm=bm)
        elif T:
            res = self.engine.classify(
                query, PackedSlab(slab.cells_u8, slab.base, wide=slab.wide),
                bn=bn, bm=bm)
        elif H:
            res = self._hot_only_result(slab)
        else:
            return HybridView(sids=[], hot=np.zeros(0, bool),
                              q_le_p=np.zeros(0, bool),
                              p_le_q=np.zeros(0, bool),
                              fp_q_before_p=np.zeros(0, np.float32),
                              fp_p_before_q=np.zeros(0, np.float32),
                              sum_p=np.zeros(0, np.float32),
                              sum_q=float(self._local_cells.sum()),
                              engine="empty")
        view = HybridView(
            sids=hot_sids + tail_sids,
            hot=np.arange(H + T) < H,
            q_le_p=np.asarray(res.q_le_p, bool),
            p_le_q=np.asarray(res.p_le_q, bool),
            fp_q_before_p=np.asarray(res.fp_q_before_p, np.float32),
            fp_p_before_q=np.asarray(res.fp_p_before_q, np.float32),
            sum_p=np.asarray(res.sum_p, np.float32),
            sum_q=float(np.asarray(res.sum_q)),
            engine=res.engine or "")
        if self.obs:
            self.obs.metrics.counter("hybrid_classified", path="hot").inc(H)
            self.obs.metrics.counter("hybrid_classified", path="tail").inc(T)
            self.obs.metrics.gauge("hybrid_hot_occupancy").set(H)
            self.obs.metrics.gauge("hybrid_tail_m").set(self.m)
            strict = view.q_le_p[H:] ^ view.p_le_q[H:]
            fps = np.where(view.q_le_p[H:], view.fp_q_before_p[H:],
                           view.fp_p_before_q[H:])[strict]
            if fps.size:
                self.obs.metrics.histogram("hybrid_tail_fp").observe_many(
                    np.clip(fps, 1e-30, 1.0))
        if self.adaptive is not None:
            self.adaptive.observe(view)
        return view

    def _hot_only_result(self, slab: HybridSlab) -> ClassifyResult:
        """Host containment math for the degenerate no-tail population —
        same verdict semantics as the kernel's hot lanes."""
        V = slab.local_version
        v = slab.hot_meta[:, 0]
        npriv = slab.hot_meta[:, 1]
        z = np.zeros(v.shape[0], np.float32)
        return ClassifyResult(
            q_le_p=jnp.asarray(V <= v), p_le_q=jnp.asarray((v <= V)
                                                           & (npriv == 0)),
            sum_q=jnp.asarray(np.float32(self._local_cells.sum())),
            sum_p=jnp.asarray(slab.hot_sums[:, 0]),
            fp_q_before_p=jnp.asarray(z), fp_p_before_q=jnp.asarray(z),
            engine="hot_exact")

    def hot_hit_rate(self) -> float:
        """Fraction of classified rows served by the exact path."""
        if not self.obs:
            return 0.0
        hot = self.obs.metrics.counter("hybrid_classified", path="hot").value
        tail = self.obs.metrics.counter("hybrid_classified",
                                        path="tail").value
        total = hot + tail
        return hot / total if total else 0.0

    # ------------------------------------------------------------------
    # all-pairs
    # ------------------------------------------------------------------
    def pairs(self, *, bi=None, bj=None, bm=None):
        """All-pairs over the population: the packed sweep over every
        row's bloom shadow (bit-identical to a flat slab), with the
        hot-hot block patched to exact containment verdicts (fp ≡ 0)."""
        hot_sids = list(self._hot)
        _, _, _, tail_sids = self._device_tail()
        order = hot_sids + tail_sids
        N = len(order)
        if N == 0:
            raise ValueError("empty population")
        u8 = np.zeros((N, self.m), np.uint8)
        base = np.zeros(N, np.int64)
        wide: dict[int, np.ndarray] = {}
        for i, sid in enumerate(order):
            s = self.sessions[sid]
            cells = (self._mint_cells(s) if s.hot
                     else self._tail_logical(s.slot))
            b = int(cells.min()) if cells.size else 0
            resid = cells - b
            if resid.max(initial=0) <= 255:
                u8[i] = resid.astype(np.uint8)
                base[i] = b
            else:
                wide[i] = _fold_i32(cells)
        slab = PackedSlab(jnp.asarray(u8), jnp.asarray(_fold_i32(base)),
                          base_host=base, wide=wide)
        res = self.engine.pairs(slab, bi=bi, bj=bj, bm=bm)
        H = len(hot_sids)
        if H:
            le = np.array(res.le, bool)
            ge = np.array(res.ge, bool)
            fp = np.array(res.fp, np.float32)
            hs = [self._hot[sid] for sid in hot_sids]
            ev = [set(s.events) for s in hs]
            for a in range(H):
                for b_ in range(H):
                    le[a, b_] = (hs[a].v <= hs[b_].v
                                 and ev[a] <= ev[b_])
                    fp[a, b_] = 0.0
            ge[:H, :H] = le[:H, :H].T
            conc = np.array(res.conc, bool)
            conc[:H, :H] = ~(le[:H, :H] | ge[:H, :H])
            res = dataclasses.replace(
                res, le=jnp.asarray(le), ge=jnp.asarray(ge),
                conc=jnp.asarray(conc), fp=jnp.asarray(fp),
                engine=(res.engine or "") + "+hot_exact")
        return res, order

    def _tail_logical(self, slot: int) -> np.ndarray:
        if slot in self._t_wide:
            return (np.asarray(self._t_wide[slot], np.int64)
                    & 0xFFFFFFFF)
        return self._t_u8[slot].astype(np.int64) + int(self._t_base[slot])

    # ------------------------------------------------------------------
    # geometry resize (quiesce-point fold)
    # ------------------------------------------------------------------
    def resize_tail(self, new_m: int, *, detail: str = "") -> None:
        """Fold the tail geometry to ``new_m`` (a power-of-two divisor
        of the current ``m``) at a quiesce point.

        The fold is EXACT: probe indices are ``mod m``, so
        ``cell'[j] = Σ_i cells[j + i·new_m]`` equals minting at
        ``new_m`` outright, and total sums are geometry-independent.
        Every live row gets an audit record carrying its pre-fold wire
        frame and the folded row's CRC, so ``replay_resize`` re-checks
        the whole migration bit-for-bit."""
        from repro.hybrid.adaptive import fold_pow2
        old_m = self.m
        if new_m == old_m:
            return
        if new_m <= 0 or old_m % new_m or (new_m & (new_m - 1)):
            raise ValueError(f"new_m={new_m} must be a pow2 divisor "
                             f"of m={old_m}")
        live = [(sid, s) for sid, s in self.sessions.items() if not s.hot]
        self.audit.record(
            "resize", "hybrid/tail",
            detail=json.dumps({"old_m": old_m, "new_m": new_m,
                               "rows": len(live),
                               "policy": detail}, sort_keys=True))
        for sid, s in live:
            cells = self._tail_logical(s.slot)
            snap = {"cells": _fold_i32(cells), "base": 0, "k": self.k}
            folded = fold_pow2(cells, new_m)
            self.audit.record(
                "resize_row", sid,
                local_frame=wire.encode_clock(snap),
                peer_crc=wire.cells_crc(_fold_i32(folded)),
                detail=json.dumps({"new_m": new_m}))
        # fold the chain probes + local clock, then re-slot every row
        self.m = new_m
        self._probes = self._probes % new_m
        self._local_cells = fold_pow2(self._local_cells, new_m)
        self._t_u8 = np.zeros((self.cfg.tail_capacity, new_m), np.uint8)
        self._t_base[:] = 0
        self._t_sums[:] = 0.0
        self._t_alive[:] = False
        self._t_wide.clear()
        self._t_free = list(range(self.cfg.tail_capacity - 1, -1, -1))
        for sid, s in live:
            s.slot = None
            self._mint_into_tail(sid, s)
        self.resizes += 1
        self._dirty = True
        if self.obs:
            self.obs.metrics.counter("hybrid_resizes").inc()
            self.obs.metrics.gauge("hybrid_tail_m").set(new_m)


def _fold_i32(cells) -> np.ndarray:
    """Fold int64 logical values onto the int32 mod-2^32 circle."""
    return (np.asarray(cells, np.int64)
            & 0xFFFFFFFF).astype(np.uint32).view(np.int32)
