"""AdaptivePolicy: fp-budget-driven (m, k) for the hybrid tail.

Operators declare an ``fp_budget``; nobody hand-picks (m, k).  The
policy watches the claimed-fp histogram the engine streams per classify
window and, when the budget has slack, re-derives the smallest tail
geometry that still meets it — then migrates at a quiesce point via the
EXACT power-of-two fold (``fold_pow2``), with per-row audit records so
the whole migration replays bit-for-bit (``replay_resize``).

The derivation inverts paper Eq. 3 at the binding operating point: the
claimed fp of a strict verdict is ``(1 - (1 - 1/m)^Σq)^Σp``, largest
for the peer with the SMALLEST total sum Σp — in a hybrid population
that peer lives in the tail, because the tiny-history sessions that
would otherwise pin m to a huge value are served exactly by the hot
set.  That is precisely why the hybrid engine can run a smaller m at
an equal budget (the headline ``BENCH_hybrid.json`` demonstrates).

Shrink-only by design: growth would need re-minting from event history
(the engine CAN re-mint — it keeps exact descriptors — but a grown
geometry changes no verdict that was already within budget, so the
controller never pays for it).  The companion k recommendation
(``k ≈ ln2 · m / n̂`` clamped to [1, 8]) is reported in the audit
detail for the next minting epoch; the fold itself preserves k so
bit-identity holds across the resize.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Optional

import numpy as np

from repro.core import wire
from repro.obs.audit import ReplayReport

__all__ = ["AdaptiveConfig", "AdaptivePolicy", "derive_mk", "fold_pow2",
           "replay_resize"]


def fold_pow2(cells, new_m: int) -> np.ndarray:
    """Exact geometry fold of counting-bloom cells to a pow2 divisor.

    Probes are ``(h1 + i·h2) mod m``; with ``new_m | m`` (both pow2),
    ``(x mod m) mod new_m == x mod new_m``, so summing the aliased
    cell groups is bit-identical to having minted at ``new_m``:
    ``cell'[j] = Σ_i cells[j + i·new_m]``.  Total sum is preserved."""
    cells = np.asarray(cells)
    m = cells.shape[-1]
    if m % new_m or (new_m & (new_m - 1)) or new_m <= 0:
        raise ValueError(f"new_m={new_m} must be a pow2 divisor of m={m}")
    shape = cells.shape[:-1] + (m // new_m, new_m)
    return cells.reshape(shape).sum(axis=-2)


def derive_mk(fp_budget: float, sum_q: float, sum_p_min: float, *,
              m_max: int, k: int, m_min: int = 128) -> tuple[int, int]:
    """Smallest pow2 ``m`` (a divisor of ``m_max``, ≥ ``m_min``) whose
    claimed Eq. 3 fp at the binding operating point (local sum Σq vs
    the smallest peer sum Σp) stays within budget, plus the textbook
    ``k`` for that geometry.

    Eq. 3: fp = (1 - (1 - 1/m)^Σq)^Σp ≤ B  ⟺
           (1 - 1/m)^Σq ≥ 1 - B^(1/Σp); evaluated with the same
    log1p/expm1 stabilization the kernels use."""
    if not (0.0 < fp_budget <= 1.0):
        raise ValueError(f"fp_budget={fp_budget} out of (0, 1]")
    if sum_p_min <= 0 or sum_q <= 0:
        return m_max, k

    def claimed(m: int) -> float:
        inner = -math.expm1(sum_q * math.log1p(-1.0 / m))
        return math.exp(sum_p_min * math.log(max(inner, 1e-300)))

    best = m_max
    m = m_max
    while m // 2 >= m_min and claimed(m // 2) <= fp_budget:
        m //= 2
        best = m
    n_hat = max(1.0, (sum_q + sum_p_min) / (2.0 * k))
    k_new = max(1, min(8, round(math.log(2.0) * best / n_hat)))
    return best, k_new


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Controller knobs — the only required one is the budget."""

    fp_budget: float = 1e-4
    window: int = 64          # classifies between re-derivations
    m_min: int = 128          # lane-aligned floor for the tail geometry
    headroom: float = 1.0     # budget scale the derivation aims at


class AdaptivePolicy:
    """Watches the per-window claimed-fp signal and resizes the tail.

    Attached by ``HybridEngine`` when its config declares ``fp_budget``;
    ``observe`` is called with every ``HybridView``.  The policy keeps
    the worst claimed fp and the smallest live tail sum seen in the
    window; at the window boundary it re-derives (m, k) and — when the
    geometry can shrink while honoring the budget — triggers the
    audited quiesce-point fold."""

    def __init__(self, engine, cfg: AdaptiveConfig = AdaptiveConfig()):
        self.engine = engine
        self.cfg = cfg
        self._seen = 0
        self._worst_fp = 0.0
        self._min_sum_p: Optional[float] = None
        self.last_recommendation: Optional[tuple[int, int]] = None

    def observe(self, view) -> None:
        tail = ~view.hot
        if tail.any():
            strict = (view.q_le_p ^ view.p_le_q) & tail
            if strict.any():
                fps = np.where(view.q_le_p, view.fp_q_before_p,
                               view.fp_p_before_q)[strict]
                self._worst_fp = max(self._worst_fp, float(fps.max()))
            sums = view.sum_p[tail]
            sums = sums[sums > 0]
            if sums.size:
                mn = float(sums.min())
                self._min_sum_p = (mn if self._min_sum_p is None
                                   else min(self._min_sum_p, mn))
        self._seen += 1
        if self._seen >= self.cfg.window:
            self.rederive(sum_q=view.sum_q)
            self._seen = 0
            self._worst_fp = 0.0
            self._min_sum_p = None

    def rederive(self, *, sum_q: float) -> tuple[int, int]:
        """One control step: invert Eq. 3 against the window's binding
        operating point and fold the tail if the budget allows."""
        eng = self.engine
        if self._min_sum_p is None:
            return eng.m, eng.k
        m_new, k_new = derive_mk(
            self.cfg.fp_budget * self.cfg.headroom, sum_q,
            self._min_sum_p, m_max=eng.m, k=eng.k, m_min=self.cfg.m_min)
        self.last_recommendation = (m_new, k_new)
        if m_new < eng.m:
            eng.resize_tail(m_new, detail=json.dumps({
                "fp_budget": self.cfg.fp_budget,
                "worst_claimed_fp": self._worst_fp,
                "min_sum_p": self._min_sum_p,
                "k_next_epoch": k_new}, sort_keys=True))
        return m_new, k_new


def replay_resize(trail) -> ReplayReport:
    """Re-verify a resize migration bit-for-bit from the audit trail.

    Every ``resize_row`` record carries the row's pre-fold wire frame
    and the CRC of the folded logical row the engine produced; replay
    decodes the frame, re-folds, and compares CRCs — exact regardless
    of what happened to the engine since.  Requires the trail to have
    been recorded with ``store_frames=True``."""
    rep = ReplayReport()
    for rec in trail.records:
        if rec.kind != "resize_row":
            continue
        if rec.local_frame is None:
            rep.skipped += 1
            continue
        rep.checked += 1
        snap = wire.decode_clock(rec.local_frame)
        new_m = int(json.loads(rec.detail)["new_m"])
        logical = (np.asarray(snap["cells"], np.int64)
                   + int(snap["base"]))
        folded = fold_pow2(logical & 0xFFFFFFFF, new_m)
        crc = wire.cells_crc(
            (folded & 0xFFFFFFFF).astype(np.uint32).view(np.int32))
        if crc == rec.peer_crc:
            rep.matched += 1
        else:
            rep.mismatches.append({
                "seq": rec.seq, "peer_id": rec.peer_id,
                "recorded": rec.peer_crc, "replayed": crc})
    return rep
