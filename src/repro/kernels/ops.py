"""Kernel wrappers around the bloom-clock Pallas kernels.

Handles: probe-index precomputation (hashing), the shared pad-and-crop
plan (``tile2d`` — every wrapper pads through it instead of duplicating
padding logic), platform dispatch (interpret=True off-TPU so the SAME
kernel bodies are exercised on CPU), engine selection for the
comparison kernels (packed-u8 triangle / rectangle / MXU thermometer /
legacy int32 — consulted from the measured ``kernels.autotune`` table),
and un-padding.

The packed engines consume the quantized slab layout from
``kernels.pack`` (u8 window residuals + per-slot int32 base).  The
int32 entry points (``_compare_matrix`` / ``_classify_vs_many``) remain
drop-in: ``_compare_matrix`` packs on the fly whenever the value span
fits a byte and silently falls back to the int32 kernel otherwise.

PUBLIC SURFACE: the comparison wrappers here are the engine room of
``repro.causal.CausalEngine`` — new code should call its two verbs
(``engine.classify`` / ``engine.pairs``) instead of these.  The
pre-front-door names (``compare_matrix``, ``classify_vs_many``, ...)
remain importable as thin ``DeprecationWarning`` shims that delegate to
the same implementations, so their results are bit-identical.
``repro.core.clock`` stays the algorithmic reference.
"""
from __future__ import annotations

import functools
import math
import warnings

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.hashing import bloom_indices
from repro.kernels import autotune
from repro.kernels.bloom_compare import bloom_merge_compare_pallas
from repro.kernels.bloom_matrix import (
    bloom_matrix_mxu_pallas,
    bloom_matrix_packed_pallas,
    bloom_matrix_pallas,
    bloom_matrix_tri_pallas,
    bloom_one_vs_many_packed_pallas,
    bloom_one_vs_many_pallas,
)
from repro.kernels.bloom_tick import bloom_tick_pallas
from repro.kernels.generate import bloom_hybrid_classify_pallas
from repro.kernels.pack import U8_MAX

__all__ = [
    "tick",
    "merge_compare",
    "classify_vs_many",
    "classify_vs_many_packed",
    "classify_vs_many_packed_sharded",
    "overlay_wide_classify",
    "compare_matrix",
    "compare_matrix_packed",
    "compare_matrix_packed_sharded",
    "pad_to",
    "pick_block",
    "tile2d",
    "eq3_outer",
    "MXU_SPAN_MAX",
]

LANE = 128  # TPU lane width

# Most recent comparison dispatch decision (op, engine, block shapes),
# recorded by the resolution helpers below.  Engine/block resolution is
# host-side (never traced), so this is accurate per call; the
# ``CausalEngine`` front-door snapshots it into result metadata and the
# fleet benchmark records it so perf claims name the engine they
# measured.
LAST_DISPATCH: dict = {}


def _note_dispatch(op: str, engine: str, **blocks) -> None:
    LAST_DISPATCH.clear()
    LAST_DISPATCH.update({"op": op, "engine": engine, **blocks})

# widest value span (max - min logical cell) the MXU thermometer engine
# accepts; FLOPs scale linearly with it, so wide windows go elementwise
MXU_SPAN_MAX = 64
_MXU_SPAN_BUCKETS = (8, 16, 32, 64)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pad_to(x: jax.Array, mult: int, axis: int, value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def pick_block(padded: int, want: int, lane: int = LANE) -> int:
    """Largest lane-multiple block <= want that divides ``padded``."""
    q = padded // lane
    best = 1
    for d in range(1, q + 1):
        if q % d == 0 and d * lane <= max(want, lane):
            best = d
    return best * lane


def tile2d(x: jax.Array, want_rows: int, want_lanes: int,
           *, row_align: int = 8, lane: int = LANE, pad_value=0):
    """Shared pad-and-crop plan for [R, C] slabs.

    Pads the lane axis to the TPU lane width and the row axis to the
    sublane alignment, then picks the largest aligned blocks <= the
    requested sizes that divide the padded shape.  Every kernel wrapper
    goes through this instead of re-deriving padding; callers crop
    results back to the original ``x.shape``.

    Returns (x_padded, row_block, lane_block).
    """
    xp = pad_to(x, lane, axis=1, value=pad_value)
    bc = pick_block(xp.shape[1], want_lanes, lane=lane)
    xp = pad_to(xp, row_align, axis=0, value=pad_value)
    br = pick_block(xp.shape[0], want_rows, lane=row_align)
    return xp, br, bc


def _pad_base(base: jax.Array, n_rows: int) -> jax.Array:
    """Base lanes as the [Np, 1] int32 column the kernels expect."""
    b = jnp.asarray(base, jnp.int32).reshape(-1, 1)
    return pad_to(b, n_rows, axis=0)


def _span_bucket(span: int) -> int:
    for b in _MXU_SPAN_BUCKETS:
        if span <= b:
            return b
    raise ValueError(f"value span {span} exceeds MXU_SPAN_MAX={MXU_SPAN_MAX}")


# ---------------------------------------------------------------------------
# tick / pairwise merge-compare
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "bb", "bm", "interpret"))
def tick(
    cells: jax.Array,        # [B, m] int32
    ev_hi: jax.Array,        # [B, E] uint32
    ev_lo: jax.Array,        # [B, E] uint32
    *,
    k: int = 4,
    bb: int = 8,
    bm: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched bloom tick: E events per clock, k probes each."""
    if interpret is None:
        interpret = not _on_tpu()
    B, m = cells.shape
    idx = bloom_indices(ev_hi, ev_lo, k, m)          # [B, E, k] uint32
    probes = idx.reshape(B, -1).astype(jnp.int32)    # [B, P], all < m
    cells_p, bb_eff, bm_eff = tile2d(cells, bb, bm)  # padded cols never hit
    probes_p = pad_to(probes, cells_p.shape[0], axis=0)  # pad rows: probe 0 hits
    out = bloom_tick_pallas(cells_p, probes_p, bb=bb_eff, bm=bm_eff,
                            interpret=interpret)
    return out[:B, :m]                               # padded-row incs sliced off


@functools.partial(jax.jit, static_argnames=("bb", "bm", "interpret"))
def merge_compare(
    a: jax.Array,            # [B, m] int32 logical cells
    b: jax.Array,
    *,
    bb: int = 8,
    bm: int = 512,
    interpret: bool | None = None,
):
    """Fused receive-path op. Returns dict with merged cells, dominance
    flags, sums and Eq.3 fp rates (see bloom_compare.py)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, m = a.shape
    # zero padding perturbs neither dominance (0<=0) nor sums; Eq. 3 must
    # use the TRUE m, passed statically to the kernel.
    a_p, bb_eff, bm_eff = tile2d(a, bb, bm)
    b_p, _, _ = tile2d(b, bb_eff, bm_eff)
    merged, flags, sums, fp = bloom_merge_compare_pallas(
        a_p, b_p, bb=bb_eff, bm=bm_eff, m_true=m, interpret=interpret
    )
    return {
        "merged": merged[:B, :m],
        "a_le_b": flags[:B, 0].astype(bool),
        "b_le_a": flags[:B, 1].astype(bool),
        "sum_a": sums[:B, 0],
        "sum_b": sums[:B, 1],
        "fp_a_before_b": fp[:B, 0],
        "fp_b_before_a": fp[:B, 1],
    }


# ---------------------------------------------------------------------------
# one-vs-many classify
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("bn", "bm", "interpret"))
def _classify_vs_many(
    q: jax.Array,            # [m] int32 local (query) logical cells
    peers: jax.Array,        # [N, m] int32 peer slab logical cells
    *,
    bn: int = 8,
    bm: int = 512,
    interpret: bool | None = None,
):
    """One-vs-many fused classify on an int32 slab (legacy layout).

    Returns dict with per-peer ``q_le_p`` / ``p_le_q`` dominance flags,
    total sums and Eq. 3 fp rates both directions.  Zero padding
    perturbs neither dominance nor sums; Eq. 3 uses the TRUE m.
    """
    if interpret is None:
        interpret = not _on_tpu()
    (m,) = q.shape
    N, mp_ = peers.shape
    assert m == mp_, (q.shape, peers.shape)
    peers_p, bn_eff, bm_eff = tile2d(peers, bn, bm)
    q_p = pad_to(q[None, :], peers_p.shape[1], axis=1)
    flags, sums, fp = bloom_one_vs_many_pallas(
        q_p, peers_p, bn=bn_eff, bm=bm_eff, m_true=m, interpret=interpret
    )
    return _classify_dict(flags, sums, fp, N)


def _classify_dict(flags, sums, fp, N):
    return {
        "q_le_p": flags[:N, 0].astype(bool),
        "p_le_q": flags[:N, 1].astype(bool),
        "sum_q": sums[0, 0],
        "sum_p": sums[:N, 1],
        "fp_q_before_p": fp[:N, 0],
        "fp_p_before_q": fp[:N, 1],
    }


def _one_vs_many_blocks(N: int, m: int, bn, bm, interpret: bool,
                        use_table: bool = True):
    """Resolve one-vs-many block defaults: explicit args > autotune >
    per-backend defaults.  The sharded wrapper resolves at FULL-N too,
    so both paths always tile the m axis identically."""
    if bn is None or bm is None:
        cfg = (autotune.lookup("one_vs_many", N, N, m, interpret) or {}) \
            if use_table else {}
        bn = bn or cfg.get("bn", 8 if not interpret else 128)
        bm = bm or cfg.get("bm", 512)
    return bn, bm


def _one_vs_many_body(q, peers, base, bn, bm, m: int, interpret: bool):
    """Pad one packed slab (or one row shard of it) and run the kernel;
    shared by the unsharded and shard_map'ed classify paths."""
    nd = peers.shape[0]
    peers_p, bn_eff, bm_eff = tile2d(peers, bn, bm)
    q_p = pad_to(q[None, :], peers_p.shape[1], axis=1)
    base_p = _pad_base(base, peers_p.shape[0])
    flags, sums, fp = bloom_one_vs_many_packed_pallas(
        q_p, peers_p, base_p, bn=bn_eff, bm=bm_eff, m_true=m,
        interpret=interpret)
    return flags[:nd], sums[:nd], fp[:nd]


def _classify_vs_many_packed(
    q: jax.Array,            # [m] int32 local (query) logical cells
    peers: jax.Array,        # [N, m] uint8 residual slab
    base: jax.Array,         # [N] (or [N, 1]) int32 per-slot offsets
    *,
    bn: int | None = None,
    bm: int | None = None,
    interpret: bool | None = None,
    use_autotune: bool = True,
):
    """One-vs-many classify against a PACKED slab: u8 HBM reads, the
    per-row base is re-applied tile-locally in VMEM.  Same result dict
    as ``_classify_vs_many``."""
    if interpret is None:
        interpret = not _on_tpu()
    (m,) = q.shape
    N, mp_ = peers.shape
    assert m == mp_, (q.shape, peers.shape)
    bn, bm = _one_vs_many_blocks(N, m, bn, bm, interpret, use_autotune)
    _note_dispatch("one_vs_many", "packed", bn=bn, bm=bm)
    flags, sums, fp = _one_vs_many_body(q, peers, base, bn, bm, m, interpret)
    return _classify_dict(flags, sums, fp, N)


def _classify_vs_many_packed_sharded(
    q: jax.Array,            # [m] int32 local (query) logical cells
    peers: jax.Array,        # [N, m] uint8 residual slab, row-sharded
    base: jax.Array,         # [N] (or [N, 1]) int32 per-slot offsets
    *,
    mesh,                    # jax.sharding.Mesh carrying ``axis``
    axis: str,               # mesh axis the slab rows are sharded over
    bn: int | None = None,
    bm: int | None = None,
    interpret: bool | None = None,
    use_autotune: bool = True,
):
    """``_classify_vs_many_packed`` over a row-sharded slab via shard_map.

    The query is replicated; every device runs the packed one-vs-many
    Pallas kernel on its own ``[N/d, m]`` row shard — no cross-device
    traffic at all (the reduction is per-row).  Block shapes are
    resolved ONCE at full-N granularity so every shard count tiles the
    m axis identically: the f32 sum accumulation order (and therefore
    the Eq. 3 fp bits) is bit-identical across shard counts and vs the
    unsharded engine.
    """
    if interpret is None:
        interpret = not _on_tpu()
    (m,) = q.shape
    N, mp_ = peers.shape
    assert m == mp_, (q.shape, peers.shape)
    shards = mesh.shape[axis]
    if N % shards:
        raise ValueError(f"slab rows {N} not divisible by {shards} shards")
    bn, bm = _one_vs_many_blocks(N, m, bn, bm, interpret, use_autotune)
    _note_dispatch("one_vs_many", "packed_sharded", bn=bn, bm=bm,
                   shards=shards)
    fn = _sharded_classify_fn(mesh, axis, bn, bm, m, interpret)
    flags, sums, fp = fn(q, peers, jnp.asarray(base, jnp.int32).reshape(-1))
    return _classify_dict(flags, sums, fp, N)


@functools.lru_cache(maxsize=64)
def _sharded_classify_fn(mesh, axis: str, bn: int, bm: int, m: int,
                         interpret: bool):
    """Jitted shard_map'd one-vs-many classify, cached per (mesh, axis,
    blocks) so repeated gossip rounds reuse the compiled executable
    instead of re-wrapping and re-tracing the kernel every call."""
    def shard_body(qv, cu8, b):
        return _one_vs_many_body(qv, cu8, b, bn, bm, m, interpret)

    return jax.jit(shard_map(
        shard_body, mesh=mesh,
        in_specs=(P(), P(axis, None), P(axis)),
        out_specs=(P(axis, None),) * 3,
        check_rep=False,     # no replication rule for pallas_call
    ))


def _overlay_wide_classify(out: dict, q: jax.Array, wide_idx,
                           wide_rows: jax.Array, *,
                           interpret: bool | None = None) -> dict:
    """Sparse promoted-row overlay for one-vs-many classify results.

    ``out`` is a packed-slab result dict whose promoted slots hold
    garbage (their u8 residuals were clipped at promotion); re-classify
    JUST the ``[P, m]`` promoted rows through the exact int32 kernel and
    patch them in.  The O(N) bulk stays packed — a single overflowed row
    no longer drops the whole slab compare to the int32 fallback.
    """
    wout = _classify_vs_many(q, wide_rows, interpret=interpret)
    idx = jnp.asarray(wide_idx, jnp.int32)
    patched = dict(out)
    for key in ("q_le_p", "p_le_q", "sum_p",
                "fp_q_before_p", "fp_p_before_q"):
        patched[key] = jnp.asarray(out[key]).at[idx].set(wout[key])
    return patched


# ---------------------------------------------------------------------------
# hybrid classify (exact hot rows + packed tail, one fused kernel)
# ---------------------------------------------------------------------------

def _hybrid_blocks(N: int, H: int, m: int, bn, bm, interpret: bool,
                   use_table: bool = True):
    """Resolve hybrid block defaults: explicit args > autotune (keyed on
    total rows AND hot count — the hot/tail split changes the winning
    tile) > per-backend defaults."""
    if bn is None or bm is None:
        cfg = (autotune.lookup("hybrid", N, H, m, interpret) or {}) \
            if use_table else {}
        bn = bn or cfg.get("bn", 8 if not interpret else 128)
        bm = bm or cfg.get("bm", 512)
    return bn, bm


def _classify_hybrid(
    q: jax.Array,            # [m] int32 local (query) logical cells
    v_local: int,            # local-chain version V the hot rows are vs
    hot_meta: jax.Array,     # [H, 2] int32 (v, n_private) exact rows
    hot_sums: jax.Array,     # [H] (or [H, 1]) f32 shadow-row total sums
    tail: jax.Array,         # [T, m] uint8 residual slab
    tail_base: jax.Array,    # [T] (or [T, 1]) int32 per-slot offsets
    *,
    bn: int | None = None,
    bm: int | None = None,
    interpret: bool | None = None,
    use_autotune: bool = True,
):
    """One query vs an exact hot set PLUS a packed bloom tail, fused.

    Hot rows never touch bloom cells: their verdicts are integer
    compares of (v, n_private) chain coordinates against ``v_local`` —
    measured AND claimed fp are identically zero.  Tail rows run the
    packed one-vs-many math unchanged, so their verdicts/sums/fp stay
    bit-identical to a flat packed slab classified with the same bm.
    Returns the ``_classify_dict`` layout over H+T rows, hot first.
    """
    if interpret is None:
        interpret = not _on_tpu()
    (m,) = q.shape
    H = hot_meta.shape[0]
    T, mt_ = tail.shape
    assert m == mt_, (q.shape, tail.shape)
    assert H > 0 and T > 0, "hybrid needs both a hot set and a tail " \
        "(route single-representation slabs through the plain engines)"
    bn, bm = _hybrid_blocks(H + T, H, m, bn, bm, interpret, use_autotune)
    tail_p, bn_eff, bm_eff = tile2d(tail, bn, bm)
    q_p = pad_to(q[None, :], tail_p.shape[1], axis=1)
    base_p = _pad_base(tail_base, tail_p.shape[0])
    # pad hot rows to the tile grain with (v=0, n_private=0) filler —
    # cropped below, never observable
    meta_p = pad_to(jnp.asarray(hot_meta, jnp.int32), bn_eff, axis=0)
    hsum_p = pad_to(
        jnp.asarray(hot_sums, jnp.float32).reshape(-1, 1), bn_eff, axis=0)
    vloc = jnp.full((1, 1), v_local, jnp.int32)
    _note_dispatch("hybrid", "fused_hot_tail", bn=bn_eff, bm=bm_eff,
                   hot=H, tail=T)
    flags, sums, fp = bloom_hybrid_classify_pallas(
        q_p, vloc, meta_p, hsum_p, tail_p, base_p,
        bn=bn_eff, bm=bm_eff, m_true=m, interpret=interpret)
    Hp = meta_p.shape[0]
    flags = jnp.concatenate([flags[:H], flags[Hp:Hp + T]], axis=0)
    sums = jnp.concatenate([sums[:H], sums[Hp:Hp + T]], axis=0)
    fp = jnp.concatenate([fp[:H], fp[Hp:Hp + T]], axis=0)
    return _classify_dict(flags, sums, fp, H + T)


# ---------------------------------------------------------------------------
# all-pairs compare
# ---------------------------------------------------------------------------

_EQ3_CLIP = 1e-30


@functools.partial(jax.jit, static_argnames=("m_true",))
def _eq3_outer(row_sums, col_sums, m_true: int):
    """Eq. 3 fp of "row happened-before col" as an outer product in log
    space — identical expression to the reference / in-kernel finalize."""
    log_q = jnp.log1p(-1.0 / m_true)
    inner = jnp.clip(-jnp.expm1(col_sums[None, :] * log_q), _EQ3_CLIP, 1.0)
    return jnp.exp(row_sums[:, None] * jnp.log(inner))


# public alias: the registry's sparse promoted-row assembly re-finalizes
# fp from corrected sums through the SAME jitted expression, keeping its
# values bit-identical to the in-engine finalize
eq3_outer = _eq3_outer


@functools.partial(jax.jit, static_argnames=("m_true",))
def _packed_row_sums(cells_u8, base, m_true: int):
    s = jnp.sum(cells_u8.astype(jnp.int32), axis=1).astype(jnp.float32)
    return s + jnp.asarray(base, jnp.int32).reshape(-1).astype(jnp.float32) \
        * m_true


@functools.partial(jax.jit, static_argnames=("n", "m", "m_true", "bi"))
def _tri_combine(le, ge, row_sums, n: int, m: int, m_true: int, bi: int):
    """Mirror the block-upper-triangle results onto the lower triangle
    (le(i, j) == ge(j, i)), crop, and finalize sums/fp."""
    k = le.shape[0] // bi
    blk = jnp.arange(k).repeat(bi)
    upper = blk[:, None] <= blk[None, :]
    le_f = jnp.where(upper, le, ge.T)[:n, :m].astype(bool)
    ge_f = jnp.where(upper, ge, le.T)[:n, :m].astype(bool)
    return _matrix_dict(le_f, ge_f, row_sums, row_sums, m_true)


def _matrix_dict(le, ge, row_sums, col_sums, m_true):
    return {
        "a_le_b": le,
        "b_le_a": ge,
        "concurrent": jnp.logical_not(jnp.logical_or(le, ge)),
        "fp": _eq3_outer(row_sums, col_sums, m_true),
        "row_sums": row_sums,
        "col_sums": col_sums,
    }


def _matrix_blocks(engine, N, M, m, bi, bj, bm, interpret,
                   use_table: bool = True, shards: int = 1):
    """Resolve block shapes: explicit args > autotune table > defaults.

    Sharded resolution (``shards > 1``) consults the ``matrix_sharded``
    table entry keyed by the GLOBAL shape AND the shard count — never
    the plain ``matrix`` entry for the per-shard sub-shape — so a
    d-shard tune and a 1-shard tune whose shapes happen to collide can
    never poison each other's block choices."""
    if not use_table:
        cfg = {}
    elif shards > 1:
        cfg = autotune.lookup("matrix_sharded", N, M, m, interpret,
                              shards=shards) or {}
    else:
        cfg = autotune.lookup("matrix", N, M, m, interpret) or {}
    if shards == 1 and cfg.get("engine") != engine:
        cfg = {}
    if interpret:
        dflt = {"tri": (128, 128, 512), "full": (128, 128, 512),
                "mxu": (128, 128, 512), "i32": (128, 128, 512)}[engine]
    else:
        # keep the pairwise int16 difference (bi*bj*bm*2B) well inside VMEM
        dflt = {"tri": (8, 8, 512), "full": (8, 128, 512),
                "mxu": (128, 128, 128), "i32": (8, 128, 512)}[engine]
    return (bi or cfg.get("bi", dflt[0]),
            bj or cfg.get("bj", dflt[1]),
            bm or cfg.get("bm", dflt[2]))


def _compare_matrix_packed(
    cells: jax.Array,           # [N, m] uint8 residual slab (rows)
    base: jax.Array,            # [N] (or [N, 1]) int32 per-slot offsets
    cols: jax.Array = None,     # [M, m] uint8 column slab; None -> symmetric
    col_base: jax.Array = None,
    *,
    engine: str | None = None,  # "tri" | "full" | "mxu" | None = auto
    bi: int | None = None,
    bj: int | None = None,
    bm: int | None = None,
    uniform_base: bool | None = None,
    interpret: bool | None = None,
    use_autotune: bool = True,
):
    """Tiled all-pairs compare over packed u8 slab(s).

    Symmetric calls (``cols is None``) sweep only the block-upper
    triangle and mirror the rest by transposition.  Returns the same
    dict as ``_compare_matrix``.
    """
    if interpret is None:
        interpret = not _on_tpu()
    symmetric = cols is None
    if symmetric:
        cols, col_base = cells, base
    N, m = cells.shape
    M = cols.shape[0]
    if engine == "i32":
        # the legacy hint selects the int32 kernel in _compare_matrix;
        # a packed slab has no int32 kernel, so resolve to auto (flags
        # are exact under every packed engine) instead of raising —
        # registry.all_pairs(**kw) call sites keep working packed
        engine = None
    if engine is None:
        cfg = (autotune.lookup("matrix", N, M, m, interpret) or {}) \
            if use_autotune else {}
        engine = cfg.get("engine", "tri")
        if engine == "i32":
            engine = "tri"
        if engine == "mxu" and not _mxu_viable(cells, base, cols, col_base):
            engine = "tri"
    if engine == "tri" and not symmetric:
        engine = "full"
    if uniform_base is None:
        b = jnp.asarray(base).reshape(-1)
        cb = jnp.asarray(col_base).reshape(-1)
        uniform_base = bool((b == b[0]).all()) and bool((cb == b[0]).all())
    bi, bj, bm = _matrix_blocks(engine, N, M, m, bi, bj, bm, interpret,
                                use_autotune)
    _note_dispatch("matrix", engine, bi=bi, bj=bj, bm=bm)

    row_sums = _packed_row_sums(cells, base, m)
    col_sums = row_sums if symmetric else _packed_row_sums(cols, col_base, m)

    if engine == "tri":
        cells_p, bi_eff, bm_eff = tile2d(cells, max(bi, bj), bm)
        base_p = _pad_base(base, cells_p.shape[0])
        le, ge = bloom_matrix_tri_pallas(
            cells_p, base_p, bi=bi_eff, bm=bm_eff, m_true=m,
            with_base=not uniform_base, interpret=interpret)
        return _tri_combine(le, ge, row_sums, N, M, m, bi_eff)

    if engine == "full":
        le, ge = _full_rect_flags(cells, base, cols, col_base, bi, bj, bm,
                                  m, not uniform_base, interpret)
        return _matrix_dict(le.astype(bool), ge.astype(bool),
                            row_sums, col_sums, m)

    if engine == "mxu":
        lo, span = _logical_bounds(cells, base, cols, col_base)
        n_thr = _span_bucket(span)
        rows_p, bi_eff, bm_eff = tile2d(cells, bi, bm)
        cols_p, bj_eff, _ = tile2d(cols, bj, bm_eff)
        cols_p = pad_to(cols_p, rows_p.shape[1], axis=1)
        viol = bloom_matrix_mxu_pallas(
            rows_p, cols_p, _pad_base(base, rows_p.shape[0]),
            _pad_base(col_base, cols_p.shape[0]),
            n_thresholds=n_thr, lo=lo,
            bi=bi_eff, bj=bj_eff, bm=bm_eff, m_true=m, interpret=interpret)
        return _mxu_finalize(viol, cells, base, cols, col_base,
                             row_sums, col_sums, N, M, m, lo)

    raise ValueError(f"unknown packed engine: {engine}")


def _full_rect_flags(rows, row_base, cols, col_base, bi, bj, bm,
                     m: int, with_base: bool, interpret: bool):
    """Pad-and-call for the packed full-rect engine, shared by the
    unsharded "full" branch and every sharded ring step (duplicate pads
    CSE away under jit).  Returns (le, ge) cropped to the true [N, M]."""
    N, M = rows.shape[0], cols.shape[0]
    rows_p, bi_eff, bm_eff = tile2d(rows, bi, bm)
    cols_p, bj_eff, _ = tile2d(cols, bj, bm_eff)
    cols_p = pad_to(cols_p, rows_p.shape[1], axis=1)
    le, ge = bloom_matrix_packed_pallas(
        rows_p, cols_p, _pad_base(row_base, rows_p.shape[0]),
        _pad_base(col_base, cols_p.shape[0]),
        bi=bi_eff, bj=bj_eff, bm=bm_eff, m_true=m,
        with_base=with_base, interpret=interpret)
    return le[:N, :M], ge[:N, :M]


def _compare_matrix_packed_sharded(
    cells: jax.Array,           # [N, m] uint8 residual slab, row-sharded
    base: jax.Array,            # [N] (or [N, 1]) int32 per-slot offsets
    *,
    mesh,                       # jax.sharding.Mesh carrying ``axis``
    axis: str,                  # mesh axis the slab rows are sharded over
    engine: str | None = None,  # engine HINT; the ring resolves to "full"
    strategy: str | None = None,   # "ring" | "replicated" | None = table
    bi: int | None = None,
    bj: int | None = None,
    bm: int | None = None,
    uniform_base: bool | None = None,
    interpret: bool | None = None,
    use_autotune: bool = True,
    mesh_outputs: bool = True,
):
    """Symmetric all-pairs over a row-sharded packed slab.

    Two strategies, dispatched per shape from the autotune table's
    ``matrix_sharded`` entry (explicit ``strategy`` wins; default
    ``ring`` when the table is silent):

    ``ring`` — each of the ``d`` devices holds a ``[N/d, m]`` row shard
    and circulates a column shard around the mesh ring with
    ``ppermute``; every ring step compares its resident rows against
    the visiting columns, filling one ``[N/d, N/d]`` block of its
    ``[N/d, N]`` block-row.  The sweep is HALVED by symmetry: only
    ceil(d/2) visiting offsets are computed, and each off-diagonal
    block ships its transposed flags back across the ring
    (``le(j, i) == ge(i, j)^T``) to fill the mirror block.  Since PR 7
    the ring is also: DOUBLE-BUFFERED (the ppermute for step s+1 is
    issued before the compute on step s, so communication overlaps
    compute on real meshes); TRIANGLE-swept on the diagonal step (the
    resident-vs-resident block is symmetric, so the tri engine sweeps
    its upper half and mirrors locally); and DEDUPLICATED on the even-d
    half-way offset (only devices ``i < d/2`` run the kernel; the
    mirror halves arrive by a partial ppermute of the transposed
    flags).  Per-device work is the single-device triangle divided by
    d, so the ring wins wherever devices compute in parallel.

    ``replicated`` — don't shard the compare at all: gather the packed
    slab (u8 residuals + int32 bases, the cheapest representation to
    ship) onto one mesh device and run the plain single-device triangle
    engine there.  No per-step collectives and no SPMD program; this
    wins where mesh devices are time-sliced onto the same host cores
    (forced-host CI meshes) and ring collectives buy no parallelism —
    exactly what the autotuner's cost model predicts and its measured
    sweep confirms per backend.

    Both strategies are bit-identical to the unsharded sweep: flags are
    exact (mirroring moves bits, it never recomputes them; replication
    runs the very same kernel), and the fp / sums finalize runs through
    the SAME ``_eq3_outer`` / ``_packed_row_sums`` expressions.

    Pass ``uniform_base`` explicitly on hot paths (the registry does,
    from its host-side base copy): the default probes the sharded base
    vector, which costs a cross-device reduction plus a blocking host
    sync per call.

    ``mesh_outputs`` (default True) guarantees the result arrays are
    row-sharded over the mesh whatever strategy ran — required whenever
    the caller combines them with other mesh-sharded arrays (dead-slot
    masks, promoted-row overlays).  Callers that hand the dict straight
    back (the fully-alive packed fast path) pass False so the
    replicated strategy skips a pointless [N, N] x 4 reshard.
    """
    if interpret is None:
        interpret = not _on_tpu()
    # every engine name valid elsewhere is accepted so sharding a
    # registry never breaks existing all_pairs(**kw) call sites: "tri"
    # has no per-tile meaning on the ring (off-diagonal tiles are
    # rectangles), "mxu" would need a host-synced global span probe,
    # and "i32" is the legacy-kernel hint from _compare_matrix — all
    # resolve to the packed tri/rect engines, whose flags are exact
    if engine not in (None, "full", "tri", "mxu", "i32"):
        raise ValueError(f"unknown packed engine: {engine}")
    N, m = cells.shape
    d = mesh.shape[axis]
    if N % d:
        raise ValueError(f"slab rows {N} not divisible by {d} shards")
    # keep the caller's array object when already normalized — the
    # replicated branch memoizes the cross-device copy by identity
    if not (isinstance(base, jax.Array) and base.dtype == jnp.int32
            and base.ndim == 1):
        base = jnp.asarray(base, jnp.int32).reshape(-1)
    if uniform_base is None:
        b = base
        uniform_base = bool((b == b[0]).all())
    with_base = not uniform_base
    if strategy is None:
        cfg = (autotune.lookup("matrix_sharded", N, N, m, interpret,
                               shards=d) or {}) if use_autotune else {}
        strategy = cfg.get("strategy", "ring")
    if strategy == "replicated":
        dev = mesh.devices.flat[0]
        cells_g = _gathered_replica(cells, dev)
        base_g = _gathered_replica(base, dev)
        out = _compare_matrix_packed(
            cells_g, base_g, bi=bi, bj=bj, bm=bm,
            uniform_base=uniform_base, interpret=interpret,
            use_autotune=use_autotune)
        inner = dict(LAST_DISPATCH)
        if mesh_outputs:
            # hand back the ring's placement contract: [N, N] matrices
            # row-sharded over the mesh, [N] sums sharded — downstream
            # masking/overlay code must not see single-device commitments
            out = {k: jax.device_put(v, NamedSharding(
                       mesh, P(axis, None) if v.ndim == 2 else P(axis)))
                   for k, v in out.items()}
        _note_dispatch("matrix",
                       f"replicated_{inner.get('engine', 'tri')}",
                       bi=inner.get("bi"), bj=inner.get("bj"),
                       bm=inner.get("bm"), shards=d, strategy="replicated")
        return out
    if strategy != "ring":
        raise ValueError(f"unknown sharded strategy: {strategy}")
    bi, bj, bm = _matrix_blocks("full", N, N, m, bi, bj, bm,
                                interpret, use_autotune, shards=d)
    _note_dispatch("matrix", "ring_full", bi=bi, bj=bj, bm=bm, shards=d,
                   strategy="ring")
    fn = _sharded_ring_fn(mesh, axis, N, bi, bj, bm, m, with_base, interpret)
    le, ge = fn(cells, base)
    row_sums = _packed_row_sums(cells, base, m)
    return _matrix_dict(le.astype(bool), ge.astype(bool),
                        row_sums, row_sums, m)


# gather memo for the "replicated" sharded strategy: registries call
# all_pairs repeatedly on the SAME slab array, so the cross-device copy
# is paid once per slab, not per call.  Keyed on object identity and
# guarded by a strong reference to the keyed array itself — an id can't
# be reused while the cache still holds the object it identifies.
_REPLICA_CACHE: dict = {}


def _gathered_replica(cells, dev):
    key = (id(cells), dev)
    hit = _REPLICA_CACHE.get(key)
    if hit is not None and hit[0] is cells:
        return hit[1]
    if len(_REPLICA_CACHE) >= 8:
        _REPLICA_CACHE.clear()
    gathered = jax.device_put(cells, dev)
    _REPLICA_CACHE[key] = (cells, gathered)
    return gathered


def _tri_flags(cells, b, bi, bm, m: int, with_base: bool, interpret: bool):
    """Triangle-sweep flags for one symmetric block, mirrored locally
    (``le(i, j) == ge(j, i)``) and cropped — the per-device diagonal
    step of the ring, at half the pairwise work of a full rectangle."""
    n = cells.shape[0]
    cells_p, bi_eff, bm_eff = tile2d(cells, bi, bm)
    le, ge = bloom_matrix_tri_pallas(
        cells_p, _pad_base(b, cells_p.shape[0]), bi=bi_eff, bm=bm_eff,
        m_true=m, with_base=with_base, interpret=interpret)
    k = le.shape[0] // bi_eff
    blk = jnp.arange(k).repeat(bi_eff)
    upper = blk[:, None] <= blk[None, :]
    return (jnp.where(upper, le, ge.T)[:n, :n],
            jnp.where(upper, ge, le.T)[:n, :n])


@functools.lru_cache(maxsize=64)
def _sharded_ring_fn(mesh, axis: str, N: int, bi: int, bj: int, bm: int,
                     m: int, with_base: bool, interpret: bool):
    """Jitted shard_map'd block-row ring, cached per (mesh, axis, shape,
    blocks) so the unrolled ppermute body traces once, not on every
    all_pairs call.

    Halved sweep: the matrix is symmetric under transposition-with-swap
    (``le(j, i) == ge(i, j)^T``), so only visiting offsets
    ``s = 0 .. d//2`` run the kernel.  For ``1 <= s <= (d-1)//2`` the
    device that computed block ``(i, i+s)`` ships both flag blocks
    transposed ``s`` hops forward, where they land exactly on the owner
    of the mirror block ``(i+s, i)``.

    Three PR 7 refinements on top:

    - **Double buffering**: the column-shard ppermute feeding step
      ``s + 1`` is issued as soon as step ``s``'s shard arrives, BEFORE
      step ``s``'s kernel runs, so its only data dependence is the
      previous permute.  XLA's async collective-permute then overlaps
      the transfer with the compute under it.
    - **Triangle diagonal**: step 0 compares the resident shard with
      itself — a symmetric block — so it runs the tri engine over the
      block-upper half and mirrors locally, not a full rectangle.
    - **Half-way dedup** (even d): offset ``s = d/2`` pairs each device
      with its antipode, and BOTH used to compute the same mirrored
      work.  Now only devices ``i < d/2`` run the kernel; a partial
      ppermute ships the transposed flags to the antipode, and each
      side fills its block-column slot from whichever of
      (computed, received) is real on that device.

    Per-device kernel work is thus ``tri(N/d) + (d-1)/2 x rect(N/d)``
    — exactly ``tri(N) / d``: the sharded sweep does NO redundant
    compute at any shard count, it only adds the ring transfers.  The
    base vector is only circulated when bases are non-uniform (the
    kernels ignore it otherwise).
    """
    d = mesh.shape[axis]
    steps = d // 2 + 1

    def ring(cu8, b):
        nd = cu8.shape[0]
        my = jax.lax.axis_index(axis)
        le_acc = jnp.zeros((nd, N), jnp.int8)
        ge_acc = jnp.zeros((nd, N), jnp.int8)
        shift = [(i, (i - 1) % d) for i in range(d)]

        def permute(cols, cb):
            return (jax.lax.ppermute(cols, axis, shift),
                    jax.lax.ppermute(cb, axis, shift) if with_base else cb)

        cols, cb = cu8, b
        nxt = permute(cols, cb) if steps > 1 else None
        for s in range(steps):
            if s:
                cols, cb = nxt
                # issue the NEXT shard's permute before this step's
                # compute: the transfer overlaps the kernel below
                nxt = permute(cols, cb) if s + 1 < steps else None
            src = (my + s) % d          # column block visiting this step
            if s == 0:
                le, ge = _tri_flags(cu8, b, max(bi, bj), bm,
                                    m, with_base, interpret)
            elif d % 2 == 0 and s == d // 2:
                # half-way offset: my and my+d/2 hold each other's
                # mirror, so only the lower half computes; collectives
                # stay OUTSIDE the cond — every device executes them
                compute = my < d // 2
                zeros = (jnp.zeros((nd, nd), jnp.int8),) * 2
                le_c, ge_c = jax.lax.cond(
                    compute,
                    lambda: _full_rect_flags(cu8, b, cols, cb, bi, bj,
                                             bm, m, with_base, interpret),
                    lambda: zeros)
                half = [(i, i + d // 2) for i in range(d // 2)]
                le_r = jax.lax.ppermute(ge_c.T, axis, half)
                ge_r = jax.lax.ppermute(le_c.T, axis, half)
                le = jnp.where(compute, le_c, le_r)
                ge = jnp.where(compute, ge_c, ge_r)
            else:
                le, ge = _full_rect_flags(cu8, b, cols, cb, bi, bj, bm,
                                          m, with_base, interpret)
            le_acc = jax.lax.dynamic_update_slice(
                le_acc, le, (0, src * nd))
            ge_acc = jax.lax.dynamic_update_slice(
                ge_acc, ge, (0, src * nd))
            if 1 <= s <= (d - 1) // 2:
                # mirror block (my+s, my): ship the transposed flags s
                # hops forward; what arrives here came from my-s and is
                # this device's block (my, my-s)
                fwd = [(i, (i + s) % d) for i in range(d)]
                le_m = jax.lax.ppermute(ge.T, axis, fwd)
                ge_m = jax.lax.ppermute(le.T, axis, fwd)
                mirror = (my - s) % d
                le_acc = jax.lax.dynamic_update_slice(
                    le_acc, le_m, (0, mirror * nd))
                ge_acc = jax.lax.dynamic_update_slice(
                    ge_acc, ge_m, (0, mirror * nd))
        return le_acc, ge_acc

    return jax.jit(shard_map(
        ring, mesh=mesh,
        in_specs=(P(axis, None), P(axis)),
        out_specs=(P(axis, None),) * 2,
        check_rep=False,     # no replication rule for pallas_call
    ))


def _logical_bounds(cells, base, cols, col_base):
    """Eager (host-synced) global [lo, hi] logical value bounds."""
    b = jnp.asarray(base, jnp.int32).reshape(-1)
    cb = jnp.asarray(col_base, jnp.int32).reshape(-1)
    lo = int(jnp.minimum(b.min(), cb.min()))
    hi = int(jnp.maximum(
        (cells.astype(jnp.int32).max(axis=1) + b).max(),
        (cols.astype(jnp.int32).max(axis=1) + cb).max()))
    return lo, hi - lo


def _mxu_viable(cells, base, cols, col_base) -> bool:
    try:
        _, span = _logical_bounds(cells, base, cols, col_base)
    except Exception:
        return False
    return span <= MXU_SPAN_MAX


@functools.partial(jax.jit, static_argnames=("N", "M", "m_true", "lo"))
def _mxu_finalize(viol, cells, base, cols, col_base,
                  row_sums, col_sums, N, M, m_true, lo):
    # shifted sums stay < 2^24 so the f32 zero-tests below are exact;
    # the window shift cancels in the rank-1 identity
    sa = _packed_row_sums(cells, jnp.asarray(base).reshape(-1) - lo, m_true)
    sb = _packed_row_sums(cols, jnp.asarray(col_base).reshape(-1) - lo, m_true)
    v = viol[:N, :M]
    le = v == 0.0                                     # no violations a -> b
    ge = (v - sa[:, None] + sb[None, :]) == 0.0       # viol_ge via rank-1
    return _matrix_dict(le, ge, row_sums, col_sums, m_true)


def _compare_matrix(
    rows: jax.Array,         # [N, m] int32 logical cells
    cols: jax.Array,         # [M, m] int32 logical cells
    *,
    engine: str | None = None,   # None = auto; "i32" forces legacy kernel
    bi: int | None = None,
    bj: int | None = None,
    bm: int | None = None,
    interpret: bool | None = None,
    use_autotune: bool = True,
):
    """Tiled all-pairs compare: drop-in for the broadcast reference
    ``repro.core.clock.comparability_matrix`` without the O(n^2 * m)
    materialization.

    Auto engine: when the global value span fits a byte the slab is
    packed on the fly (shared window base -> uniform-base fast path) and
    compared by the packed engines — the symmetric triangle sweep when
    ``rows is cols``.  Wider spans fall back to the int32 kernel.

    Returns dict with [N, M] ``a_le_b`` / ``b_le_a`` / ``concurrent``
    flag matrices, the Eq. 3 ``fp`` of "row before col", and the
    per-row / per-col sums.
    """
    if interpret is None:
        interpret = not _on_tpu()
    symmetric = rows is cols
    N, m = rows.shape
    M, mc = cols.shape
    assert m == mc, (rows.shape, cols.shape)

    if engine is None and isinstance(rows, jax.core.Tracer):
        engine = "i32"      # under an outer jit the span probe can't sync
    if engine is None and use_autotune:
        # honor a measured "int32 wins here" verdict before paying the probe
        cfg = autotune.lookup("matrix", N, M, m, interpret) or {}
        if cfg.get("engine") == "i32":
            engine = "i32"
    if engine != "i32":
        lo, hi = (int(v) for v in jax.device_get(
            _span_probe(rows, None if symmetric else cols)))
        if hi - lo <= U8_MAX:
            packed_rows = _shift_pack(rows, lo)
            base = jnp.full((N,), lo, jnp.int32)
            if symmetric:
                return _compare_matrix_packed(
                    packed_rows, base, engine=engine, bi=bi, bj=bj, bm=bm,
                    uniform_base=True, interpret=interpret,
                    use_autotune=use_autotune)
            return _compare_matrix_packed(
                packed_rows, base, _shift_pack(cols, lo),
                jnp.full((M,), lo, jnp.int32), engine=engine,
                bi=bi, bj=bj, bm=bm, uniform_base=True, interpret=interpret,
                use_autotune=use_autotune)
        if engine is not None:
            raise ValueError(
                f"engine={engine} needs value span <= {U8_MAX}, got {hi - lo}")

    bi, bj, bm = _matrix_blocks("i32", N, M, m, bi, bj, bm, interpret,
                                use_autotune)
    _note_dispatch("matrix", "i32", bi=bi, bj=bj, bm=bm)
    col_sums = jnp.sum(cols, axis=1).astype(jnp.float32)           # [M]
    rows_p, bi_eff, bm_eff = tile2d(rows, bi, bm)
    cols_p, bj_eff, _ = tile2d(cols, bj, bm_eff)
    cols_p = pad_to(cols_p, rows_p.shape[1], axis=1)
    col_sums_p = pad_to(col_sums[None, :], cols_p.shape[0], axis=1)
    le, ge, row_sums, fp = bloom_matrix_pallas(
        rows_p, cols_p, col_sums_p,
        bi=bi_eff, bj=bj_eff, bm=bm_eff, m_true=m, interpret=interpret,
    )
    le = le[:N, :M].astype(bool)
    ge = ge[:N, :M].astype(bool)
    return {
        "a_le_b": le,
        "b_le_a": ge,
        "concurrent": jnp.logical_not(jnp.logical_or(le, ge)),
        "fp": fp[:N, :M],
        "row_sums": row_sums[:N, 0],
        "col_sums": col_sums,
    }


@functools.partial(jax.jit, static_argnames=("lo",))
def _shift_pack(x, lo: int):
    return (jnp.asarray(x, jnp.int32) - lo).astype(jnp.uint8)


@jax.jit
def _span_probe(rows, cols=None):
    """[lo, hi] over one or two slabs, fetched in ONE host transfer."""
    lo, hi = jnp.min(rows), jnp.max(rows)
    if cols is not None:
        lo = jnp.minimum(lo, jnp.min(cols))
        hi = jnp.maximum(hi, jnp.max(cols))
    return jnp.stack([lo, hi])


# ---------------------------------------------------------------------------
# deprecated pre-front-door entry points
# ---------------------------------------------------------------------------

def _shim(name: str, impl):
    """Thin ``DeprecationWarning`` shim: delegates to the SAME
    implementation the ``repro.causal.CausalEngine`` front-door calls,
    so shim results are bit-identical to the new API by construction.
    The warning is attributed to the CALLER's module (stacklevel=2) so
    CI can gate ``error::DeprecationWarning`` on ``repro.*`` modules,
    proving no internal caller still uses these."""
    def wrapper(*args, **kwargs):
        warnings.warn(
            f"repro.kernels.ops.{name} is deprecated; use the "
            "repro.causal.CausalEngine front-door "
            "(engine.classify / engine.pairs) instead",
            DeprecationWarning, stacklevel=2)
        return impl(*args, **kwargs)
    wrapper.__name__ = wrapper.__qualname__ = name
    wrapper.__doc__ = ("DEPRECATED — use ``repro.causal.CausalEngine``.\n\n"
                       + (getattr(impl, "__doc__", None) or ""))
    return wrapper


compare_matrix = _shim("compare_matrix", _compare_matrix)
compare_matrix_packed = _shim("compare_matrix_packed", _compare_matrix_packed)
compare_matrix_packed_sharded = _shim(
    "compare_matrix_packed_sharded", _compare_matrix_packed_sharded)
classify_vs_many = _shim("classify_vs_many", _classify_vs_many)
classify_vs_many_packed = _shim(
    "classify_vs_many_packed", _classify_vs_many_packed)
classify_vs_many_packed_sharded = _shim(
    "classify_vs_many_packed_sharded", _classify_vs_many_packed_sharded)
overlay_wide_classify = _shim("overlay_wide_classify", _overlay_wide_classify)
