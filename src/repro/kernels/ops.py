"""Public jit'd wrappers around the bloom-clock Pallas kernels.

Handles: probe-index precomputation (hashing), padding m to the lane
boundary and B to the batch tile, platform dispatch (interpret=True off-TPU
so the SAME kernel body is exercised on CPU), and un-padding.

The rest of the framework calls these; ``repro.core.clock`` stays the
algorithmic reference.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.hashing import bloom_indices
from repro.kernels.bloom_compare import bloom_merge_compare_pallas
from repro.kernels.bloom_tick import bloom_tick_pallas

__all__ = ["tick", "merge_compare", "pad_to", "pick_block"]

LANE = 128  # TPU lane width


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pad_to(x: jax.Array, mult: int, axis: int, value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def pick_block(padded: int, want: int, lane: int = LANE) -> int:
    """Largest lane-multiple block <= want that divides ``padded``."""
    q = padded // lane
    best = 1
    for d in range(1, q + 1):
        if q % d == 0 and d * lane <= max(want, lane):
            best = d
    return best * lane


@functools.partial(jax.jit, static_argnames=("k", "bb", "bm", "interpret"))
def tick(
    cells: jax.Array,        # [B, m] int32
    ev_hi: jax.Array,        # [B, E] uint32
    ev_lo: jax.Array,        # [B, E] uint32
    *,
    k: int = 4,
    bb: int = 8,
    bm: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched bloom tick: E events per clock, k probes each."""
    if interpret is None:
        interpret = not _on_tpu()
    B, m = cells.shape
    idx = bloom_indices(ev_hi, ev_lo, k, m)          # [B, E, k] uint32
    probes = idx.reshape(B, -1).astype(jnp.int32)    # [B, P], all < m
    cells_p = pad_to(cells, LANE, axis=1)            # padded cols never hit
    mp = cells_p.shape[1]
    bm_eff = pick_block(mp, bm)
    bb_eff = min(bb, B) if B % min(bb, B) == 0 else math.gcd(B, bb)
    cells_p = pad_to(cells_p, bb_eff, axis=0)
    probes_p = pad_to(probes, bb_eff, axis=0)        # pad rows: probe 0 hits
    out = bloom_tick_pallas(cells_p, probes_p, bb=bb_eff, bm=bm_eff, interpret=interpret)
    return out[:B, :m]                               # padded-row incs sliced off


@functools.partial(jax.jit, static_argnames=("bb", "bm", "interpret"))
def merge_compare(
    a: jax.Array,            # [B, m] int32 logical cells
    b: jax.Array,
    *,
    bb: int = 8,
    bm: int = 512,
    interpret: bool | None = None,
):
    """Fused receive-path op. Returns dict with merged cells, dominance
    flags, sums and Eq.3 fp rates (see bloom_compare.py)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, m = a.shape
    a_p = pad_to(a, LANE, axis=1)
    b_p = pad_to(b, LANE, axis=1)
    mp = a_p.shape[1]
    bm_eff = pick_block(mp, bm)
    bb_eff = min(bb, B) if B % min(bb, B) == 0 else math.gcd(B, bb)
    a_p = pad_to(a_p, bb_eff, axis=0)
    b_p = pad_to(b_p, bb_eff, axis=0)
    # zero padding perturbs neither dominance (0<=0) nor sums; Eq. 3 must
    # use the TRUE m, passed statically to the kernel.
    merged, flags, sums, fp = bloom_merge_compare_pallas(
        a_p, b_p, bb=bb_eff, bm=bm_eff, m_true=m, interpret=interpret
    )
    return {
        "merged": merged[:B, :m],
        "a_le_b": flags[:B, 0].astype(bool),
        "b_le_a": flags[:B, 1].astype(bool),
        "sum_a": sums[:B, 0],
        "sum_b": sums[:B, 1],
        "fp_a_before_b": fp[:B, 0],
        "fp_b_before_a": fp[:B, 1],
    }
