"""Public jit'd wrappers around the bloom-clock Pallas kernels.

Handles: probe-index precomputation (hashing), padding m to the lane
boundary and B to the batch tile, platform dispatch (interpret=True off-TPU
so the SAME kernel body is exercised on CPU), and un-padding.

The rest of the framework calls these; ``repro.core.clock`` stays the
algorithmic reference.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.core.hashing import bloom_indices
from repro.kernels.bloom_compare import bloom_merge_compare_pallas
from repro.kernels.bloom_matrix import (
    bloom_matrix_pallas,
    bloom_one_vs_many_pallas,
)
from repro.kernels.bloom_tick import bloom_tick_pallas

__all__ = [
    "tick",
    "merge_compare",
    "classify_vs_many",
    "compare_matrix",
    "pad_to",
    "pick_block",
]

LANE = 128  # TPU lane width


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def pad_to(x: jax.Array, mult: int, axis: int, value=0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def pick_block(padded: int, want: int, lane: int = LANE) -> int:
    """Largest lane-multiple block <= want that divides ``padded``."""
    q = padded // lane
    best = 1
    for d in range(1, q + 1):
        if q % d == 0 and d * lane <= max(want, lane):
            best = d
    return best * lane


@functools.partial(jax.jit, static_argnames=("k", "bb", "bm", "interpret"))
def tick(
    cells: jax.Array,        # [B, m] int32
    ev_hi: jax.Array,        # [B, E] uint32
    ev_lo: jax.Array,        # [B, E] uint32
    *,
    k: int = 4,
    bb: int = 8,
    bm: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched bloom tick: E events per clock, k probes each."""
    if interpret is None:
        interpret = not _on_tpu()
    B, m = cells.shape
    idx = bloom_indices(ev_hi, ev_lo, k, m)          # [B, E, k] uint32
    probes = idx.reshape(B, -1).astype(jnp.int32)    # [B, P], all < m
    cells_p = pad_to(cells, LANE, axis=1)            # padded cols never hit
    mp = cells_p.shape[1]
    bm_eff = pick_block(mp, bm)
    bb_eff = min(bb, B) if B % min(bb, B) == 0 else math.gcd(B, bb)
    cells_p = pad_to(cells_p, bb_eff, axis=0)
    probes_p = pad_to(probes, bb_eff, axis=0)        # pad rows: probe 0 hits
    out = bloom_tick_pallas(cells_p, probes_p, bb=bb_eff, bm=bm_eff, interpret=interpret)
    return out[:B, :m]                               # padded-row incs sliced off


@functools.partial(jax.jit, static_argnames=("bb", "bm", "interpret"))
def merge_compare(
    a: jax.Array,            # [B, m] int32 logical cells
    b: jax.Array,
    *,
    bb: int = 8,
    bm: int = 512,
    interpret: bool | None = None,
):
    """Fused receive-path op. Returns dict with merged cells, dominance
    flags, sums and Eq.3 fp rates (see bloom_compare.py)."""
    if interpret is None:
        interpret = not _on_tpu()
    B, m = a.shape
    a_p = pad_to(a, LANE, axis=1)
    b_p = pad_to(b, LANE, axis=1)
    mp = a_p.shape[1]
    bm_eff = pick_block(mp, bm)
    bb_eff = min(bb, B) if B % min(bb, B) == 0 else math.gcd(B, bb)
    a_p = pad_to(a_p, bb_eff, axis=0)
    b_p = pad_to(b_p, bb_eff, axis=0)
    # zero padding perturbs neither dominance (0<=0) nor sums; Eq. 3 must
    # use the TRUE m, passed statically to the kernel.
    merged, flags, sums, fp = bloom_merge_compare_pallas(
        a_p, b_p, bb=bb_eff, bm=bm_eff, m_true=m, interpret=interpret
    )
    return {
        "merged": merged[:B, :m],
        "a_le_b": flags[:B, 0].astype(bool),
        "b_le_a": flags[:B, 1].astype(bool),
        "sum_a": sums[:B, 0],
        "sum_b": sums[:B, 1],
        "fp_a_before_b": fp[:B, 0],
        "fp_b_before_a": fp[:B, 1],
    }


@functools.partial(jax.jit, static_argnames=("bn", "bm", "interpret"))
def classify_vs_many(
    q: jax.Array,            # [m] int32 local (query) logical cells
    peers: jax.Array,        # [N, m] int32 peer slab logical cells
    *,
    bn: int = 8,
    bm: int = 512,
    interpret: bool | None = None,
):
    """One-vs-many fused classify: the local clock against a whole peer
    slab in a single device call.

    Returns dict with per-peer ``q_le_p`` / ``p_le_q`` dominance flags,
    total sums and Eq. 3 fp rates both directions (fp of "q before p"
    and "p before q").  Zero padding perturbs neither dominance nor
    sums; Eq. 3 uses the TRUE m, passed statically to the kernel.
    """
    if interpret is None:
        interpret = not _on_tpu()
    (m,) = q.shape
    N, mp_ = peers.shape
    assert m == mp_, (q.shape, peers.shape)
    q_p = pad_to(q[None, :], LANE, axis=1)
    peers_p = pad_to(peers, LANE, axis=1)
    mp = peers_p.shape[1]
    bm_eff = pick_block(mp, bm)
    bn_eff = min(bn, N) if N % min(bn, N) == 0 else math.gcd(N, bn)
    peers_p = pad_to(peers_p, bn_eff, axis=0)
    flags, sums, fp = bloom_one_vs_many_pallas(
        q_p, peers_p, bn=bn_eff, bm=bm_eff, m_true=m, interpret=interpret
    )
    return {
        "q_le_p": flags[:N, 0].astype(bool),
        "p_le_q": flags[:N, 1].astype(bool),
        "sum_q": sums[0, 0],
        "sum_p": sums[:N, 1],
        "fp_q_before_p": fp[:N, 0],
        "fp_p_before_q": fp[:N, 1],
    }


@functools.partial(jax.jit, static_argnames=("bi", "bj", "bm", "interpret"))
def compare_matrix(
    rows: jax.Array,         # [N, m] int32 logical cells
    cols: jax.Array,         # [M, m] int32 logical cells
    *,
    bi: int | None = None,
    bj: int = 128,
    bm: int = 512,
    interpret: bool | None = None,
):
    """Tiled all-pairs compare: drop-in for the broadcast reference
    ``repro.core.clock.comparability_matrix`` without the O(n^2 * m)
    materialization.

    Returns dict with [N, M] ``a_le_b`` / ``b_le_a`` / ``concurrent``
    flag matrices, the Eq. 3 ``fp`` of "row before col", and the per-row
    / per-col sums.  Column sums are precomputed here (an O(M * m) pass)
    and fed to the kernel — see bloom_matrix.py for why they cannot
    ADD-accumulate in-kernel.
    """
    if interpret is None:
        interpret = not _on_tpu()
    if bi is None:
        # interpret mode amortizes per-grid-step overhead with tall row
        # tiles; on real TPU the [bi, bj, bm] compare intermediate must
        # stay well inside VMEM, so keep row tiles short
        bi = 128 if interpret else 8
    N, m = rows.shape
    M, mc = cols.shape
    assert m == mc, (rows.shape, cols.shape)
    col_sums = jnp.sum(cols, axis=1).astype(jnp.float32)           # [M]
    rows_p = pad_to(rows, LANE, axis=1)
    cols_p = pad_to(cols, LANE, axis=1)
    mp = rows_p.shape[1]
    bm_eff = pick_block(mp, bm)
    # row/col tile sizes: sublane multiples that divide the padded counts
    rows_p = pad_to(rows_p, 8, axis=0)
    cols_p = pad_to(cols_p, 8, axis=0)
    bi_eff = pick_block(rows_p.shape[0], bi, lane=8)
    bj_eff = pick_block(cols_p.shape[0], bj, lane=8)
    col_sums_p = pad_to(col_sums[None, :], cols_p.shape[0], axis=1)
    le, ge, row_sums, fp = bloom_matrix_pallas(
        rows_p, cols_p, col_sums_p,
        bi=bi_eff, bj=bj_eff, bm=bm_eff, m_true=m, interpret=interpret,
    )
    le = le[:N, :M].astype(bool)
    ge = ge[:N, :M].astype(bool)
    return {
        "a_le_b": le,
        "b_le_a": ge,
        "concurrent": jnp.logical_not(jnp.logical_or(le, ge)),
        "fp": fp[:N, :M],
        "row_sums": row_sums[:N, 0],
        "col_sums": col_sums,
    }
