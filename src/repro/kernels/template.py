"""Parameterized compare-kernel template: ONE design, every engine.

The hand-rolled Pallas engines in the old ``bloom_matrix.py`` (symmetric
triangle, full rectangle, MXU thermometer, one-vs-many — each in packed
u8 and/or int32 flavors) had converged on one shape: stream m-tiles of
one or two operand slabs through VMEM, reduce a per-tile dominance
predicate into revisited output blocks, and finalize Eq. 3 on the last
m-tile.  This module is that design written once, parameterized by a
``CompareSpec``:

    topology        "tri" (block-upper-triangle sweep over one slab),
                    "rect" (full rectangle, rows x cols),
                    "mxu" (thermometer dot_general violation counts),
                    "one_vs_many" (one query row vs a peer slab),
                    "hybrid" (one query vs exact hot rows + packed tail
                    in ONE grid: leading row-tiles answer from exact
                    (v, n_private) chain coordinates with fp pinned to
                    0.0, trailing tiles run the unmodified packed
                    one-vs-many math so tail verdicts stay bit-identical
                    to the flat slab)
    pack            "u8" (quantized residuals + per-row int32 base) or
                    "i32" (logical cells)
    bi / bj / bm    block shapes (bi doubles as bn for one_vs_many)
    pipeline_depth  pallas pipeline staging: >= 2 marks the revisit-free
                    grid axes "parallel" so Mosaic double-buffers
                    operand tiles; 1 pins every axis "arbitrary"
    acc             flag accumulator dtype ("int8" / "int32"; None =
                    the topology's pinned default)
    with_base       fold per-row window bases into the tile difference
    with_stats      emit sums + Eq. 3 fp outputs alongside flags
    n_thresholds    MXU value-span budget T (thermometer width)

``emit(spec)`` validates the spec and returns a jitted wrapper whose
outputs are BIT-IDENTICAL to the hand-rolled kernel the spec names
(pinned by tests/test_template.py against verbatim copies of the
pre-refactor kernels).  ``kernels.generate`` builds the named engine
instances the rest of the system imports; nothing outside this pair
defines a kernel body anymore.

The generator refuses, at emission/call time, any knob combination
whose per-grid-step VMEM estimate (``vmem_estimate``) exceeds the
backend budget — the same analytic model the cost-model autotuner uses
to prune its search space (``kernels.autotune.predict_cost``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "CompareSpec",
    "emit",
    "validate",
    "vmem_estimate",
    "VMEM_BUDGET",
    "TOPOLOGIES",
    "PACKS",
]

TOPOLOGIES = ("tri", "rect", "mxu", "one_vs_many", "hybrid")
PACKS = ("u8", "i32")
_ACCS = ("int8", "int32")

# Per-grid-step VMEM budget (bytes).  Interpret mode has no VMEM, but
# the same model bounds host scratch so emitted specs stay sane.
VMEM_BUDGET = {"tpu": 12 * 2**20, "interpret": 512 * 2**20}

_EQ3_CLIP = 1e-30


@dataclasses.dataclass(frozen=True)
class CompareSpec:
    """One point in the compare-kernel design space (see module doc)."""

    topology: str
    pack: str = "u8"
    bi: int = 128
    bj: int = 128
    bm: int = 512
    pipeline_depth: int = 2
    acc: Optional[str] = None
    with_base: bool = False
    with_stats: bool = False
    n_thresholds: int = 0

    @property
    def acc_dtype(self):
        if self.topology == "mxu":
            return jnp.float32
        if self.acc is not None:
            return {"int8": jnp.int8, "int32": jnp.int32}[self.acc]
        # pinned defaults: what the hand-rolled kernels accumulated in
        if self.topology in ("one_vs_many", "hybrid") or self.pack == "i32":
            return jnp.int32
        return jnp.int8

    def label(self) -> str:
        parts = [self.topology, self.pack,
                 f"bi{self.bi}", f"bj{self.bj}", f"bm{self.bm}",
                 f"pd{self.pipeline_depth}"]
        if self.with_base:
            parts.append("base")
        if self.n_thresholds:
            parts.append(f"T{self.n_thresholds}")
        return "/".join(parts)


def validate(spec: CompareSpec, backend: str | None = None) -> None:
    """Refuse malformed or over-budget specs (raises ValueError)."""
    if spec.topology not in TOPOLOGIES:
        raise ValueError(f"unknown topology {spec.topology!r}")
    if spec.pack not in PACKS:
        raise ValueError(f"unknown pack mode {spec.pack!r}")
    if spec.acc is not None and spec.acc not in _ACCS:
        raise ValueError(f"unknown accumulator {spec.acc!r}")
    if spec.bi % 8 or spec.bj % 8:
        raise ValueError(f"row blocks must be sublane multiples: "
                         f"bi={spec.bi} bj={spec.bj}")
    if spec.bm % 128:
        raise ValueError(f"bm must be a lane multiple: bm={spec.bm}")
    if spec.pipeline_depth not in (1, 2, 3):
        raise ValueError(f"pipeline_depth must be 1..3, "
                         f"got {spec.pipeline_depth}")
    if spec.topology == "tri" and spec.pack != "u8":
        raise ValueError("tri topology is packed-only (pack='u8')")
    if spec.topology == "mxu":
        if spec.pack != "u8":
            raise ValueError("mxu topology is packed-only (pack='u8')")
        if spec.n_thresholds < 1:
            raise ValueError("mxu needs n_thresholds >= 1")
        if spec.with_stats:
            raise ValueError("mxu emits violation counts, not stats")
    elif spec.n_thresholds:
        raise ValueError("n_thresholds is an mxu-only knob")
    if spec.topology == "one_vs_many" and not spec.with_stats:
        raise ValueError("one_vs_many always emits stats (flags+sums+fp)")
    if spec.topology == "hybrid":
        if spec.pack != "u8":
            raise ValueError("hybrid's tail slab is packed-only "
                             "(pack='u8'); hot rows carry no cells at all")
        if not (spec.with_stats and spec.with_base):
            raise ValueError("hybrid always emits stats and folds tail "
                             "bases (with_stats=True, with_base=True)")
    if spec.topology == "rect" and spec.pack == "i32" and not spec.with_stats:
        raise ValueError("rect/i32 is the stats engine (with_stats=True)")
    if spec.with_stats and spec.topology in ("tri", "rect") \
            and spec.pack == "u8":
        raise ValueError("packed tri/rect emit flags only; sums/fp are "
                         "finalized outside the kernel")
    if backend is not None:
        need = vmem_estimate(spec)
        budget = VMEM_BUDGET[backend]
        if need > budget:
            raise ValueError(
                f"VMEM estimate {need} B exceeds the {backend} budget "
                f"{budget} B for {spec.label()}")


def vmem_estimate(spec: CompareSpec) -> int:
    """Peak per-grid-step working set (bytes) of one emitted instance.

    Operand tiles are multiplied by the pipeline depth (Mosaic keeps
    ``depth`` tiles in flight when axes are parallel); intermediates and
    output blocks are single-buffered.
    """
    bi, bj, bm, d = spec.bi, spec.bj, spec.bm, spec.pipeline_depth
    if spec.topology == "one_vs_many":
        esize = 1 if spec.pack == "u8" else 4
        operands = (bm * 4 + bi * bm * esize + bi * 4) * d
        return operands + bi * bm * 4 + 3 * bi * 2 * 4
    if spec.topology == "hybrid":
        # one_vs_many packed operands + the exact-row metadata tiles
        # (meta [bn, 2] i32, hot sums [bn, 1] f32, V scalar)
        operands = (bm * 4 + bi * bm + bi * 4 + bi * 2 * 4 + bi * 4 + 4) * d
        return operands + bi * bm * 4 + 3 * bi * 2 * 4
    if spec.topology == "mxu":
        enc = (bi + bj) * bm * spec.n_thresholds * 4   # f32 thermometer
        return enc + (bi + bj) * bm * d + bi * bj * 4
    if spec.pack == "u8":                              # tri / rect packed
        diff = bi * bj * bm * 2                        # int16 difference
        acc = jnp.dtype(spec.acc_dtype).itemsize
        return diff + (bi + bj) * bm * d + 2 * bi * bj * acc
    # rect / i32 stats engine: two bool compare intermediates
    diff = bi * bj * bm
    return 2 * diff + (bi + bj) * bm * 4 * d + 3 * bi * bj * 4


def _backend(interpret: bool) -> str:
    return "interpret" if interpret else "tpu"


def _compiler_params(spec: CompareSpec, n_axes: int, interpret: bool):
    """dimension_semantics from the pipeline-depth knob (TPU only).

    Revisit-free axes go "parallel" at depth >= 2 so Mosaic pipelines
    operand fetches; the m-tile axis (and the tri sweep axis, whose
    index map is scalar-prefetch driven) stays "arbitrary".
    """
    if interpret:
        return {}
    if spec.pipeline_depth < 2 or spec.topology == "tri":
        sem = ("arbitrary",) * n_axes
    else:
        sem = ("parallel",) * (n_axes - 1) + ("arbitrary",)
    try:
        return {"compiler_params": pltpu.TPUCompilerParams(
            dimension_semantics=sem)}
    except Exception:                                  # older pallas API
        return {}


# ---------------------------------------------------------------------------
# shared body pieces
# ---------------------------------------------------------------------------

def _eq3_pair_finalize(s, m):
    """Stable Eq. 3 both-direction fp from total sums — the exact
    expression every stats engine finalizes with."""
    log_q = jnp.log1p(-1.0 / m)
    inner_p = jnp.clip(-jnp.expm1(s[:, 1:2] * log_q), _EQ3_CLIP, 1.0)
    inner_q = jnp.clip(-jnp.expm1(s[:, 0:1] * log_q), _EQ3_CLIP, 1.0)
    fp_qp = jnp.exp(s[:, 0:1] * jnp.log(inner_p))
    fp_pq = jnp.exp(s[:, 1:2] * jnp.log(inner_q))
    return jnp.concatenate([fp_qp, fp_pq], axis=1)


def _pair_flags_u8(a_ref, b_ref, abase_ref, bbase_ref, acc,
                   *, with_base, m_true, bm, jm):
    """[bi, bj] (le, ge) for one packed tile pair from ONE int16
    difference.  ``d`` spans ±U8_MAX before the base delta; the delta is
    clipped to ±(U8_MAX + 1), which preserves verdicts exactly (any
    |delta| beyond the residual range forces the verdict) and keeps d
    inside int16.  Already wrap-safe (bounded-counter semantics): the
    base delta is an int32 wrap-subtraction before the clip, so two
    near-wrap packed rows compare through their true signed gap."""
    a = a_ref[...]
    b = b_ref[...]
    d = a.astype(jnp.int16)[:, None, :] - b.astype(jnp.int16)[None, :, :]
    if with_base:
        delta = jnp.clip(abase_ref[...] - bbase_ref[...].T, -256, 256)
        d = d + delta[:, :, None].astype(jnp.int16)
        # zero-padded lanes are only neutral when bases cancel; mask them
        col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, bm), 2) + jm * bm
        d = jnp.where(col < m_true, d, 0)
    le = (jnp.max(d, axis=2) <= 0).astype(acc)
    ge = (jnp.min(d, axis=2) >= 0).astype(acc)
    return le, ge


def _flags_accumulate(jm, le, ge, le_ref, ge_ref):
    """AND-accumulate per-m-tile flags into the revisited output pair."""
    @pl.when(jm == 0)
    def _init():
        le_ref[...] = le
        ge_ref[...] = ge

    @pl.when(jm > 0)
    def _acc():
        le_ref[...] = le_ref[...] & le
        ge_ref[...] = ge_ref[...] & ge


def _packed_flags_step(refs, *, jm, with_base, m_true, bm, acc):
    """Shared body of the packed tri/rect flag kernels."""
    if with_base:
        a_ref, b_ref, abase_ref, bbase_ref, le_ref, ge_ref = refs
    else:
        a_ref, b_ref, le_ref, ge_ref = refs
        abase_ref = bbase_ref = None
    le, ge = _pair_flags_u8(a_ref, b_ref, abase_ref, bbase_ref, acc,
                            with_base=with_base, m_true=m_true,
                            bm=bm, jm=jm)
    _flags_accumulate(jm, le, ge, le_ref, ge_ref)


def _one_vs_many_step(j, q, p, flags_ref, sums_ref, fp_ref,
                      *, n_mtiles, m, acc):
    """Shared one-vs-many body: dominance + sums accumulate across
    m-tiles, Eq. 3 finalize on the last.  Dominance is derived from the
    int32 wrap-subtraction (bounded-counter semantics, same derivation
    as ``core.clock.ordering``): bit-identical to direct compares in the
    sane range, correct across the int32 wrap point."""
    d = p - q
    le = jnp.all(d >= 0, axis=1, keepdims=True)
    ge = jnp.all(d <= 0, axis=1, keepdims=True)
    sp = jnp.sum(p, axis=1, keepdims=True).astype(jnp.float32)
    sq = jnp.broadcast_to(
        jnp.sum(q, axis=1, keepdims=True).astype(jnp.float32), sp.shape)

    @pl.when(j == 0)
    def _init():
        flags_ref[...] = jnp.concatenate([le, ge], axis=1).astype(acc)
        sums_ref[...] = jnp.concatenate([sq, sp], axis=1)

    @pl.when(j > 0)
    def _acc():
        cur = jnp.concatenate([le, ge], axis=1).astype(acc)
        flags_ref[...] = flags_ref[...] & cur
        sums_ref[...] = sums_ref[...] + jnp.concatenate([sq, sp], axis=1)

    @pl.when(j == n_mtiles - 1)
    def _finalize():
        fp_ref[...] = _eq3_pair_finalize(sums_ref[...], m)


# ---------------------------------------------------------------------------
# per-topology emitters
# ---------------------------------------------------------------------------

def _emit_tri(spec: CompareSpec):
    bi, bm, with_base = spec.bi, spec.bm, spec.with_base
    acc = spec.acc_dtype

    def kernel(ti_ref, tj_ref, *refs, n_mtiles, m_true):
        _packed_flags_step(refs, jm=pl.program_id(1), with_base=with_base,
                           m_true=m_true, bm=bm, acc=acc)

    @functools.partial(jax.jit, static_argnames=("m_true", "interpret"))
    def tri_pallas(cells, base, *, m_true=None, interpret=False):
        """Symmetric all-pairs over one packed slab (upper triangle).

        Returns (le, ge) [N, N] valid ONLY in block-upper-triangle
        positions; the caller mirrors the rest by transposition."""
        validate(spec, _backend(interpret))
        N, m = cells.shape
        assert N % bi == 0 and m % bm == 0, (N, m, bi, bm)
        k = N // bi
        tri = [(i, j) for i in range(k) for j in range(i, k)]
        ti = jnp.asarray([i for i, _ in tri], jnp.int32)
        tj = jnp.asarray([j for _, j in tri], jnp.int32)
        n_mtiles = m // bm
        body = functools.partial(kernel, n_mtiles=n_mtiles,
                                 m_true=m_true if m_true else m)
        in_specs = [
            pl.BlockSpec((bi, bm), lambda t, jm, ti, tj: (ti[t], jm)),
            pl.BlockSpec((bi, bm), lambda t, jm, ti, tj: (tj[t], jm)),
        ]
        operands = [cells, cells]
        if with_base:
            in_specs += [
                pl.BlockSpec((bi, 1), lambda t, jm, ti, tj: (ti[t], 0)),
                pl.BlockSpec((bi, 1), lambda t, jm, ti, tj: (tj[t], 0)),
            ]
            operands += [base, base]
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(len(tri), n_mtiles),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((bi, bi), lambda t, jm, ti, tj: (ti[t], tj[t])),
                pl.BlockSpec((bi, bi), lambda t, jm, ti, tj: (ti[t], tj[t])),
            ],
        )
        le, ge = pl.pallas_call(
            body,
            grid_spec=grid_spec,
            out_shape=[
                jax.ShapeDtypeStruct((N, N), acc),
                jax.ShapeDtypeStruct((N, N), acc),
            ],
            interpret=interpret,
            **_compiler_params(spec, 2, interpret),
        )(ti, tj, *operands)
        return le, ge

    return tri_pallas


def _emit_rect_u8(spec: CompareSpec):
    bi, bj, bm, with_base = spec.bi, spec.bj, spec.bm, spec.with_base
    acc = spec.acc_dtype

    def kernel(*refs, n_mtiles, m_true):
        _packed_flags_step(refs, jm=pl.program_id(2), with_base=with_base,
                           m_true=m_true, bm=bm, acc=acc)

    @functools.partial(jax.jit, static_argnames=("m_true", "interpret"))
    def rect_pallas(rows, cols, row_base, col_base, *,
                    m_true=None, interpret=False):
        """Full-rectangle packed compare: (le, ge) [N, M]."""
        validate(spec, _backend(interpret))
        N, m = rows.shape
        M, mc = cols.shape
        assert m == mc and N % bi == 0 and M % bj == 0 and m % bm == 0
        n_mtiles = m // bm
        body = functools.partial(kernel, n_mtiles=n_mtiles,
                                 m_true=m_true if m_true else m)
        in_specs = [
            pl.BlockSpec((bi, bm), lambda i, j, jm: (i, jm)),
            pl.BlockSpec((bj, bm), lambda i, j, jm: (j, jm)),
        ]
        operands = [rows, cols]
        if with_base:
            in_specs += [
                pl.BlockSpec((bi, 1), lambda i, j, jm: (i, 0)),
                pl.BlockSpec((bj, 1), lambda i, j, jm: (j, 0)),
            ]
            operands += [row_base, col_base]
        le, ge = pl.pallas_call(
            body,
            grid=(N // bi, M // bj, n_mtiles),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
                pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((N, M), acc),
                jax.ShapeDtypeStruct((N, M), acc),
            ],
            interpret=interpret,
            **_compiler_params(spec, 3, interpret),
        )(*operands)
        return le, ge

    return rect_pallas


def _emit_rect_i32_stats(spec: CompareSpec):
    bi, bj, bm = spec.bi, spec.bj, spec.bm

    def kernel(a_ref, b_ref, bsums_ref, le_ref, ge_ref, asums_ref, fp_ref,
               *, n_mtiles, m):
        j = pl.program_id(1)       # column-tile index
        jm = pl.program_id(2)      # m-tile index (innermost -> revisits)
        a = a_ref[...]             # [bi, bm] int32 row clocks
        b = b_ref[...]             # [bj, bm] int32 column clocks

        # wrap-subtraction dominance (bounded-counter semantics): exact
        # for gaps < 2^31, bit-identical to direct <=/>= in that range —
        # this is the rim engine promoted near-wrap rows ride, so it
        # must stay correct across the int32 wrap point
        d = a[:, None, :] - b[None, :, :]
        le = jnp.all(d <= 0, axis=2)
        ge = jnp.all(d >= 0, axis=2)
        sa = jnp.sum(a, axis=1, keepdims=True).astype(jnp.float32)

        # row sums: the (i, 0) block stays live for the whole i-row of
        # the grid, so add each m-tile exactly once (j == 0 stripe)
        @pl.when(jnp.logical_and(j == 0, jm == 0))
        def _init_sums():
            asums_ref[...] = sa

        @pl.when(jnp.logical_and(j == 0, jm > 0))
        def _acc_sums():
            asums_ref[...] = asums_ref[...] + sa

        _flags_accumulate(jm, le.astype(jnp.int32), ge.astype(jnp.int32),
                          le_ref, ge_ref)

        @pl.when(jm == n_mtiles - 1)
        def _finalize():
            sa_tot = asums_ref[...]            # [bi, 1] complete
            sb_tot = bsums_ref[...]            # [1, bj] precomputed input
            log_q = jnp.log1p(-1.0 / m)
            inner_b = jnp.clip(-jnp.expm1(sb_tot * log_q), _EQ3_CLIP, 1.0)
            fp_ref[...] = jnp.exp(sa_tot * jnp.log(inner_b))

    @functools.partial(jax.jit, static_argnames=("m_true", "interpret"))
    def rect_i32_pallas(rows, cols, col_sums, *, m_true=None,
                        interpret=False):
        """Tiled all-pairs int32 compare with in-kernel sums + Eq. 3."""
        validate(spec, _backend(interpret))
        N, m = rows.shape
        M, mc = cols.shape
        assert m == mc and col_sums.shape == (1, M)
        assert N % bi == 0 and M % bj == 0 and m % bm == 0, \
            (N, M, m, bi, bj, bm)
        n_mtiles = m // bm
        body = functools.partial(kernel, n_mtiles=n_mtiles,
                                 m=m_true if m_true else m)
        le, ge, row_sums, fp = pl.pallas_call(
            body,
            grid=(N // bi, M // bj, n_mtiles),
            in_specs=[
                pl.BlockSpec((bi, bm), lambda i, j, jm: (i, jm)),
                pl.BlockSpec((bj, bm), lambda i, j, jm: (j, jm)),
                pl.BlockSpec((1, bj), lambda i, j, jm: (0, j)),
            ],
            out_specs=[
                pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
                pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
                pl.BlockSpec((bi, 1), lambda i, j, jm: (i, 0)),
                pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((N, M), jnp.int32),
                jax.ShapeDtypeStruct((N, M), jnp.int32),
                jax.ShapeDtypeStruct((N, 1), jnp.float32),
                jax.ShapeDtypeStruct((N, M), jnp.float32),
            ],
            interpret=interpret,
            **_compiler_params(spec, 3, interpret),
        )(rows, cols, col_sums)
        return le, ge, row_sums, fp

    return rect_i32_pallas


def _emit_mxu(spec: CompareSpec):
    bi, bj, bm, n_thr = spec.bi, spec.bj, spec.bm, spec.n_thresholds

    def kernel(a_ref, b_ref, abase_ref, bbase_ref, viol_ref,
               *, n_mtiles, lo, m_true):
        jm = pl.program_id(2)
        # shift residuals to window-relative logical values in [0, T]
        av = a_ref[...].astype(jnp.int32) + (abase_ref[...] - lo)
        bv = b_ref[...].astype(jnp.int32) + (bbase_ref[...] - lo)
        # padded lanes must contribute zero violations either way
        col = jax.lax.broadcasted_iota(jnp.int32, (1, bm), 1) + jm * bm
        av = jnp.where(col < m_true, av, -1)           # a >= t never
        bv = jnp.where(col < m_true, bv, n_thr + 1)    # b <  t never
        thr = jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, n_thr), 2) + 1           # t = 1 .. T
        bi_, bj_ = av.shape[0], bv.shape[0]
        enc_a = (av[:, :, None] >= thr).reshape(
            bi_, -1).astype(jnp.float32)               # [bi, bm*T]
        enc_b = (bv[:, :, None] < thr).reshape(
            bj_, -1).astype(jnp.float32)               # [bj, bm*T]
        # sum_m relu(a - b) == #{(m, t): b_jm < t <= a_im} — one MXU pass
        v = jax.lax.dot_general(
            enc_a, enc_b, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)        # [bi, bj]

        @pl.when(jm == 0)
        def _init():
            viol_ref[...] = v

        @pl.when(jm > 0)
        def _acc():
            viol_ref[...] = viol_ref[...] + v

    @functools.partial(jax.jit, static_argnames=("lo", "m_true", "interpret"))
    def mxu_pallas(rows, cols, row_base, col_base, *, lo, m_true=None,
                   interpret=False):
        """MXU dominance reduction: violation counts via one dot_general.

        Returns viol f32 [N, M] with ``viol[i, j] == sum_m relu(a_im -
        b_jm)`` exactly (counts <= m * T << 2^24).  ``le = viol == 0``;
        the caller derives ``ge`` from the rank-1 identity with row/col
        sums.  Requires every logical value in [lo, lo + T]."""
        validate(spec, _backend(interpret))
        N, m = rows.shape
        M, mc = cols.shape
        assert m == mc and N % bi == 0 and M % bj == 0 and m % bm == 0
        # violation counts accumulate in f32: keep them exactly
        # representable
        assert (m_true if m_true else m) * n_thr < 2**24, \
            (m_true, n_thr, "f32 exactness bound exceeded")
        n_mtiles = m // bm
        body = functools.partial(kernel, n_mtiles=n_mtiles, lo=lo,
                                 m_true=m_true if m_true else m)
        viol = pl.pallas_call(
            body,
            grid=(N // bi, M // bj, n_mtiles),
            in_specs=[
                pl.BlockSpec((bi, bm), lambda i, j, jm: (i, jm)),
                pl.BlockSpec((bj, bm), lambda i, j, jm: (j, jm)),
                pl.BlockSpec((bi, 1), lambda i, j, jm: (i, 0)),
                pl.BlockSpec((bj, 1), lambda i, j, jm: (j, 0)),
            ],
            out_specs=pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
            out_shape=jax.ShapeDtypeStruct((N, M), jnp.float32),
            interpret=interpret,
            **_compiler_params(spec, 3, interpret),
        )(rows, cols, row_base, col_base)
        return viol

    return mxu_pallas


def _emit_one_vs_many(spec: CompareSpec):
    bn, bm, packed = spec.bi, spec.bm, spec.pack == "u8"
    acc = spec.acc_dtype

    def kernel(q_ref, p_ref, *rest, n_mtiles, m):
        if packed:
            pbase_ref, flags_ref, sums_ref, fp_ref = rest
        else:
            flags_ref, sums_ref, fp_ref = rest
        j = pl.program_id(1)
        q = q_ref[...]                                 # [1, bm] int32
        if packed:
            # widen the u8 peer tile in VMEM; HBM reads stay 1 B/cell
            p = p_ref[...].astype(jnp.int32) + pbase_ref[...]
            col = jax.lax.broadcasted_iota(jnp.int32, (1, bm), 1) + j * bm
            p = jnp.where(col < m, p, 0)               # neutral pad lanes
        else:
            p = p_ref[...]                             # [bn, bm] int32
        _one_vs_many_step(j, q, p, flags_ref, sums_ref, fp_ref,
                          n_mtiles=n_mtiles, m=m, acc=acc)

    @functools.partial(jax.jit, static_argnames=("m_true", "interpret"))
    def one_vs_many_pallas(q, peers, base=None, *, m_true=None,
                           interpret=False):
        """One-vs-many classify: per-peer flags, total sums, Eq. 3 fp."""
        validate(spec, _backend(interpret))
        N, m = peers.shape
        assert q.shape == (1, m) and m % bm == 0 and N % bn == 0
        n_mtiles = m // bm
        body = functools.partial(kernel, n_mtiles=n_mtiles,
                                 m=m_true if m_true else m)
        in_specs = [
            pl.BlockSpec((1, bm), lambda i, j: (0, j)),
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        ]
        operands = [q, peers]
        if packed:
            in_specs.append(pl.BlockSpec((bn, 1), lambda i, j: (i, 0)))
            operands.append(base)
        flags, sums, fp = pl.pallas_call(
            body,
            grid=(N // bn, n_mtiles),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
                pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
                pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((N, 2), acc),
                jax.ShapeDtypeStruct((N, 2), jnp.float32),
                jax.ShapeDtypeStruct((N, 2), jnp.float32),
            ],
            interpret=interpret,
            **_compiler_params(spec, 2, interpret),
        )(*operands)
        return flags, sums, fp

    return one_vs_many_pallas


def _emit_hybrid(spec: CompareSpec):
    bn, bm = spec.bi, spec.bm
    acc = spec.acc_dtype

    def kernel(q_ref, vloc_ref, meta_ref, hsum_ref, p_ref, pbase_ref,
               flags_ref, sums_ref, fp_ref, *, n_mtiles, m, nh_tiles):
        i = pl.program_id(0)
        j = pl.program_id(1)
        is_hot = i < nh_tiles

        # Tail candidate: the UNMODIFIED packed one-vs-many math — tail
        # verdicts/sums/fp must stay bit-identical to the flat slab.
        # (Hot grid steps read a clamped tail tile whose result is
        # discarded by the select below.)
        q = q_ref[...]                                 # [1, bm] int32
        p = p_ref[...].astype(jnp.int32) + pbase_ref[...]
        col = jax.lax.broadcasted_iota(jnp.int32, (1, bm), 1) + j * bm
        p = jnp.where(col < m, p, 0)                   # neutral pad lanes
        d = p - q
        t_le = jnp.all(d >= 0, axis=1, keepdims=True)
        t_ge = jnp.all(d <= 0, axis=1, keepdims=True)
        sp = jnp.sum(p, axis=1, keepdims=True).astype(jnp.float32)
        sq = jnp.broadcast_to(
            jnp.sum(q, axis=1, keepdims=True).astype(jnp.float32), sp.shape)

        # Hot candidate: exact chain-prefix verdicts.  A hot row is the
        # pair (v = minting-chain prefix length, n_private = events past
        # the prefix); against the local chain at version V the order is
        # an integer compare — no bloom cells, no Eq. 3 exposure.
        V = vloc_ref[0, 0]
        v = meta_ref[:, 0:1]
        npriv = meta_ref[:, 1:2]
        h_le = V <= v                                  # local chain ≼ peer
        h_ge = jnp.logical_and(v <= V, npriv == 0)     # peer ≼ local chain

        le = jnp.where(is_hot, h_le, t_le)
        ge = jnp.where(is_hot, h_ge, t_ge)
        cur = jnp.concatenate([le, ge], axis=1).astype(acc)
        # sums[:, 0] accumulates sum(q) per m-tile for hot rows too, so
        # the caller's sum_q (read off row 0) matches the tail engines
        # bit for bit; sums[:, 1] of a hot row is its precomputed shadow
        # sum, added once on the first m-tile.
        s_other = jnp.where(
            is_hot,
            jnp.where(j == 0, hsum_ref[...], jnp.zeros_like(sp)), sp)
        s_cur = jnp.concatenate([sq, s_other], axis=1)

        @pl.when(j == 0)
        def _init():
            flags_ref[...] = cur
            sums_ref[...] = s_cur

        @pl.when(j > 0)
        def _acc():
            flags_ref[...] = flags_ref[...] & cur
            sums_ref[...] = sums_ref[...] + s_cur

        @pl.when(j == n_mtiles - 1)
        def _finalize():
            fp = _eq3_pair_finalize(sums_ref[...], m)
            fp_ref[...] = jnp.where(is_hot, jnp.zeros_like(fp), fp)

    @functools.partial(jax.jit, static_argnames=("m_true", "interpret"))
    def hybrid_pallas(q, v_local, hot_meta, hot_sums, tail, tail_base, *,
                      m_true=None, interpret=False):
        """One query vs [exact hot rows ++ packed tail] in one sweep.

        Outputs are stacked hot-first: rows [0, H) are the hot set
        (exact flags, fp ≡ 0.0), rows [H, H+T) the packed tail (flags/
        sums/fp bit-identical to the one_vs_many packed engine)."""
        validate(spec, _backend(interpret))
        H = hot_meta.shape[0]
        T, m = tail.shape
        assert q.shape == (1, m) and m % bm == 0, (q.shape, m, bm)
        assert H % bn == 0 and T % bn == 0 and H > 0 and T > 0, (H, T, bn)
        assert hot_meta.shape == (H, 2) and hot_sums.shape == (H, 1)
        assert v_local.shape == (1, 1)
        nh_tiles = H // bn
        n_mtiles = m // bm
        body = functools.partial(kernel, n_mtiles=n_mtiles,
                                 m=m_true if m_true else m,
                                 nh_tiles=nh_tiles)
        # Hot tiles clamp the tail index maps to block 0 (and vice
        # versa): every grid step fetches valid blocks, the select in
        # the body discards the wrong-side result.
        in_specs = [
            pl.BlockSpec((1, bm), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((bn, 2),
                         lambda i, j: (jnp.minimum(i, nh_tiles - 1), 0)),
            pl.BlockSpec((bn, 1),
                         lambda i, j: (jnp.minimum(i, nh_tiles - 1), 0)),
            pl.BlockSpec((bn, bm),
                         lambda i, j: (jnp.maximum(i - nh_tiles, 0), j)),
            pl.BlockSpec((bn, 1),
                         lambda i, j: (jnp.maximum(i - nh_tiles, 0), 0)),
        ]
        flags, sums, fp = pl.pallas_call(
            body,
            grid=(nh_tiles + T // bn, n_mtiles),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
                pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
                pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((H + T, 2), acc),
                jax.ShapeDtypeStruct((H + T, 2), jnp.float32),
                jax.ShapeDtypeStruct((H + T, 2), jnp.float32),
            ],
            interpret=interpret,
            **_compiler_params(spec, 2, interpret),
        )(q, v_local, hot_meta, hot_sums, tail, tail_base)
        return flags, sums, fp

    return hybrid_pallas


@functools.lru_cache(maxsize=None)
def emit(spec: CompareSpec):
    """Validated, jitted wrapper for one point in the design space.

    Cached per spec, so repeated emission of the same instance reuses
    the same jitted callable (and its compiled executables)."""
    validate(spec)
    if spec.topology == "tri":
        return _emit_tri(spec)
    if spec.topology == "rect":
        if spec.pack == "i32":
            return _emit_rect_i32_stats(spec)
        return _emit_rect_u8(spec)
    if spec.topology == "mxu":
        return _emit_mxu(spec)
    if spec.topology == "hybrid":
        return _emit_hybrid(spec)
    return _emit_one_vs_many(spec)
