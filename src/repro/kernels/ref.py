"""Pure-jnp oracles for the bloom-clock kernels (ground truth for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["bloom_tick_ref", "bloom_merge_compare_ref"]


def bloom_tick_ref(cells: jax.Array, probes: jax.Array) -> jax.Array:
    """cells [B, m] int32, probes [B, P] int32 -> incremented cells.

    Straightforward one-hot formulation (what the kernel must match).
    """
    m = cells.shape[-1]
    one_hot = jax.nn.one_hot(probes, m, dtype=cells.dtype)  # [B, P, m]
    return cells + jnp.sum(one_hot, axis=-2)


def bloom_merge_compare_ref(a: jax.Array, b: jax.Array):
    """Returns (merged, flags[B,2] int32, sums[B,2] f32, fp[B,2] f32).

    flags[:, 0] = all(a<=b), flags[:, 1] = all(a>=b)
    sums[:, 0] = ΣA, sums[:, 1] = ΣB
    fp[:, 0]   = Eq.3 fp of "A -> B", fp[:, 1] = "B -> A"
    """
    m = a.shape[-1]
    merged = jnp.maximum(a, b)
    le = jnp.all(a <= b, axis=-1)
    ge = jnp.all(a >= b, axis=-1)
    sa = jnp.sum(a, axis=-1).astype(jnp.float32)
    sb = jnp.sum(b, axis=-1).astype(jnp.float32)
    log_q = jnp.log1p(-1.0 / m)
    inner_b = jnp.clip(-jnp.expm1(sb * log_q), 1e-30, 1.0)
    inner_a = jnp.clip(-jnp.expm1(sa * log_q), 1e-30, 1.0)
    fp_ab = jnp.exp(sa * jnp.log(inner_b))
    fp_ba = jnp.exp(sb * jnp.log(inner_a))
    flags = jnp.stack([le, ge], axis=-1).astype(jnp.int32)
    sums = jnp.stack([sa, sb], axis=-1)
    fp = jnp.stack([fp_ab, fp_ba], axis=-1)
    return merged, flags, sums, fp
