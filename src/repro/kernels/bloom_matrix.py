"""Bulk bloom-clock comparison engines (template-emitted; see below).

Since PR 7 every engine here is an INSTANCE of the parameterized
compare-kernel template (``kernels.template``), emitted by name in
``kernels.generate``; this module re-exports them under their historical
names so existing imports keep working.  The hand-rolled kernel bodies
that used to live here were deleted after each emitted instance was
pinned bit-identical (flags, Eq. 3 fp bits, per-row bases) against a
verbatim copy of the old code — the pins live in
``tests/test_template.py``.

What the engines compute (the design, shared by every instance):

``bloom_one_vs_many_pallas`` / ``bloom_one_vs_many_packed_pallas``
    grid (N/bn, m/bm); one query clock vs bn peers per step.  Dominance
    flags AND-accumulate and sums ADD-accumulate across m-tiles into
    per-peer [bn, 2] outputs; the Eq. 3 fp rates (both directions) are
    finalized with log1p/expm1-stable math on the last m-tile.  One HBM
    read of the peer slab total; the packed variant reads u8 residuals
    and widens in VMEM (+ per-slot int32 base).

``bloom_matrix_pallas``
    grid (N/bi, M/bj, m/bm); tiled all-pairs int32 compare with in-kernel
    row sums (accumulated on the j == 0 stripe) and Eq. 3 fp(row -> col)
    finalized as the outer product of stable-log factors; column sums
    arrive as a cheap precomputed input.

``bloom_matrix_tri_pallas``
    symmetric all-pairs over ONE u8 slab.  ``ge(i, j) == le(j, i)``, so
    only the block-upper-triangle is swept (scalar-prefetched block index
    lists drive the grid) and each tile computes BOTH directions from a
    single int16 difference: ``le = max(d) <= 0``, ``ge = min(d) >= 0``.
    Half the pairs, one pairwise intermediate, u8 HBM reads.

``bloom_matrix_packed_pallas``
    the same single-difference formulation on a full rectangle.

``bloom_matrix_mxu_pallas``
    MXU formulation: per-pair violation counts ``sum_m relu(a - b)`` as
    ONE ``dot_general`` per tile via thermometer encoding; ``le`` iff the
    count is zero, opposite direction by the rank-1 identity with row/col
    sums.  Exact in f32 (counts <= m * T << 2^24); selected only for
    narrow value spans (the regime §4 promises).

Per-row bases (window offsets) are honored in all packed engines: folded
in as a clipped [bi, bj] delta (clipping at ±(U8_MAX + 1) cannot change
a verdict since residual differences are bounded by U8_MAX) or as a
per-row shift before encoding; padded lanes are masked in-kernel where
bases make zero-padding non-neutral.

These engines are also the per-shard building blocks of the mesh-sharded
registry paths (``ops.classify_vs_many_packed_sharded`` /
``ops.compare_matrix_packed_sharded``).  Nothing in the kernel bodies is
placement-aware — flags are exact, so sharded results stay bit-identical
to the single-device sweeps.
"""
from __future__ import annotations

from repro.kernels.generate import (
    bloom_matrix_mxu_pallas,
    bloom_matrix_packed_pallas,
    bloom_matrix_pallas,
    bloom_matrix_tri_pallas,
    bloom_one_vs_many_packed_pallas,
    bloom_one_vs_many_pallas,
)

__all__ = [
    "bloom_one_vs_many_pallas",
    "bloom_one_vs_many_packed_pallas",
    "bloom_matrix_pallas",
    "bloom_matrix_tri_pallas",
    "bloom_matrix_packed_pallas",
    "bloom_matrix_mxu_pallas",
]
