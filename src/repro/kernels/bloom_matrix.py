"""Pallas TPU kernels: bulk bloom-clock comparison (one-vs-many, N x N).

The fleet layer (``repro.fleet``) never compares clocks one pair at a
time: a gossip round classifies EVERY peer against the local clock, and
the fleet monitor classifies EVERY pair.  Done with the broadcast
reference (``repro.core.clock.comparability_matrix``) that is an
O(n^2 * m) materialization — at n = m = 1024 that is three 4 GB
intermediates for what is fundamentally a streaming reduction.  These
kernels tile the reduction instead:

``bloom_one_vs_many_kernel``
    grid (N/bn, m/bm); compares one query clock against bn peers per
    step.  Same revisited-output pattern as ``bloom_compare.py``:
    dominance flags AND-accumulate and sums ADD-accumulate across
    m-tiles into per-peer [bn, 2] outputs, and the Eq. 3 fp rates (both
    directions) are finalized with log1p/expm1-stable math on the last
    m-tile.  One HBM read of the peer slab total.

``bloom_matrix_kernel``
    grid (N/bi, M/bj, m/bm); tiled all-pairs compare.  Per step it holds
    one [bi, bm] row tile and one [bj, bm] column tile in VMEM and
    AND-accumulates the [bi, bj] dominance flags across m-tiles
    (innermost grid axis -> consecutive revisits).  Row sums are
    ADD-accumulated in-kernel on the j == 0 stripe only (the [bi, 1]
    output block stays live for the whole i-row of the grid, so the
    stripe completes before any finalize step of that row needs it).
    Column sums cannot be accumulated the same way — their block would
    be revisited non-consecutively across i — so they arrive as a cheap
    precomputed input (the fleet registry caches per-clock sums
    anyway).  Eq. 3 fp(row -> col) is finalized on the last m-tile as
    the outer product of the stable-log factors.

Both kernels read each operand tile exactly once; flags are exact
(bit-identical to the reference), fp is the same f32 expression the
reference evaluates.

Packed-slab engines (the quantized fast paths — see ``kernels.pack``):

``bloom_matrix_tri_pallas``
    symmetric all-pairs over ONE u8 slab.  Because ``ge(i, j) ==
    le(j, i)``, only the block-upper-triangle is swept (scalar-prefetched
    block index lists drive the grid), and each visited tile computes
    BOTH directions from a single int16 difference: ``le = max(d) <= 0``,
    ``ge = min(d) >= 0``.  Half the pairs, one pairwise intermediate
    instead of two, u8 HBM reads: ~4x less traffic than the int32
    kernel.  The wrapper mirrors the missing triangle by transposition.

``bloom_matrix_packed_pallas``
    the same single-difference formulation on a full rectangle, for
    rows != cols.

``bloom_matrix_mxu_pallas``
    the MXU formulation of the dominance reduction: per-pair violation
    counts ``sum_m relu(a - b)`` computed as ONE ``jax.lax.dot_general``
    per tile via thermometer encoding — ``relu(a - b) = #{t : b < t <=
    a}``, so ``A[i, (m, t)] = a_im >= t`` against ``B[j, (m, t)] = b_jm
    < t`` contracts to exactly the violation count.  A pair is ``le``
    iff its count is zero; the opposite direction is the rank-1 identity
    ``viol_ge = viol_le - rowsum + colsum`` (no second pass).  Exact in
    f32 (counts <= m * T << 2^24).  FLOPs scale with the value span T,
    so the wrapper only selects this engine for narrow windows — the
    regime §4 promises.

Per-row bases (window offsets) are honored in all packed engines: either
folded in as a clipped [bi, bj] delta (clipping at ±(U8_MAX + 1) cannot
change a verdict since residual differences are bounded by U8_MAX) or as
a per-row shift before encoding.  Padded lanes are masked in-kernel
where bases make zero-padding non-neutral.

These kernels are also the per-shard building blocks of the mesh-sharded
registry paths (``ops.classify_vs_many_packed_sharded`` /
``ops.compare_matrix_packed_sharded``): shard_map runs the one-vs-many
kernel on each [N/d, m] row shard, and the all-pairs ring feeds each
visiting column shard through ``bloom_matrix_packed_pallas`` one
[N/d, N/d] tile at a time.  Nothing in the kernel bodies is
placement-aware — flags are exact, so sharded results stay bit-identical
to the single-device sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "bloom_one_vs_many_kernel",
    "bloom_one_vs_many_pallas",
    "bloom_one_vs_many_packed_pallas",
    "bloom_matrix_kernel",
    "bloom_matrix_pallas",
    "bloom_matrix_tri_pallas",
    "bloom_matrix_packed_pallas",
    "bloom_matrix_mxu_pallas",
]


def bloom_one_vs_many_kernel(
    q_ref, p_ref,
    flags_ref, sums_ref, fp_ref,
    *, n_mtiles: int, m: int,
):
    j = pl.program_id(1)
    q = q_ref[...]            # [1, bm] int32 query tile (broadcasts over rows)
    p = p_ref[...]            # [bn, bm] int32 peer tiles

    le = jnp.all(q <= p, axis=1, keepdims=True)          # [bn, 1] q <= peer
    ge = jnp.all(q >= p, axis=1, keepdims=True)          # [bn, 1] peer <= q
    sp = jnp.sum(p, axis=1, keepdims=True).astype(jnp.float32)
    sq = jnp.broadcast_to(
        jnp.sum(q, axis=1, keepdims=True).astype(jnp.float32), sp.shape)

    @pl.when(j == 0)
    def _init():
        flags_ref[...] = jnp.concatenate([le, ge], axis=1).astype(jnp.int32)
        sums_ref[...] = jnp.concatenate([sq, sp], axis=1)

    @pl.when(j > 0)
    def _acc():
        cur = jnp.concatenate([le, ge], axis=1).astype(jnp.int32)
        flags_ref[...] = flags_ref[...] & cur
        sums_ref[...] = sums_ref[...] + jnp.concatenate([sq, sp], axis=1)

    @pl.when(j == n_mtiles - 1)
    def _finalize():
        s = sums_ref[...]                     # [bn, 2] total Σq, Σp
        log_q = jnp.log1p(-1.0 / m)
        inner_p = jnp.clip(-jnp.expm1(s[:, 1:2] * log_q), 1e-30, 1.0)
        inner_q = jnp.clip(-jnp.expm1(s[:, 0:1] * log_q), 1e-30, 1.0)
        fp_qp = jnp.exp(s[:, 0:1] * jnp.log(inner_p))   # P(q ⊆ p by chance)
        fp_pq = jnp.exp(s[:, 1:2] * jnp.log(inner_q))
        fp_ref[...] = jnp.concatenate([fp_qp, fp_pq], axis=1)


@functools.partial(jax.jit, static_argnames=("bn", "bm", "m_true", "interpret"))
def bloom_one_vs_many_pallas(
    q: jax.Array,        # [1, m] int32, padded: m % bm == 0
    peers: jax.Array,    # [N, m] int32, N % bn == 0
    *,
    bn: int = 8,
    bm: int = 512,
    m_true: int | None = None,
    interpret: bool = False,
):
    N, m = peers.shape
    assert q.shape == (1, m) and m % bm == 0 and N % bn == 0
    n_mtiles = m // bm
    grid = (N // bn, n_mtiles)
    kernel = functools.partial(
        bloom_one_vs_many_kernel, n_mtiles=n_mtiles, m=m_true if m_true else m
    )
    flags, sums, fp = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm), lambda i, j: (0, j)),
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, 2), jnp.int32),
            jax.ShapeDtypeStruct((N, 2), jnp.float32),
            jax.ShapeDtypeStruct((N, 2), jnp.float32),
        ],
        interpret=interpret,
    )(q, peers)
    return flags, sums, fp


def bloom_matrix_kernel(
    a_ref, b_ref, bsums_ref,
    le_ref, ge_ref, asums_ref, fp_ref,
    *, n_mtiles: int, m: int,
):
    j = pl.program_id(1)      # column-tile index
    jm = pl.program_id(2)     # m-tile index (innermost -> revisits outputs)
    a = a_ref[...]            # [bi, bm] int32 row clocks
    b = b_ref[...]            # [bj, bm] int32 column clocks

    # pairwise dominance on this m-tile: [bi, bj]
    le = jnp.all(a[:, None, :] <= b[None, :, :], axis=2)
    ge = jnp.all(a[:, None, :] >= b[None, :, :], axis=2)
    sa = jnp.sum(a, axis=1, keepdims=True).astype(jnp.float32)  # [bi, 1]

    # row sums: the (i, 0) block is live for the entire i-row of the grid,
    # so add each m-tile exactly once (during the j == 0 stripe)
    @pl.when(jnp.logical_and(j == 0, jm == 0))
    def _init_sums():
        asums_ref[...] = sa

    @pl.when(jnp.logical_and(j == 0, jm > 0))
    def _acc_sums():
        asums_ref[...] = asums_ref[...] + sa

    @pl.when(jm == 0)
    def _init_flags():
        le_ref[...] = le.astype(jnp.int32)
        ge_ref[...] = ge.astype(jnp.int32)

    @pl.when(jm > 0)
    def _acc_flags():
        le_ref[...] = le_ref[...] & le.astype(jnp.int32)
        ge_ref[...] = ge_ref[...] & ge.astype(jnp.int32)

    @pl.when(jm == n_mtiles - 1)
    def _finalize():
        sa_tot = asums_ref[...]               # [bi, 1] complete (see above)
        sb_tot = bsums_ref[...]               # [1, bj] precomputed input
        log_q = jnp.log1p(-1.0 / m)
        inner_b = jnp.clip(-jnp.expm1(sb_tot * log_q), 1e-30, 1.0)  # [1, bj]
        # Eq. 3 fp of "row i happened-before col j": outer product in log space
        fp_ref[...] = jnp.exp(sa_tot * jnp.log(inner_b))            # [bi, bj]


@functools.partial(
    jax.jit, static_argnames=("bi", "bj", "bm", "m_true", "interpret"))
def bloom_matrix_pallas(
    rows: jax.Array,       # [N, m] int32, padded: N % bi == 0, m % bm == 0
    cols: jax.Array,       # [M, m] int32, M % bj == 0
    col_sums: jax.Array,   # [1, M] float32 total increments per column clock
    *,
    bi: int = 8,
    bj: int = 128,
    bm: int = 512,
    m_true: int | None = None,
    interpret: bool = False,
):
    N, m = rows.shape
    M, mc = cols.shape
    assert m == mc and col_sums.shape == (1, M)
    assert N % bi == 0 and M % bj == 0 and m % bm == 0, (N, M, m, bi, bj, bm)
    n_mtiles = m // bm
    grid = (N // bi, M // bj, n_mtiles)
    kernel = functools.partial(
        bloom_matrix_kernel, n_mtiles=n_mtiles, m=m_true if m_true else m
    )
    le, ge, row_sums, fp = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bm), lambda i, j, jm: (i, jm)),
            pl.BlockSpec((bj, bm), lambda i, j, jm: (j, jm)),
            pl.BlockSpec((1, bj), lambda i, j, jm: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
            pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
            pl.BlockSpec((bi, 1), lambda i, j, jm: (i, 0)),
            pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, M), jnp.int32),
            jax.ShapeDtypeStruct((N, M), jnp.int32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
            jax.ShapeDtypeStruct((N, M), jnp.float32),
        ],
        interpret=interpret,
    )(rows, cols, col_sums)
    return le, ge, row_sums, fp


# ---------------------------------------------------------------------------
# packed u8 engines
# ---------------------------------------------------------------------------

def _pair_flags_minmax(a_ref, b_ref, abase_ref, bbase_ref,
                       *, with_base, m_true, bm, jm):
    """[bi, bj] (le, ge) int8 for one tile pair from ONE int16 difference.

    ``d`` spans ±U8_MAX before the base delta; the delta is clipped to
    ±(U8_MAX + 1), which preserves verdicts exactly (any |delta| beyond
    the residual range forces the verdict) and keeps d inside int16.
    """
    a = a_ref[...]
    b = b_ref[...]
    d = a.astype(jnp.int16)[:, None, :] - b.astype(jnp.int16)[None, :, :]
    if with_base:
        delta = jnp.clip(abase_ref[...] - bbase_ref[...].T, -256, 256)
        d = d + delta[:, :, None].astype(jnp.int16)
        # zero-padded lanes are only neutral when bases cancel; mask them
        col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, bm), 2) + jm * bm
        d = jnp.where(col < m_true, d, 0)
    le = (jnp.max(d, axis=2) <= 0).astype(jnp.int8)
    ge = (jnp.min(d, axis=2) >= 0).astype(jnp.int8)
    return le, ge


def _flags_kernel_step(refs, *, jm, with_base, m_true, bm):
    """Shared body of the packed flag kernels: one min/max difference on
    this m-tile, AND-accumulated into the revisited [bi, bj] outputs."""
    if with_base:
        a_ref, b_ref, abase_ref, bbase_ref, le_ref, ge_ref = refs
    else:
        a_ref, b_ref, le_ref, ge_ref = refs
        abase_ref = bbase_ref = None
    le, ge = _pair_flags_minmax(a_ref, b_ref, abase_ref, bbase_ref,
                                with_base=with_base, m_true=m_true,
                                bm=bm, jm=jm)

    @pl.when(jm == 0)
    def _init():
        le_ref[...] = le
        ge_ref[...] = ge

    @pl.when(jm > 0)
    def _acc():
        le_ref[...] = le_ref[...] & le
        ge_ref[...] = ge_ref[...] & ge


def bloom_matrix_tri_kernel(ti_ref, tj_ref, *refs,
                            n_mtiles: int, with_base: bool,
                            m_true: int, bm: int):
    _flags_kernel_step(refs, jm=pl.program_id(1), with_base=with_base,
                       m_true=m_true, bm=bm)


@functools.partial(
    jax.jit, static_argnames=("bi", "bm", "m_true", "with_base", "interpret"))
def bloom_matrix_tri_pallas(
    cells: jax.Array,      # [N, m] uint8 residuals, N % bi == 0, m % bm == 0
    base: jax.Array,       # [N, 1] int32 per-slot window offsets
    *,
    bi: int = 128,
    bm: int = 512,
    m_true: int | None = None,
    with_base: bool = False,
    interpret: bool = False,
):
    """Symmetric all-pairs compare over one packed slab (upper triangle).

    Returns (le, ge) int8 [N, N] valid ONLY in block-upper-triangle
    positions; the caller fills ``le[lower] = ge.T[lower]`` and vice
    versa (``ops.compare_matrix_packed`` does).
    """
    N, m = cells.shape
    assert N % bi == 0 and m % bm == 0, (N, m, bi, bm)
    k = N // bi
    tri = [(i, j) for i in range(k) for j in range(i, k)]
    ti = jnp.asarray([i for i, _ in tri], jnp.int32)
    tj = jnp.asarray([j for _, j in tri], jnp.int32)
    n_mtiles = m // bm
    kernel = functools.partial(
        bloom_matrix_tri_kernel, n_mtiles=n_mtiles, with_base=with_base,
        m_true=m_true if m_true else m, bm=bm)
    in_specs = [
        pl.BlockSpec((bi, bm), lambda t, jm, ti, tj: (ti[t], jm)),
        pl.BlockSpec((bi, bm), lambda t, jm, ti, tj: (tj[t], jm)),
    ]
    operands = [cells, cells]
    if with_base:
        in_specs += [
            pl.BlockSpec((bi, 1), lambda t, jm, ti, tj: (ti[t], 0)),
            pl.BlockSpec((bi, 1), lambda t, jm, ti, tj: (tj[t], 0)),
        ]
        operands += [base, base]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(len(tri), n_mtiles),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bi, bi), lambda t, jm, ti, tj: (ti[t], tj[t])),
            pl.BlockSpec((bi, bi), lambda t, jm, ti, tj: (ti[t], tj[t])),
        ],
    )
    le, ge = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((N, N), jnp.int8),
            jax.ShapeDtypeStruct((N, N), jnp.int8),
        ],
        interpret=interpret,
    )(ti, tj, *operands)
    return le, ge


def bloom_matrix_packed_kernel(*refs, n_mtiles: int, with_base: bool,
                               m_true: int, bm: int):
    _flags_kernel_step(refs, jm=pl.program_id(2), with_base=with_base,
                       m_true=m_true, bm=bm)


@functools.partial(
    jax.jit,
    static_argnames=("bi", "bj", "bm", "m_true", "with_base", "interpret"))
def bloom_matrix_packed_pallas(
    rows: jax.Array,       # [N, m] uint8, N % bi == 0, m % bm == 0
    cols: jax.Array,       # [M, m] uint8, M % bj == 0
    row_base: jax.Array,   # [N, 1] int32
    col_base: jax.Array,   # [M, 1] int32
    *,
    bi: int = 128,
    bj: int = 128,
    bm: int = 512,
    m_true: int | None = None,
    with_base: bool = False,
    interpret: bool = False,
):
    """Full-rectangle packed compare: (le, ge) int8 [N, M]."""
    N, m = rows.shape
    M, mc = cols.shape
    assert m == mc and N % bi == 0 and M % bj == 0 and m % bm == 0
    n_mtiles = m // bm
    kernel = functools.partial(
        bloom_matrix_packed_kernel, n_mtiles=n_mtiles, with_base=with_base,
        m_true=m_true if m_true else m, bm=bm)
    in_specs = [
        pl.BlockSpec((bi, bm), lambda i, j, jm: (i, jm)),
        pl.BlockSpec((bj, bm), lambda i, j, jm: (j, jm)),
    ]
    operands = [rows, cols]
    if with_base:
        in_specs += [
            pl.BlockSpec((bi, 1), lambda i, j, jm: (i, 0)),
            pl.BlockSpec((bj, 1), lambda i, j, jm: (j, 0)),
        ]
        operands += [row_base, col_base]
    le, ge = pl.pallas_call(
        kernel,
        grid=(N // bi, M // bj, n_mtiles),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
            pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, M), jnp.int8),
            jax.ShapeDtypeStruct((N, M), jnp.int8),
        ],
        interpret=interpret,
    )(*operands)
    return le, ge


def bloom_matrix_mxu_kernel(
    a_ref, b_ref, abase_ref, bbase_ref, viol_ref,
    *, n_mtiles: int, n_thresholds: int, lo: int, m_true: int, bm: int,
):
    jm = pl.program_id(2)
    # shift residuals to window-relative logical values in [0, T]
    av = a_ref[...].astype(jnp.int32) + (abase_ref[...] - lo)   # [bi, bm]
    bv = b_ref[...].astype(jnp.int32) + (bbase_ref[...] - lo)   # [bj, bm]
    # padded lanes must contribute zero violations either way
    col = jax.lax.broadcasted_iota(jnp.int32, (1, bm), 1) + jm * bm
    av = jnp.where(col < m_true, av, -1)                 # a >= t never
    bv = jnp.where(col < m_true, bv, n_thresholds + 1)   # b <  t never
    thr = jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, n_thresholds), 2) + 1          # t = 1 .. T
    bi_, bj_ = av.shape[0], bv.shape[0]
    enc_a = (av[:, :, None] >= thr).reshape(
        bi_, -1).astype(jnp.float32)                     # [bi, bm*T]
    enc_b = (bv[:, :, None] < thr).reshape(
        bj_, -1).astype(jnp.float32)                     # [bj, bm*T]
    # sum_m relu(a - b) == #{(m, t) : b_jm < t <= a_im} — one contraction
    v = jax.lax.dot_general(
        enc_a, enc_b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)              # [bi, bj]

    @pl.when(jm == 0)
    def _init():
        viol_ref[...] = v

    @pl.when(jm > 0)
    def _acc():
        viol_ref[...] = viol_ref[...] + v


@functools.partial(
    jax.jit,
    static_argnames=("bi", "bj", "bm", "n_thresholds", "lo", "m_true",
                     "interpret"))
def bloom_matrix_mxu_pallas(
    rows: jax.Array,       # [N, m] uint8
    cols: jax.Array,       # [M, m] uint8
    row_base: jax.Array,   # [N, 1] int32
    col_base: jax.Array,   # [M, 1] int32
    *,
    n_thresholds: int,     # static value-span budget T (window width)
    lo: int,               # static minimum logical value across both slabs
    bi: int = 128,
    bj: int = 128,
    bm: int = 128,
    m_true: int | None = None,
    interpret: bool = False,
):
    """MXU dominance reduction: violation counts via one dot_general.

    Returns viol f32 [N, M] with ``viol[i, j] == sum_m relu(a_im -
    b_jm)`` exactly (counts << 2^24).  ``le = viol == 0``; the caller
    derives ``ge`` from the rank-1 identity with row/col sums.  Requires
    every logical value in [lo, lo + n_thresholds].
    """
    N, m = rows.shape
    M, mc = cols.shape
    assert m == mc and N % bi == 0 and M % bj == 0 and m % bm == 0
    # violation counts accumulate in f32: keep them exactly representable
    assert (m_true if m_true else m) * n_thresholds < 2**24, \
        (m_true, n_thresholds, "f32 exactness bound exceeded")
    n_mtiles = m // bm
    kernel = functools.partial(
        bloom_matrix_mxu_kernel, n_mtiles=n_mtiles,
        n_thresholds=n_thresholds, lo=lo,
        m_true=m_true if m_true else m, bm=bm)
    viol = pl.pallas_call(
        kernel,
        grid=(N // bi, M // bj, n_mtiles),
        in_specs=[
            pl.BlockSpec((bi, bm), lambda i, j, jm: (i, jm)),
            pl.BlockSpec((bj, bm), lambda i, j, jm: (j, jm)),
            pl.BlockSpec((bi, 1), lambda i, j, jm: (i, 0)),
            pl.BlockSpec((bj, 1), lambda i, j, jm: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, M), jnp.float32),
        interpret=interpret,
    )(rows, cols, row_base, col_base)
    return viol


def bloom_one_vs_many_packed_kernel(
    q_ref, p_ref, pbase_ref,
    flags_ref, sums_ref, fp_ref,
    *, n_mtiles: int, m: int, bm: int,
):
    j = pl.program_id(1)
    q = q_ref[...]                                       # [1, bm] int32
    # widen the u8 peer tile in VMEM; HBM read stays one byte per cell
    p = p_ref[...].astype(jnp.int32) + pbase_ref[...]    # [bn, bm]
    col = jax.lax.broadcasted_iota(jnp.int32, (1, bm), 1) + j * bm
    p = jnp.where(col < m, p, 0)                         # neutral pad lanes

    le = jnp.all(q <= p, axis=1, keepdims=True)          # [bn, 1] q <= peer
    ge = jnp.all(q >= p, axis=1, keepdims=True)
    sp = jnp.sum(p, axis=1, keepdims=True).astype(jnp.float32)
    sq = jnp.broadcast_to(
        jnp.sum(q, axis=1, keepdims=True).astype(jnp.float32), sp.shape)

    @pl.when(j == 0)
    def _init():
        flags_ref[...] = jnp.concatenate([le, ge], axis=1).astype(jnp.int32)
        sums_ref[...] = jnp.concatenate([sq, sp], axis=1)

    @pl.when(j > 0)
    def _acc():
        cur = jnp.concatenate([le, ge], axis=1).astype(jnp.int32)
        flags_ref[...] = flags_ref[...] & cur
        sums_ref[...] = sums_ref[...] + jnp.concatenate([sq, sp], axis=1)

    @pl.when(j == n_mtiles - 1)
    def _finalize():
        s = sums_ref[...]
        log_q = jnp.log1p(-1.0 / m)
        inner_p = jnp.clip(-jnp.expm1(s[:, 1:2] * log_q), 1e-30, 1.0)
        inner_q = jnp.clip(-jnp.expm1(s[:, 0:1] * log_q), 1e-30, 1.0)
        fp_qp = jnp.exp(s[:, 0:1] * jnp.log(inner_p))
        fp_pq = jnp.exp(s[:, 1:2] * jnp.log(inner_q))
        fp_ref[...] = jnp.concatenate([fp_qp, fp_pq], axis=1)


@functools.partial(jax.jit, static_argnames=("bn", "bm", "m_true", "interpret"))
def bloom_one_vs_many_packed_pallas(
    q: jax.Array,        # [1, m] int32 logical query, zero-padded
    peers: jax.Array,    # [N, m] uint8 residual slab, N % bn == 0
    base: jax.Array,     # [N, 1] int32 per-slot offsets
    *,
    bn: int = 8,
    bm: int = 512,
    m_true: int | None = None,
    interpret: bool = False,
):
    """One-vs-many classify against a PACKED peer slab (u8 HBM reads)."""
    N, m = peers.shape
    assert q.shape == (1, m) and m % bm == 0 and N % bn == 0
    n_mtiles = m // bm
    kernel = functools.partial(
        bloom_one_vs_many_packed_kernel, n_mtiles=n_mtiles,
        m=m_true if m_true else m, bm=bm)
    flags, sums, fp = pl.pallas_call(
        kernel,
        grid=(N // bn, n_mtiles),
        in_specs=[
            pl.BlockSpec((1, bm), lambda i, j: (0, j)),
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, 2), jnp.int32),
            jax.ShapeDtypeStruct((N, 2), jnp.float32),
            jax.ShapeDtypeStruct((N, 2), jnp.float32),
        ],
        interpret=interpret,
    )(q, peers, base)
    return flags, sums, fp
