"""Pallas TPU kernels: bulk bloom-clock comparison (one-vs-many, N x N).

The fleet layer (``repro.fleet``) never compares clocks one pair at a
time: a gossip round classifies EVERY peer against the local clock, and
the fleet monitor classifies EVERY pair.  Done with the broadcast
reference (``repro.core.clock.comparability_matrix``) that is an
O(n^2 * m) materialization — at n = m = 1024 that is three 4 GB
intermediates for what is fundamentally a streaming reduction.  These
kernels tile the reduction instead:

``bloom_one_vs_many_kernel``
    grid (N/bn, m/bm); compares one query clock against bn peers per
    step.  Same revisited-output pattern as ``bloom_compare.py``:
    dominance flags AND-accumulate and sums ADD-accumulate across
    m-tiles into per-peer [bn, 2] outputs, and the Eq. 3 fp rates (both
    directions) are finalized with log1p/expm1-stable math on the last
    m-tile.  One HBM read of the peer slab total.

``bloom_matrix_kernel``
    grid (N/bi, M/bj, m/bm); tiled all-pairs compare.  Per step it holds
    one [bi, bm] row tile and one [bj, bm] column tile in VMEM and
    AND-accumulates the [bi, bj] dominance flags across m-tiles
    (innermost grid axis -> consecutive revisits).  Row sums are
    ADD-accumulated in-kernel on the j == 0 stripe only (the [bi, 1]
    output block stays live for the whole i-row of the grid, so the
    stripe completes before any finalize step of that row needs it).
    Column sums cannot be accumulated the same way — their block would
    be revisited non-consecutively across i — so they arrive as a cheap
    precomputed input (the fleet registry caches per-clock sums
    anyway).  Eq. 3 fp(row -> col) is finalized on the last m-tile as
    the outer product of the stable-log factors.

Both kernels read each operand tile exactly once; flags are exact
(bit-identical to the reference), fp is the same f32 expression the
reference evaluates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "bloom_one_vs_many_kernel",
    "bloom_one_vs_many_pallas",
    "bloom_matrix_kernel",
    "bloom_matrix_pallas",
]


def bloom_one_vs_many_kernel(
    q_ref, p_ref,
    flags_ref, sums_ref, fp_ref,
    *, n_mtiles: int, m: int,
):
    j = pl.program_id(1)
    q = q_ref[...]            # [1, bm] int32 query tile (broadcasts over rows)
    p = p_ref[...]            # [bn, bm] int32 peer tiles

    le = jnp.all(q <= p, axis=1, keepdims=True)          # [bn, 1] q <= peer
    ge = jnp.all(q >= p, axis=1, keepdims=True)          # [bn, 1] peer <= q
    sp = jnp.sum(p, axis=1, keepdims=True).astype(jnp.float32)
    sq = jnp.broadcast_to(
        jnp.sum(q, axis=1, keepdims=True).astype(jnp.float32), sp.shape)

    @pl.when(j == 0)
    def _init():
        flags_ref[...] = jnp.concatenate([le, ge], axis=1).astype(jnp.int32)
        sums_ref[...] = jnp.concatenate([sq, sp], axis=1)

    @pl.when(j > 0)
    def _acc():
        cur = jnp.concatenate([le, ge], axis=1).astype(jnp.int32)
        flags_ref[...] = flags_ref[...] & cur
        sums_ref[...] = sums_ref[...] + jnp.concatenate([sq, sp], axis=1)

    @pl.when(j == n_mtiles - 1)
    def _finalize():
        s = sums_ref[...]                     # [bn, 2] total Σq, Σp
        log_q = jnp.log1p(-1.0 / m)
        inner_p = jnp.clip(-jnp.expm1(s[:, 1:2] * log_q), 1e-30, 1.0)
        inner_q = jnp.clip(-jnp.expm1(s[:, 0:1] * log_q), 1e-30, 1.0)
        fp_qp = jnp.exp(s[:, 0:1] * jnp.log(inner_p))   # P(q ⊆ p by chance)
        fp_pq = jnp.exp(s[:, 1:2] * jnp.log(inner_q))
        fp_ref[...] = jnp.concatenate([fp_qp, fp_pq], axis=1)


@functools.partial(jax.jit, static_argnames=("bn", "bm", "m_true", "interpret"))
def bloom_one_vs_many_pallas(
    q: jax.Array,        # [1, m] int32, padded: m % bm == 0
    peers: jax.Array,    # [N, m] int32, N % bn == 0
    *,
    bn: int = 8,
    bm: int = 512,
    m_true: int | None = None,
    interpret: bool = False,
):
    N, m = peers.shape
    assert q.shape == (1, m) and m % bm == 0 and N % bn == 0
    n_mtiles = m // bm
    grid = (N // bn, n_mtiles)
    kernel = functools.partial(
        bloom_one_vs_many_kernel, n_mtiles=n_mtiles, m=m_true if m_true else m
    )
    flags, sums, fp = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm), lambda i, j: (0, j)),
            pl.BlockSpec((bn, bm), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, 2), jnp.int32),
            jax.ShapeDtypeStruct((N, 2), jnp.float32),
            jax.ShapeDtypeStruct((N, 2), jnp.float32),
        ],
        interpret=interpret,
    )(q, peers)
    return flags, sums, fp


def bloom_matrix_kernel(
    a_ref, b_ref, bsums_ref,
    le_ref, ge_ref, asums_ref, fp_ref,
    *, n_mtiles: int, m: int,
):
    j = pl.program_id(1)      # column-tile index
    jm = pl.program_id(2)     # m-tile index (innermost -> revisits outputs)
    a = a_ref[...]            # [bi, bm] int32 row clocks
    b = b_ref[...]            # [bj, bm] int32 column clocks

    # pairwise dominance on this m-tile: [bi, bj]
    le = jnp.all(a[:, None, :] <= b[None, :, :], axis=2)
    ge = jnp.all(a[:, None, :] >= b[None, :, :], axis=2)
    sa = jnp.sum(a, axis=1, keepdims=True).astype(jnp.float32)  # [bi, 1]

    # row sums: the (i, 0) block is live for the entire i-row of the grid,
    # so add each m-tile exactly once (during the j == 0 stripe)
    @pl.when(jnp.logical_and(j == 0, jm == 0))
    def _init_sums():
        asums_ref[...] = sa

    @pl.when(jnp.logical_and(j == 0, jm > 0))
    def _acc_sums():
        asums_ref[...] = asums_ref[...] + sa

    @pl.when(jm == 0)
    def _init_flags():
        le_ref[...] = le.astype(jnp.int32)
        ge_ref[...] = ge.astype(jnp.int32)

    @pl.when(jm > 0)
    def _acc_flags():
        le_ref[...] = le_ref[...] & le.astype(jnp.int32)
        ge_ref[...] = ge_ref[...] & ge.astype(jnp.int32)

    @pl.when(jm == n_mtiles - 1)
    def _finalize():
        sa_tot = asums_ref[...]               # [bi, 1] complete (see above)
        sb_tot = bsums_ref[...]               # [1, bj] precomputed input
        log_q = jnp.log1p(-1.0 / m)
        inner_b = jnp.clip(-jnp.expm1(sb_tot * log_q), 1e-30, 1.0)  # [1, bj]
        # Eq. 3 fp of "row i happened-before col j": outer product in log space
        fp_ref[...] = jnp.exp(sa_tot * jnp.log(inner_b))            # [bi, bj]


@functools.partial(
    jax.jit, static_argnames=("bi", "bj", "bm", "m_true", "interpret"))
def bloom_matrix_pallas(
    rows: jax.Array,       # [N, m] int32, padded: N % bi == 0, m % bm == 0
    cols: jax.Array,       # [M, m] int32, M % bj == 0
    col_sums: jax.Array,   # [1, M] float32 total increments per column clock
    *,
    bi: int = 8,
    bj: int = 128,
    bm: int = 512,
    m_true: int | None = None,
    interpret: bool = False,
):
    N, m = rows.shape
    M, mc = cols.shape
    assert m == mc and col_sums.shape == (1, M)
    assert N % bi == 0 and M % bj == 0 and m % bm == 0, (N, M, m, bi, bj, bm)
    n_mtiles = m // bm
    grid = (N // bi, M // bj, n_mtiles)
    kernel = functools.partial(
        bloom_matrix_kernel, n_mtiles=n_mtiles, m=m_true if m_true else m
    )
    le, ge, row_sums, fp = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bi, bm), lambda i, j, jm: (i, jm)),
            pl.BlockSpec((bj, bm), lambda i, j, jm: (j, jm)),
            pl.BlockSpec((1, bj), lambda i, j, jm: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
            pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
            pl.BlockSpec((bi, 1), lambda i, j, jm: (i, 0)),
            pl.BlockSpec((bi, bj), lambda i, j, jm: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, M), jnp.int32),
            jax.ShapeDtypeStruct((N, M), jnp.int32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
            jax.ShapeDtypeStruct((N, M), jnp.float32),
        ],
        interpret=interpret,
    )(rows, cols, col_sums)
    return le, ge, row_sums, fp
