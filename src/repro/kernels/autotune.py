"""Cost-model-guided block-shape / engine / strategy selection.

The right (engine, bi, bj, bm, bn) for the bulk comparison kernels
depends on the machine: interpret mode on CPU wants few, cache-sized
grid steps; a real TPU wants every working set inside VMEM and, for
narrow §4 windows, the MXU thermometer engine whose FLOPs scale with
the value span.  Since PR 7 the search is two-stage:

1. **Analytic cost model** (``predict_cost``): per candidate, a
   VMEM-fit check (the same ``template.vmem_estimate`` the kernel
   generator refuses over-budget specs with) plus an order-of-magnitude
   time estimate from HBM traffic, compute work (VPU element ops or MXU
   FLOPs with utilization), and per-grid-step overhead.  Candidates are
   RANKED by predicted time and only the top half survive — the model
   prunes, it never has the final word.
2. **Measured ranking**: survivors race on the live backend; the
   fastest wins the table entry.

Winners are cached in a JSON table keyed by

    op | backend | N-bucket | M-bucket | m-bucket | s<shards>

(shape buckets are powers of two, rounded up, so one sweep covers a
band of nearby shapes; the shard count is part of the key, so a 2-shard
tune can never poison the 1-shard entry for the same global shape).
``kernels.ops`` consults ``lookup`` on every call and falls back to
conservative per-backend defaults when the table has no entry.

The ``matrix_sharded`` op also records a per-shape **strategy**
decision — ``ring`` (halved ppermute block-row sweep) vs ``replicated``
(gather the slab once, run the single-device triangle engine) — which
``ops._compare_matrix_packed_sharded`` dispatches on.  The cost model
knows that forced-host device meshes serialize onto the host cores
(ring collectives buy no parallelism there), so CI backends predict
``replicated`` while a real multi-core mesh predicts ``ring``.

Regenerate the shipped table with

    PYTHONPATH=src python -m repro.kernels.autotune --write

which sweeps the standard shapes on the current machine and rewrites
``autotune_table.json`` next to this module (or ``--out PATH`` /
``$REPRO_AUTOTUNE_TABLE`` for a private table).  ``--explain`` prints,
per (op, shape bucket), the cost model's predicted ranking next to the
measured result so the pruning quality is auditable; ``--trace-dir``
attaches a ``repro.obs`` Observer that records one span per sweep and
search counters (candidates / pruned / measured).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time
from pathlib import Path

__all__ = [
    "lookup",
    "key_for",
    "predict_cost",
    "predict_hybrid_cost",
    "predict_sharded_cost",
    "prune",
    "autotune_matrix",
    "autotune_matrix_sharded",
    "autotune_one_vs_many",
    "autotune_hybrid",
    "autotune_shapes",
    "table_path",
    "load_table",
    "save_table",
    "vmem_bytes",
    "CACHE_STATS",
    "SEARCH_STATS",
]

_DEFAULT_TABLE = Path(__file__).parent / "autotune_table.json"
_ENV = "REPRO_AUTOTUNE_TABLE"

_table_cache: dict | None = None
_table_cache_path: str | None = None


def table_path() -> Path:
    return Path(os.environ.get(_ENV, _DEFAULT_TABLE))


def load_table() -> dict:
    global _table_cache, _table_cache_path
    path = table_path()
    if _table_cache is not None and _table_cache_path == str(path):
        return _table_cache
    try:
        with open(path) as f:
            _table_cache = json.load(f)
    except (OSError, ValueError):
        _table_cache = {}
    _table_cache_path = str(path)
    return _table_cache


def save_table(table: dict, path: Path | None = None) -> Path:
    global _table_cache, _table_cache_path
    path = path or table_path()
    with open(path, "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
        f.write("\n")
    _table_cache, _table_cache_path = table, str(path)
    return path


def _bucket(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


def _backend(interpret: bool) -> str:
    return "interpret" if interpret else "tpu"


def key_for(op: str, N: int, M: int, m: int, interpret: bool,
            shards: int = 1) -> str:
    """Table key.  The shard count is explicit: block resolution for a
    d-shard ring differs from the 1-shard sweep of the SAME global
    shape, so their entries must never alias."""
    return (f"{op}|{_backend(interpret)}|N{_bucket(N)}|M{_bucket(M)}"
            f"|m{_bucket(m)}|s{shards}")


# running hit/miss tally for the measured-table consults; the obs
# metrics layer snapshots this around each front-door dispatch
CACHE_STATS = {"hit": 0, "miss": 0}

# running tallies for the two-stage search itself (same plumbing shape
# as CACHE_STATS: the obs layer / CLI snapshot deltas around sweeps)
SEARCH_STATS = {"candidates": 0, "pruned": 0, "measured": 0}


def lookup(op: str, N: int, M: int, m: int, interpret: bool,
           shards: int = 1) -> dict | None:
    """Best known config for this op/shape/shard band, or None."""
    cfg = load_table().get(key_for(op, N, M, m, interpret, shards))
    CACHE_STATS["hit" if cfg is not None else "miss"] += 1
    return cfg


# ---------------------------------------------------------------------------
# analytic cost model
# ---------------------------------------------------------------------------

def vmem_bytes(engine: str, bi: int, bj: int, bm: int,
               n_thresholds: int = 0) -> int:
    """Peak per-step working set of one grid step of a matrix engine.

    Delegates to the kernel generator's estimate (``template
    .vmem_estimate``) at pipeline depth 1, so the search space and the
    generator refuse the same over-budget combos from ONE model."""
    from repro.kernels.template import CompareSpec, vmem_estimate
    spec = {
        "tri": lambda: CompareSpec(topology="tri", pack="u8", bi=bi, bj=bi,
                                   bm=bm, pipeline_depth=1),
        "full": lambda: CompareSpec(topology="rect", pack="u8", bi=bi, bj=bj,
                                    bm=bm, pipeline_depth=1),
        "i32": lambda: CompareSpec(topology="rect", pack="i32", bi=bi, bj=bj,
                                   bm=bm, with_stats=True, pipeline_depth=1),
        "mxu": lambda: CompareSpec(topology="mxu", pack="u8", bi=bi, bj=bj,
                                   bm=bm, with_base=True, pipeline_depth=1,
                                   n_thresholds=max(n_thresholds, 1)),
    }.get(engine)
    if spec is None:
        raise ValueError(engine)
    return vmem_estimate(spec())


def _fits(engine: str, bi: int, bj: int, bm: int, interpret: bool,
          n_thresholds: int = 0) -> bool:
    from repro.kernels.template import VMEM_BUDGET
    return vmem_bytes(engine, bi, bj, bm, n_thresholds) <= \
        VMEM_BUDGET[_backend(interpret)]


# Order-of-magnitude machine constants.  Only the RANKING matters (the
# model prunes, measurement decides), so these are deliberately coarse:
#   interpret — a Python-dispatched emulation: per-grid-step overhead in
#       the milliseconds dominates; elementwise work runs at numpy-ish
#       rates and dot_general ~10x denser than elementwise loops.
#   tpu — per-step cost is the roofline max of HBM streaming and
#       compute; grid-step overhead is microseconds.
_MODEL = {
    "interpret": dict(step_overhead=2.0e-3, elem=4.0e-10, mxu_flop=4.0e-11,
                      hbm=0.0),
    "tpu": dict(step_overhead=2.0e-6, elem=5.0e-13, mxu_flop=2.2e-15,
                hbm=1.25e-12),
}


def predict_cost(engine: str, N: int, M: int, m: int,
                 bi: int, bj: int, bm: int, interpret: bool,
                 n_thresholds: int = 0) -> float:
    """Predicted seconds for one all-pairs sweep with this candidate.

    Infinite when the per-step working set busts the VMEM budget — the
    model and the kernel generator refuse the same combos."""
    if not _fits(engine, bi, bj, bm, interpret, n_thresholds):
        return math.inf
    c = _MODEL[_backend(interpret)]
    gi, gj, gm = -(-N // bi), -(-M // bj), -(-m // bm)
    pairs = gi * (gi + 1) // 2 if engine == "tri" else gi * gj
    steps = pairs * gm
    elem_per_step = bi * bj * bm * (2 if engine == "i32" else 1)
    if engine == "mxu":
        # thermometer encodes elementwise, then one MXU contraction;
        # utilization falls off for sub-128 tiles
        util = min(bi, 128) * min(bj, 128) / (128 * 128)
        compute = steps * ((bi + bj) * bm * n_thresholds * c["elem"]
                           + 2 * bi * bj * bm * n_thresholds
                           * c["mxu_flop"] / max(util, 1e-3))
    else:
        compute = steps * elem_per_step * c["elem"]
    esize = 4 if engine == "i32" else 1
    hbm = steps * (bi + bj) * bm * esize * c["hbm"]
    return steps * c["step_overhead"] + max(compute, hbm)


def predict_hybrid_cost(N: int, H: int, m: int, bn: int, bm: int,
                        interpret: bool) -> float:
    """Predicted seconds for one fused hot+tail hybrid classify.

    The fused kernel runs a UNIFORM body (both the exact hot verdict and
    the packed tail math execute every step, a select picks the valid
    side), so hot and tail row-tiles cost alike per grid step; the
    hybrid speedup the bench demonstrates comes from the smaller tail
    geometry ``m`` an fp budget allows once the fp-binding hot sessions
    are carried exactly — which this model sees through ``m``.  ``N`` is
    the TOTAL row count, ``H`` of which are hot."""
    c = _MODEL[_backend(interpret)]
    T = max(N - H, 1)
    steps = (-(-H // bn) + -(-T // bn)) * (-(-m // bm))
    return steps * (c["step_overhead"] + bn * bm * (c["elem"] + c["hbm"]))


def _host_serialized(interpret: bool) -> bool:
    """True when mesh devices are forced host-platform devices sharing
    the physical cores — collectives there buy zero parallel compute
    (the CI topology: XLA_FLAGS=--xla_force_host_platform_device_count)."""
    import jax
    return interpret or jax.default_backend() == "cpu"


def predict_sharded_cost(strategy: str, N: int, m: int, shards: int,
                         interpret: bool, *, bi: int | None = None,
                         bj: int | None = None, bm: int = 512) -> float:
    """Predicted seconds for one sharded all-pairs sweep.

    ``ring``: every device sweeps its [N/d, m] block-row — the tri
    diagonal plus halved visiting offsets — so TOTAL work matches the
    single-device triangle; wall-clock divides by d only when devices
    are physically parallel, and each of the 1 + d//2 steps pays a
    collective overhead.  ``replicated``: one gather of the u8 slab,
    then the plain single-device triangle sweep."""
    if shards == 1:
        strategy = "replicated"          # a 1-wide ring is the plain sweep
    if bi is None or bj is None:
        # mirror the per-backend defaults ops._matrix_blocks falls back
        # to: interpret wants few big steps, tpu must fit VMEM
        bi = bj = 128 if interpret else 8
    tri = predict_cost("tri", N, N, m, bi, bj, bm, interpret)
    if strategy == "replicated":
        gather = N * m * _MODEL[_backend(interpret)].get("hbm", 0.0) or \
            N * m * 1e-9 * (1.0 if _host_serialized(interpret) else 0.1)
        return tri + gather
    if strategy != "ring":
        raise ValueError(strategy)
    parallel = 1.0 if _host_serialized(interpret) else float(shards)
    steps = 1 + shards // 2
    collective = steps * (2.0e-3 if _host_serialized(interpret) else 5.0e-6)
    # ship-backs and per-step dispatch also serialize on a shared host
    ring_overhead = steps * shards * \
        (1.0e-3 if _host_serialized(interpret) else 0.0)
    return tri / parallel + collective + ring_overhead


def prune(candidates: list, predicted: list[float]) -> list:
    """Keep at most half of ``candidates`` (capped at 8) ranked by
    predicted cost — always at least one; infinite predictions (VMEM
    busts) never survive."""
    if not candidates:
        return []
    order = sorted(range(len(candidates)), key=lambda i: predicted[i])
    keep = max(1, min(len(candidates) // 2, 8))
    kept = [candidates[i] for i in order[:keep]
            if predicted[i] < math.inf]
    SEARCH_STATS["candidates"] += len(candidates)
    SEARCH_STATS["pruned"] += len(candidates) - len(kept)
    return kept or [candidates[order[0]]]


# ---------------------------------------------------------------------------
# measured sweeps
# ---------------------------------------------------------------------------

def _divisor_blocks(size: int, want: tuple, mult: int) -> list:
    return [b for b in want if b % mult == 0 and b <= size and size % b == 0]


def _measure(fn, reps: int = 3) -> float:
    import jax
    jax.block_until_ready(jax.tree.leaves(fn()))     # warm / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(jax.tree.leaves(fn()))
        best = min(best, time.perf_counter() - t0)
    SEARCH_STATS["measured"] += 1
    return best


def _rand_packed(N: int, m: int, span: int, seed: int = 0):
    import jax.numpy as jnp
    import numpy as np
    rng = np.random.default_rng(seed)
    cells = jnp.asarray(rng.integers(0, span, (N, m)), jnp.uint8)
    base = jnp.zeros((N, 1), jnp.int32)
    return cells, base


def _matrix_candidates(N: int, m: int, span: int, interpret: bool) -> list:
    """The full knob grid for the matrix op (before the model prunes)."""
    from repro.kernels import ops
    out = []
    for bi in (8, 64, 128, 256):
        for bm in (128, 256, 512, 1024):
            if not (_divisor_blocks(N, (bi,), 8)
                    and _divisor_blocks(m, (bm,), 128)):
                continue
            out.append(("tri", bi, bi, bm))
            out.append(("i32", bi, bi, bm))
            if span <= ops.MXU_SPAN_MAX:
                out.append(("mxu", bi, bi, bm))
    return out


def autotune_matrix(N: int, m: int, *, span: int = 30,
                    interpret: bool | None = None, verbose: bool = False,
                    explain: dict | None = None):
    """Race matrix engines x block shapes at [N, m]; return best config.

    The analytic model ranks the full grid first and only the top half
    is measured.  Pass ``explain={}`` to receive the predicted ranking,
    the survivor list, and the measured times for auditing."""
    import jax

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    from repro.kernels import ops
    cells, base = _rand_packed(N, m, span)
    cells_i32 = cells.astype("int32")

    grid = _matrix_candidates(N, m, span, interpret)
    predicted = [predict_cost(e, N, N, m, bi, bj, bm, interpret,
                              n_thresholds=span if e == "mxu" else 0)
                 for (e, bi, bj, bm) in grid]
    survivors = prune(grid, predicted)
    if explain is not None:
        ranking = sorted(zip(grid, predicted), key=lambda t: t[1])
        explain["grid"] = len(grid)
        explain["predicted"] = [
            {"engine": e, "bi": bi, "bj": bj, "bm": bm, "pred_us": p * 1e6}
            for (e, bi, bj, bm), p in ranking]
        explain["survivors"] = len(survivors)

    results = []
    for engine, bi, bj, bm in survivors:
        try:
            if engine == "i32":
                fn = lambda: ops._compare_matrix(
                    cells_i32, cells_i32, engine="i32", bi=bi, bj=bj,
                    bm=bm, interpret=interpret, use_autotune=False)
            else:
                fn = lambda: ops._compare_matrix_packed(
                    cells, base, engine=engine, bi=bi, bj=bj, bm=bm,
                    interpret=interpret, use_autotune=False)
            dt = _measure(fn)
        except Exception as e:            # candidate invalid on this backend
            if verbose:
                print(f"  matrix {engine} bi={bi} bm={bm}: FAILED {e}")
            continue
        results.append({"engine": engine, "bi": bi, "bj": bj, "bm": bm,
                        "us": dt * 1e6})
        if verbose:
            print(f"  matrix {engine} bi={bi} bj={bj} bm={bm}: {dt*1e3:.1f} ms")
    if not results:
        raise RuntimeError(f"no viable matrix candidates for N={N} m={m}")
    if explain is not None:
        explain["measured"] = sorted(results, key=lambda r: r["us"])
    return min(results, key=lambda r: r["us"])


def autotune_matrix_sharded(N: int, m: int, shards: int, *, span: int = 30,
                            interpret: bool | None = None,
                            verbose: bool = False,
                            explain: dict | None = None):
    """Race ring vs replicated for the sharded symmetric all-pairs sweep.

    Returns {"strategy", "bi", "bj", "bm", "us"} — the config
    ``ops._compare_matrix_packed_sharded`` dispatches on."""
    import jax

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    from repro.kernels import ops
    from repro.launch.mesh import make_fleet_mesh

    if len(jax.devices()) < shards:
        raise RuntimeError(
            f"{shards}-shard sweep needs {shards} devices, "
            f"have {len(jax.devices())}")
    mesh = make_fleet_mesh(shards)
    cells, base = _rand_packed(N, m, span)
    blocks = lookup("matrix", N, N, m, interpret) or {}
    bi = blocks.get("bi", 128)
    bj = blocks.get("bj", 128)
    bm = blocks.get("bm", 512)

    grid = ["ring", "replicated"]
    predicted = [predict_sharded_cost(s, N, m, shards, interpret,
                                      bi=bi, bj=bj, bm=bm) for s in grid]
    if explain is not None:
        ranking = sorted(zip(grid, predicted), key=lambda t: t[1])
        explain["predicted"] = [
            {"strategy": s, "pred_us": p * 1e6} for s, p in ranking]

    results = []
    for strategy in grid:
        try:
            fn = lambda: ops._compare_matrix_packed_sharded(
                cells, base, mesh=mesh, axis="fleet", strategy=strategy,
                uniform_base=True, interpret=interpret, use_autotune=False)
            dt = _measure(fn)
        except Exception as e:
            if verbose:
                print(f"  matrix_sharded {strategy} d={shards}: FAILED {e}")
            continue
        results.append({"strategy": strategy, "bi": bi, "bj": bj, "bm": bm,
                        "us": dt * 1e6})
        if verbose:
            print(f"  matrix_sharded {strategy} d={shards}: {dt*1e3:.1f} ms")
    if not results:
        raise RuntimeError(
            f"no viable sharded candidates for N={N} m={m} d={shards}")
    if explain is not None:
        explain["measured"] = sorted(results, key=lambda r: r["us"])
    return min(results, key=lambda r: r["us"])


def autotune_one_vs_many(N: int, m: int, *, span: int = 30,
                         interpret: bool | None = None,
                         verbose: bool = False,
                         explain: dict | None = None):
    import jax
    import jax.numpy as jnp

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    from repro.kernels import ops
    cells, base = _rand_packed(N, m, span)
    q = cells[0].astype(jnp.int32)

    grid = []
    for bn in (8, 32, 128, 256):
        for bm in (128, 256, 512, 1024):
            if (_divisor_blocks(N, (bn,), 8)
                    and _divisor_blocks(m, (bm,), 128)):
                grid.append((bn, bm))
    # one-vs-many is O(N * m) total: per-step overhead dominates, so the
    # model is simply step count x overhead + streamed work
    c = _MODEL[_backend(interpret)]
    predicted = [(-(-N // bn)) * (-(-m // bm))
                 * (c["step_overhead"] + bn * bm * c["elem"])
                 for (bn, bm) in grid]
    survivors = prune(grid, predicted)
    if explain is not None:
        ranking = sorted(zip(grid, predicted), key=lambda t: t[1])
        explain["grid"] = len(grid)
        explain["predicted"] = [
            {"engine": "packed", "bn": bn, "bm": bm, "pred_us": p * 1e6}
            for (bn, bm), p in ranking]
        explain["survivors"] = len(survivors)

    results = []
    for bn, bm in survivors:
        try:
            dt = _measure(lambda: ops._classify_vs_many_packed(
                q, cells, base, bn=bn, bm=bm, interpret=interpret,
                use_autotune=False))
        except Exception:
            continue
        results.append({"engine": "packed", "bn": bn, "bm": bm,
                        "us": dt * 1e6})
        if verbose:
            print(f"  one_vs_many bn={bn} bm={bm}: {dt*1e3:.2f} ms")
    if not results:
        raise RuntimeError(f"no viable one_vs_many candidates N={N} m={m}")
    if explain is not None:
        explain["measured"] = sorted(results, key=lambda r: r["us"])
    return min(results, key=lambda r: r["us"])


def autotune_hybrid(N: int, m: int, *, hot: int | None = None,
                    span: int = 30, interpret: bool | None = None,
                    verbose: bool = False, explain: dict | None = None):
    """Race block shapes for the fused hot+tail hybrid classify.

    ``N`` is the TOTAL row count; ``hot`` (default N // 8) of those are
    exact hot rows, the rest the packed bloom tail.  Winners land under
    ``key_for("hybrid", N, hot, m, ...)`` — the hot count rides in the
    M slot — matching the ``ops._hybrid_blocks`` lookup."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    from repro.kernels import ops
    hot = hot if hot is not None else max(8, N // 8)
    T = max(8, N - hot)
    cells, base = _rand_packed(T, m, span)
    q = cells[0].astype(jnp.int32)
    rng = np.random.default_rng(1)
    meta = jnp.asarray(np.stack([rng.integers(0, 64, hot),
                                 rng.integers(0, 4, hot)], axis=1), jnp.int32)
    hsums = jnp.asarray(rng.integers(0, 64 * span, (hot, 1)), jnp.float32)

    grid = []
    for bn in (8, 32, 128, 256):
        for bm in (128, 256, 512, 1024):
            if (_divisor_blocks(T, (bn,), 8)
                    and _divisor_blocks(m, (bm,), 128)):
                grid.append((bn, bm))
    predicted = [predict_hybrid_cost(N, hot, m, bn, bm, interpret)
                 for (bn, bm) in grid]
    survivors = prune(grid, predicted)
    if explain is not None:
        ranking = sorted(zip(grid, predicted), key=lambda t: t[1])
        explain["grid"] = len(grid)
        explain["predicted"] = [
            {"engine": "hybrid", "bn": bn, "bm": bm, "pred_us": p * 1e6}
            for (bn, bm), p in ranking]
        explain["survivors"] = len(survivors)

    results = []
    for bn, bm in survivors:
        try:
            dt = _measure(lambda: ops._classify_hybrid(
                q, 32, meta, hsums, cells, base, bn=bn, bm=bm,
                interpret=interpret, use_autotune=False))
        except Exception:
            continue
        results.append({"engine": "hybrid", "bn": bn, "bm": bm,
                        "us": dt * 1e6})
        if verbose:
            print(f"  hybrid bn={bn} bm={bm}: {dt*1e3:.2f} ms")
    if not results:
        raise RuntimeError(f"no viable hybrid candidates N={N} m={m}")
    if explain is not None:
        explain["measured"] = sorted(results, key=lambda r: r["us"])
    return min(results, key=lambda r: r["us"])


def autotune_shapes(shapes, *, shard_counts=(), interpret: bool | None = None,
                    verbose: bool = False, observer=None,
                    explains: dict | None = None) -> dict:
    """Sweep (N, m) shapes (and shard counts); returns {table_key: cfg}.

    ``observer`` (a ``repro.obs.Observer``) gets one ``autotune.sweep``
    span per (op, shape) with the search counters as attributes; the
    running module-level tallies live in ``SEARCH_STATS`` (same
    snapshot-the-deltas plumbing the dispatch metrics use for
    ``CACHE_STATS``)."""
    from repro.obs import resolve
    obs = resolve(observer)
    out = {}
    interp = interpret if interpret is not None else _is_interp()

    def swept(op, N, m, fn, **kw):
        before = dict(SEARCH_STATS)
        exp = {}
        with obs.trace.span("autotune.sweep", op=op, N=N, m=m, **kw) as span:
            best = fn(explain=exp)
            span.set(
                candidates=SEARCH_STATS["candidates"] - before["candidates"],
                pruned=SEARCH_STATS["pruned"] - before["pruned"],
                measured=SEARCH_STATS["measured"] - before["measured"],
                winner=json.dumps(best, sort_keys=True))
        for k in SEARCH_STATS:
            obs.metrics.counter(f"autotune.{k}", op=op).inc(
                SEARCH_STATS[k] - before[k])
        if explains is not None:
            explains[key_for(op, N, kw.get("M", N), m, interp,
                             kw.get("shards", 1))] = exp
        if verbose:
            print(f"  -> {best}")
        return best

    for N, m in shapes:
        if verbose:
            print(f"[autotune] matrix N={N} m={m}")
        out[key_for("matrix", N, N, m, interp)] = swept(
            "matrix", N, m,
            lambda explain: autotune_matrix(
                N, m, interpret=interpret, verbose=verbose, explain=explain))
        if verbose:
            print(f"[autotune] one_vs_many N={N} m={m}")
        out[key_for("one_vs_many", N, N, m, interp)] = swept(
            "one_vs_many", N, m,
            lambda explain: autotune_one_vs_many(
                N, m, interpret=interpret, verbose=verbose, explain=explain))
        hot = max(8, N // 8)
        if verbose:
            print(f"[autotune] hybrid N={N} hot={hot} m={m}")
        out[key_for("hybrid", N, hot, m, interp)] = swept(
            "hybrid", N, m,
            lambda explain, hot=hot: autotune_hybrid(
                N, m, hot=hot, interpret=interpret, verbose=verbose,
                explain=explain),
            M=hot)
        for d in shard_counts:
            if d < 2 or N % d:
                continue
            if verbose:
                print(f"[autotune] matrix_sharded N={N} m={m} shards={d}")
            out[key_for("matrix_sharded", N, N, m, interp, d)] = swept(
                "matrix_sharded", N, m,
                lambda explain, d=d: autotune_matrix_sharded(
                    N, m, d, interpret=interpret, verbose=verbose,
                    explain=explain),
                shards=d)
    return out


def _is_interp() -> bool:
    import jax
    return jax.default_backend() != "tpu"


def _print_explain(explains: dict) -> str:
    """Human-readable predicted-vs-measured report; returns the text."""
    lines = []
    for key, exp in sorted(explains.items()):
        pred = exp.get("predicted", [])
        meas = exp.get("measured", [])
        lines.append(f"== {key} ==")
        if "grid" in exp:
            lines.append(
                f"   grid {exp['grid']} candidates -> "
                f"{exp['survivors']} measured "
                f"({exp['grid'] - exp['survivors']} pruned by cost model)")
        lines.append("   predicted ranking          | measured")
        n = max(len(pred), len(meas))
        for i in range(n):
            left = right = ""
            if i < len(pred):
                p = dict(pred[i])
                us = p.pop("pred_us")
                left = f"{_cfg_str(p)} ~{us/1e3:.1f}ms"
            if i < len(meas):
                r = dict(meas[i])
                us = r.pop("us")
                right = f"{_cfg_str(r)} {us/1e3:.1f}ms"
            lines.append(f"   {left:<27}| {right}")
        if meas:
            win = dict(meas[0])
            win.pop("us", None)
            ranked = [
                {k: v for k, v in dict(p).items() if k != "pred_us"}
                for p in pred]
            try:
                lines.append(
                    f"   measured winner predicted at rank "
                    f"{ranked.index(win) + 1}/{len(ranked)}")
            except ValueError:
                pass
    text = "\n".join(lines)
    print(text)
    return text


def _cfg_str(cfg: dict) -> str:
    return " ".join(f"{k}={v}" for k, v in sorted(cfg.items()))


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--sizes", nargs="*", default=["256x512", "1024x1024"],
                   help="NxM cell-slab shapes to sweep (peers x cells)")
    p.add_argument("--shards", nargs="*", type=int, default=[],
                   help="also tune ring-vs-replicated at these shard counts")
    p.add_argument("--write", action="store_true",
                   help="merge results into the autotune table on disk")
    p.add_argument("--out", type=Path, default=None)
    p.add_argument("--explain", action="store_true",
                   help="print the cost model's predicted ranking next to "
                        "the measured winner for every (op, shape bucket)")
    p.add_argument("--explain-out", type=Path, default=None,
                   help="also write the --explain report to this file")
    p.add_argument("--trace-dir", type=Path, default=None,
                   help="record autotune.sweep spans + search counters "
                        "through a repro.obs Observer into this directory")
    args = p.parse_args(argv)
    shapes = [tuple(int(v) for v in s.split("x")) for s in args.sizes]

    observer = None
    if args.trace_dir is not None:
        from repro.obs import Observer
        observer = Observer.to_dir(args.trace_dir)
    explains: dict | None = {} if (args.explain or args.explain_out) else None
    results = autotune_shapes(shapes, shard_counts=tuple(args.shards),
                              verbose=True, observer=observer,
                              explains=explains)
    if observer is not None:
        observer.close()
    if explains is not None:
        text = _print_explain(explains)
        if args.explain_out is not None:
            args.explain_out.write_text(text + "\n")
    if args.write:
        table = dict(load_table())
        table.update(results)
        path = save_table(table, args.out)
        print(f"wrote {len(results)} entries -> {path}")
    else:
        print(json.dumps(results, indent=1, sort_keys=True))


if __name__ == "__main__":
    main()
