"""Measured block-shape selection for the bulk comparison kernels.

The right (engine, bi, bj, bm, bn) for ``compare_matrix`` /
``classify_vs_many`` depends on the machine: interpret mode on CPU wants
few, cache-sized grid steps; a real TPU wants every working set inside
VMEM and, for narrow §4 windows, the MXU thermometer engine whose FLOPs
scale with the value span.  Hardcoded defaults cannot satisfy both, so
this module runs a measured sweep over a candidate space filtered by a
VMEM-fit model and caches the winners in a JSON table keyed by

    op | backend | N-bucket | M-bucket | m-bucket

(shape buckets are powers of two, rounded up, so one sweep covers a
band of nearby shapes).  ``kernels.ops`` consults ``lookup`` on every
call and falls back to conservative per-backend defaults when the table
has no entry.  Regenerate the shipped table with

    PYTHONPATH=src python -m repro.kernels.autotune --write

which sweeps the standard shapes on the current machine and rewrites
``autotune_table.json`` next to this file (or ``--out PATH`` /
``$REPRO_AUTOTUNE_TABLE`` for a private table).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from pathlib import Path

__all__ = [
    "lookup",
    "autotune_matrix",
    "autotune_one_vs_many",
    "table_path",
    "load_table",
    "save_table",
]

_DEFAULT_TABLE = Path(__file__).parent / "autotune_table.json"
_ENV = "REPRO_AUTOTUNE_TABLE"

# VMEM-fit model budgets (bytes).  Interpret mode has no VMEM, but the
# same model bounds host scratch so sweeps stay sane.
_VMEM_BUDGET = {"tpu": 12 * 2**20, "interpret": 512 * 2**20}

_table_cache: dict | None = None
_table_cache_path: str | None = None


def table_path() -> Path:
    return Path(os.environ.get(_ENV, _DEFAULT_TABLE))


def load_table() -> dict:
    global _table_cache, _table_cache_path
    path = table_path()
    if _table_cache is not None and _table_cache_path == str(path):
        return _table_cache
    try:
        with open(path) as f:
            _table_cache = json.load(f)
    except (OSError, ValueError):
        _table_cache = {}
    _table_cache_path = str(path)
    return _table_cache


def save_table(table: dict, path: Path | None = None) -> Path:
    global _table_cache, _table_cache_path
    path = path or table_path()
    with open(path, "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
        f.write("\n")
    _table_cache, _table_cache_path = table, str(path)
    return path


def _bucket(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


def _backend(interpret: bool) -> str:
    return "interpret" if interpret else "tpu"


def key_for(op: str, N: int, M: int, m: int, interpret: bool) -> str:
    return f"{op}|{_backend(interpret)}|N{_bucket(N)}|M{_bucket(M)}|m{_bucket(m)}"


# running hit/miss tally for the measured-table consults; the obs
# metrics layer snapshots this around each front-door dispatch
CACHE_STATS = {"hit": 0, "miss": 0}


def lookup(op: str, N: int, M: int, m: int, interpret: bool) -> dict | None:
    """Best known config for this op/shape band, or None."""
    cfg = load_table().get(key_for(op, N, M, m, interpret))
    CACHE_STATS["hit" if cfg is not None else "miss"] += 1
    return cfg


# ---------------------------------------------------------------------------
# VMEM-fit model
# ---------------------------------------------------------------------------

def vmem_bytes(engine: str, bi: int, bj: int, bm: int,
               n_thresholds: int = 0) -> int:
    """Peak per-step working set of one grid step of a matrix engine."""
    if engine == "mxu":
        enc = (bi + bj) * bm * n_thresholds * 4      # f32 thermometer codes
        return enc + (bi + bj) * bm + bi * bj * 4
    if engine in ("tri", "full"):
        d = bi * bj * bm * 2                         # int16 difference
        return d + (bi + bj) * bm + 2 * bi * bj
    if engine == "i32":
        d = bi * bj * bm                             # bool compares (x2 dirs)
        return 2 * d + (bi + bj) * bm * 4 + 3 * bi * bj * 4
    raise ValueError(engine)


def _fits(engine: str, bi: int, bj: int, bm: int, interpret: bool,
          n_thresholds: int = 0) -> bool:
    return vmem_bytes(engine, bi, bj, bm, n_thresholds) <= \
        _VMEM_BUDGET[_backend(interpret)]


# ---------------------------------------------------------------------------
# measured sweeps
# ---------------------------------------------------------------------------

def _divisor_blocks(size: int, want: tuple, mult: int) -> list:
    return [b for b in want if b % mult == 0 and b <= size and size % b == 0]


def _measure(fn, reps: int = 3) -> float:
    import jax
    jax.block_until_ready(jax.tree.leaves(fn()))     # warm / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(jax.tree.leaves(fn()))
        best = min(best, time.perf_counter() - t0)
    return best


def _rand_packed(N: int, m: int, span: int, seed: int = 0):
    import jax.numpy as jnp
    import numpy as np
    rng = np.random.default_rng(seed)
    cells = jnp.asarray(rng.integers(0, span, (N, m)), jnp.uint8)
    base = jnp.zeros((N, 1), jnp.int32)
    return cells, base


def autotune_matrix(N: int, m: int, *, span: int = 30,
                    interpret: bool | None = None, verbose: bool = False):
    """Race matrix engines x block shapes at [N, m]; return best config."""
    import jax
    from repro.kernels import ops

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    cells, base = _rand_packed(N, m, span)
    cells_i32 = cells.astype("int32")

    candidates = []
    for bi in (8, 64, 128, 256):
        for bm in (128, 256, 512, 1024):
            if not (_divisor_blocks(N, (bi,), 8)
                    and _divisor_blocks(m, (bm,), 128)):
                continue
            steps = (N // bi) ** 2 * (m // bm)
            if interpret and steps > 2048:   # per-step overhead would drown it
                continue
            if _fits("tri", bi, bi, bm, interpret):
                candidates.append(("tri", bi, bi, bm))
            if _fits("i32", bi, bi, bm, interpret):
                candidates.append(("i32", bi, bi, bm))
            if span <= ops.MXU_SPAN_MAX and _fits(
                    "mxu", bi, bi, bm, interpret, n_thresholds=span):
                candidates.append(("mxu", bi, bi, bm))

    results = []
    for engine, bi, bj, bm in candidates:
        try:
            if engine == "i32":
                fn = lambda: ops._compare_matrix(
                    cells_i32, cells_i32, engine="i32",
                    bi=bi, bj=bj, bm=bm, interpret=interpret)
            else:
                fn = lambda: ops._compare_matrix_packed(
                    cells, base, engine=engine,
                    bi=bi, bj=bj, bm=bm, interpret=interpret)
            dt = _measure(fn)
        except Exception as e:            # candidate invalid on this backend
            if verbose:
                print(f"  matrix {engine} bi={bi} bm={bm}: FAILED {e}")
            continue
        results.append({"engine": engine, "bi": bi, "bj": bj, "bm": bm,
                        "us": dt * 1e6})
        if verbose:
            print(f"  matrix {engine} bi={bi} bj={bj} bm={bm}: {dt*1e3:.1f} ms")
    if not results:
        raise RuntimeError(f"no viable matrix candidates for N={N} m={m}")
    return min(results, key=lambda r: r["us"])


def autotune_one_vs_many(N: int, m: int, *, span: int = 30,
                         interpret: bool | None = None,
                         verbose: bool = False):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    cells, base = _rand_packed(N, m, span)
    q = cells[0].astype(jnp.int32)

    results = []
    for bn in (8, 32, 128, 256):
        for bm in (256, 512, 1024):
            if not (_divisor_blocks(N, (bn,), 8)
                    and _divisor_blocks(m, (bm,), 128)):
                continue
            try:
                dt = _measure(lambda: ops._classify_vs_many_packed(
                    q, cells, base, bn=bn, bm=bm, interpret=interpret))
            except Exception:
                continue
            results.append({"engine": "packed", "bn": bn, "bm": bm,
                            "us": dt * 1e6})
            if verbose:
                print(f"  one_vs_many bn={bn} bm={bm}: {dt*1e3:.2f} ms")
    if not results:
        raise RuntimeError(f"no viable one_vs_many candidates N={N} m={m}")
    return min(results, key=lambda r: r["us"])


def autotune_shapes(shapes, *, interpret: bool | None = None,
                    verbose: bool = False) -> dict:
    """Sweep (N, m) shapes; returns {table_key: best_config}."""
    out = {}
    for N, m in shapes:
        if verbose:
            print(f"[autotune] matrix N={N} m={m}")
        best = autotune_matrix(N, m, interpret=interpret, verbose=verbose)
        out[key_for("matrix", N, N, m, interpret
                    if interpret is not None else _is_interp())] = best
        if verbose:
            print(f"  -> {best}")
            print(f"[autotune] one_vs_many N={N} m={m}")
        best = autotune_one_vs_many(N, m, interpret=interpret, verbose=verbose)
        out[key_for("one_vs_many", N, N, m, interpret
                    if interpret is not None else _is_interp())] = best
        if verbose:
            print(f"  -> {best}")
    return out


def _is_interp() -> bool:
    import jax
    return jax.default_backend() != "tpu"


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--sizes", nargs="*", default=["256x512", "1024x1024"],
                   help="NxM cell-slab shapes to sweep (peers x cells)")
    p.add_argument("--write", action="store_true",
                   help="merge results into the autotune table on disk")
    p.add_argument("--out", type=Path, default=None)
    args = p.parse_args(argv)
    shapes = [tuple(int(v) for v in s.split("x")) for s in args.sizes]
    results = autotune_shapes(shapes, verbose=True)
    if args.write:
        table = dict(load_table())
        table.update(results)
        path = save_table(table, args.out)
        print(f"wrote {len(results)} entries -> {path}")
    else:
        print(json.dumps(results, indent=1, sort_keys=True))


if __name__ == "__main__":
    main()
