"""Pallas kernel layer for the bloom-clock hot paths.

- ``bloom_tick``     batched event recording (scatter-add per probe)
- ``bloom_compare``  fused pairwise merge + compare + Eq. 3 fp
- ``bloom_matrix``   one-vs-many and N x N comparison engines, including
                     the packed-u8 triangle sweep and the MXU
                     (dot_general thermometer) dominance reduction
- ``pack``           quantized slab layout: u8 window residuals + base
- ``autotune``       measured block-shape/engine table the wrappers use
- ``ops``            padded/dispatched wrappers — the engine room of the
                     ``repro.causal.CausalEngine`` front-door (the old
                     public comparison names remain as deprecation shims)
- ``ref``            pure-jnp oracles for tests
"""
