"""Quantized slab packing: int32 bloom-clock cells <-> u8 residuals + base.

The paper's §4 observation is that within a moving window the cells of a
bloom clock stay within a byte of each other, so a slab of N peer clocks
does not need N * m * 4 bytes: store per row the minimum logical value
(``base``, one int32 lane per slot) and the residuals ``cells - base``
as u8.  That cuts HBM traffic and VMEM footprint of every bulk compare
4x, which is exactly what the comparison kernels are bound by.

Packing is *lossless or refused*: a row whose residual span exceeds
``U8_MAX`` cannot be represented and is reported via the ``ok`` mask so
the caller can promote it (keep it int32) instead of silently clipping.
``repro.fleet.ClockRegistry`` uses that mask to keep a small int32
side-store for promoted rows; everything else stays packed.

All functions are jitted and shape-generic ([N, m] slabs or single [m]
rows via ``pack_rows(x[None])``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["U8_MAX", "pack_rows", "unpack_rows", "rows_fit_u8"]

U8_MAX = 255


@jax.jit
def pack_rows(cells: jax.Array, base: jax.Array | None = None):
    """Pack int32 logical rows into (residuals u8, base i32, ok bool).

    cells: [N, m] int32 residual-or-logical cell values.
    base:  [N] int32 offset already applied to ``cells`` (None = zeros).

    Per row the minimum is lifted into the base (§4 compression), so the
    returned residuals always have ``min == 0``.  ``ok[i]`` is False
    when the row's span exceeds U8_MAX; its residuals are clipped and
    MUST NOT be used — the caller promotes such rows.
    """
    cells = jnp.asarray(cells, jnp.int32)
    if base is None:
        base = jnp.zeros(cells.shape[:-1], jnp.int32)
    mn = jnp.min(cells, axis=-1)
    span = jnp.max(cells, axis=-1) - mn
    resid = cells - mn[..., None]
    packed = jnp.clip(resid, 0, U8_MAX).astype(jnp.uint8)
    return packed, base + mn, span <= U8_MAX


@jax.jit
def unpack_rows(packed: jax.Array, base: jax.Array) -> jax.Array:
    """Inverse of ``pack_rows``: materialize int32 logical cells."""
    return packed.astype(jnp.int32) + jnp.asarray(base, jnp.int32)[..., None]


@jax.jit
def rows_fit_u8(cells: jax.Array) -> jax.Array:
    """[N] bool: can each int32 row be packed losslessly?"""
    cells = jnp.asarray(cells, jnp.int32)
    return (jnp.max(cells, axis=-1) - jnp.min(cells, axis=-1)) <= U8_MAX
