"""Pallas TPU kernel: batched bloom-clock tick (scatter-free increment).

GPU formulation of a counting-bloom insert is k atomic scatter-adds per
event — hostile to TPU (no fast scatter; serialized DMA).  TPU-native
adaptation: the probe indices are precomputed on the VPU (cheap integer
mixing, see ``repro.core.hashing``) and the increment becomes a dense
one-hot accumulation per (batch, m)-tile:

    inc[b, c] = Σ_p  [probe[b, p] == c]

i.e. an iota-compare + reduction over the probe axis, fully vectorized,
with m padded to the 128-lane boundary.  Each m-tile sees the full probe
row, so the grid is embarrassingly parallel (no cross-tile accumulation,
no revisiting).

Block layout (VMEM per grid step, defaults bb=8, bm=512, P<=1024):
    cells tile   bb x bm   int32   16 KiB
    probe tile   bb x P    int32   32 KiB
    match cube   bb x P x bm bool  (register/VPU temporary, streamed)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bloom_tick_kernel", "bloom_tick_pallas"]


def bloom_tick_kernel(probe_ref, cells_ref, out_ref, *, bm: int):
    """One (batch-tile, m-tile) grid step."""
    j = pl.program_id(1)
    probes = probe_ref[...]                      # [bb, P] int32 global cell ids
    cells = cells_ref[...]                       # [bb, bm]
    col0 = j * bm
    # local column ids of this m-tile, as a [1, bm] row for broadcasting
    cols = col0 + jax.lax.broadcasted_iota(jnp.int32, (1, bm), 1)
    # [bb, P, bm]: does probe p hit column c of this tile?
    match = probes[:, :, None] == cols[None, :, :]
    # accumulate in int32 regardless of cell dtype (16-bit cells would
    # otherwise reject the mixed-dtype store), cast back on the way out
    inc = jnp.sum(match.astype(jnp.int32), axis=1)  # [bb, bm]
    out_ref[...] = (cells.astype(jnp.int32) + inc).astype(cells.dtype)


@functools.partial(jax.jit, static_argnames=("bb", "bm", "interpret"))
def bloom_tick_pallas(
    cells: jax.Array,       # [B, m] int32 (m % bm == 0, B % bb == 0: caller pads)
    probes: jax.Array,      # [B, P] int32 global cell indices in [0, m)
    *,
    bb: int = 8,
    bm: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, m = cells.shape
    _, P = probes.shape
    assert m % bm == 0 and B % bb == 0, (B, m, bb, bm)
    grid = (B // bb, m // bm)
    return pl.pallas_call(
        functools.partial(bloom_tick_kernel, bm=bm),
        grid=grid,
        in_specs=[
            # every m-tile needs the full probe row of its batch tile
            pl.BlockSpec((bb, P), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, m), cells.dtype),
        interpret=interpret,
    )(probes, cells)
