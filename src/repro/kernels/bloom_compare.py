"""Pallas TPU kernel: fused bloom-clock merge + compare + Eq. 3 fp rate.

The runtime's receive path (§3 step 3) needs, per message:
    merged   = max(A, B)                  (the new clock)
    a_le_b   = all(A <= B)                (dominance -> ordering claim)
    b_le_a   = all(B <= A)
    ΣA, ΣB                                (Eq. 3 inputs)
    fp_ab, fp_ba                          (Eq. 3 both directions)

Done naively that is 5 separate HBM passes over the two cell arrays; all
of them are trivially byte-bound, so fusing them into ONE read of each
operand tile is a straight bandwidth win (~5x).  The m axis is tiled and
reduced with the revisited-output accumulation pattern: flags and sums
accumulate across m-tiles, and the fp rates are finalized with
log1p/expm1-stable math on the last tile.

Grid: (B/bb, m/bm); the second axis revisits the per-batch outputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bloom_compare_kernel", "bloom_merge_compare_pallas"]


def bloom_compare_kernel(
    a_ref, b_ref,
    merged_ref, flags_ref, sums_ref, fp_ref,
    *, n_mtiles: int, m: int,
):
    j = pl.program_id(1)
    a = a_ref[...]            # [bb, bm] int32
    b = b_ref[...]

    merged_ref[...] = jnp.maximum(a, b)

    # tile-local reductions (keep 2D: [bb, 1])
    le = jnp.all(a <= b, axis=1, keepdims=True)
    ge = jnp.all(a >= b, axis=1, keepdims=True)
    sa = jnp.sum(a, axis=1, keepdims=True).astype(jnp.float32)
    sb = jnp.sum(b, axis=1, keepdims=True).astype(jnp.float32)

    @pl.when(j == 0)
    def _init():
        flags_ref[...] = jnp.concatenate([le, ge], axis=1).astype(jnp.int32)
        sums_ref[...] = jnp.concatenate([sa, sb], axis=1)

    @pl.when(j > 0)
    def _acc():
        prev_flags = flags_ref[...]
        cur = jnp.concatenate([le, ge], axis=1).astype(jnp.int32)
        flags_ref[...] = prev_flags & cur
        sums_ref[...] = sums_ref[...] + jnp.concatenate([sa, sb], axis=1)

    @pl.when(j == n_mtiles - 1)
    def _finalize():
        s = sums_ref[...]                     # [bb, 2] total ΣA, ΣB
        log_q = jnp.log1p(-1.0 / m)
        # fp(x_sum over y_sum) = exp(x * log(-expm1(y * log_q)))
        inner_b = jnp.clip(-jnp.expm1(s[:, 1:2] * log_q), 1e-30, 1.0)
        inner_a = jnp.clip(-jnp.expm1(s[:, 0:1] * log_q), 1e-30, 1.0)
        fp_ab = jnp.exp(s[:, 0:1] * jnp.log(inner_b))   # P(A ⊆ B by chance)
        fp_ba = jnp.exp(s[:, 1:2] * jnp.log(inner_a))
        fp_ref[...] = jnp.concatenate([fp_ab, fp_ba], axis=1)


@functools.partial(jax.jit, static_argnames=("bb", "bm", "m_true", "interpret"))
def bloom_merge_compare_pallas(
    a: jax.Array,   # [B, m] int32, padded: m % bm == 0, B % bb == 0
    b: jax.Array,
    *,
    bb: int = 8,
    bm: int = 512,
    m_true: int | None = None,   # Eq. 3 uses the un-padded cell count
    interpret: bool = False,
):
    B, m = a.shape
    assert a.shape == b.shape and m % bm == 0 and B % bb == 0
    n_mtiles = m // bm
    grid = (B // bb, n_mtiles)
    kernel = functools.partial(
        bloom_compare_kernel, n_mtiles=n_mtiles, m=m_true if m_true else m
    )
    merged, flags, sums, fp = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
            pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
            # per-batch reductions: revisited across j
            pl.BlockSpec((bb, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, 2), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, m), a.dtype),
            jax.ShapeDtypeStruct((B, 2), jnp.int32),
            jax.ShapeDtypeStruct((B, 2), jnp.float32),
            jax.ShapeDtypeStruct((B, 2), jnp.float32),
        ],
        interpret=interpret,
    )(a, b)
    return merged, flags, sums, fp
