"""Named engine instances emitted from the compare-kernel template.

This module is the ONLY place the seven production engines are defined:
each public function below builds a ``CompareSpec`` from its knobs and
calls ``template.emit`` — there are no hand-rolled kernel bodies left
anywhere in the tree.  Signatures are byte-for-byte the ones the old
``bloom_matrix`` wrappers exposed, and every instance is pinned
bit-identical to its pre-refactor kernel by ``tests/test_template.py``
(which carries verbatim copies of the deleted bodies as references).

``ENGINE_SPECS`` names the default spec behind each instance — the
autotuner sweeps neighborhoods of these points, and docs/tests introspect
it instead of reverse-engineering knob defaults from call sites.
"""
from __future__ import annotations

import jax

from repro.kernels.template import CompareSpec, emit

__all__ = [
    "ENGINE_SPECS",
    "bloom_one_vs_many_pallas",
    "bloom_one_vs_many_packed_pallas",
    "bloom_matrix_pallas",
    "bloom_matrix_tri_pallas",
    "bloom_matrix_packed_pallas",
    "bloom_matrix_mxu_pallas",
    "bloom_hybrid_classify_pallas",
]

# the template point each named engine is an instance of (default blocks)
ENGINE_SPECS = {
    "one_vs_many_i32": CompareSpec(
        topology="one_vs_many", pack="i32", bi=8, bm=512, with_stats=True),
    "one_vs_many_packed": CompareSpec(
        topology="one_vs_many", pack="u8", bi=8, bm=512,
        with_base=True, with_stats=True),
    "matrix_i32_stats": CompareSpec(
        topology="rect", pack="i32", bi=8, bj=128, bm=512, with_stats=True),
    "matrix_tri": CompareSpec(topology="tri", pack="u8", bi=128, bm=512),
    "matrix_rect": CompareSpec(
        topology="rect", pack="u8", bi=128, bj=128, bm=512),
    "matrix_mxu": CompareSpec(
        topology="mxu", pack="u8", bi=128, bj=128, bm=128,
        with_base=True, n_thresholds=64),
    "hybrid_one_vs_many": CompareSpec(
        topology="hybrid", pack="u8", bi=8, bm=512,
        with_base=True, with_stats=True),
}


def bloom_one_vs_many_pallas(
    q: jax.Array,        # [1, m] int32, padded: m % bm == 0
    peers: jax.Array,    # [N, m] int32, N % bn == 0
    *,
    bn: int = 8,
    bm: int = 512,
    m_true: int | None = None,
    interpret: bool = False,
):
    """One-vs-many classify (int32 peers): per-peer flags, sums, Eq. 3 fp."""
    fn = emit(CompareSpec(topology="one_vs_many", pack="i32",
                          bi=bn, bm=bm, with_stats=True))
    return fn(q, peers, m_true=m_true, interpret=interpret)


def bloom_one_vs_many_packed_pallas(
    q: jax.Array,        # [1, m] int32 logical query, zero-padded
    peers: jax.Array,    # [N, m] uint8 residual slab, N % bn == 0
    base: jax.Array,     # [N, 1] int32 per-slot offsets
    *,
    bn: int = 8,
    bm: int = 512,
    m_true: int | None = None,
    interpret: bool = False,
):
    """One-vs-many classify against a PACKED peer slab (u8 HBM reads)."""
    fn = emit(CompareSpec(topology="one_vs_many", pack="u8",
                          bi=bn, bm=bm, with_base=True, with_stats=True))
    return fn(q, peers, base, m_true=m_true, interpret=interpret)


def bloom_matrix_pallas(
    rows: jax.Array,       # [N, m] int32, padded: N % bi == 0, m % bm == 0
    cols: jax.Array,       # [M, m] int32, M % bj == 0
    col_sums: jax.Array,   # [1, M] float32 total increments per column clock
    *,
    bi: int = 8,
    bj: int = 128,
    bm: int = 512,
    m_true: int | None = None,
    interpret: bool = False,
):
    """Tiled all-pairs int32 compare with in-kernel sums + Eq. 3 fp."""
    fn = emit(CompareSpec(topology="rect", pack="i32",
                          bi=bi, bj=bj, bm=bm, with_stats=True))
    return fn(rows, cols, col_sums, m_true=m_true, interpret=interpret)


def bloom_matrix_tri_pallas(
    cells: jax.Array,      # [N, m] uint8 residuals, N % bi == 0, m % bm == 0
    base: jax.Array,       # [N, 1] int32 per-slot window offsets
    *,
    bi: int = 128,
    bm: int = 512,
    m_true: int | None = None,
    with_base: bool = False,
    interpret: bool = False,
):
    """Symmetric all-pairs compare over one packed slab (upper triangle)."""
    fn = emit(CompareSpec(topology="tri", pack="u8",
                          bi=bi, bj=bi, bm=bm, with_base=with_base))
    return fn(cells, base, m_true=m_true, interpret=interpret)


def bloom_matrix_packed_pallas(
    rows: jax.Array,       # [N, m] uint8, N % bi == 0, m % bm == 0
    cols: jax.Array,       # [M, m] uint8, M % bj == 0
    row_base: jax.Array,   # [N, 1] int32
    col_base: jax.Array,   # [M, 1] int32
    *,
    bi: int = 128,
    bj: int = 128,
    bm: int = 512,
    m_true: int | None = None,
    with_base: bool = False,
    interpret: bool = False,
):
    """Full-rectangle packed compare: (le, ge) int8 [N, M]."""
    fn = emit(CompareSpec(topology="rect", pack="u8",
                          bi=bi, bj=bj, bm=bm, with_base=with_base))
    return fn(rows, cols, row_base, col_base,
              m_true=m_true, interpret=interpret)


def bloom_matrix_mxu_pallas(
    rows: jax.Array,       # [N, m] uint8
    cols: jax.Array,       # [M, m] uint8
    row_base: jax.Array,   # [N, 1] int32
    col_base: jax.Array,   # [M, 1] int32
    *,
    n_thresholds: int,     # static value-span budget T (window width)
    lo: int,               # static minimum logical value across both slabs
    bi: int = 128,
    bj: int = 128,
    bm: int = 128,
    m_true: int | None = None,
    interpret: bool = False,
):
    """MXU dominance reduction: violation counts via one dot_general."""
    fn = emit(CompareSpec(topology="mxu", pack="u8",
                          bi=bi, bj=bj, bm=bm, with_base=True,
                          n_thresholds=n_thresholds))
    return fn(rows, cols, row_base, col_base,
              lo=lo, m_true=m_true, interpret=interpret)


def bloom_hybrid_classify_pallas(
    q: jax.Array,          # [1, m] int32 logical query, zero-padded
    v_local: jax.Array,    # [1, 1] int32 local-chain version V
    hot_meta: jax.Array,   # [H, 2] int32 (v, n_private) per hot row
    hot_sums: jax.Array,   # [H, 1] float32 shadow-row total sums
    tail: jax.Array,       # [T, m] uint8 residual slab, T % bn == 0
    tail_base: jax.Array,  # [T, 1] int32 per-slot offsets
    *,
    bn: int = 8,
    bm: int = 512,
    m_true: int | None = None,
    interpret: bool = False,
):
    """Fused hot+tail classify: exact verdicts (fp ≡ 0) for the hot
    rows, packed one-vs-many bloom verdicts for the tail, one kernel."""
    fn = emit(CompareSpec(topology="hybrid", pack="u8",
                          bi=bn, bm=bm, with_base=True, with_stats=True))
    return fn(q, v_local, hot_meta, hot_sums, tail, tail_base,
              m_true=m_true, interpret=interpret)
