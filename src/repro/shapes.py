"""Assigned input shapes (one set, shared by all 10 LM-family archs).

- train_4k / prefill_32k lower full-sequence steps (train_step / prefill).
- decode_32k / long_500k lower ``serve_step``: ONE new token against a KV
  cache of seq_len.
- long_500k requires a sub-quadratic path: runs only for ssm/hybrid
  (mamba2-130m, hymba-1.5b); skipped for pure full-attention archs
  (documented in DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

__all__ = ["Shape", "SHAPES", "runnable", "cells"]


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str      # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}

# families with a sub-quadratic long-context path
_LONG_OK_FAMILIES = ("ssm", "hybrid")


def runnable(arch_family: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_family in _LONG_OK_FAMILIES
    return True


def cells(arch_names_families: dict) -> list:
    """All (arch, shape) cells incl. skip markers."""
    out = []
    for arch, fam in arch_names_families.items():
        for s in SHAPES:
            out.append((arch, s, runnable(fam, s)))
    return out
