"""stablelm-2-1_6b [dense] — 24L d=2048 32H (GQA kv=32) ff=5632 V=100352.

[hf:stabilityai/stablelm-2-1_6b; unverified]  LayerNorm + partial rotary
(25%), QKV bias, gated-SiLU MLP.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_head=64,
    d_ff=5632,
    vocab=100352,
    norm="layernorm",
    act="silu_glu",
    qkv_bias=True,
    rope_theta=10_000.0,
    rope_pct=0.25,
)

SMOKE = ModelConfig(
    name="stablelm-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab=512,
    norm="layernorm",
    act="silu_glu",
    qkv_bias=True,
    rope_pct=0.25,
    attn_chunk=64,
)
