"""deepseek-v2-236b [moe] — 60L d=5120 128H, MLA kv_lora=512, V=102400,
MoE 160 routed top-6 + 2 shared (expert ff=1536).

[arXiv:2405.04434; hf]  MLA: q_lora=1536, qk_nope=128, qk_rope=64,
v_head=128; decode uses the absorbed-matmul latent-cache path.
Deviation: the published model's layer 0 is dense (ff=12288); here all 60
layers are MoE so the stack scans homogeneously (DESIGN.md §5).
param_dtype bf16 + int8 optimizer state (giant-model memory policy).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_head=192,              # qk_nope + qk_rope (informational; MLA path)
    d_ff=12288,              # unused (all layers MoE); kept for reference
    vocab=102400,
    norm="rmsnorm",
    rope_theta=10_000.0,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1536,
    capacity_factor=1.25,
    param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="deepseek-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=48,
    d_ff=128,
    vocab=512,
    use_mla=True,
    kv_lora_rank=32,
    q_lora_rank=48,
    qk_nope_dim=32,
    qk_rope_dim=16,
    v_head_dim=32,
    n_experts=4,
    n_shared_experts=1,
    top_k=2,
    moe_d_ff=32,
    attn_chunk=64,
)
