"""hymba-1.5b [hybrid] — 32L d=1600 25H (GQA kv=5) ff=5504 V=32001,
parallel attn + mamba heads, ssm_state=16.

[arXiv:2411.13676; hf]  Sliding-window attention (2048) in all layers
except 3 global ones (first/middle/last); the SSM path runs in parallel
with attention in every layer, outputs fused with per-path RMS norms and
learned gains.  Deviations (DESIGN.md §5): no meta-tokens, no cross-layer
KV sharing.  Vocab padded 32001->32128.  Sub-quadratic (window + SSM):
runs long_500k with all attention layers windowed + ring KV buffers.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab=32001,
    vocab_pad=32128,
    norm="rmsnorm",
    rope_theta=10_000.0,
    window=2048,
    global_layers=(0, 15, 31),
    ssm_state=16,
    ssm_heads=50,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    ssm_conv=4,
)

SMOKE = ModelConfig(
    name="hymba-smoke",
    family="hybrid",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=512,
    window=16,
    global_layers=(0,),
    ssm_state=8,
    ssm_heads=4,
    ssm_head_dim=32,
    ssm_expand=2,
    ssm_chunk=16,
    ssm_conv=4,
    attn_chunk=32,
)
