"""whisper-large-v3 [audio] — enc-dec, 32L+32L d=1280 20H ff=5120 V=51866.

[arXiv:2212.04356; unverified]  The conv frontend is a STUB: input_specs()
feeds precomputed (1500, d_model) frame embeddings to the encoder.
LayerNorm, GELU MLP, learned decoder positions.  Vocab padded 51866->51968
(mesh divisibility); decoder max_seq raised for the decode_32k cell
(published model decodes <=448 tokens; deviation noted in DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    is_encdec=True,
    n_layers=32,
    n_enc_layers=32,
    enc_seq=1500,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_head=64,
    d_ff=5120,
    vocab=51866,
    vocab_pad=51968,
    norm="layernorm",
    act="gelu",
    qkv_bias=True,
    pos="learned",
    max_seq=40_960,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="encdec",
    is_encdec=True,
    n_layers=2,
    n_enc_layers=2,
    enc_seq=16,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab=512,
    norm="layernorm",
    act="gelu",
    qkv_bias=True,
    pos="learned",
    max_seq=256,
    attn_chunk=64,
)
