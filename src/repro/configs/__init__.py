"""Arch registry: the 10 assigned architectures + reduced smoke variants.

``get_config(name)`` returns the exact assigned config;
``get_smoke_config(name)`` returns a same-family reduced config that runs
a forward/train step on CPU in seconds.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig, validate

ARCHS = [
    "stablelm_1_6b",
    "qwen1_5_0_5b",
    "qwen1_5_110b",
    "granite_20b",
    "whisper_large_v3",
    "mamba2_130m",
    "deepseek_v2_236b",
    "grok_1_314b",
    "pixtral_12b",
    "hymba_1_5b",
]

# CLI ids (dashes) -> module names
ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def get_config(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.CONFIG
    validate(cfg)
    return cfg


def get_smoke_config(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.SMOKE
    validate(cfg)
    return cfg


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCHS}


def families() -> dict:
    return {a: get_config(a).family for a in ARCHS}
