"""granite-20b [dense/code] — 52L d=6144 48H (MQA kv=1) ff=24576 V=49152.

[arXiv:2405.04324; hf]  GPT-BigCode style: LayerNorm, learned absolute
positions, GELU 2-matrix MLP, multi-query attention, biases.
max_seq raised to 40960 so the assigned decode_32k cell (learned-pos
table lookup at position 32768) is well-defined — the published model
stops at 8192; deviation noted in DESIGN.md.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_head=128,
    d_ff=24576,
    vocab=49152,
    norm="layernorm",
    act="gelu",
    qkv_bias=True,
    pos="learned",
    max_seq=40_960,
)

SMOKE = ModelConfig(
    name="granite-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_head=16,
    d_ff=128,
    vocab=512,
    norm="layernorm",
    act="gelu",
    qkv_bias=True,
    pos="learned",
    max_seq=256,
    attn_chunk=64,
)
