"""pixtral-12b [vlm] — 40L d=5120 32H (GQA kv=8) ff=14336 V=131072.

[hf:mistralai/Pixtral-12B-2409; unverified]  Mistral-NeMo-style backbone
(head_dim 128 -> q width 4096 != d_model).  The pixtral ViT frontend is a
STUB: input_specs() provides 256 precomputed patch embeddings prepended to
the token stream (seq_len counts patches + text).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab=131072,
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    n_prefix=256,
)

SMOKE = ModelConfig(
    name="pixtral-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=512,
    n_prefix=4,
    attn_chunk=64,
)
