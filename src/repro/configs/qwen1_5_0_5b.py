"""qwen1.5-0.5b [dense] — 24L d=1024 16H (GQA kv=16) ff=2816 V=151936.

[hf:Qwen/Qwen1.5-0.5B; hf]  RMSNorm, QKV bias, rope theta 1e6 (32k ctx),
tied embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=2816,
    vocab=151936,
    norm="rmsnorm",
    act="silu_glu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen0.5-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab=512,
    qkv_bias=True,
    tie_embeddings=True,
    attn_chunk=64,
)
