"""qwen1.5-110b [dense] — 80L d=8192 64H (GQA kv=8) ff=49152 V=152064.

[hf:Qwen/Qwen1.5-110B; hf]  RMSNorm, QKV bias, rope theta 1e6.
param_dtype bf16 + int8 optimizer state (giant-model memory policy).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=49152,
    vocab=152064,
    norm="rmsnorm",
    act="silu_glu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="qwen110b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_head=16,
    d_ff=192,
    vocab=512,
    qkv_bias=True,
    attn_chunk=64,
)
