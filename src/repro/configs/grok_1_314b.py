"""grok-1-314b [moe] — 64L d=6144 48H (GQA kv=8) expert_ff=32768 V=131072,
MoE 8 experts top-2.

[hf:xai-org/grok-1; unverified]  RMSNorm, rope, logit softcap 30.
On a 16-wide model axis the 8 experts are replicated 2x (expert
replication, round-robin by token) so expert-parallel all_to_all stays
uniform; documented in DESIGN.md.  param_dtype bf16 + int8 opt state.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=32768,              # reference; experts use moe_d_ff
    vocab=131072,
    norm="rmsnorm",
    rope_theta=10_000.0,
    n_experts=8,
    n_shared_experts=0,
    top_k=2,
    moe_d_ff=32768,
    capacity_factor=1.25,
    logit_cap=30.0,
    param_dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="grok-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab=512,
    n_experts=4,
    n_shared_experts=0,
    top_k=2,
    moe_d_ff=64,
    logit_cap=30.0,
    attn_chunk=64,
)
