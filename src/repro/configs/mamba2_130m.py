"""mamba2-130m [ssm] — 24L d=768, attn-free, ssm_state=128, V=50280.

[arXiv:2405.21060; unverified]  Pure SSD stack (no MLP: d_ff=0), expand=2
-> d_inner=1536, head_dim=64 -> 24 ssm heads, conv width 4, tied
embeddings.  Vocab padded 50280->50304.  Sub-quadratic: runs long_500k.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=1,            # unused (attn-free)
    n_kv_heads=1,
    d_head=1,
    d_ff=0,
    vocab=50280,
    vocab_pad=50304,
    norm="rmsnorm",
    pos="none",
    tie_embeddings=True,
    ssm_state=128,
    ssm_heads=24,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    ssm_conv=4,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=1,
    n_kv_heads=1,
    d_head=1,
    d_ff=0,
    vocab=512,
    pos="none",
    tie_embeddings=True,
    ssm_state=16,
    ssm_heads=4,
    ssm_head_dim=32,
    ssm_expand=2,
    ssm_chunk=16,
    ssm_conv=4,
)
