"""AdamW with cosine schedule, global-norm clipping, and optional int8
block-quantized moments (the distributed-optimization trick that lets the
314B-param archs carry optimizer state on 16GB/chip meshes).

Pure-JAX (no optax in this environment): state is a pytree mirroring the
params, updates are functional.  Quantized moments store int8 codes plus a
per-block fp32 absmax scale (block = last-dim groups of 128), dequantized
on the fly inside the update — memory 4x smaller than fp32 moments at ~1e-2
relative quantization error, standard for large-scale setups.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt_state", "adamw_update", "cosine_lr"]

_BLOCK = 128


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"   # "float32" | "int8"


def cosine_lr(cfg: OptConfig, step) -> jax.Array:
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * jnp.clip(prog, 0.0, 1.0)))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, 0.1 + 0.9 * cos)


# --- int8 blockwise quantization ------------------------------------------

def _pad_len(n: int) -> int:
    return (-n) % _BLOCK


def _quantize(x: jax.Array):
    """fp32 [..., d] -> (int8 codes [..., d_pad], fp32 scales [..., d_pad/B])."""
    d = x.shape[-1]
    pad = _pad_len(d)
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]) if pad else x
    blocks = xp.reshape(xp.shape[:-1] + (-1, _BLOCK))
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    codes = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return codes.reshape(xp.shape), scale[..., 0]


def _dequantize(codes: jax.Array, scale: jax.Array, d: int):
    blocks = codes.reshape(codes.shape[:-1] + (-1, _BLOCK)).astype(jnp.float32)
    x = blocks * scale[..., None]
    return x.reshape(codes.shape)[..., :d]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Moment:
    """One quantized moment tensor."""

    codes: jax.Array
    scale: jax.Array
    d: int

    def tree_flatten(self):
        return (self.codes, self.scale), self.d

    @classmethod
    def tree_unflatten(cls, d, leaves):
        return cls(*leaves, d=d)

    def value(self) -> jax.Array:
        return _dequantize(self.codes, self.scale, self.d)

    @classmethod
    def of(cls, x: jax.Array) -> "Moment":
        codes, scale = _quantize(x)
        return cls(codes, scale, x.shape[-1])


def _zeros_like_moment(p: jax.Array, quantize: bool):
    if quantize and p.ndim >= 1 and p.shape[-1] >= _BLOCK:
        return Moment.of(jnp.zeros(p.shape, jnp.float32))
    return jnp.zeros(p.shape, jnp.float32)


def init_opt_state(params: dict, cfg: OptConfig) -> dict:
    q = cfg.state_dtype == "int8"
    return {
        "m": {k: _zeros_like_moment(v, q) for k, v in params.items()},
        "v": {k: _zeros_like_moment(v, q) for k, v in params.items()},
        "step": jnp.zeros((), jnp.int32),
    }


def _as_value(x):
    return x.value() if isinstance(x, Moment) else x


def _like(old, new_val: jax.Array):
    return Moment.of(new_val) if isinstance(old, Moment) else new_val


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(params: dict, grads: dict, state: dict, cfg: OptConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    new_params, new_m, new_v = {}, {}, {}
    for k, p in params.items():
        g = grads[k].astype(jnp.float32) * scale
        m = _as_value(state["m"][k])
        v = _as_value(state["v"][k])
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/biases exempt)
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_params[k] = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        new_m[k] = _like(state["m"][k], m)
        new_v[k] = _like(state["v"][k], v)

    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
