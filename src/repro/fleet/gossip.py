"""Anti-entropy gossip rounds over a ClockRegistry.

One round = what a node does when it wakes up and reconciles with its
view of the fleet, driven end-to-end by the fused kernels (no per-peer
Python on the hot path):

1. ``classify_all``: one device call classifies every peer against the
   local clock (lineage + Eq. 3 confidence).  A mesh-sharded registry
   runs it shard_map'ed over the row shards transparently — the round's
   policy and results are identical for every shard count.
2. policy, on [N] host vectors: FORKED peers are quarantined (their
   events diverged from ours — merging would launder a causality
   violation); stragglers (clock-sum gap above ``straggler_gap`` below
   the alive median) are skipped this round, not quarantined; remaining
   comparable peers with fp within ``fp_threshold`` are accepted.
3. one batched ``union`` merges the local clock with every accepted row
   (paper §3 receive rule, applied fleet-wide in a single max-reduce).
4. optional push-back: the merged union is broadcast into the accepted
   rows, modelling the outbound half of anti-entropy — after a round the
   accepted peers' registry rows equal the union, so a skipped straggler
   that later syncs catches up instead of lagging forever.  The row
   ships in §4 wire form — u8 residuals plus one base scalar (the
   registry slab itself is packed, see ``kernels.pack``) — so the
   outbound half costs ~4x less than an int32 row per peer;
   ``GossipReport.pushback_bytes`` records the modelled wire cost.

The whole round costs O(N * m / lanes) device work and a handful of
host<->device transfers independent of how many peers are accepted:
the view fetch, the merged clock, and (with push-back) the packed row's
scalar base + fits-u8 flag.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.causal import CausalPolicy
from repro.core import clock as bc
from repro.fleet import registry as reg

__all__ = ["GossipConfig", "GossipReport", "gossip_round"]


@dataclasses.dataclass(frozen=True)
class GossipConfig:
    fp_threshold: float = 1e-4    # Eq. 3 confidence gate for merges
    straggler_gap: float = 64.0   # clock-sum ticks below alive median
    push_back: bool = True        # write the union into accepted rows
    # the one source of truth when set: rounds gate on
    # ``policy.fp_threshold`` (overriding the scalar above), so a
    # runtime can thread its CausalPolicy straight through gossip
    policy: Optional[CausalPolicy] = None

    @property
    def fp_gate(self) -> float:
        return (self.policy.fp_threshold if self.policy is not None
                else self.fp_threshold)


@dataclasses.dataclass
class GossipReport:
    """Outcome masks of one round (numpy, [capacity])."""

    accepted: np.ndarray          # merged this round
    quarantined: np.ndarray       # FORKED -> excluded until resolved
    stragglers: np.ndarray        # skipped this round (not quarantined)
    unconfident: np.ndarray       # comparable but fp above threshold
    view: reg.FleetView           # the classification the round acted on
    pushback_bytes: int = 0       # wire cost of the outbound half (§4 form)
    shards: int = 1               # device shards the registry slab spans

    @property
    def n_accepted(self) -> int:
        return int(self.accepted.sum())

    def summary(self) -> str:
        return (
            f"accepted={int(self.accepted.sum())} "
            f"quarantined={int(self.quarantined.sum())} "
            f"stragglers={int(self.stragglers.sum())} "
            f"unconfident={int(self.unconfident.sum())} "
            f"alive={int(self.view.alive.sum())}"
        )


def gossip_round(
    registry: reg.ClockRegistry,
    local: bc.BloomClock,
    cfg: GossipConfig = GossipConfig(),
) -> tuple[bc.BloomClock, GossipReport]:
    """Run one anti-entropy round; returns (merged local clock, report)."""
    view = registry.classify_all(local)
    alive = view.alive

    quarantined = alive & (view.status == reg.FORKED)

    stragglers = np.zeros_like(alive)
    if alive.any():
        med = float(np.median(view.sums[alive]))
        stragglers = alive & ~quarantined & (
            (med - view.sums) > cfg.straggler_gap)

    comparable = alive & ~quarantined & ~stragglers
    unconfident = comparable & ~view.confident(cfg.fp_gate)
    accepted = comparable & ~unconfident

    merged = local
    pushback_bytes = 0
    if accepted.any():
        merged = registry.union(accepted, local)
        merged = bc.compress(merged)
        if cfg.push_back:
            shipped_packed = registry.broadcast(accepted, merged)
            # u8 residuals + int32 base per accepted peer when the row
            # packs; int32 cells otherwise (promoted-row fallback)
            cell_bytes = registry.m * (1 if shipped_packed else 4)
            pushback_bytes = int(accepted.sum()) * (cell_bytes + 4)

    return merged, GossipReport(
        accepted=accepted,
        quarantined=quarantined,
        stragglers=stragglers,
        unconfident=unconfident,
        view=view,
        pushback_bytes=pushback_bytes,
        shards=registry.n_shards,
    )
