"""Anti-entropy gossip: config, report, and the loopback round.

One round = what a node does when it wakes up and reconciles with its
view of the fleet.  The protocol itself — digest exchange → classify
via the ``CausalEngine`` → delta pull of §4 wire rows → one batched
union merge → push-back — lives in ``fleet.transport.session`` and is
parameterized by a :class:`~repro.fleet.transport.Transport`:

- ``LoopbackTransport``        the local registry slab is the fleet
  (this module's ``gossip_round`` — the original single-process round,
  bit-identical masks / merged cells / Eq. 3 fp bits);
- ``MeshCollectiveTransport``  a mesh-sharded registry whose digest
  exchange runs as a ``ppermute`` ring over the fleet axis — row shards
  never round-trip through the host;
- ``SocketTransport``          real processes exchanging length-prefixed
  ``core.wire`` frames over TCP.

The round's policy, on [N] host vectors: FORKED peers are quarantined
(their events diverged from ours — merging would launder a causality
violation); stragglers (clock-sum gap above ``straggler_gap`` below the
alive median) are skipped this round, not quarantined; remaining
comparable peers with Eq. 3 fp within the policy gate are accepted and
merged in ONE batched union (paper §3 receive rule fleet-wide).  With
push-back, the union ships back to every accepted peer in §4 wire form;
``GossipReport`` records the MEASURED frame bytes of each phase, not a
model estimate.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional

import numpy as np

from repro.causal import CausalPolicy
from repro.core import clock as bc
from repro.fleet import registry as reg

__all__ = ["GossipConfig", "GossipReport", "gossip_round"]

_FP_DEFAULT = 1e-4


@dataclasses.dataclass(frozen=True)
class GossipConfig:
    # DEPRECATED: pass ``policy=CausalPolicy(fp_threshold=...)`` instead.
    # The scalar duplicated the policy's gate; it keeps working (and
    # still wins when no policy is set) but warns on explicit use.
    fp_threshold: Optional[float] = None
    straggler_gap: float = 64.0   # clock-sum ticks below alive median
    push_back: bool = True        # write the union into accepted rows
    # the one source of truth when set: rounds gate on
    # ``policy.fp_threshold``, so a runtime threads its CausalPolicy
    # straight through gossip
    policy: Optional[CausalPolicy] = None
    # instrumentation override for this config; sessions fall back to
    # ``policy.observer`` and then the registry's policy when None
    observer: Any = None
    # self-stabilization: verify every alive registry row against its
    # recorded CRC at the top of each session; corrupted rows are
    # quarantined and (on a non-authoritative fabric) repaired by
    # forcing the delta phase to re-pull them from any peer whose
    # digest covers the row
    verify_rows: bool = False
    # paper §3 pure receive rule: merge FORKED (concurrent) peers too
    # instead of quarantining them.  Quarantine treats a fork as replica
    # divergence to investigate; a gossip fleet whose nodes legitimately
    # tick concurrently (the chaos/convergence harness) needs forks to
    # MERGE or concurrent peers could never reconverge.
    merge_forked: bool = False

    def __post_init__(self):
        if self.fp_threshold is not None:
            warnings.warn(
                "GossipConfig.fp_threshold is deprecated; pass "
                "policy=CausalPolicy(fp_threshold=...) — the policy is "
                "the one source of truth for the Eq. 3 gate",
                DeprecationWarning, stacklevel=3)

    @property
    def fp_gate(self) -> float:
        if self.policy is not None:
            return self.policy.fp_threshold
        return _FP_DEFAULT if self.fp_threshold is None else self.fp_threshold


@dataclasses.dataclass
class GossipReport:
    """Outcome masks of one round (numpy, [capacity]) + measured wire."""

    accepted: np.ndarray          # merged this round
    quarantined: np.ndarray       # FORKED -> excluded until resolved
    stragglers: np.ndarray        # skipped this round (not quarantined)
    unconfident: np.ndarray       # comparable but fp above threshold
    view: reg.FleetView           # the classification the round acted on
    pushback_bytes: int = 0       # MEASURED outbound frame bytes (§4 form)
    digest_bytes: int = 0         # MEASURED inbound digest-exchange bytes
    delta_bytes: int = 0          # MEASURED inbound delta-frame bytes
    transport: str = "loopback"   # fabric the session ran over
    shards: int = 1               # device shards the registry slab spans
    unreachable: tuple = ()       # peers skipped mid-session (socket)
    rejected: tuple = ()          # peers whose pulled frame failed decode
    corrupted: tuple = ()         # rows that failed the CRC integrity check
    repaired: tuple = ()          # corrupted rows re-pulled this session

    @property
    def n_accepted(self) -> int:
        return int(self.accepted.sum())

    @property
    def wire_bytes(self) -> int:
        """Total measured bytes this round moved over the fabric."""
        return self.digest_bytes + self.delta_bytes + self.pushback_bytes

    def summary(self) -> str:
        return (
            f"accepted={int(self.accepted.sum())} "
            f"quarantined={int(self.quarantined.sum())} "
            f"stragglers={int(self.stragglers.sum())} "
            f"unconfident={int(self.unconfident.sum())} "
            f"alive={int(self.view.alive.sum())} "
            f"wire={self.wire_bytes}B[{self.transport}]"
            + (f" unreachable={len(self.unreachable)}"
               if self.unreachable else "")
            + (f" rejected={len(self.rejected)}" if self.rejected else "")
            + (f" corrupted={len(self.corrupted)}"
               f" repaired={len(self.repaired)}" if self.corrupted else "")
        )


def gossip_round(
    registry: reg.ClockRegistry,
    local: bc.BloomClock,
    cfg: GossipConfig = GossipConfig(),
) -> tuple[bc.BloomClock, GossipReport]:
    """One anti-entropy round over the LOCAL registry slab.

    Loopback session: identical decision math to every other transport,
    with the peer rows already in the slab (no digest/delta traffic).
    Returns (merged local clock, report).
    """
    from repro.fleet.transport import LoopbackTransport
    from repro.fleet.transport.session import anti_entropy_session
    return anti_entropy_session(registry, local, LoopbackTransport(registry),
                                cfg)
