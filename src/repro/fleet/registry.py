"""ClockRegistry: a fixed-capacity quantized slab of peer bloom clocks.

The registry is the fleet-scale replacement for holding one
``BloomClock`` object per peer and comparing them one ``bool()`` at a
time.  Peer state lives in four device arrays — the §4 packed layout
(see ``repro.kernels.pack``):

    cells_u8 [N, m] uint8  window-relative residuals per slot
    base     [N]    int32  per-slot window offset (logical = base + u8)
    sums     [N]    f32    cached total increments (Eq. 3 inputs)
    alive    [N]    bool   liveness mask (evicted slots stay allocated)

u8 residuals cut slab memory and every kernel's HBM traffic 4x versus
the old int32 slab.  A row whose residual span cannot fit a byte is
**automatically promoted**: its int32 logical cells go to a small host
side-store and all bulk operations transparently fall back to a
materialized int32 slab until the row is overwritten with packable data
(or evicted).  Scatter, union and broadcast operate directly on
(u8, base) — no int32 round-trip on the packed path.

Slot assignment is host-side (a dict + free list); everything that
touches cell data is batched: ``admit_many`` / ``update_many`` are one
scatter each, ``classify_all`` is ONE device call through the packed
one-vs-many Pallas kernel, ``all_pairs`` gathers the alive rows and
runs the symmetric triangle kernel over them only (dead slots cost no
work and report all-False flags).

Status codes (``FleetView.status``) are small ints so a whole fleet's
classification is a single int8 vector:

    DEAD < 0: slot empty/evicted;  ANCESTOR: peer ≼ local;
    SAME: equal;  DESCENDANT: local ≼ peer;  FORKED: concurrent
    (exact — no false negatives, paper §3).

**Sharded mode** (``ClockRegistry(..., mesh=mesh, axis="fleet")``): the
slab arrays carry a row-sharded ``NamedSharding`` over one mesh axis —
``cells_u8`` lives as ``[N/d, m]`` per-device shards so a fleet can
outgrow any single device's memory.  ``classify_all`` becomes a
``shard_map``'d one-vs-many kernel (query replicated, zero cross-device
traffic) and ``all_pairs`` a block-row ring: each device circulates a
column shard via ``ppermute`` and fills its ``[N/d, N]`` block-row with
the packed full-rect engine.  Both paths are bit-identical to the
single-device packed engines for every shard count — the multi-device
harness (``tests/test_sharded_fleet.py``) enforces it.  Mutations
(admit / evict / update / union / broadcast) stay one batched device
call; XLA routes each scattered row to its owning shard and the result
is re-placed onto the registry's sharding.  Slot assignment remains a
host-side dict, so slot ``s`` deterministically lives on device
``s // (N / d)``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clock as bc
from repro.kernels import ops, pack
from repro.sharding import FLEET_AXIS, slab_shardings

__all__ = [
    "ClockRegistry",
    "FleetView",
    "DEAD",
    "ANCESTOR",
    "SAME",
    "DESCENDANT",
    "FORKED",
    "STATUS_NAMES",
]

DEAD = -1
ANCESTOR = 0
SAME = 1
DESCENDANT = 2
FORKED = 3

STATUS_NAMES = {
    DEAD: "dead",
    ANCESTOR: "ancestor",
    SAME: "same",
    DESCENDANT: "descendant",
    FORKED: "forked",
}


@dataclasses.dataclass
class FleetView:
    """Host-side result of one ``classify_all`` call (numpy, [capacity])."""

    status: np.ndarray        # int8 status code per slot
    fp: np.ndarray            # float32 Eq. 3 fp of the claimed direction
    sums: np.ndarray          # float32 cached clock sums
    alive: np.ndarray         # bool liveness mask
    local_sum: float          # the query clock's total increments

    def slots(self, code: int) -> np.ndarray:
        return np.flatnonzero(self.status == code)

    def counts(self) -> dict[str, int]:
        return {
            name: int(np.sum(self.status == code))
            for code, name in STATUS_NAMES.items()
        }


@jax.jit
def _scatter_rows(cells_u8, base, sums, alive, idx, new_u8, new_base, new_sums):
    cells_u8 = cells_u8.at[idx].set(new_u8)
    base = base.at[idx].set(new_base)
    sums = sums.at[idx].set(new_sums)
    alive = alive.at[idx].set(True)
    return cells_u8, base, sums, alive


@jax.jit
def _union_rows_packed(cells_u8, base, mask, local_cells):
    """max(local, max over masked logical rows); the widen fuses with the
    reduce, so the only slab read is the u8 residuals."""
    logical = cells_u8.astype(jnp.int32) + base[:, None]
    masked = jnp.where(mask[:, None], logical, 0)
    return jnp.maximum(local_cells, jnp.max(masked, axis=0))


@jax.jit
def _broadcast_rows(cells_u8, base, sums, mask, row_u8, row_base, row_sum):
    cells_u8 = jnp.where(mask[:, None], row_u8[None, :], cells_u8)
    base = jnp.where(mask, row_base, base)
    sums = jnp.where(mask, row_sum, sums)
    return cells_u8, base, sums


@jax.jit
def _materialize(cells_u8, base):
    return pack.unpack_rows(cells_u8, base)


class ClockRegistry:
    """Peer clock registry: one device slab, or mesh-sharded row shards."""

    def __init__(self, capacity: int, m: int, k: int = 4, *,
                 mesh=None, axis: str = FLEET_AXIS):
        self.capacity = capacity
        self.m = m
        self.k = k
        self.mesh = mesh
        self.axis = axis if mesh is not None else None
        if mesh is not None:
            shards = mesh.shape[axis]
            if capacity % shards:
                raise ValueError(
                    f"capacity {capacity} not divisible by mesh axis "
                    f"{axis!r} extent {shards}")
            self._slab_sharding, self._vec_sharding = slab_shardings(
                mesh, axis)
        else:
            self._slab_sharding = self._vec_sharding = None
        self.cells_u8 = self._place2d(jnp.zeros((capacity, m), jnp.uint8))
        self.base = self._place1d(jnp.zeros((capacity,), jnp.int32))
        self.sums = self._place1d(jnp.zeros((capacity,), jnp.float32))
        self.alive = self._place1d(jnp.zeros((capacity,), bool))
        self._alive_host = np.zeros(capacity, bool)
        self._base_host = np.zeros(capacity, np.int64)
        self._wide: dict[int, np.ndarray] = {}   # promoted int32 rows
        self._mat: jax.Array | None = None       # materialized i32 cache
        self._slot_of: dict = {}
        self._free: list[int] = list(range(capacity - 1, -1, -1))

    @property
    def n_shards(self) -> int:
        return 1 if self.mesh is None else self.mesh.shape[self.axis]

    def _place2d(self, x: jax.Array) -> jax.Array:
        """Pin a [N, m] slab to the registry's row sharding (no-op when
        unsharded).  Every mutation re-places its result so XLA's output
        placement choices never silently gather the slab."""
        return x if self._slab_sharding is None else jax.device_put(
            x, self._slab_sharding)

    def _place1d(self, x: jax.Array) -> jax.Array:
        return x if self._vec_sharding is None else jax.device_put(
            x, self._vec_sharding)

    # ---- membership ----
    def __len__(self) -> int:
        return len(self._slot_of)

    def __contains__(self, peer_id) -> bool:
        return peer_id in self._slot_of

    def slot_of(self, peer_id) -> int:
        return self._slot_of[peer_id]

    def peer_ids(self) -> list:
        return list(self._slot_of)

    @property
    def packed(self) -> bool:
        """True when every row is in the u8 fast-path representation."""
        return not self._wide

    @property
    def cells(self) -> jax.Array:
        """Materialized int32 logical cells (back-compat / debug view)."""
        return self._materialized()

    def _materialized(self) -> jax.Array:
        if self._mat is None:
            mat = _materialize(self.cells_u8, self.base)
            if self._wide:
                idx = jnp.asarray(sorted(self._wide), jnp.int32)
                rows = jnp.asarray(
                    np.stack([self._wide[s] for s in sorted(self._wide)]))
                mat = mat.at[idx].set(rows)
            self._mat = mat
        return self._mat

    def _uniform_base(self) -> bool:
        b = self._base_host[self._alive_host]
        return b.size == 0 or bool((b == b[0]).all())

    # ---- batched mutation ----
    def admit_many(self, peers: dict) -> dict:
        """Admit {peer_id: BloomClock}; one scatter for the whole batch.

        Re-admitting a known peer_id overwrites its row (re-spawned
        peers keep their slot).  Returns {peer_id: slot}.  Raises when
        capacity is exhausted.
        """
        if not peers:
            return {}
        fresh = [pid for pid in peers if pid not in self._slot_of]
        if len(fresh) > len(self._free):
            raise RuntimeError(
                f"registry full: {len(fresh)} admits, {len(self._free)} free slots")
        slots = {pid: (self._slot_of[pid] if pid in self._slot_of
                       else self._free.pop()) for pid in peers}
        self._slot_of.update(slots)
        self._write(list(slots.values()), list(peers.values()))
        return slots

    def admit(self, peer_id, clock: bc.BloomClock) -> int:
        return self.admit_many({peer_id: clock})[peer_id]

    def update_many(self, peers: dict) -> None:
        """Overwrite existing peers' rows; one scatter for the batch."""
        if not peers:
            return
        self._write([self._slot_of[pid] for pid in peers], list(peers.values()))

    def update(self, peer_id, clock: bc.BloomClock) -> None:
        self.update_many({peer_id: clock})

    def evict_many(self, peer_ids) -> None:
        peer_ids = list(dict.fromkeys(peer_ids))   # dedupe, keep order
        # resolve every slot BEFORE mutating: an unknown peer_id raises
        # with the registry untouched instead of half-evicted
        idx = [self._slot_of[pid] for pid in peer_ids]
        if not idx:
            return
        for pid in peer_ids:
            del self._slot_of[pid]
        self.alive = self._place1d(self.alive.at[jnp.asarray(idx)].set(False))
        self._alive_host[idx] = False
        for slot in idx:
            self._wide.pop(slot, None)
        self._free.extend(idx)

    def evict(self, peer_id) -> None:
        self.evict_many([peer_id])

    def _write(self, idx: list, clocks: list) -> None:
        logical = jnp.stack(
            [c.logical_cells().astype(jnp.int32) for c in clocks])
        new_sums = jnp.stack([bc.clock_sum(c) for c in clocks])
        new_u8, new_base, ok = pack.pack_rows(logical)
        cells_u8, base, sums, alive = _scatter_rows(
            self.cells_u8, self.base, self.sums, self.alive,
            jnp.asarray(idx), new_u8, new_base, new_sums)
        self.cells_u8 = self._place2d(cells_u8)
        self.base = self._place1d(base)
        self.sums = self._place1d(sums)
        self.alive = self._place1d(alive)
        ok_h = np.asarray(ok)
        self._base_host[idx] = np.asarray(new_base)
        self._alive_host[idx] = True
        for pos, slot in enumerate(idx):
            if ok_h[pos]:
                self._wide.pop(slot, None)     # demotion: row packs again
            else:                              # promotion: span > U8_MAX
                self._wide[slot] = np.asarray(logical[pos])
        self._mat = None

    def get(self, peer_id) -> bc.BloomClock:
        slot = self._slot_of[peer_id]
        if slot in self._wide:
            return bc.BloomClock(cells=jnp.asarray(self._wide[slot]),
                                 base=jnp.zeros((), jnp.int32), k=self.k)
        return bc.BloomClock(cells=self.cells_u8[slot].astype(jnp.int32),
                             base=self.base[slot], k=self.k)

    # ---- batched classification ----
    def classify_all(self, local: bc.BloomClock) -> FleetView:
        """Lineage status + Eq. 3 fp for EVERY slot in one device call.

        Direction convention matches ``ClockRuntime.lineage``: a peer
        that is ≼ the local clock is an ANCESTOR (its events are in the
        local past), a peer the local clock is ≼ is a DESCENDANT, and
        incomparable peers are FORKED (exact, §3).

        Sharded mode runs the shard_map'd packed kernel over the row
        shards (query replicated, no cross-device traffic).  Promoted
        rows never drop the slab to the int32 fallback anymore: the
        bulk stays packed and only the promoted handful is re-classified
        wide, then patched in (``ops.overlay_wide_classify``).
        """
        q = local.logical_cells().astype(jnp.int32)
        if self.mesh is not None:
            out = ops.classify_vs_many_packed_sharded(
                q, self.cells_u8, self.base, mesh=self.mesh, axis=self.axis)
        else:
            out = ops.classify_vs_many_packed(q, self.cells_u8, self.base)
        if self._wide:
            widx = sorted(self._wide)
            out = ops.overlay_wide_classify(
                out, q, widx,
                jnp.asarray(np.stack([self._wide[s] for s in widx])))
        h = jax.device_get(out)          # single host transfer for the dict
        alive = self._alive_host
        p_le_q = h["p_le_q"]
        q_le_p = h["q_le_p"]
        equal = p_le_q & q_le_p
        status = np.full(self.capacity, FORKED, np.int8)
        status[p_le_q] = ANCESTOR
        status[q_le_p] = DESCENDANT
        status[equal] = SAME
        status[~alive] = DEAD
        # fp of the direction actually claimed; SAME and FORKED are exact
        fp = np.where(p_le_q, h["fp_p_before_q"], h["fp_q_before_p"])
        fp = np.where(equal | ~(p_le_q | q_le_p), 0.0, fp).astype(np.float32)
        fp[~alive] = 0.0
        return FleetView(
            status=status,
            fp=fp,
            sums=h["sum_p"],
            alive=alive.copy(),
            local_sum=float(h["sum_q"]),
        )

    def all_pairs(self, **kw) -> dict:
        """Tiled all-pairs compare; dead slots report all-False flags
        and ``fp = row_sums = 0`` — no misleading verdicts from stale
        cells.

        Unsharded, fully-packed fleets gather the alive rows into a
        dense sub-slab (dead slots cost no compute) and sweep the
        symmetric triangle engine.  Sharded fleets run the block-row
        ``ppermute`` ring over the full capacity slab — even row shards
        beat gather-compaction across devices — and mask dead slots
        after.  Promoted rows no longer drop the whole slab to the
        int32 fallback: the O(N^2) bulk stays packed and only the
        promoted handful is compared wide (``_host_pairs``).
        """
        cap = self.capacity
        aidx = np.flatnonzero(self._alive_host)
        if aidx.size == 0:
            false = jnp.zeros((cap, cap), bool)
            return {
                "a_le_b": false, "b_le_a": false, "concurrent": false,
                "fp": jnp.zeros((cap, cap), jnp.float32),
                "row_sums": jnp.zeros((cap,), jnp.float32),
                "col_sums": jnp.zeros((cap,), jnp.float32),
            }
        if self.mesh is not None:
            bulk = ops.compare_matrix_packed_sharded(
                self.cells_u8, self.base, mesh=self.mesh, axis=self.axis,
                uniform_base=self._uniform_base(), **kw)
            if aidx.size == cap and self.packed:
                return bulk
            if not self.packed:
                # promoted rows: patch the O(P * A) int32 rim into the
                # bulk ON DEVICE — the [cap, cap] matrices stay sharded
                bulk = self._device_wide_overlay(bulk, aidx, **kw)
            # dead slots report nothing; masking is device-side too, so
            # a huge sharded fleet never materializes flags on host
            return _mask_dead_pairs(bulk, self.alive)
        if aidx.size == cap and self.packed:
            return ops.compare_matrix_packed(
                self.cells_u8, self.base,
                uniform_base=self._uniform_base(), **kw)
        if self.packed:
            jidx = jnp.asarray(aidx)
            sub = ops.compare_matrix_packed(
                jnp.take(self.cells_u8, jidx, axis=0),
                jnp.take(self.base, jidx),
                uniform_base=self._uniform_base(), **kw)
            return _expand_alive(sub, jidx, cap)
        return self._host_pairs(aidx, **kw)

    def _alive_widx(self, aidx: np.ndarray) -> np.ndarray:
        """Promoted slots restricted to the given alive index set."""
        keep = set(int(s) for s in aidx)
        return np.asarray(
            sorted(s for s in self._wide if s in keep), np.int64)

    def _wide_rim(self, aidx: np.ndarray, widx: np.ndarray, **kw) -> dict:
        """Exact int32 compare of the promoted rows vs every alive row
        ([P, A]).  Unpacks ONLY the gathered alive rows — never the
        full-capacity slab — and patches the promoted rows' true values
        over their clipped residuals.

        Known scale limit (ROADMAP): the gathered [A, m] int32 operand
        is placed by the gather, so on a mesh-sharded registry the rim
        still concentrates ~4x the alive u8 bytes on one device; a
        shard-wise rim (wide rows replicated vs each row shard under
        shard_map) would remove that.  Promoted rows contradict the §4
        moving-window premise, so fleets sharded for scale should treat
        them as an eviction signal, not steady state."""
        # interpret/block-shape overrides carry over; a packed-engine
        # hint does not (it can't run on overflowed rows) — and since a
        # promoted row's span exceeds a byte BY DEFINITION, name the
        # int32 engine outright and skip the futile span probe
        rim_kw = {kk: v for kk, v in kw.items()
                  if kk in ("interpret", "bi", "bj", "bm")}
        rim_kw["engine"] = "i32"
        wide_rows = jnp.asarray(
            np.stack([self._wide[int(s)] for s in widx]))
        jaidx = jnp.asarray(aidx)
        alive_i32 = pack.unpack_rows(
            jnp.take(self.cells_u8, jaidx, axis=0),
            jnp.take(self.base, jaidx))
        wpos = {int(s): i for i, s in enumerate(aidx)}
        alive_i32 = alive_i32.at[
            jnp.asarray([wpos[int(s)] for s in widx])].set(wide_rows)
        return ops.compare_matrix(wide_rows, alive_i32, **rim_kw)

    def _device_wide_overlay(self, bulk: dict, aidx: np.ndarray,
                             **kw) -> dict:
        """Patch the promoted rows'/cols' flags into the sharded bulk and
        re-finalize fp from corrected sums, entirely ON DEVICE — the
        [cap, cap] matrices stay sharded, so even a promoted row on a
        fleet too large for one device costs only the O(P * cap) rim."""
        cap, m = self.capacity, self.m
        widx = self._alive_widx(aidx)
        if widx.size == 0:
            return bulk
        rim = self._wide_rim(aidx, widx, **kw)
        jw = jnp.asarray(widx)
        jaidx = jnp.asarray(aidx)
        P = int(widx.size)

        def patch(mat, row_pa, col_pa):
            rows_full = jnp.zeros((P, cap), bool).at[:, jaidx].set(row_pa)
            cols_full = jnp.zeros((P, cap), bool).at[:, jaidx].set(col_pa)
            mat = jnp.asarray(mat, bool).at[jw, :].set(rows_full)
            return mat.at[:, jw].set(cols_full.T)

        le = patch(bulk["a_le_b"], rim["a_le_b"], rim["b_le_a"])
        ge = patch(bulk["b_le_a"], rim["b_le_a"], rim["a_le_b"])
        sums = jnp.asarray(bulk["row_sums"]).at[jw].set(rim["row_sums"])
        return {
            "a_le_b": le, "b_le_a": ge,
            "concurrent": jnp.logical_not(jnp.logical_or(le, ge)),
            # same jitted Eq. 3 expression as every engine finalize, over
            # the corrected sums -> bit-identical to the unsharded path
            "fp": ops.eq3_outer(sums, sums, m),
            "row_sums": sums, "col_sums": sums,
        }

    def _host_pairs(self, aidx: np.ndarray, **kw) -> dict:
        """Unsharded sparse promoted-row assembly: packed engines over
        the still-packed alive rows plus the exact int32 rim for the
        promoted handful, stitched on host (the slab already lives on
        one device here — the sharded path patches on device instead,
        see ``_device_wide_overlay``).  fp is re-finalized from the
        corrected sums through the SAME jitted Eq. 3 expression the
        engines use (``ops.eq3_outer``), so values stay bit-identical
        to the single-device int32 fallback this replaces."""
        cap, m = self.capacity, self.m
        alive = self._alive_host
        widx = self._alive_widx(aidx)
        le = np.zeros((cap, cap), bool)
        ge = np.zeros((cap, cap), bool)
        sums = np.zeros(cap, np.float32)
        pidx = np.asarray([s for s in aidx if s not in self._wide],
                          np.int64)
        if pidx.size:
            b = self._base_host[pidx]
            sub = jax.device_get(ops.compare_matrix_packed(
                jnp.take(self.cells_u8, jnp.asarray(pidx), axis=0),
                jnp.take(self.base, jnp.asarray(pidx)),
                uniform_base=bool((b == b[0]).all()), **kw))
            le[np.ix_(pidx, pidx)] = sub["a_le_b"]
            ge[np.ix_(pidx, pidx)] = sub["b_le_a"]
            sums[pidx] = sub["row_sums"]
        if widx.size:
            rim = jax.device_get(self._wide_rim(aidx, widx, **kw))
            le[np.ix_(widx, aidx)] = rim["a_le_b"]
            ge[np.ix_(widx, aidx)] = rim["b_le_a"]
            le[np.ix_(aidx, widx)] = rim["b_le_a"].T
            ge[np.ix_(aidx, widx)] = rim["a_le_b"].T
            sums[widx] = rim["row_sums"]
        le[~alive] = False
        le[:, ~alive] = False
        ge[~alive] = False
        ge[:, ~alive] = False
        sums[~alive] = 0.0
        pair = np.ix_(aidx, aidx)
        conc = np.zeros((cap, cap), bool)
        conc[pair] = ~(le[pair] | ge[pair])
        fp = np.zeros((cap, cap), np.float32)
        fp[pair] = np.asarray(ops.eq3_outer(
            jnp.asarray(sums[aidx]), jnp.asarray(sums[aidx]), m))
        s = jnp.asarray(sums)
        return {
            "a_le_b": jnp.asarray(le), "b_le_a": jnp.asarray(ge),
            "concurrent": jnp.asarray(conc), "fp": jnp.asarray(fp),
            "row_sums": s, "col_sums": s,
        }

    # ---- batched merge ----
    def union(self, mask: np.ndarray, local: bc.BloomClock) -> bc.BloomClock:
        """Merge the local clock with every masked row (one device call).

        With promoted rows present, only the MASKED rows are gathered
        and unpacked (plus the promoted handful patched in wide) — the
        full slab is never materialized int32, so a sharded fleet's
        gossip round stays within its per-device memory bound.
        """
        local_cells = local.logical_cells().astype(jnp.int32)
        mask_h = np.asarray(mask, bool)
        midx = np.flatnonzero(mask_h)
        if midx.size == 0:
            return bc.BloomClock(
                cells=local_cells, base=jnp.zeros((), jnp.int32), k=self.k)
        if self.packed:
            merged = _union_rows_packed(
                self.cells_u8, self.base, jnp.asarray(mask_h), local_cells)
        else:
            jmid = jnp.asarray(midx)
            rows = pack.unpack_rows(
                jnp.take(self.cells_u8, jmid, axis=0),
                jnp.take(self.base, jmid))
            wsel = [(pos, int(s)) for pos, s in enumerate(midx)
                    if int(s) in self._wide]
            if wsel:
                rows = rows.at[jnp.asarray([p for p, _ in wsel])].set(
                    jnp.asarray(np.stack([self._wide[s] for _, s in wsel])))
            merged = jnp.maximum(local_cells, jnp.max(rows, axis=0))
        return bc.BloomClock(
            cells=merged, base=jnp.zeros((), jnp.int32), k=self.k)

    def broadcast(self, mask: np.ndarray, clock: bc.BloomClock) -> bool:
        """Write one clock into every masked row (anti-entropy push-back).

        The row ships in wire form: u8 residuals + one base scalar
        (§4 compression), 4x less traffic than an int32 row.  A row too
        wide for u8 promotes the masked slots instead.  Returns whether
        the row went out packed (False = int32 promoted-row fallback).
        """
        logical = clock.logical_cells().astype(jnp.int32)
        row_u8, row_base, ok = pack.pack_rows(logical[None])
        row_sum = bc.clock_sum(clock)
        mask_d = jnp.asarray(mask, bool)
        cells_u8, base, sums = _broadcast_rows(
            self.cells_u8, self.base, self.sums, mask_d,
            row_u8[0], row_base[0], row_sum)
        self.cells_u8 = self._place2d(cells_u8)
        self.base = self._place1d(base)
        self.sums = self._place1d(sums)
        midx = np.flatnonzero(np.asarray(mask))
        self._base_host[midx] = int(row_base[0])
        packed_ok = bool(ok[0])
        if packed_ok:
            for slot in midx:
                self._wide.pop(int(slot), None)
        else:
            row_np = np.asarray(logical)
            for slot in midx:
                self._wide[int(slot)] = row_np
        self._mat = None
        return packed_ok


@jax.jit
def _mask_dead_pairs(bulk: dict, alive: jax.Array) -> dict:
    """Device-side dead-slot masking of a full-capacity all-pairs bulk:
    the sharded ring's counterpart of ``_expand_alive`` (same contract —
    dead rows/cols report all-False flags and zero fp / sums)."""
    pair = alive[:, None] & alive[None, :]
    le = jnp.asarray(bulk["a_le_b"], bool) & pair
    ge = jnp.asarray(bulk["b_le_a"], bool) & pair
    sums = jnp.where(alive, bulk["row_sums"], 0.0)
    return {
        "a_le_b": le,
        "b_le_a": ge,
        "concurrent": jnp.logical_not(jnp.logical_or(le, ge)) & pair,
        "fp": jnp.where(pair, bulk["fp"], 0.0),
        "row_sums": sums,
        "col_sums": sums,
    }


def _expand_alive(sub: dict, jidx: jax.Array, cap: int) -> dict:
    """Scatter an alive-compacted result back to [capacity, capacity]."""
    rows = jidx[:, None]
    cols = jidx[None, :]
    def mat(x, fill, dtype):
        return jnp.full((cap, cap), fill, dtype).at[rows, cols].set(x)
    def vec(x):
        return jnp.zeros((cap,), x.dtype).at[jidx].set(x)
    return {
        "a_le_b": mat(sub["a_le_b"], False, bool),
        "b_le_a": mat(sub["b_le_a"], False, bool),
        "concurrent": mat(sub["concurrent"], False, bool),
        "fp": mat(sub["fp"], 0.0, jnp.float32),
        "row_sums": vec(sub["row_sums"]),
        "col_sums": vec(sub["col_sums"]),
    }
