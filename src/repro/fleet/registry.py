"""ClockRegistry: a fixed-capacity quantized slab of peer bloom clocks.

The registry is the fleet-scale replacement for holding one
``BloomClock`` object per peer and comparing them one ``bool()`` at a
time.  Peer state lives in four device arrays — the §4 packed layout
(see ``repro.kernels.pack``):

    cells_u8 [N, m] uint8  window-relative residuals per slot
    base     [N]    int32  per-slot window offset (logical = base + u8)
    sums     [N]    f32    cached total increments (Eq. 3 inputs)
    alive    [N]    bool   liveness mask (evicted slots stay allocated)

u8 residuals cut slab memory and every kernel's HBM traffic 4x versus
the old int32 slab.  A row whose residual span cannot fit a byte is
**automatically promoted**: its int32 logical cells go to a small host
side-store and all bulk operations transparently fall back to a
materialized int32 slab until the row is overwritten with packable data
(or evicted).  Scatter, union and broadcast operate directly on
(u8, base) — no int32 round-trip on the packed path.

Slot assignment is host-side (a dict + free list); everything that
touches cell data is batched: ``admit_many`` / ``update_many`` are one
scatter each, ``classify_all`` is ONE device call through the packed
one-vs-many Pallas kernel, ``all_pairs`` gathers the alive rows and
runs the symmetric triangle kernel over them only (dead slots cost no
work and report all-False flags).

Status codes (``FleetView.status``) are small ints so a whole fleet's
classification is a single int8 vector:

    DEAD < 0: slot empty/evicted;  ANCESTOR: peer ≼ local;
    SAME: equal;  DESCENDANT: local ≼ peer;  FORKED: concurrent
    (exact — no false negatives, paper §3).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clock as bc
from repro.kernels import ops, pack

__all__ = [
    "ClockRegistry",
    "FleetView",
    "DEAD",
    "ANCESTOR",
    "SAME",
    "DESCENDANT",
    "FORKED",
    "STATUS_NAMES",
]

DEAD = -1
ANCESTOR = 0
SAME = 1
DESCENDANT = 2
FORKED = 3

STATUS_NAMES = {
    DEAD: "dead",
    ANCESTOR: "ancestor",
    SAME: "same",
    DESCENDANT: "descendant",
    FORKED: "forked",
}


@dataclasses.dataclass
class FleetView:
    """Host-side result of one ``classify_all`` call (numpy, [capacity])."""

    status: np.ndarray        # int8 status code per slot
    fp: np.ndarray            # float32 Eq. 3 fp of the claimed direction
    sums: np.ndarray          # float32 cached clock sums
    alive: np.ndarray         # bool liveness mask
    local_sum: float          # the query clock's total increments

    def slots(self, code: int) -> np.ndarray:
        return np.flatnonzero(self.status == code)

    def counts(self) -> dict[str, int]:
        return {
            name: int(np.sum(self.status == code))
            for code, name in STATUS_NAMES.items()
        }


@jax.jit
def _scatter_rows(cells_u8, base, sums, alive, idx, new_u8, new_base, new_sums):
    cells_u8 = cells_u8.at[idx].set(new_u8)
    base = base.at[idx].set(new_base)
    sums = sums.at[idx].set(new_sums)
    alive = alive.at[idx].set(True)
    return cells_u8, base, sums, alive


@jax.jit
def _union_rows_packed(cells_u8, base, mask, local_cells):
    """max(local, max over masked logical rows); the widen fuses with the
    reduce, so the only slab read is the u8 residuals."""
    logical = cells_u8.astype(jnp.int32) + base[:, None]
    masked = jnp.where(mask[:, None], logical, 0)
    return jnp.maximum(local_cells, jnp.max(masked, axis=0))


@jax.jit
def _union_rows_i32(cells, mask, local_cells):
    masked = jnp.where(mask[:, None], cells, 0)
    return jnp.maximum(local_cells, jnp.max(masked, axis=0))


@jax.jit
def _broadcast_rows(cells_u8, base, sums, mask, row_u8, row_base, row_sum):
    cells_u8 = jnp.where(mask[:, None], row_u8[None, :], cells_u8)
    base = jnp.where(mask, row_base, base)
    sums = jnp.where(mask, row_sum, sums)
    return cells_u8, base, sums


@jax.jit
def _materialize(cells_u8, base):
    return pack.unpack_rows(cells_u8, base)


class ClockRegistry:
    """Sharded-slab peer clock registry (one shard = one device slab)."""

    def __init__(self, capacity: int, m: int, k: int = 4):
        self.capacity = capacity
        self.m = m
        self.k = k
        self.cells_u8 = jnp.zeros((capacity, m), jnp.uint8)
        self.base = jnp.zeros((capacity,), jnp.int32)
        self.sums = jnp.zeros((capacity,), jnp.float32)
        self.alive = jnp.zeros((capacity,), bool)
        self._alive_host = np.zeros(capacity, bool)
        self._base_host = np.zeros(capacity, np.int64)
        self._wide: dict[int, np.ndarray] = {}   # promoted int32 rows
        self._mat: jax.Array | None = None       # materialized i32 cache
        self._slot_of: dict = {}
        self._free: list[int] = list(range(capacity - 1, -1, -1))

    # ---- membership ----
    def __len__(self) -> int:
        return len(self._slot_of)

    def __contains__(self, peer_id) -> bool:
        return peer_id in self._slot_of

    def slot_of(self, peer_id) -> int:
        return self._slot_of[peer_id]

    def peer_ids(self) -> list:
        return list(self._slot_of)

    @property
    def packed(self) -> bool:
        """True when every row is in the u8 fast-path representation."""
        return not self._wide

    @property
    def cells(self) -> jax.Array:
        """Materialized int32 logical cells (back-compat / debug view)."""
        return self._materialized()

    def _materialized(self) -> jax.Array:
        if self._mat is None:
            mat = _materialize(self.cells_u8, self.base)
            if self._wide:
                idx = jnp.asarray(sorted(self._wide), jnp.int32)
                rows = jnp.asarray(
                    np.stack([self._wide[s] for s in sorted(self._wide)]))
                mat = mat.at[idx].set(rows)
            self._mat = mat
        return self._mat

    def _uniform_base(self) -> bool:
        b = self._base_host[self._alive_host]
        return b.size == 0 or bool((b == b[0]).all())

    # ---- batched mutation ----
    def admit_many(self, peers: dict) -> dict:
        """Admit {peer_id: BloomClock}; one scatter for the whole batch.

        Re-admitting a known peer_id overwrites its row (re-spawned
        peers keep their slot).  Returns {peer_id: slot}.  Raises when
        capacity is exhausted.
        """
        if not peers:
            return {}
        fresh = [pid for pid in peers if pid not in self._slot_of]
        if len(fresh) > len(self._free):
            raise RuntimeError(
                f"registry full: {len(fresh)} admits, {len(self._free)} free slots")
        slots = {pid: (self._slot_of[pid] if pid in self._slot_of
                       else self._free.pop()) for pid in peers}
        self._slot_of.update(slots)
        self._write(list(slots.values()), list(peers.values()))
        return slots

    def admit(self, peer_id, clock: bc.BloomClock) -> int:
        return self.admit_many({peer_id: clock})[peer_id]

    def update_many(self, peers: dict) -> None:
        """Overwrite existing peers' rows; one scatter for the batch."""
        if not peers:
            return
        self._write([self._slot_of[pid] for pid in peers], list(peers.values()))

    def update(self, peer_id, clock: bc.BloomClock) -> None:
        self.update_many({peer_id: clock})

    def evict_many(self, peer_ids) -> None:
        idx = [self._slot_of.pop(pid) for pid in peer_ids]
        if not idx:
            return
        self.alive = self.alive.at[jnp.asarray(idx)].set(False)
        self._alive_host[idx] = False
        for slot in idx:
            self._wide.pop(slot, None)
        self._free.extend(idx)

    def evict(self, peer_id) -> None:
        self.evict_many([peer_id])

    def _write(self, idx: list, clocks: list) -> None:
        logical = jnp.stack(
            [c.logical_cells().astype(jnp.int32) for c in clocks])
        new_sums = jnp.stack([bc.clock_sum(c) for c in clocks])
        new_u8, new_base, ok = pack.pack_rows(logical)
        self.cells_u8, self.base, self.sums, self.alive = _scatter_rows(
            self.cells_u8, self.base, self.sums, self.alive,
            jnp.asarray(idx), new_u8, new_base, new_sums)
        ok_h = np.asarray(ok)
        self._base_host[idx] = np.asarray(new_base)
        self._alive_host[idx] = True
        for pos, slot in enumerate(idx):
            if ok_h[pos]:
                self._wide.pop(slot, None)     # demotion: row packs again
            else:                              # promotion: span > U8_MAX
                self._wide[slot] = np.asarray(logical[pos])
        self._mat = None

    def get(self, peer_id) -> bc.BloomClock:
        slot = self._slot_of[peer_id]
        if slot in self._wide:
            return bc.BloomClock(cells=jnp.asarray(self._wide[slot]),
                                 base=jnp.zeros((), jnp.int32), k=self.k)
        return bc.BloomClock(cells=self.cells_u8[slot].astype(jnp.int32),
                             base=self.base[slot], k=self.k)

    # ---- batched classification ----
    def classify_all(self, local: bc.BloomClock) -> FleetView:
        """Lineage status + Eq. 3 fp for EVERY slot in one device call.

        Direction convention matches ``ClockRuntime.lineage``: a peer
        that is ≼ the local clock is an ANCESTOR (its events are in the
        local past), a peer the local clock is ≼ is a DESCENDANT, and
        incomparable peers are FORKED (exact, §3).
        """
        q = local.logical_cells().astype(jnp.int32)
        if self.packed:
            out = ops.classify_vs_many_packed(q, self.cells_u8, self.base)
        else:
            out = ops.classify_vs_many(q, self._materialized())
        h = jax.device_get(out)          # single host transfer for the dict
        alive = self._alive_host
        p_le_q = h["p_le_q"]
        q_le_p = h["q_le_p"]
        equal = p_le_q & q_le_p
        status = np.full(self.capacity, FORKED, np.int8)
        status[p_le_q] = ANCESTOR
        status[q_le_p] = DESCENDANT
        status[equal] = SAME
        status[~alive] = DEAD
        # fp of the direction actually claimed; SAME and FORKED are exact
        fp = np.where(p_le_q, h["fp_p_before_q"], h["fp_q_before_p"])
        fp = np.where(equal | ~(p_le_q | q_le_p), 0.0, fp).astype(np.float32)
        fp[~alive] = 0.0
        return FleetView(
            status=status,
            fp=fp,
            sums=h["sum_p"],
            alive=alive.copy(),
            local_sum=float(h["sum_q"]),
        )

    def all_pairs(self, **kw) -> dict:
        """Tiled all-pairs compare over the ALIVE rows only.

        Dead slots are masked out before the kernel (the alive rows are
        gathered into a dense sub-slab, so dead slots cost no compute)
        and report ``a_le_b = b_le_a = concurrent = False`` and
        ``fp = row_sums = 0`` — no misleading verdicts from stale cells.
        """
        cap = self.capacity
        aidx = np.flatnonzero(self._alive_host)
        if aidx.size == 0:
            false = jnp.zeros((cap, cap), bool)
            return {
                "a_le_b": false, "b_le_a": false, "concurrent": false,
                "fp": jnp.zeros((cap, cap), jnp.float32),
                "row_sums": jnp.zeros((cap,), jnp.float32),
                "col_sums": jnp.zeros((cap,), jnp.float32),
            }
        if aidx.size == cap and self.packed:
            return ops.compare_matrix_packed(
                self.cells_u8, self.base,
                uniform_base=self._uniform_base(), **kw)
        jidx = jnp.asarray(aidx)
        if self.packed:
            sub = ops.compare_matrix_packed(
                jnp.take(self.cells_u8, jidx, axis=0),
                jnp.take(self.base, jidx),
                uniform_base=self._uniform_base(), **kw)
        else:
            rows = jnp.take(self._materialized(), jidx, axis=0)
            sub = ops.compare_matrix(rows, rows, **kw)
        return _expand_alive(sub, jidx, cap)

    # ---- batched merge ----
    def union(self, mask: np.ndarray, local: bc.BloomClock) -> bc.BloomClock:
        """Merge the local clock with every masked row (one device call)."""
        local_cells = local.logical_cells().astype(jnp.int32)
        mask = jnp.asarray(mask, bool)
        if self.packed:
            merged = _union_rows_packed(self.cells_u8, self.base, mask,
                                        local_cells)
        else:
            merged = _union_rows_i32(self._materialized(), mask, local_cells)
        return bc.BloomClock(
            cells=merged, base=jnp.zeros((), jnp.int32), k=self.k)

    def broadcast(self, mask: np.ndarray, clock: bc.BloomClock) -> bool:
        """Write one clock into every masked row (anti-entropy push-back).

        The row ships in wire form: u8 residuals + one base scalar
        (§4 compression), 4x less traffic than an int32 row.  A row too
        wide for u8 promotes the masked slots instead.  Returns whether
        the row went out packed (False = int32 promoted-row fallback).
        """
        logical = clock.logical_cells().astype(jnp.int32)
        row_u8, row_base, ok = pack.pack_rows(logical[None])
        row_sum = bc.clock_sum(clock)
        mask_d = jnp.asarray(mask, bool)
        self.cells_u8, self.base, self.sums = _broadcast_rows(
            self.cells_u8, self.base, self.sums, mask_d,
            row_u8[0], row_base[0], row_sum)
        midx = np.flatnonzero(np.asarray(mask))
        self._base_host[midx] = int(row_base[0])
        packed_ok = bool(ok[0])
        if packed_ok:
            for slot in midx:
                self._wide.pop(int(slot), None)
        else:
            row_np = np.asarray(logical)
            for slot in midx:
                self._wide[int(slot)] = row_np
        self._mat = None
        return packed_ok


def _expand_alive(sub: dict, jidx: jax.Array, cap: int) -> dict:
    """Scatter an alive-compacted result back to [capacity, capacity]."""
    rows = jidx[:, None]
    cols = jidx[None, :]
    def mat(x, fill, dtype):
        return jnp.full((cap, cap), fill, dtype).at[rows, cols].set(x)
    def vec(x):
        return jnp.zeros((cap,), x.dtype).at[jidx].set(x)
    return {
        "a_le_b": mat(sub["a_le_b"], False, bool),
        "b_le_a": mat(sub["b_le_a"], False, bool),
        "concurrent": mat(sub["concurrent"], False, bool),
        "fp": mat(sub["fp"], 0.0, jnp.float32),
        "row_sums": vec(sub["row_sums"]),
        "col_sums": vec(sub["col_sums"]),
    }
