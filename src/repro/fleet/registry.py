"""ClockRegistry: a fixed-capacity slab of peer bloom clocks.

The registry is the fleet-scale replacement for holding one
``BloomClock`` object per peer and comparing them one ``bool()`` at a
time.  All peer state lives in three device arrays:

    cells [N, m] int32   logical cells per slot (decompressed)
    sums  [N]    float32 cached total increments (Eq. 3 inputs)
    alive [N]    bool    liveness mask (evicted slots stay allocated)

Slot assignment is host-side (a dict + free list); everything that
touches cell data is batched: ``admit_many`` / ``update_many`` are one
scatter each, ``classify_all`` is ONE device call through the fused
one-vs-many Pallas kernel and returns lineage status + Eq. 3 fp for
every slot, ``all_pairs`` runs the tiled N x N kernel.

Status codes (``FleetView.status``) are small ints so a whole fleet's
classification is a single int8 vector:

    DEAD < 0: slot empty/evicted;  ANCESTOR: peer ≼ local;
    SAME: equal;  DESCENDANT: local ≼ peer;  FORKED: concurrent
    (exact — no false negatives, paper §3).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clock as bc
from repro.kernels import ops

__all__ = [
    "ClockRegistry",
    "FleetView",
    "DEAD",
    "ANCESTOR",
    "SAME",
    "DESCENDANT",
    "FORKED",
    "STATUS_NAMES",
]

DEAD = -1
ANCESTOR = 0
SAME = 1
DESCENDANT = 2
FORKED = 3

STATUS_NAMES = {
    DEAD: "dead",
    ANCESTOR: "ancestor",
    SAME: "same",
    DESCENDANT: "descendant",
    FORKED: "forked",
}


@dataclasses.dataclass
class FleetView:
    """Host-side result of one ``classify_all`` call (numpy, [capacity])."""

    status: np.ndarray        # int8 status code per slot
    fp: np.ndarray            # float32 Eq. 3 fp of the claimed direction
    sums: np.ndarray          # float32 cached clock sums
    alive: np.ndarray         # bool liveness mask
    local_sum: float          # the query clock's total increments

    def slots(self, code: int) -> np.ndarray:
        return np.flatnonzero(self.status == code)

    def counts(self) -> dict[str, int]:
        return {
            name: int(np.sum(self.status == code))
            for code, name in STATUS_NAMES.items()
        }


@jax.jit
def _scatter_rows(cells, sums, alive, idx, new_cells, new_sums):
    cells = cells.at[idx].set(new_cells)
    sums = sums.at[idx].set(new_sums)
    alive = alive.at[idx].set(True)
    return cells, sums, alive


@jax.jit
def _union_rows(cells, mask, local_cells):
    """max(local, max over masked rows); logical cells are >= 0 so the
    masked-out fill of 0 is the identity."""
    masked = jnp.where(mask[:, None], cells, 0)
    return jnp.maximum(local_cells, jnp.max(masked, axis=0))


@jax.jit
def _broadcast_rows(cells, sums, mask, row, row_sum):
    cells = jnp.where(mask[:, None], row[None, :], cells)
    sums = jnp.where(mask, row_sum, sums)
    return cells, sums


class ClockRegistry:
    """Sharded-slab peer clock registry (one shard = one device slab)."""

    def __init__(self, capacity: int, m: int, k: int = 4):
        self.capacity = capacity
        self.m = m
        self.k = k
        self.cells = jnp.zeros((capacity, m), jnp.int32)
        self.sums = jnp.zeros((capacity,), jnp.float32)
        self.alive = jnp.zeros((capacity,), bool)
        self._slot_of: dict = {}
        self._free: list[int] = list(range(capacity - 1, -1, -1))

    # ---- membership ----
    def __len__(self) -> int:
        return len(self._slot_of)

    def __contains__(self, peer_id) -> bool:
        return peer_id in self._slot_of

    def slot_of(self, peer_id) -> int:
        return self._slot_of[peer_id]

    def peer_ids(self) -> list:
        return list(self._slot_of)

    # ---- batched mutation ----
    def admit_many(self, peers: dict) -> dict:
        """Admit {peer_id: BloomClock}; one scatter for the whole batch.

        Re-admitting a known peer_id overwrites its row (re-spawned
        peers keep their slot).  Returns {peer_id: slot}.  Raises when
        capacity is exhausted.
        """
        if not peers:
            return {}
        fresh = [pid for pid in peers if pid not in self._slot_of]
        if len(fresh) > len(self._free):
            raise RuntimeError(
                f"registry full: {len(fresh)} admits, {len(self._free)} free slots")
        slots = {pid: (self._slot_of[pid] if pid in self._slot_of
                       else self._free.pop()) for pid in peers}
        self._slot_of.update(slots)
        self._write(list(slots.values()), list(peers.values()))
        return slots

    def admit(self, peer_id, clock: bc.BloomClock) -> int:
        return self.admit_many({peer_id: clock})[peer_id]

    def update_many(self, peers: dict) -> None:
        """Overwrite existing peers' rows; one scatter for the batch."""
        if not peers:
            return
        self._write([self._slot_of[pid] for pid in peers], list(peers.values()))

    def update(self, peer_id, clock: bc.BloomClock) -> None:
        self.update_many({peer_id: clock})

    def evict_many(self, peer_ids) -> None:
        idx = [self._slot_of.pop(pid) for pid in peer_ids]
        if not idx:
            return
        self.alive = self.alive.at[jnp.asarray(idx)].set(False)
        self._free.extend(idx)

    def evict(self, peer_id) -> None:
        self.evict_many([peer_id])

    def _write(self, idx: list, clocks: list) -> None:
        new_cells = jnp.stack([c.logical_cells().astype(jnp.int32) for c in clocks])
        new_sums = jnp.stack([bc.clock_sum(c) for c in clocks])
        self.cells, self.sums, self.alive = _scatter_rows(
            self.cells, self.sums, self.alive, jnp.asarray(idx), new_cells, new_sums)

    def get(self, peer_id) -> bc.BloomClock:
        row = self.cells[self._slot_of[peer_id]]
        return bc.BloomClock(cells=row, base=jnp.zeros((), jnp.int32), k=self.k)

    # ---- batched classification ----
    def classify_all(self, local: bc.BloomClock) -> FleetView:
        """Lineage status + Eq. 3 fp for EVERY slot in one device call.

        Direction convention matches ``ClockRuntime.lineage``: a peer
        that is ≼ the local clock is an ANCESTOR (its events are in the
        local past), a peer the local clock is ≼ is a DESCENDANT, and
        incomparable peers are FORKED (exact, §3).
        """
        out = ops.classify_vs_many(
            local.logical_cells().astype(jnp.int32), self.cells)
        h = jax.device_get(out)          # single host transfer for the dict
        alive = np.asarray(self.alive)
        p_le_q = h["p_le_q"]
        q_le_p = h["q_le_p"]
        equal = p_le_q & q_le_p
        status = np.full(self.capacity, FORKED, np.int8)
        status[p_le_q] = ANCESTOR
        status[q_le_p] = DESCENDANT
        status[equal] = SAME
        status[~alive] = DEAD
        # fp of the direction actually claimed; SAME and FORKED are exact
        fp = np.where(p_le_q, h["fp_p_before_q"], h["fp_q_before_p"])
        fp = np.where(equal | ~(p_le_q | q_le_p), 0.0, fp).astype(np.float32)
        fp[~alive] = 0.0
        return FleetView(
            status=status,
            fp=fp,
            sums=h["sum_p"],
            alive=alive,
            local_sum=float(h["sum_q"]),
        )

    def all_pairs(self, **kw) -> dict:
        """Tiled N x N compare over the whole slab (see ops.compare_matrix)."""
        return ops.compare_matrix(self.cells, self.cells, **kw)

    # ---- batched merge ----
    def union(self, mask: np.ndarray, local: bc.BloomClock) -> bc.BloomClock:
        """Merge the local clock with every masked row (one device call)."""
        merged = _union_rows(
            self.cells, jnp.asarray(mask, bool),
            local.logical_cells().astype(jnp.int32))
        return bc.BloomClock(
            cells=merged, base=jnp.zeros((), jnp.int32), k=self.k)

    def broadcast(self, mask: np.ndarray, clock: bc.BloomClock) -> None:
        """Write one clock into every masked row (anti-entropy push-back)."""
        row = clock.logical_cells().astype(jnp.int32)
        self.cells, self.sums = _broadcast_rows(
            self.cells, self.sums, jnp.asarray(mask, bool), row,
            bc.clock_sum(clock))
