"""ClockRegistry: a fixed-capacity quantized slab of peer bloom clocks.

The registry is the fleet-scale replacement for holding one
``BloomClock`` object per peer and comparing them one ``bool()`` at a
time.  Peer state lives in four device arrays — the §4 packed layout
(see ``repro.kernels.pack``):

    cells_u8 [N, m] uint8  window-relative residuals per slot
    base     [N]    int32  per-slot window offset (logical = base + u8)
    sums     [N]    f32    cached total increments (Eq. 3 inputs)
    alive    [N]    bool   liveness mask (evicted slots stay allocated)

u8 residuals cut slab memory and every kernel's HBM traffic 4x versus
the old int32 slab.  A row whose residual span cannot fit a byte is
**automatically promoted**: its int32 logical cells go to a small host
side-store and all bulk operations transparently fall back to a
materialized int32 slab until the row is overwritten with packable data
(or evicted).  Scatter, union and broadcast operate directly on
(u8, base) — no int32 round-trip on the packed path.

Slot assignment is host-side (a dict + free list); everything that
touches cell data is batched: ``admit_many`` / ``update_many`` are one
scatter each, and all classification goes through the ONE dispatch
front-door — ``repro.causal.CausalEngine`` — built from the registry's
``CausalPolicy``: ``classify_all`` is ``engine.classify`` over the
packed slab (one device call), ``all_pairs`` is ``engine.pairs`` with
the alive mask (dead slots cost no work and report all-False flags;
promoted rows get the exact int32 rim inside the engine).

Status codes (``FleetView.status``) are small ints so a whole fleet's
classification is a single int8 vector:

    DEAD < 0: slot empty/evicted;  ANCESTOR: peer ≼ local;
    SAME: equal;  DESCENDANT: local ≼ peer;  FORKED: concurrent
    (exact — no false negatives, paper §3).

**Sharded mode** (``ClockRegistry(..., mesh=mesh, axis="fleet")``): the
slab arrays carry a row-sharded ``NamedSharding`` over one mesh axis —
``cells_u8`` lives as ``[N/d, m]`` per-device shards so a fleet can
outgrow any single device's memory.  ``classify_all`` becomes a
``shard_map``'d one-vs-many kernel (query replicated, zero cross-device
traffic) and ``all_pairs`` a block-row ring: each device circulates a
column shard via ``ppermute`` and fills its ``[N/d, N]`` block-row with
the packed full-rect engine.  Both paths are bit-identical to the
single-device packed engines for every shard count — the multi-device
harness (``tests/test_sharded_fleet.py``) enforces it.  Mutations
(admit / evict / update / union / broadcast) stay one batched device
call; XLA routes each scattered row to its owning shard and the result
is re-placed onto the registry's sharding.  Slot assignment remains a
host-side dict, so slot ``s`` deterministically lives on device
``s // (N / d)``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.causal import CausalEngine, CausalPolicy, PackedSlab
from repro.core import clock as bc
from repro.core import wire
from repro.kernels import pack
from repro.obs.observer import resolve
from repro.sharding import FLEET_AXIS, slab_shardings

__all__ = [
    "ClockRegistry",
    "EvictedRow",
    "FleetView",
    "view_from_classify",
    "DEAD",
    "ANCESTOR",
    "SAME",
    "DESCENDANT",
    "FORKED",
    "STATUS_NAMES",
    "NEAR_WRAP_MARGIN",
]

INT32_MAX = np.iinfo(np.int32).max

#: a row whose §4 base lands within this margin of INT32_MAX (or has
#: already wrapped negative) is routed through promotion — the exact
#: int32 rim compares with wrap-subtraction, so near-wrap rows can
#: never produce an inverted le/ge bit through the packed fast path,
#: whose in-kernel f32 sums would overflow first.  2^20 leaves room
#: for ~a million more ticks plus the u8 residual window.
NEAR_WRAP_MARGIN = 1 << 20


def _near_wrap(base: np.ndarray) -> np.ndarray:
    """Bool mask of §4 bases too close to (or past) the int32 wrap."""
    base = np.asarray(base, np.int64)
    return (base > INT32_MAX - NEAR_WRAP_MARGIN) | (base < 0)


def _pow2_bucket(n: int, cap: int | None = None) -> int:
    """Next power of two ≥ n: batched mutations pad to these buckets so
    the compiled scatter/gather shape count stays logarithmic under
    churny variable-size admit/evict waves.  ``cap`` (the slab
    capacity) clamps the bucket: a batch one past a pow2 boundary must
    not pad beyond the slab and rely on downstream crop — there are no
    valid slots to alias the padding to past capacity."""
    bucket = 1 << max(0, n - 1).bit_length() if n > 1 else n
    return bucket if cap is None else min(bucket, cap)

DEAD = -1
ANCESTOR = 0
SAME = 1
DESCENDANT = 2
FORKED = 3

STATUS_NAMES = {
    DEAD: "dead",
    ANCESTOR: "ancestor",
    SAME: "same",
    DESCENDANT: "descendant",
    FORKED: "forked",
}


@dataclasses.dataclass
class EvictedRow:
    """One row captured for an ``on_evict`` hook, in the slab's own
    packed representation: u8 residuals + base (plus the promoted int32
    logical row when the slot was wide).  A tiered store (see
    ``repro.serve.tiers``) ingests these directly — the demotion path
    never materializes the full slab."""

    cells_u8: np.ndarray      # [m] uint8 residuals
    base: int                 # §4 window offset
    sum: float                # cached clock sum (Eq. 3 input)
    wide: Optional[np.ndarray] = None   # promoted int32 logical row

    def logical(self) -> np.ndarray:
        """Materialized int32 logical cells (mod-2^32 circle)."""
        if self.wide is not None:
            return np.asarray(self.wide, np.int32)
        return (self.cells_u8.astype(np.int64)
                + int(self.base)).astype(np.int32)


@dataclasses.dataclass
class FleetView:
    """Host-side result of one ``classify_all`` call (numpy, [capacity])."""

    status: np.ndarray        # int8 status code per slot
    fp: np.ndarray            # float32 Eq. 3 fp of the claimed direction
    sums: np.ndarray          # float32 cached clock sums
    alive: np.ndarray         # bool liveness mask
    local_sum: float          # the query clock's total increments
    engine: str = ""          # dispatch label that produced this view

    def slots(self, code: int) -> np.ndarray:
        return np.flatnonzero(self.status == code)

    def counts(self) -> dict[str, int]:
        return {
            name: int(np.sum(self.status == code))
            for code, name in STATUS_NAMES.items()
        }

    def confident(self, threshold: float) -> np.ndarray:
        """The uniform Eq. 3 gate over the claimed direction, mirroring
        ``causal.ClassifyResult.confident`` (exact verdicts — SAME,
        FORKED, DEAD — carry fp 0 and are always confident)."""
        return self.fp <= threshold


def view_from_classify(res, alive: np.ndarray, capacity: int,
                       local_sum: float | None = None) -> FleetView:
    """Fold a host-side ``ClassifyResult`` into a ``FleetView``.

    The ONE place classify flags become status codes + claimed-direction
    fp — ``ClockRegistry.classify_all`` and the tiered registry
    (``repro.serve.tiers``) both route through it, so a tier split can
    never drift from the flat slab's verdict semantics.
    """
    alive = np.asarray(alive, bool)
    p_le_q = res.after()           # peer ≼ local
    q_le_p = res.before()          # local ≼ peer
    equal = res.equal()
    status = np.full(capacity, FORKED, np.int8)
    status[p_le_q] = ANCESTOR
    status[q_le_p] = DESCENDANT
    status[equal] = SAME
    status[~alive] = DEAD
    # fp of the direction actually claimed; SAME and FORKED are exact
    fp = np.asarray(res.claimed_fp(), np.float32)
    fp[~alive] = 0.0
    return FleetView(
        status=status,
        fp=fp,
        sums=res.sum_p,
        alive=alive.copy(),
        local_sum=float(res.sum_q) if local_sum is None else local_sum,
        engine=res.engine or "",
    )


@jax.jit
def _scatter_rows(cells_u8, base, sums, alive, idx, new_u8, new_base, new_sums):
    cells_u8 = cells_u8.at[idx].set(new_u8)
    base = base.at[idx].set(new_base)
    sums = sums.at[idx].set(new_sums)
    alive = alive.at[idx].set(True)
    return cells_u8, base, sums, alive


@jax.jit
def _union_rows_packed(cells_u8, base, mask, local_cells):
    """max(local, max over masked logical rows); the widen fuses with the
    reduce, so the only slab read is the u8 residuals.  The max is the
    wrap-safe ``local + relu(row - local)`` derivation (bounded-counter
    semantics) — bit-identical to a direct maximum in the sane range,
    correct when a row's base has wrapped past INT32_MAX."""
    logical = cells_u8.astype(jnp.int32) + base[:, None]
    gain = jnp.where(mask[:, None],
                     jnp.maximum(logical - local_cells, 0), 0)
    return local_cells + jnp.max(gain, axis=0)


@jax.jit
def _broadcast_rows(cells_u8, base, sums, mask, row_u8, row_base, row_sum):
    cells_u8 = jnp.where(mask[:, None], row_u8[None, :], cells_u8)
    base = jnp.where(mask, row_base, base)
    sums = jnp.where(mask, row_sum, sums)
    return cells_u8, base, sums


@jax.jit
def _materialize(cells_u8, base):
    return pack.unpack_rows(cells_u8, base)


class ClockRegistry:
    """Peer clock registry: one device slab, or mesh-sharded row shards."""

    def __init__(self, capacity: int, m: int, k: int = 4, *,
                 mesh=None, axis: str = FLEET_AXIS,
                 policy: CausalPolicy | None = None):
        self.capacity = capacity
        self.m = m
        self.k = k
        # the CausalPolicy is the one source of truth for dispatch: the
        # mesh/axis arguments fold into it (explicit args win so the
        # pre-policy constructor signature keeps working), and every
        # comparison below goes through the resulting CausalEngine
        base_policy = policy if policy is not None else CausalPolicy()
        if mesh is None:
            mesh = base_policy.mesh
            if mesh is not None and axis == FLEET_AXIS:
                axis = base_policy.axis
        self.policy = dataclasses.replace(base_policy, mesh=mesh, axis=axis)
        self.engine = CausalEngine(self.policy)
        self.obs = resolve(getattr(self.policy, "observer", None))
        self.mesh = mesh
        self.axis = axis if mesh is not None else None
        if mesh is not None:
            shards = mesh.shape[axis]
            if capacity % shards:
                raise ValueError(
                    f"capacity {capacity} not divisible by mesh axis "
                    f"{axis!r} extent {shards}")
            self._slab_sharding, self._vec_sharding = slab_shardings(
                mesh, axis)
        else:
            self._slab_sharding = self._vec_sharding = None
        self.cells_u8 = self._place2d(jnp.zeros((capacity, m), jnp.uint8))
        self.base = self._place1d(jnp.zeros((capacity,), jnp.int32))
        self.sums = self._place1d(jnp.zeros((capacity,), jnp.float32))
        self.alive = self._place1d(jnp.zeros((capacity,), bool))
        self._alive_host = np.zeros(capacity, bool)
        self._base_host = np.zeros(capacity, np.int64)
        # per-slot CRC32 of the logical cells, written at every mutation:
        # the ground truth check_integrity() verifies the slab against
        # (corruption detection on admit/union, repaired via gossip)
        self._crc_host = np.zeros(capacity, np.int64)
        self._wide: dict[int, np.ndarray] = {}   # promoted int32 rows
        self._mat: jax.Array | None = None       # materialized i32 cache
        self._slot_of: dict = {}
        self._free: list[int] = list(range(capacity - 1, -1, -1))
        #: demotion hook: called as ``on_evict({peer_id: EvictedRow})``
        #: with every ALIVE row an ``evict_many`` is about to free —
        #: quarantined (corrupt) rows are never handed out.  A tiered
        #: store installs this to catch hot-tier evictions (see
        #: ``repro.serve.tiers``).
        self.on_evict: Optional[Callable[[dict], None]] = None

    @property
    def n_shards(self) -> int:
        return 1 if self.mesh is None else self.mesh.shape[self.axis]

    def _place2d(self, x: jax.Array) -> jax.Array:
        """Pin a [N, m] slab to the registry's row sharding (no-op when
        unsharded).  Every mutation re-places its result so XLA's output
        placement choices never silently gather the slab."""
        return x if self._slab_sharding is None else jax.device_put(
            x, self._slab_sharding)

    def _place1d(self, x: jax.Array) -> jax.Array:
        return x if self._vec_sharding is None else jax.device_put(
            x, self._vec_sharding)

    # ---- membership ----
    def __len__(self) -> int:
        return len(self._slot_of)

    def __contains__(self, peer_id) -> bool:
        return peer_id in self._slot_of

    def slot_of(self, peer_id) -> int:
        return self._slot_of[peer_id]

    def peer_ids(self) -> list:
        return list(self._slot_of)

    def row_alive(self, peer_id) -> bool:
        """True when the peer's row is present AND not quarantined."""
        slot = self._slot_of.get(peer_id)
        return slot is not None and bool(self._alive_host[slot])

    @property
    def packed(self) -> bool:
        """True when every row is in the u8 fast-path representation."""
        return not self._wide

    @property
    def cells(self) -> jax.Array:
        """Materialized int32 logical cells (back-compat / debug view)."""
        return self._materialized()

    def _materialized(self) -> jax.Array:
        if self._mat is None:
            mat = _materialize(self.cells_u8, self.base)
            if self._wide:
                idx = jnp.asarray(sorted(self._wide), jnp.int32)
                rows = jnp.asarray(
                    np.stack([self._wide[s] for s in sorted(self._wide)]))
                mat = mat.at[idx].set(rows)
            self._mat = mat
        return self._mat

    def _slab(self) -> PackedSlab:
        """The engine-facing view of the slab arrays (wide rows and the
        host base copy ride along so the front-door can overlay promoted
        rows and probe base uniformity without device syncs)."""
        return PackedSlab(self.cells_u8, self.base,
                          base_host=self._base_host, wide=self._wide)

    # ---- batched mutation ----
    def admit_many(self, peers: dict) -> dict:
        """Admit {peer_id: BloomClock}; one scatter for the whole batch.

        Re-admitting a known peer_id overwrites its row (re-spawned
        peers keep their slot).  Returns {peer_id: slot}.  Raises when
        capacity is exhausted.
        """
        if not peers:
            return {}
        fresh = [pid for pid in peers if pid not in self._slot_of]
        if len(fresh) > len(self._free):
            raise RuntimeError(
                f"registry full: {len(fresh)} admits, {len(self._free)} free slots")
        with self.obs.trace.span("registry.admit", n=len(peers),
                                 fresh=len(fresh)):
            slots = {pid: (self._slot_of[pid] if pid in self._slot_of
                           else self._free.pop()) for pid in peers}
            self._slot_of.update(slots)
            self._write(list(slots.values()), list(peers.values()))
        self.obs.metrics.counter("registry_admits").inc(len(peers))
        self._note_occupancy()
        return slots

    def admit(self, peer_id, clock: bc.BloomClock) -> int:
        return self.admit_many({peer_id: clock})[peer_id]

    def update_many(self, peers: dict) -> None:
        """Overwrite existing peers' rows; one scatter for the batch."""
        if not peers:
            return
        with self.obs.trace.span("registry.update", n=len(peers)):
            self._write([self._slot_of[pid] for pid in peers],
                        list(peers.values()))

    def update(self, peer_id, clock: bc.BloomClock) -> None:
        self.update_many({peer_id: clock})

    def evict_many(self, peer_ids) -> None:
        peer_ids = list(dict.fromkeys(peer_ids))   # dedupe, keep order
        # resolve every slot BEFORE mutating: an unknown peer_id raises
        # with the registry untouched instead of half-evicted
        idx = [self._slot_of[pid] for pid in peer_ids]
        if not idx:
            return
        captured = self._capture_rows(peer_ids, idx)
        with self.obs.trace.span("registry.evict", n=len(idx)):
            for pid in peer_ids:
                del self._slot_of[pid]
            pidx = idx + [idx[-1]] * (_pow2_bucket(len(idx), self.capacity) - len(idx))
            self.alive = self._place1d(
                self.alive.at[jnp.asarray(pidx)].set(False))
            self._alive_host[idx] = False
            for slot in idx:
                self._wide.pop(slot, None)
            self._free.extend(idx)
        self.obs.metrics.counter("registry_evictions").inc(len(idx))
        self._note_occupancy()
        if captured:
            self.on_evict(captured)

    def _capture_rows(self, peer_ids: list, idx: list) -> Optional[dict]:
        """Snapshot the alive rows an eviction is about to free, in the
        packed representation (one gathered device transfer for the
        batch, not a full-slab materialize)."""
        if self.on_evict is None:
            return None
        live = [(pid, slot) for pid, slot in zip(peer_ids, idx)
                if self._alive_host[slot]]
        if not live:
            return None
        slots = [slot for _, slot in live]
        slots += [slots[-1]] * (_pow2_bucket(len(slots), self.capacity)
                                - len(slots))
        jidx = jnp.asarray(slots)
        u8 = np.asarray(jnp.take(self.cells_u8, jidx, axis=0))
        sums = np.asarray(jnp.take(self.sums, jidx))
        return {
            pid: EvictedRow(
                cells_u8=u8[pos].copy(),
                base=int(self._base_host[slot]),
                sum=float(sums[pos]),
                wide=(None if slot not in self._wide
                      else self._wide[slot].copy()))
            for pos, (pid, slot) in enumerate(live)
        }

    def evict(self, peer_id) -> None:
        self.evict_many([peer_id])

    def _write(self, idx: list, clocks: list) -> None:
        # materialize logical rows host-side (int32 wraparound kept via
        # the mod-2^32 fold) and sum them in ONE batched op: per-clock
        # eager dispatches dominate bulk admits otherwise
        n0 = len(clocks)
        n = _pow2_bucket(n0, self.capacity)
        logical_h = np.empty((n, self.m), np.int32)
        for pos, c in enumerate(clocks):
            cells = np.asarray(c.cells, np.int64)
            b = int(np.asarray(c.base))
            logical_h[pos] = ((cells + b) & 0xFFFFFFFF).astype(
                np.uint32).view(np.int32)
        if n > n0:
            # pad to a power-of-two bucket by repeating the last row at
            # its own slot — the duplicate scatter rewrites identical
            # data, and the compiled shape count stays logarithmic
            logical_h[n0:] = logical_h[n0 - 1]
            idx = list(idx) + [idx[-1]] * (n - n0)
        logical = jnp.asarray(logical_h)
        new_sums = bc.clock_sum(bc.BloomClock(
            cells=logical, base=jnp.zeros(n, jnp.int32),
            k=clocks[0].k))
        new_u8, new_base, ok = pack.pack_rows(logical)
        cells_u8, base, sums, alive = _scatter_rows(
            self.cells_u8, self.base, self.sums, self.alive,
            jnp.asarray(idx), new_u8, new_base, new_sums)
        self.cells_u8 = self._place2d(cells_u8)
        self.base = self._place1d(base)
        self.sums = self._place1d(sums)
        self.alive = self._place1d(alive)
        ok_h = np.asarray(ok)
        base_h = np.asarray(new_base)
        # near-wrap guard: a base within NEAR_WRAP_MARGIN of INT32_MAX
        # (or already wrapped) rides the exact int32 rim via promotion —
        # the packed path's in-kernel sums are not wrap-safe
        nw_h = _near_wrap(base_h)
        self._base_host[idx] = base_h
        self._alive_host[idx] = True
        promoted = demoted = 0
        for pos, slot in enumerate(idx):
            self._crc_host[slot] = wire.cells_crc(logical_h[pos])
            if ok_h[pos] and not nw_h[pos]:
                if self._wide.pop(slot, None) is not None:
                    demoted += 1               # demotion: row packs again
            else:                  # promotion: span > U8_MAX or near-wrap
                if slot not in self._wide:
                    promoted += 1
                self._wide[slot] = logical_h[pos].copy()
        if promoted:
            self.obs.metrics.counter("registry_promotions").inc(promoted)
        if demoted:
            self.obs.metrics.counter("registry_demotions").inc(demoted)
        self._mat = None

    def _note_occupancy(self) -> None:
        obs = self.obs
        if obs:
            obs.metrics.gauge("registry_occupancy").set(len(self._slot_of))
            obs.metrics.gauge("registry_wide_rows").set(len(self._wide))

    # ---- self-stabilization: row integrity ----
    def check_integrity(self) -> list:
        """Verify every alive row against the CRC recorded when it was
        written; returns the peer ids whose slab state no longer hashes
        to it (bit rot, a bad scatter, hostile mutation).

        The CRC is over the canonical logical cells
        (``core.wire.cells_crc``), so packed and promoted rows verify
        identically.  Detection only — callers quarantine and repair
        via :meth:`quarantine_rows` + the gossip delta pull (the session
        protocol does both when ``GossipConfig.verify_rows`` is set).
        """
        mat = np.asarray(self._materialized())
        bad = []
        for pid, slot in self._slot_of.items():
            if not self._alive_host[slot]:
                continue
            if wire.cells_crc(mat[slot]) != int(self._crc_host[slot]):
                bad.append(pid)
        if bad:
            self.obs.metrics.counter("registry_corrupt_rows").inc(len(bad))
        return bad

    def quarantine_rows(self, peer_ids) -> None:
        """Mark corrupted rows dead WITHOUT freeing their slots: the
        peer stays known (``slot_of`` keeps resolving) but classify /
        union / all_pairs ignore the poisoned cells.  A subsequent
        ``update_many`` — e.g. the session's forced delta re-pull from
        any peer whose digest covers the row — rewrites the row, marks
        it alive again, and refreshes its CRC."""
        idx = [self._slot_of[pid] for pid in peer_ids]
        if not idx:
            return
        self.alive = self._place1d(
            self.alive.at[jnp.asarray(idx)].set(False))
        self._alive_host[idx] = False
        self._mat = None

    def get(self, peer_id) -> bc.BloomClock:
        slot = self._slot_of[peer_id]
        if slot in self._wide:
            return bc.BloomClock(cells=jnp.asarray(self._wide[slot]),
                                 base=jnp.zeros((), jnp.int32), k=self.k)
        return bc.BloomClock(cells=self.cells_u8[slot].astype(jnp.int32),
                             base=self.base[slot], k=self.k)

    # ---- batched classification ----
    def classify_all(self, local: bc.BloomClock) -> FleetView:
        """Lineage status + Eq. 3 fp for EVERY slot in one device call.

        Direction convention matches ``ClockRuntime.lineage``: a peer
        that is ≼ the local clock is an ANCESTOR (its events are in the
        local past), a peer the local clock is ≼ is a DESCENDANT, and
        incomparable peers are FORKED (exact, §3).

        One ``engine.classify`` call: the front-door runs the packed
        one-vs-many kernel (shard_map'd over the row shards when the
        policy carries a mesh) and overlays promoted rows through the
        exact int32 kernel — the bulk never drops to the fallback.
        """
        res = jax.device_get(          # single host transfer for the pytree
            self.engine.classify(local, self._slab()))
        return view_from_classify(res, self._alive_host, self.capacity)

    def all_pairs(self, **kw):
        """Tiled all-pairs compare -> ``causal.ComparisonMatrix`` (also
        answers the legacy dict keys); dead slots report all-False flags
        and ``fp = row_sums = 0`` — no misleading verdicts from stale
        cells.

        One ``engine.pairs`` call over the packed slab: the front-door
        alive-compacts unsharded fleets (dead slots cost no compute),
        runs the block-row ``ppermute`` ring and masks dead slots on
        device for sharded ones, and patches promoted rows through the
        exact int32 rim in both modes.  ``**kw`` carries per-call
        dispatch overrides (engine / block shapes / interpret).
        """
        return self.engine.pairs(self._slab(), alive=self._alive_host,
                                 alive_dev=self.alive, **kw)

    # ---- batched merge ----
    def union(self, mask: np.ndarray, local: bc.BloomClock) -> bc.BloomClock:
        """Merge the local clock with every masked row (one device call).

        With promoted rows present, only the MASKED rows are gathered
        and unpacked (plus the promoted handful patched in wide) — the
        full slab is never materialized int32, so a sharded fleet's
        gossip round stays within its per-device memory bound.
        """
        local_cells = local.logical_cells().astype(jnp.int32)
        mask_h = np.asarray(mask, bool)
        midx = np.flatnonzero(mask_h)
        if midx.size == 0:
            return bc.BloomClock(
                cells=local_cells, base=jnp.zeros((), jnp.int32), k=self.k)
        if self.packed:
            merged = _union_rows_packed(
                self.cells_u8, self.base, jnp.asarray(mask_h), local_cells)
        else:
            jmid = jnp.asarray(midx)
            rows = pack.unpack_rows(
                jnp.take(self.cells_u8, jmid, axis=0),
                jnp.take(self.base, jmid))
            wsel = [(pos, int(s)) for pos, s in enumerate(midx)
                    if int(s) in self._wide]
            if wsel:
                rows = rows.at[jnp.asarray([p for p, _ in wsel])].set(
                    jnp.asarray(np.stack([self._wide[s] for _, s in wsel])))
            # wrap-safe max (same derivation as _union_rows_packed)
            merged = local_cells + jnp.maximum(
                jnp.max(rows - local_cells, axis=0), 0)
        return bc.BloomClock(
            cells=merged, base=jnp.zeros((), jnp.int32), k=self.k)

    def broadcast(self, mask: np.ndarray, clock: bc.BloomClock) -> bool:
        """Write one clock into every masked row (anti-entropy push-back).

        The row ships in wire form: u8 residuals + one base scalar
        (§4 compression), 4x less traffic than an int32 row.  A row too
        wide for u8 promotes the masked slots instead.  Returns whether
        the row went out packed (False = int32 promoted-row fallback).
        """
        logical = clock.logical_cells().astype(jnp.int32)
        row_u8, row_base, ok = pack.pack_rows(logical[None])
        row_sum = bc.clock_sum(clock)
        mask_d = jnp.asarray(mask, bool)
        cells_u8, base, sums = _broadcast_rows(
            self.cells_u8, self.base, self.sums, mask_d,
            row_u8[0], row_base[0], row_sum)
        self.cells_u8 = self._place2d(cells_u8)
        self.base = self._place1d(base)
        self.sums = self._place1d(sums)
        midx = np.flatnonzero(np.asarray(mask))
        self._base_host[midx] = int(row_base[0])
        row_np = np.asarray(logical)
        self._crc_host[midx] = wire.cells_crc(row_np)
        # same near-wrap guard as _write: a union row pushed back near
        # the int32 wrap stays on the exact rim
        packed_ok = bool(ok[0]) and not bool(_near_wrap(
            np.asarray([int(row_base[0])]))[0])
        if packed_ok:
            for slot in midx:
                self._wide.pop(int(slot), None)
        else:
            for slot in midx:
                self._wide[int(slot)] = row_np
        self._mat = None
        return packed_ok


