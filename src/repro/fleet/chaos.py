"""ChaosTransport: seeded, replayable fault injection over any fabric.

The hostile-fleet harness.  :class:`ChaosTransport` wraps any
:class:`~repro.fleet.transport.Transport` (loopback, mesh-collective,
socket) and injects the failure modes a real deployment sees, WITHOUT
the wrapped fabric or the session protocol knowing:

- **drops** — a peer's digest answer or pulled delta frame is lost;
- **duplicates / delays** — a pulled frame is ALSO redelivered on the
  next round (a stale duplicate), or arrives one round late instead;
- **reorders** — the realized delivery order of a round's frames is
  permuted;
- **truncations / bit-flips** — a frame arrives damaged, inbound or on
  the push-back path;
- **crashes** — a peer answers the digest exchange and then dies
  mid-session (pull and push fail), staying down for a configured
  number of rounds before it restarts;
- **partitions** — a set of peers is unreachable for a window of rounds
  and then heals.

Every injected fault is **deterministic in** ``(seed, round, phase,
peer, op)`` — the decision stream is independent of wall clock, thread
interleaving, and dict ordering — and is recorded twice: on
``ChaosTransport.schedule`` (the realized :class:`FaultEvent` list) and
in the ``repro.obs`` audit trail as ``kind="chaos"`` records.  Two runs
with the same seed inject the identical fault schedule, so a failing
chaos run is a repro, not an anecdote.

What the harness demonstrates (``tests/test_chaos.py``, the
``chaos-smoke`` CI job, ``core.sim.run_gossip_sim(chaos=...)``): the
anti-entropy session survives every fault class — damaged frames are
rejected at decode and re-pulled, duplicated/reordered deliveries are
idempotent under the §3 merge-on-ingest receive rule, dead peers are
skipped-and-reported — and once faults quiesce the fleet converges to
identical rows with zero false negatives.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import zlib
from typing import Optional

import numpy as np

from repro.fleet.transport.base import Transport
from repro.fleet.transport.socket import PeerRejected
from repro.obs.observer import resolve

__all__ = ["ChaosConfig", "ChaosTransport", "FaultEvent",
           "corrupt_registry_row", "main"]


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Fault mix for one :class:`ChaosTransport`.

    Probabilities are per (round, peer) decision points; ``crashes`` and
    ``partitions`` are explicit schedules.  All randomness derives from
    ``seed`` + the decision coordinates, never from global state.
    """

    seed: int = 0
    p_drop_digest: float = 0.0    # peer's digest answer lost this round
    p_drop_frame: float = 0.0     # pulled delta frame lost in flight
    p_duplicate: float = 0.0      # pulled frame ALSO redelivered next round
    p_delay: float = 0.0          # pulled frame arrives next round instead
    p_reorder: float = 0.0        # per-round: permute frame delivery order
    p_truncate: float = 0.0       # pulled frame cut at a random offset
    p_bitflip: float = 0.0        # pulled frame gets one random bit flipped
    p_drop_push: float = 0.0      # outbound union frame to one peer lost
    p_bitflip_push: float = 0.0   # outbound union frame damaged
    #: (peer_id, crash_round, n_down_rounds): the peer answers digests on
    #: ``crash_round`` and then dies mid-session (pull/push fail); it is
    #: fully gone for the next ``n_down_rounds - 1`` rounds, then back.
    crashes: tuple = ()
    #: (peer_ids, start_round, heal_round): the peers are unreachable for
    #: rounds in [start, heal) and then the partition heals.
    partitions: tuple = ()
    #: round index after which all probabilistic faults switch off (the
    #: settle window a convergence check needs); crash / partition
    #: schedules still honor their own rounds.  None = never quiesce.
    quiesce_after: Optional[int] = None


@dataclasses.dataclass
class FaultEvent:
    """One realized injected fault (the schedule entry)."""

    round: int
    phase: str     # digest | pull | push
    pid: str
    kind: str      # peer_down, drop_digest, drop_frame, duplicate, ...
    detail: str = ""

    def as_tuple(self) -> tuple:
        return (self.round, self.phase, self.pid, self.kind, self.detail)


def _flip_bit(frame: bytes, rng: np.random.Generator) -> bytes:
    """Flip one random bit of a frame (never a no-op for len > 0)."""
    if not frame:
        return frame
    pos = int(rng.integers(0, len(frame)))
    bit = int(rng.integers(0, 8))
    buf = bytearray(frame)
    buf[pos] ^= 1 << bit
    return bytes(buf)


class ChaosTransport(Transport):
    """Wrap a transport in a seeded, replayable fault schedule.

    The wrapper proxies ``have`` / ``unreachable`` to the inner
    transport (the session mutates them through the wrapper), counts
    rounds at each ``digests()`` call, and injects faults between the
    session and the fabric.  Faults surface exactly like real ones:
    a dropped digest or dead peer lands in ``unreachable`` (prefixed
    ``chaos:``), a damaged frame reaches the session's decode layer and
    is rejected there — the session code path under test is the real
    one, not a mock.
    """

    authoritative = False        # overridden per-instance from inner

    def __init__(self, inner: Transport, cfg: ChaosConfig = ChaosConfig(),
                 observer=None):
        # deliberately NOT calling super().__init__(): have/unreachable
        # live on the inner transport so the session sees one state
        self.inner = inner
        self.cfg = cfg
        self.obs = resolve(observer)
        self.name = f"chaos+{inner.name}"
        self.authoritative = inner.authoritative
        self.schedule: list[FaultEvent] = []
        self._round = -1           # first digests() call makes it round 0
        self._stash: dict = {}     # pid -> frame queued for next round
        self._quiesced = False

    # ---- session-visible state proxies ----
    @property
    def have(self) -> dict:
        return self.inner.have

    @property
    def unreachable(self) -> dict:
        return self.inner.unreachable

    # ---- deterministic decision stream ----
    def _rng(self, phase: str, pid, op: str) -> np.random.Generator:
        tag = zlib.crc32(f"{phase}|{pid}|{op}".encode())
        return np.random.default_rng((self.cfg.seed, self._round, tag))

    def _hit(self, p: float, phase: str, pid, op: str) -> bool:
        if p <= 0.0 or self._quiesced:
            return False
        if (self.cfg.quiesce_after is not None
                and self._round > self.cfg.quiesce_after):
            return False
        return float(self._rng(phase, pid, op).random()) < p

    def _down(self, pid, digest_phase: bool = False) -> Optional[str]:
        """Crash/partition verdict for this peer at the current round.

        On the crash round itself the peer still answers digests (it
        dies MID-session) — only pull/push see it down.
        """
        if self._quiesced:
            return None
        for c_pid, start, n_down in self.cfg.crashes:
            lo = start + 1 if digest_phase else start
            if str(c_pid) == str(pid) and lo <= self._round < start + n_down:
                return f"crashed r{start} (down {n_down} rounds)"
        for pids, start, heal in self.cfg.partitions:
            if start <= self._round < heal and any(
                    str(q) == str(pid) for q in pids):
                return f"partitioned rounds [{start},{heal})"
        return None

    def quiesce(self) -> None:
        """Switch every fault off (heal crashes and partitions too) —
        the settle window a convergence assertion runs in."""
        self._quiesced = True

    def _fault(self, phase: str, pid, kind: str, detail: str = "") -> None:
        ev = FaultEvent(round=self._round, phase=phase, pid=str(pid),
                        kind=kind, detail=detail)
        self.schedule.append(ev)
        self.obs.audit.record(
            "chaos", pid, action=kind, transport=self.name,
            detail=f"r{ev.round}/{phase}" + (f": {detail}" if detail else ""))
        self.obs.metrics.counter("chaos_faults", kind=kind).inc()

    # ---- the Transport surface ----
    def digests(self):
        self._round += 1
        digs, nbytes = self.inner.digests()    # inner resets unreachable
        out = {}
        for pid in sorted(digs, key=str):
            why = self._down(pid, digest_phase=True)
            if why:
                self.inner.unreachable[pid] = f"chaos: {why}"
                self._fault("digest", pid, "peer_down", why)
                continue
            if self._hit(self.cfg.p_drop_digest, "digest", pid, "drop"):
                self.inner.unreachable[pid] = "chaos: digest dropped"
                self._fault("digest", pid, "drop_digest")
                continue
            out[pid] = digs[pid]
        return out, nbytes

    def pull(self, peer_ids):
        live = []
        for pid in peer_ids:
            why = self._down(pid)
            if why:
                self.inner.unreachable[pid] = f"chaos: {why}"
                self._fault("pull", pid, "peer_down", why)
            else:
                live.append(pid)
        frames, nbytes = self.inner.pull(live)

        order = sorted(frames, key=str)
        if len(order) > 1 and self._hit(self.cfg.p_reorder, "pull",
                                        "*", "reorder"):
            perm = self._rng("pull", "*", "perm").permutation(len(order))
            order = [order[int(i)] for i in perm]
            self._fault("pull", "*", "reorder",
                        "->".join(str(p) for p in order))

        # frames stashed in an earlier round (duplicates / delays) are
        # redelivered now — stale by one-or-more rounds, which the
        # session's merge-on-ingest must absorb without regressing
        ready, self._stash = self._stash, {}
        out: dict = {}
        for pid, frame in ready.items():
            self._fault("pull", pid, "redeliver", f"{len(frame)}B stale")
            out[pid] = frame

        for pid in order:
            frame = frames[pid]
            if self._hit(self.cfg.p_drop_frame, "pull", pid, "drop"):
                self._fault("pull", pid, "drop_frame", f"{len(frame)}B")
                continue
            if self._hit(self.cfg.p_duplicate, "pull", pid, "dup"):
                self._stash[pid] = frame     # clean copy arrives AGAIN
                self._fault("pull", pid, "duplicate")
            if self._hit(self.cfg.p_truncate, "pull", pid, "trunc"):
                cut = int(self._rng("pull", pid, "cutpos").integers(
                    0, max(len(frame), 1)))
                self._fault("pull", pid, "truncate",
                            f"{cut}/{len(frame)}B")
                frame = frame[:cut]
            elif self._hit(self.cfg.p_bitflip, "pull", pid, "flip"):
                frame = _flip_bit(frame, self._rng("pull", pid, "flippos"))
                self._fault("pull", pid, "bitflip")
            if self._hit(self.cfg.p_delay, "pull", pid, "delay"):
                self._stash[pid] = frame     # arrives NEXT round instead
                self._fault("pull", pid, "delay")
                continue
            out[pid] = frame
        return out, nbytes

    def push(self, peer_ids, frame: bytes) -> int:
        sent = 0
        for pid in peer_ids:
            why = self._down(pid)
            if why:
                self.inner.unreachable[pid] = f"chaos: {why}"
                self._fault("push", pid, "peer_down", why)
                continue
            if self._hit(self.cfg.p_drop_push, "push", pid, "drop"):
                # the peer never saw the union: report it so the session
                # neither counts the bytes nor advances the have key
                self.inner.unreachable[pid] = "chaos: push dropped"
                self._fault("push", pid, "drop_push")
                continue
            out = frame
            if self._hit(self.cfg.p_bitflip_push, "push", pid, "flip"):
                out = _flip_bit(frame, self._rng("push", pid, "flippos"))
                self._fault("push", pid, "bitflip_push")
                try:
                    sent += self.inner.push([pid], out)
                except PeerRejected as e:
                    # the peer is alive and refused our damaged frame —
                    # under chaos that is the fabric's fault, not a bug
                    # in our encoder, so report instead of propagating
                    self.inner.unreachable[pid] = (
                        f"chaos: push rejected ({e})")
                    self._fault("push", pid, "push_rejected", str(e))
                continue
            sent += self.inner.push([pid], out)
        return sent

    def close(self) -> None:
        self.inner.close()


def corrupt_registry_row(registry, peer_id, seed: int = 0) -> None:
    """Flip state in one stored registry row WITHOUT refreshing its CRC
    — simulated bit rot / hostile mutation for the self-stabilization
    path (``ClockRegistry.check_integrity`` must flag the row,
    ``GossipConfig.verify_rows`` sessions must quarantine + repair it).
    """
    rng = np.random.default_rng((seed, zlib.crc32(str(peer_id).encode())))
    slot = registry.slot_of(peer_id)
    if slot in registry._wide:
        row = registry._wide[slot].copy()
        i = int(rng.integers(0, row.shape[0]))
        row[i] ^= row.dtype.type(1 << int(rng.integers(0, 16)))
        registry._wide[slot] = row
    else:
        cells = registry.cells_u8
        i = int(rng.integers(0, cells.shape[1]))
        flipped = int(np.asarray(cells[slot, i])) ^ (
            1 << int(rng.integers(0, 8)))
        registry.cells_u8 = registry._place2d(
            cells.at[slot, i].set(np.uint8(flipped)))
    registry._mat = None


def main(argv=None) -> int:
    """CI ``chaos-smoke``: one seeded hostile socket fleet, end to end.

    Runs ``core.sim.run_gossip_sim`` over a real TCP fabric wrapped in
    a ChaosTransport injecting drops, duplicates, damaged frames, and
    one mid-session peer crash, plus one corrupted registry row, then
    asserts the §3 story survived: zero false negatives, convergence to
    identical rows after faults quiesce, the corrupted row repaired,
    and the fault schedule + frame order replayable from the audit
    trail.
    """
    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run the seeded hostile-fleet smoke")
    ap.add_argument("--nodes", type=int, default=5)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--trace-dir", default=None,
                    help="write trace/metrics/audit JSONL here")
    args = ap.parse_args(argv)
    if not args.smoke:
        ap.error("nothing to do (pass --smoke)")

    from repro.causal import CausalPolicy
    from repro.core.sim import SimConfig, run_gossip_sim
    from repro.fleet.gossip import GossipConfig
    from repro.obs import AuditTrail, Observer

    obs = (Observer.to_dir(args.trace_dir) if args.trace_dir
           else Observer(audit=AuditTrail(store_frames=True)))
    chaos = ChaosConfig(
        seed=args.seed,
        p_drop_digest=0.10, p_drop_frame=0.15, p_duplicate=0.20,
        p_delay=0.10, p_reorder=0.30, p_truncate=0.10, p_bitflip=0.10,
        p_drop_push=0.10,
        crashes=((f"n{args.nodes - 1}", 2, 2),),
        quiesce_after=args.rounds - 1,
    )
    res = run_gossip_sim(
        SimConfig(n_nodes=args.nodes, n_events=150, m=64, k=3,
                  seed=args.seed),
        n_rounds=args.rounds,
        gossip_cfg=GossipConfig(policy=CausalPolicy(fp_threshold=1.0),
                                straggler_gap=np.inf, observer=obs,
                                merge_forked=True),
        transport="socket",
        chaos=chaos,
        corrupt_at=(3, 1),
    )
    print("chaos-smoke:", res.summary())

    failures = []
    if res.false_negatives:
        failures.append(f"false negatives: {res.false_negatives}")
    if not res.converged:
        failures.append("fleet did not converge after quiesce")
    if not res.fault_events:
        failures.append("chaos injected no faults (schedule empty)")
    if not res.repaired:
        failures.append("corrupted registry row was never repaired")

    # the trail must carry the realized fault schedule and replay the
    # session frames bit-for-bit (a failing run is a repro)
    chaos_recs = [r for r in obs.audit.records if r.kind == "chaos"]
    if not chaos_recs:
        failures.append("no chaos records in the audit trail")
    rep = obs.audit.replay_frames()
    if not rep.ok:
        failures.append(f"audit frame replay diverged: {rep.summary()}")
    print(f"chaos-smoke: {len(chaos_recs)} audited faults, "
          f"replay {rep.summary()}")

    if args.trace_dir:
        obs.close()
    if failures:
        for f in failures:
            print("chaos-smoke FAIL:", f, file=sys.stderr)
        return 1
    print("chaos-smoke: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
