"""Fleet health views built on the tiled all-pairs Pallas kernel.

``fleet_health`` runs ONE ``registry.all_pairs`` call — the symmetric
packed-triangle kernel over the gathered ALIVE rows only (dead slots
cost no compute and report all-False flags) — and derives, on host
numpy:

- **fork components**: connected components of the comparability graph
  (peers i, j connected iff their clocks are ordered either way).  A
  healthy fleet is one component; every extra component is a fork —
  a set of peers whose causal histories have diverged from the rest.
- **straggler mask**: alive peers whose clock sum lags the alive median
  by more than ``straggler_gap`` (clock sums are monotone progress
  counters).
- **predicted-fp histogram**: log10-binned Eq. 3 fp over the ordered
  pairs — the fleet's claimed-order confidence profile.  Validation
  against a MEASURED rate needs ground truth the monitor does not have;
  the simulator supplies it (``repro.core.sim.run_gossip_sim``) and
  ``fp_within_band`` is the shared check.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.fleet.registry import ClockRegistry

__all__ = ["FleetHealth", "fleet_health", "fork_components", "fp_within_band"]


@dataclasses.dataclass
class FleetHealth:
    n_alive: int
    comparable_fraction: float    # ordered pairs / alive pairs
    component: np.ndarray         # [capacity] component label, -1 for dead
    n_components: int             # fork count: healthy == 1 (or 0 if empty)
    straggler_mask: np.ndarray    # [capacity] bool
    sums: np.ndarray              # [capacity] float32 clock sums
    fp_hist: np.ndarray           # counts per log10-fp bin (ordered pairs)
    fp_bin_edges: np.ndarray      # len(fp_hist) + 1 edges, log10(fp)
    mean_predicted_fp: float      # mean Eq. 3 fp over ordered pairs
    shards: int = 1               # device shards the registry slab spans

    def summary(self) -> str:
        return (
            f"alive={self.n_alive} components={self.n_components} "
            f"comparable={self.comparable_fraction:.3f} "
            f"stragglers={int(self.straggler_mask.sum())} "
            f"mean_pred_fp={self.mean_predicted_fp:.3e} "
            f"shards={self.shards}"
        )


def fork_components(comparable: np.ndarray, alive: np.ndarray) -> tuple[np.ndarray, int]:
    """Union-find over the comparability graph.  Returns (labels, count);
    dead slots get label -1."""
    n = comparable.shape[0]
    parent = np.arange(n)

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    ii, jj = np.nonzero(comparable & alive[:, None] & alive[None, :])
    for i, j in zip(ii.tolist(), jj.tolist()):
        ri, rj = find(i), find(j)
        if ri != rj:
            parent[ri] = rj

    labels = np.full(n, -1, np.int64)
    roots: dict[int, int] = {}
    for i in np.flatnonzero(alive):
        r = find(int(i))
        labels[i] = roots.setdefault(r, len(roots))
    return labels, len(roots)


def fp_within_band(measured_fp: float, mean_predicted_fp: float,
                   slack: float = 3.0, abs_tol: float = 0.01) -> bool:
    """Is a measured false-positive rate consistent with the Eq. 3
    prediction?  Eq. 3 is an independence approximation, so we accept a
    multiplicative slack plus an absolute floor for small samples."""
    return measured_fp <= mean_predicted_fp * slack + abs_tol


def fleet_health(
    registry: ClockRegistry,
    *,
    straggler_gap: float = 64.0,
    fp_bins: int = 12,
    **matrix_kw,
) -> FleetHealth:
    """One all-pairs kernel call -> full fleet health snapshot."""
    h = jax.device_get(registry.all_pairs(**matrix_kw))   # ComparisonMatrix
    alive = np.asarray(registry.alive)
    n_alive = int(alive.sum())

    le = h.before()
    ge = h.after()
    comparable = (le | ge)
    np.fill_diagonal(comparable, False)

    pair_mask = alive[:, None] & alive[None, :]
    np.fill_diagonal(pair_mask, False)
    n_pairs = int(pair_mask.sum())
    n_ordered = int((comparable & pair_mask).sum())

    labels, n_components = fork_components(comparable, alive)

    sums = h.row_sums
    straggler = np.zeros_like(alive)
    if n_alive:
        med = float(np.median(sums[alive]))
        straggler = alive & ((med - sums) > straggler_gap)

    # ordered (strict) claims row->col: dominance holds and clocks differ
    strict = le & ~h.equal() & pair_mask
    fps = h.fp[strict]
    edges = np.linspace(-30.0, 0.0, fp_bins + 1)
    hist, _ = np.histogram(np.log10(np.clip(fps, 1e-30, 1.0)), bins=edges)

    return FleetHealth(
        n_alive=n_alive,
        comparable_fraction=n_ordered / max(n_pairs, 1),
        component=labels,
        n_components=n_components,
        straggler_mask=straggler,
        sums=sums,
        fp_hist=hist,
        fp_bin_edges=edges,
        mean_predicted_fp=float(fps.mean()) if fps.size else 0.0,
        shards=registry.n_shards,
    )
