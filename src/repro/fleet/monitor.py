"""Fleet health views built on the tiled all-pairs Pallas kernel.

``fleet_health`` runs ONE ``registry.all_pairs`` call — the symmetric
packed-triangle kernel over the gathered ALIVE rows only (dead slots
cost no compute and report all-False flags) — and derives, on host
numpy:

- **fork components**: connected components of the comparability graph
  (peers i, j connected iff their clocks are ordered either way).  A
  healthy fleet is one component; every extra component is a fork —
  a set of peers whose causal histories have diverged from the rest.
  Components run through ``scipy.sparse.csgraph`` when scipy is
  available (the Python union-find is the fallback) — ``watch()`` calls
  this every tick, so the O(pairs) Python loop matters.
- **straggler mask**: alive peers whose clock sum lags the alive median
  by more than ``straggler_gap`` (clock sums are monotone progress
  counters).
- **predicted-fp histogram**: log10-binned Eq. 3 fp over the strict
  ordered pairs — the fleet's claimed-order confidence profile.
  Validation against a MEASURED rate needs ground truth the monitor
  does not have; the simulator supplies it (``run_gossip_sim``) and the
  audit trail evaluates it continuously (``repro.obs.audit``);
  ``fp_within_band`` is the shared check.

``watch()`` turns the one-shot snapshot into a time series: it samples
``fleet_health`` periodically and folds every sample into an
``Observer``'s metrics registry (gauges + the streaming fp histogram),
yielding each snapshot so callers can also react inline.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterator, Optional

import jax
import numpy as np

from repro.fleet.registry import ClockRegistry
from repro.obs.observer import resolve

try:
    from scipy.sparse import csr_matrix
    from scipy.sparse.csgraph import connected_components as _scipy_cc
except ImportError:          # pragma: no cover - scipy ships in the image
    _scipy_cc = None

__all__ = ["FleetHealth", "fleet_health", "fork_components",
           "fp_within_band", "record_health", "watch"]


@dataclasses.dataclass
class FleetHealth:
    n_alive: int
    comparable_fraction: float    # ordered pairs / alive pairs
    component: np.ndarray         # [capacity] component label, -1 for dead
    n_components: int             # fork count: healthy == 1 (or 0 if empty)
    straggler_mask: np.ndarray    # [capacity] bool
    sums: np.ndarray              # [capacity] float32 clock sums
    fp_hist: np.ndarray           # counts per log10-fp bin (strict pairs)
    fp_bin_edges: np.ndarray      # len(fp_hist) + 1 edges, log10(fp)
    mean_strict_fp: float         # mean Eq. 3 fp over STRICT ordered pairs
                                  # (dominance holds, clocks differ);
                                  # 0.0 when no strict pair exists
    shards: int = 1               # device shards the registry slab spans

    @property
    def mean_predicted_fp(self) -> float:
        """Back-compat alias of ``mean_strict_fp`` (the old name implied
        all ordered pairs; the value was always strict-pairs-only)."""
        return self.mean_strict_fp

    def summary(self) -> str:
        return (
            f"alive={self.n_alive} components={self.n_components} "
            f"comparable={self.comparable_fraction:.3f} "
            f"stragglers={int(self.straggler_mask.sum())} "
            f"mean_strict_fp={self.mean_strict_fp:.3e} "
            f"shards={self.shards}"
        )


def _fork_components_py(comparable: np.ndarray,
                        alive: np.ndarray) -> tuple[np.ndarray, int]:
    """Pure-Python union-find fallback (O(pairs) — scipy path preferred)."""
    n = comparable.shape[0]
    parent = np.arange(n)

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    ii, jj = np.nonzero(comparable & alive[:, None] & alive[None, :])
    for i, j in zip(ii.tolist(), jj.tolist()):
        ri, rj = find(i), find(j)
        if ri != rj:
            parent[ri] = rj

    labels = np.full(n, -1, np.int64)
    roots: dict[int, int] = {}
    for i in np.flatnonzero(alive):
        r = find(int(i))
        labels[i] = roots.setdefault(r, len(roots))
    return labels, len(roots)


def fork_components(comparable: np.ndarray, alive: np.ndarray) -> tuple[np.ndarray, int]:
    """Connected components of the comparability graph over alive slots.

    Returns (labels, count); dead slots get label -1.  Labels are
    canonical — numbered by first occurrence in ascending slot order —
    so the scipy and pure-Python paths return identical arrays.
    """
    alive = np.asarray(alive, bool)
    if _scipy_cc is None:
        return _fork_components_py(comparable, alive)
    aidx = np.flatnonzero(alive)
    n = comparable.shape[0]
    labels = np.full(n, -1, np.int64)
    if aidx.size == 0:
        return labels, 0
    sub = np.asarray(comparable, bool)[np.ix_(aidx, aidx)]
    n_comp, sub_labels = _scipy_cc(csr_matrix(sub), directed=False)
    # canonical relabel: component ids by first occurrence, matching the
    # union-find's ascending-slot numbering bit-for-bit
    remap: dict[int, int] = {}
    for pos, slot in enumerate(aidx):
        labels[slot] = remap.setdefault(int(sub_labels[pos]), len(remap))
    return labels, int(n_comp)


def fp_within_band(measured_fp: float, mean_predicted_fp: float,
                   slack: float = 3.0, abs_tol: float = 0.01) -> bool:
    """Is a measured false-positive rate consistent with the Eq. 3
    prediction?  Eq. 3 is an independence approximation, so we accept a
    multiplicative slack plus an absolute floor for small samples."""
    return measured_fp <= mean_predicted_fp * slack + abs_tol


def fleet_health(
    registry: ClockRegistry,
    *,
    straggler_gap: float = 64.0,
    fp_bins: int = 12,
    **matrix_kw,
) -> FleetHealth:
    """One all-pairs kernel call -> full fleet health snapshot."""
    h = jax.device_get(registry.all_pairs(**matrix_kw))   # ComparisonMatrix
    alive = np.asarray(registry.alive)
    n_alive = int(alive.sum())

    le = h.before()
    ge = h.after()
    comparable = (le | ge)
    np.fill_diagonal(comparable, False)

    pair_mask = alive[:, None] & alive[None, :]
    np.fill_diagonal(pair_mask, False)
    n_pairs = int(pair_mask.sum())
    n_ordered = int((comparable & pair_mask).sum())

    labels, n_components = fork_components(comparable, alive)

    sums = h.row_sums
    straggler = np.zeros_like(alive)
    if n_alive:
        med = float(np.median(sums[alive]))
        straggler = alive & ((med - sums) > straggler_gap)

    # strict ordered claims row->col: dominance holds and clocks differ
    strict = le & ~h.equal() & pair_mask
    fps = h.fp[strict]
    edges = np.linspace(-30.0, 0.0, fp_bins + 1)
    hist, _ = np.histogram(np.log10(np.clip(fps, 1e-30, 1.0)), bins=edges)

    return FleetHealth(
        n_alive=n_alive,
        comparable_fraction=n_ordered / max(n_pairs, 1),
        component=labels,
        n_components=n_components,
        straggler_mask=straggler,
        sums=sums,
        fp_hist=hist,
        fp_bin_edges=edges,
        mean_strict_fp=float(fps.mean()) if fps.size else 0.0,
        shards=registry.n_shards,
    )


def record_health(health: FleetHealth, metrics) -> None:
    """Fold one health snapshot into a metrics registry."""
    metrics.gauge("fleet_alive").set(health.n_alive)
    metrics.gauge("fleet_components").set(health.n_components)
    metrics.gauge("fleet_comparable_fraction").set(
        health.comparable_fraction)
    metrics.gauge("fleet_stragglers").set(
        int(health.straggler_mask.sum()))
    metrics.gauge("fleet_mean_strict_fp").set(health.mean_strict_fp)
    metrics.histogram(
        "fleet_fp", edges=tuple(float(e) for e in health.fp_bin_edges),
    ).add_counts(health.fp_hist)
    metrics.counter("fleet_health_samples").inc()


def watch(
    registry: ClockRegistry,
    *,
    interval: float = 5.0,
    samples: Optional[int] = None,
    observer=None,
    **health_kw,
) -> Iterator[FleetHealth]:
    """Periodic ``fleet_health`` sampling into an Observer's metrics.

    A generator: every ``interval`` seconds (starting immediately) it
    takes one snapshot, records it (gauges + the streaming fp histogram
    — the confidence profile becomes a time series), and yields it, for
    ``samples`` ticks (None = forever).  The observer resolves from the
    argument, else the registry's policy; with neither, snapshots still
    yield but record nowhere.
    """
    obs = resolve(observer if observer is not None
                  else getattr(registry.policy, "observer", None))
    taken = 0
    while samples is None or taken < samples:
        with obs.trace.span("fleet.health"):
            health = fleet_health(registry, **health_kw)
        record_health(health, obs.metrics)
        taken += 1
        yield health
        if samples is not None and taken >= samples:
            break
        time.sleep(interval)
