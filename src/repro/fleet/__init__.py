"""Fleet causality subsystem: bulk bloom-clock tracking for whole fleets.

The paper's O(m) comparison only pays off when the machinery around it
is batch-oriented.  This package provides that machinery:

- ``registry``  — fixed-capacity slab of peer clocks with batched
  admit/evict/update and a single-device-call ``classify_all``;
- ``gossip``    — anti-entropy round config/report + the loopback round
  (batched merge, fork quarantine, straggler skipping);
- ``transport`` — the pluggable gossip fabric: one session protocol
  (digest → classify → delta → union → push-back) over loopback,
  mesh-collective (ppermute digest ring), and TCP socket transports;
- ``chaos``     — seeded, replayable fault injection
  (``ChaosTransport`` wraps any fabric: drops, duplicates, reorders,
  damaged frames, mid-session crashes, healing partitions);
- ``monitor``   — fleet health views built on the tiled all-pairs
  Pallas kernel (fork components, stragglers, fp histograms).
"""
from repro.fleet.chaos import ChaosConfig, ChaosTransport, FaultEvent
from repro.fleet.registry import (
    ANCESTOR,
    DEAD,
    DESCENDANT,
    FORKED,
    SAME,
    STATUS_NAMES,
    ClockRegistry,
    EvictedRow,
    FleetView,
    view_from_classify,
)
from repro.fleet.gossip import GossipConfig, GossipReport, gossip_round
from repro.fleet.monitor import FleetHealth, fleet_health
from repro.fleet.transport import (
    ClockNode,
    ClockPeerServer,
    LoopbackTransport,
    MeshCollectiveTransport,
    SocketTransport,
    Transport,
    anti_entropy_session,
)

__all__ = [
    "ClockRegistry",
    "EvictedRow",
    "FleetView",
    "view_from_classify",
    "GossipConfig",
    "GossipReport",
    "gossip_round",
    "anti_entropy_session",
    "Transport",
    "LoopbackTransport",
    "MeshCollectiveTransport",
    "SocketTransport",
    "ClockNode",
    "ClockPeerServer",
    "ChaosConfig",
    "ChaosTransport",
    "FaultEvent",
    "FleetHealth",
    "fleet_health",
    "ANCESTOR",
    "SAME",
    "DESCENDANT",
    "FORKED",
    "DEAD",
    "STATUS_NAMES",
]
