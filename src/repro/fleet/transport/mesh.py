"""MeshCollectiveTransport: digest exchange as a ppermute ring.

A mesh-sharded ``ClockRegistry`` already holds the fleet's rows as
``[N/d, m]`` per-device shards, and its classify / all-pairs kernels
run shard_map'd — a session over it needs no host-side row movement at
all.  What a round DOES need fleet-wide is the digest view (clock sums,
liveness, §4 bases) of every shard.  This transport runs that exchange
as a ``d-1``-hop ``ppermute`` ring over the fleet axis — each device
circulates its digest shard around the ring and assembles the
replicated full vectors on device, exactly like the all-pairs block-row
ring — then lands the result on host in ONE transfer.  Row shards
themselves never round-trip through the host: deltas don't exist
(the slab is authoritative) and push-back is the registry's batched
scatter, which XLA routes to each row's owning shard.

``digest_bytes`` reports the measured per-node inbound ring traffic:
``(d - 1)`` hops of one digest shard (f32 sum + bool alive + i32 base
per slot).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import wire
from repro.fleet.transport.base import Transport

__all__ = ["MeshCollectiveTransport"]


@functools.lru_cache(maxsize=16)
def _digest_ring_fn(mesh, axis: str):
    """Jitted shard_map'd digest all-gather: each device walks its
    (sums, alive, base) shard around the ring and every device returns
    the replicated full vectors.  Cached per (mesh, axis) so repeated
    sessions reuse the compiled ring."""
    d = mesh.shape[axis]

    def ring(sums, alive, base):
        nd = sums.shape[0]
        my = jax.lax.axis_index(axis)
        out_s = jnp.zeros((d * nd,), sums.dtype)
        out_a = jnp.zeros((d * nd,), alive.dtype)
        out_b = jnp.zeros((d * nd,), base.dtype)
        cs, ca, cb = sums, alive, base
        shift = [(i, (i + 1) % d) for i in range(d)]
        for h in range(d):
            if h:
                cs = jax.lax.ppermute(cs, axis, shift)
                ca = jax.lax.ppermute(ca, axis, shift)
                cb = jax.lax.ppermute(cb, axis, shift)
            src = (my - h) % d          # shard visiting this device now
            out_s = jax.lax.dynamic_update_slice(out_s, cs, (src * nd,))
            out_a = jax.lax.dynamic_update_slice(out_a, ca, (src * nd,))
            out_b = jax.lax.dynamic_update_slice(out_b, cb, (src * nd,))
        return out_s, out_a, out_b

    return jax.jit(shard_map(
        ring, mesh=mesh,
        in_specs=(P(axis),) * 3,
        out_specs=(P(),) * 3,
        check_rep=False,     # replication holds by construction (full ring)
    ))


class MeshCollectiveTransport(Transport):
    name = "mesh"
    authoritative = True

    def __init__(self, registry):
        super().__init__()
        if registry.mesh is None:
            raise ValueError(
                "MeshCollectiveTransport needs a mesh-sharded registry "
                "(ClockRegistry(..., mesh=make_fleet_mesh(...)))")
        self.registry = registry
        self._ring = _digest_ring_fn(registry.mesh, registry.axis)

    def digests(self) -> tuple[dict, int]:
        """Run the per-round digest exchange (the ring collective) and
        return the observer's replicated fleet view.

        The session itself only needs the exchange to have happened (the
        slab is authoritative, nothing is ingested); the digest dict is
        the host-side fleet view for callers above the session —
        dashboards, convergence checks, tests pinning ring-vs-slab
        agreement.  ``digest_bytes`` is derived from the vectors the
        ring actually circulated: each of the ``d - 1`` hops delivers
        one foreign shard of every vector to this node.
        """
        self._begin_round()
        r = self.registry
        sums, alive, base = jax.device_get(
            self._ring(r.sums, r.alive, r.base))
        slot_to_pid = {s: pid for pid, s in r._slot_of.items()}
        digs = {}
        for slot in np.flatnonzero(alive):
            pid = slot_to_pid.get(int(slot))
            if pid is None:
                continue          # evicted between scatter and ring
            # crc=0: content keys are never consulted on an
            # authoritative fabric — cells stay sharded on device
            digs[pid] = wire.ClockDigest(
                peer_id=str(pid), clock_sum=float(sums[slot]),
                base=int(base[slot]), m=r.m, k=r.k, crc=0)
        d = r.n_shards
        ring_bytes = (sum(v.nbytes for v in (sums, alive, base))
                      * (d - 1) // d)
        return digs, ring_bytes

    def pull(self, peer_ids) -> tuple[dict[str, bytes], int]:
        return {}, 0              # the sharded slab is authoritative

    def push(self, peer_ids, frame: bytes) -> int:
        # delivery is the session's registry.broadcast — one batched
        # scatter XLA routes to each accepted row's owning shard
        return len(frame) * len(peer_ids)
