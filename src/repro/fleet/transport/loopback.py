"""LoopbackTransport: the local registry slab IS the fleet.

The original single-process gossip deployment, expressed as a
transport: peer rows are already in the session registry (admitted by
whatever owns it), so the digest and delta phases carry zero bytes and
the session reduces to exactly the pre-transport ``gossip_round`` —
same masks, same merged cells, same Eq. 3 fp bits.  Push-back is the
registry broadcast the session already performs; this transport only
measures what the outbound half WOULD cost on a real wire (one encoded
§4 frame per accepted peer), so loopback reports are comparable with
socket reports byte-for-byte.
"""
from __future__ import annotations

from repro.core import wire
from repro.fleet.transport.base import Transport

__all__ = ["LoopbackTransport"]


class LoopbackTransport(Transport):
    name = "loopback"
    authoritative = True

    def __init__(self, registry):
        super().__init__()
        self.registry = registry

    def digests(self) -> tuple[dict[str, wire.ClockDigest], int]:
        self._begin_round()
        return {}, 0

    def pull(self, peer_ids) -> tuple[dict[str, bytes], int]:
        return {}, 0

    def push(self, peer_ids, frame: bytes) -> int:
        # delivery is the session's registry.broadcast; the frame length
        # is the measured per-peer wire cost of that outbound half
        return len(frame) * len(peer_ids)
