"""SocketTransport: anti-entropy over TCP between real processes.

The multi-host deployment of the gossip fabric.  Every participating
process runs a :class:`ClockPeerServer` — a tiny threaded TCP server
answering three requests about ONE node's clock — and a session on any
node reaches its peers through a :class:`SocketTransport` holding their
addresses.  All clock payloads are ``core.wire`` frames (§4 u8
residuals + base, versioned header, CRC trailer), so a truncated or
corrupted byte stream is rejected at decode, never merged.

Message envelope (both directions):

    bytes 0-3   payload length, u32
    byte  4     protocol version (1)
    byte  5     message type
    ...         payload

Types: ``DIGEST`` (empty -> digest frame), ``PULL`` (empty -> clock
frame), ``PUSH`` (clock frame -> 1-byte ack; the server merges the
union into its node, the §3 receive rule), ``ERR`` (utf-8 reason).

:class:`ClockNode` is the host-side clock state a server exposes: plain
numpy + a lock, so server processes need no device work to answer a
request.  Sessions stay pull-driven and idempotent — a node that
crashes and restarts re-converges from digests alone.
"""
from __future__ import annotations

import socket
import socketserver
import struct
import threading
import time

import numpy as np

from repro.core import wire
from repro.fleet.transport.base import Transport

__all__ = ["ClockNode", "ClockPeerServer", "PeerRejected",
           "SocketTransport", "TransportError"]

PROTO_VERSION = 1
MSG_DIGEST, MSG_PULL, MSG_PUSH, MSG_ACK, MSG_ERR = 1, 2, 3, 4, 255

_ENVELOPE = struct.Struct("!IBB")
_MAX_PAYLOAD = 64 * 1024 * 1024


class TransportError(RuntimeError):
    """A peer answered with an error or spoke a different protocol."""


class PeerRejected(TransportError):
    """The peer is ALIVE and explicitly refused the request (an
    ``MSG_ERR`` answer — e.g. a corrupted or wrong-shape frame we
    pushed).  Never treated as unreachability: the frame is our bug,
    so sessions let it propagate instead of skip-and-report."""


def _recv_exact(sock: socket.socket, n: int,
                deadline: float | None = None) -> bytes:
    """Read exactly ``n`` bytes, bounded by an absolute ``deadline``.

    A per-recv socket timeout alone does NOT bound a whole message: a
    peer that accepts the connection and then trickles one byte per
    almost-timeout (or stalls mid-frame after the header) resets the
    clock on every chunk, so the caller could block for ~n × timeout.
    With a deadline (``time.monotonic()`` instant), the remaining budget
    shrinks as chunks arrive and a mid-frame stall raises
    ``socket.timeout`` — an ``OSError`` the transport's skip-and-report
    path turns into an ``unreachable`` entry, never a dead round.
    """
    buf = bytearray()
    while len(buf) < n:
        if deadline is not None:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise socket.timeout(
                    f"message deadline exhausted mid-frame "
                    f"({len(buf)}/{n} bytes)")
            sock.settimeout(remaining)
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise TransportError(
                f"connection closed mid-message ({len(buf)}/{n} bytes)")
        buf += chunk
    return bytes(buf)


def _send_msg(sock: socket.socket, msg_type: int, payload: bytes = b"") -> None:
    sock.sendall(_ENVELOPE.pack(len(payload), PROTO_VERSION, msg_type)
                 + payload)


def _recv_msg(sock: socket.socket,
              deadline: float | None = None) -> tuple[int, bytes]:
    length, version, msg_type = _ENVELOPE.unpack(
        _recv_exact(sock, _ENVELOPE.size, deadline))
    if version != PROTO_VERSION:
        raise TransportError(
            f"peer speaks protocol version {version}, "
            f"this build speaks {PROTO_VERSION}")
    if length > _MAX_PAYLOAD:
        raise TransportError(f"refusing {length}-byte payload "
                             f"(cap {_MAX_PAYLOAD})")
    return msg_type, _recv_exact(sock, length, deadline)


class ClockNode:
    """One process's servable clock state: numpy cells + a lock.

    The owning process mutates it (``set_cells`` from its runtime clock,
    or inbound ``merge_snapshot`` applied by its server thread); any
    peer's session reads it through digest / snapshot requests.
    """

    def __init__(self, peer_id: str, m: int, k: int = 4):
        self.peer_id = str(peer_id)
        self.m = int(m)
        self.k = int(k)
        self._cells = np.zeros(m, np.int64)      # logical cells, base 0
        self._lock = threading.Lock()

    def set_cells(self, cells) -> None:
        cells = np.asarray(cells, np.int64)
        assert cells.shape == (self.m,), (cells.shape, self.m)
        with self._lock:
            self._cells = cells.copy()

    def cells(self) -> np.ndarray:
        with self._lock:
            return self._cells.copy()

    def merge_snapshot(self, snap: dict) -> None:
        """§3 receive rule: element-wise max with an inbound wire row."""
        inbound = (np.asarray(snap["cells"], np.int64)
                   + int(snap["base"]))
        if inbound.shape != (self.m,):
            raise wire.WireFormatError(
                f"frame carries m={inbound.shape[0]} cells, "
                f"node {self.peer_id!r} has m={self.m}")
        with self._lock:
            np.maximum(self._cells, inbound, out=self._cells)

    def snapshot(self) -> dict:
        """§4 wire form of the current cells (u8 residuals when the
        window fits a byte, int32 otherwise) — ``core.clock.to_wire``
        semantics without touching a device."""
        cells = self.cells()
        base = int(cells.min()) if cells.size else 0
        resid = cells - base
        if resid.max(initial=0) <= 255:
            out = resid.astype(np.uint8)
        else:
            out = resid.astype(np.int32)
        return {"cells": out, "base": base, "k": self.k}

    def digest(self) -> wire.ClockDigest:
        return wire.digest_of(self.peer_id, self.cells(), 0, self.k)


class _Handler(socketserver.BaseRequestHandler):
    #: per-request budget: a client that connects and stalls mid-frame
    #: (or never sends) releases its daemon thread instead of pinning it
    request_timeout = 30.0

    def handle(self):
        node: ClockNode = self.server.node    # type: ignore[attr-defined]
        try:
            self.request.settimeout(self.request_timeout)
            msg_type, payload = _recv_msg(
                self.request, time.monotonic() + self.request_timeout)
            if msg_type == MSG_DIGEST:
                _send_msg(self.request, MSG_DIGEST,
                          wire.encode_digest(node.digest()))
            elif msg_type == MSG_PULL:
                _send_msg(self.request, MSG_PULL,
                          wire.encode_clock(node.snapshot()))
            elif msg_type == MSG_PUSH:
                node.merge_snapshot(wire.decode_clock(payload))
                _send_msg(self.request, MSG_ACK, b"\x01")
            else:
                _send_msg(self.request, MSG_ERR,
                          f"unknown message type {msg_type}".encode())
        except socket.timeout:
            pass          # stalled client: drop it, free the thread
        except (wire.WireFormatError, TransportError) as e:
            try:
                _send_msg(self.request, MSG_ERR, str(e).encode())
            except OSError:
                pass


class _Server(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True


class ClockPeerServer:
    """Threaded TCP server exposing one ``ClockNode`` to the fleet."""

    def __init__(self, node: ClockNode, host: str = "127.0.0.1",
                 port: int = 0):
        self.node = node
        self._server = _Server((host, port), _Handler)
        self._server.node = node              # type: ignore[attr-defined]
        self._thread = threading.Thread(
            target=self._server.serve_forever, daemon=True,
            name=f"clock-peer-{node.peer_id}")

    @property
    def address(self) -> tuple[str, int]:
        return self._server.server_address[:2]

    def start(self) -> "ClockPeerServer":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._server.shutdown()
        self._server.server_close()


class SocketTransport(Transport):
    """Reach a fleet of ``ClockPeerServer`` processes over TCP.

    ``peers`` maps peer_id -> (host, port).  Connections are
    per-request (the payloads are one frame each); ``timeout`` guards
    every socket operation so a hung peer cannot stall the session.

    Unreachable peers are **skipped and reported**, not fatal: a
    connection-level failure on one peer (connect refused, timeout,
    closed mid-message, version/type confusion) records it (with the
    error) in ``self.unreachable`` and the phase continues with the
    remaining peers — a dead peer costs its timeout, never the round.
    An explicit ``MSG_ERR`` rejection (:class:`PeerRejected` — the peer
    is alive and says OUR frame is bad) still raises.
    ``unreachable`` resets at the next ``digests()`` call, so each
    session sees only its own round's skips; the session protocol turns
    the entries into ``peer_unreachable`` audit/metric events and
    surfaces them on ``GossipReport.unreachable``.
    """

    name = "socket"
    authoritative = False

    def __init__(self, peers: dict, timeout: float = 5.0):
        super().__init__()
        self.peers = {str(pid): tuple(addr) for pid, addr in peers.items()}
        self.timeout = timeout

    def _mark_unreachable(self, pid: str, err: Exception) -> None:
        self.unreachable[pid] = f"{type(err).__name__}: {err}"

    def _request(self, pid: str, msg_type: int,
                 payload: bytes = b"") -> bytes:
        host, port = self.peers[pid]
        # one absolute deadline for the WHOLE reply: a peer that accepts
        # then stalls (or trickles) mid-frame times out within ~timeout
        # total, not per-recv-chunk
        deadline = time.monotonic() + self.timeout
        with socket.create_connection((host, port),
                                      timeout=self.timeout) as sock:
            _send_msg(sock, msg_type, payload)
            kind, reply = _recv_msg(sock, deadline)
        if kind == MSG_ERR:
            raise PeerRejected(
                f"peer {pid!r} at {host}:{port} rejected the request: "
                f"{reply.decode(errors='replace')}")
        if kind != msg_type and not (msg_type == MSG_PUSH
                                     and kind == MSG_ACK):
            raise TransportError(
                f"peer {pid!r} answered type {kind} to a {msg_type} request")
        return reply

    def digests(self) -> tuple[dict[str, wire.ClockDigest], int]:
        self._begin_round()        # fresh skip list per session round
        digs, nbytes = {}, 0
        for pid in self.peers:
            try:
                reply = self._request(pid, MSG_DIGEST)
                digs[pid] = wire.decode_digest(reply)
                nbytes += len(reply)
            except PeerRejected:
                raise
            except (OSError, wire.WireFormatError, TransportError) as e:
                self._mark_unreachable(pid, e)
        return digs, nbytes

    def pull(self, peer_ids) -> tuple[dict[str, bytes], int]:
        frames, nbytes = {}, 0
        for pid in peer_ids:
            if pid in self.unreachable:
                continue
            try:
                frame = self._request(pid, MSG_PULL)
                frames[pid] = frame
                nbytes += len(frame)
            except PeerRejected:
                raise
            except (OSError, TransportError) as e:
                self._mark_unreachable(pid, e)
        return frames, nbytes

    def push(self, peer_ids, frame: bytes) -> int:
        sent = 0
        for pid in peer_ids:
            if pid in self.unreachable:
                continue
            try:
                self._request(pid, MSG_PUSH, frame)
                sent += len(frame)     # counted only on ack'd delivery
            except PeerRejected:
                raise
            except (OSError, TransportError) as e:
                self._mark_unreachable(pid, e)
        return sent
