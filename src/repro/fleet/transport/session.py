"""The transport-agnostic anti-entropy session protocol.

One session is the full reconcile a node runs when it wakes up,
factored so that WHERE the peer rows live is the transport's problem
and WHAT the node decides is shared, bit-for-bit, across fabrics:

1. **digest exchange** — ``transport.digests()`` advertises every
   peer's content key (clock-sum + §4 base + cells CRC).  Authoritative
   transports (loopback / mesh-collective) skip ingest entirely: the
   session registry already IS the peer state.
2. **delta pull** — only peers whose key differs from what this node
   last ingested are pulled, as ``core.wire`` clock frames.  A frame
   that fails decode (truncated / bit-flipped / version-skewed) is
   **rejected cleanly**: the peer keeps its previous row, lands on
   ``GossipReport.rejected`` with a ``frame_rejected`` audit record,
   and the round continues — one hostile frame never kills a session.
   Decoded rows are **merged** into existing rows (§3 receive rule),
   which makes duplicated and reordered deliveries idempotent: a stale
   duplicate can only re-assert history the row already contains.  Each
   ingested frame is audited (``frame_ingest``) in realized order, so a
   chaos run's message schedule replays from the trail.
3. **classify** — one ``registry.classify_all`` device call through the
   ``CausalEngine`` (shard_map'd transparently on a mesh-sharded slab).
4. **policy** — quarantine FORKED peers, skip stragglers, gate the
   comparable rest on the Eq. 3 confidence threshold.  Pure numpy on
   [N] host vectors; this is verbatim the pre-transport ``gossip_round``
   policy, which is what keeps loopback sessions bit-identical to it.
5. **union merge** — one batched max-reduce over the accepted rows
   (paper §3 receive rule fleet-wide), then §4 re-compress.
6. **push-back** — the union is written into the accepted registry rows
   (the local view of the outbound half) and shipped to the accepted
   peers as ONE encoded §4 wire frame via ``transport.push``.  Reported
   bytes are the measured ``len(frame)`` costs, not an estimate.

**Observability**: a session resolves its ``repro.obs.Observer`` from
``cfg.observer`` → ``cfg.policy.observer`` → the registry's policy, and
instruments every phase — a ``gossip.session`` span wrapping
``gossip.digest`` / ``gossip.pull`` / ``gossip.classify`` /
``gossip.union`` / ``gossip.push`` child spans, measured byte counters
per phase, peer-outcome counters, a streaming log10 histogram of the
claimed Eq. 3 fp, and an audit record for every acted-on verdict
(accepts AND quarantines) captured BEFORE push-back overwrites the rows
it was computed from.  Peers a non-authoritative transport reports
unreachable are skipped, audited, and surfaced on
``GossipReport.unreachable`` instead of aborting the round.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import clock as bc
from repro.core import wire
from repro.fleet import registry as reg
from repro.fleet.gossip import GossipConfig, GossipReport
from repro.fleet.transport.base import Transport
from repro.obs.observer import resolve

__all__ = ["anti_entropy_session"]

# log10(ms) bins for session round latency: 10µs .. 100s
_LATENCY_EDGES = tuple(float(x) for x in np.linspace(-2.0, 5.0, 15))


def _session_observer(cfg: GossipConfig, registry: reg.ClockRegistry):
    obs = cfg.observer
    if obs is None and cfg.policy is not None:
        obs = cfg.policy.observer
    if obs is None:
        obs = getattr(registry.policy, "observer", None)
    return resolve(obs)


def _ingest_delta(registry: reg.ClockRegistry, transport: Transport,
                  obs) -> tuple[int, int, dict, set]:
    """Digest exchange + delta pull into the session registry.

    Returns measured (digest_bytes, delta_bytes, rejected, revived) —
    ``revived`` is the pids whose quarantined (corrupt) row this pull
    rewrote, i.e. the gossip repairs that landed this session.
    Peers advertised with an unchanged content key are skipped; vanished
    peers are left in the registry (liveness is the registry owner's
    policy, not the wire's).

    Hostile-fleet hardening:

    - a frame that fails ``wire`` decode is dropped for THIS peer only
      (``rejected[pid] = reason``, audited as ``frame_rejected``); its
      ``have`` key is not advanced, so the next round re-pulls it;
    - a decoded row is **merged** with the live row it updates (§3
      receive rule) rather than overwriting it, so duplicated, delayed,
      or reordered deliveries are idempotent — a stale frame can only
      re-assert history the row already contains.  A quarantined
      (corrupt) row is replaced outright: merging would launder the
      corruption into the fresh pull;
    - every ingested frame leaves a ``frame_ingest`` audit record, which
      is the realized message order a replay needs.
    """
    with obs.trace.span("gossip.digest") as sp:
        digests, digest_bytes = transport.digests()
        sp.set(peers=len(digests), bytes=digest_bytes)
    if transport.authoritative:
        return digest_bytes, 0, {}, set()
    wanted = [pid for pid, d in digests.items()
              if transport.have.get(pid) != d.key]
    with obs.trace.span("gossip.pull", wanted=len(wanted)) as sp:
        if not wanted:
            sp.set(bytes=0)
            return digest_bytes, 0, {}, set()
        frames, delta_bytes = transport.pull(wanted)
        sp.set(pulled=len(frames), bytes=delta_bytes)
        clocks, rejected = {}, {}
        for pid, frame in frames.items():
            try:
                clocks[pid] = bc.from_wire(frame)
            except wire.WireFormatError as e:
                rejected[pid] = str(e)
                obs.audit.record("frame_rejected", pid,
                                 transport=transport.name, detail=str(e))
                obs.metrics.counter("frames_rejected",
                                    transport=transport.name).inc()
        known, fresh, revived = {}, {}, set()
        for pid, c in clocks.items():
            if pid not in registry:
                fresh[pid] = c
            elif registry.row_alive(pid):
                known[pid] = bc.merge(registry.get(pid), c)
            else:
                known[pid] = c       # quarantined row: replace, don't merge
                revived.add(pid)
        if known:
            registry.update_many(known)
        if fresh:
            registry.admit_many(fresh)
        if obs.audit:
            for pid, c in clocks.items():
                obs.audit.record(
                    "frame_ingest", pid, transport=transport.name,
                    peer_crc=wire.cells_crc(
                        np.asarray(c.logical_cells())))
        for pid in clocks:
            # record the key of the row we now HOLD (not the advertised
            # key): if a delayed/duplicated frame left the row stale, the
            # keys differ and the next digest exchange re-pulls the peer
            row = known[pid] if pid in known else fresh[pid]
            transport.have[pid] = (
                wire.cells_crc(np.asarray(row.logical_cells())),
                registry.m)
        if rejected:
            sp.set(rejected=len(rejected))
    return digest_bytes, delta_bytes, rejected, revived


def _audit_verdicts(obs, registry: reg.ClockRegistry,
                    local: bc.BloomClock, view: reg.FleetView,
                    masks: dict, cfg: GossipConfig,
                    transport_name: str) -> list:
    """One audit record per acted-on verdict, captured pre-push-back."""
    mat = np.asarray(registry._materialized())
    local_cells = np.asarray(local.logical_cells())
    local_crc = wire.cells_crc(local_cells)
    local_frame = (wire.encode_clock(bc.to_wire(local))
                   if obs.audit.store_frames else None)
    slot_pid = {registry.slot_of(pid): pid for pid in registry.peer_ids()}
    recs = []
    for action, mask in masks.items():
        for slot in np.flatnonzero(mask):
            pid = slot_pid.get(int(slot))
            if pid is None:
                continue
            peer_frame = None
            if obs.audit.store_frames:
                peer_frame = wire.encode_clock(
                    bc.to_wire(registry.get(pid)))
            recs.append(obs.audit.record(
                "verdict", pid,
                verdict=reg.STATUS_NAMES[int(view.status[slot])],
                action=action,
                fp=float(view.fp[slot]),
                threshold=float(cfg.fp_gate),
                engine=view.engine,
                local_crc=local_crc,
                peer_crc=wire.cells_crc(mat[slot]),
                local_sum=float(view.local_sum),
                peer_sum=float(view.sums[slot]),
                transport=transport_name,
                local_frame=local_frame,
                peer_frame=peer_frame,
            ))
    return recs


def anti_entropy_session(
    registry: reg.ClockRegistry,
    local: bc.BloomClock,
    transport: Transport,
    cfg: GossipConfig = GossipConfig(),
) -> tuple[bc.BloomClock, GossipReport]:
    """Run one anti-entropy session; returns (merged local clock, report)."""
    obs = _session_observer(cfg, registry)
    t0 = time.perf_counter_ns()
    with obs.trace.span("gossip.session", transport=transport.name,
                        shards=registry.n_shards) as sess_sp:
        corrupted: tuple = ()
        if cfg.verify_rows:
            with obs.trace.span("gossip.verify") as sp:
                bad = registry.check_integrity()
                sp.set(corrupted=len(bad))
            if bad:
                registry.quarantine_rows(bad)
                for pid in bad:
                    obs.audit.record(
                        "row_corrupt", pid, transport=transport.name,
                        detail="registry row CRC mismatch; quarantined "
                               "pending gossip repair")
                    obs.metrics.counter("rows_corrupt",
                                        transport=transport.name).inc()
                    if not transport.authoritative:
                        # force the delta phase to re-pull the row from
                        # any peer whose digest covers it
                        transport.have.pop(pid, None)
                corrupted = tuple(sorted(bad, key=str))

        digest_bytes, delta_bytes, rejected, revived = _ingest_delta(
            registry, transport, obs)

        # repairs are pulls that rewrote a quarantined row — including
        # rows quarantined in an EARLIER session whose re-pull the fabric
        # kept dropping until now
        repaired = tuple(sorted(revived, key=str))
        for pid in repaired:
            obs.audit.record("row_repaired", pid, transport=transport.name,
                             detail="corrupt row replaced by re-pulled "
                                    "peer frame")
            obs.metrics.counter("rows_repaired",
                                transport=transport.name).inc()

        with obs.trace.span("gossip.classify") as sp:
            view = registry.classify_all(local)
            sp.set(engine=view.engine, alive=int(view.alive.sum()))
        alive = view.alive

        forked = alive & (view.status == reg.FORKED)
        # §3 pure receive rule merges concurrent histories; the default
        # policy instead quarantines them as suspected replica divergence
        quarantined = (np.zeros_like(forked) if cfg.merge_forked
                       else forked)

        stragglers = np.zeros_like(alive)
        if alive.any():
            med = float(np.median(view.sums[alive]))
            stragglers = alive & ~quarantined & (
                (med - view.sums) > cfg.straggler_gap)

        comparable = alive & ~quarantined & ~stragglers
        unconfident = comparable & ~view.confident(cfg.fp_gate)
        accepted = comparable & ~unconfident

        if obs.audit:
            _audit_verdicts(
                obs, registry, local, view,
                {"accept": accepted, "quarantine": quarantined}, cfg,
                transport.name)

        merged = local
        pushback_bytes = 0
        if accepted.any():
            with obs.trace.span("gossip.union",
                                n=int(accepted.sum())):
                merged = registry.union(accepted, local)
                merged = bc.compress(merged)
            if cfg.push_back:
                with obs.trace.span("gossip.push") as sp:
                    snap = bc.to_wire(merged)
                    frame = wire.encode_clock(snap)
                    accepted_ids = [pid for pid in registry.peer_ids()
                                    if accepted[registry.slot_of(pid)]]
                    pushback_bytes = transport.push(accepted_ids, frame)
                    sp.set(peers=len(accepted_ids), bytes=pushback_bytes)
                    if transport.authoritative:
                        registry.broadcast(accepted, merged)
                    else:
                        # a staging row mirrors its PEER: only rows whose
                        # push was acknowledged may claim the union —
                        # writing it into an undelivered peer's row would
                        # fork the row from the peer it stands for
                        delivered = [pid for pid in accepted_ids
                                     if pid not in transport.unreachable]
                        dmask = np.zeros_like(accepted)
                        for pid in delivered:
                            dmask[registry.slot_of(pid)] = True
                        if dmask.any():
                            registry.broadcast(dmask, merged)
                        # the union row is now what those peers hold
                        # (unless they tick first, which the next digest
                        # exchange sees)
                        key = wire.digest_of("", snap["cells"],
                                             snap["base"], snap["k"]).key
                        for pid in delivered:
                            transport.have[pid] = key

        # peers the transport skipped-and-reported in ANY phase this
        # round (socket connect/timeout/protocol failures): audit +
        # metric per peer, session completed without them
        unreachable = dict(getattr(transport, "unreachable", {}) or {})
        for pid, err in unreachable.items():
            obs.metrics.counter("peer_unreachable",
                                transport=transport.name).inc()
            obs.audit.record("peer_unreachable", pid,
                             transport=transport.name, detail=str(err))

        sess_sp.set(accepted=int(accepted.sum()),
                    quarantined=int(quarantined.sum()),
                    unreachable=len(unreachable),
                    rejected=len(rejected),
                    corrupted=len(corrupted))

    if obs.metrics:
        ms = (time.perf_counter_ns() - t0) / 1e6
        obs.metrics.counter("gossip_sessions",
                            transport=transport.name).inc()
        obs.metrics.histogram("gossip_session_ms", edges=_LATENCY_EDGES,
                              transport=transport.name).observe(ms)
        for phase, nbytes in (("digest", digest_bytes),
                              ("delta", delta_bytes),
                              ("push", pushback_bytes)):
            obs.metrics.counter("gossip_bytes", phase=phase).inc(nbytes)
        for outcome, mask in (("accepted", accepted),
                              ("quarantined", quarantined),
                              ("stragglers", stragglers),
                              ("unconfident", unconfident)):
            n = int(mask.sum())
            if n:
                obs.metrics.counter("gossip_peers", outcome=outcome).inc(n)
        strict = alive & np.isin(view.status,
                                 (reg.ANCESTOR, reg.DESCENDANT))
        if strict.any():
            obs.metrics.histogram("fp_claimed").observe_many(
                view.fp[strict])

    return merged, GossipReport(
        accepted=accepted,
        quarantined=quarantined,
        stragglers=stragglers,
        unconfident=unconfident,
        view=view,
        pushback_bytes=pushback_bytes,
        digest_bytes=digest_bytes,
        delta_bytes=delta_bytes,
        transport=transport.name,
        shards=registry.n_shards,
        unreachable=tuple(sorted(unreachable)),
        rejected=tuple(sorted(rejected, key=str)),
        corrupted=corrupted,
        repaired=repaired,
    )
