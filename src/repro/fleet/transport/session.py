"""The transport-agnostic anti-entropy session protocol.

One session is the full reconcile a node runs when it wakes up,
factored so that WHERE the peer rows live is the transport's problem
and WHAT the node decides is shared, bit-for-bit, across fabrics:

1. **digest exchange** — ``transport.digests()`` advertises every
   peer's content key (clock-sum + §4 base + cells CRC).  Authoritative
   transports (loopback / mesh-collective) skip ingest entirely: the
   session registry already IS the peer state.
2. **delta pull** — only peers whose key differs from what this node
   last ingested are pulled, as ``core.wire`` clock frames, decoded
   (validated — truncated/corrupted frames raise, never merge) and
   scattered into the registry in one ``admit_many``/``update_many``
   batch.
3. **classify** — one ``registry.classify_all`` device call through the
   ``CausalEngine`` (shard_map'd transparently on a mesh-sharded slab).
4. **policy** — quarantine FORKED peers, skip stragglers, gate the
   comparable rest on the Eq. 3 confidence threshold.  Pure numpy on
   [N] host vectors; this is verbatim the pre-transport ``gossip_round``
   policy, which is what keeps loopback sessions bit-identical to it.
5. **union merge** — one batched max-reduce over the accepted rows
   (paper §3 receive rule fleet-wide), then §4 re-compress.
6. **push-back** — the union is written into the accepted registry rows
   (the local view of the outbound half) and shipped to the accepted
   peers as ONE encoded §4 wire frame via ``transport.push``.  Reported
   bytes are the measured ``len(frame)`` costs, not an estimate.

**Observability**: a session resolves its ``repro.obs.Observer`` from
``cfg.observer`` → ``cfg.policy.observer`` → the registry's policy, and
instruments every phase — a ``gossip.session`` span wrapping
``gossip.digest`` / ``gossip.pull`` / ``gossip.classify`` /
``gossip.union`` / ``gossip.push`` child spans, measured byte counters
per phase, peer-outcome counters, a streaming log10 histogram of the
claimed Eq. 3 fp, and an audit record for every acted-on verdict
(accepts AND quarantines) captured BEFORE push-back overwrites the rows
it was computed from.  Peers a non-authoritative transport reports
unreachable are skipped, audited, and surfaced on
``GossipReport.unreachable`` instead of aborting the round.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import clock as bc
from repro.core import wire
from repro.fleet import registry as reg
from repro.fleet.gossip import GossipConfig, GossipReport
from repro.fleet.transport.base import Transport
from repro.obs.observer import resolve

__all__ = ["anti_entropy_session"]

# log10(ms) bins for session round latency: 10µs .. 100s
_LATENCY_EDGES = tuple(float(x) for x in np.linspace(-2.0, 5.0, 15))


def _session_observer(cfg: GossipConfig, registry: reg.ClockRegistry):
    obs = cfg.observer
    if obs is None and cfg.policy is not None:
        obs = cfg.policy.observer
    if obs is None:
        obs = getattr(registry.policy, "observer", None)
    return resolve(obs)


def _ingest_delta(registry: reg.ClockRegistry, transport: Transport,
                  obs) -> tuple[int, int]:
    """Digest exchange + delta pull into the session registry.

    Returns measured (digest_bytes, delta_bytes).  Peers advertised with
    an unchanged content key are skipped; vanished peers are left in the
    registry (liveness is the registry owner's policy, not the wire's).
    """
    with obs.trace.span("gossip.digest") as sp:
        digests, digest_bytes = transport.digests()
        sp.set(peers=len(digests), bytes=digest_bytes)
    if transport.authoritative:
        return digest_bytes, 0
    wanted = [pid for pid, d in digests.items()
              if transport.have.get(pid) != d.key]
    with obs.trace.span("gossip.pull", wanted=len(wanted)) as sp:
        if not wanted:
            sp.set(bytes=0)
            return digest_bytes, 0
        frames, delta_bytes = transport.pull(wanted)
        sp.set(pulled=len(frames), bytes=delta_bytes)
        clocks = {pid: bc.from_wire(frame) for pid, frame in frames.items()}
        known = {pid: c for pid, c in clocks.items() if pid in registry}
        fresh = {pid: c for pid, c in clocks.items() if pid not in registry}
        if known:
            registry.update_many(known)
        if fresh:
            registry.admit_many(fresh)
        for pid in clocks:
            transport.have[pid] = digests[pid].key
    return digest_bytes, delta_bytes


def _audit_verdicts(obs, registry: reg.ClockRegistry,
                    local: bc.BloomClock, view: reg.FleetView,
                    masks: dict, cfg: GossipConfig,
                    transport_name: str) -> list:
    """One audit record per acted-on verdict, captured pre-push-back."""
    mat = np.asarray(registry._materialized())
    local_cells = np.asarray(local.logical_cells())
    local_crc = wire.cells_crc(local_cells)
    local_frame = (wire.encode_clock(bc.to_wire(local))
                   if obs.audit.store_frames else None)
    slot_pid = {registry.slot_of(pid): pid for pid in registry.peer_ids()}
    recs = []
    for action, mask in masks.items():
        for slot in np.flatnonzero(mask):
            pid = slot_pid.get(int(slot))
            if pid is None:
                continue
            peer_frame = None
            if obs.audit.store_frames:
                peer_frame = wire.encode_clock(
                    bc.to_wire(registry.get(pid)))
            recs.append(obs.audit.record(
                "verdict", pid,
                verdict=reg.STATUS_NAMES[int(view.status[slot])],
                action=action,
                fp=float(view.fp[slot]),
                threshold=float(cfg.fp_gate),
                engine=view.engine,
                local_crc=local_crc,
                peer_crc=wire.cells_crc(mat[slot]),
                local_sum=float(view.local_sum),
                peer_sum=float(view.sums[slot]),
                transport=transport_name,
                local_frame=local_frame,
                peer_frame=peer_frame,
            ))
    return recs


def anti_entropy_session(
    registry: reg.ClockRegistry,
    local: bc.BloomClock,
    transport: Transport,
    cfg: GossipConfig = GossipConfig(),
) -> tuple[bc.BloomClock, GossipReport]:
    """Run one anti-entropy session; returns (merged local clock, report)."""
    obs = _session_observer(cfg, registry)
    t0 = time.perf_counter_ns()
    with obs.trace.span("gossip.session", transport=transport.name,
                        shards=registry.n_shards) as sess_sp:
        digest_bytes, delta_bytes = _ingest_delta(registry, transport, obs)

        with obs.trace.span("gossip.classify") as sp:
            view = registry.classify_all(local)
            sp.set(engine=view.engine, alive=int(view.alive.sum()))
        alive = view.alive

        quarantined = alive & (view.status == reg.FORKED)

        stragglers = np.zeros_like(alive)
        if alive.any():
            med = float(np.median(view.sums[alive]))
            stragglers = alive & ~quarantined & (
                (med - view.sums) > cfg.straggler_gap)

        comparable = alive & ~quarantined & ~stragglers
        unconfident = comparable & ~view.confident(cfg.fp_gate)
        accepted = comparable & ~unconfident

        if obs.audit:
            _audit_verdicts(
                obs, registry, local, view,
                {"accept": accepted, "quarantine": quarantined}, cfg,
                transport.name)

        merged = local
        pushback_bytes = 0
        if accepted.any():
            with obs.trace.span("gossip.union",
                                n=int(accepted.sum())):
                merged = registry.union(accepted, local)
                merged = bc.compress(merged)
            if cfg.push_back:
                with obs.trace.span("gossip.push") as sp:
                    snap = bc.to_wire(merged)
                    frame = wire.encode_clock(snap)
                    registry.broadcast(accepted, merged)
                    accepted_ids = [pid for pid in registry.peer_ids()
                                    if accepted[registry.slot_of(pid)]]
                    pushback_bytes = transport.push(accepted_ids, frame)
                    sp.set(peers=len(accepted_ids), bytes=pushback_bytes)
                    if not transport.authoritative:
                        # the union row is now what those peers hold
                        # (unless they tick first, which the next digest
                        # exchange sees)
                        key = wire.digest_of("", snap["cells"],
                                             snap["base"], snap["k"]).key
                        for pid in accepted_ids:
                            if pid not in transport.unreachable:
                                transport.have[pid] = key

        # peers the transport skipped-and-reported in ANY phase this
        # round (socket connect/timeout/protocol failures): audit +
        # metric per peer, session completed without them
        unreachable = dict(getattr(transport, "unreachable", {}) or {})
        for pid, err in unreachable.items():
            obs.metrics.counter("peer_unreachable",
                                transport=transport.name).inc()
            obs.audit.record("peer_unreachable", pid,
                             transport=transport.name, detail=str(err))

        sess_sp.set(accepted=int(accepted.sum()),
                    quarantined=int(quarantined.sum()),
                    unreachable=len(unreachable))

    if obs.metrics:
        ms = (time.perf_counter_ns() - t0) / 1e6
        obs.metrics.counter("gossip_sessions",
                            transport=transport.name).inc()
        obs.metrics.histogram("gossip_session_ms", edges=_LATENCY_EDGES,
                              transport=transport.name).observe(ms)
        for phase, nbytes in (("digest", digest_bytes),
                              ("delta", delta_bytes),
                              ("push", pushback_bytes)):
            obs.metrics.counter("gossip_bytes", phase=phase).inc(nbytes)
        for outcome, mask in (("accepted", accepted),
                              ("quarantined", quarantined),
                              ("stragglers", stragglers),
                              ("unconfident", unconfident)):
            n = int(mask.sum())
            if n:
                obs.metrics.counter("gossip_peers", outcome=outcome).inc(n)
        strict = alive & np.isin(view.status,
                                 (reg.ANCESTOR, reg.DESCENDANT))
        if strict.any():
            obs.metrics.histogram("fp_claimed").observe_many(
                view.fp[strict])

    return merged, GossipReport(
        accepted=accepted,
        quarantined=quarantined,
        stragglers=stragglers,
        unconfident=unconfident,
        view=view,
        pushback_bytes=pushback_bytes,
        digest_bytes=digest_bytes,
        delta_bytes=delta_bytes,
        transport=transport.name,
        shards=registry.n_shards,
        unreachable=tuple(sorted(unreachable)),
    )
