"""The transport-agnostic anti-entropy session protocol.

One session is the full reconcile a node runs when it wakes up,
factored so that WHERE the peer rows live is the transport's problem
and WHAT the node decides is shared, bit-for-bit, across fabrics:

1. **digest exchange** — ``transport.digests()`` advertises every
   peer's content key (clock-sum + §4 base + cells CRC).  Authoritative
   transports (loopback / mesh-collective) skip ingest entirely: the
   session registry already IS the peer state.
2. **delta pull** — only peers whose key differs from what this node
   last ingested are pulled, as ``core.wire`` clock frames, decoded
   (validated — truncated/corrupted frames raise, never merge) and
   scattered into the registry in one ``admit_many``/``update_many``
   batch.
3. **classify** — one ``registry.classify_all`` device call through the
   ``CausalEngine`` (shard_map'd transparently on a mesh-sharded slab).
4. **policy** — quarantine FORKED peers, skip stragglers, gate the
   comparable rest on the Eq. 3 confidence threshold.  Pure numpy on
   [N] host vectors; this is verbatim the pre-transport ``gossip_round``
   policy, which is what keeps loopback sessions bit-identical to it.
5. **union merge** — one batched max-reduce over the accepted rows
   (paper §3 receive rule fleet-wide), then §4 re-compress.
6. **push-back** — the union is written into the accepted registry rows
   (the local view of the outbound half) and shipped to the accepted
   peers as ONE encoded §4 wire frame via ``transport.push``.  Reported
   bytes are the measured ``len(frame)`` costs, not an estimate.
"""
from __future__ import annotations

import numpy as np

from repro.core import clock as bc
from repro.core import wire
from repro.fleet import registry as reg
from repro.fleet.gossip import GossipConfig, GossipReport
from repro.fleet.transport.base import Transport

__all__ = ["anti_entropy_session"]


def _ingest_delta(registry: reg.ClockRegistry,
                  transport: Transport) -> tuple[int, int]:
    """Digest exchange + delta pull into the session registry.

    Returns measured (digest_bytes, delta_bytes).  Peers advertised with
    an unchanged content key are skipped; vanished peers are left in the
    registry (liveness is the registry owner's policy, not the wire's).
    """
    digests, digest_bytes = transport.digests()
    if transport.authoritative:
        return digest_bytes, 0
    wanted = [pid for pid, d in digests.items()
              if transport.have.get(pid) != d.key]
    if not wanted:
        return digest_bytes, 0
    frames, delta_bytes = transport.pull(wanted)
    clocks = {pid: bc.from_wire(frame) for pid, frame in frames.items()}
    known = {pid: c for pid, c in clocks.items() if pid in registry}
    fresh = {pid: c for pid, c in clocks.items() if pid not in registry}
    if known:
        registry.update_many(known)
    if fresh:
        registry.admit_many(fresh)
    for pid in clocks:
        transport.have[pid] = digests[pid].key
    return digest_bytes, delta_bytes


def anti_entropy_session(
    registry: reg.ClockRegistry,
    local: bc.BloomClock,
    transport: Transport,
    cfg: GossipConfig = GossipConfig(),
) -> tuple[bc.BloomClock, GossipReport]:
    """Run one anti-entropy session; returns (merged local clock, report)."""
    digest_bytes, delta_bytes = _ingest_delta(registry, transport)

    view = registry.classify_all(local)
    alive = view.alive

    quarantined = alive & (view.status == reg.FORKED)

    stragglers = np.zeros_like(alive)
    if alive.any():
        med = float(np.median(view.sums[alive]))
        stragglers = alive & ~quarantined & (
            (med - view.sums) > cfg.straggler_gap)

    comparable = alive & ~quarantined & ~stragglers
    unconfident = comparable & ~view.confident(cfg.fp_gate)
    accepted = comparable & ~unconfident

    merged = local
    pushback_bytes = 0
    if accepted.any():
        merged = registry.union(accepted, local)
        merged = bc.compress(merged)
        if cfg.push_back:
            snap = bc.to_wire(merged)
            frame = wire.encode_clock(snap)
            registry.broadcast(accepted, merged)
            accepted_ids = [pid for pid in registry.peer_ids()
                            if accepted[registry.slot_of(pid)]]
            pushback_bytes = transport.push(accepted_ids, frame)
            if not transport.authoritative:
                # the union row is now what those peers hold (unless
                # they tick first, which the next digest exchange sees)
                key = wire.digest_of("", snap["cells"], snap["base"],
                                     snap["k"]).key
                for pid in accepted_ids:
                    transport.have[pid] = key

    return merged, GossipReport(
        accepted=accepted,
        quarantined=quarantined,
        stragglers=stragglers,
        unconfident=unconfident,
        view=view,
        pushback_bytes=pushback_bytes,
        digest_bytes=digest_bytes,
        delta_bytes=delta_bytes,
        transport=transport.name,
        shards=registry.n_shards,
    )
