"""The Transport interface anti-entropy sessions are parameterized by.

A transport answers three questions for one node's gossip session, and
nothing else — classification, policy, and merging stay in the session
protocol (``fleet.transport.session``):

- ``digests()``   — the inbound half of the digest exchange: who are my
  peers and what is the content key of each one's clock right now?
- ``pull(ids)``   — the delta: encoded §4 wire frames for exactly the
  peers whose digest no longer matches what this node ingested.
- ``push(ids, frame)`` — the outbound half: ship the merged union row
  to the accepted peers.

Every method returns MEASURED byte counts (the length of the frames
that actually moved), so ``GossipReport`` wire costs are observations,
not model estimates.

``authoritative`` transports (loopback, mesh-collective) hold the peer
rows in the session's own registry slab — there is nothing to pull and
ingest, so their sessions reduce to exactly the pre-transport
``gossip_round`` (bit-identical masks, merged cells, and fp bits).  The
socket transport is non-authoritative: the session's registry is a
staging replica of remote processes, kept in sync by digest/delta.
"""
from __future__ import annotations

import abc

from repro.core import wire

__all__ = ["Transport"]


class Transport(abc.ABC):
    """Peer fabric one anti-entropy session runs over."""

    #: short name recorded in ``GossipReport.transport`` / bench records
    name: str = "abstract"

    #: True when the session registry IS the peer state (no delta phase)
    authoritative: bool = False

    def __init__(self) -> None:
        # content keys (``ClockDigest.key``) already ingested per peer:
        # the session pulls only peers whose advertised key differs, so
        # an unchanged fleet costs digest bytes only.
        self.have: dict = {}
        # peer_id -> error string for peers this session could not
        # reach.  A transport that skips-and-reports (socket) fills it
        # per round; the session audits the entries and surfaces them
        # on ``GossipReport.unreachable`` instead of aborting.
        # In-process transports only populate it under fault injection
        # (``fleet.chaos.ChaosTransport`` wraps any fabric).
        self.unreachable: dict = {}

    def _begin_round(self) -> None:
        """Reset per-round skip state.  Every transport's ``digests()``
        calls this first, so each session round sees only its own skips
        — including faults a wrapping ``ChaosTransport`` injects."""
        self.unreachable = {}

    @abc.abstractmethod
    def digests(self) -> tuple[dict[str, wire.ClockDigest], int]:
        """(peer_id -> digest, measured inbound digest bytes)."""

    @abc.abstractmethod
    def pull(self, peer_ids) -> tuple[dict[str, bytes], int]:
        """(peer_id -> encoded clock frame, measured inbound bytes)."""

    @abc.abstractmethod
    def push(self, peer_ids, frame: bytes) -> int:
        """Ship the merged-union frame to every peer; returns measured
        outbound bytes."""

    def close(self) -> None:
        """Release sockets/handles (no-op for in-process transports)."""
