"""Pluggable gossip transport fabric.

The anti-entropy protocol (``anti_entropy_session``) is one pure
session — digest exchange → classify via the ``CausalEngine`` → delta
pull of §4 wire rows → batched union merge → push-back — parameterized
by a :class:`Transport`:

- :class:`LoopbackTransport`        the local registry slab is the
  fleet (bit-identical to the original single-process ``gossip_round``);
- :class:`MeshCollectiveTransport`  mesh-sharded registries exchange
  digest shards over a ``ppermute`` ring, rows never leave the devices;
- :class:`SocketTransport`          real processes exchanging
  length-prefixed, CRC-checked ``core.wire`` frames over TCP
  (:class:`ClockPeerServer` / :class:`ClockNode` are the serving side).

Every report byte count is measured from the frames that actually
moved, so loopback, mesh, and socket sessions are comparable.
"""
from repro.fleet.transport.base import Transport
from repro.fleet.transport.loopback import LoopbackTransport
from repro.fleet.transport.mesh import MeshCollectiveTransport
from repro.fleet.transport.session import anti_entropy_session
from repro.fleet.transport.socket import (
    ClockNode,
    ClockPeerServer,
    SocketTransport,
    TransportError,
)

__all__ = [
    "Transport",
    "LoopbackTransport",
    "MeshCollectiveTransport",
    "SocketTransport",
    "ClockNode",
    "ClockPeerServer",
    "TransportError",
    "anti_entropy_session",
]
