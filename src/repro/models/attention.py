"""Attention: GQA with chunked online-softmax (XLA-only flash equivalent).

Materializing (B, H, S, S) scores at 32k+ context does not fit HBM, so
train/prefill attention streams KV in chunks with the online-softmax
recurrence (running max / normalizer), via lax.scan — the standard
flash-attention decomposition expressed at the XLA level (no Pallas here;
the paper's kernels are the bloom-clock ops, and XLA fuses this loop well).

Masks support: causal, sliding window (0 = off), non-causal (encoder /
cross).  Decode (Sq == 1) reuses the same path against a cache; sliding-
window decode uses a ring buffer (softmax is permutation-invariant over
KV so ring order needs no rotation — positions ride with the cached keys
via pre-applied RoPE).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rope

__all__ = ["attention_core", "attn_block", "KVCache",
           "decode_attention_split_kv"]

NEG_INF = -1e30


def decode_attention_split_kv(q, k, v, *, kv_valid, window, q_pos, mesh,
                              axis: str = "model"):
    """Split-KV decode attention (flash-decode): the cache stays sharded
    over ``axis`` along its seq dim; each shard computes partial softmax
    stats (m, l, acc) over its slice and the shards combine with
    pmax/psum — ~40x less traffic than all-gathering the cache (psum of a
    [B,1,H,Dv] accumulator vs all-gather of [B,S,KV,Dh] k AND v).

    q: [B, 1, H, Dh] (replicated inside — it is tiny);
    k/v: [B, Skv, KV, Dh] with Skv sharded over ``axis``.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    B, Sq, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV

    def local(q_l, k_l, v_l, kv_valid_l, window_l, q_pos_l):
        B_loc, S_loc = q_l.shape[0], k_l.shape[1]
        shard = jax.lax.axis_index(axis)
        kv_pos = shard * S_loc + jnp.arange(S_loc)
        qg = q_l.reshape(B_loc, Sq, KV, G, Dh).astype(jnp.float32)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, k_l.astype(jnp.float32))
        s = s / (Dh ** 0.5)
        mask = kv_pos < kv_valid_l
        w = jnp.asarray(window_l)
        mask = mask & ((w == 0) | (kv_pos > q_pos_l - w))
        s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)
        m_loc = jnp.max(s, axis=-1)
        p = jnp.exp(s - m_loc[..., None])
        l_loc = jnp.sum(p, axis=-1)
        acc_loc = jnp.einsum("bqkgc,bckd->bqkgd", p, v_l.astype(jnp.float32))
        # combine partial stats across seq shards
        m = jax.lax.pmax(m_loc, axis)
        corr = jnp.exp(m_loc - m)
        l = jax.lax.psum(l_loc * corr, axis)
        acc = jax.lax.psum(acc_loc * corr[..., None], axis)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.reshape(B_loc, Sq, H, v_l.shape[-1]).astype(q_l.dtype)

    # keep the batch dim sharded over the dp axes (replicating it would
    # all-gather the whole cache across data shards — measured 7x worse)
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = dp if B % max(1, __import__("math").prod(
        mesh.shape[a] for a in dp)) == 0 else None
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None, None, None), P(dp, axis, None, None),
                  P(dp, axis, None, None), P(), P(), P()),
        out_specs=P(dp, None, None, None),
        check_rep=False,
    )(q, k, v, jnp.asarray(kv_valid), jnp.asarray(window),
      jnp.asarray(q_pos))


def attention_core(
    q: jax.Array,            # [B, Sq, H, Dh]
    k: jax.Array,            # [B, Skv, KV, Dh]
    v: jax.Array,            # [B, Skv, KV, Dh]
    *,
    causal: bool,
    window,                  # int or traced scalar; 0 = full
    q_offset,                # scalar: absolute position of q[0]
    kv_valid,                # scalar: number of valid kv positions
    chunk: int,
    acc_dtype=jnp.float32,   # bf16 halves accumulator traffic (opt-in)
) -> jax.Array:
    B, Sq, H, Dh = q.shape
    _, Skv, KV, _ = k.shape
    Dv = v.shape[-1]          # value width may differ (MLA)
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, Dh).astype(acc_dtype)
    scale = jnp.asarray(1.0 / (Dh ** 0.5), acc_dtype)

    if Sq == 1:
        # decode: single-shot — scores are [B,1,H,Skv] (small), and a plain
        # einsum contraction over a sharded KV-seq dim lets SPMD emit
        # partial-softmax + reduce instead of gathering the cache
        chunk = Skv
    chunk = min(chunk, Skv)
    pad = (-Skv) % chunk
    if pad:  # padded tail is masked off via kv_valid
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_valid = jnp.minimum(jnp.asarray(kv_valid), Skv)
        Skv = Skv + pad
    n_chunks = Skv // chunk

    q_pos = q_offset + jnp.arange(Sq)  # absolute q positions

    def body(carry, c_idx):
        acc, m_run, l_run = carry
        start = c_idx * chunk
        kc = jax.lax.dynamic_slice_in_dim(k, start, chunk, axis=1).astype(acc_dtype)
        vc = jax.lax.dynamic_slice_in_dim(v, start, chunk, axis=1).astype(acc_dtype)
        kv_pos = start + jnp.arange(chunk)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kc,
                       preferred_element_type=jnp.float32) * scale.astype(jnp.float32)
        mask = kv_pos[None, :] < kv_valid  # validity
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        w = jnp.asarray(window)
        mask = mask & ((w == 0) | (kv_pos[None, :] > q_pos[:, None] - w))
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        acc = acc * corr.astype(acc_dtype)[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p.astype(acc_dtype), vc)
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((B, Sq, KV, G, Dv), acc_dtype)
    m0 = jnp.full((B, Sq, KV, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), jnp.float32)
    if n_chunks == 1:
        (acc, m_run, l_run), _ = body((acc0, m0, l0), 0)
    else:
        (acc, m_run, l_run), _ = jax.lax.scan(
            body, (acc0, m0, l0), jnp.arange(n_chunks)
        )
    out = acc.astype(jnp.float32) / jnp.maximum(l_run, 1e-30)[..., None]
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("k", "v", "length", "pos"), meta_fields=("ring",))
@dataclasses.dataclass
class KVCache:
    """Decode cache. k/v: [B, S_buf, KV, Dh] (ring buffer when windowed).

    length: valid entries; pos: absolute position of the next token.
    """

    k: jax.Array
    v: jax.Array
    length: jax.Array   # int32 scalar
    pos: jax.Array      # int32 scalar
    ring: bool = False


def init_cache(cfg: ModelConfig, batch: int, buf_len: int, kv_heads: int,
               d_head: int, ring: bool = False) -> KVCache:
    dt = cfg.compute_dtype
    return KVCache(
        k=jnp.zeros((batch, buf_len, kv_heads, d_head), dt),
        v=jnp.zeros((batch, buf_len, kv_heads, d_head), dt),
        length=jnp.zeros((), jnp.int32),
        pos=jnp.zeros((), jnp.int32),
        ring=ring,
    )


def _sharded_slot_update(buf_arr, new_row, slot, mesh, axis: str = "model"):
    """Owner-writes dynamic update on a seq-sharded buffer.

    A plain dynamic_update_slice on a sharded dim makes SPMD all-gather
    the whole cache to write ONE token (measured 0.5 GB/layer/step on
    qwen110b decode).  Instead each shard checks whether it owns ``slot``
    and updates locally — zero collectives.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    import math
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    if buf_arr.shape[0] % max(1, math.prod(mesh.shape[a] for a in dp)):
        dp = None

    def local(b_loc, n_loc, slot_g):
        S_loc = b_loc.shape[1]
        shard = jax.lax.axis_index(axis)
        slot_local = slot_g - shard * S_loc
        inside = (slot_local >= 0) & (slot_local < S_loc)
        upd = jax.lax.dynamic_update_slice_in_dim(
            b_loc, n_loc.astype(b_loc.dtype),
            jnp.clip(slot_local, 0, S_loc - 1), axis=1)
        return jnp.where(inside, upd, b_loc)

    nd = buf_arr.ndim
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, axis, *([None] * (nd - 2))),
                  P(dp, *([None] * (nd - 1))), P()),
        out_specs=P(dp, axis, *([None] * (nd - 2))),
        check_rep=False,
    )(buf_arr, new_row, jnp.asarray(slot))


def cache_update(cache: KVCache, k_new: jax.Array, v_new: jax.Array,
                 sharded_axis_mesh=None) -> KVCache:
    """Append one step (Sq=1) at the ring/linear write position."""
    buf = cache.k.shape[1]
    slot = jnp.where(cache.ring, cache.pos % buf, jnp.minimum(cache.pos, buf - 1))
    mesh = sharded_axis_mesh
    if (mesh is not None and "model" in mesh.shape
            and buf % mesh.shape["model"] == 0):
        k = _sharded_slot_update(cache.k, k_new, slot, mesh)
        v = _sharded_slot_update(cache.v, v_new, slot, mesh)
    else:
        k = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new.astype(cache.k.dtype), slot, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new.astype(cache.v.dtype), slot, axis=1)
    return KVCache(k=k, v=v, length=jnp.minimum(cache.length + 1, buf),
                   pos=cache.pos + 1, ring=cache.ring)


def attn_block(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,                 # [B, Sq, D]
    *,
    positions: jax.Array,         # [Sq] absolute
    causal: bool = True,
    window=0,
    cache: KVCache | None = None,
    xa: jax.Array | None = None,  # cross-attention source [B, Se, D]
):
    """Full GQA block: qkv proj, rope, core, out proj.

    Returns (out [B,Sq,D], new_cache | None).
    """
    dt = cfg.compute_dtype
    B, Sq, D = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head

    q = x @ params["wq"].astype(dt)
    if "bq" in params:
        q = q + params["bq"].astype(dt)
    q = q.reshape(B, Sq, H, Dh)

    kv_src = xa if xa is not None else x
    k = kv_src @ params["wk"].astype(dt)
    v = kv_src @ params["wv"].astype(dt)
    if "bk" in params:
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    k = k.reshape(B, kv_src.shape[1], KV, Dh)
    v = v.reshape(B, kv_src.shape[1], KV, Dh)

    if cfg.pos == "rope" and xa is None:
        q = rope(q, positions, cfg)
        k = rope(k, positions, cfg)

    new_cache = (k, v)  # train/prefill: expose kv so the stack can build a cache
    if cache is not None and xa is None:
        from repro.sharding import current_mesh

        _mesh = current_mesh() if cfg.decode_attn == "split_kv" else None
        new_cache = cache_update(cache, k, v, sharded_axis_mesh=_mesh)
        k, v = new_cache.k, new_cache.v
        kv_valid = new_cache.length
        q_offset = new_cache.pos - 1  # position of the token being decoded
        # linear cache: slot == absolute position, so window masking applies.
        # ring cache: buffer size == window, eviction enforces it; positions
        # in the ring are not absolute so the mask must stay off.
        w_eff = 0 if cache.ring else window
        from repro.sharding import current_mesh

        mesh = current_mesh()
        if (cfg.decode_attn == "split_kv" and mesh is not None
                and "model" in mesh.shape
                and k.shape[1] % mesh.shape["model"] == 0):
            out = decode_attention_split_kv(
                q, k, v, kv_valid=kv_valid, window=w_eff, q_pos=q_offset,
                mesh=mesh)
        else:
            out = attention_core(
                q, k, v, causal=False, window=w_eff, q_offset=q_offset,
                kv_valid=kv_valid, chunk=cfg.attn_chunk,
                acc_dtype=jnp.bfloat16 if cfg.attn_acc == "bf16" else jnp.float32,
            )
    else:
        kv_valid = k.shape[1]
        out = attention_core(
            q, k, v, causal=causal and xa is None, window=window,
            q_offset=positions[0] if causal else 0,
            kv_valid=kv_valid, chunk=cfg.attn_chunk,
            acc_dtype=jnp.bfloat16 if cfg.attn_acc == "bf16" else jnp.float32,
        )
    out = out.reshape(B, Sq, H * Dh) @ params["wo"].astype(dt)
    return out, new_cache
