"""Mixture-of-Experts FFN with two interchangeable distribution strategies.

``moe_impl = "gather"`` (pjit baseline, paper-era standard):
  top-k routing -> sort token-slots by expert -> capacity-bounded
  scatter into an (E, C, D) per-expert buffer -> batched expert matmuls
  -> scatter-add combine.  Pure pjit: XLA SPMD inserts the (expensive)
  cross-shard gathers/reduces.  Compiles everywhere; its collective cost
  is the §Perf baseline.

``moe_impl = "alltoall"`` (shard_map optimized path):
  tokens are sharded over (dp axes x model); each shard routes its own
  tokens and exchanges expert buckets with explicit ``jax.lax.all_to_all``
  over the model axis (true expert parallelism); each device computes only
  its local expert slots over tokens from every peer.

``moe_replicas > 1`` stores physical copies of each expert
(params-level; round-robin routing by token parity) so EP stays uniform
when n_experts < model-axis size (grok: 8 experts x 2 replicas on a
16-wide axis).  Replicas start identical and diverge under training —
an intentional capacity/load-balance variant, documented in DESIGN.md.

Both paths drop overflow tokens (capacity factor), add the standard
load-balance auxiliary loss, and weight top-k combine by softmax gates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import mlp, sub

__all__ = ["moe_ffn"]


def _top_k_gates(logits: jax.Array, k: int):
    """softmax-renormalized top-k gates. logits [T, E] -> (gates [T,k], idx [T,k])."""
    gates, idx = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(gates, axis=-1)
    return gates, idx


def _aux_loss(logits: jax.Array, idx: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style load-balance loss: E * <fraction routed> . <router prob>."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)  # [T, E]
    me = jnp.mean(probs, axis=0)
    one_hot = jax.nn.one_hot(idx[:, 0], n_experts, dtype=jnp.float32)
    ce = jnp.mean(one_hot, axis=0)
    return n_experts * jnp.sum(me * ce)


def _phys_idx(idx: jax.Array, replicas: int):
    """Map logical expert ids -> physical slots (round-robin by token)."""
    if replicas == 1:
        return idx
    T, k = idx.shape
    rep = (jnp.arange(T)[:, None] + jnp.arange(k)[None, :]) % replicas
    return idx * replicas + rep


def _dispatch_indices(idx: jax.Array, T: int, k: int, E: int, C: int):
    """Routing bookkeeping shared by both impls.

    Returns (slot_token [T*k], slot_expert [T*k], rank_in_expert [T*k],
             keep [T*k]) with slots sorted by expert.
    """
    slot_expert = idx.reshape(-1)                       # [T*k]
    order = jnp.argsort(slot_expert, stable=True)       # slots grouped by expert
    slot_expert_s = slot_expert[order]
    slot_token_s = (jnp.arange(T * k) // k)[order]
    first = jnp.searchsorted(slot_expert_s, jnp.arange(E), side="left")
    rank = jnp.arange(T * k) - first[slot_expert_s]
    keep = rank < C
    return slot_token_s, slot_expert_s, rank, keep


def _expert_mlp(cfg: ModelConfig, xe: jax.Array, w_gate, w_up, w_down):
    """xe [E, C, D] through per-expert gated MLP."""
    dt = cfg.compute_dtype
    g = jnp.einsum("ecd,edf->ecf", xe, w_gate.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xe, w_up.astype(dt))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down.astype(dt))


def _route_and_bucket(cfg: ModelConfig, x2d, router, E_phys: int, C: int):
    """Shared per-(global or local)-view routing: returns xe, combine info."""
    dt = cfg.compute_dtype
    T, D = x2d.shape
    k = cfg.top_k
    logits = x2d @ router.astype(dt)
    gates, idx = _top_k_gates(logits, k)
    aux = _aux_loss(logits, idx, cfg.n_experts)
    idx_phys = _phys_idx(idx, cfg.moe_replicas)
    tok, exp, rank, keep = _dispatch_indices(idx_phys, T, k, E_phys, C)
    dest = exp * C + jnp.minimum(rank, C - 1)
    xe = jnp.zeros((E_phys * C, D), dt)
    xe = xe.at[dest].add(jnp.where(keep[:, None], x2d[tok], 0), mode="drop")
    gate_of_slot = gates.reshape(-1)[jnp.argsort(idx_phys.reshape(-1),
                                                 stable=True)]
    return xe, (tok, dest, keep, gate_of_slot), aux


def _combine(x2d_shape, dt, ye_flat, tok, dest, keep, gate_of_slot):
    y = jnp.zeros(x2d_shape, dt)
    return y.at[tok].add(
        jnp.where(keep[:, None], ye_flat[dest] * gate_of_slot[:, None], 0),
        mode="drop")


def _moe_gather(params: dict, cfg: ModelConfig, x2d: jax.Array):
    """pjit sort-gather-scatter formulation over the global token view."""
    T = x2d.shape[0]
    E_phys = cfg.n_experts * cfg.moe_replicas
    C = max(1, int(cfg.capacity_factor * T * cfg.top_k / E_phys))
    xe, (tok, dest, keep, gate), aux = _route_and_bucket(
        cfg, x2d, params["router"], E_phys, C)
    ye = _expert_mlp(cfg, xe.reshape(E_phys, C, -1),
                     params["w_gate"], params["w_up"], params["w_down"])
    y = _combine(x2d.shape, x2d.dtype, ye.reshape(E_phys * C, -1),
                 tok, dest, keep, gate)
    return y, aux


def _moe_alltoall(params: dict, cfg: ModelConfig, x2d: jax.Array,
                  mesh, dp_axes, ep_axis: str):
    """shard_map expert-parallel path with explicit all_to_all.

    Tokens are sharded over dp_axes + (ep_axis,): every device routes only
    its own token shard (no redundant routing across the model axis), then
    all_to_all over ep_axis moves expert buckets to their owners.
    """
    from jax.experimental.shard_map import shard_map

    ep = mesh.shape[ep_axis]
    E_phys = cfg.n_experts * cfg.moe_replicas
    assert E_phys % ep == 0, (E_phys, ep, "pick moe_replicas so ep | E_phys")
    E_loc = E_phys // ep
    dt = cfg.compute_dtype

    def local(x_loc, router, w_gate, w_up, w_down):
        T_loc, D = x_loc.shape
        C_loc = max(1, int(cfg.capacity_factor * T_loc * cfg.top_k / E_phys))
        xe, (tok, dest, keep, gate), aux = _route_and_bucket(
            cfg, x_loc, router, E_phys, C_loc)
        # [ep, E_loc*C_loc, D] -> each device receives its experts' buckets
        # from every peer: [ep(peers)*E_loc*C_loc, D]
        xe = xe.reshape(ep, E_loc * C_loc, D)
        xe = jax.lax.all_to_all(xe, ep_axis, split_axis=0, concat_axis=0)
        xe = (xe.reshape(ep, E_loc, C_loc, D).transpose(1, 0, 2, 3)
                .reshape(E_loc, ep * C_loc, D))
        ye = _expert_mlp(cfg, xe, w_gate, w_up, w_down)
        ye = (ye.reshape(E_loc, ep, C_loc, D).transpose(1, 0, 2, 3)
                .reshape(ep, E_loc * C_loc, D))
        ye = jax.lax.all_to_all(ye, ep_axis, split_axis=0, concat_axis=0)
        y = _combine(x_loc.shape, x_loc.dtype, ye.reshape(E_phys * C_loc, D),
                     tok, dest, keep, gate)
        return y, aux[None]

    token_axes = tuple(dp_axes) + (ep_axis,)
    dp_spec = P(token_axes, None)
    y, aux = shard_map(
        local,
        mesh=mesh,
        in_specs=(dp_spec, P(None, None), P(ep_axis, None, None),
                  P(ep_axis, None, None), P(ep_axis, None, None)),
        out_specs=(dp_spec, P(token_axes)),
        check_rep=False,
    )(x2d, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])
    return y, jnp.mean(aux)


def moe_ffn(params: dict, cfg: ModelConfig, x: jax.Array, *,
            mesh=None, dp_axes=None, ep_axis: str = "model"):
    """MoE FFN over [B, S, D]. Returns (y, aux_loss). Adds shared experts."""
    B, S, D = x.shape
    x2d = x.reshape(B * S, D)

    use_a2a = (cfg.moe_impl == "alltoall" and mesh is not None
               and ep_axis in mesh.shape
               and (cfg.n_experts * cfg.moe_replicas) % mesh.shape[ep_axis] == 0
               and (B * S) % (mesh.shape[ep_axis] *
                              max(1, __import__("math").prod(
                                  mesh.shape[a] for a in (dp_axes or ())))) == 0)
    if use_a2a:
        y2d, aux = _moe_alltoall(params, cfg, x2d, mesh, dp_axes or (), ep_axis)
    else:
        y2d, aux = _moe_gather(params, cfg, x2d)

    y = y2d.reshape(B, S, D)
    if cfg.n_shared_experts:
        y = y + mlp(sub(params, "shared"), cfg, x)
    return y, aux
