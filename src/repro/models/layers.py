"""Shared primitive layers: norms, RoPE, MLPs, embeddings.

Pure functions over flat param dicts (path -> array).  ``sub(params, p)``
narrows to a prefix so blocks compose: attention reads "wq", the layer
passes ``sub(params, "attn")``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = ["sub", "norm", "rope", "mlp", "embed_tokens"]


def sub(params: dict, prefix: str) -> dict:
    pre = prefix + "/"
    return {k[len(pre):]: v for k, v in params.items() if k.startswith(pre)}


def norm(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """RMSNorm or LayerNorm in fp32, cast back to compute dtype."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    scale = params["scale"].astype(jnp.float32)
    if cfg.norm == "layernorm":
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mean) * jax.lax.rsqrt(var + 1e-5) * scale + params["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + 1e-6) * scale
    return out.astype(dt)


def _rope_angles(positions: jax.Array, dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions [...,] -> (cos, sin) of shape [..., dim//2]."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def rope(x: jax.Array, positions: jax.Array, cfg: ModelConfig, dim: int | None = None) -> jax.Array:
    """Rotary embedding on the last dim (partial when cfg.rope_pct < 1).

    x: [..., S, H, Dh]; positions: [S] or [..., S] absolute positions.
    Pairs are (even, odd) interleaved — GPT-NeoX "half-split" layout.
    """
    Dh = x.shape[-1]
    rot = dim if dim is not None else int(Dh * cfg.rope_pct)
    rot = max(2, (rot // 2) * 2)
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    cos, sin = _rope_angles(positions, rot, cfg.rope_theta)  # [..., S, rot/2]
    # broadcast over heads: positions [..., S] -> [..., S, 1, rot/2]
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x_rot, 2, axis=-1)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.concatenate([r1, r2, x_pass], axis=-1)
    return out.astype(x.dtype)


def mlp(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    dt = cfg.compute_dtype
    if cfg.act == "silu_glu":
        g = x @ params["w_gate"].astype(dt)
        u = x @ params["w_up"].astype(dt)
        return (jax.nn.silu(g) * u) @ params["w_down"].astype(dt)
    h = x @ params["w_in"].astype(dt) + params["b_in"].astype(dt)
    h = jax.nn.gelu(h)
    return h @ params["w_out"].astype(dt) + params["b_out"].astype(dt)


def embed_tokens(params: dict, cfg: ModelConfig, tokens: jax.Array) -> jax.Array:
    """Token ids -> embeddings via one-hot matmul (TPU-friendly gather)."""
    table = params["embed/tokens"].astype(cfg.compute_dtype)
    return jnp.take(table, tokens, axis=0)


def unembed(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["embed/tokens"].astype(cfg.compute_dtype).T
    else:
        w = params["lm_head"].astype(cfg.compute_dtype)
    logits = x @ w
    if cfg.logit_cap > 0:
        logits = cfg.logit_cap * jnp.tanh(logits / cfg.logit_cap)
    return logits
