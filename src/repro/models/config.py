"""Model configuration — one dataclass covers all 10 assigned families.

Field groups activate per family: dense (default), moe, mla, ssm, hybrid,
encdec, vlm/audio prefix stubs.  Configs are frozen; arch definitions live
in ``repro.configs.<id>``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "tiny"
    family: str = "dense"        # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 2
    d_model: int = 64
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 16
    d_ff: int = 128
    vocab: int = 256
    vocab_pad: int = 0           # physical table size (0 = vocab); padding
                                 # keeps the vocab dim shardable by the mesh

    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "silu_glu"        # silu_glu | gelu (plain 2-matrix MLP)
    qkv_bias: bool = False
    pos: str = "rope"            # rope | learned | none
    rope_theta: float = 10_000.0
    rope_pct: float = 1.0        # partial rotary (stablelm: 0.25)
    tie_embeddings: bool = False
    max_seq: int = 4096          # learned-pos table size / decode default

    # --- attention window (0 = full causal). hymba: SWA everywhere except
    # global_layers; long-context decode windows everything. ---
    window: int = 0
    global_layers: Tuple[int, ...] = ()

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0            # per-expert hidden dim
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_impl: str = "gather"     # gather (pjit baseline) | alltoall (shard_map)
    moe_replicas: int = 1        # physical copies per expert (load-balance /
                                 # EP-uniformity when n_experts < model axis)

    # --- MLA (deepseek-v2) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4

    # --- enc-dec (whisper) ---
    is_encdec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500          # precomputed frame embeddings (stub frontend)

    # --- vlm (pixtral): prefix patch embeddings (stub frontend) ---
    n_prefix: int = 0            # prefix embeddings prepended to tokens

    # --- numerics / structure ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"   # giants use bf16 masters + int8 opt state
    scan_layers: bool = True
    remat_policy: str = "nothing"  # nothing | dots | full(=save everything)
    attn_chunk: int = 1024         # kv-chunk for online-softmax attention
    attn_acc: str = "f32"          # f32 | bf16 accumulation inside attention
    decode_attn: str = "xla"       # xla | split_kv (shard_map flash-decode
                                   # over the seq-sharded cache)
    ce_chunk: int = 0              # seq-chunked CE loss (0 = monolithic)
    logit_cap: float = 0.0

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def d_q(self) -> int:
        if self.use_mla:
            return self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
        return self.n_heads * self.d_head

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def attends(self) -> bool:
        return self.family != "ssm"

    def n_params(self) -> int:
        """Total parameter count (matches param_table; used for 6ND)."""
        from repro.models.params import param_table  # lazy, avoids cycle

        total = 0
        for info in param_table(self).values():
            n = 1
            for s in info.shape:
                n *= s
            total += n
        return total

    def n_active_params(self) -> int:
        """Active-per-token params (MoE: routed top_k + shared only)."""
        from repro.models.params import param_table

        total = 0
        for path, info in param_table(self).items():
            n = 1
            for s in info.shape:
                n *= s
            if "experts" in info.axes:  # routed expert weights (maybe
                # behind a leading stacked-"layers" axis)
                n = (n // (self.n_experts * self.moe_replicas)
                     * min(self.top_k, self.n_experts))
            total += n
        return total


def validate(cfg: ModelConfig) -> None:
    assert cfg.family in ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")
    if cfg.family in ("dense", "encdec", "vlm", "hybrid"):
        assert cfg.n_heads % cfg.n_kv_heads == 0
    if cfg.family == "moe":
        assert cfg.n_experts > 0 and cfg.top_k > 0 and cfg.moe_d_ff > 0
    if cfg.family in ("ssm", "hybrid"):
        assert cfg.ssm_state > 0 and cfg.ssm_heads > 0
    if cfg.family == "encdec":
        assert cfg.is_encdec and cfg.n_enc_layers > 0
