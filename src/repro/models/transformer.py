"""Layer stacks for all families: train / prefill / decode, scan + remat.

One ``layer_fn`` serves every family (dense / moe / mla / ssm / hybrid /
encdec-decoder); the stack runs it under ``jax.lax.scan`` over stacked
layer params (HLO size O(1) in depth) with a configurable remat policy.
Per-layer heterogeneity (hymba's global-vs-window attention) rides along
as scanned per-layer scalars, so the scanned body stays homogeneous.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import embed_tokens, mlp, norm, sub, unembed
from repro.sharding import shard, current_mesh

__all__ = [
    "layer_windows",
    "forward_train",
    "encode",
    "prefill",
    "decode_step",
    "init_decode_caches",
]


# ---------------------------------------------------------------------------
# per-layer static schedule
# ---------------------------------------------------------------------------

def layer_windows(cfg: ModelConfig, force_window: bool = False):
    """int32[L]: attention window per layer (0 = global)."""
    w = []
    for i in range(cfg.n_layers):
        if cfg.window and (i not in cfg.global_layers or force_window):
            w.append(cfg.window)
        else:
            w.append(0)
    return jnp.asarray(w, jnp.int32)


# ---------------------------------------------------------------------------
# single layer
# ---------------------------------------------------------------------------

def _fuse_paths(params, a_out, s_out):
    """Hymba-style fusion: per-path RMS-normalized, learned gains, mean."""
    def _n(x):
        xf = x.astype(jnp.float32)
        return xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + 1e-6)

    ga = params["fuse/gain_attn"].astype(jnp.float32)
    gs = params["fuse/gain_ssm"].astype(jnp.float32)
    return (0.5 * (_n(a_out) * ga + _n(s_out) * gs)).astype(a_out.dtype)


def layer_fn(params, cfg: ModelConfig, x, *, positions, window, mode,
             cache=None, enc_out=None):
    """One decoder layer. mode: train | prefill | decode.

    cache: dict {attn, ssm, cross} or None. Returns (x', new_cache, aux).
    """
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    h = norm(sub(params, "norm1"), cfg, x)
    h = shard(h, ("act_batch", "act_seq", "act_embed"))

    if cfg.family == "ssm":
        s_out, s_state = ssm_mod.ssm_block(
            sub(params, "ssm"), cfg, h,
            cache=cache.get("ssm") if cache else None)
        x = x + s_out
        new_cache["ssm"] = s_state if mode != "train" else None
    else:
        if cfg.use_mla:
            a_out, a_state = mla_mod.mla_block(
                sub(params, "attn"), cfg, h, positions=positions,
                cache=cache.get("attn") if cache else None)
        else:
            a_out, a_state = attn_mod.attn_block(
                sub(params, "attn"), cfg, h, positions=positions,
                causal=True, window=window,
                cache=cache.get("attn") if cache else None)
        if cfg.family == "hybrid":
            s_out, s_state = ssm_mod.ssm_block(
                sub(params, "ssm"), cfg, h,
                cache=cache.get("ssm") if cache else None)
            x = x + _fuse_paths(params, a_out, s_out)
            new_cache["ssm"] = s_state if mode != "train" else None
        else:
            x = x + a_out
        new_cache["attn"] = a_state if mode != "train" else None

        if cfg.is_encdec:
            hc = norm(sub(params, "norm_cross"), cfg, x)
            if mode == "decode" and cache and cache.get("cross") is not None:
                ck, cv = cache["cross"]
                c_out = _cross_from_cache(params, cfg, hc, ck, cv)
                new_cache["cross"] = (ck, cv)
            else:
                c_out, ckv = attn_mod.attn_block(
                    sub(params, "cross"), cfg, hc, positions=positions,
                    causal=False, xa=enc_out)
                new_cache["cross"] = ckv if mode != "train" else None
            x = x + c_out

    x = shard(x, ("act_batch", "act_seq", "act_embed"))
    if cfg.family == "moe":
        h2 = norm(sub(params, "norm2"), cfg, x)
        mesh = current_mesh()
        y, aux = moe_mod.moe_ffn(
            sub(params, "moe"), cfg, h2, mesh=mesh,
            dp_axes=("pod", "data") if mesh and "pod" in mesh.shape else ("data",))
        x = x + y
    elif cfg.family != "ssm":  # pure mamba stack has no MLP (d_ff = 0)
        h2 = norm(sub(params, "norm2"), cfg, x)
        h2 = shard(h2, ("act_batch", "act_seq", "act_embed"))
        x = x + mlp(sub(params, "mlp"), cfg, h2)
    x = shard(x, ("act_batch", "act_seq", "act_embed"))
    return x, new_cache, aux


def _cross_from_cache(params, cfg: ModelConfig, x, ck, cv):
    """Cross-attention against precomputed encoder K/V (decode path)."""
    dt = cfg.compute_dtype
    B, Sq, D = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = (x @ params["cross/wq"].astype(dt))
    if "cross/bq" in params:
        q = q + params["cross/bq"].astype(dt)
    q = q.reshape(B, Sq, H, Dh)
    out = attn_mod.attention_core(
        q, ck, cv, causal=False, window=0, q_offset=0,
        kv_valid=ck.shape[1], chunk=cfg.attn_chunk)
    return out.reshape(B, Sq, H * Dh) @ params["cross/wo"].astype(dt)


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------

def _remat(fn, cfg: ModelConfig):
    if cfg.remat_policy == "full":
        return fn
    if cfg.remat_policy == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)  # "nothing" saveable


def _split_layer_params(params: dict, prefix: str) -> dict:
    pre = prefix + "/"
    return {k[len(pre):]: v for k, v in params.items() if k.startswith(pre)}


def run_stack(params, cfg: ModelConfig, x, *, positions, mode,
              caches=None, enc_out=None, prefix="layers",
              windows=None, n_layers=None):
    """Scan (or unrolled loop) over the layer stack."""
    lp = _split_layer_params(params, prefix)
    L = n_layers if n_layers is not None else cfg.n_layers
    if windows is None:
        windows = layer_windows(cfg) if prefix == "layers" else jnp.zeros((L,), jnp.int32)

    if not cfg.scan_layers:
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = []

        def one(li, xc, w, c):
            return layer_fn(li, cfg, xc, positions=positions, window=w,
                            mode=mode, cache=c, enc_out=enc_out)

        if mode == "train" and cfg.remat_policy != "full":
            one = _remat(one, cfg)
        for i in range(L):
            li = {k: v for k, v in _split_layer_params(params, f"{prefix}_{i}").items()}
            c = jax.tree.map(lambda a: a[i], caches) if caches is not None else None
            x, nc, aux = one(li, x, windows[i], c)
            aux_total += aux
            new_caches.append(nc)
        stacked = (jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
                   if mode != "train" else None)
        return x, stacked, aux_total

    def body(carry, per_layer):
        xc, aux_acc = carry
        lparams, w, c = per_layer
        xc, nc, aux = layer_fn(lparams, cfg, xc, positions=positions,
                               window=w, mode=mode, cache=c, enc_out=enc_out)
        return (xc, aux_acc + aux), nc

    body = _remat(body, cfg) if mode == "train" else body
    (x, aux_total), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (lp, windows, caches))
    return x, (new_caches if mode != "train" else None), aux_total


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def _positions(cfg, start, S):
    return start + jnp.arange(S)


def _embed_input(params, cfg: ModelConfig, tokens, prefix_embeds=None):
    """tokens [B, St] (+ optional prefix embeds [B, Pfx, D]) -> [B, S, D]."""
    x = embed_tokens(params, cfg, tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    if cfg.pos == "learned":
        S = x.shape[1]
        x = x + params["embed/pos"][:S].astype(x.dtype)
    return x


def encode(params, cfg: ModelConfig, frames):
    """Whisper encoder over precomputed frame embeddings (frontend stub)."""
    x = frames.astype(cfg.compute_dtype)
    x = x + params["encoder/pos"][: x.shape[1]].astype(x.dtype)
    x = shard(x, ("act_batch", "act_seq", "act_embed"))

    lp = _split_layer_params(params, "enc_layers")

    def body(carry, lparams):
        xc = carry
        h = norm(sub(lparams, "norm1"), cfg, xc)
        a, _ = attn_mod.attn_block(sub(lparams, "attn"), cfg, h,
                                   positions=jnp.arange(xc.shape[1]),
                                   causal=False)
        xc = xc + a
        h2 = norm(sub(lparams, "norm2"), cfg, xc)
        xc = xc + mlp(sub(lparams, "mlp"), cfg, h2)
        return xc, None

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, lp)
    else:
        for i in range(cfg.n_enc_layers):
            x, _ = body(x, _split_layer_params(params, f"enc_layers_{i}"))
    return norm(sub(params, "encoder/norm_f"), cfg, x)


def forward_hidden(params, cfg: ModelConfig, tokens, prefix_embeds=None,
                   enc_frames=None):
    """Teacher-forced final hidden states [B, S, D] (pre-unembed) + aux."""
    enc_out = encode(params, cfg, enc_frames) if cfg.is_encdec else None
    x = _embed_input(params, cfg, tokens, prefix_embeds)
    x = shard(x, ("act_batch", "act_seq", "act_embed"))
    positions = _positions(cfg, 0, x.shape[1])
    x, _, aux = run_stack(params, cfg, x, positions=positions, mode="train",
                          enc_out=enc_out)
    x = norm(sub(params, "norm_f"), cfg, x)
    return x, aux


def forward_train(params, cfg: ModelConfig, tokens, prefix_embeds=None,
                  enc_frames=None):
    """Teacher-forced logits for training. Returns (logits, aux_loss)."""
    x, aux = forward_hidden(params, cfg, tokens, prefix_embeds, enc_frames)
    logits = unembed(params, cfg, x)
    logits = shard(logits, ("act_batch", "act_seq", "act_vocab"))
    return logits, aux


def init_decode_caches(cfg: ModelConfig, batch: int, buf_len: int,
                       long_context: bool = False):
    """Stacked (L-leading) cache pytree for decode."""
    L = cfg.n_layers

    def stk(leaf_fn):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *[leaf_fn() for _ in range(L)])

    caches = {}
    if cfg.family == "ssm":
        caches = {"ssm": stk(lambda: ssm_mod.init_ssm_cache(cfg, batch))}
    elif cfg.use_mla:
        caches = {"attn": stk(lambda: mla_mod.init_mla_cache(cfg, batch, buf_len))}
    else:
        ring = long_context and cfg.window > 0
        buf = min(buf_len, cfg.window) if ring else buf_len
        caches = {"attn": stk(lambda: attn_mod.init_cache(
            cfg, batch, buf, cfg.n_kv_heads, cfg.d_head, ring=ring))}
        if cfg.family == "hybrid":
            caches["ssm"] = stk(lambda: ssm_mod.init_ssm_cache(cfg, batch))
        if cfg.is_encdec:
            dt = cfg.compute_dtype
            caches["cross"] = (
                jnp.zeros((L, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.d_head), dt),
                jnp.zeros((L, batch, cfg.enc_seq, cfg.n_kv_heads, cfg.d_head), dt),
            )
    return caches


def prefill(params, cfg: ModelConfig, tokens, prefix_embeds=None,
            enc_frames=None, buf_len: int | None = None):
    """Process a prompt, return (last-position logits, decode caches).

    buf_len: KV-buffer capacity for subsequent decode (>= prompt length);
    defaults to prompt length + 64.
    """
    enc_out = encode(params, cfg, enc_frames) if cfg.is_encdec else None
    x = _embed_input(params, cfg, tokens, prefix_embeds)
    x = shard(x, ("act_batch", "act_seq", "act_embed"))
    S = x.shape[1]
    positions = _positions(cfg, 0, S)
    x, kv_per_layer, _ = run_stack(params, cfg, x, positions=positions,
                                   mode="prefill", enc_out=enc_out)
    x = norm(sub(params, "norm_f"), cfg, x[:, -1:])
    logits = unembed(params, cfg, x)

    caches = _assemble_prefill_caches(cfg, kv_per_layer, S,
                                      buf_len if buf_len else S + 64)
    return logits[:, 0], caches


def _assemble_prefill_caches(cfg: ModelConfig, kv_per_layer, S, buf_len):
    """Wrap per-layer prefill outputs into decode-ready cache pytrees."""
    caches = {}
    length = jnp.full((cfg.n_layers,), S, jnp.int32)
    grow = max(0, buf_len - S)

    def pad_seq(x):  # [L, B, S, ...] -> [L, B, buf, ...]
        widths = [(0, 0)] * x.ndim
        widths[2] = (0, grow)
        return jnp.pad(x, widths)

    if kv_per_layer.get("ssm") is not None:
        caches["ssm"] = kv_per_layer["ssm"]      # stacked SSMCache
    if kv_per_layer.get("attn") is not None:
        if cfg.use_mla:
            ckv, krope = kv_per_layer["attn"]
            caches["attn"] = mla_mod.MLACache(ckv=pad_seq(ckv), krope=pad_seq(krope),
                                              length=length, pos=length)
        else:
            k, v = kv_per_layer["attn"]
            caches["attn"] = attn_mod.KVCache(k=pad_seq(k), v=pad_seq(v),
                                              length=length, pos=length, ring=False)
    if kv_per_layer.get("cross") is not None:
        caches["cross"] = kv_per_layer["cross"]
    return caches


def decode_step(params, cfg: ModelConfig, caches, token, pos):
    """One decode step: token [B] int32, pos scalar. -> (logits [B,V], caches)."""
    x = embed_tokens(params, cfg, token[:, None])
    if cfg.pos == "learned":
        x = x + jax.lax.dynamic_slice_in_dim(
            params["embed/pos"], pos, 1, axis=0).astype(x.dtype)[None]
    positions = pos[None] if hasattr(pos, "shape") else jnp.asarray([pos])
    x, new_caches, _ = run_stack(params, cfg, x, positions=positions,
                                 mode="decode", caches=caches)
    x = norm(sub(params, "norm_f"), cfg, x)
    logits = unembed(params, cfg, x)
    return logits[:, 0], new_caches
