"""Mamba2 SSD (state-space duality) block — chunked, MXU-friendly form.

Train/prefill uses the SSD block decomposition (arXiv:2405.21060): the
sequence is split into chunks of Q tokens; intra-chunk terms are dense
(C B^T ⊙ decay-mask) matmuls (quadratic only within a chunk), inter-chunk
terms pass a recurrent (H, P, N) state between chunks — so compute is
matmul-dominated (MXU) instead of an elementwise scan.  Decode is the O(1)
recurrent update.  Sub-quadratic in S -> this family runs ``long_500k``.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = ["ssm_block", "SSMCache", "init_ssm_cache"]


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("conv", "state"), meta_fields=())
@dataclasses.dataclass
class SSMCache:
    conv: jax.Array    # [B, conv_w - 1, conv_ch] trailing inputs
    state: jax.Array   # [B, H, P, N] recurrent state


def init_ssm_cache(cfg: ModelConfig, batch: int) -> SSMCache:
    di = cfg.d_inner_ssm
    conv_ch = di + 2 * cfg.ssm_state
    return SSMCache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), cfg.compute_dtype),
        state=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
    )


def _split_proj(params, cfg: ModelConfig, x):
    """in_proj -> (z [B,S,di], xBC [B,S,di+2N], dt_raw [B,S,H])."""
    dt = cfg.compute_dtype
    di, N, H = cfg.d_inner_ssm, cfg.ssm_state, cfg.ssm_heads
    proj = x @ params["in_proj"].astype(dt)
    z, xBC, dt_raw = jnp.split(proj, [di, 2 * di + 2 * N], axis=-1)
    return z, xBC, dt_raw


def _causal_conv(params, cfg: ModelConfig, xBC, conv_state=None):
    """Depthwise causal conv (width cfg.ssm_conv) + silu.

    Train: conv_state None, left-pad zeros.  Decode: conv_state [B, w-1, ch]
    holds the trailing context; returns (y, new_conv_state).
    """
    dt = cfg.compute_dtype
    w = params["conv_w"].astype(dt)      # [w, ch]
    b = params["conv_b"].astype(dt)
    width = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros(xBC.shape[:1] + (width - 1,) + xBC.shape[2:], xBC.dtype)
        full = jnp.concatenate([pad, xBC], axis=1)
        new_state = full[:, -(width - 1):] if width > 1 else None
    else:
        full = jnp.concatenate([conv_state, xBC], axis=1)
        new_state = full[:, -(width - 1):]
    # y[t] = Σ_i w[i] * full[t + i]
    y = sum(w[i] * jax.lax.dynamic_slice_in_dim(full, i, xBC.shape[1], axis=1)
            for i in range(width))
    return jax.nn.silu(y + b), new_state


def _gated_norm(params, cfg: ModelConfig, y, z):
    """Mamba2 output: RMSNorm(y * silu(z)) with learned scale."""
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    out = gf * jax.lax.rsqrt(jnp.mean(jnp.square(gf), -1, keepdims=True) + 1e-6)
    return (out * params["norm"].astype(jnp.float32)).astype(y.dtype)


def _ssd_chunked(cfg: ModelConfig, xh, dtv, A, Bm, Cm, init_state=None):
    """The SSD algorithm.

    xh: [B,S,H,P] inputs; dtv: [B,S,H] positive step sizes; A: [H] (<0);
    Bm/Cm: [B,S,N] (single group, broadcast over heads).
    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    Bb, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    Q = min(cfg.ssm_chunk, S)
    pad = (-S) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtv = jnp.pad(dtv, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    f32 = jnp.float32
    xc = xh.reshape(Bb, nc, Q, H, Pd).astype(f32)
    dtc = dtv.reshape(Bb, nc, Q, H).astype(f32)
    Bc = Bm.reshape(Bb, nc, Q, N).astype(f32)
    Cc = Cm.reshape(Bb, nc, Q, N).astype(f32)

    dA = dtc * A[None, None, None, :]              # [B,nc,Q,H] (negative)
    cum = jnp.cumsum(dA, axis=2)                   # within-chunk cumulative
    chunk_sum = cum[:, :, -1, :]                   # [B,nc,H]

    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for j<=i
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [B,nc,Qi,Qj,H]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    # scores CB[i,j] = C_i . B_j  (single group)
    CB = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)
    xdt = xc * dtc[..., None]                      # dt-weighted inputs
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", CB, L.transpose(0, 1, 2, 3, 4), xdt)

    # chunk states: sum_j exp(chunk_sum - cum_j) * xdt_j ⊗ B_j
    decay_out = jnp.exp(chunk_sum[:, :, None, :] - cum)    # [B,nc,Q,H]
    states = jnp.einsum("bcjh,bcjhp,bcjn->bchpn", decay_out, xdt, Bc)

    # inter-chunk recurrence
    s0 = jnp.zeros((Bb, H, Pd, N), f32) if init_state is None else init_state.astype(f32)

    def step(carry, inp):
        st_prev = carry
        chunk_state, csum = inp
        st = st_prev * jnp.exp(csum)[:, :, None, None] + chunk_state
        return st, st_prev

    final, prev_states = jax.lax.scan(
        step, s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_sum.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # [B,nc,H,P,N]

    # inter-chunk output: C_i . (decay_in_i * state_prev)
    decay_in = jnp.exp(cum)                                # [B,nc,Q,H]
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp", Cc, prev_states, decay_in)

    y = (y_intra + y_inter).reshape(Bb, Sp, H, Pd)[:, :S]
    return y, final


def ssm_block(params: dict, cfg: ModelConfig, x: jax.Array,
              cache: SSMCache | None = None):
    """Full Mamba2 block: in_proj, conv, SSD, gated norm, out_proj.

    Returns (out [B,S,D], new_cache_or_final_state).
    """
    dt = cfg.compute_dtype
    B, S, D = x.shape
    H, Pd, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    di = cfg.d_inner_ssm

    z, xBC, dt_raw = _split_proj(params, cfg, x)
    dtv = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                          params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["a_log"].astype(jnp.float32))

    if cache is None:
        xBC_pre = xBC
        xBC, conv_tail = _causal_conv(params, cfg, xBC)
        xs, Bm, Cm = jnp.split(xBC, [di, di + N], axis=-1)
        xh = xs.reshape(B, S, H, Pd)
        y, final = _ssd_chunked(cfg, xh, dtv, A, Bm, Cm)
        y = y + params["d_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
        y = y.reshape(B, S, di).astype(dt)
        out = _gated_norm(params, cfg, y, z) @ params["out_proj"].astype(dt)
        # prefill hands decode a ready cache (conv tail = trailing PRE-conv
        # inputs; _causal_conv returns exactly that)
        new_cache = SSMCache(
            conv=xBC_pre[:, -(cfg.ssm_conv - 1):].astype(dt) if S >= cfg.ssm_conv - 1
            else jnp.pad(xBC_pre, ((0, 0), (cfg.ssm_conv - 1 - S, 0), (0, 0))).astype(dt),
            state=final,
        )
        return out, new_cache

    # ---- decode: O(1) recurrent update (S == 1) ----
    xBC_c, new_conv = _causal_conv(params, cfg, xBC, cache.conv)
    xs, Bm, Cm = jnp.split(xBC_c, [di, di + N], axis=-1)
    xh = xs.reshape(B, 1, H, Pd).astype(jnp.float32)[:, 0]        # [B,H,P]
    dt1 = dtv[:, 0]                                               # [B,H]
    Bm1 = Bm[:, 0].astype(jnp.float32)                            # [B,N]
    Cm1 = Cm[:, 0].astype(jnp.float32)
    dA = jnp.exp(dt1 * A[None, :])                                # [B,H]
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt1, xh, Bm1)
    state = cache.state * dA[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm1, state)
    y = y + params["d_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, 1, di).astype(dt)
    out = _gated_norm(params, cfg, y, z) @ params["out_proj"].astype(dt)
    return out, SSMCache(conv=new_conv, state=state)
