"""Multi-head Latent Attention (DeepSeek-V2) with absorbed-matmul decode.

Train/prefill: decompress c_kv -> per-head K_nope/V and run standard GQA
math (kv heads == q heads).  Decode: the cache holds only the compressed
latent (kv_lora + shared rope key = 576 dims/token for the 236B config),
and W_uk / W_uv are *absorbed* into the query/output projections so scores
are taken directly against the latent — the memory win that makes MLA.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rope
from repro.models.attention import attention_core

__all__ = ["mla_block", "MLACache", "init_mla_cache"]


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=("ckv", "krope", "length", "pos"), meta_fields=())
@dataclasses.dataclass
class MLACache:
    """ckv: [B, S_buf, kv_lora]; krope: [B, S_buf, qk_rope_dim] (rope applied)."""

    ckv: jax.Array
    krope: jax.Array
    length: jax.Array
    pos: jax.Array


def init_mla_cache(cfg: ModelConfig, batch: int, buf_len: int) -> MLACache:
    dt = cfg.compute_dtype
    return MLACache(
        ckv=jnp.zeros((batch, buf_len, cfg.kv_lora_rank), dt),
        krope=jnp.zeros((batch, buf_len, cfg.qk_rope_dim), dt),
        length=jnp.zeros((), jnp.int32),
        pos=jnp.zeros((), jnp.int32),
    )


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    out = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def _project_q(params, cfg: ModelConfig, x, positions):
    dt = cfg.compute_dtype
    B, S, _ = x.shape
    H = cfg.n_heads
    cq = _rms(x @ params["w_dq"].astype(dt), params["q_norm"])
    q = (cq @ params["w_uq"].astype(dt)).reshape(B, S, H, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [cfg.qk_nope_dim], axis=-1)
    q_rope = rope(q_rope, positions, cfg, dim=cfg.qk_rope_dim)
    return q_nope, q_rope


def _project_kv_latent(params, cfg: ModelConfig, x, positions):
    dt = cfg.compute_dtype
    dkv = x @ params["w_dkv"].astype(dt)
    ckv, k_rope = jnp.split(dkv, [cfg.kv_lora_rank], axis=-1)
    ckv = _rms(ckv, params["kv_norm"])
    # shared (single-head) rope key
    k_rope = rope(k_rope[:, :, None, :], positions, cfg, dim=cfg.qk_rope_dim)[:, :, 0, :]
    return ckv, k_rope


def mla_block(
    params: dict,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    positions: jax.Array,
    cache: MLACache | None = None,
):
    """Returns (out, new_cache_or_latents)."""
    dt = cfg.compute_dtype
    B, S, D = x.shape
    H = cfg.n_heads

    q_nope, q_rope = _project_q(params, cfg, x, positions)
    ckv, k_rope = _project_kv_latent(params, cfg, x, positions)

    if cache is None:
        # ---- train/prefill: decompress and run standard attention ----
        Skv = S
        w_uk = params["w_uk"].astype(dt).reshape(cfg.kv_lora_rank, H, cfg.qk_nope_dim)
        w_uv = params["w_uv"].astype(dt).reshape(cfg.kv_lora_rank, H, cfg.v_head_dim)
        k_nope = jnp.einsum("bsl,lhd->bshd", ckv, w_uk)
        v = jnp.einsum("bsl,lhd->bshd", ckv, w_uv)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, Skv, H, cfg.qk_rope_dim))],
            axis=-1,
        )
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = attention_core(
            q, k, v, causal=True, window=0, q_offset=positions[0],
            kv_valid=Skv, chunk=cfg.attn_chunk,
        )
        out = out.reshape(B, S, H * cfg.v_head_dim) @ params["wo"].astype(dt)
        return out, (ckv, k_rope)

    # ---- decode: absorbed matmuls against the latent cache ----
    slot = jnp.minimum(cache.pos, cache.ckv.shape[1] - 1)
    new_cache = MLACache(
        ckv=jax.lax.dynamic_update_slice_in_dim(cache.ckv, ckv.astype(cache.ckv.dtype), slot, 1),
        krope=jax.lax.dynamic_update_slice_in_dim(cache.krope, k_rope.astype(cache.krope.dtype), slot, 1),
        length=jnp.minimum(cache.length + 1, cache.ckv.shape[1]),
        pos=cache.pos + 1,
    )
    w_uk = params["w_uk"].astype(dt).reshape(cfg.kv_lora_rank, H, cfg.qk_nope_dim)
    # absorb W_uk into q: q_lat [B,1,H,kv_lora]
    q_lat = jnp.einsum("bshd,lhd->bshl", q_nope, w_uk)
    scale = 1.0 / jnp.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    s_lat = jnp.einsum("bshl,bTl->bshT", q_lat.astype(jnp.float32),
                       new_cache.ckv.astype(jnp.float32))
    s_rope = jnp.einsum("bshd,bTd->bshT", q_rope.astype(jnp.float32),
                        new_cache.krope.astype(jnp.float32))
    s = (s_lat + s_rope) * scale
    valid = jnp.arange(new_cache.ckv.shape[1]) < new_cache.length
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # attend over latents, then decompress once per head (absorbed W_uv)
    ctx_lat = jnp.einsum("bshT,bTl->bshl", p, new_cache.ckv.astype(jnp.float32))
    w_uv = params["w_uv"].astype(dt).reshape(cfg.kv_lora_rank, H, cfg.v_head_dim)
    ctx = jnp.einsum("bshl,lhd->bshd", ctx_lat.astype(dt), w_uv)
    out = ctx.reshape(B, S, H * cfg.v_head_dim) @ params["wo"].astype(dt)
    return out, new_cache
