"""Parameter table: the single source of truth for every weight.

``param_table(cfg)`` maps path -> ParamInfo(shape, dtype, logical axes,
init kind).  Everything else derives from it:

- ``init_params``      materialize + randomly initialize (by path hash)
- ``abstract_params``  ShapeDtypeStructs for dry-run lowering
- ``param_pspecs``     logical axes -> PartitionSpec via sharding rules

Per-layer entries are stacked along a leading "layers" axis when
``cfg.scan_layers`` (scan-over-layers keeps HLO size O(1) in depth).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

__all__ = ["ParamInfo", "param_table", "init_params", "abstract_params"]


@dataclasses.dataclass(frozen=True)
class ParamInfo:
    shape: tuple
    axes: tuple              # logical axis names, len == len(shape)
    init: str = "linear"     # linear | embed | zeros | ones | ssm_a | dt_bias
    dtype: str = "float32"


def _norm_entries(cfg: ModelConfig, prefix: str) -> "OrderedDict[str, ParamInfo]":
    t = OrderedDict()
    t[f"{prefix}/scale"] = ParamInfo((cfg.d_model,), ("embed_v",), "ones")
    if cfg.norm == "layernorm":
        t[f"{prefix}/bias"] = ParamInfo((cfg.d_model,), ("embed_v",), "zeros")
    return t


def _attn_entries(cfg: ModelConfig, prefix: str, cross: bool = False) -> "OrderedDict[str, ParamInfo]":
    t = OrderedDict()
    H, KV, Dh, D = cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.d_model
    t[f"{prefix}/wq"] = ParamInfo((D, H * Dh), ("embed", "q_heads"))
    t[f"{prefix}/wk"] = ParamInfo((D, KV * Dh), ("embed", "kv_heads"))
    t[f"{prefix}/wv"] = ParamInfo((D, KV * Dh), ("embed", "kv_heads"))
    t[f"{prefix}/wo"] = ParamInfo((H * Dh, D), ("q_heads", "embed"))
    if cfg.qkv_bias:
        t[f"{prefix}/bq"] = ParamInfo((H * Dh,), ("q_heads_v",), "zeros")
        t[f"{prefix}/bk"] = ParamInfo((KV * Dh,), ("kv_heads_v",), "zeros")
        t[f"{prefix}/bv"] = ParamInfo((KV * Dh,), ("kv_heads_v",), "zeros")
    return t


def _mla_entries(cfg: ModelConfig, prefix: str) -> "OrderedDict[str, ParamInfo]":
    t = OrderedDict()
    D = cfg.d_model
    H = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    t[f"{prefix}/w_dq"] = ParamInfo((D, cfg.q_lora_rank), ("embed", "lora"))
    t[f"{prefix}/q_norm"] = ParamInfo((cfg.q_lora_rank,), ("lora_v",), "ones")
    t[f"{prefix}/w_uq"] = ParamInfo((cfg.q_lora_rank, H * qk), ("lora", "q_heads"))
    # down-proj emits the compressed kv (kv_lora) and the shared rope key
    t[f"{prefix}/w_dkv"] = ParamInfo(
        (D, cfg.kv_lora_rank + cfg.qk_rope_dim), ("embed", "lora")
    )
    t[f"{prefix}/kv_norm"] = ParamInfo((cfg.kv_lora_rank,), ("lora_v",), "ones")
    t[f"{prefix}/w_uk"] = ParamInfo(
        (cfg.kv_lora_rank, H * cfg.qk_nope_dim), ("lora", "q_heads")
    )
    t[f"{prefix}/w_uv"] = ParamInfo(
        (cfg.kv_lora_rank, H * cfg.v_head_dim), ("lora", "q_heads")
    )
    t[f"{prefix}/wo"] = ParamInfo((H * cfg.v_head_dim, D), ("q_heads", "embed"))
    return t


def _mlp_entries(cfg: ModelConfig, prefix: str, d_ff: int | None = None) -> "OrderedDict[str, ParamInfo]":
    t = OrderedDict()
    D, F = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "silu_glu":
        t[f"{prefix}/w_gate"] = ParamInfo((D, F), ("embed", "mlp"))
        t[f"{prefix}/w_up"] = ParamInfo((D, F), ("embed", "mlp"))
        t[f"{prefix}/w_down"] = ParamInfo((F, D), ("mlp", "embed"))
    else:  # gelu 2-matrix
        t[f"{prefix}/w_in"] = ParamInfo((D, F), ("embed", "mlp"))
        t[f"{prefix}/b_in"] = ParamInfo((F,), ("mlp_v",), "zeros")
        t[f"{prefix}/w_out"] = ParamInfo((F, D), ("mlp", "embed"))
        t[f"{prefix}/b_out"] = ParamInfo((D,), ("embed_v",), "zeros")
    return t


def _moe_entries(cfg: ModelConfig, prefix: str) -> "OrderedDict[str, ParamInfo]":
    t = OrderedDict()
    D, F = cfg.d_model, cfg.moe_d_ff
    E = cfg.n_experts * cfg.moe_replicas  # physical expert slots
    t[f"{prefix}/router"] = ParamInfo((D, cfg.n_experts), ("embed", "experts_r"))
    t[f"{prefix}/w_gate"] = ParamInfo((E, D, F), ("experts", "embed", "expert_mlp"))
    t[f"{prefix}/w_up"] = ParamInfo((E, D, F), ("experts", "embed", "expert_mlp"))
    t[f"{prefix}/w_down"] = ParamInfo((E, F, D), ("experts", "expert_mlp", "embed"))
    if cfg.n_shared_experts:
        t.update(_mlp_entries(cfg, f"{prefix}/shared", cfg.n_shared_experts * F))
    return t


def _ssm_entries(cfg: ModelConfig, prefix: str) -> "OrderedDict[str, ParamInfo]":
    t = OrderedDict()
    D = cfg.d_model
    di = cfg.d_inner_ssm
    H, N = cfg.ssm_heads, cfg.ssm_state
    g = 1  # single B/C group (mamba2 default n_groups=1)
    conv_ch = di + 2 * g * N
    # in_proj -> [z(di), x(di), B(g*N), C(g*N), dt(H)]
    t[f"{prefix}/in_proj"] = ParamInfo((D, 2 * di + 2 * g * N + H), ("embed", "ssm_inner"))
    t[f"{prefix}/conv_w"] = ParamInfo((cfg.ssm_conv, conv_ch), ("conv_v", "ssm_inner_v"))
    t[f"{prefix}/conv_b"] = ParamInfo((conv_ch,), ("ssm_inner_v",), "zeros")
    t[f"{prefix}/a_log"] = ParamInfo((H,), ("ssm_heads_v",), "ssm_a")
    t[f"{prefix}/d_skip"] = ParamInfo((H,), ("ssm_heads_v",), "ones")
    t[f"{prefix}/dt_bias"] = ParamInfo((H,), ("ssm_heads_v",), "dt_bias")
    t[f"{prefix}/norm"] = ParamInfo((di,), ("ssm_inner_v",), "ones")
    t[f"{prefix}/out_proj"] = ParamInfo((di, D), ("ssm_inner", "embed"))
    return t


def _layer_table(cfg: ModelConfig) -> "OrderedDict[str, ParamInfo]":
    """One decoder layer (the scanned unit)."""
    t = OrderedDict()
    fam = cfg.family
    if fam == "ssm":
        t.update(_norm_entries(cfg, "norm1"))
        t.update(_ssm_entries(cfg, "ssm"))
        return t
    t.update(_norm_entries(cfg, "norm1"))
    if cfg.use_mla:
        t.update(_mla_entries(cfg, "attn"))
    else:
        t.update(_attn_entries(cfg, "attn"))
    if fam == "hybrid":
        t.update(_ssm_entries(cfg, "ssm"))
        # per-path output gains (hymba-style normalized fusion)
        t["fuse/gain_attn"] = ParamInfo((cfg.d_model,), ("embed_v",), "ones")
        t["fuse/gain_ssm"] = ParamInfo((cfg.d_model,), ("embed_v",), "ones")
    if cfg.is_encdec:
        t.update(_norm_entries(cfg, "norm_cross"))
        t.update(_attn_entries(cfg, "cross", cross=True))
    t.update(_norm_entries(cfg, "norm2"))
    if fam == "moe":
        t.update(_moe_entries(cfg, "moe"))
    else:
        t.update(_mlp_entries(cfg, "mlp"))
    return t


def _enc_layer_table(cfg: ModelConfig) -> "OrderedDict[str, ParamInfo]":
    t = OrderedDict()
    t.update(_norm_entries(cfg, "norm1"))
    t.update(_attn_entries(cfg, "attn"))
    t.update(_norm_entries(cfg, "norm2"))
    t.update(_mlp_entries(cfg, "mlp"))
    return t


def _stack(layer_t: "OrderedDict[str, ParamInfo]", n: int, scan: bool, prefix: str):
    t = OrderedDict()
    if scan:
        for k, v in layer_t.items():
            t[f"{prefix}/{k}"] = ParamInfo((n,) + v.shape, ("layers",) + v.axes, v.init, v.dtype)
    else:
        for i in range(n):
            for k, v in layer_t.items():
                t[f"{prefix}_{i}/{k}"] = v
    return t


def param_table(cfg: ModelConfig) -> "OrderedDict[str, ParamInfo]":
    t = OrderedDict()
    V = cfg.vocab_pad or cfg.vocab
    t["embed/tokens"] = ParamInfo((V, cfg.d_model), ("vocab", "embed"), "embed")
    if cfg.pos == "learned":
        t["embed/pos"] = ParamInfo((cfg.max_seq, cfg.d_model), ("seq_tab", "embed"), "embed")
    if cfg.is_encdec:
        # encoder positional table over frame slots (frontend itself is a stub)
        t["encoder/pos"] = ParamInfo((cfg.enc_seq, cfg.d_model), ("seq_tab", "embed"), "embed")
        t.update(_stack(_enc_layer_table(cfg), cfg.n_enc_layers, cfg.scan_layers, "enc_layers"))
        t["encoder/norm_f/scale"] = ParamInfo((cfg.d_model,), ("embed_v",), "ones")
        if cfg.norm == "layernorm":
            t["encoder/norm_f/bias"] = ParamInfo((cfg.d_model,), ("embed_v",), "zeros")
    t.update(_stack(_layer_table(cfg), cfg.n_layers, cfg.scan_layers, "layers"))
    t.update(_norm_entries(cfg, "norm_f"))
    if not cfg.tie_embeddings:
        t["lm_head"] = ParamInfo((cfg.d_model, V), ("embed", "vocab"))
    if cfg.param_dtype != "float32":
        t = OrderedDict(
            (k, dataclasses.replace(v, dtype=cfg.param_dtype)) for k, v in t.items()
        )
    return t


# ---------------------------------------------------------------------------


def _init_leaf(key, info: ParamInfo):
    shape, kind = info.shape, info.init
    dt = jnp.dtype(info.dtype)
    if kind == "zeros":
        return jnp.zeros(shape, dt)
    if kind == "ones":
        return jnp.ones(shape, dt)
    if kind == "embed":
        return (jax.random.normal(key, shape) * 0.02).astype(dt)
    if kind == "ssm_a":  # A in [-8, -1): a_log = log(-A)
        u = jax.random.uniform(key, shape, minval=1.0, maxval=8.0)
        return jnp.log(u).astype(dt)
    if kind == "dt_bias":  # softplus^-1 of dt ~ U[1e-3, 1e-1]
        u = jax.random.uniform(key, shape, minval=1e-3, maxval=1e-1)
        return (u + jnp.log(-jnp.expm1(-u))).astype(dt)
    # linear: truncated-normal fan-in scaling (lecun)
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dt)


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    table = param_table(cfg)
    params = {}
    for i, (path, info) in enumerate(table.items()):
        params[path] = _init_leaf(jax.random.fold_in(key, i), info)
    return params


def abstract_params(cfg: ModelConfig) -> dict:
    return {
        path: jax.ShapeDtypeStruct(info.shape, jnp.dtype(info.dtype))
        for path, info in param_table(cfg).items()
    }
