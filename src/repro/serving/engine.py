"""Batched serving engine with bloom-clock session stamping.

Continuous-batching-lite: requests join a fixed-width slot table; each
engine step decodes one token for every active slot.  Clock integration:

  - the engine ticks per admitted request and per emitted token batch;
  - each session carries its own clock; on migration between replicas the
    destination verifies ``session.clock ≼ replica.clock`` (the session's
    KV snapshot is from this replica's causal past) before adopting it —
    replaying a session onto a replica that never saw its history is
    exactly the stale-read the paper's comparison detects;
  - fleet-level request ordering across replicas needs no per-replica
    vector slots (O(m), elastic).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clock as bc
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.clock_runtime import ClockConfig, ClockRuntime

__all__ = ["ServeConfig", "ServingEngine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_seq: int = 512
    temperature: float = 0.0    # 0 = greedy
    seed: int = 0


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, s_cfg: ServeConfig,
                 c_cfg: ClockConfig, replica_id: str = "replica0"):
        self.params = params
        self.cfg = cfg
        self.s_cfg = s_cfg
        self.clock = ClockRuntime(c_cfg, run_id="serve")
        self.replica_id = replica_id
        self._decode = jax.jit(
            lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos))
        self._prefill = jax.jit(
            lambda p, t: T.prefill(p, cfg, t, buf_len=s_cfg.max_seq))
        self._admitted = 0

    # ---- session admission ----
    def admit(self, prompts: jax.Array) -> dict:
        """prompts [B, S] int32 -> session dict with caches + session clock."""
        B = prompts.shape[0]
        logits, caches = self._prefill(self.params, prompts)
        for i in range(B):
            self.clock.tick("admit", self.replica_id, self._admitted + i)
        self._admitted += B
        sess_clock = ClockRuntime(self.clock.cfg, run_id="serve")
        sess_clock.clock = bc.merge(sess_clock.clock, self.clock.clock)
        return {
            "caches": caches,
            "last_logits": logits,
            "pos": prompts.shape[1],
            "tokens": [prompts],
            "clock": sess_clock,
            "done": np.zeros(B, bool),
        }

    # ---- decode loop ----
    def _sample(self, logits: jax.Array, step: int) -> jax.Array:
        if self.s_cfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        key = jax.random.fold_in(jax.random.PRNGKey(self.s_cfg.seed), step)
        return jax.random.categorical(
            key, logits / self.s_cfg.temperature).astype(jnp.int32)

    def generate(self, session: dict, n_tokens: int) -> jax.Array:
        """Decode n tokens for every slot; ticks clocks per emitted batch."""
        out = []
        tok = self._sample(session["last_logits"], 0)
        for t in range(n_tokens):
            out.append(tok)
            logits, session["caches"] = self._decode(
                self.params, session["caches"], tok,
                jnp.asarray(session["pos"], jnp.int32))
            session["pos"] += 1
            self.clock.tick("tokens", self.replica_id, session["pos"])
            session["clock"].clock = bc.merge(session["clock"].clock,
                                              self.clock.clock)
            tok = self._sample(logits, t + 1)
            session["last_logits"] = logits
        return jnp.stack(out, axis=1)  # [B, n_tokens]

    # ---- migration ----
    def can_adopt(self, session: dict) -> tuple[bool, str, float]:
        """Clock-gated session migration (see module docstring)."""
        status, fp = self.clock.lineage(session["clock"].clock)
        ok = status in ("ancestor", "same") and fp <= self.clock.cfg.fp_threshold
        return ok, status, fp

    def adopt(self, session: dict) -> bool:
        ok, status, fp = self.can_adopt(session)
        if ok:
            self.clock.clock = bc.merge(self.clock.clock, session["clock"].clock)
        return ok
