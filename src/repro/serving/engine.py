"""Batched serving engine with bloom-clock session stamping.

Continuous-batching-lite: requests join a fixed-width slot table; each
engine step decodes one token for every active slot.  Clock integration:

  - the engine ticks per admitted request and per emitted token batch;
  - each session carries its own clock; on migration between replicas the
    destination verifies ``session.clock ≼ replica.clock`` (the session's
    KV snapshot is from this replica's causal past) before adopting it —
    replaying a session onto a replica that never saw its history is
    exactly the stale-read the paper's comparison detects;
  - fleet-level request ordering across replicas needs no per-replica
    vector slots (O(m), elastic);
  - live session clocks sit in a ``fleet.ClockRegistry`` slab, so bulk
    migration (``adopt_many``) classifies a whole batch of incoming
    sessions with ONE fused one-vs-many kernel call.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clock as bc
from repro.core import wire
from repro.fleet.registry import ClockRegistry
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime.clock_runtime import ClockConfig, ClockRuntime

__all__ = ["ServeConfig", "ServingEngine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_seq: int = 512
    temperature: float = 0.0    # 0 = greedy
    seed: int = 0


class ServingEngine:
    def __init__(self, params, cfg: ModelConfig, s_cfg: ServeConfig,
                 c_cfg: ClockConfig, replica_id: str = "replica0"):
        self.params = params
        self.cfg = cfg
        self.s_cfg = s_cfg
        self.clock = ClockRuntime(c_cfg, run_id="serve")
        self.replica_id = replica_id
        self._decode = jax.jit(
            lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos))
        self._prefill = jax.jit(
            lambda p, t: T.prefill(p, cfg, t, buf_len=s_cfg.max_seq))
        self._admitted = 0
        # fleet registry of live session clocks: migration audits and
        # fleet dashboards classify all of them in one device call.
        # Bounded: when full, the oldest tracked session is evicted
        # (FIFO) so a long-running engine never crashes on admission;
        # callers can release() finished sessions to free slots early.
        self.sessions = ClockRegistry(
            capacity=max(16, 8 * s_cfg.max_batch), m=c_cfg.m, k=c_cfg.k,
            policy=self.clock.policy)
        self._session_order: list = []
        self._session_seq = 0
        # instrumentation rides the clock policy (see repro.obs)
        self.obs = self.clock.obs

    def _audit_adopt(self, sid, session: dict, verdict: str, ok: bool,
                     fp: float, engine: str) -> None:
        """Audit one migration verdict the engine acted on."""
        obs = self.obs
        if not obs.audit:
            return
        local_cells = np.asarray(self.clock.clock.logical_cells())
        peer_cells = np.asarray(session["clock"].clock.logical_cells())
        frames = {}
        if obs.audit.store_frames:
            frames = {
                "local_frame": wire.encode_clock(bc.to_wire(self.clock.clock)),
                "peer_frame": wire.encode_clock(
                    bc.to_wire(session["clock"].clock)),
            }
        obs.audit.record(
            "verdict", sid,
            verdict=verdict,
            action="adopt" if ok else "reject",
            fp=fp,
            threshold=float(self.clock.policy.fp_threshold),
            engine=engine,
            local_crc=wire.cells_crc(local_cells),
            peer_crc=wire.cells_crc(peer_cells),
            local_sum=float(local_cells.sum()),
            peer_sum=float(peer_cells.sum()),
            transport="serving",
            **frames)
        obs.metrics.counter(
            "serving_adoptions",
            outcome="adopted" if ok else "rejected").inc()

    def _register_session(self, sid, clock) -> None:
        if sid not in self.sessions:
            while len(self.sessions) >= self.sessions.capacity:
                self.sessions.evict(self._session_order.pop(0))
            self._session_order.append(sid)
        self.sessions.admit(sid, clock)

    def release(self, session: dict) -> None:
        """Drop a finished session's clock from the registry."""
        sid = session.get("sid")
        if sid is not None and sid in self.sessions:
            self.sessions.evict(sid)
            self._session_order.remove(sid)

    # ---- session admission ----
    def admit(self, prompts: jax.Array) -> dict:
        """prompts [B, S] int32 -> session dict with caches + session clock."""
        B = prompts.shape[0]
        logits, caches = self._prefill(self.params, prompts)
        for i in range(B):
            self.clock.tick("admit", self.replica_id, self._admitted + i)
        self._admitted += B
        sess_clock = ClockRuntime(self.clock.cfg, run_id="serve")
        sess_clock.clock = bc.merge(sess_clock.clock, self.clock.clock)
        sid = f"{self.replica_id}/s{self._session_seq}"
        self._session_seq += 1
        self._register_session(sid, sess_clock.clock)
        return {
            "sid": sid,
            "caches": caches,
            "last_logits": logits,
            "pos": prompts.shape[1],
            "tokens": [prompts],
            "clock": sess_clock,
            "done": np.zeros(B, bool),
        }

    # ---- decode loop ----
    def _sample(self, logits: jax.Array, step: int) -> jax.Array:
        if self.s_cfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        key = jax.random.fold_in(jax.random.PRNGKey(self.s_cfg.seed), step)
        return jax.random.categorical(
            key, logits / self.s_cfg.temperature).astype(jnp.int32)

    def generate(self, session: dict, n_tokens: int) -> jax.Array:
        """Decode n tokens for every slot; ticks clocks per emitted batch."""
        out = []
        tok = self._sample(session["last_logits"], 0)
        for t in range(n_tokens):
            out.append(tok)
            logits, session["caches"] = self._decode(
                self.params, session["caches"], tok,
                jnp.asarray(session["pos"], jnp.int32))
            session["pos"] += 1
            self.clock.tick("tokens", self.replica_id, session["pos"])
            session["clock"].clock = bc.merge(session["clock"].clock,
                                              self.clock.clock)
            tok = self._sample(logits, t + 1)
            session["last_logits"] = logits
        if session.get("sid") in self.sessions:
            self.sessions.update(session["sid"], session["clock"].clock)
        return jnp.stack(out, axis=1)  # [B, n_tokens]

    # ---- migration ----
    def can_adopt(self, session: dict) -> tuple[bool, str, float]:
        """Clock-gated session migration (see module docstring)."""
        status, fp = self.clock.lineage(session["clock"].clock)
        ok = (status in ("ancestor", "same")
              and fp <= self.clock.policy.fp_threshold)
        return ok, status, fp

    def adopt(self, session: dict) -> bool:
        """Single-session migration: the batched classify path with a
        batch of one, so the audit record carries the REAL dispatch
        engine (packed/tri/wide-overlay/...) instead of a fixed label
        and the merge shares the wrap-safe bulk reduction."""
        return bool(self.adopt_many([session])[0])

    def adopt_many(self, sessions: list) -> np.ndarray:
        """Clock-gated BULK migration: classify every incoming session
        against the replica clock with ONE ``causal.classify`` call,
        adopt the safe ones, merge their clocks in one reduction.

        Returns the bool accept mask (aligned with ``sessions``).
        """
        if not sessions:
            return np.zeros(0, bool)
        cells = jnp.stack([
            s["clock"].clock.logical_cells().astype(jnp.int32)
            for s in sessions])
        res = jax.device_get(self.clock.causal.classify(
            self.clock.clock, cells))
        # session ≼ replica (its KV snapshot is from our causal past)
        # with Eq.-3 confidence — same rule as can_adopt, batched
        ok = res.after() & (res.fp_after() <= self.clock.policy.fp_threshold)
        if self.obs.audit:
            equal = res.after() & res.before()
            for i, s in enumerate(sessions):
                verdict = ("same" if equal[i]
                           else "ancestor" if res.after()[i]
                           else "descendant" if res.before()[i]
                           else "forked")
                self._audit_adopt(
                    s.get("sid") or f"migrating/{i}", s, verdict,
                    bool(ok[i]), float(res.fp_after()[i]),
                    res.engine or "i32")
        if ok.any():
            # wrap-safe bulk merge: fold core.clock.merge's wrap-
            # subtraction form (local + relu(peer - local), exact on the
            # mod-2^32 circle) across accepted rows — a raw jnp.maximum
            # would zero a near-wrap local clock against sane peers
            local = self.clock.clock.logical_cells().astype(jnp.int32)
            gain = jnp.where(jnp.asarray(ok)[:, None],
                             jnp.maximum(cells - local, 0), 0)
            self.clock.clock = bc.compress(bc.BloomClock(
                cells=local + jnp.max(gain, axis=0),
                base=jnp.zeros((), jnp.int32),
                k=self.clock.clock.k))
            for i, s in enumerate(sessions):
                if ok[i]:
                    sid = s.get("sid") or f"migrated/s{self._session_seq}"
                    s["sid"] = sid
                    self._session_seq += 1
                    self._register_session(sid, s["clock"].clock)
        return ok
