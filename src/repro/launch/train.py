"""End-to-end training driver with clock-stamped checkpointing and
fault-tolerant restart.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch qwen1_5_0_5b --smoke \\
      --steps 200 --ckpt-dir /tmp/ckpt --ckpt-every 50

Restart behavior: if ``--ckpt-dir`` holds a checkpoint, training resumes
from it — after the runtime verifies the checkpoint's bloom clock is an
ancestor of (or equal to) the live run's clock.  ``--inject-failure N``
kills and restarts the loop at step N to exercise the path.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.causal import CausalPolicy
from repro.configs import get_config, get_smoke_config
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.optim.adamw import OptConfig
from repro.runtime.clock_runtime import ClockConfig, ClockRuntime
from repro.runtime.training import init_train_state, make_train_step
from repro.core import clock as bc
from repro.sharding import DEFAULT_RULES, use_mesh_rules


def build(args):
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.seq:
        pass  # seq comes from data config
    opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps,
                        warmup_steps=max(args.steps // 20, 5))
    # the launch spec names the causality policy explicitly: it is the
    # one source of truth the runtime threads through its registry,
    # gossip and checkpoint-lineage gates
    clock_cfg = ClockConfig(policy=CausalPolicy(fp_threshold=1e-4))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                  global_batch=args.batch, run_id=args.run_id))
    return cfg, opt_cfg, clock_cfg, data


def train_loop(args) -> dict:
    cfg, opt_cfg, clock_cfg, data = build(args)
    runtime = ClockRuntime(clock_cfg, run_id=args.run_id)
    mgr = CheckpointManager(args.ckpt_dir, keep=3, run_id=args.run_id)

    step_fn = jax.jit(make_train_step(cfg, opt_cfg, clock_cfg,
                                      num_microbatches=args.microbatches))
    state = init_train_state(jax.random.PRNGKey(args.seed), cfg, opt_cfg,
                             clock_cfg)

    start_step = 0
    if mgr.latest_step() is not None:
        restored, manifest = mgr.restore(target_structure=state)
        ckpt_clock = ClockRuntime.clock_from_snapshot(manifest["clock"])
        ok, status, fp = runtime.admit_restore(ckpt_clock)
        print(f"[train] restore step={manifest['step']} lineage={status} "
              f"fp={fp:.2e} admitted={ok}")
        if not ok:
            raise RuntimeError(f"refusing restore: lineage={status}")
        state = restored
        runtime.clock = bc.merge(runtime.clock, ckpt_clock)
        start_step = manifest["step"]

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = data.batch(step)
        hi, lo = data.event_id(step)
        batch["ev_hi"] = jnp.uint32(hi)
        batch["ev_lo"] = jnp.uint32(lo)
        runtime.tick_batch(step)
        state, metrics = step_fn(state, batch)
        runtime.tick_step(step)
        losses.append(float(metrics["loss"]))
        if args.log_every and step % args.log_every == 0:
            print(f"[train] step={step} loss={losses[-1]:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"clock_sum={float(metrics['clock_sum']):.0f}")
        if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            runtime.tick_checkpoint(step + 1)
            mgr.save(step + 1, state, runtime.snapshot(), block=args.sync_ckpt)
        if args.inject_failure and step + 1 == args.inject_failure:
            mgr.wait()
            print(f"[train] INJECTED FAILURE at step {step + 1}; restarting")
            return _restart(args)
    mgr.wait()
    dt = time.time() - t0
    print(f"[train] done: {args.steps - start_step} steps in {dt:.1f}s, "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return {"losses": losses, "final_state": state, "runtime": runtime}


def _restart(args):
    args2 = argparse.Namespace(**vars(args))
    args2.inject_failure = 0
    return train_loop(args2)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="qwen1_5_0_5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--run-id", type=str, default="run0")
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--sync-ckpt", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--inject-failure", type=int, default=0)
    args = ap.parse_args()
    train_loop(args)


if __name__ == "__main__":
    main()
