import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import (jax locks device count on first init).

_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  - build the step function (train / prefill / serve per the shape kind),
  - ShapeDtypeStruct inputs (no allocation), shardings from the logical
    rule table,
  - ``jax.jit(...).lower(...)`` then ``.compile()`` on the production mesh
    (16x16 single-pod; 2x16x16 multi-pod),
  - record ``memory_analysis()`` (fits?), ``cost_analysis()`` (FLOPs /
    bytes), and the collective-bytes tally parsed from the HLO (not in
    cost_analysis) -> feeds EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen1_5_0_5b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out reports/dryrun]
"""

import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as S
from repro.models.params import param_table
from repro.optim.adamw import OptConfig
from repro.runtime.clock_runtime import ClockConfig
from repro.sharding import DEFAULT_RULES, make_rules, use_mesh_rules
from repro.shapes import SHAPES, runnable

# ---------------------------------------------------------------------------
# HLO collective parsing (collective bytes are NOT in cost_analysis)
# ---------------------------------------------------------------------------

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2, "u16": 2, "f8e4m3": 1,
    "f8e5m2": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _op_output_bytes(line: str) -> int:
    """Bytes of the op's output (incl. tuple elements), from the HLO line."""
    lhs = line.split("=", 1)[0] if "=" in line else line
    # shapes appear right after '=': e.g.  %x = (bf16[4,8]{...}, ...) op(...)
    rhs = line.split("=", 1)[1] if "=" in line else line
    head = rhs.split("(", 2)[0] + (rhs.split("(", 2)[1] if rhs.startswith(" (") else "")
    # simpler: scan shape tokens in the segment before the op name
    seg = rhs[: rhs.find(")") + 1] if rhs.lstrip().startswith("(") else rhs.split(" ", 3)[:3]
    seg = seg if isinstance(seg, str) else " ".join(seg)
    total = 0
    for dt, dims in _SHAPE_RE.findall(seg):
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * nb
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes per collective kind over the HLO module text."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        for kind in _COLLECTIVES:
            # match op name after '=', e.g. "= bf16[...] all-gather(" — avoid
            # matching "all-gather-start"/"-done" twice (count -start only)
            if f" {kind}(" in ls or f" {kind}-start(" in ls:
                out[kind] += _op_output_bytes(ls)
                counts[kind] += 1
                break
    out["counts"] = counts
    return out


# ---------------------------------------------------------------------------
# one cell
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             rules: dict | None = None, opt_override: dict | None = None,
             cfg_override=None, quiet: bool = False) -> dict:
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "kind": shape.kind}
    if not runnable(cfg.family, shape_name):
        rec["status"] = "skip"
        rec["reason"] = "full-attention arch; long_500k needs sub-quadratic path"
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules or dict(DEFAULT_RULES)
    opt_cfg = OptConfig(state_dtype="int8" if cfg.param_dtype == "bfloat16"
                        else "float32", **(opt_override or {}))
    clock_cfg = ClockConfig()

    with use_mesh_rules(mesh, rules):
        step = S.build_step(cfg, shape, opt_cfg, clock_cfg)
        if shape.kind == "train":
            state = S.abstract_state(cfg, opt_cfg, clock_cfg)
            st_sh = S.state_shardings(mesh, rules, cfg, state)
            bspecs = S.batch_specs(cfg, shape)
            b_sh = S.batch_shardings(mesh, bspecs)
            jitted = jax.jit(step, in_shardings=(st_sh, b_sh),
                             out_shardings=(st_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state, bspecs)
        elif shape.kind == "prefill":
            params = S.abstract_params_dict(cfg)
            p_sh = S.params_shardings(mesh, rules, cfg)
            bspecs = S.batch_specs(cfg, shape)
            b_sh = S.batch_shardings(mesh, bspecs)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params, bspecs)
        else:  # decode
            params = S.abstract_params_dict(cfg)
            p_sh = S.params_shardings(mesh, rules, cfg)
            caches = S.cache_specs(cfg, shape, long_context=(shape_name == "long_500k"))
            c_sh = S.cache_shardings(mesh, rules, caches)
            tok = jax.ShapeDtypeStruct((shape.global_batch,), jax.numpy.int32)
            pos = jax.ShapeDtypeStruct((), jax.numpy.int32)
            t_sh = S.batch_shardings(mesh, {"t": tok})["t"]
            jitted = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh, None),
                             out_shardings=(None, c_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params, caches, tok, pos)

        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        rec["bytes_per_device"] = {
            "argument": getattr(mem, "argument_size_in_bytes", None),
            "output": getattr(mem, "output_size_in_bytes", None),
            "temp": getattr(mem, "temp_size_in_bytes", None),
            "peak": getattr(mem, "peak_memory_in_bytes", None),
            "generated_code": getattr(mem, "generated_code_size_in_bytes", None),
        }
        rec["cost"] = {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
        }
        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes(hlo)
        rec["status"] = "ok"
        if not quiet:
            print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: OK "
                  f"(lower {rec['lower_s']}s compile {rec['compile_s']}s, "
                  f"flops={rec['cost']['flops']:.3e})")
            print("  memory:", rec["bytes_per_device"])
            print("  collectives:", {k: v for k, v in rec["collectives"].items()
                                     if k != "counts"})
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", type=str, default="reports/dryrun.jsonl")
    args = ap.parse_args()

    cells = []
    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done = set()
    if os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if r.get("status") in ("ok", "skip"):
                    done.add((r["arch"], r["shape"], r["mesh"]))

    n_fail = 0
    with open(args.out, "a") as f:
        for a, s, mp in cells:
            key = (a, s, "2x16x16" if mp else "16x16")
            if key in done:
                print(f"[dryrun] {key}: cached, skipping")
                continue
            try:
                rec = run_cell(a, s, multi_pod=mp)
            except Exception as e:
                traceback.print_exc()
                rec = {"arch": a, "shape": s,
                       "mesh": "2x16x16" if mp else "16x16",
                       "status": "fail", "error": f"{type(e).__name__}: {e}"}
                n_fail += 1
            f.write(json.dumps(rec) + "\n")
            f.flush()
    print(f"[dryrun] finished, {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
