"""Dry-run plumbing: abstract inputs, state shardings, step functions.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
input of the step that cell lowers (weak-type-correct, shardable, no
device allocation), and ``build_step`` returns the corresponding jittable
function:

  train_4k    -> train_step(state, batch)
  prefill_32k -> prefill_step(params, batch)
  decode_32k / long_500k -> serve_step(params, caches, token, pos)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.params import abstract_params, param_table
from repro.optim.adamw import OptConfig, init_opt_state
from repro.runtime.clock_runtime import ClockConfig
from repro.runtime.training import TrainState, init_train_state, make_train_step
from repro.sharding import logical_to_pspec, use_mesh_rules
from repro.shapes import Shape

__all__ = ["abstract_state", "state_shardings", "batch_specs",
           "batch_shardings", "build_step", "cache_specs", "cache_shardings"]


# --------------------------------------------------------------------------
# abstract state
# --------------------------------------------------------------------------

def abstract_state(cfg: ModelConfig, opt_cfg: OptConfig,
                   clock_cfg: ClockConfig) -> TrainState:
    def init():
        return init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg, clock_cfg)

    return jax.eval_shape(init)


def abstract_params_dict(cfg: ModelConfig) -> dict:
    return abstract_params(cfg)


def params_shardings(mesh: Mesh, rules: dict, cfg: ModelConfig) -> dict:
    table = param_table(cfg)
    return {
        path: NamedSharding(mesh, logical_to_pspec(mesh, rules, info.axes, info.shape))
        for path, info in table.items()
    }


def _dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def state_shardings(mesh: Mesh, rules: dict, cfg: ModelConfig,
                    abstract: TrainState) -> TrainState:
    """Mirror the param table's logical axes onto every state leaf.

    Optimizer moments (incl. int8 Moment codes/scales) reuse their param's
    axes — divisibility fallback handles the blocked scale dims.
    """
    table = param_table(cfg)

    def spec_for(path_key: str, leaf) -> NamedSharding:
        axes = None
        info = table.get(path_key)
        if info is not None and len(info.axes) == leaf.ndim:
            axes = info.axes
        if axes is None:
            axes = (None,) * leaf.ndim
        return NamedSharding(mesh, logical_to_pspec(mesh, rules, axes, leaf.shape))

    def map_dict(d):
        out = {}
        for k, v in d.items():
            if hasattr(v, "codes"):  # Moment
                out[k] = type(v)(codes=spec_for(k, v.codes),
                                 scale=spec_for(k, v.scale), d=v.d)
            else:
                out[k] = spec_for(k, v)
        return out

    repl = NamedSharding(mesh, P())
    return TrainState(
        params=map_dict(abstract.params),
        opt={
            "m": map_dict(abstract.opt["m"]),
            "v": map_dict(abstract.opt["v"]),
            "step": repl,
        },
        clock_cells=repl,
        step=repl,
    )


# --------------------------------------------------------------------------
# batch inputs
# --------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: Shape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    toks = S - cfg.n_prefix if cfg.n_prefix else S
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, toks), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, toks), jnp.int32),
        "ev_hi": jax.ShapeDtypeStruct((), jnp.uint32),
        "ev_lo": jax.ShapeDtypeStruct((), jnp.uint32),
    }
    if cfg.n_prefix:
        specs["prefix_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_prefix, cfg.d_model), cfg.compute_dtype)
    if cfg.is_encdec:
        specs["enc_frames"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_seq, cfg.d_model), cfg.compute_dtype)
    return specs


def batch_shardings(mesh: Mesh, specs: dict) -> dict:
    dp = _dp_axes(mesh)
    out = {}
    for k, v in specs.items():
        if v.ndim == 0:
            out[k] = NamedSharding(mesh, P())
            continue
        B = v.shape[0]
        ext = 1
        for a in dp:
            ext *= mesh.shape[a]
        lead = dp if B % ext == 0 else None
        out[k] = NamedSharding(mesh, P(lead, *([None] * (v.ndim - 1))))
    return out


# --------------------------------------------------------------------------
# decode caches
# --------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, shape: Shape, long_context: bool = False):
    """Abstract decode caches mirroring init_decode_caches."""
    def init():
        return T.init_decode_caches(cfg, shape.global_batch, shape.seq_len,
                                    long_context=long_context)

    return jax.eval_shape(init)


_CACHE_AXES = {
    # leaf-name suffix -> logical axes (leading "layers" implicit)
    "k": ("layers", "act_batch", "act_seq_cache", "act_kv_cache", None),
    "v": ("layers", "act_batch", "act_seq_cache", "act_kv_cache", None),
    "ckv": ("layers", "act_batch", "act_seq_cache", None),
    "krope": ("layers", "act_batch", "act_seq_cache", None),
    "conv": ("layers", "act_batch", None, "act_mlp"),
    "state": ("layers", "act_batch", "act_ssm_heads", None, None),
    # cross-attention cache (enc-dec): enc_seq (1500) rarely divides the
    # model axis -> rely on batch sharding
    "cross": ("layers", "act_batch", "act_seq_cache", "act_kv_cache", None),
}


def cache_shardings(mesh: Mesh, rules: dict, caches) -> dict:
    rules = dict(rules)
    rules.setdefault("act_seq_cache", None)
    rules.setdefault("act_ssm_heads", "model")

    def spec(path, leaf):
        name = None
        for p in reversed(path):
            key = str(getattr(p, "name", getattr(p, "key", "")))
            if key in _CACHE_AXES:
                name = key
                break
        if name is None or len(_CACHE_AXES[name]) != leaf.ndim:
            return NamedSharding(mesh, P(*([None] * leaf.ndim)))
        return NamedSharding(
            mesh, logical_to_pspec(mesh, rules, _CACHE_AXES[name], leaf.shape))

    return jax.tree_util.tree_map_with_path(spec, caches)


# --------------------------------------------------------------------------
# step builders
# --------------------------------------------------------------------------

def build_step(cfg: ModelConfig, shape: Shape, opt_cfg: OptConfig = None,
               clock_cfg: ClockConfig = None) -> Callable:
    opt_cfg = opt_cfg or OptConfig()
    clock_cfg = clock_cfg or ClockConfig()

    if shape.kind == "train":
        return make_train_step(cfg, opt_cfg, clock_cfg)

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            logits, caches = T.prefill(
                params, cfg, batch["tokens"],
                prefix_embeds=batch.get("prefix_embeds"),
                enc_frames=batch.get("enc_frames"),
                buf_len=batch["tokens"].shape[1] + (cfg.n_prefix or 0))
            return logits, caches

        return prefill_step

    def serve_step(params, caches, token, pos):
        return T.decode_step(params, cfg, caches, token, pos)

    return serve_step
