"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS for 512 host devices before first jax init; tests/benches see
the single real CPU device.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

from repro.sharding import FLEET_AXIS

__all__ = ["make_production_mesh", "make_local_mesh", "make_fleet_mesh",
           "mesh_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))


def make_fleet_mesh(shards: int | None = None, axis: str = FLEET_AXIS) -> Mesh:
    """1-D mesh for registry slab sharding (``ClockRegistry(mesh=...)``).

    Takes the FIRST ``shards`` local devices (default: all of them), so
    shard counts below the device count work — the multi-device test
    harness sweeps {1, 2, 4, 8} on one 8-device host platform.  For
    local testing without accelerators, force host devices BEFORE jax
    initializes:  XLA_FLAGS=--xla_force_host_platform_device_count=8
    (tests/conftest.py does this for the whole suite).
    """
    devs = jax.devices()
    shards = len(devs) if shards is None else shards
    if shards < 1 or shards > len(devs):
        raise ValueError(
            f"need 1 <= shards <= {len(devs)} local devices, got {shards}")
    return Mesh(np.asarray(devs[:shards]), (axis,))


def mesh_axes(mesh) -> tuple:
    return tuple(mesh.shape.keys())
