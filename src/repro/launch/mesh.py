"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS for 512 host devices before first jax init; tests/benches see
the single real CPU device.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "mesh_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axes(mesh) -> tuple:
    return tuple(mesh.shape.keys())
