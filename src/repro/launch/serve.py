"""Serving driver: batched prefill + decode with clock-stamped sessions.

Example (CPU, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1_5_0_5b --smoke \\
      --batch 4 --prompt-len 32 --gen 16

With ``--peers "id@host:port,..."`` the replica joins a multi-process
gossip fleet: after serving it runs one anti-entropy session over a
``SocketTransport`` to the listed ``ClockPeerServer`` processes (see
``repro.launch.peers``), so replica clocks reconcile across hosts.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.causal import CausalPolicy
from repro.configs import get_config, get_smoke_config
from repro.models.params import init_params
from repro.runtime.clock_runtime import ClockConfig
from repro.serving.engine import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="qwen1_5_0_5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--peers", type=str, default=None,
                    help="gossip fleet peers, 'id@host:port,...' "
                         "(repro.launch.peers serves them)")
    ap.add_argument("--replica-id", type=str, default="replica0")
    ap.add_argument("--trace-dir", type=str, default=None,
                    help="record spans/metrics/audit for this run under "
                         "this directory (see repro.obs)")
    ap.add_argument("--tiered", action="store_true",
                    help="hold session clocks in a hot/warm/cold "
                         "TieredRegistry behind a streaming admission "
                         "pipeline (repro.serve) instead of the flat "
                         "engine slab")
    ap.add_argument("--hybrid", action="store_true",
                    help="serve session causality through the adaptive "
                         "HybridEngine: exact clocks for the hot set "
                         "over the packed bloom tail (repro.hybrid)")
    ap.add_argument("--fp-budget", type=float, default=1e-4,
                    help="declared Eq. 3 false-positive budget for "
                         "--hybrid; AdaptivePolicy derives the tail "
                         "(m, k) from it — operators set a budget, "
                         "not clock geometry")
    ap.add_argument("--bench-serve", action="store_true",
                    help="run the serve churn benchmark (quick config) "
                         "and exit; heavier runs via "
                         "benchmarks/bench_serve.py")
    args = ap.parse_args()

    if args.bench_serve:
        import json

        from repro.serve.churn import ChurnConfig, run_churn
        report = run_churn(ChurnConfig.quick(seed=args.seed,
                                             trace_dir=args.trace_dir))
        print(json.dumps(report.to_dict(), indent=2))
        raise SystemExit(0 if report.ok() else 1)

    obs = None
    policy = CausalPolicy(fp_threshold=1e-4)
    if args.trace_dir:
        from repro.obs import Observer
        obs = Observer.to_dir(args.trace_dir)
        policy = dataclasses.replace(policy, observer=obs)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = ServingEngine(
        params, cfg,
        ServeConfig(max_batch=args.batch,
                    max_seq=args.prompt_len + args.gen + 8,
                    temperature=args.temperature, seed=args.seed),
        ClockConfig(policy=policy))

    key = jax.random.PRNGKey(args.seed + 1)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)

    t0 = time.time()
    session = engine.admit(prompts)
    t1 = time.time()
    out = engine.generate(session, args.gen)
    t2 = time.time()
    print(f"[serve] prefill {args.batch}x{args.prompt_len} in {t1-t0:.2f}s; "
          f"decode {args.gen} toks in {t2-t1:.2f}s "
          f"({args.batch*args.gen/(t2-t1):.1f} tok/s)")
    print(f"[serve] sample outputs: {out[:, :8].tolist()}")
    print(f"[serve] engine clock sum: {float(engine.clock.clock.sum()):.0f}")

    if args.tiered:
        from repro.serve import AdmissionPipeline, TierConfig, TieredRegistry
        tiers = TieredRegistry(
            TierConfig(hot_capacity=max(16, 4 * args.batch)),
            m=engine.clock.cfg.m, k=engine.clock.cfg.k,
            policy=dataclasses.replace(engine.clock.policy,
                                       fp_threshold=1.0))
        pipe = AdmissionPipeline(tiers, lambda: engine.clock.clock)
        ticket = pipe.submit(session["sid"],
                             clock=session["clock"].clock)
        pipe.drain(timeout=60)
        v = ticket.result(1)
        q = pipe.submit(session["sid"], kind="query").result(60)
        print(f"[serve] tiered admission: {v.verdict} fp={v.fp:.3g} "
              f"admitted={v.admitted} engine={v.engine}; "
              f"query={q.verdict}; tiers={tiers.occupancy()}")
        pipe.close()
        tiers.close()

    if args.hybrid:
        from repro.hybrid import HybridConfig, HybridEngine
        hyb = HybridEngine(
            HybridConfig(m=max(128, engine.clock.cfg.m),
                         k=engine.clock.cfg.k,
                         hot_capacity=max(16, 4 * args.batch),
                         fp_budget=args.fp_budget),
            observer=obs)
        # mirror this run's decode steps into the local chain, then
        # register the serving sessions as prefixes of it
        hyb.advance_local(args.prompt_len + args.gen)
        for i in range(args.batch):
            hyb.admit(f"{session['sid']}/{i}",
                      v=min(args.prompt_len + i, hyb.local_version))
        for _ in range(3):
            for i in range(min(4, args.batch)):
                hyb.touch(f"{session['sid']}/{i}")
        view = hyb.classify()
        hot_n = int(view.hot.sum())
        print(f"[serve] hybrid classify[{view.engine}]: "
              f"{hot_n} hot (exact, fp=0) + {len(view.sids) - hot_n} tail "
              f"rows, tail m={hyb.m}, fp_budget={args.fp_budget:g}, "
              f"hot_fraction={hot_n / max(1, len(view.sids)):.2f}")

    if args.peers:
        from repro.launch.peers import parse_peers, transport_from_specs
        specs = parse_peers(args.peers)
        transport = transport_from_specs(specs, exclude=args.replica_id)
        registry = engine.clock.make_registry(
            capacity=max(8, 2 * len(specs)))
        report = engine.clock.gossip(registry, transport=transport)
        print(f"[serve] gossip[{report.transport}] {report.summary()}")
        print(f"[serve] post-gossip clock sum: "
              f"{float(engine.clock.clock.sum()):.0f}")

    if obs is not None:
        obs.close()
        print(f"[serve] trace written to {args.trace_dir} "
              "(trace.jsonl, metrics.json, audit.jsonl)")


if __name__ == "__main__":
    main()
