"""Process-level gossip peers: specs, a serving loop, and a smoke driver.

``PeerSpec`` / ``parse_peers`` turn ``"id@host:port,..."`` strings into
socket-transport peer tables — the launch-config surface for wiring a
trainer or serving replica into a multi-process gossip fleet
(``repro.launch.serve --peers ...`` uses the same parser).

As a module this is also the multi-process smoke driver CI runs:

    python -m repro.launch.peers --smoke 3

spawns ``N-1`` real child processes, each serving its own clock over a
``ClockPeerServer`` on localhost TCP, then drives anti-entropy sessions
from the leader over a ``SocketTransport``.  The children's clocks are
constructed as strict causal prefixes of the leader's, so the paper's
§3 guarantee makes any quarantine a false negative; the driver asserts
zero of them, asserts the fleet converges (every peer's digest CRC
equals the merged union's), and asserts the second round's delta phase
is empty (converged peers cost digest bytes only).  Exit code 0 on
success — the CI job is exactly this invocation.

Child mode (spawned by the driver, or by hand for ad-hoc fleets):

    python -m repro.launch.peers --serve node1@127.0.0.1:0 \\
        --m 128 --k 3 --tick-prefix 40 --port-file /tmp/node1.port
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import subprocess
import sys
import tempfile
import time

import numpy as np

__all__ = ["PeerSpec", "parse_peers", "transport_from_specs"]


@dataclasses.dataclass(frozen=True)
class PeerSpec:
    peer_id: str
    host: str
    port: int

    @property
    def address(self) -> tuple[str, int]:
        return (self.host, self.port)

    def __str__(self) -> str:
        return f"{self.peer_id}@{self.host}:{self.port}"


def parse_peers(spec: str) -> list[PeerSpec]:
    """Parse ``"id@host:port,id@host:port,..."`` into PeerSpecs."""
    out = []
    for part in filter(None, (s.strip() for s in spec.split(","))):
        try:
            pid, addr = part.split("@", 1)
            host, port = addr.rsplit(":", 1)
            # bracketed IPv6 ("[::1]:9002"): strip the brackets so the
            # host is directly connectable by socket.create_connection
            if host.startswith("[") and host.endswith("]"):
                host = host[1:-1]
            out.append(PeerSpec(pid, host, int(port)))
        except ValueError as e:
            raise ValueError(
                f"bad peer spec {part!r} (want id@host:port)") from e
    ids = [p.peer_id for p in out]
    if len(set(ids)) != len(ids):
        raise ValueError(f"duplicate peer ids in {spec!r}")
    return out


def transport_from_specs(specs, exclude: str | None = None,
                         timeout: float = 5.0):
    """SocketTransport over the given peers (minus ``exclude``, the
    caller's own id when the spec string lists the whole fleet)."""
    from repro.fleet.transport import SocketTransport
    return SocketTransport(
        {p.peer_id: p.address for p in specs if p.peer_id != exclude},
        timeout=timeout)


def _ticked_clock(m: int, k: int, n_events: int):
    """Deterministic event prefix: every process ticking ``n`` events
    gets a clock that is a causal prefix of any process ticking more."""
    import jax.numpy as jnp
    from repro.core import clock as bc
    c = bc.zeros(m, k)
    for e in range(n_events):
        c = bc.tick(c, jnp.uint32(e >> 32), jnp.uint32(e & 0xFFFFFFFF))
    return c


def _serve(args) -> int:
    from repro.fleet.transport import ClockNode, ClockPeerServer
    spec = parse_peers(args.serve)[0]
    node = ClockNode(spec.peer_id, args.m, args.k)
    if args.tick_prefix:
        clock = _ticked_clock(args.m, args.k, args.tick_prefix)
        node.set_cells(np.asarray(clock.logical_cells()))
    server = ClockPeerServer(node, spec.host, spec.port).start()
    host, port = server.address
    if args.port_file:
        tmp = args.port_file + ".tmp"
        with open(tmp, "w") as f:
            f.write(f"{host}:{port}\n")
        os.replace(tmp, args.port_file)      # atomic: readers never see half
    print(f"[peer {spec.peer_id}] serving on {host}:{port} "
          f"(prefix={args.tick_prefix})", flush=True)
    try:
        while True:                          # until the driver kills us
            time.sleep(1.0)
    except KeyboardInterrupt:
        pass
    finally:
        server.stop()
    return 0


def _wait_port_file(path: str, timeout: float = 90.0) -> tuple[str, int]:
    deadline = time.time() + timeout
    while time.time() < deadline:
        if os.path.exists(path):
            host, port = open(path).read().strip().rsplit(":", 1)
            return host, int(port)
        time.sleep(0.1)
    raise TimeoutError(f"peer never wrote {path}")


def _check_trace(obs, trace_dir: str, rounds: int, replay_policy) -> list:
    """Post-run observability assertions for the smoke driver: the trace
    parses, every round produced one complete session span with its
    phase children, the Chrome export writes, and the audit trail's
    frame replay matches the live verdicts bit-for-bit."""
    import json

    from repro.obs import export as obs_export

    failures = []
    obs.flush()
    spans = obs_export.load_spans(os.path.join(trace_dir, "trace.jsonl"))
    sessions = [s for s in spans if s["name"] == "gossip.session"]
    if len(sessions) != rounds:
        failures.append(
            f"trace has {len(sessions)} gossip.session spans, "
            f"expected one per round ({rounds})")
    for sess in sessions:
        kids = {s["name"] for s in spans if s["parent"] == sess["sid"]}
        missing = {"gossip.digest", "gossip.pull",
                   "gossip.classify"} - kids
        if missing:
            failures.append(
                f"session span {sess['sid']} missing phase children "
                f"{sorted(missing)}")
    names = {s["name"] for s in spans}
    for phase in ("gossip.digest", "gossip.pull", "gossip.classify",
                  "gossip.union", "gossip.push"):
        if phase not in names:
            failures.append(f"trace never recorded a {phase} span")
    chrome_path = os.path.join(trace_dir, "trace.chrome.json")
    with open(chrome_path, "w") as f:
        json.dump(obs_export.to_chrome(spans), f)
    replay = obs.audit.replay_frames(policy=replay_policy)
    if replay.checked == 0 or not replay.ok:
        failures.append(f"audit frame replay failed: {replay.summary()}")
    if not failures:
        print(f"[leader] trace OK: {len(spans)} spans, "
              f"{len(sessions)} sessions, chrome export at {chrome_path}; "
              f"audit {replay.summary()}", flush=True)
    return failures


def _smoke(args) -> int:
    from repro.causal import CausalPolicy
    from repro.core import wire
    from repro.fleet.gossip import GossipConfig
    from repro.fleet.registry import ClockRegistry
    from repro.fleet.transport import SocketTransport
    from repro.fleet.transport.session import anti_entropy_session
    from repro.obs import Observer

    n, m, k, events = args.smoke, args.m, args.k, args.events
    children, peers = [], {}
    tmpdir = tempfile.mkdtemp(prefix="gossip-peers-")
    try:
        for i in range(1, n):
            pid = f"node{i}"
            port_file = os.path.join(tmpdir, f"{pid}.port")
            # strict prefixes of the leader's event sequence: every
            # peer is a true ancestor, so quarantine == false negative
            prefix = events * (n - i) // n
            children.append(subprocess.Popen(
                [sys.executable, "-m", "repro.launch.peers",
                 "--serve", f"{pid}@127.0.0.1:0",
                 "--m", str(m), "--k", str(k),
                 "--tick-prefix", str(prefix), "--port-file", port_file],
                env={**os.environ, "JAX_PLATFORMS": "cpu"}))
            peers[pid] = port_file
        addresses = {pid: _wait_port_file(path)
                     for pid, path in peers.items()}
        print(f"[leader] {n - 1} peers up: "
              + " ".join(f"{pid}@{h}:{p}"
                         for pid, (h, p) in addresses.items()), flush=True)

        leader = _ticked_clock(m, k, events)
        policy = CausalPolicy(fp_threshold=1.0)
        obs = None
        if args.trace_dir:
            obs = Observer.to_dir(args.trace_dir)
            policy = dataclasses.replace(policy, observer=obs)
        registry = ClockRegistry(capacity=max(8, n), m=m, k=k,
                                 policy=policy)
        transport = SocketTransport(addresses, timeout=10.0)
        cfg = GossipConfig(policy=policy, straggler_gap=np.inf)

        reports = []
        merged = leader
        for r in range(args.rounds):
            merged, report = anti_entropy_session(
                registry, merged, transport, cfg)
            reports.append(report)
            print(f"[leader] round {r}: {report.summary()}", flush=True)

        failures = []
        if any(int(rep.quarantined.sum()) for rep in reports):
            failures.append(
                "false negative: a causally-ordered peer was quarantined")
        if int(reports[0].n_accepted) != n - 1:
            failures.append(
                f"round 0 accepted {reports[0].n_accepted}/{n - 1} peers")
        if reports[1].delta_bytes != 0:
            failures.append(
                f"round 1 re-pulled {reports[1].delta_bytes}B from "
                "converged peers (digest/delta skip broken)")
        digests, _ = transport.digests()
        union_crc = wire.cells_crc(np.asarray(merged.logical_cells()))
        stragglers = {pid: d.crc for pid, d in digests.items()
                      if d.crc != union_crc}
        if stragglers:
            failures.append(f"fleet did not converge: {sorted(stragglers)} "
                            "disagree with the union")
        if obs is not None:
            failures.extend(_check_trace(
                obs, args.trace_dir, args.rounds,
                CausalPolicy(fp_threshold=1.0)))
            obs.close()
        if failures:
            for f in failures:
                print(f"[leader] FAIL: {f}", flush=True)
            return 1
        wire_total = sum(rep.wire_bytes for rep in reports)
        print(f"[leader] OK: {n} processes converged in {args.rounds} "
              f"rounds, 0 false negatives, {wire_total}B measured on the "
              "wire", flush=True)
        return 0
    finally:
        for child in children:
            child.terminate()
        for child in children:
            try:
                child.wait(timeout=10)
            except subprocess.TimeoutExpired:
                child.kill()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--serve", type=str, default=None,
                    help="child mode: serve one peer, id@host:port")
    ap.add_argument("--smoke", type=int, default=None, metavar="N",
                    help="driver mode: spawn N-1 peer processes and run "
                         "anti-entropy sessions from the leader")
    ap.add_argument("--m", type=int, default=128)
    ap.add_argument("--k", type=int, default=3)
    ap.add_argument("--events", type=int, default=48,
                    help="leader event count (children tick prefixes)")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--tick-prefix", type=int, default=0,
                    help="child mode: tick this causal event prefix")
    ap.add_argument("--port-file", type=str, default=None,
                    help="child mode: write the bound host:port here")
    ap.add_argument("--trace-dir", type=str, default=None,
                    help="driver mode: record spans/metrics/audit under "
                         "this directory and assert the trace is complete "
                         "(trace.jsonl, trace.chrome.json, metrics.json, "
                         "audit.jsonl)")
    args = ap.parse_args(argv)
    if (args.serve is None) == (args.smoke is None):
        ap.error("pick exactly one of --serve / --smoke")
    if args.smoke is not None and args.rounds < 2:
        ap.error("--smoke needs --rounds >= 2 (round 1 asserts the "
                 "converged fleet's delta phase is empty)")
    return _serve(args) if args.serve else _smoke(args)


if __name__ == "__main__":
    sys.exit(main())
