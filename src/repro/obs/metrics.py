"""Process-local metrics: counters, gauges, and streaming histograms.

A :class:`MetricsRecorder` hands out named instruments with optional
label sets — ``rec.counter("gossip_bytes", phase="digest")`` — keyed on
``(kind, name, sorted labels)`` so the same call site always returns
the same instrument.  Everything is plain Python + numpy; no exporter
dependencies, one ``dump()`` call serializes the whole registry.

The histogram is *streaming* with fixed bin edges in **log10 space**
(defaulting to the Eq. 3 fp bands used by ``fleet_health``): samples
are clipped into the edge range, binned with ``np.histogram``, and only
the per-bin counts plus count/total/min/max survive.  Two histograms
over the same edges merge exactly — merging recorders from two
processes is identical to one recorder having seen the concatenated
sample stream (the property test in ``tests/test_obs.py`` pins this).

Disabled metrics cost near zero: :class:`NullRecorder` returns shared
no-op instruments — no dict lookup, no allocation.
"""
from __future__ import annotations

import json
import math
import threading

import numpy as np

__all__ = [
    "Counter", "Gauge", "Histogram", "FP_LOG10_EDGES",
    "MetricsRecorder", "NullRecorder", "NULL_RECORDER",
]

# log10(fp) bands matching fleet_health's fp_bins=12 default over
# [1e-30, 1]; a 13-edge linspace gives 12 bins plus under/overflow
# handled by clipping.
FP_LOG10_EDGES = tuple(np.linspace(-30.0, 0.0, 13).tolist())


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n

    def as_dict(self) -> dict:
        return {"value": self.value}


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = None

    def set(self, v) -> None:
        self.value = float(v)

    def as_dict(self) -> dict:
        return {"value": self.value}


class Histogram:
    """Fixed-edge log10-binned streaming histogram with exact merge."""

    __slots__ = ("edges", "counts", "count", "total", "vmin", "vmax",
                 "_edges_arr", "_floor")

    def __init__(self, edges=FP_LOG10_EDGES):
        self.edges = tuple(float(e) for e in edges)
        self.counts = np.zeros(len(self.edges) - 1, np.int64)
        self.count = 0
        self.total = 0.0
        self.vmin = None
        self.vmax = None
        self._edges_arr = np.asarray(self.edges)
        self._floor = 10.0 ** self.edges[0]

    def _bin_of(self, logs):
        """Bin indices matching np.histogram's convention: right-open
        bins, the last bin closed (``logs`` already clipped to range)."""
        idx = np.searchsorted(self._edges_arr, logs, side="right") - 1
        return np.clip(idx, 0, self.counts.size - 1)

    def observe(self, v) -> None:
        # scalar fast path: the hot per-session call sites observe one
        # value at a time, so skip the array round-trip
        v = float(v)
        self.count += 1
        self.total += v
        self.vmin = v if self.vmin is None else min(self.vmin, v)
        self.vmax = v if self.vmax is None else max(self.vmax, v)
        log = math.log10(v) if v > self._floor else self.edges[0]
        log = min(log, self.edges[-1])
        self.counts[int(self._bin_of(log))] += 1

    def observe_many(self, values) -> None:
        vals = np.asarray(values, np.float64).ravel()
        if vals.size == 0:
            return
        self.count += int(vals.size)
        self.total += float(vals.sum())
        lo, hi = (float(vals.min()), float(vals.max()))
        self.vmin = lo if self.vmin is None else min(self.vmin, lo)
        self.vmax = hi if self.vmax is None else max(self.vmax, hi)
        # values are raw fp probabilities; bin in log10 space, clipping
        # zeros/underflow into the lowest bin and >=1 into the highest.
        logs = np.log10(np.clip(vals, self._floor, None))
        logs = np.clip(logs, self.edges[0], self.edges[-1])
        self.counts += np.bincount(self._bin_of(logs),
                                   minlength=self.counts.size)

    def add_counts(self, counts) -> None:
        """Fold pre-binned counts (e.g. ``FleetHealth.fp_hist``) in;
        bins must align with this histogram's edges."""
        counts = np.asarray(counts, np.int64)
        if counts.shape != self.counts.shape:
            raise ValueError(
                f"bin mismatch: {counts.shape} vs {self.counts.shape}")
        self.counts += counts
        self.count += int(counts.sum())

    def merge(self, other: "Histogram") -> None:
        if self.edges != other.edges:
            raise ValueError("cannot merge histograms with different edges")
        self.counts += other.counts
        self.count += other.count
        self.total += other.total
        for attr, pick in (("vmin", min), ("vmax", max)):
            ov = getattr(other, attr)
            if ov is not None:
                sv = getattr(self, attr)
                setattr(self, attr, ov if sv is None else pick(sv, ov))

    def as_dict(self) -> dict:
        return {
            "edges": list(self.edges),
            "counts": self.counts.tolist(),
            "count": self.count,
            "total": self.total,
            "min": self.vmin,
            "max": self.vmax,
        }


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRecorder:
    """Registry of named, labeled instruments."""

    def __init__(self):
        self._instruments: dict = {}
        self._lock = threading.Lock()

    def __bool__(self) -> bool:
        return True

    def _get(self, kind: str, name: str, labels: dict, **kw):
        key = (kind, name, tuple(sorted(labels.items())))
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = self._instruments[key] = _KINDS[kind](**kw)
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, edges=FP_LOG10_EDGES, **labels) -> Histogram:
        return self._get("histogram", name, labels, edges=edges)

    def merge(self, other: "MetricsRecorder") -> None:
        """Fold another recorder in (counters add, gauges take theirs,
        histograms merge exactly)."""
        with other._lock:
            items = list(other._instruments.items())
        for (kind, name, labels), inst in items:
            mine = self._get(kind, name, dict(labels),
                             **({"edges": inst.edges}
                                if kind == "histogram" else {}))
            if kind == "counter":
                mine.inc(inst.value)
            elif kind == "gauge":
                if inst.value is not None:
                    mine.set(inst.value)
            else:
                mine.merge(inst)

    def dump(self) -> list:
        """Every instrument as a JSON-ready record."""
        with self._lock:
            items = sorted(self._instruments.items(),
                           key=lambda kv: (kv[0][0], kv[0][1], kv[0][2]))
        return [
            {"kind": kind, "name": name, "labels": dict(labels),
             **inst.as_dict()}
            for (kind, name, labels), inst in items
        ]

    def to_json(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.dump(), f, indent=1)


class _NullInstrument:
    __slots__ = ()

    def inc(self, n=1) -> None:
        pass

    def set(self, v) -> None:
        pass

    def observe(self, v) -> None:
        pass

    def observe_many(self, values) -> None:
        pass

    def add_counts(self, counts) -> None:
        pass


_NULL_INSTRUMENT = _NullInstrument()


class NullRecorder:
    """Metrics disabled: every instrument is the same shared no-op."""

    __slots__ = ()

    def __bool__(self) -> bool:
        return False

    def counter(self, name: str, **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def gauge(self, name: str, **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def histogram(self, name: str, edges=FP_LOG10_EDGES, **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def dump(self) -> list:
        return []

    def to_json(self, path) -> None:
        pass


NULL_RECORDER = NullRecorder()
