"""Trace export: JSONL span stream -> Chrome ``trace_event`` JSON.

    python -m repro.obs.export trace.jsonl --chrome -o trace.chrome.json

The output loads directly in ``chrome://tracing`` / Perfetto: each span
becomes one complete ("ph": "X") event with its attributes under
``args``; pid/tid come from the emitting process/thread so a 3-process
socket smoke renders as three lanes.  Without ``--chrome`` the tool
just validates the stream and prints a per-span-name summary.
"""
from __future__ import annotations

import argparse
import json
import sys

__all__ = ["load_spans", "to_chrome", "summarize", "main"]


def load_spans(path) -> list[dict]:
    """Strictly parse a trace JSONL file to a list of span dicts.

    Meta header lines are skipped; any non-JSON or non-span line raises
    (a truncated or interleaved trace should fail loudly, not render a
    misleading timeline).
    """
    spans = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{lineno}: not JSON: {e}") from None
            if "meta" in ev:
                continue
            for key in ("name", "sid", "ts_us", "dur_us"):
                if key not in ev:
                    raise ValueError(
                        f"{path}:{lineno}: span record missing {key!r}")
            spans.append(ev)
    return spans


def to_chrome(spans: list[dict]) -> dict:
    """Spans -> Chrome trace_event 'complete event' JSON object."""
    events = []
    for ev in spans:
        events.append({
            "ph": "X",
            "name": ev["name"],
            "ts": ev["ts_us"],
            "dur": ev["dur_us"],
            "pid": ev.get("pid", 0),
            "tid": ev.get("tid", 0),
            "args": ev.get("attrs", {}),
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def summarize(spans: list[dict]) -> str:
    by_name: dict[str, list[float]] = {}
    for ev in spans:
        by_name.setdefault(ev["name"], []).append(ev["dur_us"])
    lines = [f"{len(spans)} spans, {len(by_name)} names"]
    for name in sorted(by_name):
        durs = by_name[name]
        lines.append(
            f"  {name:<28} n={len(durs):<5} total={sum(durs)/1e3:9.2f}ms "
            f"max={max(durs)/1e3:8.2f}ms")
    return "\n".join(lines)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.export",
        description="validate / convert bloom-clock trace JSONL")
    p.add_argument("trace", help="trace.jsonl emitted by obs.Tracer")
    p.add_argument("--chrome", action="store_true",
                   help="emit Chrome trace_event JSON")
    p.add_argument("-o", "--out", default=None,
                   help="output path (default: stdout)")
    args = p.parse_args(argv)

    spans = load_spans(args.trace)
    if args.chrome:
        out = json.dumps(to_chrome(spans))
        if args.out:
            with open(args.out, "w") as f:
                f.write(out)
            print(f"wrote {args.out}: {len(spans)} events")
        else:
            print(out)
    else:
        print(summarize(spans))
    return 0


if __name__ == "__main__":
    sys.exit(main())
