"""Fleet-wide causality observability: spans, metrics, audit trail.

Three sinks, one rider object:

- ``obs.trace``   — nestable span contexts -> JSONL -> Chrome trace
- ``obs.metrics`` — counters / gauges / streaming log10 fp histograms
- ``obs.audit``   — append-only acted-on verdict log with replay

``Observer`` bundles them and rides ``CausalPolicy(observer=...)`` the
same way ``policy`` rides everything else; disabled sinks are null
objects with near-zero call cost.  This package imports nothing from
the rest of ``repro`` at module level (audit replay lazy-imports), so
any layer can depend on it without cycles.
"""
from repro.obs.audit import NULL_AUDIT, AuditRecord, AuditTrail, NullAudit, ReplayReport
from repro.obs.metrics import (
    FP_LOG10_EDGES,
    NULL_RECORDER,
    Counter,
    Gauge,
    Histogram,
    MetricsRecorder,
    NullRecorder,
)
from repro.obs.observer import NULL_OBSERVER, Observer, resolve
from repro.obs.trace import NULL_TRACER, NullTracer, Tracer

__all__ = [
    "Observer", "NULL_OBSERVER", "resolve",
    "Tracer", "NullTracer", "NULL_TRACER",
    "MetricsRecorder", "NullRecorder", "NULL_RECORDER",
    "Counter", "Gauge", "Histogram", "FP_LOG10_EDGES",
    "AuditTrail", "AuditRecord", "NullAudit", "NULL_AUDIT", "ReplayReport",
]
