"""Nestable trace spans with monotonic timings and typed attributes.

A :class:`Tracer` hands out span context managers; entering a span
pushes it on a thread-local stack (so nesting needs no plumbing — a
``CausalEngine.classify`` span started inside a gossip session span
records that session as its parent automatically), and exiting emits
one JSONL record with the span's monotonic start/duration in
microseconds, its id/parent-id, process/thread ids, and its attributes.

Timing is ``time.perf_counter_ns`` relative to the tracer's origin —
monotonic within a process, immune to wall-clock steps.  A ``meta``
header line records the wall-clock origin so multi-process traces can
be aligned after the fact.

Attributes are *typed*: ``str``/``int``/``float``/``bool``/``None``
pass through verbatim; anything else is stringified at emit time so a
stray jax array in an attr can never make a record unserializable.

Disabled tracing costs near zero: :class:`NullTracer` returns one
shared no-op span object from every ``span()`` call — no allocation,
no clock read, no stack push.

``repro.obs.export`` converts the JSONL stream to Chrome
``trace_event`` format (load in ``chrome://tracing`` / Perfetto).
"""
from __future__ import annotations

import itertools
import json
import os
import threading
import time

__all__ = ["Tracer", "NullTracer", "NULL_TRACER"]

_ATTR_TYPES = (str, int, float, bool, type(None))


def _typed(attrs: dict) -> dict:
    return {k: (v if isinstance(v, _ATTR_TYPES) else str(v))
            for k, v in attrs.items()}


class _Span:
    """One live span: its own context manager, re-entrant never."""

    __slots__ = ("_tracer", "name", "sid", "parent", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.sid = next(tracer._ids)
        self.parent = None
        self._t0 = 0

    def set(self, **attrs) -> "_Span":
        """Attach attributes discovered mid-span (engine chosen, bytes
        moved, ...); later keys win."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "_Span":
        stack = self._tracer._stack()
        self.parent = stack[-1].sid if stack else None
        stack.append(self)
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter_ns()
        self._tracer._stack().pop()
        self._tracer._emit(self, t1)
        return False


class Tracer:
    """Span factory + JSONL sink (in-memory always; file when ``path``)."""

    def __init__(self, path=None):
        self._path = str(path) if path else None
        self._events: list[dict] = []
        self._ids = itertools.count(1)
        self._tl = threading.local()
        self._lock = threading.Lock()
        self._origin_ns = time.perf_counter_ns()
        self.origin_unix = time.time()
        self._file = None
        if self._path:
            self._file = open(self._path, "w")
            self._file.write(json.dumps({
                "meta": {"origin_unix": self.origin_unix,
                         "pid": os.getpid()}}) + "\n")

    def __bool__(self) -> bool:
        return True

    def _stack(self) -> list:
        stack = getattr(self._tl, "stack", None)
        if stack is None:
            stack = self._tl.stack = []
        return stack

    def span(self, name: str, **attrs) -> _Span:
        """A context manager recording one complete span."""
        return _Span(self, name, _typed(attrs) if attrs else {})

    def _emit(self, span: _Span, t1_ns: int) -> None:
        ev = {
            "name": span.name,
            "sid": span.sid,
            "parent": span.parent,
            "ts_us": (span._t0 - self._origin_ns) / 1e3,
            "dur_us": (t1_ns - span._t0) / 1e3,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
            "attrs": _typed(span.attrs),
        }
        with self._lock:
            self._events.append(ev)
            if self._file is not None:
                self._file.write(json.dumps(ev) + "\n")

    def events(self) -> list[dict]:
        """Snapshot of every span emitted so far (exit order: children
        before their parents)."""
        with self._lock:
            return list(self._events)

    def flush(self) -> None:
        with self._lock:
            if self._file is not None:
                self._file.flush()

    def close(self) -> None:
        with self._lock:
            if self._file is not None:
                self._file.close()
                self._file = None


class _NullSpan:
    __slots__ = ()

    def set(self, **attrs) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Tracing disabled: every ``span()`` is the same shared no-op."""

    __slots__ = ()

    def __bool__(self) -> bool:
        return False

    def span(self, name: str, **attrs) -> _NullSpan:
        return _NULL_SPAN

    def events(self) -> list:
        return []

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


NULL_TRACER = NullTracer()
