"""The Observer: one object that rides policies the way ``policy`` does.

``Observer(trace=..., metrics=..., audit=...)`` bundles the three
instrumentation sinks; any component left ``None`` is replaced by its
null twin, so instrumented code never branches — it always calls
``obs.trace.span(...)`` / ``obs.metrics.counter(...)`` / ``obs.audit
.record(...)`` and pays near-zero when the sink is off.

Threading: set ``CausalPolicy(observer=obs)`` and every consumer of the
policy — ``CausalEngine``, ``ClockRegistry``, ``ClockRuntime``,
``GossipConfig``-driven sessions, ``ServingEngine`` — picks it up with
no further arguments.  ``resolve(x)`` normalizes "maybe an Observer,
maybe None" call sites to a never-None observer.

``Observer.to_dir(path)`` is the batteries-included constructor used by
the ``--trace-dir`` launch flags: trace.jsonl + metrics.json +
audit.jsonl (with wire frames, so the audit replays standalone).
"""
from __future__ import annotations

import os

from repro.obs.audit import NULL_AUDIT, AuditTrail
from repro.obs.metrics import NULL_RECORDER, MetricsRecorder
from repro.obs.trace import NULL_TRACER, Tracer

__all__ = ["Observer", "NULL_OBSERVER", "resolve"]


class Observer:
    """Bundle of trace/metrics/audit sinks (None components → null)."""

    __slots__ = ("trace", "metrics", "audit", "_dir")

    def __init__(self, trace=None, metrics=None, audit=None):
        self.trace = trace if trace is not None else NULL_TRACER
        self.metrics = metrics if metrics is not None else NULL_RECORDER
        self.audit = audit if audit is not None else NULL_AUDIT
        self._dir = None

    def __bool__(self) -> bool:
        return bool(self.trace) or bool(self.metrics) or bool(self.audit)

    # Policies carrying an observer stay hashable (identity semantics —
    # two policies share instrumentation iff they share the object).
    def __hash__(self) -> int:
        return id(self)

    def __eq__(self, other) -> bool:
        return self is other

    @classmethod
    def to_dir(cls, path) -> "Observer":
        """Full observer writing trace.jsonl / metrics.json / audit.jsonl
        (frames stored — the audit trail replays standalone)."""
        os.makedirs(path, exist_ok=True)
        obs = cls(
            trace=Tracer(os.path.join(path, "trace.jsonl")),
            metrics=MetricsRecorder(),
            audit=AuditTrail(os.path.join(path, "audit.jsonl"),
                             store_frames=True),
        )
        obs._dir = str(path)
        return obs

    def flush(self) -> None:
        self.trace.flush()
        self.audit.flush()
        if self._dir is not None and self.metrics:
            self.metrics.to_json(os.path.join(self._dir, "metrics.json"))

    def close(self) -> None:
        self.flush()
        self.trace.close()
        self.audit.close()


NULL_OBSERVER = Observer()


def resolve(obs) -> Observer:
    """Normalize an optional observer to a never-None one."""
    return obs if obs is not None else NULL_OBSERVER
