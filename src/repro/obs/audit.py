"""Append-only audit trail of acted-on causality verdicts.

Every strict-order verdict a gossip session or a serving admit *acts
on* — accept a peer's history, quarantine a fork, adopt a migrating
session — is recorded with everything needed to re-check it later:
the CRC content digests of both clocks (``core.wire.cells_crc``), the
verdict, the Eq. 3 false-positive probability the engine claimed, the
policy threshold it was gated against, and which engine produced it.
With ``store_frames=True`` the trail additionally keeps both clocks'
wire frames (base64 in the JSONL), making every record *standalone
replayable* even after push-back has overwritten the registry row the
verdict was computed from.

Two replay checkers:

- :func:`AuditTrail.replay` re-runs ``classify_all`` against a live
  registry and compares verdict + fp **bit-for-bit**; records whose
  CRC pair no longer matches the registry state are reported ``stale``
  rather than failed (the row moved on — expected under push-back).
- :func:`AuditTrail.replay_frames` decodes the stored wire frames,
  re-admits them into a scratch registry, and re-runs the same
  ``classify_all`` path the live session used — exact regardless of
  what happened to the original registry since.

Under ``run_gossip_sim`` each verdict is additionally annotated with
vector-clock ground truth (``annotate_truth``), so the trail reports a
*measured* fp rate next to the predicted one and ``fp_within_band``
becomes a continuously evaluated property instead of a sim-only one.
"""
from __future__ import annotations

import base64
import dataclasses
import json
from typing import Optional

import numpy as np

__all__ = ["AuditRecord", "AuditTrail", "NullAudit", "NULL_AUDIT",
           "ReplayReport"]


@dataclasses.dataclass
class AuditRecord:
    """One acted-on verdict (or transport fault) in the trail."""

    seq: int
    kind: str                 # "verdict" | "peer_unreachable" | "chaos"
                              # | "frame_ingest" | "frame_rejected"
                              # | "row_corrupt" | "row_repaired"
    peer_id: str
    verdict: str = ""         # STATUS_NAMES string, e.g. "ancestor"
    action: str = ""          # what the verdict drove: accept/quarantine/...
    fp: float = 0.0           # Eq. 3 fp the engine claimed
    threshold: float = 0.0    # policy gate it was compared against
    engine: str = ""          # dispatch label that produced it
    local_crc: int = 0        # cells_crc of the local/query clock
    peer_crc: int = 0         # cells_crc of the peer clock
    local_sum: float = 0.0
    peer_sum: float = 0.0
    transport: str = ""
    detail: str = ""          # free text (e.g. the unreachable error)
    truth_ok: Optional[bool] = None   # vector-clock ground truth, if known
    local_frame: Optional[bytes] = None   # wire frames for replay_frames
    peer_frame: Optional[bytes] = None

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        for key in ("local_frame", "peer_frame"):
            if d[key] is not None:
                d[key] = base64.b64encode(d[key]).decode()
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "AuditRecord":
        d = dict(d)
        for key in ("local_frame", "peer_frame"):
            if d.get(key) is not None:
                d[key] = base64.b64decode(d[key])
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)
                      if f.name in d})


@dataclasses.dataclass
class ReplayReport:
    """Outcome of re-verifying a trail's verdicts."""

    checked: int = 0          # records re-verified
    matched: int = 0          # verdict AND fp bit-identical
    stale: int = 0            # CRC pair no longer matches registry state
    skipped: int = 0          # not replayable (no frames / unknown peer)
    mismatches: list = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return self.checked > 0 and not self.mismatches

    def summary(self) -> str:
        return (f"replay: {self.matched}/{self.checked} matched, "
                f"{self.stale} stale, {self.skipped} skipped, "
                f"{len(self.mismatches)} mismatched")


class AuditTrail:
    """Append-only verdict log, optionally mirrored to JSONL."""

    def __init__(self, path=None, *, store_frames: bool = False):
        self.records: list[AuditRecord] = []
        self.store_frames = store_frames
        self._path = str(path) if path else None
        self._file = open(self._path, "w") if self._path else None
        self._seq = 0

    def __bool__(self) -> bool:
        return True

    def __len__(self) -> int:
        return len(self.records)

    def record(self, kind: str, peer_id, **kw) -> AuditRecord:
        if not self.store_frames:
            kw.pop("local_frame", None)
            kw.pop("peer_frame", None)
        rec = AuditRecord(seq=self._seq, kind=kind, peer_id=str(peer_id), **kw)
        self._seq += 1
        self.records.append(rec)
        if self._file is not None:
            self._file.write(json.dumps(rec.as_dict()) + "\n")
        return rec

    def annotate_truth(self, rec: AuditRecord, ok: bool) -> None:
        """Attach vector-clock ground truth to a recorded verdict; the
        JSONL mirror gets an amend line keyed by seq."""
        rec.truth_ok = bool(ok)
        if self._file is not None:
            self._file.write(json.dumps(
                {"amend": rec.seq, "truth_ok": rec.truth_ok}) + "\n")

    def flush(self) -> None:
        if self._file is not None:
            self._file.flush()

    def close(self) -> None:
        if self._file is not None:
            self._file.close()
            self._file = None

    # ---- accounting ----
    def verdicts(self) -> list[AuditRecord]:
        return [r for r in self.records if r.kind == "verdict"]

    def chaos_events(self) -> list[AuditRecord]:
        """Realized fault schedule (``kind="chaos"``) in injection
        order — with the seed, this is the repro of a hostile run."""
        return [r for r in self.records if r.kind == "chaos"]

    def frame_sequence(self) -> list[AuditRecord]:
        """Realized ingest order of decoded delta frames
        (``kind="frame_ingest"``): which frame landed in which session,
        in order — the message schedule a chaos replay must reproduce."""
        return [r for r in self.records if r.kind == "frame_ingest"]

    def mean_predicted_fp(self) -> float:
        """Mean claimed Eq. 3 fp over strict-order verdicts on record."""
        fps = [r.fp for r in self.verdicts()
               if r.verdict in ("ancestor", "descendant")]
        return float(np.mean(fps)) if fps else 0.0

    def measured_fp_rate(self) -> Optional[float]:
        """Fraction of truth-annotated strict verdicts ground truth
        refutes — the *measured* counterpart of Eq. 3.  None until at
        least one verdict has been annotated."""
        judged = [r for r in self.verdicts() if r.truth_ok is not None]
        if not judged:
            return None
        return float(np.mean([not r.truth_ok for r in judged]))

    def fp_within_band(self, slack: float = 3.0, abs_tol: float = 0.01) -> Optional[bool]:
        """Is the measured fp rate consistent with the mean prediction?
        Same band as ``fleet.monitor.fp_within_band``."""
        measured = self.measured_fp_rate()
        if measured is None:
            return None
        from repro.fleet.monitor import fp_within_band
        return fp_within_band(measured, self.mean_predicted_fp(),
                              slack=slack, abs_tol=abs_tol)

    # ---- replay ----
    def replay(self, registry, local) -> ReplayReport:
        """Re-verify recorded verdicts against a LIVE registry.

        Re-runs the registry's own ``classify_all`` once and compares
        each record whose (local_crc, peer_crc) still matches current
        state — verdict string and fp float must be bit-identical.
        Records whose row has since changed count as ``stale``.
        """
        from repro.core.wire import cells_crc
        from repro.fleet.registry import STATUS_NAMES

        rep = ReplayReport()
        todo = self.verdicts()
        if not todo:
            return rep
        local_crc = cells_crc(np.asarray(local.logical_cells()))
        view = registry.classify_all(local)
        mat = np.asarray(registry._materialized())
        for rec in todo:
            if rec.peer_id not in registry:
                rep.skipped += 1
                continue
            slot = registry.slot_of(rec.peer_id)
            peer_crc = cells_crc(mat[slot])
            if rec.local_crc != local_crc or rec.peer_crc != peer_crc:
                rep.stale += 1
                continue
            rep.checked += 1
            got_verdict = STATUS_NAMES[int(view.status[slot])]
            got_fp = float(view.fp[slot])
            if got_verdict == rec.verdict and got_fp == rec.fp:
                rep.matched += 1
            else:
                rep.mismatches.append({
                    "seq": rec.seq, "peer_id": rec.peer_id,
                    "recorded": (rec.verdict, rec.fp),
                    "replayed": (got_verdict, got_fp)})
        return rep

    def replay_frames(self, policy=None) -> ReplayReport:
        """Re-verify from the stored wire frames alone.

        Frames are decoded, re-admitted into a scratch registry built
        from ``policy`` (grouped per local clock so each group costs one
        ``classify_all``), and compared bit-for-bit — the original
        registry may have been pushed-back over, discarded, or live in
        another process.  Requires ``store_frames=True`` at record time.
        """
        from repro.core import clock as bc
        from repro.core.wire import decode_clock
        from repro.fleet.registry import ClockRegistry, STATUS_NAMES
        import jax.numpy as jnp

        rep = ReplayReport()
        groups: dict[bytes, list[AuditRecord]] = {}
        for rec in self.verdicts():
            if rec.local_frame is None or rec.peer_frame is None:
                rep.skipped += 1
                continue
            groups.setdefault(rec.local_frame, []).append(rec)
        for local_frame, recs in groups.items():
            snap = decode_clock(local_frame)
            local = bc.from_wire(snap)
            m, k = int(np.asarray(snap["cells"]).shape[0]), int(snap["k"])
            reg = ClockRegistry(capacity=max(8, len(recs)), m=m, k=k,
                                policy=policy)
            clocks = {}
            for i, rec in enumerate(recs):
                psnap = decode_clock(rec.peer_frame)
                clocks[f"replay/{i}"] = bc.from_wire(psnap)
            reg.admit_many(clocks)
            view = reg.classify_all(local)
            for i, rec in enumerate(recs):
                rep.checked += 1
                slot = reg.slot_of(f"replay/{i}")
                got_verdict = STATUS_NAMES[int(view.status[slot])]
                got_fp = float(view.fp[slot])
                if got_verdict == rec.verdict and got_fp == rec.fp:
                    rep.matched += 1
                else:
                    rep.mismatches.append({
                        "seq": rec.seq, "peer_id": rec.peer_id,
                        "recorded": (rec.verdict, rec.fp),
                        "replayed": (got_verdict, got_fp)})
        return rep

    @classmethod
    def load(cls, path) -> "AuditTrail":
        """Read a JSONL trail back (amend lines applied in order)."""
        trail = cls()
        by_seq = {}
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                d = json.loads(line)
                if "amend" in d:
                    rec = by_seq.get(d["amend"])
                    if rec is not None:
                        rec.truth_ok = d.get("truth_ok")
                    continue
                rec = AuditRecord.from_dict(d)
                by_seq[rec.seq] = rec
                trail.records.append(rec)
        trail._seq = max(by_seq) + 1 if by_seq else 0
        trail.store_frames = any(
            r.local_frame is not None for r in trail.records)
        return trail


class NullAudit:
    """Auditing disabled: records vanish, replay reports empty."""

    __slots__ = ()
    store_frames = False

    def __bool__(self) -> bool:
        return False

    def __len__(self) -> int:
        return 0

    def record(self, kind: str, peer_id, **kw) -> None:
        return None

    def annotate_truth(self, rec, ok) -> None:
        pass

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass


NULL_AUDIT = NullAudit()
