"""Logical-axis sharding: one rule table maps tensor dims -> mesh axes.

MaxText-style: every parameter dim carries a logical axis name (see
``models/params.py``); activations are constrained at block boundaries via
``shard(x, (names...))``.  Rules resolve a logical name to a mesh axis (or
a tuple of axes), with automatic fallback to replication when the dim is
not divisible by the mesh-axis extent — so every (arch x shape x mesh)
cell compiles, and suboptimal fallbacks show up in the roofline instead of
as compile failures.

The active (mesh, rules) pair is installed with ``use_mesh_rules`` —
model code stays mesh-agnostic and smoke tests run unsharded.
"""
from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "DEFAULT_RULES",
    "FLEET_AXIS",
    "make_rules",
    "logical_to_pspec",
    "param_pspecs",
    "shard",
    "slab_shardings",
    "use_mesh_rules",
    "current_mesh",
]

# mesh axis name the fleet registry shards its peer slab over; kept out
# of DEFAULT_RULES because the slab is placed explicitly (NamedSharding
# on the arrays + shard_map'ed kernels), not via logical-axis constraint
FLEET_AXIS = "fleet"


def slab_shardings(mesh: "Mesh", axis: str = FLEET_AXIS):
    """(rows, vec) NamedShardings for a registry slab: the ``[N, m]``
    cell slab row-sharded over ``axis`` and its ``[N]`` per-slot
    vectors (base / sums / alive) sharded to match."""
    return (NamedSharding(mesh, P(axis, None)), NamedSharding(mesh, P(axis)))

# logical axis -> mesh axis (str), tuple of axes, or None (replicate).
# "*_v" names are small vectors (biases/scales): always replicated.
DEFAULT_RULES = {
    # weights
    "vocab": "model",
    "embed": "data",          # FSDP dim
    "q_heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    # experts take the model axis when the count divides it (deepseek);
    # otherwise the per-expert hidden dim picks it up (grok: 8 experts on a
    # 16-wide axis -> expert weights shard over d_ff instead of replicating)
    "expert_mlp": "model",
    "experts": "model",
    "experts_r": None,
    "lora": None,
    "ssm_inner": "model",
    "layers": None,
    "seq_tab": None,
    "conv_v": None,
    # activations
    "act_batch": ("pod", "data"),
    "act_seq": None,           # flips to "model" under sequence parallelism
    "act_embed": None,
    "act_heads": "model",
    "act_kv": "model",
    "act_mlp": "model",
    "act_vocab": "model",
    "act_experts": "model",
    "act_expert_cap": None,
    "act_state": None,
    # decode KV caches: shard the cache SEQ dim over model (kv-head counts
    # rarely divide 16); decode attention contracts over it -> SPMD emits
    # partial softmax + reduce instead of gathering the cache
    "act_seq_cache": "model",
    "act_kv_cache": None,
    "act_ssm_heads": "model",
}


def make_rules(**overrides) -> dict:
    r = dict(DEFAULT_RULES)
    r.update(overrides)
    return r


class _Ctx:
    def __init__(self, mesh: Optional[Mesh], rules: dict):
        self.mesh = mesh
        self.rules = rules


_ACTIVE: contextvars.ContextVar[Optional[_Ctx]] = contextvars.ContextVar(
    "shard_ctx", default=None
)


@contextlib.contextmanager
def use_mesh_rules(mesh: Optional[Mesh], rules: Optional[dict] = None):
    tok = _ACTIVE.set(_Ctx(mesh, rules or DEFAULT_RULES))
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _ACTIVE.reset(tok)


def current_mesh() -> Optional[Mesh]:
    ctx = _ACTIVE.get()
    return ctx.mesh if ctx else None


def _axis_extent(mesh: Mesh, spec_entry) -> int:
    if spec_entry is None:
        return 1
    if isinstance(spec_entry, tuple):
        return math.prod(mesh.shape.get(a, 1) for a in spec_entry)
    return mesh.shape.get(spec_entry, 1)


def _resolve_entry(mesh: Mesh, rules: dict, name: Optional[str], dim: int):
    """Rule lookup + divisibility fallback (replicate if it doesn't divide)."""
    if name is None:
        return None
    entry = rules.get(name)
    if entry is None:
        return None
    if isinstance(entry, tuple):
        # drop axes missing from this mesh (e.g. "pod" on single-pod)
        entry = tuple(a for a in entry if a in mesh.shape)
        if not entry:
            return None
        ext = _axis_extent(mesh, entry)
        if dim % ext != 0:
            # try progressively shorter prefixes
            while entry and dim % _axis_extent(mesh, entry) != 0:
                entry = entry[:-1]
            return entry or None
        return entry
    if entry not in mesh.shape:
        return None
    if dim % mesh.shape[entry] != 0:
        return None
    return entry


def logical_to_pspec(mesh: Mesh, rules: dict, axes: tuple, shape: tuple) -> P:
    """Logical axes + concrete shape -> PartitionSpec (with fallbacks).

    Guarantees no mesh axis is used twice in one spec (XLA requirement):
    first-come wins, later dims fall back to replication.
    """
    used: set = set()
    entries = []
    for name, dim in zip(axes, shape):
        e = _resolve_entry(mesh, rules, name if name and not name.endswith("_v") else None, dim)
        if e is None:
            entries.append(None)
            continue
        flat = e if isinstance(e, tuple) else (e,)
        if any(a in used for a in flat):
            entries.append(None)
            continue
        used.update(flat)
        entries.append(e)
    return P(*entries)


def param_pspecs(mesh: Mesh, rules: dict, table: dict) -> dict:
    """param_table -> {path: NamedSharding}."""
    return {
        path: NamedSharding(mesh, logical_to_pspec(mesh, rules, info.axes, info.shape))
        for path, info in table.items()
    }


def shard(x: jax.Array, axes: tuple):
    """Activation sharding constraint by logical names; no-op without ctx."""
    ctx = _ACTIVE.get()
    if ctx is None or ctx.mesh is None:
        return x
    spec = logical_to_pspec(ctx.mesh, ctx.rules, axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
