"""Timestamp history window (paper §3).

"The node whose bloom filter has larger values, can go through its history
of timestamps, pick the timestamp with the smallest difference to that of
the other node's timestamp, and verify with high confidence the order."

A ``History`` is a fixed-capacity ring of past clocks (a jnp array stack),
so it jits cleanly and its memory is bounded — this is the paper's "moving
window in which the partial order of events can be inferred with high
confidence".
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import clock as bc

__all__ = ["History", "init", "push", "best_predecessor_fp"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class History:
    """cells: int32[W, m] logical cells of the last W timestamps.
    sums:  float32[W] their increment counts.
    count: int32 number of valid entries (<= W).
    """

    cells: jax.Array
    sums: jax.Array
    count: jax.Array
    k: int = 4

    def tree_flatten(self):
        return (self.cells, self.sums, self.count), self.k

    @classmethod
    def tree_unflatten(cls, k, leaves):
        return cls(*leaves, k=k)

    @property
    def window(self) -> int:
        return self.cells.shape[0]

    @property
    def m(self) -> int:
        return self.cells.shape[-1]


def init(window: int, m: int, k: int = 4) -> History:
    return History(
        cells=jnp.zeros((window, m), jnp.int32),
        sums=jnp.zeros((window,), jnp.float32),
        count=jnp.zeros((), jnp.int32),
        k=k,
    )


def push(h: History, c: bc.BloomClock) -> History:
    """Append a timestamp, evicting the oldest when full (ring shift)."""
    cells = jnp.roll(h.cells, -1, axis=0).at[-1].set(c.logical_cells())
    sums = jnp.roll(h.sums, -1).at[-1].set(bc.clock_sum(c))
    return History(cells=cells, sums=sums, count=jnp.minimum(h.count + 1, h.window), k=h.k)


@jax.jit
def best_predecessor_fp(h: History, other: bc.BloomClock):
    """§3 refinement: over all stored timestamps t that dominate ``other``,
    return the smallest Eq.-3 fp rate (i.e. compare against the *closest*
    dominating timestamp instead of the newest one).

    Returns (fp, index); fp = +inf when no stored timestamp dominates.
    """
    lo = other.logical_cells()
    so = bc.clock_sum(other)
    dominates = jnp.all(h.cells >= lo[None, :], axis=-1)  # [W]
    valid = jnp.arange(h.window) >= (h.window - h.count)
    ok = jnp.logical_and(dominates, valid)
    fps = bc.fp_rate(so, h.sums, h.m)  # fp of "other -> stored_t" per entry
    fps = jnp.where(ok, fps, jnp.inf)
    idx = jnp.argmin(fps)
    return fps[idx], idx
