"""The Bloom Clock (Ramabaja, 2019) as a composable JAX module.

A clock is a counting bloom filter of ``m`` int32 cells plus a scalar
``base`` implementing the paper's §4 compression: the logical cell value is
``base + cells[i]``.  All operations are pure functions over pytrees and are
jit/vmap/pjit compatible; batched clocks simply carry leading batch dims.

Paper-op mapping:
  tick        §3 step 2  (hash event k times, increment cells)
  merge       §3 step 3  (element-wise max)
  ordering    §3          (cell-wise dominance; exact concurrency detection)
  fp_rate     §3 Eq. 3    ((1-(1-1/m)^{ΣB})^{ΣA}), log-stable
  compress    §4          ((c)[residuals] base-offset form)

**Bounded-counter semantics** (practically-self-stabilizing vector
clocks): int32 counters live on the mod-2^32 circle, so every compare /
max / min below is derived from the *wrap-subtraction* ``a - b`` — in
two's complement that difference is the correct signed distance
whenever the true gap is under 2^31, even when one side has wrapped
past ``INT32_MAX`` and the other has not.  For clocks in the sane range
(everything far from the wrap point) the derived predicates are
bit-identical to the direct ``<=`` / ``maximum`` forms, which is what
keeps every kernel bit-identity pin intact; near the wrap point they
keep returning the right answer where the direct forms silently invert.
The same derivation runs inside the Pallas kernels
(``repro.kernels.template``).

The hot paths (tick / fused merge+compare) have Pallas TPU kernels in
``repro.kernels``; this module is the reference implementation.  For
comparisons, the public surface is ``repro.causal`` (``causal.compare``
for typed pairwise results, ``CausalEngine`` for the bulk verbs); the
old ``compare`` name remains importable as a ``DeprecationWarning``
shim over ``ordering``, the in-module reference the internal helpers
(``happened_before``, ``comparability_matrix``) build on.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashing import bloom_indices

__all__ = [
    "BloomClock",
    "zeros",
    "tick",
    "merge",
    "ordering",
    "compare",
    "Ordering",
    "fp_rate",
    "compress",
    "decompress",
    "clock_sum",
    "residual_span",
    "to_wire",
    "from_wire",
]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BloomClock:
    """Counting-bloom-filter logical clock.

    cells: int32[..., m] residual counters.
    base:  int32[...]    shared offset (paper §4 compression); logical
                         value of cell i is base + cells[i].
    k:     static number of hash probes per event.
    """

    cells: jax.Array
    base: jax.Array
    k: int = 4

    # -- pytree protocol (k is static) --
    def tree_flatten(self):
        return (self.cells, self.base), self.k

    @classmethod
    def tree_unflatten(cls, k, leaves):
        return cls(leaves[0], leaves[1], k)

    @property
    def m(self) -> int:
        return self.cells.shape[-1]

    @property
    def batch_shape(self):
        return self.cells.shape[:-1]

    def logical_cells(self) -> jax.Array:
        return self.cells + self.base[..., None].astype(self.cells.dtype)

    def sum(self) -> jax.Array:
        return clock_sum(self)


def zeros(m: int, k: int = 4, batch_shape: tuple = (), dtype=jnp.int32) -> BloomClock:
    return BloomClock(
        cells=jnp.zeros(batch_shape + (m,), dtype),
        base=jnp.zeros(batch_shape, dtype),
        k=k,
    )


def _as_mod_u32(x: jax.Array) -> jax.Array:
    """Reinterpret int32 counters as their position on the mod-2^32
    circle (uint32).  A wrapped counter (negative two's-complement bits)
    reads back as the large value it actually represents; sane values
    are unchanged."""
    if x.dtype == jnp.int32:
        return jax.lax.bitcast_convert_type(x, jnp.uint32)
    return x


def clock_sum(c: BloomClock) -> jax.Array:
    """Total number of increments recorded (Σ cells + m·base), as float32.

    float32 because sums reach k × events and feed Eq. 3 exponents.
    int32 cells/bases are read through their mod-2^32 positions, so a
    near-wrap clock contributes its true (huge, Eq.3-saturating) sum
    instead of an int32-overflowed garbage value; in the sane range the
    result is bit-identical to a plain int32 sum.
    """
    s = jnp.sum(_as_mod_u32(c.cells), axis=-1).astype(jnp.float32)
    return s + _as_mod_u32(c.base).astype(jnp.float32) * c.m


def tick(c: BloomClock, event_hi, event_lo) -> BloomClock:
    """Record event(s): increment the k hashed cells per event.

    event_hi/lo: uint32 scalars or arrays whose shape is either
    ``c.batch_shape`` (one event per clock) or ``c.batch_shape + (E,)``
    (E events per clock).
    """
    event_hi = jnp.asarray(event_hi, jnp.uint32)
    event_lo = jnp.asarray(event_lo, jnp.uint32)
    idx = bloom_indices(event_hi, event_lo, c.k, c.m)  # [..., (E,) , k]
    # flatten any trailing event axes into one probe axis
    probe = idx.reshape(c.batch_shape + (-1,))
    one_hot = jax.nn.one_hot(probe, c.m, dtype=c.cells.dtype)  # [..., P, m]
    inc = jnp.sum(one_hot, axis=-2)
    return dataclasses.replace(c, cells=c.cells + inc)


def merge(a: BloomClock, b: BloomClock) -> BloomClock:
    """§3 step 3: element-wise max of logical cells.

    Keeps the max base and re-normalizes residuals so compression survives
    merging.  The max is derived from the wrap-subtraction
    ``a + relu(b - a)`` so a near-wrap clock merges correctly
    (bounded-counter semantics); in the sane range this is bit-identical
    to ``jnp.maximum``.
    """
    la = a.logical_cells()
    lb = b.logical_cells()
    mx = la + jnp.maximum(lb - la, 0)
    base = jnp.where(a.base - b.base >= 0, a.base, b.base)
    return BloomClock(cells=mx - base[..., None].astype(mx.dtype), base=base, k=a.k)


@dataclasses.dataclass(frozen=True)
class Ordering:
    """Result of comparing two clocks A, B.

    a_le_b / b_le_a: bool[...] cell-wise dominance each way.
    concurrent:      bool[...] neither dominates -> *exact* concurrency
                     (no false negatives, paper §3).
    equal:           bool[...] identical logical cells.
    fp_a_before_b:   float32[...] Eq. 3 false-positive rate of the claim
                     "A happened-before B" (valid where a_le_b).
    fp_b_before_a:   float32[...] symmetric.
    """

    a_le_b: jax.Array
    b_le_a: jax.Array
    concurrent: jax.Array
    equal: jax.Array
    fp_a_before_b: jax.Array
    fp_b_before_a: jax.Array


def fp_rate(sum_a, sum_b, m: int) -> jax.Array:
    """Paper Eq. 3: (1 - (1 - 1/m)^{ΣB})^{ΣA}, numerically stable.

    Valid under Eq. 4 (ΣB ≥ ΣA); callers pass sums either way and pick the
    branch via the dominance predicate.  Computed as
        exp(ΣA * log(-expm1(ΣB * log1p(-1/m))))
    so ΣB ~ 1e9 doesn't underflow pow.
    """
    sum_a = jnp.asarray(sum_a, jnp.float32)
    sum_b = jnp.asarray(sum_b, jnp.float32)
    log_q = jnp.log1p(-1.0 / m)          # log(1 - 1/m) < 0
    inner = -jnp.expm1(sum_b * log_q)    # 1 - (1-1/m)^ΣB  in (0, 1)
    inner = jnp.clip(inner, 1e-30, 1.0)
    return jnp.exp(sum_a * jnp.log(inner))


def ordering(a: BloomClock, b: BloomClock) -> Ordering:
    """Cell-wise partial-order comparison + Eq. 3 confidence, one pass.

    The algorithmic reference every kernel is validated against.  New
    code that wants accessor-style results should prefer
    ``repro.causal.compare`` (same math, typed ``Comparison`` pytree).
    """
    la = a.logical_cells()
    lb = b.logical_cells()
    # wrap-subtraction dominance (bounded-counter semantics): the signed
    # difference is exact whenever the true gap is < 2^31, so a clock
    # that wrapped past INT32_MAX still compares correctly; identical to
    # the direct <= in the sane range
    d = lb - la
    a_le_b = jnp.all(d >= 0, axis=-1)
    b_le_a = jnp.all(d <= 0, axis=-1)
    equal = jnp.logical_and(a_le_b, b_le_a)
    concurrent = jnp.logical_not(jnp.logical_or(a_le_b, b_le_a))
    sa = clock_sum(a)
    sb = clock_sum(b)
    return Ordering(
        a_le_b=a_le_b,
        b_le_a=b_le_a,
        concurrent=concurrent,
        equal=equal,
        fp_a_before_b=fp_rate(sa, sb, a.m),
        fp_b_before_a=fp_rate(sb, sa, a.m),
    )


def compare(a: BloomClock, b: BloomClock) -> Ordering:
    """DEPRECATED alias of ``ordering`` — use ``repro.causal.compare``
    (typed ``Comparison`` with accessors) or ``ordering`` directly."""
    warnings.warn(
        "repro.core.clock.compare is deprecated; use repro.causal.compare "
        "(typed Comparison results) or repro.core.clock.ordering",
        DeprecationWarning, stacklevel=2)
    return ordering(a, b)


def compress(c: BloomClock) -> BloomClock:
    """§4: lift min(cells) into the base so residuals stay small.

    [4,3,3,5,7,...] -> base+=3, cells=[1,0,0,2,4,...].  Happens naturally
    every ~m/k events; callers may apply it after every merge.

    The min is taken over wrap-differences from a reference cell so a
    window straddling the int32 wrap point (some cells wrapped negative,
    some not) still finds the true window floor; exact integer identity
    with the direct min in the sane range.
    """
    ref = c.cells[..., :1]
    mn = ref[..., 0] + jnp.min(c.cells - ref, axis=-1)
    return BloomClock(
        cells=c.cells - mn[..., None],
        base=c.base + mn.astype(c.base.dtype),
        k=c.k,
    )


def decompress(c: BloomClock) -> BloomClock:
    """Inverse of compress (materialize logical cells, zero base)."""
    return BloomClock(cells=c.logical_cells(), base=jnp.zeros_like(c.base), k=c.k)


def residual_span(c: BloomClock) -> jax.Array:
    """max - min of the residual cells: the §4 moving-window width.

    A clock whose span fits a byte ships / stores as u8 residuals plus
    one int32 base (see ``to_wire`` and ``repro.kernels.pack``).
    Wrap-safe: measured on differences from a reference cell, so a
    window straddling the int32 wrap point reports its true width.
    """
    d = c.cells - c.cells[..., :1]
    return jnp.max(d, axis=-1) - jnp.min(d, axis=-1)


def to_wire(c: BloomClock) -> dict:
    """Wire snapshot of one clock: §4 compression + u8 quantization.

    Applies ``compress`` then emits the residuals as uint8 whenever the
    window span fits a byte (the common case the paper argues for —
    ~4x smaller messages), falling back to int32 otherwise.  The dict is
    what gossip transports and checkpoint manifests persist.
    """
    cc = compress(c)
    cells = np.asarray(cc.cells)
    if cells.max(initial=0) <= 255:
        cells = cells.astype(np.uint8)
    return {"cells": cells, "base": int(cc.base), "k": cc.k}


def from_wire(snap) -> BloomClock:
    """Rebuild a clock from a ``to_wire`` dict (either cell dtype) or an
    encoded binary frame (``core.wire.encode_clock`` bytes, as shipped
    by the socket gossip transport).  Byte input is validated first —
    truncated / corrupted / unknown-version frames raise
    ``core.wire.WireFormatError`` instead of yielding a garbage clock.
    """
    if isinstance(snap, (bytes, bytearray, memoryview)):
        from repro.core import wire
        snap = wire.decode_clock(snap)
    return BloomClock(
        cells=jnp.asarray(snap["cells"], jnp.int32),
        base=jnp.asarray(int(snap["base"]), jnp.int32),
        k=int(snap["k"]),
    )


# ---------------------------------------------------------------------------
# convenience jitted entry points used across the runtime
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("threshold",))
def happened_before(a: BloomClock, b: BloomClock, threshold: float = 0.01):
    """True where "A -> B" holds with fp rate within ``threshold``.

    This is the decision rule the runtime uses (checkpoint lineage, async
    merge guards): dominance AND confidence — the same ``fp <= t`` gate
    as ``causal.Comparison.confident(t)`` and every registry/gossip
    admit path (an exact-boundary fp == t now passes, matching them).
    """
    o = ordering(a, b)
    return jnp.logical_and(o.a_le_b, o.fp_a_before_b <= threshold)


def comparability_matrix(clocks: BloomClock) -> dict[str, jax.Array]:
    """All-pairs comparison for a batch of clocks [n, m] -> n x n matrices.

    Used by the simulator and by fleet-level debugging dashboards.
    """
    n = clocks.cells.shape[0]
    ai = jax.tree.map(lambda x: x[:, None] if x.ndim == 1 else x[:, None, :], clocks)
    bi = jax.tree.map(lambda x: x[None, :] if x.ndim == 1 else x[None, :, :], clocks)
    ai = jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape[1:]), ai)
    bi = jax.tree.map(lambda x: jnp.broadcast_to(x, (n, n) + x.shape[2:]), bi)
    o = ordering(ai, bi)
    return {
        "a_le_b": o.a_le_b,
        "concurrent": o.concurrent,
        "fp": o.fp_a_before_b,
    }
