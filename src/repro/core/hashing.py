"""Integer hashing for bloom-clock event ids.

The paper treats hash functions as a black box producing k independent
indices per event.  We follow standard bloom-filter engineering practice:

- events are uint64 identifiers (callers hash arbitrary payloads down to
  64 bits however they like; `stable_event_id` is provided for tuples of
  ints / bytes),
- two independent 64-bit finalizers (splitmix64 and a murmur3-style
  variant) produce h1, h2,
- the k indices come from double hashing (Kirsch-Mitzenmacher 2006):
  idx_i = (h1 + i * h2) mod m, which is provably as good as k independent
  hashes for bloom filters.

Everything is pure jnp on uint32 pairs so it runs identically on
TPU (which has no native 64-bit multiply in the VPU fast path) and CPU.
We represent a 64-bit value as (hi, lo) uint32 lanes.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = [
    "splitmix64",
    "murmur64",
    "bloom_indices",
    "stable_event_id",
]

_MASK32 = np.uint32(0xFFFFFFFF)


def _mul64(a_hi, a_lo, b_hi, b_lo):
    """64x64 -> low 64 bits of product, on uint32 lanes."""
    a_lo = a_lo.astype(jnp.uint32)
    b_lo = b_lo.astype(jnp.uint32)
    # 32x32 -> 64 via 16-bit split to stay in uint32 arithmetic.
    a0 = a_lo & 0xFFFF
    a1 = a_lo >> 16
    b0 = b_lo & 0xFFFF
    b1 = b_lo >> 16
    ll = a0 * b0
    lh = a0 * b1
    hl = a1 * b0
    hh = a1 * b1
    mid = (ll >> 16) + (lh & 0xFFFF) + (hl & 0xFFFF)
    lo = (ll & 0xFFFF) | ((mid & 0xFFFF) << 16)
    carry = mid >> 16
    hi_from_lo = hh + (lh >> 16) + (hl >> 16) + carry
    hi = (a_hi * b_lo + a_lo * b_hi + hi_from_lo).astype(jnp.uint32)
    return hi, lo


def _add64(a_hi, a_lo, b_hi, b_lo):
    lo = (a_lo + b_lo).astype(jnp.uint32)
    carry = (lo < a_lo).astype(jnp.uint32)
    hi = (a_hi + b_hi + carry).astype(jnp.uint32)
    return hi, lo


def _xor64(a_hi, a_lo, b_hi, b_lo):
    return a_hi ^ b_hi, a_lo ^ b_lo


def _shr64(hi, lo, n: int):
    if n == 0:
        return hi, lo
    if n >= 32:
        return jnp.zeros_like(hi), (hi >> (n - 32)).astype(jnp.uint32)
    lo2 = ((lo >> n) | (hi << (32 - n))).astype(jnp.uint32)
    hi2 = (hi >> n).astype(jnp.uint32)
    return hi2, lo2


def _const64(v: int):
    return np.uint32((v >> 32) & 0xFFFFFFFF), np.uint32(v & 0xFFFFFFFF)


def splitmix64(hi, lo):
    """splitmix64 finalizer on (hi, lo) uint32 lanes."""
    c1 = _const64(0x9E3779B97F4A7C15)
    c2 = _const64(0xBF58476D1CE4E5B9)
    c3 = _const64(0x94D049BB133111EB)
    hi, lo = _add64(hi, lo, *c1)
    x = _xor64(hi, lo, *_shr64(hi, lo, 30))
    hi, lo = _mul64(*x, *c2)
    x = _xor64(hi, lo, *_shr64(hi, lo, 27))
    hi, lo = _mul64(*x, *c3)
    hi, lo = _xor64(hi, lo, *_shr64(hi, lo, 31))
    return hi, lo


def murmur64(hi, lo):
    """murmur3 fmix64 finalizer on (hi, lo) uint32 lanes."""
    c1 = _const64(0xFF51AFD7ED558CCD)
    c2 = _const64(0xC4CEB9FE1A85EC53)
    hi, lo = _xor64(hi, lo, *_shr64(hi, lo, 33))
    hi, lo = _mul64(hi, lo, *c1)
    hi, lo = _xor64(hi, lo, *_shr64(hi, lo, 33))
    hi, lo = _mul64(hi, lo, *c2)
    hi, lo = _xor64(hi, lo, *_shr64(hi, lo, 33))
    return hi, lo


def bloom_indices(event_hi, event_lo, k: int, m: int):
    """k bloom-filter indices in [0, m) for each event.

    event_hi/event_lo: uint32 arrays of identical shape S (64-bit event ids
    split into lanes).  Returns uint32 array of shape S + (k,).

    Double hashing: idx_i = (h1 + i*h2) mod m computed in 32-bit space.
    m is assumed << 2^32; we fold the 64-bit hashes to 32 bits first
    (xor-fold) which preserves uniformity.
    """
    event_hi = jnp.asarray(event_hi, jnp.uint32)
    event_lo = jnp.asarray(event_lo, jnp.uint32)
    h1_hi, h1_lo = splitmix64(event_hi, event_lo)
    h2_hi, h2_lo = murmur64(event_hi, event_lo)
    h1 = (h1_hi ^ h1_lo).astype(jnp.uint32)
    h2 = (h2_hi ^ h2_lo).astype(jnp.uint32)
    # force h2 odd so the stride is coprime with any power-of-two m and
    # never collapses the k probes onto one index
    h2 = h2 | jnp.uint32(1)
    i = jnp.arange(k, dtype=jnp.uint32)
    idx = h1[..., None] + i * h2[..., None]
    return (idx % jnp.uint32(m)).astype(jnp.uint32)


def stable_event_id(*parts) -> tuple[int, int]:
    """Deterministically mix python ints / bytes into a 64-bit event id.

    Returns (hi, lo) uint32 python ints.  Host-side helper (not traced).
    """
    acc = 0xCBF29CE484222325  # FNV offset basis
    for p in parts:
        if isinstance(p, bytes):
            data = p
        elif isinstance(p, str):
            data = p.encode()
        else:
            data = int(p).to_bytes(8, "little", signed=False)
        for b in data:
            acc ^= b
            acc = (acc * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF  # FNV prime
    return (acc >> 32) & 0xFFFFFFFF, acc & 0xFFFFFFFF
