"""Vector clock baseline (paper §1.2) — the structure the bloom clock replaces.

Implemented with the same functional surface as ``repro.core.clock`` so the
simulator and benchmarks can swap the two and measure the §4 trade-offs
(space, comparability, exactness).  A vector clock is exact: comparisons
have no false positives, at O(N) space per message.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["VectorClock", "zeros", "tick", "merge", "compare"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class VectorClock:
    """vec: int32[..., n_nodes]."""

    vec: jax.Array

    def tree_flatten(self):
        return (self.vec,), None

    @classmethod
    def tree_unflatten(cls, _, leaves):
        return cls(leaves[0])

    @property
    def n(self) -> int:
        return self.vec.shape[-1]

    def sum(self) -> jax.Array:
        return jnp.sum(self.vec, axis=-1)


def zeros(n_nodes: int, batch_shape: tuple = (), dtype=jnp.int32) -> VectorClock:
    return VectorClock(jnp.zeros(batch_shape + (n_nodes,), dtype))


def tick(c: VectorClock, node_id) -> VectorClock:
    """§1.2 step 2: increment own slot."""
    one_hot = jax.nn.one_hot(node_id, c.n, dtype=c.vec.dtype)
    return VectorClock(c.vec + one_hot)


def merge(a: VectorClock, b: VectorClock) -> VectorClock:
    """§1.2 step 3 (without the local tick): element-wise max."""
    return VectorClock(jnp.maximum(a.vec, b.vec))


@dataclasses.dataclass(frozen=True)
class VCOrdering:
    a_le_b: jax.Array
    b_le_a: jax.Array
    concurrent: jax.Array
    equal: jax.Array


def compare(a: VectorClock, b: VectorClock) -> VCOrdering:
    a_le_b = jnp.all(a.vec <= b.vec, axis=-1)
    b_le_a = jnp.all(b.vec <= a.vec, axis=-1)
    return VCOrdering(
        a_le_b=a_le_b,
        b_le_a=b_le_a,
        concurrent=jnp.logical_not(jnp.logical_or(a_le_b, b_le_a)),
        equal=jnp.logical_and(a_le_b, b_le_a),
    )


def wire_bytes(n_nodes: int, counter_bytes: int = 4) -> int:
    """Message size of a vector clock (§2: O(N))."""
    return n_nodes * counter_bytes
