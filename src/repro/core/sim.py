"""Event-driven N-node protocol simulator (paper §3 Fig. 6, scaled up).

Generates a random distributed execution (internal events, broadcasts with
per-link drops and delays), replays it under BOTH clocks:

- vector clock  -> exact ground-truth causality (Fidge/Mattern),
- bloom clock   -> the paper's probabilistic timestamps,

then scores the bloom clock against ground truth:

- incomparability is detected exactly (no false negatives — §3),
- measured false-positive rate of "A happened-before B" claims vs. the
  Eq. 3 prediction,
- wire bytes per message for both clocks (§2/§4 space story).

The replay is sequential by nature, so it runs on host numpy; the bloom
index hashing is the same jnp code the runtime uses (computed vectorized
up-front for every event id).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core import clock as bc
from repro.core.hashing import bloom_indices

__all__ = ["SimConfig", "SimResult", "run_sim"]


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_nodes: int = 8
    n_events: int = 400          # total events across all nodes
    m: int = 64                  # bloom cells
    k: int = 3                   # hash probes
    p_broadcast: float = 0.5     # P(event is a broadcast) vs internal
    p_drop: float = 0.2          # per-recipient message drop
    max_delay: int = 3           # message delay in "event slots"
    seed: int = 0
    sample_pairs: int = 4000     # event pairs scored for fp measurement


@dataclasses.dataclass
class SimResult:
    false_negatives: int          # truly-ordered pairs bloom called concurrent (must be 0)
    true_concurrent: int          # pairs both call concurrent
    true_positives: int           # ordered pairs bloom confirms (right direction)
    false_positives: int          # bloom claims order, truth says concurrent/reverse
    measured_fp_rate: float
    mean_predicted_fp: float      # mean Eq. 3 value over claimed-order pairs
    bloom_wire_bytes: int
    vector_wire_bytes: int
    n_pairs_scored: int

    def summary(self) -> str:
        return (
            f"fn={self.false_negatives} tp={self.true_positives} "
            f"fp={self.false_positives} conc={self.true_concurrent} "
            f"measured_fp={self.measured_fp_rate:.4f} "
            f"predicted_fp={self.mean_predicted_fp:.4f} "
            f"wire bloom={self.bloom_wire_bytes}B vector={self.vector_wire_bytes}B"
        )


def run_sim(cfg: SimConfig) -> SimResult:
    rng = np.random.default_rng(cfg.seed)
    n, m, k = cfg.n_nodes, cfg.m, cfg.k

    # ---- precompute bloom indices for every event id with the jnp hasher ----
    ev_ids = np.arange(cfg.n_events, dtype=np.uint64)
    idx = np.asarray(
        bloom_indices(
            jnp.asarray((ev_ids >> np.uint64(32)).astype(np.uint32)),
            jnp.asarray((ev_ids & np.uint64(0xFFFFFFFF)).astype(np.uint32)),
            k,
            m,
        )
    )  # [n_events, k]

    # ---- replay ----
    bloom = np.zeros((n, m), np.int64)
    vec = np.zeros((n, n), np.int64)
    # in-flight messages: (deliver_slot, dst, bloom_snapshot, vec_snapshot)
    inflight: list[tuple[int, int, np.ndarray, np.ndarray]] = []

    # per-event records for scoring
    ev_bloom = np.zeros((cfg.n_events, m), np.int64)
    ev_vec = np.zeros((cfg.n_events, n), np.int64)

    for t in range(cfg.n_events):
        # deliver due messages first (receive = merge, §3 step 3)
        due = [msg for msg in inflight if msg[0] <= t]
        inflight = [msg for msg in inflight if msg[0] > t]
        for _, dst, bsnap, vsnap in due:
            np.maximum(bloom[dst], bsnap, out=bloom[dst])
            np.maximum(vec[dst], vsnap, out=vec[dst])

        src = rng.integers(n)
        # the event itself: bloom ticks k cells, vector ticks own slot
        np.add.at(bloom[src], idx[t], 1)
        vec[src, src] += 1
        ev_bloom[t] = bloom[src]
        ev_vec[t] = vec[src]

        if rng.random() < cfg.p_broadcast:
            for dst in range(n):
                if dst == src or rng.random() < cfg.p_drop:
                    continue
                delay = 1 + rng.integers(cfg.max_delay)
                inflight.append((t + delay, dst, bloom[src].copy(), vec[src].copy()))

    # ---- score sampled pairs ----
    pa = rng.integers(cfg.n_events, size=cfg.sample_pairs)
    pb = rng.integers(cfg.n_events, size=cfg.sample_pairs)
    keep = pa != pb
    pa, pb = pa[keep], pb[keep]

    A_b, B_b = ev_bloom[pa], ev_bloom[pb]
    A_v, B_v = ev_vec[pa], ev_vec[pb]

    truth_ab = np.all(A_v <= B_v, axis=1) & ~np.all(B_v <= A_v, axis=1)
    truth_ba = np.all(B_v <= A_v, axis=1) & ~np.all(A_v <= B_v, axis=1)
    truth_conc = ~truth_ab & ~truth_ba & ~np.all(A_v == B_v, axis=1)
    truth_eq = np.all(A_v == B_v, axis=1)

    claim_ab = np.all(A_b <= B_b, axis=1)
    claim_ba = np.all(B_b <= A_b, axis=1)
    claim_conc = ~claim_ab & ~claim_ba

    # no-false-negative check: if truth says A->B then cell-wise dominance
    # MUST hold (bloom can only over-claim, never under-claim)
    false_negatives = int(np.sum(truth_ab & ~claim_ab) + np.sum(truth_ba & ~claim_ba))

    # strict order claims (exclude equality) for fp accounting
    strict_ab = claim_ab & ~claim_ba
    strict_ba = claim_ba & ~claim_ab
    tp = int(np.sum(strict_ab & truth_ab) + np.sum(strict_ba & truth_ba))
    fp = int(np.sum(strict_ab & ~truth_ab & ~truth_eq) + np.sum(strict_ba & ~truth_ba & ~truth_eq))
    conc_agree = int(np.sum(claim_conc & truth_conc))

    sa = A_b.sum(1).astype(np.float64)
    sb = B_b.sum(1).astype(np.float64)
    pred_ab = np.asarray(bc.fp_rate(jnp.asarray(sa), jnp.asarray(sb), m))
    pred_ba = np.asarray(bc.fp_rate(jnp.asarray(sb), jnp.asarray(sa), m))
    preds = np.concatenate([pred_ab[strict_ab], pred_ba[strict_ba]])

    claims = int(np.sum(strict_ab) + np.sum(strict_ba))
    return SimResult(
        false_negatives=false_negatives,
        true_concurrent=conc_agree,
        true_positives=tp,
        false_positives=fp,
        measured_fp_rate=fp / max(claims, 1),
        mean_predicted_fp=float(preds.mean()) if preds.size else 0.0,
        bloom_wire_bytes=m * 4,
        vector_wire_bytes=n * 4,
        n_pairs_scored=int(pa.size),
    )


def monte_carlo_overlap(m: int, sum_a: int, sum_b: int, trials: int, seed: int = 0) -> float:
    """Empirical probability that a random clock with ``sum_b`` increments
    cell-wise dominates an independent random clock with ``sum_a`` increments
    — the quantity Eq. 3 approximates.  Used by tests/benchmarks to validate
    the formula (including the paper's m=6, ΣB=10, ΣA=7 -> 0.29 example).
    """
    rng = np.random.default_rng(seed)
    a_cells = rng.multinomial(sum_a, np.full(m, 1.0 / m), size=trials)
    b_cells = rng.multinomial(sum_b, np.full(m, 1.0 / m), size=trials)
    return float(np.mean(np.all(a_cells <= b_cells, axis=1)))
