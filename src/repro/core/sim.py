"""Event-driven N-node protocol simulator (paper §3 Fig. 6, scaled up).

Generates a random distributed execution (internal events, broadcasts with
per-link drops and delays), replays it under BOTH clocks:

- vector clock  -> exact ground-truth causality (Fidge/Mattern),
- bloom clock   -> the paper's probabilistic timestamps,

then scores the bloom clock against ground truth:

- incomparability is detected exactly (no false negatives — §3),
- measured false-positive rate of "A happened-before B" claims vs. the
  Eq. 3 prediction,
- wire bytes per message for both clocks (§2/§4 space story).

The replay is sequential by nature, so it runs on host numpy; the bloom
index hashing is the same jnp code the runtime uses (computed vectorized
up-front for every event id).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from repro.core import clock as bc
from repro.core.hashing import bloom_indices

__all__ = ["SimConfig", "SimResult", "run_sim",
           "GossipSimResult", "run_gossip_sim"]


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_nodes: int = 8
    n_events: int = 400          # total events across all nodes
    m: int = 64                  # bloom cells
    k: int = 3                   # hash probes
    p_broadcast: float = 0.5     # P(event is a broadcast) vs internal
    p_drop: float = 0.2          # per-recipient message drop
    max_delay: int = 3           # message delay in "event slots"
    seed: int = 0
    sample_pairs: int = 4000     # event pairs scored for fp measurement


@dataclasses.dataclass
class SimResult:
    false_negatives: int          # truly-ordered pairs bloom called concurrent (must be 0)
    true_concurrent: int          # pairs both call concurrent
    true_positives: int           # ordered pairs bloom confirms (right direction)
    false_positives: int          # bloom claims order, truth says concurrent/reverse
    measured_fp_rate: float
    mean_predicted_fp: float      # mean Eq. 3 value over claimed-order pairs
    bloom_wire_bytes: int
    vector_wire_bytes: int
    n_pairs_scored: int

    def summary(self) -> str:
        return (
            f"fn={self.false_negatives} tp={self.true_positives} "
            f"fp={self.false_positives} conc={self.true_concurrent} "
            f"measured_fp={self.measured_fp_rate:.4f} "
            f"predicted_fp={self.mean_predicted_fp:.4f} "
            f"wire bloom={self.bloom_wire_bytes}B vector={self.vector_wire_bytes}B"
        )


def _event_probe_indices(cfg: SimConfig) -> np.ndarray:
    """Bloom indices for every event id, via the same jnp hasher the
    runtime uses.  [n_events, k]."""
    ev_ids = np.arange(cfg.n_events, dtype=np.uint64)
    return np.asarray(
        bloom_indices(
            jnp.asarray((ev_ids >> np.uint64(32)).astype(np.uint32)),
            jnp.asarray((ev_ids & np.uint64(0xFFFFFFFF)).astype(np.uint32)),
            cfg.k,
            cfg.m,
        )
    )


def _replay(cfg: SimConfig, rng: np.random.Generator, idx: np.ndarray):
    """Shared protocol-event generator for both sims.

    Yields (t, src, bloom [n, m], vec [n, n]) after each event commits
    (deliveries applied, src ticked, broadcasts enqueued).  The yielded
    arrays are the LIVE state: consumers may mutate them between events
    (e.g. gossip merges) and the mutation takes effect from the next
    event on — snapshots already in flight are unaffected, like real
    messages on the wire.
    """
    n = cfg.n_nodes
    bloom = np.zeros((n, cfg.m), np.int64)
    vec = np.zeros((n, n), np.int64)
    # in-flight messages: (deliver_slot, dst, bloom_snapshot, vec_snapshot)
    inflight: list[tuple[int, int, np.ndarray, np.ndarray]] = []

    for t in range(cfg.n_events):
        # deliver due messages first (receive = merge, §3 step 3)
        due = [msg for msg in inflight if msg[0] <= t]
        inflight = [msg for msg in inflight if msg[0] > t]
        for _, dst, bsnap, vsnap in due:
            np.maximum(bloom[dst], bsnap, out=bloom[dst])
            np.maximum(vec[dst], vsnap, out=vec[dst])

        src = rng.integers(n)
        # the event itself: bloom ticks k cells, vector ticks own slot
        np.add.at(bloom[src], idx[t], 1)
        vec[src, src] += 1

        if rng.random() < cfg.p_broadcast:
            for dst in range(n):
                if dst == src or rng.random() < cfg.p_drop:
                    continue
                delay = 1 + rng.integers(cfg.max_delay)
                inflight.append((t + delay, dst, bloom[src].copy(), vec[src].copy()))

        yield t, src, bloom, vec


def run_sim(cfg: SimConfig) -> SimResult:
    rng = np.random.default_rng(cfg.seed)
    n, m = cfg.n_nodes, cfg.m
    idx = _event_probe_indices(cfg)

    # per-event records for scoring
    ev_bloom = np.zeros((cfg.n_events, m), np.int64)
    ev_vec = np.zeros((cfg.n_events, n), np.int64)
    for t, src, bloom, vec in _replay(cfg, rng, idx):
        ev_bloom[t] = bloom[src]
        ev_vec[t] = vec[src]

    # ---- score sampled pairs ----
    pa = rng.integers(cfg.n_events, size=cfg.sample_pairs)
    pb = rng.integers(cfg.n_events, size=cfg.sample_pairs)
    keep = pa != pb
    pa, pb = pa[keep], pb[keep]

    A_b, B_b = ev_bloom[pa], ev_bloom[pb]
    A_v, B_v = ev_vec[pa], ev_vec[pb]

    truth_ab = np.all(A_v <= B_v, axis=1) & ~np.all(B_v <= A_v, axis=1)
    truth_ba = np.all(B_v <= A_v, axis=1) & ~np.all(A_v <= B_v, axis=1)
    truth_conc = ~truth_ab & ~truth_ba & ~np.all(A_v == B_v, axis=1)
    truth_eq = np.all(A_v == B_v, axis=1)

    claim_ab = np.all(A_b <= B_b, axis=1)
    claim_ba = np.all(B_b <= A_b, axis=1)
    claim_conc = ~claim_ab & ~claim_ba

    # no-false-negative check: if truth says A->B then cell-wise dominance
    # MUST hold (bloom can only over-claim, never under-claim)
    false_negatives = int(np.sum(truth_ab & ~claim_ab) + np.sum(truth_ba & ~claim_ba))

    # strict order claims (exclude equality) for fp accounting
    strict_ab = claim_ab & ~claim_ba
    strict_ba = claim_ba & ~claim_ab
    tp = int(np.sum(strict_ab & truth_ab) + np.sum(strict_ba & truth_ba))
    fp = int(np.sum(strict_ab & ~truth_ab & ~truth_eq) + np.sum(strict_ba & ~truth_ba & ~truth_eq))
    conc_agree = int(np.sum(claim_conc & truth_conc))

    sa = A_b.sum(1).astype(np.float64)
    sb = B_b.sum(1).astype(np.float64)
    pred_ab = np.asarray(bc.fp_rate(jnp.asarray(sa), jnp.asarray(sb), m))
    pred_ba = np.asarray(bc.fp_rate(jnp.asarray(sb), jnp.asarray(sa), m))
    preds = np.concatenate([pred_ab[strict_ab], pred_ba[strict_ba]])

    claims = int(np.sum(strict_ab) + np.sum(strict_ba))
    return SimResult(
        false_negatives=false_negatives,
        true_concurrent=conc_agree,
        true_positives=tp,
        false_positives=fp,
        measured_fp_rate=fp / max(claims, 1),
        mean_predicted_fp=float(preds.mean()) if preds.size else 0.0,
        bloom_wire_bytes=m * 4,
        vector_wire_bytes=n * 4,
        n_pairs_scored=int(pa.size),
    )


@dataclasses.dataclass
class GossipSimResult:
    """Score of fleet gossip rounds against vector-clock ground truth."""

    rounds: int
    false_negatives: int      # truth-ordered peers the fleet called FORKED (must be 0)
    claims: int               # ordered/equal verdicts issued across rounds
    false_positives: int      # claims the vector clocks contradict
    measured_fp_rate: float
    mean_predicted_fp: float  # mean Eq. 3 fp over the issued claims
    within_eq3_band: bool     # measured consistent with predicted (monitor.fp_within_band)
    merges: int               # peers actually merged across rounds
    quarantines: int          # FORKED verdicts (all truth-concurrent when fn == 0)
    transport: str = "loopback"   # fabric the audited sessions ran over
    digest_bytes: int = 0     # MEASURED inbound digest bytes across rounds
    delta_bytes: int = 0      # MEASURED inbound delta-frame bytes
    pushback_bytes: int = 0   # MEASURED outbound push-back frame bytes
    converged: bool = True    # all nodes ended on identical rows (chaos)
    fault_events: int = 0     # faults the ChaosTransport injected
    rejected_frames: int = 0  # damaged frames the sessions rejected
    corrupted: int = 0        # registry rows flagged by integrity checks
    repaired: int = 0         # quarantined rows rewritten by gossip repair

    @property
    def wire_bytes(self) -> int:
        return self.digest_bytes + self.delta_bytes + self.pushback_bytes

    def summary(self) -> str:
        s = (
            f"rounds={self.rounds} fn={self.false_negatives} "
            f"claims={self.claims} fp={self.false_positives} "
            f"measured_fp={self.measured_fp_rate:.4f} "
            f"predicted_fp={self.mean_predicted_fp:.4f} "
            f"band_ok={self.within_eq3_band} merges={self.merges} "
            f"quarantines={self.quarantines} "
            f"wire={self.wire_bytes}B[{self.transport}]"
        )
        if self.fault_events:
            s += (f" faults={self.fault_events} "
                  f"rejected={self.rejected_frames} "
                  f"converged={self.converged}")
        if self.corrupted:
            s += f" corrupted={self.corrupted} repaired={self.repaired}"
        return s


def run_gossip_sim(cfg: SimConfig, n_rounds: int = 6, observer: int = 0,
                   gossip_cfg=None, registry_factory=None,
                   transport: str = "loopback", chaos=None,
                   corrupt_at=None, settle_rounds: int = 3) -> GossipSimResult:
    """Replay a random execution and interleave REAL fleet gossip rounds,
    scoring every verdict against the exact vector-clock truth.

    Between bursts of ordinary protocol events (same generator as
    ``run_sim``), the observer node runs one
    ``fleet.transport.anti_entropy_session`` over a ``ClockRegistry``
    holding its view of every other node's clock.  Each round's
    classification is audited:

    - a FORKED verdict for a truth-ordered peer is a false negative —
      the paper's §3 guarantee says this can NEVER happen;
    - ordered/equal verdicts the vector clocks contradict are false
      positives, whose measured rate must sit within the Eq. 3 band;
    - accepted merges are applied to BOTH clock families (receive rule),
      so causality stays aligned across rounds, including the
      anti-entropy push-back to accepted peers.

    ``registry_factory(capacity, m, k) -> ClockRegistry`` swaps the
    observer's registry construction — the sharded-fleet harness passes
    a mesh-backed factory so every audited verdict also exercises the
    shard_map kernel paths.

    ``transport`` picks the fabric the audited sessions run over:
    ``"loopback"`` (peer rows admitted into the slab directly),
    ``"mesh"`` (``MeshCollectiveTransport`` over the factory's sharded
    registry — digest ring on device), or ``"socket"``, which serves
    every peer's clock from a real threaded TCP ``ClockPeerServer`` and
    syncs the observer's registry purely through the digest/delta/§4
    wire-frame path.  All reported wire bytes are measured frame
    lengths.  The verdict audit is identical for every fabric.

    ``chaos`` (a ``fleet.chaos.ChaosConfig``) wraps the chosen fabric in
    a ``ChaosTransport``: drops, duplicates, reorders, damaged frames,
    mid-session crashes, and partitions are injected between the
    session and the fabric, then quiesced for ``settle_rounds`` extra
    event-free rounds so the run can assert **convergence** (every node
    on identical rows — ``GossipSimResult.converged``) and **zero false
    negatives** under fault load.  Under chaos a registry row may be a
    STALE snapshot of its peer (delayed / duplicated frames), so
    verdicts are scored against the vector-clock state each row
    actually carries — tracked per published-snapshot CRC through the
    audit trail's ``frame_ingest`` records — not against the peer's
    current clock; a stale-but-honest row is not a false negative.

    ``corrupt_at=(round, peer)`` flips bits in that peer's registry row
    before the given round (first round it exists) and turns on
    ``GossipConfig.verify_rows``: the session must detect the CRC
    mismatch, quarantine the row, and repair it via a forced delta
    re-pull (``GossipSimResult.corrupted`` / ``repaired``).
    """
    from repro.causal import CausalPolicy
    from repro.core import wire
    from repro.fleet import gossip as fg
    from repro.fleet import monitor as fm
    from repro.fleet import registry as fr
    from repro.fleet import transport as ft
    from repro.obs.observer import resolve

    if gossip_cfg is None:
        # accept-everything-comparable audit policy, threaded as a
        # CausalPolicy so the sim exercises the same config surface the
        # runtime uses.  Under chaos, forks are legitimate concurrency
        # (not replica divergence), so sessions merge them (§3 pure
        # receive rule) — quarantined forks could never reconverge.
        fg_cfg = fg.GossipConfig(policy=CausalPolicy(fp_threshold=1.0),
                                 straggler_gap=np.inf,
                                 merge_forked=chaos is not None)
    else:
        fg_cfg = gossip_cfg
    if chaos is not None and corrupt_at is not None:
        fg_cfg = dataclasses.replace(fg_cfg, verify_rows=True)
    rng = np.random.default_rng(cfg.seed)
    n, m, k = cfg.n_nodes, cfg.m, cfg.k
    idx = _event_probe_indices(cfg)

    if registry_factory is None:
        registry_factory = lambda cap, mm, kk: fr.ClockRegistry(
            capacity=cap, m=mm, k=kk)
    registry = registry_factory(max(8, n), m, k)
    peers = [p for p in range(n) if p != observer]
    # the instrumentation observer (as opposed to the observer NODE
    # above) rides the gossip config / policies; when present, every
    # audited verdict gets its vector-clock ground truth attached
    obs = resolve(fg_cfg.observer
                  or (fg_cfg.policy.observer
                      if fg_cfg.policy is not None else None)
                  or getattr(registry.policy, "observer", None))
    if chaos is not None and not obs.audit:
        # chaos scoring reads realized ingest order + row CRCs from the
        # trail, so an audit sink is mandatory under fault injection
        from repro.obs import AuditTrail, Observer
        obs = Observer(trace=obs.trace, metrics=obs.metrics,
                       audit=AuditTrail())
        fg_cfg = dataclasses.replace(fg_cfg, observer=obs)

    nodes: dict = {}
    servers: list = []
    if callable(transport):
        tp = transport(registry)
    elif transport == "loopback":
        tp = ft.LoopbackTransport(registry)
    elif transport == "mesh":
        tp = ft.MeshCollectiveTransport(registry)
    elif transport == "socket":
        for p in peers:
            node = ft.ClockNode(f"n{p}", m, k)
            server = ft.ClockPeerServer(node).start()
            nodes[p] = node
            servers.append(server)
        tp = ft.SocketTransport(
            {f"n{p}": s.address for p, s in zip(peers, servers)})
    else:
        raise ValueError(f"unknown transport {transport!r}")
    chaos_tp = None
    if chaos is not None:
        from repro.fleet import chaos as chaos_mod
        chaos_tp = chaos_mod.ChaosTransport(tp, chaos, observer=obs)
        tp = chaos_tp
    # registry key each sim peer is tracked under (socket peers arrive
    # from the wire under their node ids)
    pid_of = {p: (f"n{p}" if p in nodes else p) for p in peers}

    def as_clock(cells_row: np.ndarray) -> bc.BloomClock:
        return bc.BloomClock(
            cells=jnp.asarray(cells_row, jnp.int32),
            base=jnp.zeros((), jnp.int32), k=k)

    fn = fp_count = claims = merges = quarantines = 0
    digest_bytes = delta_bytes = pushback_bytes = 0
    rejected_frames = corrupted_rows = repaired_rows = 0
    predicted: list[float] = []
    round_marks = set(
        np.linspace(cfg.n_events // max(n_rounds, 1), cfg.n_events - 1,
                    n_rounds, dtype=int).tolist())
    rounds_done = 0
    converged = True
    corrupt_done = False
    # chaos ground truth: a registry row may be a STALE snapshot of its
    # peer, so each published bloom state's CRC maps to the vector-clock
    # state it was taken with, and ``reg_truth`` shadows what each
    # registry row causally contains (None = unknowable, never scored)
    vec_by_crc: dict[int, np.ndarray] = {}
    reg_truth: dict = {}
    by_spid = {str(pid_of[p]): p for p in peers}

    def chaos_round(bloom, vec):
        """One gossip round under fault injection, scored against the
        snapshot each registry row actually carries."""
        nonlocal fn, fp_count, claims, merges, quarantines
        nonlocal digest_bytes, delta_bytes, pushback_bytes
        nonlocal rejected_frames, corrupted_rows, repaired_rows
        nonlocal corrupt_done
        if tp.authoritative:
            registry.admit_many({p: as_clock(bloom[p]) for p in peers})
        else:
            for p in peers:
                nodes[p].set_cells(bloom[p])
                vec_by_crc[wire.cells_crc(bloom[p])] = vec[p].copy()
        if (corrupt_at is not None and not corrupt_done
                and rounds_done - 1 >= corrupt_at[0]):
            pid_c = pid_of[corrupt_at[1]]
            if pid_c in registry and registry.row_alive(pid_c):
                from repro.fleet import chaos as chaos_mod
                chaos_mod.corrupt_registry_row(registry, pid_c,
                                               seed=chaos.seed)
                corrupt_done = True
        local = as_clock(bloom[observer])
        audit_mark = len(obs.audit.records)
        merged, report = ft.anti_entropy_session(registry, local, tp, fg_cfg)
        digest_bytes += report.digest_bytes
        delta_bytes += report.delta_bytes
        pushback_bytes += report.pushback_bytes
        rejected_frames += len(report.rejected)
        corrupted_rows += len(report.corrupted)
        repaired_rows += len(report.repaired)

        # what does each registry row causally contain now?  Fresh or
        # repair pulls replace the row with the frame's snapshot; pulls
        # into a live row merge with it (§3 receive rule)
        if tp.authoritative:
            for p in peers:
                reg_truth[pid_of[p]] = vec[p].copy()
        else:
            for rec in obs.audit.records[audit_mark:]:
                if rec.kind != "frame_ingest":
                    continue
                p = by_spid.get(rec.peer_id)
                if p is None:
                    continue
                pid = pid_of[p]
                frame_vec = vec_by_crc.get(int(rec.peer_crc))
                if frame_vec is None:
                    reg_truth[pid] = None
                elif pid in report.repaired or pid not in reg_truth:
                    reg_truth[pid] = frame_vec.copy()
                elif reg_truth[pid] is not None:
                    reg_truth[pid] = np.maximum(reg_truth[pid], frame_vec)

        vo = vec[observer]
        truth_of: dict[str, bool] = {}
        for p in peers:
            pid = pid_of[p]
            if pid not in registry:
                continue           # digest dropped before first ingest
            s = registry.slot_of(pid)
            if not bool(report.view.alive[s]):
                continue           # quarantined this round: no verdict
            vp = reg_truth.get(pid)
            if vp is None:
                continue           # row snapshot unknowable: not scored
            code = int(report.view.status[s])
            p_le_o = bool(np.all(vp <= vo))
            o_le_p = bool(np.all(vo <= vp))
            if code == fr.FORKED:
                quarantines += 1
                truth_of[str(pid)] = not (p_le_o or o_le_p)
                if p_le_o or o_le_p:
                    fn += 1        # §3 violation: can never happen
                continue
            claims += 1
            predicted.append(float(report.view.fp[s]))
            truth_ok = {
                fr.ANCESTOR: p_le_o,
                fr.SAME: p_le_o and o_le_p,
                fr.DESCENDANT: o_le_p,
            }[code]
            truth_of[str(pid)] = truth_ok
            if not truth_ok:
                fp_count += 1

        for rec in obs.audit.records[audit_mark:]:
            if rec.kind == "verdict" and rec.peer_id in truth_of:
                obs.audit.annotate_truth(rec, truth_of[rec.peer_id])

        # commit: the union's causal content is the join of the
        # SNAPSHOTS its rows carried, not the peers' current clocks
        accept_ids = [p for p in peers if pid_of[p] in registry
                      and report.accepted[registry.slot_of(pid_of[p])]]
        merges += len(accept_ids)
        if accept_ids:
            merged_np = np.asarray(merged.logical_cells(), np.int64)
            union_vec = vo.copy()
            union_known = True
            for p in accept_ids:
                vp = reg_truth.get(pid_of[p])
                if vp is None:
                    union_known = False
                else:
                    np.maximum(union_vec, vp, out=union_vec)
            np.maximum(bloom[observer], merged_np, out=bloom[observer])
            if union_known:
                np.maximum(vec[observer], union_vec, out=vec[observer])
            if fg_cfg.push_back:
                for p in accept_ids:
                    if (not tp.authoritative
                            and pid_of[p] in report.unreachable):
                        continue   # chaos ate the push: peer never saw it
                    np.maximum(bloom[p], merged_np, out=bloom[p])
                    if union_known:
                        np.maximum(vec[p], union_vec, out=vec[p])
                    # the session broadcast the union into this row (on
                    # non-authoritative fabrics: only because the push
                    # was acknowledged)
                    reg_truth[pid_of[p]] = (union_vec.copy()
                                            if union_known else None)

    last_state = None
    try:
        for t, _src, bloom, vec in _replay(cfg, rng, idx):
            if t not in round_marks:
                continue

            # ---- one audited gossip round at the observer ----
            rounds_done += 1
            last_state = (bloom, vec)
            if chaos is not None:
                chaos_round(bloom, vec)
                continue
            if tp.authoritative:
                registry.admit_many({p: as_clock(bloom[p]) for p in peers})
            else:
                # peers publish their CURRENT clock on their own server;
                # the observer's registry syncs via digest/delta frames
                for p in peers:
                    nodes[p].set_cells(bloom[p])
            local = as_clock(bloom[observer])
            audit_mark = len(obs.audit.records) if obs.audit else 0
            merged, report = ft.anti_entropy_session(
                registry, local, tp, fg_cfg)
            digest_bytes += report.digest_bytes
            delta_bytes += report.delta_bytes
            pushback_bytes += report.pushback_bytes

            vo = vec[observer]
            truth_of: dict[str, bool] = {}
            for p in peers:
                s = registry.slot_of(pid_of[p])
                code = int(report.view.status[s])
                p_le_o = bool(np.all(vec[p] <= vo))
                o_le_p = bool(np.all(vo <= vec[p]))
                if code == fr.FORKED:
                    quarantines += 1
                    # a quarantine is "correct" iff truly concurrent
                    truth_of[str(pid_of[p])] = not (p_le_o or o_le_p)
                    if p_le_o or o_le_p:
                        fn += 1      # §3 violation: can never happen
                    continue
                claims += 1
                predicted.append(float(report.view.fp[s]))
                truth_ok = {
                    fr.ANCESTOR: p_le_o,
                    fr.SAME: p_le_o and o_le_p,
                    fr.DESCENDANT: o_le_p,
                }[code]
                truth_of[str(pid_of[p])] = truth_ok
                if not truth_ok:
                    fp_count += 1

            # annotate this round's audit records with ground truth:
            # the trail now carries measured-vs-predicted fp natively
            if obs.audit:
                for rec in obs.audit.records[audit_mark:]:
                    if rec.kind == "verdict" and rec.peer_id in truth_of:
                        obs.audit.annotate_truth(rec, truth_of[rec.peer_id])

            # commit the round to BOTH clock families (receive rule)
            accept_ids = [p for p in peers
                          if report.accepted[registry.slot_of(pid_of[p])]]
            merges += len(accept_ids)
            if accept_ids:
                union_vec = vo.copy()
                for p in accept_ids:
                    np.maximum(union_vec, vec[p], out=union_vec)
                bloom[observer] = np.asarray(merged.logical_cells(), np.int64)
                vec[observer] = union_vec
                if fg_cfg.push_back:
                    for p in accept_ids:
                        bloom[p] = np.asarray(merged.logical_cells(), np.int64)
                        vec[p] = union_vec.copy()

        # ---- chaos settle: faults off, no new events, prove recovery ----
        if chaos is not None and last_state is not None:
            chaos_tp.quiesce()
            bloom, vec = last_state
            for _ in range(max(settle_rounds, 0)):
                rounds_done += 1
                chaos_round(bloom, vec)
            converged = all(
                np.array_equal(bloom[p], bloom[observer]) for p in peers)
    finally:
        tp.close()
        for server in servers:
            server.stop()

    measured = fp_count / max(claims, 1)
    mean_pred = float(np.mean(predicted)) if predicted else 0.0
    if obs.metrics:
        obs.metrics.gauge("sim_measured_fp").set(measured)
        obs.metrics.gauge("sim_mean_predicted_fp").set(mean_pred)
        obs.metrics.gauge("sim_fp_within_band").set(
            float(fm.fp_within_band(measured, mean_pred)))
    return GossipSimResult(
        rounds=rounds_done,
        false_negatives=fn,
        claims=claims,
        false_positives=fp_count,
        measured_fp_rate=measured,
        mean_predicted_fp=mean_pred,
        within_eq3_band=fm.fp_within_band(measured, mean_pred),
        merges=merges,
        quarantines=quarantines,
        transport=tp.name,
        digest_bytes=digest_bytes,
        delta_bytes=delta_bytes,
        pushback_bytes=pushback_bytes,
        converged=converged,
        fault_events=len(chaos_tp.schedule) if chaos_tp is not None else 0,
        rejected_frames=rejected_frames,
        corrupted=corrupted_rows,
        repaired=repaired_rows,
    )


def monte_carlo_overlap(m: int, sum_a: int, sum_b: int, trials: int, seed: int = 0) -> float:
    """Empirical probability that a random clock with ``sum_b`` increments
    cell-wise dominates an independent random clock with ``sum_a`` increments
    — the quantity Eq. 3 approximates.  Used by tests/benchmarks to validate
    the formula (including the paper's m=6, ΣB=10, ΣA=7 -> 0.29 example).
    """
    rng = np.random.default_rng(seed)
    a_cells = rng.multinomial(sum_a, np.full(m, 1.0 / m), size=trials)
    b_cells = rng.multinomial(sum_b, np.full(m, 1.0 / m), size=trials)
    return float(np.mean(np.all(a_cells <= b_cells, axis=1)))
