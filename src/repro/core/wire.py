"""Binary wire framing for §4 clock snapshots and anti-entropy digests.

``core.clock.to_wire`` decides WHAT ships — u8 window residuals plus one
int32 base when the §4 moving window fits a byte (the common case the
paper argues for), int32 cells otherwise.  This module decides HOW it
ships between processes: a fixed header, an explicit big-endian payload,
and a CRC32 trailer, so a receiver at the far end of a TCP stream can
reject truncated, corrupted, or future-versioned frames with a clear
error instead of silently reconstructing a garbage clock.

Clock frame layout (``encode_clock`` / ``decode_clock``):

    bytes 0-1    magic ``b"BC"``
    byte  2      wire version (currently 1)
    byte  3      cell dtype code: 0 = uint8 residuals, 1 = int32 cells
    byte  4      k (hash probes per event)
    byte  5      reserved (0)
    bytes 6-9    m (cell count), u32
    bytes 10-13  base (§4 window offset), i32
    ...          cells payload: m bytes (u8) or 4·m bytes (i32)
    last 4       CRC32 over everything before it, u32

Exact-row frames (``encode_exact`` / ``decode_exact``, wire version 2)
carry the hybrid engine's hot-set representation: not bloom cells at all
but the exact causal coordinates of a session relative to its minting
replica's local chain — the chain-prefix length ``v``, the count of
private (post-fork) events, and the private event ids themselves.  A
receiver holding the same chain can then answer ordering queries with
ZERO false positives (integer compares, no Eq. 3 exposure), which is
the whole point of promoting a hot session out of the bloom slab.
Layout:

    bytes 0-1    magic ``b"BE"``
    byte  2      wire version
    byte  3      k (geometry the session's shadow bloom row uses)
    bytes 4-11   v (local-chain prefix length), u64
    bytes 12-15  n_private (private events past the prefix), u32
    ...          n_private × 16 bytes: (event_hi u64, event_lo u64) pairs
    last 4       CRC32 over everything before it, u32

Digest frames (``encode_digest`` / ``decode_digest``) are the tiny
per-peer summaries anti-entropy sessions exchange FIRST: a peer whose
digest matches what the caller already ingested is skipped entirely, so
a quiet fleet costs digest bytes only.  A digest carries the clock sum
(the Eq. 3 / straggler input), the §4 base, and a CRC32 of the logical
cells — the content key delta decisions are made on.  Two clocks with
equal sums are NOT necessarily equal (any two event sets of the same
size tie), so the checksum, not the sum, is what detects a changed row.
"""
from __future__ import annotations

import dataclasses
import struct
import zlib

import numpy as np

__all__ = [
    "WIRE_VERSION",
    "WireFormatError",
    "ClockDigest",
    "encode_clock",
    "decode_clock",
    "clock_frame_nbytes",
    "encode_exact",
    "decode_exact",
    "exact_frame_nbytes",
    "cells_crc",
    "digest_of",
    "encode_digest",
    "decode_digest",
]

#: version 2 added the exact-row frame kind (``b"BE"``) for the hybrid
#: engine's hot set; clock/digest layouts are unchanged from version 1.
WIRE_VERSION = 2

_CLOCK_MAGIC = b"BC"
_DIGEST_MAGIC = b"BD"
_EXACT_MAGIC = b"BE"
_U8, _I32 = 0, 1

_CLOCK_HDR = struct.Struct("!2sBBBxIi")
#                magic ver k idlen pad m  sum  base crc
_DIGEST_HDR = struct.Struct("!2sBBBxIdiI")
#               magic ver k  v  n_private
_EXACT_HDR = struct.Struct("!2sBBQI")
_EVENT = struct.Struct("!QQ")
_CRC = struct.Struct("!I")


class WireFormatError(ValueError):
    """A frame failed validation: truncated, corrupted, or wrong version."""


def _wrap_i32(value: int) -> int:
    """Fold an integer onto the int32 two's-complement circle.

    Bounded-counter semantics: wire bases are mod-2^32 positions, so a
    host-side counter that ticked past ``INT32_MAX`` (e.g. a ClockNode's
    int64 cells) ships as its wrapped representative instead of crashing
    ``struct.pack`` — the wrap-subtraction compares on the receiving
    side read it back correctly.  Identity for values already in range.
    """
    value = int(value) & 0xFFFFFFFF
    return value - 0x100000000 if value >= 0x80000000 else value


def _check_magic_version(buf: bytes, magic: bytes, kind: str) -> None:
    if len(buf) < 3:
        raise WireFormatError(
            f"truncated {kind} frame: {len(buf)} bytes is too short even "
            f"for the magic + version prefix")
    if buf[:2] != magic:
        raise WireFormatError(
            f"bad {kind} frame magic {buf[:2]!r} (expected {magic!r}) — "
            "not a bloom-clock wire frame, or framing lost sync")
    if buf[2] != WIRE_VERSION:
        raise WireFormatError(
            f"unsupported {kind} wire version {buf[2]} "
            f"(this build speaks version {WIRE_VERSION})")


def cells_crc(cells: np.ndarray, base: int = 0) -> int:
    """CRC32 of the canonical logical cells (base applied, int32 BE).

    Representation-independent: a (u8 residuals, base) row and its
    materialized int32 logical cells hash identically, so digests match
    across the packed and promoted storage forms.
    """
    logical = np.asarray(cells, np.int64) + int(base)
    return zlib.crc32(np.ascontiguousarray(logical.astype(">i4")).tobytes())


# ---------------------------------------------------------------------------
# clock frames
# ---------------------------------------------------------------------------

def encode_clock(snap: dict) -> bytes:
    """Encode a ``core.clock.to_wire`` snapshot dict as one binary frame."""
    cells = np.asarray(snap["cells"])
    if cells.ndim != 1:
        raise ValueError(f"one clock per frame; got cells shape {cells.shape}")
    if cells.dtype == np.uint8:
        code, payload = _U8, cells.tobytes()
    else:
        code = _I32
        payload = np.ascontiguousarray(cells.astype(">i4")).tobytes()
    body = _CLOCK_HDR.pack(_CLOCK_MAGIC, WIRE_VERSION, code,
                           int(snap["k"]), cells.shape[0],
                           _wrap_i32(snap["base"])) + payload
    return body + _CRC.pack(zlib.crc32(body))


def decode_clock(buf: bytes) -> dict:
    """Decode one clock frame back to a ``from_wire``-shaped snapshot dict.

    Raises :class:`WireFormatError` on truncation, trailing garbage,
    checksum mismatch, unknown version, or a dtype code this build does
    not know — never returns a partially-decoded clock.
    """
    buf = bytes(buf)
    _check_magic_version(buf, _CLOCK_MAGIC, "clock")
    if len(buf) < _CLOCK_HDR.size:
        raise WireFormatError(
            f"truncated clock frame: {len(buf)} bytes, need "
            f"{_CLOCK_HDR.size} for the header")
    _, _, code, k, m, base = _CLOCK_HDR.unpack_from(buf)
    if code not in (_U8, _I32):
        raise WireFormatError(f"unknown cell dtype code {code}")
    cell_bytes = m * (1 if code == _U8 else 4)
    expect = _CLOCK_HDR.size + cell_bytes + _CRC.size
    if len(buf) < expect:
        raise WireFormatError(
            f"truncated clock frame: {len(buf)} bytes, header declares "
            f"m={m} ({'u8' if code == _U8 else 'i32'} cells) = {expect}")
    if len(buf) > expect:
        raise WireFormatError(
            f"oversized clock frame: {len(buf)} bytes, header declares "
            f"{expect} — {len(buf) - expect} trailing bytes")
    (crc,) = _CRC.unpack_from(buf, expect - _CRC.size)
    if crc != zlib.crc32(buf[: expect - _CRC.size]):
        raise WireFormatError(
            "corrupted clock frame: CRC32 mismatch over header + cells")
    raw = buf[_CLOCK_HDR.size: _CLOCK_HDR.size + cell_bytes]
    if code == _U8:
        cells = np.frombuffer(raw, np.uint8).copy()
    else:
        cells = np.frombuffer(raw, ">i4").astype(np.int32)
    return {"cells": cells, "base": int(base), "k": int(k)}


def clock_frame_nbytes(m: int, packed: bool = True) -> int:
    """Encoded frame size for an m-cell clock (u8 vs promoted int32)."""
    return _CLOCK_HDR.size + m * (1 if packed else 4) + _CRC.size


# ---------------------------------------------------------------------------
# exact-row frames (hybrid hot set)
# ---------------------------------------------------------------------------

def encode_exact(meta: dict) -> bytes:
    """Encode an exact hot-row snapshot ``{"v", "n_private", "events",
    "k"}`` as one binary frame.

    ``events`` is the sequence of private (event_hi, event_lo) id pairs;
    its length must equal ``n_private`` (when ``n_private`` is present)
    because a receiver reconstructs concurrency verdicts from the count
    and re-mints the session's shadow bloom row from the ids.
    """
    events = [(int(hi), int(lo)) for hi, lo in meta.get("events", ())]
    n_private = int(meta.get("n_private", len(events)))
    if n_private != len(events):
        raise ValueError(
            f"n_private={n_private} disagrees with {len(events)} event ids")
    body = _EXACT_HDR.pack(_EXACT_MAGIC, WIRE_VERSION, int(meta["k"]),
                           int(meta["v"]), n_private)
    body += b"".join(_EVENT.pack(hi & 0xFFFFFFFFFFFFFFFF,
                                 lo & 0xFFFFFFFFFFFFFFFF)
                     for hi, lo in events)
    return body + _CRC.pack(zlib.crc32(body))


def decode_exact(buf: bytes) -> dict:
    """Decode one exact-row frame; same absolute contract as clock
    frames — truncation, trailing garbage, CRC mismatch, or version skew
    raise :class:`WireFormatError`, never a partially-decoded row."""
    buf = bytes(buf)
    _check_magic_version(buf, _EXACT_MAGIC, "exact")
    if len(buf) < _EXACT_HDR.size:
        raise WireFormatError(
            f"truncated exact frame: {len(buf)} bytes, need "
            f"{_EXACT_HDR.size} for the header")
    _, _, k, v, n_private = _EXACT_HDR.unpack_from(buf)
    expect = _EXACT_HDR.size + n_private * _EVENT.size + _CRC.size
    if len(buf) < expect:
        raise WireFormatError(
            f"truncated exact frame: {len(buf)} bytes, header declares "
            f"n_private={n_private} = {expect}")
    if len(buf) > expect:
        raise WireFormatError(
            f"oversized exact frame: {len(buf)} bytes, header declares "
            f"{expect} — {len(buf) - expect} trailing bytes")
    (crc,) = _CRC.unpack_from(buf, expect - _CRC.size)
    if crc != zlib.crc32(buf[: expect - _CRC.size]):
        raise WireFormatError(
            "corrupted exact frame: CRC32 mismatch over header + events")
    events = tuple(
        _EVENT.unpack_from(buf, _EXACT_HDR.size + i * _EVENT.size)
        for i in range(n_private))
    return {"v": int(v), "n_private": int(n_private), "events": events,
            "k": int(k)}


def exact_frame_nbytes(n_private: int) -> int:
    """Encoded frame size for an exact row with ``n_private`` events."""
    return _EXACT_HDR.size + n_private * _EVENT.size + _CRC.size


# ---------------------------------------------------------------------------
# digests
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClockDigest:
    """Per-peer anti-entropy summary: enough to decide pull-or-skip."""

    peer_id: str
    clock_sum: float          # Eq. 3 / straggler input
    base: int                 # §4 window offset
    m: int                    # cell count (schema check before a pull)
    k: int
    crc: int                  # cells_crc of the logical cells

    @property
    def key(self) -> tuple:
        """Content identity a delta decision compares against."""
        return (self.crc, self.m)

    @property
    def nbytes(self) -> int:
        return _DIGEST_HDR.size + len(self.peer_id.encode()) + _CRC.size


def digest_of(peer_id: str, cells, base: int = 0, k: int = 4) -> ClockDigest:
    """Digest of one clock's host-side cells (any integer dtype)."""
    cells = np.asarray(cells)
    s = float(np.asarray(cells, np.float64).sum()
              + float(base) * cells.shape[-1])
    return ClockDigest(peer_id=str(peer_id), clock_sum=s, base=int(base),
                       m=int(cells.shape[-1]), k=int(k),
                       crc=cells_crc(cells, base))


def encode_digest(d: ClockDigest) -> bytes:
    pid = d.peer_id.encode()
    if len(pid) > 255:
        raise ValueError(f"peer_id too long for wire ({len(pid)} bytes)")
    body = _DIGEST_HDR.pack(_DIGEST_MAGIC, WIRE_VERSION, d.k, len(pid),
                            d.m, d.clock_sum, _wrap_i32(d.base), d.crc) + pid
    return body + _CRC.pack(zlib.crc32(body))


def decode_digest(buf: bytes) -> ClockDigest:
    """Decode one digest frame; like clock frames, a corrupted digest is
    rejected (CRC trailer over header + peer id) rather than steering a
    wrong pull/skip decision."""
    buf = bytes(buf)
    _check_magic_version(buf, _DIGEST_MAGIC, "digest")
    if len(buf) < _DIGEST_HDR.size:
        raise WireFormatError(
            f"truncated digest frame: {len(buf)} bytes, need "
            f"{_DIGEST_HDR.size} for the header")
    _, _, k, idlen, m, s, base, crc = _DIGEST_HDR.unpack_from(buf)
    expect = _DIGEST_HDR.size + idlen + _CRC.size
    if len(buf) != expect:
        raise WireFormatError(
            f"digest frame length {len(buf)} does not match declared "
            f"peer-id length {idlen} (expected {expect})")
    (frame_crc,) = _CRC.unpack_from(buf, expect - _CRC.size)
    if frame_crc != zlib.crc32(buf[: expect - _CRC.size]):
        raise WireFormatError(
            "corrupted digest frame: CRC32 mismatch over header + peer id")
    try:
        pid = buf[_DIGEST_HDR.size: expect - _CRC.size].decode()
    except UnicodeDecodeError as e:
        raise WireFormatError(f"corrupted digest frame: peer id is not "
                              f"valid utf-8 ({e})") from None
    return ClockDigest(peer_id=pid, clock_sum=s, base=base, m=m, k=k, crc=crc)
