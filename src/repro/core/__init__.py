"""The paper's primary contribution: the Bloom Clock and its ecosystem.

- ``clock``        BloomClock pytree + tick/merge/compare/fp_rate/compress
- ``vector_clock`` exact O(N) baseline the paper compares against
- ``hashing``      event-id mixing + double-hashed bloom indices
- ``history``      §3 moving-window predecessor refinement
- ``sim``          N-node protocol simulator with ground-truth scoring
- ``wire``         binary frame/digest encoding for gossip transports
"""
from repro.core import clock, hashing, history, sim, vector_clock, wire  # noqa: F401
from repro.core.clock import (  # noqa: F401
    BloomClock,
    compare,
    fp_rate,
    merge,
    ordering,
    tick,
    zeros,
)
