"""Deterministic synthetic token pipeline with bloom-clock batch stamping.

Production shape without external data: batches are generated from a
counter-based RNG (reproducible across restarts and elastic rescales —
batch ``i`` is identical no matter which host materializes it), sharded
per host, and every global batch carries a 64-bit event id derived from
(run_id, step).  The trainer ticks its bloom clock with that id, so after
any restart/rescale the runtime can *prove* (to Eq.-3 confidence) that its
sample stream is causally consistent with a checkpoint's — a stale or
forked data cursor shows up as clock incomparability.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hashing import stable_event_id

__all__ = ["DataConfig", "SyntheticLM", "batch_event_id"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    run_id: str = "run0"
    seed: int = 1234
    # structured synthetic stream: repeated n-gram process so the model has
    # something learnable (loss visibly decreases in examples/)
    ngram: int = 3


def batch_event_id(run_id: str, step: int) -> tuple[int, int]:
    """(hi, lo) uint32 event id for the bloom clock tick of batch ``step``."""
    return stable_event_id("batch", run_id, step)


class SyntheticLM:
    """Counter-based synthetic LM stream.

    ``batch(step)`` -> dict(tokens [B, S+1] int32).  Tokens follow a
    deterministic mixture: token_t = f(token_{t-1..t-n}) with noise, so
    cross-entropy is reducible and training curves are meaningful.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed random transition table: next = table[prev] (+ noise)
        self._table = rng.integers(0, cfg.vocab, size=cfg.vocab, dtype=np.int64)

    def batch(self, step: int, host_id: int = 0, n_hosts: int = 1) -> dict:
        cfg = self.cfg
        assert cfg.global_batch % n_hosts == 0
        local_b = cfg.global_batch // n_hosts
        rng = np.random.default_rng(
            (cfg.seed, step, host_id)
        )  # counter-based: (seed, step, host) fully determines the batch
        toks = np.empty((local_b, cfg.seq_len + 1), np.int64)
        toks[:, 0] = rng.integers(0, cfg.vocab, size=local_b)
        noise = rng.random((local_b, cfg.seq_len)) < 0.1
        rands = rng.integers(0, cfg.vocab, size=(local_b, cfg.seq_len))
        for t in range(cfg.seq_len):
            nxt = self._table[toks[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rands[:, t], nxt)
        return {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }

    def event_id(self, step: int) -> tuple[int, int]:
        return batch_event_id(self.cfg.run_id, step)
