"""Benchmark driver: one function per paper claim/table.

Prints ``name,us_per_call,derived`` CSV.  The roofline extraction (which
re-lowers 512-device programs and takes ~30 min for all 32 cells) runs
separately via ``python -m benchmarks.bench_roofline``; here we include
its cached summary when reports/roofline.csv exists.
"""
from __future__ import annotations

import os


def main() -> None:
    from benchmarks.bench_clock import all_benches
    from benchmarks.bench_fleet import all_benches as fleet_benches

    print("name,us_per_call,derived")
    for name, us, derived in all_benches() + fleet_benches():
        print(f'{name},{us:.2f},"{derived}"')

    path = os.path.join(os.path.dirname(__file__), "..", "reports",
                        "roofline.csv")
    if os.path.exists(path):
        with open(path) as f:
            lines = f.read().splitlines()
        for line in lines[1:]:
            if not line:
                continue
            p = line.split(",")
            print(f'roofline_{p[0]}_{p[1]},0.00,"dom={p[9]} '
                  f'useful={p[11]} frac={p[12]}"')


if __name__ == "__main__":
    main()
