"""Roofline-term extraction for every dry-run cell.

XLA's cost_analysis counts a ``scan``(while-loop) body ONCE, so the
full-depth numbers from the baseline dry-run undercount layer work.  This
bench therefore lowers each cell twice more at reduced depth (L=2, L=4,
scan disabled) at FULL width/batch, takes the per-layer delta, and scales:

    total(X) = X(L=2) + (L - 2) * (X(L=4) - X(L=2)) / 2

for X in {flops, bytes_accessed, collective_bytes}.  Hardware model
(TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

    compute_term    = flops_per_chip / 197e12
    memory_term     = bytes_per_chip / 819e9
    collective_term = coll_bytes_per_chip / 50e9

Writes reports/roofline.csv; run via ``python -m benchmarks.run`` (fast
cells only) or ``python -m benchmarks.bench_roofline --all``.
"""
from __future__ import annotations

import dataclasses
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

CSV_HEADER = ("arch,shape,mesh,flops_per_chip,bytes_per_chip,coll_bytes_per_chip,"
              "compute_s,memory_s,collective_s,dominant,model_flops_per_chip,"
              "useful_ratio,roofline_frac")


def _reduced_cfg(cfg, L):
    kw = {"n_layers": L, "scan_layers": False}
    if cfg.is_encdec:
        kw["n_enc_layers"] = L
    if cfg.family == "hybrid":
        kw["global_layers"] = ()      # homogeneous layers for the delta
    return dataclasses.replace(cfg, **kw)


def _extract(rec):
    coll = rec["collectives"]
    cbytes = sum(v for k, v in coll.items() if k != "counts")
    return (rec["cost"]["flops"] or 0.0,
            rec["cost"]["bytes_accessed"] or 0.0,
            float(cbytes))


def measure_cell(arch, shape_name, rules=None, cfg_override=None, quiet=True):
    """Returns dict with L-scaled per-chip flops/bytes/collective bytes."""
    from repro.configs import get_config
    from repro.launch.dryrun import run_cell

    cfg = cfg_override or get_config(arch)
    L = cfg.n_layers
    r2 = run_cell(arch, shape_name, rules=rules,
                  cfg_override=_reduced_cfg(cfg, 2), quiet=quiet)
    r4 = run_cell(arch, shape_name, rules=rules,
                  cfg_override=_reduced_cfg(cfg, 4), quiet=quiet)
    f2, b2, c2 = _extract(r2)
    f4, b4, c4 = _extract(r4)
    per_layer = ((f4 - f2) / 2, (b4 - b2) / 2, (c4 - c2) / 2)
    tot = (f2 + (L - 2) * per_layer[0],
           b2 + (L - 2) * per_layer[1],
           c2 + (L - 2) * per_layer[2])
    return {"flops": tot[0], "bytes": tot[1], "coll": tot[2],
            "per_layer": per_layer, "L": L}


def model_flops_per_chip(cfg, shape, chips=256):
    """6·N·D (dense train) / 2·N·D (prefill) / 2·N_active·B (decode),
    with N_active for MoE; divided by chips."""
    n = cfg.n_params()
    n_act = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_act * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_act * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_act * shape.global_batch
    return total / chips


def roofline_row(arch, shape_name, meas, cfg=None, chips=256):
    from repro.configs import get_config
    from repro.shapes import SHAPES

    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    comp = meas["flops"] / PEAK_FLOPS
    memt = meas["bytes"] / HBM_BW
    coll = meas["coll"] / ICI_BW
    dom = max(("compute", comp), ("memory", memt), ("collective", coll),
              key=lambda kv: kv[1])[0]
    mf = model_flops_per_chip(cfg, shape, chips)
    useful = mf / meas["flops"] if meas["flops"] else 0.0
    # roofline fraction: useful-compute time over the actual bottleneck time
    bound = max(comp, memt, coll)
    frac = (mf / PEAK_FLOPS) / bound if bound else 0.0
    return {
        "arch": arch, "shape": shape_name, "mesh": f"{chips}chips",
        "flops": meas["flops"], "bytes": meas["bytes"], "coll": meas["coll"],
        "compute_s": comp, "memory_s": memt, "collective_s": coll,
        "dominant": dom, "model_flops": mf, "useful_ratio": useful,
        "roofline_frac": frac,
    }


def fmt_csv(row):
    return (f'{row["arch"]},{row["shape"]},{row["mesh"]},{row["flops"]:.4e},'
            f'{row["bytes"]:.4e},{row["coll"]:.4e},{row["compute_s"]:.4e},'
            f'{row["memory_s"]:.4e},{row["collective_s"]:.4e},{row["dominant"]},'
            f'{row["model_flops"]:.4e},{row["useful_ratio"]:.4f},'
            f'{row["roofline_frac"]:.4f}')


def main(cells=None, out="reports/roofline.csv", rules=None):
    from repro.configs import ARCHS, get_config
    from repro.shapes import SHAPES, runnable

    if cells is None:
        cells = [(a, s) for a in ARCHS for s in SHAPES
                 if runnable(get_config(a).family, s)]
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    done = {}
    if os.path.exists(out):
        with open(out) as f:
            for line in f.read().splitlines()[1:]:
                if line:
                    parts = line.split(",")
                    done[(parts[0], parts[1])] = line
    rows = []
    with open(out, "w") as f:
        f.write(CSV_HEADER + "\n")
        for k, line in done.items():
            f.write(line + "\n")
        f.flush()
        for arch, s in cells:
            if (arch, s) in done:
                print(f"[roofline] cached {arch} x {s}")
                continue
            try:
                meas = measure_cell(arch, s, rules=rules)
                row = roofline_row(arch, s, meas)
                rows.append(row)
                f.write(fmt_csv(row) + "\n")
                f.flush()
                print(f"[roofline] {arch:18s} {s:12s} dom={row['dominant']:10s} "
                      f"frac={row['roofline_frac']:.3f}")
            except Exception as e:
                import traceback
                traceback.print_exc()
                print(f"[roofline] FAIL {arch} {s}: {e}")
    return rows


if __name__ == "__main__":
    main()
