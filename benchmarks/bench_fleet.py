"""Fleet-subsystem benchmarks: bulk classification vs the broadcast path.

Three claims, each (name, us_per_call, derived) CSV rows like bench_clock:

- **all-pairs**: the tiled Pallas matrix kernel (interpret mode on CPU,
  compiled on TPU) vs ``repro.core.clock.comparability_matrix``, the
  eager O(n^2 * m) broadcast reference.  Checked bit-exact on flags and
  to 1e-6 on Eq. 3 fp before timing; the acceptance config is n = m =
  1024 (three ~4 GB broadcast intermediates for the reference vs a
  streamed tile sweep for the kernel).
- **classify-all**: one registry ``classify_all`` device call vs the
  per-peer ``lineage`` loop the runtime used to run (one fused compare +
  host sync per peer).
- **gossip round**: full anti-entropy rounds/second over the registry.

``python -m benchmarks.bench_fleet`` runs the full acceptance config;
``all_benches()`` (used by benchmarks/run.py) runs a smaller sweep.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clock as bc
from repro.fleet import ClockRegistry, GossipConfig, fleet_health, gossip_round
from repro.kernels import ops


def _rand_cells(n: int, m: int, seed: int = 0) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 30, (n, m)), jnp.int32)


def _time(fn, n: int = 3) -> float:
    fn()                                   # warm / compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(jax.tree.leaves(fn()))
    return (time.perf_counter() - t0) / n


def bench_all_pairs(n: int = 1024, m: int = 1024, verify: bool = True) -> list:
    """Tiled matrix kernel vs broadcast reference: correctness + speedup."""
    rows = []
    cells = _rand_cells(n, m)
    clocks = bc.BloomClock(cells, jnp.zeros((n,), jnp.int32), 4)

    if verify:
        got = jax.device_get(ops.compare_matrix(cells, cells))
        ref = jax.device_get(bc.comparability_matrix(clocks))
        flags_exact = bool(
            np.array_equal(got["a_le_b"], ref["a_le_b"])
            and np.array_equal(got["concurrent"], ref["concurrent"]))
        fp_err = float(np.max(np.abs(got["fp"] - ref["fp"])))
        rows.append((f"matrix_kernel_verify_n{n}_m{m}", 0.0,
                     f"flags_exact={flags_exact} max_fp_err={fp_err:.2e}"))
        assert flags_exact and fp_err <= 1e-6, (flags_exact, fp_err)

    t_kernel = _time(lambda: ops.compare_matrix(cells, cells))
    t_ref = _time(lambda: bc.comparability_matrix(clocks), n=1)
    rows.append((f"matrix_kernel_n{n}_m{m}", t_kernel * 1e6,
                 f"{n * n / t_kernel / 1e6:.1f} Mpairs/s"))
    rows.append((f"broadcast_reference_n{n}_m{m}", t_ref * 1e6,
                 f"{n * n / t_ref / 1e6:.1f} Mpairs/s"))
    rows.append((f"matrix_speedup_n{n}_m{m}", 0.0,
                 f"kernel_over_broadcast={t_ref / t_kernel:.1f}x (need >=5x)"))
    return rows


def _filled_registry(n: int, m: int, seed: int = 0) -> ClockRegistry:
    registry = ClockRegistry(capacity=n, m=m, k=4)
    cells = np.asarray(_rand_cells(n, m, seed))
    registry.admit_many({
        f"peer{i}": bc.BloomClock(jnp.asarray(cells[i]),
                                  jnp.zeros((), jnp.int32), 4)
        for i in range(n)})
    return registry


def bench_classify_all(n: int = 1024, m: int = 1024) -> list:
    """One fused classify_all call vs the per-peer lineage loop."""
    from repro.runtime.clock_runtime import ClockConfig, ClockRuntime

    rows = []
    registry = _filled_registry(n, m)
    rt = ClockRuntime(ClockConfig(m=m, k=4))
    rt.clock = registry.get("peer0")

    t_fleet = _time(lambda: registry.classify_all(rt.clock))
    rows.append((f"classify_all_n{n}_m{m}", t_fleet * 1e6,
                 f"{n / t_fleet / 1e3:.1f} Kpeers/s one device call"))

    def loop(k_peers: int = 64):
        return [rt.lineage(registry.get(f"peer{i}")) for i in range(k_peers)]

    t_loop = _time(loop, n=1) / 64 * n     # extrapolated to n peers
    rows.append((f"lineage_loop_n{n}_m{m}", t_loop * 1e6,
                 f"extrapolated from 64 peers; {t_loop / t_fleet:.1f}x slower"))
    return rows


def bench_gossip(n: int = 1024, m: int = 1024) -> list:
    rows = []
    registry = _filled_registry(n, m)
    local = registry.get("peer0")
    cfg = GossipConfig(fp_threshold=1.0, push_back=False)
    t = _time(lambda: gossip_round(registry, local, cfg)[0].cells)
    rows.append((f"gossip_round_n{n}_m{m}", t * 1e6,
                 f"{1.0 / t:.2f} rounds/s full classify+merge"))
    t_h = _time(lambda: fleet_health(registry).n_components, n=1)
    rows.append((f"fleet_health_n{n}_m{m}", t_h * 1e6,
                 "all-pairs + fork components + fp histogram"))
    return rows


def all_benches() -> list:
    """Smaller sweep for benchmarks/run.py (the full acceptance config
    runs via ``python -m benchmarks.bench_fleet``)."""
    rows = []
    rows += bench_all_pairs(n=256, m=512)
    rows += bench_classify_all(n=256, m=512)
    rows += bench_gossip(n=256, m=512)
    return rows


def main() -> None:
    print("name,us_per_call,derived")
    for name, us, derived in (
            bench_all_pairs(n=1024, m=1024)
            + bench_classify_all(n=1024, m=1024)
            + bench_gossip(n=1024, m=1024)):
        print(f'{name},{us:.2f},"{derived}"')


if __name__ == "__main__":
    main()
