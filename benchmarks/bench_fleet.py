"""Fleet-subsystem benchmarks: packed-slab engines vs references.

Every claim is recorded twice: as a (name, us_per_call, derived) CSV row
on stdout (like bench_clock) and as a machine-readable record in
``BENCH_fleet.json`` — ``{op, shape, ms, speedup_vs_reference,
reference, policy, engine}`` — so the perf trajectory is tracked across
PRs and CI can smoke-run the whole file in interpret mode.  The
``policy`` and ``engine`` columns name the ``CausalPolicy`` the call
ran under and the engine/block shape the ``CausalEngine`` dispatch
ACTUALLY chose (from the dispatch metadata), so a speedup claim is
attributable to a concrete kernel configuration, not "whatever auto
picked that day".

- **all-pairs**: the packed u8 triangle kernel (the registry's engine)
  vs (a) the int32 Pallas kernel it replaced and (b)
  ``repro.core.clock.comparability_matrix``, the eager O(n^2 * m)
  broadcast reference.  Flags are checked bit-exact and fp to 1e-6
  before timing.  The acceptance config is n = m = 1024, where the
  packed kernel must be >= 2x the int32 kernel.
- **classify-all**: one registry ``classify_all`` device call (packed
  one-vs-many kernel) vs the per-peer ``lineage`` loop.
- **gossip round**: full anti-entropy rounds/second over the registry,
  including the u8 push-back wire model.

``python -m benchmarks.bench_fleet`` runs the full acceptance config;
``--quick`` (CI smoke) and ``all_benches()`` (benchmarks/run.py) run a
smaller sweep.  ``--json PATH`` overrides the output path.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.causal import CausalEngine, CausalPolicy, PackedSlab
from repro.core import clock as bc
from repro.fleet import ClockRegistry, GossipConfig, fleet_health, gossip_round
from repro.kernels import ops, pack


def _rand_cells(n: int, m: int, seed: int = 0) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 30, (n, m)), jnp.int32)


def _time(fn, n: int = 3) -> float:
    fn()                                   # warm / compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(jax.tree.leaves(fn()))
    return (time.perf_counter() - t0) / n


def _time_interleaved(fns: dict, reps: int = 5) -> dict:
    """Round-robin the callables and return per-name MEDIAN seconds.

    Used wherever a record is a ratio of two timings (sharded vs
    1-shard): machine drift moves interleaved samples together, so the
    ratio compares like with like instead of whichever ran first."""
    for fn in fns.values():                # warm / compile
        jax.block_until_ready(jax.tree.leaves(fn()))
    samples: dict = {name: [] for name in fns}
    for _ in range(reps):
        for name, fn in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(jax.tree.leaves(fn()))
            samples[name].append(time.perf_counter() - t0)
    return {name: float(np.median(ts)) for name, ts in samples.items()}


def _engine_of(res) -> str | None:
    """Engine + block shapes from a typed result's dispatch metadata,
    e.g. "tri bi8 bj8 bm512" — always names the BULK engine."""
    if getattr(res, "engine", None) is None:
        return None
    blocks = " ".join(f"{k}{v}" for k, v in (res.blocks or ()))
    return f"{res.engine} {blocks}".strip()


def _last_engine() -> str | None:
    """Like ``_engine_of`` but from the most recent ops dispatch
    (``ops.LAST_DISPATCH``) — for paths whose host-side summaries
    (FleetView / FleetHealth) carry no metadata.  Only accurate when
    the timed call's LAST dispatch IS its bulk engine, which holds for
    the fully-packed registries these benches build (a promoted row
    would make the int32 rim the last dispatch)."""
    d = ops.LAST_DISPATCH
    if not d:
        return None
    blocks = " ".join(f"{k}{v}" for k, v in sorted(d.items())
                      if k not in ("op", "engine"))
    return f"{d['engine']} {blocks}".strip()


def _rec(records: list, op: str, shape: str, seconds: float,
         reference: str | None = None, speedup: float | None = None,
         shards: int = 1, policy: str | None = None,
         engine: str | None = None, transport: str | None = None,
         report=None) -> None:
    records.append({
        "op": op,
        "shape": shape,
        "shards": shards,
        "ms": round(seconds * 1e3, 4),
        "speedup_vs_reference": round(speedup, 3) if speedup else None,
        "reference": reference,
        "policy": policy,
        "engine": engine,
        # gossip fabric + MEASURED per-round frame bytes (None for
        # non-session ops); reports measure len() of what actually moved
        "transport": transport,
        "digest_bytes": None if report is None else report.digest_bytes,
        "delta_bytes": None if report is None else report.delta_bytes,
        "pushback_bytes": None if report is None else report.pushback_bytes,
    })


def bench_all_pairs(n: int = 1024, m: int = 1024, verify: bool = True,
                    records: list | None = None) -> list:
    """Packed triangle kernel vs int32 kernel vs broadcast reference,
    both driven through the CausalEngine front-door."""
    records = records if records is not None else []
    rows = []
    shape = f"n{n}_m{m}"
    cells = _rand_cells(n, m)
    cells_u8, base, ok = pack.pack_rows(cells)
    assert bool(ok.all())
    clocks = bc.BloomClock(cells, jnp.zeros((n,), jnp.int32), 4)
    auto_pol = CausalPolicy()
    i32_pol = CausalPolicy(engine="i32", pack=False)
    eng_auto = CausalEngine(auto_pol)
    eng_i32 = CausalEngine(i32_pol)
    slab = PackedSlab(cells_u8, base)

    # time the kernels BEFORE touching the broadcast reference: its
    # O(n^2 * m) intermediates (~4 GB at the acceptance config) degrade
    # allocator/cache behavior for everything measured after them
    t_packed = _time(lambda: eng_auto.pairs(slab))
    packed_eng = _engine_of(eng_auto.pairs(slab))   # what auto chose
    t_i32 = _time(lambda: eng_i32.pairs(cells))
    i32_eng = _engine_of(eng_i32.pairs(cells))

    if verify:
        got = jax.device_get(eng_auto.pairs(slab))
        i32 = jax.device_get(eng_i32.pairs(cells))
        ref = jax.device_get(bc.comparability_matrix(clocks))
        flags_exact = bool(
            np.array_equal(got["a_le_b"], ref["a_le_b"])
            and np.array_equal(got["concurrent"], ref["concurrent"])
            and np.array_equal(got["a_le_b"], i32["a_le_b"])
            and np.array_equal(got["b_le_a"], i32["b_le_a"]))
        fp_err = float(np.max(np.abs(got["fp"] - ref["fp"])))
        rows.append((f"matrix_kernel_verify_{shape}", 0.0,
                     f"flags_exact={flags_exact} max_fp_err={fp_err:.2e}"))
        assert flags_exact and fp_err <= 1e-6, (flags_exact, fp_err)

    t_ref = _time(lambda: bc.comparability_matrix(clocks), n=1)
    rows.append((f"matrix_packed_u8_{shape}", t_packed * 1e6,
                 f"{n * n / t_packed / 1e6:.1f} Mpairs/s [{packed_eng}]"))
    rows.append((f"matrix_kernel_i32_{shape}", t_i32 * 1e6,
                 f"{n * n / t_i32 / 1e6:.1f} Mpairs/s [{i32_eng}]"))
    rows.append((f"broadcast_reference_{shape}", t_ref * 1e6,
                 f"{n * n / t_ref / 1e6:.1f} Mpairs/s"))
    bar = " (need >=2x)" if (n, m) == (1024, 1024) else ""
    rows.append((f"matrix_packed_speedup_{shape}", 0.0,
                 f"packed_over_i32={t_i32 / t_packed:.2f}x{bar} "
                 f"packed_over_broadcast={t_ref / t_packed:.1f}x"))
    _rec(records, "bloom_matrix_pallas_packed_u8", shape, t_packed,
         reference="bloom_matrix_pallas_int32", speedup=t_i32 / t_packed,
         policy=auto_pol.label(), engine=packed_eng)
    _rec(records, "bloom_matrix_pallas_int32", shape, t_i32,
         reference="comparability_matrix", speedup=t_ref / t_i32,
         policy=i32_pol.label(), engine=i32_eng)
    _rec(records, "comparability_matrix", shape, t_ref,
         engine="broadcast_reference")
    return rows


def _filled_registry(n: int, m: int, seed: int = 0, mesh=None) -> ClockRegistry:
    registry = ClockRegistry(capacity=n, m=m, k=4, mesh=mesh)
    cells = np.asarray(_rand_cells(n, m, seed))
    registry.admit_many({
        f"peer{i}": bc.BloomClock(jnp.asarray(cells[i]),
                                  jnp.zeros((), jnp.int32), 4)
        for i in range(n)})
    return registry


def bench_sharded(n: int, m: int, shards: int,
                  records: list | None = None) -> list:
    """Mesh-sharded classify_all / all_pairs (shard_map + ppermute ring)
    vs the single-device registry — results checked bit-identical first."""
    from repro.launch.mesh import make_fleet_mesh

    records = records if records is not None else []
    rows = []
    shape = f"n{n}_m{m}"
    if shards > len(jax.devices()):
        rows.append((f"sharded_skip_{shape}", 0.0,
                     f"need {shards} devices, have {len(jax.devices())} "
                     "(set XLA_FLAGS=--xla_force_host_platform_device_count)"))
        # leave a marker in the JSON too, so the perf-trajectory tooling
        # sees "requested but skipped" instead of a silent gap
        _rec(records, "sharded_benches_skipped", shape, 0.0,
             reference=f"need_{shards}_devices_have_{len(jax.devices())}",
             shards=shards)
        return rows
    ref = _filled_registry(n, m)
    reg = _filled_registry(n, m, mesh=make_fleet_mesh(shards))
    local = ref.get("peer0")

    v_ref, v_got = ref.classify_all(local), reg.classify_all(local)
    assert (v_got.status == v_ref.status).all() and (v_got.fp == v_ref.fp).all()
    p_ref = jax.device_get(ref.all_pairs())
    p_got = jax.device_get(reg.all_pairs())
    assert np.array_equal(np.asarray(p_got["a_le_b"], bool),
                          np.asarray(p_ref["a_le_b"], bool))
    assert (np.asarray(p_got["fp"]) == np.asarray(p_ref["fp"])).all()

    t1 = _time(lambda: ref.classify_all(local))
    ts = _time(lambda: reg.classify_all(local))
    cls_eng = _last_engine()
    rows.append((f"classify_all_sharded{shards}_{shape}", ts * 1e6,
                 f"bit-identical; 1-device {t1 * 1e6:.0f}us"))
    _rec(records, "classify_all_sharded", shape, ts,
         reference="classify_all_1shard", speedup=t1 / ts, shards=shards,
         policy=reg.policy.label(), engine=cls_eng)
    t = _time_interleaved({
        "one": lambda: ref.all_pairs()["a_le_b"],
        "sharded": lambda: reg.all_pairs()["a_le_b"],
    }, reps=7)
    t1, ts = t["one"], t["sharded"]
    ring_eng = _engine_of(reg.all_pairs())
    strategy = ops.LAST_DISPATCH.get("strategy")
    rows.append((f"all_pairs_sharded{shards}_{shape}", ts * 1e6,
                 f"strategy={strategy}, bit-identical; "
                 f"1-device {t1 * 1e6:.0f}us"))
    _rec(records, "all_pairs_ring", shape, ts,
         reference="all_pairs_1shard", speedup=t1 / ts, shards=shards,
         policy=reg.policy.label(), engine=ring_eng)
    return rows


def bench_classify_all(n: int = 1024, m: int = 1024,
                       records: list | None = None) -> list:
    """One fused classify_all call vs the per-peer lineage loop."""
    from repro.runtime.clock_runtime import ClockConfig, ClockRuntime

    records = records if records is not None else []
    rows = []
    shape = f"n{n}_m{m}"
    registry = _filled_registry(n, m)
    rt = ClockRuntime(ClockConfig(m=m, k=4))
    rt.clock = registry.get("peer0")

    t_fleet = _time(lambda: registry.classify_all(rt.clock))
    cls_eng = _last_engine()
    rows.append((f"classify_all_{shape}", t_fleet * 1e6,
                 f"{n / t_fleet / 1e3:.1f} Kpeers/s one device call (packed)"))

    def loop(k_peers: int = 64):
        return [rt.lineage(registry.get(f"peer{i}")) for i in range(k_peers)]

    t_loop = _time(loop, n=1) / 64 * n     # extrapolated to n peers
    rows.append((f"lineage_loop_{shape}", t_loop * 1e6,
                 f"extrapolated from 64 peers; {t_loop / t_fleet:.1f}x slower"))
    _rec(records, "classify_all_packed", shape, t_fleet,
         reference="per_peer_lineage_loop", speedup=t_loop / t_fleet,
         policy=registry.policy.label(), engine=cls_eng)
    return rows


def bench_gossip(n: int = 1024, m: int = 1024,
                 records: list | None = None) -> list:
    records = records if records is not None else []
    rows = []
    shape = f"n{n}_m{m}"
    registry = _filled_registry(n, m)
    local = registry.get("peer0")
    cfg = GossipConfig(policy=CausalPolicy(fp_threshold=1.0),
                       push_back=False)
    t = _time(lambda: gossip_round(registry, local, cfg)[0].cells)
    rows.append((f"gossip_round_{shape}", t * 1e6,
                 f"{1.0 / t:.2f} rounds/s full classify+merge"))
    _rec(records, "gossip_round", shape, t, policy=cfg.policy.label(),
         engine=_last_engine())
    t_h = _time(lambda: fleet_health(registry).n_components, n=1)
    rows.append((f"fleet_health_{shape}", t_h * 1e6,
                 "all-pairs + fork components + fp histogram"))
    _rec(records, "fleet_health", shape, t_h,
         policy=registry.policy.label(), engine=_last_engine())
    return rows


def bench_transports(n: int, m: int, transports: list,
                     records: list | None = None, shards: int = 2) -> list:
    """Anti-entropy sessions per transport: steady-state rounds/s plus
    the MEASURED digest/delta/push-back frame bytes of one round.

    Socket sessions run against ``min(n, 64)`` real threaded TCP peer
    servers (one per peer) and include the full frame encode/decode +
    syscall cost; mesh sessions need ``shards`` devices and an
    ``n % shards == 0`` slab.  The loopback row is the baseline the
    other fabrics are compared against byte-for-byte.
    """
    from repro.fleet.transport import (LoopbackTransport,
                                       MeshCollectiveTransport,
                                       SocketTransport, ClockNode,
                                       ClockPeerServer)
    from repro.fleet.transport.session import anti_entropy_session

    records = records if records is not None else []
    rows = []
    # accept-everything policy (fp gate open, straggler skip off — same
    # as the sim audit config) so the timed session really does merge
    # and push back to ALL n_eff peers, not a draw-dependent subset
    cfg = GossipConfig(policy=CausalPolicy(fp_threshold=1.0),
                       straggler_gap=np.inf)

    for tname in transports:
        servers = []
        try:
            if tname == "mesh":
                from repro.launch.mesh import make_fleet_mesh
                if shards > len(jax.devices()) or n % shards:
                    rows.append((f"session_mesh_skip_n{n}_m{m}", 0.0,
                                 f"need {shards} devices dividing n"))
                    _rec(records, "gossip_session", f"n{n}_m{m}", 0.0,
                         reference=f"skipped_need_{shards}_devices",
                         shards=shards, transport="mesh")
                    continue
                mesh = make_fleet_mesh(shards)
            else:
                mesh = None
            # one TCP server per peer, so cap the socket fleet
            n_eff = min(n, 64) if tname == "socket" else n
            # ONE draw feeds every fabric's peer state AND the local
            # clock below — the fabrics stay comparable and the
            # dominance construction can't silently drift apart
            peer_cells = np.asarray(_rand_cells(n_eff, m))

            if tname == "socket":
                addresses = {}
                for i in range(n_eff):
                    node = ClockNode(f"peer{i}", m, 4)
                    node.set_cells(peer_cells[i])
                    server = ClockPeerServer(node).start()
                    servers.append(server)
                    addresses[f"peer{i}"] = server.address
                registry = ClockRegistry(capacity=n_eff, m=m, k=4)
                tp = SocketTransport(addresses)
            else:
                registry = ClockRegistry(capacity=n_eff, m=m, k=4,
                                         mesh=mesh)
                registry.admit_many({
                    f"peer{i}": bc.BloomClock(jnp.asarray(peer_cells[i]),
                                              jnp.zeros((), jnp.int32), 4)
                    for i in range(n_eff)})
                tp = (LoopbackTransport(registry) if mesh is None
                      else MeshCollectiveTransport(registry))

            # local dominates every peer (cell-wise max + 1), so all n_eff
            # peers are ANCESTORs and accepted: the timed session runs
            # the FULL protocol — digest, classify, union, push-back
            local = bc.BloomClock(jnp.asarray(peer_cells.max(axis=0) + 1),
                                  jnp.zeros((), jnp.int32), 4)
            shape = f"n{n_eff}_m{m}"
            # first session pays the delta ingest (socket) / compile
            _, first = anti_entropy_session(registry, local, tp, cfg)
            t = _time(lambda: anti_entropy_session(registry, local, tp,
                                                   cfg)[1].n_accepted)
            _, steady = anti_entropy_session(registry, local, tp, cfg)
            rows.append((
                f"session_{tname}_{shape}", t * 1e6,
                f"{1.0 / t:.2f} rounds/s; measured wire/round "
                f"digest={steady.digest_bytes}B delta={steady.delta_bytes}B "
                f"push={steady.pushback_bytes}B "
                f"(first-round delta={first.delta_bytes}B)"))
            _rec(records, "gossip_session", shape, t,
                 reference="session_loopback",
                 shards=registry.n_shards, policy=cfg.policy.label(),
                 engine=_last_engine(), transport=tname, report=steady)
        finally:
            for server in servers:
                server.stop()
    return rows


def bench_observer(n: int = 256, m: int = 512,
                   records: list | None = None) -> list:
    """Observability overhead on a full loopback anti-entropy session:
    observer off (no Observer anywhere), attached-but-disabled (null
    sinks — must be in the noise), and fully on (tracing + metrics into
    in-memory sinks — the acceptance bar is <= 5% over observer-off).
    Audit is excluded here: it snapshots registry rows per verdict and
    is priced separately by its own record."""
    from repro.fleet.transport import LoopbackTransport
    from repro.fleet.transport.session import anti_entropy_session
    from repro.obs import (AuditTrail, MetricsRecorder, Observer, Tracer)

    records = records if records is not None else []
    rows = []
    shape = f"n{n}_m{m}"
    peer_cells = np.asarray(_rand_cells(n, m))
    local = bc.BloomClock(jnp.asarray(peer_cells.max(axis=0) + 1),
                          jnp.zeros((), jnp.int32), 4)

    def setup(observer):
        policy = CausalPolicy(fp_threshold=1.0, observer=observer)
        registry = ClockRegistry(capacity=n, m=m, k=4, policy=policy)
        registry.admit_many({
            f"peer{i}": bc.BloomClock(jnp.asarray(peer_cells[i]),
                                      jnp.zeros((), jnp.int32), 4)
            for i in range(n)})
        tp = LoopbackTransport(registry)
        cfg = GossipConfig(policy=policy, straggler_gap=np.inf)
        anti_entropy_session(registry, local, tp, cfg)      # warm/compile
        return registry, tp, cfg

    variants = {
        "off": setup(None),
        "null": setup(Observer()),          # attached, every sink null
        "on": setup(Observer(trace=Tracer(), metrics=MetricsRecorder())),
        "audit": setup(Observer(trace=Tracer(), metrics=MetricsRecorder(),
                                audit=AuditTrail())),
    }
    # interleave the variants round-robin and take per-variant medians:
    # machine drift (allocator, thermal, co-tenants) moves all four
    # together, so back-to-back blocks would misattribute it as
    # observer cost (or credit).  30 rounds x ~7ms keeps this < 1s.
    samples: dict = {name: [] for name in variants}
    for _ in range(30):
        for name, (registry, tp, cfg) in variants.items():
            t0 = time.perf_counter()
            anti_entropy_session(registry, local, tp, cfg)
            samples[name].append(time.perf_counter() - t0)
    t_off, t_null, t_on, t_audit = (
        float(np.median(samples[name])) for name in
        ("off", "null", "on", "audit"))

    def pct(t):
        return (t / t_off - 1.0) * 100.0

    rows.append((f"session_observer_off_{shape}", t_off * 1e6, "baseline"))
    rows.append((f"session_observer_null_{shape}", t_null * 1e6,
                 f"null sinks attached; {pct(t_null):+.1f}% vs off"))
    rows.append((f"session_observer_on_{shape}", t_on * 1e6,
                 f"tracing+metrics; {pct(t_on):+.1f}% vs off (bar <=5%)"))
    rows.append((f"session_observer_audit_{shape}", t_audit * 1e6,
                 f"tracing+metrics+audit; {pct(t_audit):+.1f}% vs off"))
    pol = CausalPolicy(fp_threshold=1.0).label()
    _rec(records, "session_observer_off", shape, t_off, policy=pol,
         transport="loopback")
    _rec(records, "session_observer_null", shape, t_null,
         reference="session_observer_off", speedup=t_off / t_null,
         policy=pol, transport="loopback")
    _rec(records, "session_observer_on", shape, t_on,
         reference="session_observer_off", speedup=t_off / t_on,
         policy=pol, transport="loopback")
    _rec(records, "session_observer_audit", shape, t_audit,
         reference="session_observer_off", speedup=t_off / t_audit,
         policy=pol, transport="loopback")
    return rows


def check_against(baseline_path: str, records: list,
                  tolerance: float = 0.15) -> list:
    """Compare this run against a recorded baseline; return failures.

    Records are matched on (op, shape, shards, transport).  A matched
    op FAILS when it got more than ``tolerance`` slower than the
    baseline (ratio test, plus a 1 ms absolute floor so micro-timings
    can't flake the gate on scheduler noise).  Ops present only on one
    side are ignored — the gate guards regressions in EXISTING ops, it
    doesn't pin the bench roster.  Transport sessions (socket spawns
    real processes, loopback/mesh sessions ride thread scheduling) sit
    well above a 15% noise floor run-to-run, so only pure compute
    records (``transport is None``) are gated.

    Absolute wall time is NOT comparable across machines (CI runners
    vary ~2x) or even across a long benching session on one box
    (sustained-load throttling).  When both runs carry the
    ``comparability_matrix`` reference at a shape, its old/new ratio is
    used as a per-shape calibration factor — the gate then measures how
    much an op slowed *relative to the dense reference on the same
    machine state*, which is what a code regression actually looks
    like."""
    with open(baseline_path) as f:
        baseline = json.load(f)

    def key(r):
        return (r["op"], r["shape"], r.get("shards", 1), r.get("transport"))

    current = {key(r): r for r in records}
    cal = {}
    for old in baseline.get("records", []):
        if old["op"] != "comparability_matrix" or not old.get("ms"):
            continue
        new = current.get(key(old))
        if new is not None and new.get("ms"):
            cal[old["shape"]] = old["ms"] / new["ms"]
    failures = []
    for old in baseline.get("records", []):
        if not old.get("ms") or old.get("transport") is not None:
            continue
        if old["op"] == "comparability_matrix":
            continue  # the calibration anchor is never gated
        new = current.get(key(old))
        if new is None or not new.get("ms"):
            continue
        c = cal.get(old["shape"], 1.0)
        ratio = new["ms"] * c / old["ms"]
        if ratio > 1.0 + tolerance and new["ms"] * c - old["ms"] > 1.0:
            failures.append(
                f"{'|'.join(str(p) for p in key(old))}: "
                f"{old['ms']:.2f}ms -> {new['ms']:.2f}ms "
                f"(calibrated {ratio:.2f}x, tolerance {1.0 + tolerance:.2f}x)")
    return failures


def all_benches() -> list:
    """Smaller sweep for benchmarks/run.py (the full acceptance config
    runs via ``python -m benchmarks.bench_fleet``)."""
    rows = []
    rows += bench_all_pairs(n=256, m=512)
    rows += bench_classify_all(n=256, m=512)
    rows += bench_gossip(n=256, m=512)
    return rows


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="small shapes (CI smoke, interpret mode on CPU)")
    p.add_argument("--shards", type=int, default=1,
                   help="also bench the mesh-sharded registry over this many "
                        "devices (shard_map classify_all + ppermute all_pairs)")
    p.add_argument("--transport", default=None,
                   choices=["loopback", "mesh", "socket", "all"],
                   help="also bench anti-entropy sessions over this gossip "
                        "fabric (measured wire bytes land in the JSON)")
    p.add_argument("--observe", action="store_true",
                   help="also bench observer overhead on a loopback session "
                        "(off vs null sinks vs full tracing+metrics)")
    p.add_argument("--json", default="BENCH_fleet.json",
                   help="machine-readable output path")
    p.add_argument("--check-against", default=None, metavar="BASELINE",
                   help="compare against a recorded BENCH_fleet.json and "
                        "exit nonzero if any existing op got >15%% slower")
    p.add_argument("--check-tolerance", type=float, default=0.15,
                   help="allowed fractional slowdown for --check-against")
    args = p.parse_args(argv)
    n, m = (256, 512) if args.quick else (1024, 1024)
    records: list = []
    rows = (bench_all_pairs(n=n, m=m, records=records)
            + bench_classify_all(n=n, m=m, records=records)
            + bench_gossip(n=n, m=m, records=records))
    if args.shards > 1:
        rows += bench_sharded(n=n, m=m, shards=args.shards, records=records)
    if args.transport:
        names = (["loopback", "mesh", "socket"] if args.transport == "all"
                 else [args.transport])
        rows += bench_transports(n=n, m=m, transports=names,
                                 records=records,
                                 shards=max(args.shards, 2))
    if args.observe:
        rows += bench_observer(n=n, m=m, records=records)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f'{name},{us:.2f},"{derived}"')
    with open(args.json, "w") as f:
        json.dump({"backend": jax.default_backend(),
                   "interpret": jax.default_backend() != "tpu",
                   "records": records}, f, indent=1)
        f.write("\n")
    print(f"# wrote {len(records)} records -> {args.json}")
    if args.check_against:
        failures = check_against(args.check_against, records,
                                 tolerance=args.check_tolerance)
        if failures:
            print(f"# REGRESSION vs {args.check_against}:", file=sys.stderr)
            for line in failures:
                print(f"#   {line}", file=sys.stderr)
            sys.exit(1)
        print(f"# no regressions vs {args.check_against} "
              f"(tolerance {args.check_tolerance:.0%})")


if __name__ == "__main__":
    main()
