"""Serving-at-scale benchmarks: ``BENCH_serve.json``.

Two measurements:

1. ``pipeline_vs_adopt_loop`` — the streaming admission pipeline
   (double-buffered staging + digest cache over a ``TieredRegistry``)
   against the obvious baseline, a synchronous ``ServingEngine.
   adopt_many`` loop at the SAME batch size.  The pipeline must win:
   its host staging for batch t+1 overlaps the device classify of
   batch t, and its rows are materialized batched host-side instead of
   per-session eager dispatches.  Both records land in the JSON; the
   compute-only baseline (``transport=None``) is gated by
   ``--check-against``, the threaded pipeline record rides ungated as
   ``transport="pipeline"`` (thread scheduling sits above the noise
   floor, same rule as gossip sessions in ``bench_fleet``).

2. ``serve_churn`` — the full churn driver at ≥1M sessions: arrivals,
   Zipf queries, migrations, expiries against the hot/warm/cold tiers.
   Reports p50/p99 admission latency, sustained QPS, per-tier
   occupancy + movement counters, and whether the stated SLO
   (p99 admission latency under an open-loop step burst) held.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_serve                # full 1M
  PYTHONPATH=src python -m benchmarks.bench_serve --quick        # CI smoke
  PYTHONPATH=src python -m benchmarks.bench_serve --check-against BENCH_serve.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
import types

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_fleet import _rec, check_against
from repro.causal import CausalPolicy
from repro.core import clock as bc
from repro.core import wire
from repro.serve.churn import ChurnConfig, run_churn
from repro.serve.pipeline import AdmissionPipeline, PipelineConfig
from repro.serve.tiers import TierConfig, TieredRegistry

#: stated SLO for the churn leg: p99 admission latency under the
#: open-loop per-step burst (the driver enqueues a whole step's
#: arrivals, then drains).  Chosen ~4x the measured steady-state p99 on
#: a CPU dev box so only a real regression trips it.
SLO_P99_MS = 15_000.0


def _mk_sessions(n: int, m: int, k: int, seed: int):
    """n distinct session clocks, all ≼ the returned local clock."""
    rng = np.random.default_rng(seed)
    cells = rng.integers(0, 6, (n, m)).astype(np.int32)
    local = bc.BloomClock(cells=jnp.asarray(cells.max(axis=0) + 1),
                          base=jnp.zeros((), jnp.int32), k=k)
    clocks = [bc.BloomClock(cells=jnp.asarray(cells[i]),
                            base=jnp.zeros((), jnp.int32), k=k)
              for i in range(n)]
    return local, clocks


def bench_pipeline_vs_adopt_loop(n: int = 4096, m: int = 256,
                                 batch: int = 256, seed: int = 0,
                                 records: list | None = None) -> list:
    from repro.configs import get_smoke_config
    from repro.models.params import init_params
    from repro.runtime.clock_runtime import ClockConfig
    from repro.serving.engine import ServeConfig, ServingEngine

    records = records if records is not None else []
    rows = []
    shape = f"n{n}_m{m}_b{batch}"
    pol = CausalPolicy(fp_threshold=1.0)
    local, clocks = _mk_sessions(n, m, 4, seed)

    # -- baseline: synchronous adopt_many loop, batch at a time --------
    cfg32 = dataclasses.replace(get_smoke_config("qwen1_5_0_5b"),
                                dtype="float32")
    params = init_params(jax.random.PRNGKey(seed), cfg32)
    eng = ServingEngine(params, cfg32, ServeConfig(max_batch=batch),
                        ClockConfig(m=m, k=4, policy=pol), replica_id="bench")
    eng.clock.clock = local
    sessions = [{"sid": f"s{i}", "clock": types.SimpleNamespace(clock=c)}
                for i, c in enumerate(clocks)]
    # warmup compiles on a throwaway batch so neither side pays them
    eng.adopt_many([{"sid": "warm", "clock": sessions[0]["clock"]}])
    eng.clock.clock = local
    t0 = time.perf_counter()
    adopted = 0
    for i in range(0, n, batch):
        adopted += int(eng.adopt_many(sessions[i:i + batch]).sum())
    t_loop = time.perf_counter() - t0
    assert adopted >= n, f"baseline rejected sessions: {adopted}/{n}"
    _rec(records, "serve_adopt_many_loop", shape, t_loop / n,
         policy="fp1.0", engine="packed")
    rows.append((f"adopt_many_loop {shape}", t_loop / n * 1e6,
                 f"{n / t_loop:.0f} sessions/s"))

    # -- pipeline: same clocks, same batch size ------------------------
    tiers = TieredRegistry(
        TierConfig(hot_capacity=max(batch * 2, 512),
                   warm_capacity=max(batch * 8, 2048)),
        m=m, k=4, policy=pol)
    pipe = AdmissionPipeline(tiers, lambda: local,
                             PipelineConfig(batch_size=batch))
    # Sessions arrive as wire frames (that's what migration puts on the
    # network); encode outside the timer, exactly as the loop baseline
    # receives already-decoded clocks.
    frames = [wire.encode_clock(bc.to_wire(c)) for c in clocks]
    pipe.submit("warm", clock=clocks[0])            # compile warmup
    pipe.drain(timeout=120)
    t0 = time.perf_counter()
    for i, f in enumerate(frames):
        pipe.submit(f"p{i}", frame=f)
    pipe.drain(timeout=600)
    t_pipe = time.perf_counter() - t0
    assert pipe.n_admitted >= n, \
        f"pipeline rejected sessions: {pipe.n_admitted}/{n}"
    speedup = t_loop / t_pipe
    _rec(records, "serve_pipeline_admit", shape, t_pipe / n,
         reference="serve_adopt_many_loop", speedup=speedup,
         policy="fp1.0", engine=tiers.engine.__class__.__name__,
         transport="pipeline")
    rows.append((f"pipeline_admit {shape}", t_pipe / n * 1e6,
                 f"{n / t_pipe:.0f} sessions/s, {speedup:.2f}x vs loop"))
    pipe.close()
    tiers.close()
    if speedup <= 1.0:
        print(f"# WARNING: pipeline did not beat the adopt_many loop "
              f"({speedup:.2f}x)", file=sys.stderr)
    return rows


def bench_churn(cfg: ChurnConfig, records: list | None = None) -> list:
    records = records if records is not None else []
    report = run_churn(cfg)
    d = report.to_dict()
    assert report.fn_violations == 0, d
    assert d["tier_counts"].get("cold", 0) > 0, \
        f"cold tier never exercised: {d['tier_counts']}"
    shape = f"s{cfg.sessions}_m{cfg.m}_b{cfg.batch_size}"
    rec = {
        "op": "serve_churn",
        "shape": shape,
        "shards": 1,
        "ms": round(report.wall_s * 1e3, 1),
        "speedup_vs_reference": None,
        "reference": None,
        "policy": f"fp{cfg.fp_threshold:g}",
        "engine": None,
        "transport": "pipeline",      # threaded driver: never gated
        "digest_bytes": None,
        "delta_bytes": None,
        "pushback_bytes": None,
        # digest-cache effectiveness and tier placement as TOP-LEVEL
        # columns (not just nested obs counters) so a --check-against
        # gate — and anyone grepping the JSON — can regress on them
        "cache_hits": d.get("cache_hits"),
        "cache_misses": d.get("cache_misses"),
        "cache_hit_rate": (d["cache_hits"]
                           / max(1, d["cache_hits"] + d["cache_misses"])
                           if d.get("cache_hits") is not None else None),
        "tier_hot": d["tier_counts"].get("hot", 0),
        "tier_warm": d["tier_counts"].get("warm", 0),
        "tier_cold": d["tier_counts"].get("cold", 0),
        "serve": {**d, "slo_p99_ms": SLO_P99_MS,
                  "slo_met": report.p99_ms <= SLO_P99_MS},
    }
    records.append(rec)
    return [(f"churn {shape}", report.wall_s * 1e6 / max(1, cfg.sessions),
             f"{report.qps:.0f} qps, p50 {report.p50_ms:.0f}ms, "
             f"p99 {report.p99_ms:.0f}ms, slo_met="
             f"{report.p99_ms <= SLO_P99_MS}, tiers {d['tier_counts']}")]


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="CI smoke: small churn + small adopt comparison")
    p.add_argument("--sessions", type=int, default=1_000_000)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--json", default="BENCH_serve.json")
    p.add_argument("--trace-dir", default=None)
    p.add_argument("--check-against", default=None, metavar="BASELINE",
                   help="compare against a recorded BENCH_serve.json and "
                        "exit nonzero if any gated op got >15%% slower")
    p.add_argument("--check-tolerance", type=float, default=0.15)
    args = p.parse_args(argv)

    records: list = []
    if args.quick:
        rows = bench_pipeline_vs_adopt_loop(n=1024, m=64, batch=64,
                                            seed=args.seed, records=records)
        rows += bench_churn(ChurnConfig.quick(seed=args.seed,
                                              trace_dir=args.trace_dir),
                            records=records)
    else:
        rows = bench_pipeline_vs_adopt_loop(n=4096, m=256, batch=256,
                                            seed=args.seed, records=records)
        rows += bench_churn(
            ChurnConfig(sessions=args.sessions, seed=args.seed,
                        audit=False, trace_dir=args.trace_dir),
            records=records)
    print("name,us_per_item,derived")
    for name, us, derived in rows:
        print(f'{name},{us:.2f},"{derived}"')
    with open(args.json, "w") as f:
        json.dump({"backend": jax.default_backend(),
                   "interpret": jax.default_backend() != "tpu",
                   "slo_p99_ms": SLO_P99_MS,
                   "records": records}, f, indent=1)
        f.write("\n")
    print(f"# wrote {len(records)} records -> {args.json}")
    if args.check_against:
        failures = check_against(args.check_against, records,
                                 tolerance=args.check_tolerance)
        if failures:
            print(f"# REGRESSION vs {args.check_against}:", file=sys.stderr)
            for line in failures:
                print(f"#   {line}", file=sys.stderr)
            sys.exit(1)
        print(f"# no regressions vs {args.check_against} "
              f"(tolerance {args.check_tolerance:.0%})")


if __name__ == "__main__":
    main()
