"""Paper-claim benchmarks for the bloom clock itself.

One function per claim; each returns CSV rows (name, us_per_call, derived).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import clock as bc
from repro.core import vector_clock as vc
from repro.core.sim import SimConfig, monte_carlo_overlap, run_sim
from repro.kernels import ops


def _timeit(fn, *args, n=20):
    fn(*args)  # compile / warm
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6  # us


def bench_eq3_fp_rate() -> list:
    """Paper §3 Eq. 3 vs Monte-Carlo ground truth (incl. the 0.29 example)."""
    rows = []
    us = _timeit(lambda: bc.fp_rate(7.0, 10.0, 6))
    paper_example = float(bc.fp_rate(7, 10, 6))
    rows.append(("eq3_paper_example_m6", us,
                 f"pred={paper_example:.4f} (paper: 0.29)"))
    for m, sa, sb in [(6, 7, 10), (32, 30, 40), (64, 30, 90), (256, 100, 200)]:
        pred = float(bc.fp_rate(sa, sb, m))
        mc = monte_carlo_overlap(m, sa, sb, trials=100_000)
        rows.append((f"eq3_vs_mc_m{m}_a{sa}_b{sb}", 0.0,
                     f"pred={pred:.4f} mc={mc:.4f} conservative={mc <= pred + 1e-3}"))
    return rows


def bench_space_vs_n() -> list:
    """Paper §2/§4: wire bytes, bloom O(m) vs vector O(N)."""
    rows = []
    m = 1024  # runtime default: 4KB/clock
    for n in (64, 256, 1024, 4096, 65_536, 1_048_576):
        vb = vc.wire_bytes(n)
        bb = m * 4
        rows.append((f"wire_bytes_n{n}", 0.0,
                     f"vector={vb}B bloom={bb}B ratio={vb / bb:.2f}"))
    # compression (§4) shrinks further: residuals fit u8 once spread
    c = bc.zeros(m, 4)
    hi = jnp.zeros((2000,), jnp.uint32)
    lo = jnp.arange(2000, dtype=jnp.uint32)
    c = bc.tick(c, hi, lo)
    z = bc.compress(c)
    u8_ok = int(jnp.max(z.cells)) < 256
    rows.append(("compressed_cells_fit_u8_after_2k_events", 0.0,
                 f"base={int(z.base)} max_resid={int(jnp.max(z.cells))} u8={u8_ok}"))
    return rows


def bench_op_throughput() -> list:
    """Clock-op latency: core jnp vs Pallas kernel (interpret) paths."""
    rows = []
    B, m, E, k = 64, 1024, 8, 4
    cells = jnp.zeros((B, m), jnp.int32)
    hi = jnp.zeros((B, E), jnp.uint32)
    lo = jnp.tile(jnp.arange(E, dtype=jnp.uint32), (B, 1))

    batch_clock = bc.BloomClock(cells, jnp.zeros((B,), jnp.int32), k)
    tick_core = jax.jit(lambda c, h, l: bc.tick(c, h, l))
    us = _timeit(tick_core, batch_clock, hi, lo)
    rows.append((f"tick_core_jnp_B{B}_m{m}_E{E}", us, f"{B * E / us:.1f} ev/us"))

    us = _timeit(lambda: ops.tick(cells, hi, lo, k=k))
    rows.append((f"tick_pallas_interp_B{B}_m{m}_E{E}", us,
                 "kernel body in python (CPU interpret)"))

    a = jnp.ones((B, m), jnp.int32)
    b = jnp.ones((B, m), jnp.int32)
    cmp_core = jax.jit(lambda x, y: bc.ordering(
        bc.BloomClock(x, jnp.zeros((B,), jnp.int32), k),
        bc.BloomClock(y, jnp.zeros((B,), jnp.int32), k)).a_le_b)
    us = _timeit(cmp_core, a, b)
    rows.append((f"compare_core_jnp_B{B}_m{m}", us, f"{B / us:.2f} cmp/us"))

    us = _timeit(lambda: ops.merge_compare(a, b))
    rows.append((f"merge_compare_pallas_interp_B{B}_m{m}", us,
                 "fused merge+flags+sums+fp"))
    return rows


def bench_protocol_sim() -> list:
    """N-node protocol accuracy vs clock size m (paper's trade-off)."""
    rows = []
    for m in (16, 32, 64, 128, 256):
        t0 = time.perf_counter()
        r = run_sim(SimConfig(n_nodes=12, n_events=600, m=m, k=3, seed=7,
                              sample_pairs=6000))
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((f"sim_12node_600ev_m{m}", dt / 600,
                     f"fn={r.false_negatives} fp_rate={r.measured_fp_rate:.4f} "
                     f"tp={r.true_positives} wire={r.bloom_wire_bytes}B"))
    return rows


def bench_history_refinement() -> list:
    """§3 history-window: fp improvement from closest-predecessor search."""
    from repro.core import history as hist

    rows = []
    m, k, W = 128, 3, 32
    c = bc.zeros(m, k)
    h = hist.init(W, m, k)
    old = None
    for i in range(60):
        c = bc.tick(c, jnp.uint32(0), jnp.uint32(i))
        h = hist.push(h, c)
        if i == 10:
            old = c
    fp_newest = float(bc.ordering(old, c).fp_a_before_b)
    fp_best, _ = hist.best_predecessor_fp(h, old)
    us = _timeit(lambda: hist.best_predecessor_fp(h, old))
    rows.append((f"history_refine_W{W}_m{m}", us,
                 f"fp_newest={fp_newest:.3e} fp_refined={float(fp_best):.3e} "
                 f"gain={fp_newest / max(float(fp_best), 1e-30):.1e}x"))
    return rows


def all_benches() -> list:
    rows = []
    rows += bench_eq3_fp_rate()
    rows += bench_space_vs_n()
    rows += bench_op_throughput()
    rows += bench_protocol_sim()
    rows += bench_history_refinement()
    return rows
