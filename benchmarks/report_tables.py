"""Render EXPERIMENTS.md tables from reports/*.jsonl|csv artifacts."""
from __future__ import annotations

import json
import os

GB = 1e9


def dryrun_table(path="reports/dryrun_baseline.jsonl") -> str:
    recs = [json.loads(l) for l in open(path)]
    # keep the newest record per cell
    by_key = {}
    for r in recs:
        by_key[(r["arch"], r["shape"], r["mesh"])] = r
    lines = [
        "| arch | shape | mesh | status | compile_s | arg GB/dev | temp GB/dev | "
        "flops/dev | ag GB | ar GB | a2a GB | cp GB |",
        "|---|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (a, s, m), r in sorted(by_key.items()):
        if r["status"] == "skip":
            lines.append(f"| {a} | {s} | {m} | SKIP (full-attn, documented) "
                         f"| | | | | | | | |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {a} | {s} | {m} | FAIL | | | | | | | | |")
            continue
        b = r["bytes_per_device"]
        c = r["collectives"]
        lines.append(
            f"| {a} | {s} | {m} | ok | {r['compile_s']} "
            f"| {b['argument']/GB:.2f} | {b['temp']/GB:.2f} "
            f"| {r['cost']['flops']:.2e} "
            f"| {c['all-gather']/GB:.2f} | {c['all-reduce']/GB:.2f} "
            f"| {c['all-to-all']/GB:.2f} | {c['collective-permute']/GB:.2f} |")
    return "\n".join(lines)


def roofline_table(path="reports/roofline.csv") -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "useful (6ND/HLO) | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    with open(path) as f:
        rows = f.read().splitlines()[1:]
    for row in sorted(rows):
        if not row:
            continue
        p = row.split(",")
        lines.append(
            f"| {p[0]} | {p[1]} | {float(p[6]):.3e} | {float(p[7]):.3e} "
            f"| {float(p[8]):.3e} | **{p[9]}** | {float(p[11]):.3f} "
            f"| {float(p[12]):.4f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys

    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### Dry-run table\n")
        print(dryrun_table())
    if which in ("all", "roofline"):
        print("\n### Roofline table\n")
        print(roofline_table())
