"""Render the §Perf-results table + §Roofline summary into EXPERIMENTS.md
from reports/perf_iterations.jsonl and reports/roofline.csv."""
from __future__ import annotations

import json
import re
from collections import defaultdict


def perf_table() -> str:
    rows = [json.loads(l) for l in open("reports/perf_iterations.jsonl")]
    by_cell = defaultdict(list)
    for r in rows:
        by_cell[r["id"].split("/")[0]].append(r)
    out = []
    for cell, rs in by_cell.items():
        out.append(f"\n**{cell}**\n")
        out.append("| iter | hypothesis (abridged) | compute_s | memory_s | "
                   "collective_s | dominant | frac | verdict |")
        out.append("|---|---|---|---|---|---|---|---|")
        prev = None
        for r in rs:
            if "error" in r:
                out.append(f"| {r['id'].split('/')[1]} | {r['hypothesis'][:60]} "
                           f"| — | — | — | — | — | ERROR |")
                continue
            ro = r["roofline"]
            frac = ro["roofline_frac"]
            if prev is None:
                verdict = "baseline"
            else:
                d = (frac - prev) / max(prev, 1e-9)
                verdict = ("**confirmed** (+{:.0%})".format(d) if d > 0.05
                           else "refuted/neutral ({:+.1%})".format(d))
            prev = frac
            hyp = r["hypothesis"].split(":")[0][:70]
            out.append(
                f"| {r['id'].split('/')[1]} | {hyp} | {ro['compute_s']:.2f} "
                f"| {ro['memory_s']:.2f} | {ro['collective_s']:.2f} "
                f"| {ro['dominant']} | **{frac:.3f}** | {verdict} |")
    return "\n".join(out)


def summary() -> str:
    rows = [json.loads(l) for l in open("reports/perf_iterations.jsonl")
            if "error" not in l]
    by_cell = defaultdict(list)
    for r in rows:
        by_cell[r["id"].split("/")[0]].append(r["roofline"]["roofline_frac"])
    lines = ["\n**Paper-faithful baseline vs beyond-paper optimized (roofline "
             "fraction):**\n",
             "| cell | baseline (V0) | optimized (best) | gain |",
             "|---|---|---|---|"]
    for cell, fr in by_cell.items():
        lines.append(f"| {cell} | {fr[0]:.3f} | {max(fr):.3f} "
                     f"| **{max(fr)/fr[0]:.1f}×** |")
    return "\n".join(lines)


if __name__ == "__main__":
    text = open("EXPERIMENTS.md").read()
    block = summary() + "\n" + perf_table()
    text = re.sub(r"<!-- PERF_TABLE -->.*?(?=\n### |\Z)",
                  "<!-- PERF_TABLE -->\n" + block + "\n\n",
                  text, flags=re.S)
    open("EXPERIMENTS.md", "w").write(text)
    print(block)
