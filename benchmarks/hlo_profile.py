"""Dry-run 'profiler': group HLO output bytes by op kind for a cell.

No wall-clock on CPU — the lowered IR is the profile.  Output-bytes by op
kind (with while-body ops scaled by an L/2 layer factor when requested)
localizes WHERE the roofline's memory/collective terms come from, which
drives the §Perf hypothesis loop.

Usage: PYTHONPATH=src python -m benchmarks.hlo_profile --arch grok_1_314b \\
           --shape prefill_32k [--top 25]
"""
from __future__ import annotations

import argparse
import re
from collections import defaultdict

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2, "u16": 2,
}
_OP_RE = re.compile(r"=\s+(?:\([^)]*\)|\S+)\s+([a-z][\w-]*)\(")


def op_bytes(line: str) -> int:
    seg = line.split("=", 1)[1] if "=" in line else line
    # take text up to the op call args to capture the result shape(s)
    total = 0
    head = seg[: seg.find("(")] if "(" in seg else seg
    if seg.lstrip().startswith("("):
        head = seg[: seg.find(")") + 1]
    for dt, dims in _SHAPE_RE.findall(head):
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def profile_text(hlo: str, top: int = 25) -> list:
    by_kind = defaultdict(lambda: [0, 0])
    for line in hlo.splitlines():
        ls = line.strip()
        if "=" not in ls or not ls.startswith("%") and not ls.startswith("ROOT"):
            continue
        m = _OP_RE.search(ls)
        if not m:
            continue
        kind = m.group(1)
        if kind in ("parameter", "constant", "tuple", "get-tuple-element"):
            continue
        b = op_bytes(ls)
        by_kind[kind][0] += b
        by_kind[kind][1] += 1
    rows = sorted(by_kind.items(), key=lambda kv: -kv[1][0])[:top]
    return [(k, v[0], v[1]) for k, v in rows]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--moe-impl", type=str, default=None)
    args = ap.parse_args()

    import dataclasses

    from repro.configs import get_config
    from repro.launch.dryrun import run_cell  # sets XLA device flags
    import repro.launch.dryrun as dr
    import jax
    from repro.launch.mesh import make_production_mesh
    from repro.launch import specs as S
    from repro.optim.adamw import OptConfig
    from repro.runtime.clock_runtime import ClockConfig
    from repro.sharding import DEFAULT_RULES, use_mesh_rules
    from repro.shapes import SHAPES

    cfg = get_config(args.arch)
    kw = {"n_layers": args.layers, "scan_layers": False}
    if cfg.is_encdec:
        kw["n_enc_layers"] = args.layers
    if args.moe_impl:
        kw["moe_impl"] = args.moe_impl
    cfg = dataclasses.replace(cfg, **kw)
    rec_holder = {}

    # reuse run_cell but grab the HLO: easiest is to re-lower here
    shape = SHAPES[args.shape]
    mesh = make_production_mesh()
    rules = dict(DEFAULT_RULES)
    opt_cfg = OptConfig(state_dtype="int8" if cfg.param_dtype == "bfloat16" else "float32")
    clock_cfg = ClockConfig()
    with use_mesh_rules(mesh, rules):
        step = S.build_step(cfg, shape, opt_cfg, clock_cfg)
        if shape.kind == "train":
            state = S.abstract_state(cfg, opt_cfg, clock_cfg)
            st_sh = S.state_shardings(mesh, rules, cfg, state)
            bspecs = S.batch_specs(cfg, shape)
            b_sh = S.batch_shardings(mesh, bspecs)
            lowered = jax.jit(step, in_shardings=(st_sh, b_sh),
                              out_shardings=(st_sh, None),
                              donate_argnums=(0,)).lower(state, bspecs)
        elif shape.kind == "prefill":
            params = S.abstract_params_dict(cfg)
            p_sh = S.params_shardings(mesh, rules, cfg)
            bspecs = S.batch_specs(cfg, shape)
            b_sh = S.batch_shardings(mesh, bspecs)
            lowered = jax.jit(step, in_shardings=(p_sh, b_sh)).lower(params, bspecs)
        else:
            params = S.abstract_params_dict(cfg)
            p_sh = S.params_shardings(mesh, rules, cfg)
            caches = S.cache_specs(cfg, shape, long_context=(args.shape == "long_500k"))
            c_sh = S.cache_shardings(mesh, rules, caches)
            tok = jax.ShapeDtypeStruct((shape.global_batch,), jax.numpy.int32)
            pos = jax.ShapeDtypeStruct((), jax.numpy.int32)
            t_sh = S.batch_shardings(mesh, {"t": tok})["t"]
            lowered = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh, None),
                              out_shardings=(None, c_sh),
                              donate_argnums=(1,)).lower(params, caches, tok, pos)
        compiled = lowered.compile()
        hlo = compiled.as_text()
    print(f"# {args.arch} x {args.shape} (L={args.layers}, "
          f"moe_impl={cfg.moe_impl if cfg.n_experts else '-'})")
    print(f"# total flops={compiled.cost_analysis()['flops']:.3e} "
          f"bytes={compiled.cost_analysis().get('bytes accessed', 0):.3e}")
    print(f"{'op-kind':28s} {'GB(out)':>12s} {'count':>8s}")
    for kind, b, n in profile_text(hlo, args.top):
        print(f"{kind:28s} {b/1e9:12.2f} {n:8d}")


if __name__ == "__main__":
    main()
