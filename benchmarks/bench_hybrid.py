"""Hybrid causality engine headline benchmark: ``BENCH_hybrid.json``.

One measurement, the PR's acceptance demonstration: a seeded
Zipf(1.1)-skewed churn workload at an EQUAL declared fp budget, served
two ways:

  pure-bloom   every session is a packed bloom row.  The budget is
               binding at the smallest peer sum Σp — the tiny-history
               hot sessions — so inverting paper Eq. 3 pins the whole
               slab to a huge ``m_pure``;
  hybrid       ``HybridEngine`` serves those tiny sessions EXACTLY
               (fp ≡ 0, no cells at all) and only the long tail — whose
               smallest Σp is orders of magnitude larger — constrains
               the bloom geometry, so the same budget derives a much
               smaller ``m_tail``.

Same budget, ~``m_pure / m_tail`` less device work per classify: the
hybrid fused sweep must come out ≥ 2x faster, with zero false
negatives overall, measured fp = 0 on hot-set verdicts (not just
claimed), tail verdicts bit-identical to a flat packed slab at the
same blocks, and a mid-run ``AdaptivePolicy`` (m, k) resize that
replays bit-for-bit from the audit trail.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_hybrid           # full
  PYTHONPATH=src python -m benchmarks.bench_hybrid --quick   # CI smoke
  PYTHONPATH=src python -m benchmarks.bench_hybrid --quick \
      --check-against BENCH_hybrid.json --check-tolerance 0.5
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_fleet import _rec, check_against
from repro.causal.engine import PackedSlab
from repro.core import clock as bc
from repro.core.hashing import bloom_indices, stable_event_id
from repro.hybrid import (AdaptiveConfig, AdaptivePolicy, HybridConfig,
                          HybridEngine, derive_mk, replay_resize)
from repro.obs.audit import AuditTrail


@dataclasses.dataclass(frozen=True)
class BenchCfg:
    label: str
    V: int                  # local chain length (Σq = k·V)
    n_hot: int              # tiny-history sessions (the Zipf head)
    n_tail: int             # long-history sessions (the tail)
    tail_v_min: int = 64    # smallest tail prefix: the budget's binding Σp
    k: int = 4
    fp_budget: float = 1e-4
    seed: int = 0
    churn_rounds: int = 6   # classify rounds after the policy attaches
    draws_per_round: int = 2048
    reps: int = 5           # timed classifies per side

    @property
    def n(self) -> int:
        return self.n_hot + self.n_tail


QUICK = BenchCfg("quick", V=192, n_hot=24, n_tail=232, reps=3,
                 draws_per_round=1024)
FULL = BenchCfg("full", V=384, n_hot=48, n_tail=464)


def _population(cfg: BenchCfg, rng) -> list:
    """(sid, v, events) per session, Zipf-popularity order: the tiny
    sessions come first (ranks 0..n_hot-1), the tail after."""
    pop = []
    # one exactly-equal session (v == V, no private events) for coverage
    pop.append(("hot/0", cfg.V, ()))
    for i in range(1, cfg.n_hot):
        v = int(rng.integers(1, 9))
        npriv = int(rng.integers(0, 3))
        ev = tuple(stable_event_id(b"hybrid/bench-priv", i, j)
                   for j in range(npriv))
        pop.append((f"hot/{i}", v, ev))
    # the first tail row sits exactly at the binding operating point
    pop.append(("tail/0", cfg.tail_v_min, ()))
    for i in range(1, cfg.n_tail):
        v = int(rng.integers(cfg.tail_v_min, cfg.V))
        npriv = int(rng.integers(0, 3))
        ev = tuple(stable_event_id(b"hybrid/bench-priv", cfg.n_hot + i, j)
                   for j in range(npriv))
        pop.append((f"tail/{i}", v, ev))
    return pop


def _truth(V: int, v: int, n_private: int) -> tuple[bool, bool]:
    """Ground-truth (query ≼ peer, peer ≼ query) for a session that is a
    v-long prefix of the V-long local chain plus private events."""
    return v >= V, n_private == 0


def _verify_view(view, pop, V: int) -> dict:
    """Count fn / measured-fp violations of one classify against ground
    truth.  Bloom claims may only err one way (fp); the hot rows may
    not err at all."""
    out = {"fn": 0, "hot_fp": 0, "tail_fp": 0, "hot_claimed_max": 0.0,
           "tail_claimed_max": 0.0}
    by_sid = {sid: (v, len(ev)) for sid, v, ev in pop}
    for i, sid in enumerate(view.sids):
        v, npriv = by_sid[sid]
        t_le, t_ge = _truth(V, v, npriv)
        le, ge = bool(view.q_le_p[i]), bool(view.p_le_q[i])
        if (t_le and not le) or (t_ge and not ge):
            out["fn"] += 1
        fp_measured = int((le and not t_le) or (ge and not t_ge))
        claimed = max(float(view.fp_q_before_p[i]),
                      float(view.fp_p_before_q[i]))
        if view.hot[i]:
            out["hot_fp"] += fp_measured
            out["hot_claimed_max"] = max(out["hot_claimed_max"], claimed)
        else:
            out["tail_fp"] += fp_measured
            out["tail_claimed_max"] = max(out["tail_claimed_max"], claimed)
    return out


def _merge(acc: dict, one: dict) -> None:
    acc["fn"] += one["fn"]
    acc["hot_fp"] += one["hot_fp"]
    acc["tail_fp"] += one["tail_fp"]
    acc["hot_claimed_max"] = max(acc["hot_claimed_max"],
                                 one["hot_claimed_max"])
    acc["tail_claimed_max"] = max(acc["tail_claimed_max"],
                                  one["tail_claimed_max"])


def _pure_slab(cfg: BenchCfg, m_pure: int, chain, pop):
    """Mint the whole population as packed bloom rows at ``m_pure`` and
    the full local chain as the query — the pure-bloom baseline that an
    equal fp budget forces without the exact hot set."""
    probes = np.stack([np.asarray(bloom_indices(np.uint32(hi),
                                                np.uint32(lo),
                                                cfg.k, m_pure), np.int64)
                       for hi, lo in chain])
    qcells = np.bincount(probes.ravel(), minlength=m_pure).astype(np.int64)
    u8 = np.zeros((len(pop), m_pure), np.uint8)
    base = np.zeros(len(pop), np.int32)
    for i, (_, v, events) in enumerate(pop):
        cells = np.bincount(probes[:v].ravel(),
                            minlength=m_pure).astype(np.int64)
        for hi, lo in events:
            idx = np.asarray(bloom_indices(np.uint32(hi), np.uint32(lo),
                                           cfg.k, m_pure), np.int64)
            np.add.at(cells, idx, 1)
        b = int(cells.min())
        resid = cells - b
        assert resid.max(initial=0) <= 255, "pure slab overflows u8 pack"
        u8[i] = resid.astype(np.uint8)
        base[i] = b
    query = bc.BloomClock(cells=jnp.asarray(qcells.astype(np.int32)),
                          base=jnp.zeros((), jnp.int32), k=cfg.k)
    return PackedSlab(jnp.asarray(u8), jnp.asarray(base)), query


def run_hybrid_bench(cfg: BenchCfg, records: list | None = None) -> list:
    records = records if records is not None else []
    rng = np.random.default_rng(cfg.seed)
    B = cfg.fp_budget
    pop = _population(cfg, rng)
    sids = [sid for sid, _, _ in pop]
    N, V, k = cfg.n, cfg.V, cfg.k

    # -- equal-budget geometry on both sides (invert Eq. 3) ------------
    sum_q = float(k * V)
    min_p_all = float(k * min(v + len(ev) for _, v, ev in pop))
    min_p_tail = float(k * min(v + len(ev) for sid, v, ev in pop
                               if sid.startswith("tail/")))
    m_pure, _ = derive_mk(B, sum_q, min_p_all, m_max=1 << 22, k=k)
    m_tail, _ = derive_mk(B, sum_q, min_p_tail, m_max=1 << 22, k=k)
    assert m_pure > m_tail, (m_pure, m_tail)
    # start one fold above the derived tail geometry so the adaptive
    # policy performs exactly one audited mid-run resize
    m_start = 2 * m_tail

    trail = AuditTrail(store_frames=True)
    # capacity margin: near-boundary tail sessions may go hot too (and
    # churn among themselves) without ever displacing the tiny head —
    # displacing it would put a tiny Σp back in the tail and (correctly)
    # veto the adaptive shrink
    eng = HybridEngine(
        HybridConfig(m=m_start, k=k, hot_capacity=cfg.n_hot + 8,
                     tail_capacity=1 << (N - 1).bit_length(),
                     promote_after=3, min_residency=0,
                     max_migrations_per_window=1 << 30, window=1 << 30),
        audit=trail)
    eng.advance_local(V)
    chain = [stable_event_id(b"hybrid/local", i) for i in range(V)]
    for sid, v, events in pop:
        eng.admit(sid, v=v, events=events)

    # -- Zipf(1.1) churn: access counters promote the head -------------
    def churn_round():
        z = rng.zipf(1.1, cfg.draws_per_round)
        for i in np.minimum(z - 1, N - 1):
            eng.touch(sids[i])
        # the head is the distribution's mode by construction; a sweep
        # per round compresses what a longer draw would do and keeps its
        # access floor above any single tail session's draw count
        for _ in range(6):
            for sid in sids[:cfg.n_hot]:
                eng.touch(sid)

    acc = {"fn": 0, "hot_fp": 0, "tail_fp": 0, "hot_claimed_max": 0.0,
           "tail_claimed_max": 0.0}
    for _ in range(2):
        churn_round()
        _merge(acc, _verify_view(eng.classify(), pop, V))
    # the Zipf head is the tiny sessions by construction; finish any
    # stragglers the draw missed before handing control to the policy.
    # Sweep the whole head together so its access counts rise in
    # lockstep and the cold tail rows become the swap victims.
    for _ in range(10_000):
        if all(eng.sessions[s].hot for s in sids[:cfg.n_hot]):
            break
        for sid in sids[:cfg.n_hot]:
            eng.touch(sid)
    assert all(eng.sessions[s].hot for s in sids[:cfg.n_hot]), \
        "Zipf head never fully promoted"

    # -- AdaptivePolicy: declared budget, derived geometry --------------
    eng.adaptive = AdaptivePolicy(eng, AdaptiveConfig(fp_budget=B, window=3))
    for _ in range(cfg.churn_rounds):
        churn_round()
        _merge(acc, _verify_view(eng.classify(), pop, V))
    assert eng.resizes == 1, f"expected one adaptive resize, got {eng.resizes}"
    assert eng.m == m_tail, (eng.m, m_tail)
    rep = replay_resize(trail)
    assert rep.ok and rep.matched == rep.checked, rep.summary()

    # -- correctness: zero fn anywhere, zero fp (measured) on the hot set
    assert all(eng.sessions[s].hot for s in sids[:cfg.n_hot]), \
        "churn displaced the Zipf head from the hot set"
    final = _verify_view(eng.classify(), pop, V)
    _merge(acc, final)
    assert acc["fn"] == 0, f"false negatives: {acc}"
    assert acc["hot_fp"] == 0 and acc["hot_claimed_max"] == 0.0, acc
    assert acc["tail_claimed_max"] <= B * 1.01, acc

    # -- tail bit-identity vs a flat packed slab at the SAME blocks ----
    bn, bm = 128, min(m_tail, 512)
    view = eng.classify(bn=bn, bm=bm)
    slab = eng.slab()
    flat = eng.engine.classify(
        eng.local_clock(),
        PackedSlab(slab.cells_u8, slab.base, wide=slab.wide),
        bn=bn, bm=bm)
    H = slab.hot_count
    for name in ("q_le_p", "p_le_q", "fp_q_before_p", "fp_p_before_q",
                 "sum_p"):
        hyb_tail = np.asarray(getattr(view, name))[H:]
        assert np.array_equal(hyb_tail, np.asarray(getattr(flat, name))), \
            f"tail {name} diverged from the flat packed slab"

    # -- timing: equal budget, two engines ------------------------------
    shape = f"n{N}_v{V}_fp{B:g}"
    eng.classify(bn=bn, bm=bm)                       # hybrid warmup
    t0 = time.perf_counter()
    for _ in range(cfg.reps):
        view = eng.classify(bn=bn, bm=bm)            # HybridView is host-
    t_hyb = (time.perf_counter() - t0) / cfg.reps    # side: synced

    pure, query = _pure_slab(cfg, m_pure, chain, pop)

    def pure_classify():
        res = eng.engine.classify(query, pure, bn=bn, bm=min(m_pure, 512))
        jax.block_until_ready(res.q_le_p)
        return res

    res = pure_classify()                            # warmup + sanity
    pq = {sid: (bool(np.asarray(res.q_le_p)[i]),
                bool(np.asarray(res.p_le_q)[i]))
          for i, sid in enumerate(sids)}
    for sid, v, events in pop:
        t_le, t_ge = _truth(V, v, len(events))
        assert (not t_le or pq[sid][0]) and (not t_ge or pq[sid][1]), \
            f"pure-bloom fn on {sid}"
    t0 = time.perf_counter()
    for _ in range(cfg.reps):
        pure_classify()
    t_pure = (time.perf_counter() - t0) / cfg.reps

    speedup = t_pure / t_hyb
    _rec(records, "pure_bloom_classify", shape, t_pure / N,
         policy=f"fp{B:g}", engine="packed")
    _rec(records, "hybrid_classify", shape, t_hyb / N,
         reference="pure_bloom_classify", speedup=speedup,
         policy=f"fp{B:g}", engine=view.engine)
    records.append({
        "op": "hybrid_verify", "shape": shape, "shards": 1,
        "ms": None, "speedup_vs_reference": None, "reference": None,
        "policy": f"fp{B:g}", "engine": view.engine,
        "transport": "verify",          # correctness ledger: never gated
        "digest_bytes": None, "delta_bytes": None, "pushback_bytes": None,
        "m_pure": m_pure, "m_start": m_start, "m_tail": m_tail,
        "hot_rows": H, "tail_rows": N - H,
        "fn_violations": acc["fn"],
        "hot_fp_measured": acc["hot_fp"],
        "hot_fp_claimed_max": acc["hot_claimed_max"],
        "tail_fp_measured": acc["tail_fp"],
        "tail_fp_claimed_max": acc["tail_claimed_max"],
        "promotions": eng.promotions, "demotions": eng.demotions,
        "resizes": eng.resizes,
        "resize_replay": rep.summary(),
        "hot_hit_rate": round(H / N, 4),
    })
    rows = [
        (f"pure_bloom_classify {shape}_m{m_pure}", t_pure / N * 1e6,
         f"{N / t_pure:.0f} rows/s at m={m_pure}"),
        (f"hybrid_classify {shape}_m{m_tail}", t_hyb / N * 1e6,
         f"{N / t_hyb:.0f} rows/s at m={m_tail} (+{H} exact), "
         f"{speedup:.2f}x vs pure bloom"),
    ]
    if speedup < 2.0:
        print(f"# FAIL: hybrid classify only {speedup:.2f}x pure bloom "
              f"(acceptance needs >= 2x)", file=sys.stderr)
        sys.exit(1)
    return rows


def main(argv=None) -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="CI smoke: the quick-shape leg only")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--fp-budget", type=float, default=1e-4)
    p.add_argument("--json", default="BENCH_hybrid.json")
    p.add_argument("--check-against", default=None, metavar="BASELINE",
                   help="compare against a recorded BENCH_hybrid.json "
                        "and exit nonzero if a gated op regressed")
    p.add_argument("--check-tolerance", type=float, default=0.15)
    args = p.parse_args(argv)

    records: list = []
    cfgs = [QUICK] if args.quick else [QUICK, FULL]
    rows = []
    for cfg in cfgs:
        cfg = dataclasses.replace(cfg, seed=args.seed,
                                  fp_budget=args.fp_budget)
        rows += run_hybrid_bench(cfg, records=records)
    print("name,us_per_item,derived")
    for name, us, derived in rows:
        print(f'{name},{us:.2f},"{derived}"')
    with open(args.json, "w") as f:
        json.dump({"backend": jax.default_backend(),
                   "interpret": jax.default_backend() != "tpu",
                   "records": records}, f, indent=1)
        f.write("\n")
    print(f"# wrote {len(records)} records -> {args.json}")
    if args.check_against:
        failures = check_against(args.check_against, records,
                                 tolerance=args.check_tolerance)
        if failures:
            print(f"# REGRESSION vs {args.check_against}:", file=sys.stderr)
            for line in failures:
                print(f"#   {line}", file=sys.stderr)
            sys.exit(1)
        print(f"# no regressions vs {args.check_against} "
              f"(tolerance {args.check_tolerance:.0%})")


if __name__ == "__main__":
    main()
