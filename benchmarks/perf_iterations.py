"""§Perf hillclimb: hypothesis -> change -> re-lower -> measure cycles on
the three chosen cells.  Results append to reports/perf_iterations.jsonl;
EXPERIMENTS.md §Perf is written from that log.

Cells (chosen per the selection rule):
  - qwen1_5_110b x train_4k     best train roofline frac (0.163), memory-dom
  - grok_1_314b  x prefill_32k  most collective-bound (72.7s coll vs 4.4s comp)
  - deepseek_v2_236b x train_4k paper-representative (flagship MoE arch of the
                                clock-guarded async-DP runtime), frac 0.020

Levers: moe_impl=alltoall (shard_map EP), SP (act_seq -> model),
ce_chunk (seq-chunked CE), attn_acc=bf16, remat policy.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time


def run():
    from benchmarks.bench_roofline import measure_cell, roofline_row
    from repro.configs import get_config
    from repro.sharding import make_rules

    out = "reports/perf_iterations.jsonl"
    os.makedirs("reports", exist_ok=True)
    done = set()
    if os.path.exists(out):
        with open(out) as f:
            done = {json.loads(l)["id"] for l in f}

    def cfgmod(arch, **kw):
        return dataclasses.replace(get_config(arch), **kw)

    ITERS = [
        # id, arch, shape, hypothesis, cfg kwargs, rule overrides
        ("qwen110b_train/V0_baseline", "qwen1_5_110b", "train_4k",
         "baseline (paper-faithful framework defaults)", {}, {}),
        ("qwen110b_train/V1_sp", "qwen1_5_110b", "train_4k",
         "SP (act_seq->model): TP all-reduces become RS+AG pairs and the "
         "saved residual shards 16x -> collective ~2x down, memory down", {},
         {"act_seq": "model"}),
        ("qwen110b_train/V2_sp_cechunk", "qwen1_5_110b", "train_4k",
         "+ce_chunk=1024: never materialize [B,S,V] fp32 logits -> memory "
         "term down by the logit/softmax traffic", {"ce_chunk": 1024},
         {"act_seq": "model"}),
        ("qwen110b_train/V3_sp_ce_bf16acc", "qwen1_5_110b", "train_4k",
         "+attn_acc=bf16: q/k/v casts and flash accumulator at half width "
         "-> convert+multiply bytes down ~2x in attention",
         {"ce_chunk": 1024, "attn_acc": "bf16"}, {"act_seq": "model"}),
        ("qwen110b_train/V4_plus_dots", "qwen1_5_110b", "train_4k",
         "+remat=dots: save matmul outputs instead of recomputing -> bwd "
         "recompute bytes down, peak residency up",
         {"ce_chunk": 1024, "attn_acc": "bf16", "remat_policy": "dots"},
         {"act_seq": "model"}),

        ("grok_prefill/V0_baseline", "grok_1_314b", "prefill_32k",
         "baseline pjit sort-gather MoE (paper-era standard)", {}, {}),
        ("grok_prefill/V1_alltoall", "grok_1_314b", "prefill_32k",
         "shard_map all_to_all EP (tokens sharded dp x model; 8 experts x 2 "
         "physical replicas for a uniform 16-way EP): dispatch all-reduce "
         "(105GB/2L/dev) and gathers replaced by token all_to_all -> "
         "collective >>down. First attempt (tokens sharded over data only) "
         "ran every model column redundantly: compute 4.4->64.7s — refuted, "
         "fixed by sharding tokens over dp+ep before routing.",
         {"moe_impl": "alltoall", "moe_replicas": 2}, {}),
        ("grok_prefill/V2_a2a_sp", "grok_1_314b", "prefill_32k",
         "+SP: shard the 32k-seq residual over model between blocks (also "
         "makes the [B*S,D] token view natively (dp,ep)-sharded -> the "
         "shard_map entry reshard is free)",
         {"moe_impl": "alltoall", "moe_replicas": 2}, {"act_seq": "model"}),
        ("grok_prefill/V3_a2a_sp_bf16", "grok_1_314b", "prefill_32k",
         "+attn_acc=bf16 for the 32k-context attention accumulators",
         {"moe_impl": "alltoall", "moe_replicas": 2, "attn_acc": "bf16"},
         {"act_seq": "model"}),

        ("deepseek_train/V0_baseline", "deepseek_v2_236b", "train_4k",
         "baseline pjit sort-gather MoE", {}, {}),
        ("deepseek_train/V1_alltoall", "deepseek_v2_236b", "train_4k",
         "shard_map all_to_all EP (160 experts / 16-way)",
         {"moe_impl": "alltoall"}, {}),
        ("deepseek_train/V2_a2a_sp_ce", "deepseek_v2_236b", "train_4k",
         "+SP +ce_chunk=1024", {"moe_impl": "alltoall", "ce_chunk": 1024},
         {"act_seq": "model"}),
        ("deepseek_train/V3_a2a_sp_ce_bf16", "deepseek_v2_236b", "train_4k",
         "+attn_acc=bf16 (MLA decompressed attention accumulators)",
         {"moe_impl": "alltoall", "ce_chunk": 1024, "attn_acc": "bf16"},
         {"act_seq": "model"}),
    ]

    with open(out, "a") as f:
        for iid, arch, shape, hyp, ckw, rkw in ITERS:
            if iid in done:
                print(f"[perf] cached {iid}")
                continue
            t0 = time.time()
            try:
                cfg = cfgmod(arch, **ckw)
                rules = make_rules(**rkw)
                meas = measure_cell(arch, shape, rules=rules, cfg_override=cfg)
                row = roofline_row(arch, shape, meas, cfg=cfg)
                rec = {"id": iid, "hypothesis": hyp, "cfg": ckw, "rules": rkw,
                       "roofline": {k: row[k] for k in
                                    ("compute_s", "memory_s", "collective_s",
                                     "dominant", "useful_ratio",
                                     "roofline_frac")},
                       "raw": {"flops": meas["flops"], "bytes": meas["bytes"],
                               "coll": meas["coll"]},
                       "wall_s": round(time.time() - t0, 1)}
            except Exception as e:
                import traceback
                traceback.print_exc()
                rec = {"id": iid, "hypothesis": hyp, "error": str(e)}
            f.write(json.dumps(rec) + "\n")
            f.flush()
            r = rec.get("roofline", {})
            print(f"[perf] {iid}: dom={r.get('dominant')} "
                  f"comp={r.get('compute_s', 0):.2f}s "
                  f"mem={r.get('memory_s', 0):.2f}s "
                  f"coll={r.get('collective_s', 0):.2f}s "
                  f"frac={r.get('roofline_frac', 0):.4f}")


if __name__ == "__main__":
    run()
