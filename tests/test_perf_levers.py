"""Correctness tests for the §Perf optimization levers: every optimized
path must agree with the baseline it replaces."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.params import init_params
from repro.models import transformer as T
from repro.optim.adamw import OptConfig
from repro.runtime.clock_runtime import ClockConfig
from repro.runtime.training import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def test_chunked_ce_matches_monolithic():
    cfg = dataclasses.replace(get_smoke_config("qwen1_5_0_5b"), dtype="float32")
    opt, ck = OptConfig(total_steps=5), ClockConfig(m=64)
    state = init_train_state(KEY, cfg, opt, ck)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 33), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens,
             "ev_hi": jnp.uint32(0), "ev_lo": jnp.uint32(1)}
    s1, m1 = jax.jit(make_train_step(cfg, opt, ck))(state, batch)
    cfg2 = dataclasses.replace(cfg, ce_chunk=8)
    s2, m2 = jax.jit(make_train_step(cfg2, opt, ck))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for k in list(state.params)[:4]:
        np.testing.assert_allclose(np.asarray(s1.params[k]),
                                   np.asarray(s2.params[k]),
                                   rtol=2e-4, atol=1e-5)


def test_bf16_attention_acc_close_to_f32():
    cfg = dataclasses.replace(get_smoke_config("stablelm_1_6b"))
    params = init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (2, 32), 0, cfg.vocab)
    l32, _ = T.forward_train(params, cfg, tokens)
    cfgb = dataclasses.replace(cfg, attn_acc="bf16")
    lb, _ = T.forward_train(params, cfgb, tokens)
    # same model, reduced-precision accumulate: logits track within bf16 noise
    np.testing.assert_allclose(np.asarray(l32, np.float32),
                               np.asarray(lb, np.float32), rtol=0.1, atol=0.15)


def test_remat_policy_preserves_values():
    cfg = dataclasses.replace(get_smoke_config("qwen1_5_0_5b"),
                              dtype="float32", scan_layers=True)
    opt, ck = OptConfig(total_steps=5), ClockConfig(m=64)
    state = init_train_state(KEY, cfg, opt, ck)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens,
             "ev_hi": jnp.uint32(0), "ev_lo": jnp.uint32(1)}
    outs = {}
    for pol in ("nothing", "dots", "full"):
        c = dataclasses.replace(cfg, remat_policy=pol)
        _, m = jax.jit(make_train_step(c, opt, ck))(state, batch)
        outs[pol] = float(m["loss"])
    assert outs["nothing"] == pytest.approx(outs["dots"], rel=1e-6)
    assert outs["nothing"] == pytest.approx(outs["full"], rel=1e-6)


def test_scan_vs_unrolled_same_loss():
    cfg = dataclasses.replace(get_smoke_config("qwen1_5_0_5b"), dtype="float32")
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    params_scan = init_params(KEY, cfg)
    l1, _ = T.forward_train(params_scan, cfg, tokens)
    # unrolled layout stores per-layer params under layers_i/
    cfg_u = dataclasses.replace(cfg, scan_layers=False)
    params_u = {}
    for k, v in params_scan.items():
        if k.startswith("layers/"):
            for i in range(cfg.n_layers):
                params_u[f"layers_{i}/{k[len('layers/'):]}"] = v[i]
        else:
            params_u[k] = v
    l2, _ = T.forward_train(params_u, cfg_u, tokens)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-5, atol=2e-5)


_MOE_AGREE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, jax, jax.numpy as jnp, numpy as np
import sys; sys.path.insert(0, "src")
from repro.configs import get_smoke_config
from repro.models.params import init_params
from repro.models import transformer as T
from repro.sharding import use_mesh_rules, make_rules

mesh = jax.make_mesh((2, 2), ("data", "model"))
cfg = dataclasses.replace(get_smoke_config("grok_1_314b"), dtype="float32",
                          capacity_factor=64.0)
params = init_params(jax.random.PRNGKey(0), cfg)
tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
with use_mesh_rules(mesh, make_rules()):
    lg, _ = jax.jit(lambda p, t: T.forward_train(p, cfg, t))(params, tokens)
cfg2 = dataclasses.replace(cfg, moe_impl="alltoall")
with use_mesh_rules(mesh, make_rules()):
    la, _ = jax.jit(lambda p, t: T.forward_train(p, cfg2, t))(params, tokens)
np.testing.assert_allclose(np.asarray(lg), np.asarray(la), rtol=1e-3, atol=1e-3)
print("AGREE")
"""


def test_moe_alltoall_agrees_with_gather_subprocess():
    """The shard_map all_to_all MoE == pjit gather MoE (no capacity drops).

    Runs in a subprocess because it needs its OWN forced host device
    count (the suite-wide conftest forces 8; this script pins 4 via its
    own XLA_FLAGS before jax initializes in the child process)."""
    r = subprocess.run([sys.executable, "-c", _MOE_AGREE],
                       capture_output=True, text=True, cwd=os.path.dirname(
                           os.path.dirname(os.path.abspath(__file__))))
    assert "AGREE" in r.stdout, r.stderr[-2000:]
