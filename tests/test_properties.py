"""Property-based tests (hypothesis) for the system's core invariants.

The load-bearing one is the paper's §3 guarantee: **no false negatives** —
if execution order truly holds, cell-wise dominance ALWAYS holds; the
bloom clock can over-claim order but never miss it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# optional dev dependency (pip install -e ".[dev]"): skip cleanly instead of
# aborting the whole collection when it isn't in the environment
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import clock as bc
from repro.core import vector_clock as vc
from repro.core.sim import SimConfig, run_sim

_settings = settings(max_examples=40, deadline=None)


def _tick_seq(c, events):
    for e in events:
        c = bc.tick(c, jnp.uint32(e >> 32), jnp.uint32(e & 0xFFFFFFFF))
    return c


@_settings
@given(
    m=st.sampled_from([8, 64, 129]),
    k=st.integers(1, 6),
    events=st.lists(st.integers(0, 2**40), min_size=0, max_size=30),
    extra=st.lists(st.integers(0, 2**40), min_size=1, max_size=10),
)
def test_no_false_negatives_prefix(m, k, events, extra):
    """A clock is always ≼ any of its causal descendants."""
    a = _tick_seq(bc.zeros(m, k), events)
    b = _tick_seq(a, extra)
    o = bc.ordering(a, b)
    assert bool(o.a_le_b)
    assert not bool(o.concurrent)


@_settings
@given(
    m=st.sampled_from([16, 64]),
    k=st.integers(1, 4),
    ev_a=st.lists(st.integers(0, 2**40), min_size=0, max_size=20),
    ev_b=st.lists(st.integers(0, 2**40), min_size=0, max_size=20),
)
def test_merge_is_lub(m, k, ev_a, ev_b):
    """merge = least upper bound: dominates both, minimal cell-wise."""
    a = _tick_seq(bc.zeros(m, k), ev_a)
    b = _tick_seq(bc.zeros(m, k), ev_b)
    mg = bc.merge(a, b)
    assert bool(bc.ordering(a, mg).a_le_b)
    assert bool(bc.ordering(b, mg).a_le_b)
    lub = jnp.maximum(a.logical_cells(), b.logical_cells())
    assert bool(jnp.all(mg.logical_cells() == lub))


@_settings
@given(
    m=st.sampled_from([16, 64]),
    k=st.integers(1, 4),
    ev=st.lists(st.integers(0, 2**40), min_size=1, max_size=25),
)
def test_compress_roundtrip(m, k, ev):
    c = _tick_seq(bc.zeros(m, k), ev)
    z = bc.compress(c)
    assert int(jnp.min(z.cells)) == 0
    assert bool(jnp.all(z.logical_cells() == c.logical_cells()))


@_settings
@given(
    sum_a=st.integers(0, 10_000),
    gap=st.integers(0, 10_000),
    m=st.sampled_from([6, 64, 1024]),
)
def test_fp_rate_bounds_and_monotonicity(sum_a, gap, m):
    fp = float(bc.fp_rate(sum_a, sum_a + gap, m))
    assert 0.0 <= fp <= 1.0
    fp_bigger_gap = float(bc.fp_rate(sum_a, sum_a + gap + 100, m))
    assert fp_bigger_gap >= fp - 1e-6


@_settings
@given(
    merges=st.lists(
        st.tuples(st.integers(0, 2), st.integers(0, 2)), min_size=1, max_size=8
    )
)
def test_merge_commutative_associative(merges):
    m, k = 32, 3
    clocks = [_tick_seq(bc.zeros(m, k), [i * 7 + j for j in range(3)])
              for i in range(3)]
    for i, j in merges:
        ab = bc.merge(clocks[i], clocks[j])
        ba = bc.merge(clocks[j], clocks[i])
        assert bool(jnp.all(ab.logical_cells() == ba.logical_cells()))
    abc1 = bc.merge(bc.merge(clocks[0], clocks[1]), clocks[2])
    abc2 = bc.merge(clocks[0], bc.merge(clocks[1], clocks[2]))
    assert bool(jnp.all(abc1.logical_cells() == abc2.logical_cells()))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_simulator_no_false_negatives(seed):
    """End-to-end protocol property: across random executions with drops
    and delays, the bloom clock NEVER misses a true ordering (§3)."""
    r = run_sim(SimConfig(n_nodes=6, n_events=150, m=32, k=3, seed=seed,
                          sample_pairs=1500))
    assert r.false_negatives == 0


@settings(max_examples=15, deadline=None)
@given(
    peer_events=st.lists(
        st.lists(st.integers(0, 2**40), min_size=0, max_size=12),
        min_size=1, max_size=6),
    local_events=st.lists(st.integers(0, 2**40), min_size=0, max_size=12),
)
def test_registry_classify_matches_pairwise_compare(peer_events, local_events):
    """Fleet invariant: one batched classify_all agrees with per-peer
    compare() for every peer, and the cached sums track the cells."""
    from repro.fleet import ANCESTOR, DESCENDANT, FORKED, SAME, ClockRegistry

    m, k = 64, 3
    local = _tick_seq(bc.zeros(m, k), local_events)
    reg = ClockRegistry(capacity=8, m=m, k=k)
    reg.admit_many({i: _tick_seq(bc.zeros(m, k), evs)
                    for i, evs in enumerate(peer_events)})
    np.testing.assert_allclose(
        np.asarray(reg.sums), np.asarray(jnp.sum(reg.cells, axis=1)))
    view = reg.classify_all(local)
    for i in range(len(peer_events)):
        o = bc.ordering(reg.get(i), local)
        want = (SAME if bool(o.equal) else
                ANCESTOR if bool(o.a_le_b) else
                DESCENDANT if bool(o.b_le_a) else FORKED)
        assert int(view.status[reg.slot_of(i)]) == want


@settings(max_examples=15, deadline=None)
@given(
    peer_events=st.lists(
        st.lists(st.integers(0, 2**40), min_size=0, max_size=10),
        min_size=1, max_size=5),
    local_events=st.lists(st.integers(0, 2**40), min_size=0, max_size=10),
)
def test_gossip_merge_is_fleet_lub(peer_events, local_events):
    """Gossip invariant: the merged clock dominates the local clock and
    every accepted peer, and never absorbs a quarantined (forked) peer's
    unilateral events beyond what accepted peers supplied."""
    from repro.causal import CausalPolicy
    from repro.fleet import ClockRegistry, GossipConfig, gossip_round

    m, k = 64, 3
    local = _tick_seq(bc.zeros(m, k), local_events)
    reg = ClockRegistry(capacity=8, m=m, k=k)
    peers = {i: _tick_seq(bc.zeros(m, k), evs)
             for i, evs in enumerate(peer_events)}
    reg.admit_many(peers)
    merged, report = gossip_round(
        reg, local, GossipConfig(policy=CausalPolicy(fp_threshold=1.0),
                                 push_back=False))
    assert bool(bc.ordering(local, merged).a_le_b)
    lub = local.logical_cells()
    for i, p in peers.items():
        if report.accepted[reg.slot_of(i)]:
            assert bool(bc.ordering(p, merged).a_le_b)
            lub = jnp.maximum(lub, p.logical_cells())
    # merged == lub(local, accepted): nothing extra leaked in
    assert bool(jnp.all(merged.logical_cells() == lub))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_vector_clock_ground_truth_consistency(seed):
    """The vector clock in the same sim is exact: every bloom 'concurrent'
    verdict must be truly concurrent (bloom never under-claims)."""
    r = run_sim(SimConfig(n_nodes=5, n_events=120, m=64, k=4, seed=seed,
                          sample_pairs=1000))
    # with m=64 >> events, fp should be small but non-negative
    assert 0.0 <= r.measured_fp_rate <= 0.2
