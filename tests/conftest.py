import jax
import pytest

# smoke tests / benches must see ONE device (the dry-run sets its own
# XLA_FLAGS in-process before importing jax — never here).
jax.config.update("jax_platforms", "cpu")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
