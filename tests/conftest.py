import os

# The sharded-fleet harness (tests/test_sharded_fleet.py) shard_maps the
# registry kernels over a mesh, which needs multiple devices — and on
# the CPU host platform they must be forced BEFORE jax initializes its
# backend, so this happens at conftest import, not in a fixture body.
# 8 forced host devices are harmless for the single-device tests
# (unsharded work runs on device 0); the dry-run sets its own XLA_FLAGS
# in its own process and never inherits these.
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=8"
    ).strip()

import jax
import pytest

# smoke tests / benches must see the CPU platform regardless of build.
jax.config.update("jax_platforms", "cpu")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def host_devices():
    """The forced 8-device host platform the shard_map tests run on."""
    devs = jax.devices()
    if len(devs) < 8:
        pytest.skip(
            f"needs 8 forced host devices, have {len(devs)} "
            "(jax initialized before conftest set XLA_FLAGS?)")
    return devs
