"""Observability subsystem: spans, metrics, audit replay, monitor.

Covers the ``repro.obs`` package plus its integration points — the
instrumented anti-entropy session, the socket transport's
skip-and-report behavior for unreachable peers, the scipy-backed
``fork_components``, and the ``mean_strict_fp`` rename regression.

The histogram-merge and span-nesting property tests need ``hypothesis``
(installed in CI); they skip cleanly where it is absent.
"""
import json
import math
import os
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.causal import CausalPolicy
from repro.core import clock as bc
from repro.core.sim import SimConfig, run_gossip_sim
from repro.fleet import ClockRegistry, GossipConfig, fleet_health
from repro.fleet.monitor import (FleetHealth, _fork_components_py,
                                 fork_components, record_health, watch)
from repro.fleet.transport import (ClockNode, ClockPeerServer,
                                   LoopbackTransport, SocketTransport)
from repro.fleet.transport.session import anti_entropy_session
from repro.obs import (NULL_OBSERVER, AuditTrail, FP_LOG10_EDGES, Histogram,
                       MetricsRecorder, NullRecorder, Observer, Tracer,
                       resolve)
from repro.obs import export as obs_export

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # hypothesis is a CI-only extra
    HAVE_HYPOTHESIS = False

M, K = 96, 3


def _clock(row) -> bc.BloomClock:
    return bc.BloomClock(jnp.asarray(row, jnp.int32),
                         jnp.zeros((), jnp.int32), K)


def _fleet(n: int, seed: int = 0, m: int = M) -> dict:
    rng = np.random.default_rng(seed)
    return {f"peer{i}": _clock(rng.integers(0, 25, m)) for i in range(n)}


def _dominating(peers, m: int = M) -> bc.BloomClock:
    cells = np.max([np.asarray(c.logical_cells()) for c in peers.values()],
                   axis=0)
    return _clock(cells + 1)


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_tracer_nesting_and_jsonl(tmp_path):
    path = tmp_path / "trace.jsonl"
    tr = Tracer(path)
    with tr.span("outer", transport="loopback") as outer:
        with tr.span("inner") as inner:
            inner.set(bytes=42)
        with tr.span("inner2", n=jnp.zeros(3)):    # non-scalar attr
            pass
    tr.close()

    evs = tr.events()
    assert [e["name"] for e in evs] == ["inner", "inner2", "outer"]
    by_name = {e["name"]: e for e in evs}
    assert by_name["outer"]["parent"] is None
    assert by_name["inner"]["parent"] == by_name["outer"]["sid"]
    assert by_name["inner"]["attrs"] == {"bytes": 42}
    # jax arrays stringify instead of breaking serialization
    assert isinstance(by_name["inner2"]["attrs"]["n"], str)
    # children are contained in the parent's interval
    for child in ("inner", "inner2"):
        c, p = by_name[child], by_name["outer"]
        assert c["ts_us"] >= p["ts_us"]
        assert c["ts_us"] + c["dur_us"] <= p["ts_us"] + p["dur_us"]

    spans = obs_export.load_spans(path)
    assert [s["name"] for s in spans] == ["inner", "inner2", "outer"]
    chrome = obs_export.to_chrome(spans)
    assert {e["ph"] for e in chrome["traceEvents"]} == {"X"}
    assert len(chrome["traceEvents"]) == 3


def test_tracer_sibling_spans_do_not_nest():
    tr = Tracer()
    with tr.span("a"):
        pass
    with tr.span("b"):
        pass
    a, b = tr.events()
    assert a["parent"] is None and b["parent"] is None
    assert a["sid"] != b["sid"]


def test_tracer_threads_get_independent_stacks():
    tr = Tracer()
    done = threading.Event()

    def worker():
        with tr.span("worker"):
            done.wait(5.0)

    t = threading.Thread(target=worker)
    with tr.span("main"):
        t.start()
        done.set()
        t.join()
    by_name = {e["name"]: e for e in tr.events()}
    # the worker span must NOT claim "main" as parent: stacks are
    # thread-local
    assert by_name["worker"]["parent"] is None


def test_load_spans_rejects_garbage(tmp_path):
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"name": "x"}\n')      # missing sid/ts_us/dur_us
    with pytest.raises(ValueError):
        obs_export.load_spans(bad)
    bad.write_text("not json\n")
    with pytest.raises(ValueError):
        obs_export.load_spans(bad)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_metrics_instruments_and_labels():
    rec = MetricsRecorder()
    rec.counter("bytes", phase="digest").inc(10)
    rec.counter("bytes", phase="digest").inc(5)
    rec.counter("bytes", phase="delta").inc(7)
    rec.gauge("occupancy").set(3)
    rec.histogram("fp").observe(1e-6)
    assert rec.counter("bytes", phase="digest").value == 15
    assert rec.counter("bytes", phase="delta").value == 7
    dump = rec.dump()
    assert {(d["kind"], d["name"], tuple(sorted(d["labels"].items())))
            for d in dump} == {
        ("counter", "bytes", (("phase", "digest"),)),
        ("counter", "bytes", (("phase", "delta"),)),
        ("gauge", "occupancy", ()),
        ("histogram", "fp", ()),
    }


def test_histogram_scalar_matches_vector_path():
    vals = [0.0, 1.0, 1e-31, 1e-6, 0.5, 10.0 ** FP_LOG10_EDGES[4]]
    h1, h2 = Histogram(), Histogram()
    h1.observe_many(vals)
    for v in vals:
        h2.observe(v)
    assert (h1.counts == h2.counts).all()
    assert h1.count == h2.count == len(vals)
    assert h1.vmin == h2.vmin and h1.vmax == h2.vmax


def test_histogram_add_counts_shape_guard():
    h = Histogram()
    with pytest.raises(ValueError, match="bin mismatch"):
        h.add_counts(np.zeros(5, np.int64))


def test_histogram_merge_rejects_different_edges():
    with pytest.raises(ValueError, match="different edges"):
        Histogram().merge(Histogram(edges=(0.0, 1.0, 2.0)))


def test_recorder_merge_folds_every_kind():
    a, b = MetricsRecorder(), MetricsRecorder()
    a.counter("n").inc(2)
    b.counter("n").inc(3)
    b.gauge("g").set(7)
    a.histogram("h").observe(1e-4)
    b.histogram("h").observe(1e-8)
    a.merge(b)
    assert a.counter("n").value == 5
    assert a.gauge("g").value == 7.0
    assert a.histogram("h").count == 2


def test_null_recorder_is_falsy_noop():
    rec = NullRecorder()
    assert not rec
    rec.counter("x").inc()
    rec.gauge("x").set(1)
    rec.histogram("x").observe(0.5)
    assert rec.dump() == []


if HAVE_HYPOTHESIS:
    _samples = st.lists(
        st.floats(min_value=0.0, max_value=1.0,
                  allow_nan=False, allow_subnormal=False),
        max_size=40)

    @settings(max_examples=40, deadline=None)
    @given(a=_samples, b=_samples)
    def test_histogram_merge_equals_concatenated_stream(a, b):
        """Merging two histograms == one histogram over the concatenated
        samples: counts/count/min/max exact, total to float tolerance."""
        h1, h2, ref = Histogram(), Histogram(), Histogram()
        h1.observe_many(a)
        h2.observe_many(b)
        ref.observe_many(a + b)
        h1.merge(h2)
        assert (h1.counts == ref.counts).all()
        assert h1.count == ref.count
        assert h1.vmin == ref.vmin and h1.vmax == ref.vmax
        assert math.isclose(h1.total, ref.total,
                            rel_tol=1e-12, abs_tol=1e-12)

    _tree = st.recursive(
        st.just([]),
        lambda kids: st.lists(kids, max_size=3),
        max_leaves=12)

    @settings(max_examples=40, deadline=None)
    @given(tree=_tree)
    def test_span_nesting_invariants(tree):
        """For ANY nesting structure: sids unique, every recorded parent
        id was emitted, children are contained in the parent interval,
        and the recorded tree is exactly the one executed."""
        tr = Tracer()
        shape = []

        def run(subtree, out):
            for i, kids in enumerate(subtree):
                entry = (f"s{len(out)}_{i}", [])
                with tr.span(entry[0]):
                    run(kids, entry[1])
                out.append(entry)

        run(tree, shape)
        evs = tr.events()
        sids = [e["sid"] for e in evs]
        assert len(sids) == len(set(sids))
        by_sid = {e["sid"]: e for e in evs}
        children: dict = {}
        for e in evs:
            if e["parent"] is not None:
                assert e["parent"] in by_sid
                p = by_sid[e["parent"]]
                assert e["ts_us"] >= p["ts_us"]
                assert (e["ts_us"] + e["dur_us"]
                        <= p["ts_us"] + p["dur_us"])
            children.setdefault(e["parent"], []).append(e["name"])

        def names(subtree, prefix_out):
            # children of each node, in execution order
            return [entry[0] for entry in prefix_out]

        # roots recorded == top-level spans executed, in order
        if shape:
            assert children.get(None, []) == [entry[0] for entry in shape]


# ---------------------------------------------------------------------------
# observer wiring
# ---------------------------------------------------------------------------

def test_observer_bool_and_resolve(tmp_path):
    assert not Observer()
    assert Observer(trace=Tracer())
    assert resolve(None) is NULL_OBSERVER
    obs = Observer.to_dir(tmp_path / "run")
    assert obs
    with obs.trace.span("x"):
        pass
    obs.audit.record("verdict", "p0", verdict="ancestor")
    obs.close()
    for name in ("trace.jsonl", "metrics.json", "audit.jsonl"):
        assert (tmp_path / "run" / name).exists(), name


def test_policy_label_excludes_observer():
    """The observer rides the policy without perturbing its identity
    label (cache keys, bench records)."""
    plain = CausalPolicy(fp_threshold=1.0)
    riding = CausalPolicy(fp_threshold=1.0, observer=Observer())
    assert plain.label() == riding.label()
    hash(riding)                           # observer keeps policy hashable


def test_session_spans_metrics_and_audit_loopback():
    peers = _fleet(12, seed=1)
    obs = Observer(trace=Tracer(), metrics=MetricsRecorder(),
                   audit=AuditTrail(store_frames=True))
    policy = CausalPolicy(fp_threshold=1.0, observer=obs)
    registry = ClockRegistry(capacity=16, m=M, k=K, policy=policy)
    registry.admit_many(peers)
    local = _dominating(peers)
    cfg = GossipConfig(policy=policy, straggler_gap=np.inf)
    merged, report = anti_entropy_session(
        registry, local, LoopbackTransport(registry), cfg)

    names = [e["name"] for e in obs.trace.events()]
    assert "gossip.session" in names and "gossip.classify" in names
    assert "gossip.union" in names and "registry.admit" in names
    assert "causal.classify" in names
    sess = next(e for e in obs.trace.events()
                if e["name"] == "gossip.session")
    assert sess["attrs"]["accepted"] == 12

    assert obs.metrics.counter("gossip_sessions",
                               transport="loopback").value == 1
    assert obs.metrics.counter("gossip_peers",
                               outcome="accepted").value == 12
    assert obs.metrics.counter("engine_dispatch", verb="classify",
                               engine="packed").value >= 1
    assert obs.metrics.histogram("fp_claimed").count == 12
    assert obs.metrics.gauge("registry_occupancy").value == 12.0

    verdicts = obs.audit.verdicts()
    assert len(verdicts) == 12
    assert all(v.action == "accept" and v.verdict == "ancestor"
               for v in verdicts)
    # frame replay is standalone: exact even after push-back rewrote
    # the registry rows the verdicts were computed from
    rep = obs.audit.replay_frames(policy=CausalPolicy(fp_threshold=1.0))
    assert rep.ok and rep.matched == rep.checked == 12


def test_audit_live_replay_bit_identity():
    """Without push-back the registry rows stay pristine, so the LIVE
    replay path must re-derive every verdict + fp bit-for-bit."""
    peers = _fleet(10, seed=2)
    obs = Observer(audit=AuditTrail())
    policy = CausalPolicy(fp_threshold=1.0, observer=obs)
    registry = ClockRegistry(capacity=16, m=M, k=K, policy=policy)
    registry.admit_many(peers)
    local = _dominating(peers)
    cfg = GossipConfig(policy=policy, straggler_gap=np.inf,
                       push_back=False)
    anti_entropy_session(registry, local, LoopbackTransport(registry), cfg)
    rep = obs.audit.replay(registry, local)
    assert rep.ok and rep.matched == rep.checked == 10
    assert rep.stale == 0 and not rep.mismatches


def test_audit_trail_jsonl_roundtrip(tmp_path):
    path = tmp_path / "audit.jsonl"
    trail = AuditTrail(path, store_frames=True)
    c = _clock(np.arange(M) % 7)
    from repro.core import wire
    frame = wire.encode_clock(bc.to_wire(c))
    rec = trail.record("verdict", "peerX", verdict="ancestor", fp=1e-7,
                       threshold=1e-4, engine="packed", local_crc=123,
                       peer_crc=456, transport="socket",
                       local_frame=frame, peer_frame=frame)
    trail.record("peer_unreachable", "peerY", transport="socket",
                 detail="ConnectionRefusedError: [Errno 111]")
    trail.annotate_truth(rec, True)
    trail.close()

    loaded = AuditTrail.load(path)
    assert len(loaded) == 2
    got = loaded.records[0]
    assert got.peer_id == "peerX" and got.fp == 1e-7
    assert got.local_frame == frame and got.truth_ok is True
    assert loaded.records[1].kind == "peer_unreachable"
    assert loaded.store_frames
    assert loaded.measured_fp_rate() == 0.0
    assert loaded.mean_predicted_fp() == 1e-7


def test_sim_annotates_audit_with_ground_truth():
    obs = Observer(metrics=MetricsRecorder(),
                   audit=AuditTrail(store_frames=True))
    cfg = GossipConfig(
        policy=CausalPolicy(fp_threshold=1.0, observer=obs),
        straggler_gap=np.inf)
    res = run_gossip_sim(SimConfig(n_nodes=6, n_events=120, m=64, k=3,
                                   seed=0), n_rounds=3, gossip_cfg=cfg)
    assert res.false_negatives == 0
    verdicts = obs.audit.verdicts()
    assert verdicts and all(v.truth_ok is not None for v in verdicts)
    # measured fp sits next to predicted, continuously evaluated
    assert obs.audit.measured_fp_rate() is not None
    assert obs.audit.fp_within_band() is True
    assert obs.metrics.gauge("sim_fp_within_band").value == 1.0
    # every sim verdict replays bit-for-bit from its stored frames
    rep = obs.audit.replay_frames(policy=CausalPolicy(fp_threshold=1.0))
    assert rep.ok and rep.matched == rep.checked == len(verdicts)


# ---------------------------------------------------------------------------
# socket transport: skip-and-report unreachable peers
# ---------------------------------------------------------------------------

def test_socket_session_skips_unreachable_peer():
    peers = _fleet(3, seed=3)
    servers, addresses = [], {}
    try:
        for pid, c in peers.items():
            node = ClockNode(pid, M, K)
            node.set_cells(np.asarray(c.logical_cells()))
            server = ClockPeerServer(node).start()
            servers.append(server)
            addresses[pid] = server.address
        dead = "peer1"
        servers[1].stop()                  # peer1's port now refuses

        obs = Observer(metrics=MetricsRecorder(), audit=AuditTrail())
        policy = CausalPolicy(fp_threshold=1.0, observer=obs)
        registry = ClockRegistry(capacity=8, m=M, k=K, policy=policy)
        tp = SocketTransport(addresses, timeout=5.0)
        cfg = GossipConfig(policy=policy, straggler_gap=np.inf)
        local = _dominating(peers)
        merged, report = anti_entropy_session(registry, local, tp, cfg)

        # the session completed WITHOUT the dead peer and says so
        assert report.unreachable == (dead,)
        assert "unreachable=1" in report.summary()
        assert int(report.n_accepted) == 2
        assert dead in tp.unreachable
        assert dead not in registry
        assert obs.metrics.counter("peer_unreachable",
                                   transport="socket").value == 1
        faults = [r for r in obs.audit.records
                  if r.kind == "peer_unreachable"]
        assert [r.peer_id for r in faults] == [dead]
        assert faults[0].detail          # carries the socket error text

        # the NEXT round still works and still reports it
        _, again = anti_entropy_session(registry, local, tp, cfg)
        assert again.unreachable == (dead,)
    finally:
        for server in servers:
            server.stop()


def test_report_unreachable_defaults_empty():
    peers = _fleet(4, seed=4)
    registry = ClockRegistry(capacity=8, m=M, k=K)
    registry.admit_many(peers)
    _, report = anti_entropy_session(
        registry, _dominating(peers), LoopbackTransport(registry),
        GossipConfig(policy=CausalPolicy(fp_threshold=1.0),
                     straggler_gap=np.inf))
    assert report.unreachable == ()
    assert "unreachable" not in report.summary()


# ---------------------------------------------------------------------------
# monitor: scipy components, rename regression, watch()
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(5))
def test_fork_components_scipy_matches_union_find(seed):
    rng = np.random.default_rng(seed)
    n = 24
    comparable = rng.random((n, n)) < 0.08
    comparable |= comparable.T             # symmetric, like le | ge
    np.fill_diagonal(comparable, False)
    alive = rng.random(n) < 0.8
    got_labels, got_n = fork_components(comparable, alive)
    ref_labels, ref_n = _fork_components_py(comparable, alive)
    np.testing.assert_array_equal(got_labels, ref_labels)
    assert got_n == ref_n
    assert (got_labels[~alive] == -1).all()


def test_fork_components_empty_fleet():
    comparable = np.zeros((4, 4), bool)
    labels, n = fork_components(comparable, np.zeros(4, bool))
    assert n == 0 and (labels == -1).all()


def test_mean_strict_fp_zero_when_no_strict_pairs():
    """Regression for the docstring/field mismatch: the value is the
    mean over STRICT ordered pairs only, and must be 0.0 (not nan)
    when none exist — empty fleet and single-clock fleet."""
    empty = ClockRegistry(capacity=8, m=M, k=K)
    h = fleet_health(empty)
    assert h.mean_strict_fp == 0.0 and not math.isnan(h.mean_strict_fp)

    solo = ClockRegistry(capacity=8, m=M, k=K)
    solo.admit_many({"only": _clock(np.arange(M) % 5)})
    h = fleet_health(solo)
    assert h.mean_strict_fp == 0.0
    # back-compat alias stays readable and equal
    assert h.mean_predicted_fp == h.mean_strict_fp
    assert "mean_strict_fp=" in h.summary()


def test_watch_samples_into_observer_metrics():
    peers = _fleet(6, seed=5)
    obs = Observer(metrics=MetricsRecorder())
    registry = ClockRegistry(capacity=8, m=M, k=K)
    registry.admit_many(peers)
    snaps = list(watch(registry, interval=0.0, samples=3, observer=obs))
    assert len(snaps) == 3
    assert all(isinstance(s, FleetHealth) for s in snaps)
    assert obs.metrics.counter("fleet_health_samples").value == 3
    assert obs.metrics.gauge("fleet_alive").value == 6.0
    assert obs.metrics.histogram(
        "fleet_fp",
        edges=tuple(float(e) for e in snaps[0].fp_bin_edges),
    ).count == int(snaps[0].fp_hist.sum()) * 3


def test_record_health_with_null_metrics_is_noop():
    peers = _fleet(4, seed=6)
    registry = ClockRegistry(capacity=8, m=M, k=K)
    registry.admit_many(peers)
    record_health(fleet_health(registry), NullRecorder())   # must not raise
