"""Hybrid causality engine invariants.

The contracts under test:

- **Exact hot rows are exact**: hot-set verdicts equal ground-truth set
  containment with claimed AND measured fp ≡ 0, while tail verdicts
  stay bit-identical to a flat packed slab at the same blocks — the
  hybrid engine is an optimization, not a semantic.
- **Geometry folds are exact**: ``fold_pow2`` equals re-minting at the
  smaller modulus, so ``resize_tail`` changes no verdict and replays
  bit-for-bit from its audit records.
- **Movement is damped**: alternating access at the hot-set boundary
  (hybrid engine AND tiered registry) performs a bounded number of
  representation moves per window instead of thrashing.
- **The exact-row wire frame is adversarial-proof**: truncation, bit
  flips, version skew, trailing garbage all raise, never misparse
  (same absolute contract as tests/test_wire_fuzz.py).
- ``_pow2_bucket`` never pads a batch past the slab it indexes into.
"""
import dataclasses
import struct
import zlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.causal.engine import CausalEngine, PackedSlab
from repro.causal.policy import CausalPolicy
from repro.core import clock as bc
from repro.core import wire
from repro.core.hashing import stable_event_id
from repro.fleet.registry import ClockRegistry, _pow2_bucket
from repro.hybrid import (AdaptiveConfig, AdaptivePolicy, HybridConfig,
                          HybridEngine, HybridSlab, derive_mk, fold_pow2,
                          replay_resize)
from repro.obs.audit import AuditTrail
from repro.serve.tiers import TierConfig, TieredRegistry


def _engine(m=256, V=48, **kw):
    cfg = dict(m=m, k=4, hot_capacity=8, tail_capacity=64,
               promote_after=2, min_residency=0,
               max_migrations_per_window=1 << 30, window=1 << 30)
    cfg.update(kw)
    eng = HybridEngine(HybridConfig(**cfg))
    eng.advance_local(V)
    return eng


def _priv(i, j=0):
    return stable_event_id(b"test/priv", i, j)


# ---------------------------------------------------------------------------
# exact verdicts + tail bit-identity
# ---------------------------------------------------------------------------

def test_hot_verdicts_exact_with_zero_fp():
    eng = _engine(V=32)
    eng.admit("equal", v=32)
    eng.admit("past", v=10)
    eng.admit("conc", v=10, events=[_priv(1)])
    eng.admit("tail", v=20)
    for sid in ("equal", "past", "conc"):
        eng.touch(sid)
        eng.touch(sid)
        assert eng.sessions[sid].hot
    view = eng.classify()
    assert view.verdict_of("equal") == "equal"
    assert view.verdict_of("past") == "ancestor"
    assert view.verdict_of("conc") == "concurrent"
    hot = view.hot
    assert hot.sum() == 3
    np.testing.assert_array_equal(view.fp_q_before_p[hot], 0.0)
    np.testing.assert_array_equal(view.fp_p_before_q[hot], 0.0)
    # the dispatch went through the fused kernel, not a host loop
    assert view.engine.startswith("fused_hot_tail")


def test_tail_bit_identical_to_flat_packed_slab():
    eng = _engine(V=48)
    rng = np.random.default_rng(3)
    for i in range(4):
        eng.admit(f"hot/{i}", v=int(rng.integers(1, 8)))
        eng.touch(f"hot/{i}")
        eng.touch(f"hot/{i}")
    for i in range(20):
        eng.admit(f"tail/{i}", v=int(rng.integers(8, 48)),
                  events=[_priv(i, j) for j in range(rng.integers(0, 3))])
    bn, bm = 8, eng.m
    view = eng.classify(bn=bn, bm=bm)
    slab = eng.slab()
    H = slab.hot_count
    flat = eng.engine.classify(
        eng.local_clock(),
        PackedSlab(slab.cells_u8, slab.base, wide=slab.wide),
        bn=bn, bm=bm)
    for name in ("q_le_p", "p_le_q", "fp_q_before_p", "fp_p_before_q",
                 "sum_p"):
        np.testing.assert_array_equal(
            np.asarray(getattr(view, name))[H:],
            np.asarray(getattr(flat, name)), err_msg=name)


def test_wide_tail_row_overlaid_at_shifted_index():
    # a >255-span tail row rides the int32 side dict; with a hot set in
    # front the overlay index must shift by H in the fused result
    eng = _engine(V=16)
    eng.admit("hot", v=4)
    eng.touch("hot")
    eng.touch("hot")
    eng.admit("narrow", v=8)
    eng.admit("wide", v=2, events=[_priv(9)] * 300)   # one cell count ~300
    assert any(eng._t_wide), "span >255 must take the wide representation"
    view = eng.classify()
    assert view.verdict_of("hot") == "ancestor"
    assert view.verdict_of("narrow") == "ancestor"
    # 300 private events: concurrent with the local chain, and the
    # verdict must come from the overlaid exact row, not a clipped u8
    assert view.verdict_of("wide") == "concurrent"
    assert "+wide_overlay" in view.engine


def test_pairs_hot_hot_block_is_exact():
    eng = _engine(V=24)
    eng.admit("a", v=3)
    eng.admit("b", v=5)
    eng.admit("c", v=3, events=[_priv(7)])
    eng.admit("t", v=20)
    for sid in ("a", "b", "c"):
        eng.touch(sid)
        eng.touch(sid)
    res, order = eng.pairs()
    i = {sid: order.index(sid) for sid in order}
    le = np.asarray(res.le, bool)
    fp = np.asarray(res.fp, np.float32)
    assert le[i["a"], i["b"]] and not le[i["b"], i["a"]]   # prefix order
    assert not le[i["a"], i["c"]] and not le[i["c"], i["a"]] or \
        le[i["a"], i["c"]]  # a ⊆ c: a's prefix is inside c's prefix+priv
    # c has a private event b lacks: c ⋠ b even though v_c <= v_b
    assert not le[i["c"], i["b"]]
    H = 3
    np.testing.assert_array_equal(fp[:H, :H], 0.0)
    assert res.engine.endswith("+hot_exact")


def test_pairs_guard_rejects_hot_slab_on_causal_engine():
    eng = _engine(V=8)
    eng.admit("h", v=2)
    eng.touch("h")
    eng.touch("h")
    eng.admit("t", v=4)
    with pytest.raises(ValueError, match="classify-only"):
        eng.engine.pairs(eng.slab())


def test_demote_re_mints_bit_identically():
    eng = _engine(V=32)
    eng.admit("s", v=13, events=[_priv(0)])
    slot0 = eng.sessions["s"].slot
    row0 = eng._tail_logical(slot0).copy()
    eng.touch("s")
    eng.touch("s")
    assert eng.sessions["s"].hot
    eng.demote("s")
    np.testing.assert_array_equal(
        eng._tail_logical(eng.sessions["s"].slot), row0)


# ---------------------------------------------------------------------------
# exact pow2 folds + fp-budget derivation + audited resize
# ---------------------------------------------------------------------------

def test_fold_pow2_equals_minting_small():
    rng = np.random.default_rng(0)
    idx = rng.integers(0, 1 << 32, 5000)
    for m, new_m in ((512, 128), (256, 256), (1024, 128)):
        minted_big = np.bincount(idx % m, minlength=m)
        minted_small = np.bincount(idx % new_m, minlength=new_m)
        np.testing.assert_array_equal(fold_pow2(minted_big, new_m),
                                      minted_small)
    with pytest.raises(ValueError):
        fold_pow2(np.zeros(512), 96)      # not pow2
    with pytest.raises(ValueError):
        fold_pow2(np.zeros(512), 1024)    # growth is not a fold


def test_derive_mk_respects_budget_and_monotonicity():
    def claimed(m, sq, sp):
        import math
        inner = -math.expm1(sq * math.log1p(-1.0 / m))
        return math.exp(sp * math.log(max(inner, 1e-300)))

    sq = 1024.0
    for budget in (1e-2, 1e-4, 1e-8):
        for sp in (4.0, 64.0, 256.0):
            m, k = derive_mk(budget, sq, sp, m_max=1 << 20, k=4)
            assert claimed(m, sq, sp) <= budget or m == 1 << 20
            assert 1 <= k <= 8
    # smaller budget -> never a smaller m; larger binding Σp -> never larger
    m_loose, _ = derive_mk(1e-2, sq, 64.0, m_max=1 << 20, k=4)
    m_tight, _ = derive_mk(1e-8, sq, 64.0, m_max=1 << 20, k=4)
    assert m_tight >= m_loose
    m_small_p, _ = derive_mk(1e-4, sq, 4.0, m_max=1 << 20, k=4)
    m_big_p, _ = derive_mk(1e-4, sq, 256.0, m_max=1 << 20, k=4)
    assert m_big_p <= m_small_p
    # floor and degenerate operating points
    m, _ = derive_mk(1.0, sq, 256.0, m_max=1 << 20, k=4, m_min=256)
    assert m >= 256
    assert derive_mk(1e-4, sq, 0.0, m_max=512, k=4) == (512, 4)
    with pytest.raises(ValueError):
        derive_mk(0.0, sq, 64.0, m_max=512, k=4)


def test_resize_preserves_verdicts_and_replays_bit_for_bit():
    trail = AuditTrail(store_frames=True)
    eng = HybridEngine(HybridConfig(m=512, k=4, hot_capacity=4,
                                    tail_capacity=32), audit=trail)
    eng.advance_local(64)
    rng = np.random.default_rng(7)
    truth = {}
    for i in range(12):
        v = int(rng.integers(16, 64))
        npriv = int(rng.integers(0, 2))
        eng.admit(f"s{i}", v=v, events=[_priv(i, j) for j in range(npriv)])
        truth[f"s{i}"] = "ancestor" if npriv == 0 else "concurrent"
    before = eng.classify()
    eng.resize_tail(128, detail="test")
    assert eng.m == 128
    # verdicts at the new geometry equal minting there outright (the
    # fold is exact), so like any smaller bloom it may add claimed fps
    # — but it can NEVER lose a true verdict
    after = eng.classify()
    for sid, want in truth.items():
        assert before.verdict_of(sid) == want
        if want == "ancestor":
            assert after.verdict_of(sid) == "ancestor"
        else:
            assert after.verdict_of(sid) in ("concurrent", "ancestor")
    for sid, s in eng.sessions.items():
        np.testing.assert_array_equal(
            eng._tail_logical(s.slot), eng._mint_cells(s),
            err_msg=f"{sid}: fold diverged from minting at new_m")
    rep = replay_resize(trail)
    assert rep.ok and rep.checked == 12 and rep.matched == 12, rep.summary()
    # a tampered audit frame must be caught, not silently replayed
    rec = next(r for r in trail.records if r.kind == "resize_row")
    snap = wire.decode_clock(rec.local_frame)
    snap["cells"] = np.asarray(snap["cells"]).copy()
    snap["cells"][0] += 1
    rec.local_frame = wire.encode_clock(snap)
    assert not replay_resize(trail).ok


def test_adaptive_policy_folds_once_budget_allows():
    eng = _engine(m=512, V=128, hot_capacity=4, promote_after=1)
    eng.admit("tiny", v=1)
    eng.touch("tiny")
    assert eng.sessions["tiny"].hot
    for i in range(6):
        eng.admit(f"t{i}", v=64 + i)
    eng.adaptive = AdaptivePolicy(eng, AdaptiveConfig(fp_budget=1e-4,
                                                      window=2))
    eng.classify()
    assert eng.resizes == 0          # window not closed yet
    eng.classify()
    assert eng.resizes == 1 and eng.m < 512
    assert eng.adaptive.last_recommendation is not None
    # with the tiny-Σp session in the TAIL the same budget must veto
    # any shrink: the binding row pins the geometry
    eng2 = _engine(m=512, V=128, hot_capacity=4)
    eng2.admit("tiny", v=1)
    for i in range(6):
        eng2.admit(f"t{i}", v=64 + i)
    eng2.adaptive = AdaptivePolicy(eng2, AdaptiveConfig(fp_budget=1e-4,
                                                        window=1))
    eng2.classify()
    assert eng2.resizes == 0 and eng2.m == 512


# ---------------------------------------------------------------------------
# hysteresis: bounded representation moves at the hot-set boundary
# ---------------------------------------------------------------------------

def test_hybrid_boundary_thrash_bounded_per_window():
    cap = 4
    eng = _engine(V=16, hot_capacity=1, promote_after=1,
                  min_residency=0, max_migrations_per_window=cap,
                  window=10_000)
    eng.admit("a", v=2)
    eng.admit("b", v=3)
    # escalating alternation: each round the cold session out-touches
    # the hot one, which without a budget would swap representations
    # every single round (2 migrations per swap)
    for r in range(50):
        cold = "b" if eng.sessions["a"].hot else "a"
        for _ in range(r + 2):
            eng.touch(cold)
    assert eng.promotions + eng.demotions <= cap, \
        (eng.promotions, eng.demotions)
    # the engine still classifies correctly after the adversarial churn
    view = eng.classify()
    assert view.verdict_of("a") == "ancestor"
    assert view.verdict_of("b") == "ancestor"


def test_hybrid_min_residency_shields_fresh_promotions():
    eng = _engine(V=16, hot_capacity=1, promote_after=1,
                  min_residency=3, max_migrations_per_window=1 << 30,
                  window=4)
    eng.admit("a", v=2)
    eng.admit("b", v=3)
    eng.touch("a")
    assert eng.sessions["a"].hot and eng.promotions == 1
    promoted_at = eng.sessions["a"].promoted_window
    for _ in range(40):
        eng.touch("b")
        if eng._window_idx - promoted_at < 3:
            assert eng.demotions == 0, \
                "fresh promotion demoted inside its residency window"
    assert eng.demotions >= 1     # immunity expires, movement resumes


def test_tiered_registry_boundary_thrash_bounded():
    m, k = 32, 3
    rng = np.random.default_rng(5)

    def clock():
        return bc.BloomClock(
            cells=jnp.asarray(rng.integers(0, 5, m), jnp.int32),
            base=jnp.zeros((), jnp.int32), k=k)

    budget = 4
    t = TieredRegistry(
        TierConfig(hot_capacity=2, warm_capacity=8, promote_after=1,
                   demote_batch=1, min_residency=16,
                   max_migrations_per_window=budget, window=10_000),
        m=m, k=k)
    t.admit_many({f"s{i}": clock() for i in range(8)})
    # three favorites cycling through a 2-slot hot tier: every touch of
    # whichever is currently cold would promote (evicting another
    # favorite) — unbounded thrash without the per-window budget
    warm = [s for s, tier in t._tier_of.items() if tier != "hot"][:3]
    base_promotions = t.promotions
    for _ in range(50):
        for sid in warm:
            t.touch(sid)
    assert t.promotions - base_promotions <= budget
    assert t.promotion_deferrals > 0, \
        "the migration budget never engaged under alternating access"
    t.close()


def test_tiered_victims_skip_fresh_promotions():
    t = TieredRegistry(
        TierConfig(hot_capacity=4, warm_capacity=8, promote_after=1,
                   demote_batch=1, min_residency=16,
                   max_migrations_per_window=1 << 30, window=1 << 30),
        m=32, k=3)
    rng = np.random.default_rng(6)
    t.admit_many({f"s{i}": bc.BloomClock(
        cells=jnp.asarray(rng.integers(0, 5, 32), jnp.int32),
        base=jnp.zeros((), jnp.int32), k=3) for i in range(4)})
    t.promote("s0")  # no-op if already hot; records residency either way
    t._promoted_at["s0"] = t._age_seq
    victims = t._victims(["s0", "s1", "s2", "s3"], 2)
    assert "s0" not in victims, "fresh promotion must not be first victim"
    # when EVERY candidate is fresh, eviction still proceeds
    for s in ("s1", "s2", "s3"):
        t._promoted_at[s] = t._age_seq
    assert len(t._victims(["s0", "s1", "s2", "s3"], 2)) == 2
    t.close()


# ---------------------------------------------------------------------------
# _pow2_bucket: padded batches never outgrow the slab
# ---------------------------------------------------------------------------

def test_pow2_bucket_clamps_at_capacity():
    assert _pow2_bucket(0) == 0
    assert _pow2_bucket(1) == 1
    assert _pow2_bucket(5) == 8
    assert _pow2_bucket(8) == 8
    assert _pow2_bucket(9) == 16
    # the regression: one past a non-pow2 capacity used to round up to
    # a bucket LARGER than the slab the padded indices scatter into
    for cap in (6, 12, 100):
        assert _pow2_bucket(cap + 1, cap) == cap
        assert _pow2_bucket(cap, cap) <= cap
    assert _pow2_bucket(9, 16) == 16   # clamp only binds at the slab edge


def test_registry_full_capacity_batch_admit():
    cap = 12          # non-pow2: the pre-clamp bucket would be 16
    reg = ClockRegistry(capacity=cap, m=32, k=3,
                        policy=CausalPolicy(fp_threshold=1.0))
    rng = np.random.default_rng(2)
    clocks = {f"s{i}": bc.BloomClock(
        cells=jnp.asarray(rng.integers(0, 5, 32), jnp.int32),
        base=jnp.zeros((), jnp.int32), k=3) for i in range(cap)}
    reg.admit_many(clocks)
    assert len(reg) == cap
    slots = [reg.slot_of(s) for s in clocks]
    assert sorted(slots) == list(range(cap))


# ---------------------------------------------------------------------------
# exact-row wire frames: the same absolute adversarial contract as
# clock frames (tests/test_wire_fuzz.py)
# ---------------------------------------------------------------------------

METAS = {
    "empty": {"v": 0, "events": (), "k": 4},
    "plain": {"v": 7, "events": ((1, 2), (3, 4), (5, 6)), "k": 4},
    "big": {"v": 1 << 40,
            "events": tuple((int(h), int(l)) for h, l in
                            (_priv(i) for i in range(5))), "k": 8},
}


@pytest.mark.parametrize("name", sorted(METAS))
def test_exact_frame_roundtrip(name):
    meta = METAS[name]
    buf = wire.encode_exact(meta)
    assert len(buf) == wire.exact_frame_nbytes(len(meta["events"]))
    got = wire.decode_exact(buf)
    assert got["v"] == meta["v"]
    assert got["k"] == meta["k"]
    assert got["n_private"] == len(meta["events"])
    assert got["events"] == tuple(meta["events"])


def test_exact_frame_rejects_event_count_mismatch():
    with pytest.raises(ValueError, match="disagrees"):
        wire.encode_exact({"v": 1, "n_private": 2, "events": ((1, 2),),
                           "k": 4})


@pytest.mark.parametrize("name", sorted(METAS))
def test_exact_frame_truncation_always_raises(name):
    buf = wire.encode_exact(METAS[name])
    for cut in range(len(buf)):
        with pytest.raises(wire.WireFormatError):
            wire.decode_exact(buf[:cut])


@pytest.mark.parametrize("name", sorted(METAS))
def test_exact_frame_single_bit_flips_never_misparse(name):
    meta = METAS[name]
    buf = bytearray(wire.encode_exact(meta))
    for byte in range(len(buf)):
        for bit in range(8):
            buf[byte] ^= 1 << bit
            try:
                got = wire.decode_exact(bytes(buf))
            except wire.WireFormatError:
                pass
            else:       # a flip may only ever decode to the ORIGINAL
                assert got["v"] == meta["v"]
                assert got["events"] == tuple(meta["events"])
            buf[byte] ^= 1 << bit


def test_exact_frame_version_skew_rejected_even_resealed():
    assert wire.WIRE_VERSION == 2
    buf = bytearray(wire.encode_exact(METAS["plain"]))
    for skew in (-1, 1, 5):
        bad = bytearray(buf)
        bad[2] = (wire.WIRE_VERSION + skew) & 0xFF
        body = bytes(bad[:-4])
        resealed = body + struct.pack("!I", zlib.crc32(body))
        with pytest.raises(wire.WireFormatError, match="version"):
            wire.decode_exact(resealed)


def test_exact_frame_trailing_garbage_rejected():
    buf = wire.encode_exact(METAS["plain"])
    with pytest.raises(wire.WireFormatError, match="oversized"):
        wire.decode_exact(buf + b"\x00")


def test_exact_frame_roundtrips_engine_hot_row():
    eng = _engine(V=12)
    eng.admit("s", v=9, events=[_priv(0), _priv(1)])
    s = eng.sessions["s"]
    frame = wire.encode_exact({"v": s.v, "events": s.events, "k": eng.k})
    got = wire.decode_exact(frame)
    # a receiver re-mints the SAME shadow bloom row from the frame
    clone = dataclasses.replace(s, events=tuple(got["events"]),
                                v=got["v"])
    np.testing.assert_array_equal(eng._mint_cells(clone),
                                  eng._mint_cells(s))
