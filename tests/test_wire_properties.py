"""Hypothesis round-trip properties for the binary wire format.

Separate from tests/test_transport.py so a missing hypothesis skips
ONLY the property sweep (repo idiom, see tests/test_properties.py);
the deterministic wire-robustness tests always run.
"""
import jax.numpy as jnp
import numpy as np
import pytest

# optional dev dependency: skip cleanly instead of aborting collection
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import clock as bc
from repro.core import wire
from repro.fleet import ClockRegistry
from repro.launch.mesh import make_fleet_mesh

_settings = settings(max_examples=40, deadline=None)


@_settings
@given(
    m=st.integers(4, 96),
    base=st.integers(0, 1 << 20),
    hi=st.sampled_from([5, 200, 255, 256, 5000]),   # u8-packed AND promoted
    seed=st.integers(0, 2**31 - 1),
)
def test_wire_roundtrip_property(m, base, hi, seed):
    """encode -> decode is lossless for every §4 representation the
    quantizer can pick, and picks u8 exactly when the window fits."""
    rng = np.random.default_rng(seed)
    cells = rng.integers(0, hi + 1, m)
    c = bc.BloomClock(jnp.asarray(cells, jnp.int32),
                      jnp.asarray(int(base), jnp.int32), 4)
    snap = bc.to_wire(c)
    span = int(cells.max() - cells.min())
    assert (np.asarray(snap["cells"]).dtype == np.uint8) == (span <= 255)
    back = bc.from_wire(wire.encode_clock(snap))
    np.testing.assert_array_equal(np.asarray(back.logical_cells()),
                                  np.asarray(c.logical_cells()))
    # digest content key is invariant across the wire representation
    assert (wire.digest_of("x", np.asarray(c.logical_cells()), 0).crc
            == wire.digest_of("x", snap["cells"], snap["base"]).crc)


@_settings
@given(
    m=st.integers(4, 96),
    hi=st.sampled_from([5, 255, 5000]),             # u8-packed AND promoted
    base=st.integers(-(2**31), 2**31 - 1),          # includes wrapped rim
    seed=st.integers(0, 2**31 - 1),
    mutation=st.sampled_from(
        ["truncate", "flip1", "flip4", "append", "version", "swap"]),
    salt=st.integers(0, 2**31 - 1),
)
def test_wire_mutation_fuzz(m, hi, base, seed, mutation, salt):
    """Hostile-frame property (chaos-harness contract): ANY mutation of
    an encoded clock frame either raises ``WireFormatError`` or decodes
    bit-identically to the original — never to a different clock."""
    rng = np.random.default_rng(seed)
    if hi <= 255:
        cells = rng.integers(0, hi + 1, m).astype(np.uint8)
    else:
        cells = rng.integers(-hi, hi, m).astype(np.int32)
    snap = {"cells": cells, "base": int(base), "k": 4}
    frame = wire.encode_clock(snap)

    mrng = np.random.default_rng(salt)
    buf = bytearray(frame)
    if mutation == "truncate":
        buf = buf[: int(mrng.integers(0, len(buf)))]
    elif mutation in ("flip1", "flip4"):
        for _ in range(1 if mutation == "flip1" else 4):
            buf[int(mrng.integers(0, len(buf)))] ^= 1 << int(
                mrng.integers(0, 8))
    elif mutation == "append":
        buf += bytes(mrng.integers(0, 256, int(mrng.integers(1, 9)),
                                   dtype=np.uint8))
    elif mutation == "version":
        buf[2] = int(mrng.integers(0, 256))
    else:                                            # swap two bytes
        i, j = (int(x) for x in mrng.integers(0, len(buf), 2))
        buf[i], buf[j] = buf[j], buf[i]
    mutated = bytes(buf)

    try:
        got = wire.decode_clock(mutated)
    except wire.WireFormatError:
        return
    assert mutated == frame                          # no-op mutation only
    np.testing.assert_array_equal(got["cells"], cells)
    assert got["base"] == wire._wrap_i32(base)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_wire_roundtrip_across_shard_boundaries(seed):
    """Rows fetched from a mesh-sharded slab (including rows at the
    shard boundary and a promoted int32 row) survive the wire
    unchanged."""
    rng = np.random.default_rng(seed)
    m, shards, cap = 64, 2, 8
    if len(__import__("jax").devices()) < shards:
        pytest.skip("needs forced multi-device host platform")
    reg = ClockRegistry(capacity=cap, m=m, k=3,
                        mesh=make_fleet_mesh(shards))
    rows = {f"p{i}": bc.BloomClock(jnp.asarray(rng.integers(0, 9, m),
                                               jnp.int32),
                                   jnp.zeros((), jnp.int32), 3)
            for i in range(cap)}
    wide = np.zeros(m, np.int64)
    wide[1] = 999
    rows["p5"] = bc.BloomClock(jnp.asarray(wide, jnp.int32),
                               jnp.zeros((), jnp.int32), 3)
    reg.admit_many(rows)
    for pid in rows:
        c = reg.get(pid)
        back = bc.from_wire(wire.encode_clock(bc.to_wire(c)))
        np.testing.assert_array_equal(np.asarray(back.logical_cells()),
                                      np.asarray(c.logical_cells()), pid)
