"""Hypothesis round-trip properties for the binary wire format.

Separate from tests/test_transport.py so a missing hypothesis skips
ONLY the property sweep (repo idiom, see tests/test_properties.py);
the deterministic wire-robustness tests always run.
"""
import jax.numpy as jnp
import numpy as np
import pytest

# optional dev dependency: skip cleanly instead of aborting collection
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import clock as bc
from repro.core import wire
from repro.fleet import ClockRegistry
from repro.launch.mesh import make_fleet_mesh

_settings = settings(max_examples=40, deadline=None)


@_settings
@given(
    m=st.integers(4, 96),
    base=st.integers(0, 1 << 20),
    hi=st.sampled_from([5, 200, 255, 256, 5000]),   # u8-packed AND promoted
    seed=st.integers(0, 2**31 - 1),
)
def test_wire_roundtrip_property(m, base, hi, seed):
    """encode -> decode is lossless for every §4 representation the
    quantizer can pick, and picks u8 exactly when the window fits."""
    rng = np.random.default_rng(seed)
    cells = rng.integers(0, hi + 1, m)
    c = bc.BloomClock(jnp.asarray(cells, jnp.int32),
                      jnp.asarray(int(base), jnp.int32), 4)
    snap = bc.to_wire(c)
    span = int(cells.max() - cells.min())
    assert (np.asarray(snap["cells"]).dtype == np.uint8) == (span <= 255)
    back = bc.from_wire(wire.encode_clock(snap))
    np.testing.assert_array_equal(np.asarray(back.logical_cells()),
                                  np.asarray(c.logical_cells()))
    # digest content key is invariant across the wire representation
    assert (wire.digest_of("x", np.asarray(c.logical_cells()), 0).crc
            == wire.digest_of("x", snap["cells"], snap["base"]).crc)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_wire_roundtrip_across_shard_boundaries(seed):
    """Rows fetched from a mesh-sharded slab (including rows at the
    shard boundary and a promoted int32 row) survive the wire
    unchanged."""
    rng = np.random.default_rng(seed)
    m, shards, cap = 64, 2, 8
    if len(__import__("jax").devices()) < shards:
        pytest.skip("needs forced multi-device host platform")
    reg = ClockRegistry(capacity=cap, m=m, k=3,
                        mesh=make_fleet_mesh(shards))
    rows = {f"p{i}": bc.BloomClock(jnp.asarray(rng.integers(0, 9, m),
                                               jnp.int32),
                                   jnp.zeros((), jnp.int32), 3)
            for i in range(cap)}
    wide = np.zeros(m, np.int64)
    wide[1] = 999
    rows["p5"] = bc.BloomClock(jnp.asarray(wide, jnp.int32),
                               jnp.zeros((), jnp.int32), 3)
    reg.admit_many(rows)
    for pid in rows:
        c = reg.get(pid)
        back = bc.from_wire(wire.encode_clock(bc.to_wire(c)))
        np.testing.assert_array_equal(np.asarray(back.logical_cells()),
                                      np.asarray(c.logical_cells()), pid)
