"""Fleet subsystem validation: matrix kernels vs the broadcast reference,
registry/gossip/monitor behavior, and sim-driven gossip scoring.

Kernels run with interpret=True on CPU (dispatched automatically by
``kernels.ops``); flag matrices must be bit-exact against
``comparability_matrix``, fp rates within 1e-6.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clock as bc
from repro.core.sim import SimConfig, run_gossip_sim
from repro.fleet import (
    ANCESTOR,
    DEAD,
    DESCENDANT,
    FORKED,
    SAME,
    ClockRegistry,
    GossipConfig,
    fleet_health,
    gossip_round,
)
from repro import causal
from repro.kernels import ops  # noqa: F401 (impl spies elsewhere)

RNG = np.random.default_rng(7)


def _cells(n, m, hi=20):
    return jnp.asarray(RNG.integers(0, hi, (n, m)), jnp.int32)


def _clock_from(row) -> bc.BloomClock:
    return bc.BloomClock(jnp.asarray(row, jnp.int32), jnp.zeros((), jnp.int32), 3)


def _ticked(c, events):
    for e in events:
        c = bc.tick(c, jnp.uint32(e >> 32), jnp.uint32(e & 0xFFFFFFFF))
    return c


# ---------------------------------------------------------------------------
# matrix kernel vs broadcast reference (ragged shapes)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m", [
    (5, 300),      # N not a tile multiple, m needs lane padding
    (16, 64),      # m below one lane
    (33, 129),     # both ragged
    (8, 512),      # aligned
    (130, 1000),   # N above one col tile, m needs padding
])
def test_compare_matrix_matches_broadcast_reference(n, m):
    cells = _cells(n, m)
    # inject ordered/equal structure so every flag kind is exercised
    cells = cells.at[1].set(cells[0])
    if n > 2:
        cells = cells.at[2].set(cells[0] + 1)
    clocks = bc.BloomClock(cells, jnp.zeros((n,), jnp.int32), 3)
    ref = bc.comparability_matrix(clocks)
    got = causal.CausalEngine().pairs(cells)
    np.testing.assert_array_equal(np.asarray(got["a_le_b"]),
                                  np.asarray(ref["a_le_b"]))
    np.testing.assert_array_equal(np.asarray(got["concurrent"]),
                                  np.asarray(ref["concurrent"]))
    np.testing.assert_allclose(np.asarray(got["fp"]), np.asarray(ref["fp"]),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(got["row_sums"]),
                               np.asarray(jnp.sum(cells, axis=1)))


@pytest.mark.parametrize("n,m", [(5, 300), (33, 129), (17, 512)])
def test_classify_vs_many_matches_pairwise(n, m):
    cells = _cells(n, m)
    cells = cells.at[1].set(cells[0])
    q = cells[0]
    got = causal.CausalEngine().classify(q, cells)
    clocks = bc.BloomClock(cells, jnp.zeros((n,), jnp.int32), 3)
    qc = bc.BloomClock(q, jnp.zeros((), jnp.int32), 3)
    o = bc.ordering(qc, clocks)     # broadcast pairwise reference
    np.testing.assert_array_equal(np.asarray(got["q_le_p"]), np.asarray(o.a_le_b))
    np.testing.assert_array_equal(np.asarray(got["p_le_q"]), np.asarray(o.b_le_a))
    np.testing.assert_allclose(np.asarray(got["fp_q_before_p"]),
                               np.asarray(o.fp_a_before_b), atol=1e-6)
    np.testing.assert_allclose(np.asarray(got["fp_p_before_q"]),
                               np.asarray(o.fp_b_before_a), atol=1e-6)


def test_matrix_kernel_multi_tile_accumulation():
    """Dominance violated ONLY in the last m-tile / last rows: catches
    bad cross-tile accumulation and bad ragged-row handling."""
    n, m = 9, 1000     # pads to 1024 cells, 16 rows
    a = jnp.zeros((n, m), jnp.int32)
    a = a.at[0, m - 1].set(5)              # row 0 beats everyone, last tile
    got = causal.CausalEngine().pairs(a)
    le = np.asarray(got["a_le_b"])
    assert not le[0, 1] and le[1, 0]       # 0 !<= 1 but 1 <= 0
    assert float(np.asarray(got["row_sums"])[0]) == 5.0


# ---------------------------------------------------------------------------
# registry invariants
# ---------------------------------------------------------------------------

def _seeded_registry(m=128, k=3):
    local = _ticked(bc.zeros(m, k), range(20))
    reg = ClockRegistry(capacity=8, m=m, k=k)
    reg.admit_many({
        "anc": _ticked(bc.zeros(m, k), range(10)),      # prefix of local
        "same": local,
        "desc": _ticked(local, range(100, 105)),
        "fork": _ticked(bc.zeros(m, k), range(500, 515)),
    })
    return reg, local


def test_registry_classify_all_statuses():
    reg, local = _seeded_registry()
    view = reg.classify_all(local)
    assert view.status[reg.slot_of("anc")] == ANCESTOR
    assert view.status[reg.slot_of("same")] == SAME
    assert view.status[reg.slot_of("desc")] == DESCENDANT
    assert view.status[reg.slot_of("fork")] == FORKED
    assert (view.status[~view.alive] == DEAD).all()
    # exact verdicts carry fp 0; probabilistic ones are in (0, 1]
    assert view.fp[reg.slot_of("same")] == 0.0
    assert view.fp[reg.slot_of("fork")] == 0.0
    assert 0.0 <= view.fp[reg.slot_of("anc")] <= 1.0


def test_registry_admit_update_evict():
    reg, local = _seeded_registry()
    assert len(reg) == 4 and "anc" in reg
    # cached sums must track cell contents through updates
    np.testing.assert_allclose(
        np.asarray(reg.sums), np.asarray(jnp.sum(reg.cells, axis=1)))
    reg.update("anc", local)
    assert reg.classify_all(local).status[reg.slot_of("anc")] == SAME
    slot = reg.slot_of("fork")
    reg.evict("fork")
    assert "fork" not in reg and len(reg) == 3
    assert reg.classify_all(local).status[slot] == DEAD
    # slot is reusable and re-admits land batched
    reg.admit_many({"new1": local, "new2": local})
    assert len(reg) == 5
    # re-admitting a known id keeps its slot
    s0 = reg.slot_of("new1")
    reg.admit("new1", _ticked(local, [1234]))
    assert reg.slot_of("new1") == s0


def test_registry_capacity_enforced():
    reg = ClockRegistry(capacity=2, m=64, k=3)
    c = bc.zeros(64, 3)
    reg.admit_many({"a": c, "b": c})
    with pytest.raises(RuntimeError):
        reg.admit("c", c)


def test_registry_union_dominates_members():
    reg, local = _seeded_registry()
    mask = np.asarray(reg.alive).copy()
    merged = reg.union(mask, local)
    assert bool(bc.ordering(local, merged).a_le_b)
    for pid in reg.peer_ids():
        assert bool(bc.ordering(reg.get(pid), merged).a_le_b)


# ---------------------------------------------------------------------------
# gossip rounds
# ---------------------------------------------------------------------------

def test_gossip_round_policy():
    reg, local = _seeded_registry()
    merged, report = gossip_round(
        reg, local, GossipConfig(policy=causal.CausalPolicy(fp_threshold=1.0)))
    assert report.quarantined[reg.slot_of("fork")]
    assert report.n_accepted == 3
    # merged absorbed the descendant's extra events
    assert bool(bc.ordering(reg.get("desc"), merged).a_le_b)
    assert bool(bc.ordering(local, merged).a_le_b)
    # push-back: accepted rows now equal the union
    view = reg.classify_all(merged)
    for pid in ("anc", "same", "desc"):
        assert view.status[reg.slot_of(pid)] == SAME


def test_gossip_straggler_skipped_not_quarantined():
    m, k = 128, 3
    reg = ClockRegistry(capacity=8, m=m, k=k)
    local = _ticked(bc.zeros(m, k), range(200))
    reg.admit_many({
        "fresh1": local, "fresh2": local, "fresh3": local,
        "lagging": _ticked(bc.zeros(m, k), range(2)),   # ancestor, far behind
    })
    merged, report = gossip_round(
        reg, local, GossipConfig(policy=causal.CausalPolicy(fp_threshold=1.0),
                                 straggler_gap=10.0))
    s = reg.slot_of("lagging")
    assert report.stragglers[s] and not report.accepted[s]
    assert not report.quarantined[s]
    assert report.n_accepted == 3


def test_gossip_empty_registry_is_identity():
    m, k = 64, 3
    reg = ClockRegistry(capacity=4, m=m, k=k)
    local = _ticked(bc.zeros(m, k), range(5))
    merged, report = gossip_round(reg, local)
    assert report.n_accepted == 0
    assert bool(jnp.all(merged.logical_cells() == local.logical_cells()))


# ---------------------------------------------------------------------------
# monitor
# ---------------------------------------------------------------------------

def test_fleet_health_fork_components():
    m, k = 128, 3
    reg = ClockRegistry(capacity=8, m=m, k=k)
    a = _ticked(bc.zeros(m, k), range(10))
    b = _ticked(bc.zeros(m, k), range(1000, 1010))    # independent history
    reg.admit_many({
        "a1": a, "a2": _ticked(a, [77]),              # component 1
        "b1": b, "b2": _ticked(b, [88]),              # component 2
    })
    health = fleet_health(reg)
    assert health.n_alive == 4
    assert health.n_components == 2
    lab = health.component
    assert lab[reg.slot_of("a1")] == lab[reg.slot_of("a2")]
    assert lab[reg.slot_of("b1")] == lab[reg.slot_of("b2")]
    assert lab[reg.slot_of("a1")] != lab[reg.slot_of("b1")]
    assert health.fp_hist.sum() >= 2                  # ordered pairs recorded


# ---------------------------------------------------------------------------
# sim-driven gossip validation (vector-clock ground truth)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 3, 11])
def test_gossip_sim_no_false_negatives(seed):
    r = run_gossip_sim(
        SimConfig(n_nodes=6, n_events=200, m=64, k=3, seed=seed))
    assert r.false_negatives == 0
    assert r.rounds == 6
    assert r.within_eq3_band


def test_gossip_sim_small_m_stays_in_band():
    """With m tiny relative to event count, fp claims DO happen; the
    measured rate must stay within the Eq. 3 band."""
    r = run_gossip_sim(
        SimConfig(n_nodes=8, n_events=400, m=16, k=2, seed=5), n_rounds=8)
    assert r.false_negatives == 0
    assert r.within_eq3_band


def test_evict_many_unknown_peer_is_atomic():
    """An unknown peer_id in the batch leaves the registry untouched —
    no half-evicted peers stuck alive outside the free list."""
    reg = ClockRegistry(capacity=4, m=64, k=3)
    reg.admit_many({"a": _clock_from(_cells(1, 64)[0]),
                    "b": _clock_from(_cells(1, 64)[0])})
    with pytest.raises(KeyError):
        reg.evict_many(["a", "nope"])
    assert "a" in reg and "b" in reg
    assert np.asarray(reg.alive).sum() == 2
    reg.evict_many(["a", "a", "b"])        # duplicates collapse cleanly
    assert len(reg) == 0 and not np.asarray(reg.alive).any()
    assert sorted(reg._free) == list(range(4))   # no leaked slots
