"""Quantized-slab validation: u8<->int32 round-trips, overflow
promotion, bit-exactness of every packed compare engine (triangle /
rectangle / MXU thermometer) against the broadcast reference across odd
shapes, alive-masked all_pairs, wire compression, batched checkpoint
lineage, and the autotune table plumbing.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro import causal
from repro.core import clock as bc
from repro.fleet import ANCESTOR, DEAD, SAME, ClockRegistry, gossip_round
from repro.kernels import autotune, ops, pack

RNG = np.random.default_rng(11)


def _cells(n, m, hi=20):
    return jnp.asarray(RNG.integers(0, hi, (n, m)), jnp.int32)


def _ticked(c, events):
    for e in events:
        c = bc.tick(c, jnp.uint32(e >> 32), jnp.uint32(e & 0xFFFFFFFF))
    return c


# ---------------------------------------------------------------------------
# pack round-trips and promotion
# ---------------------------------------------------------------------------

def test_pack_roundtrip_exact():
    cells = _cells(9, 300, hi=200)
    u8, base, ok = pack.pack_rows(cells)
    assert bool(ok.all())
    np.testing.assert_array_equal(
        np.asarray(pack.unpack_rows(u8, base)), np.asarray(cells))
    # packing lifts the row minimum into the base
    assert int(jnp.min(u8)) == 0


def test_pack_reports_overflow():
    cells = _cells(4, 64, hi=10)
    cells = cells.at[2, 0].set(1000)          # span > 255 in row 2 only
    u8, base, ok = pack.pack_rows(cells)
    np.testing.assert_array_equal(np.asarray(ok), [True, True, False, True])
    good = np.asarray(ok)
    np.testing.assert_array_equal(
        np.asarray(pack.unpack_rows(u8, base))[good], np.asarray(cells)[good])


@pytest.mark.parametrize("hi", [2, 30, 255])
def test_pack_roundtrip_property(hi):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(vals=st.lists(st.integers(0, hi), min_size=4, max_size=40),
           base=st.integers(0, 2**20))
    def check(vals, base):
        row = jnp.asarray([vals], jnp.int32)
        u8, b, ok = pack.pack_rows(row, jnp.asarray([base], jnp.int32))
        assert bool(ok.all())
        np.testing.assert_array_equal(
            np.asarray(pack.unpack_rows(u8, b)[0]),
            np.asarray(row[0]) + base)

    check()


def test_registry_promotes_and_demotes_wide_rows():
    m, k = 128, 3
    reg = ClockRegistry(capacity=4, m=m, k=k)
    narrow = _ticked(bc.zeros(m, k), range(12))
    wide = bc.BloomClock(
        jnp.zeros((m,), jnp.int32).at[0].set(1000), jnp.zeros((), jnp.int32), k)
    reg.admit_many({"a": narrow, "w": wide})
    assert not reg.packed                      # promotion happened
    # verdicts stay exact through the promoted fallback
    view = reg.classify_all(narrow)
    assert view.status[reg.slot_of("a")] == SAME
    np.testing.assert_array_equal(
        np.asarray(reg.get("w").logical_cells()),
        np.asarray(wide.logical_cells()))
    mats = reg.all_pairs()
    assert not bool(mats["a_le_b"][reg.slot_of("a"), reg.slot_of("w")])
    # overwriting with packable data demotes back to the fast path
    reg.update("w", narrow)
    assert reg.packed
    assert reg.classify_all(narrow).status[reg.slot_of("w")] == SAME


# ---------------------------------------------------------------------------
# packed engines vs broadcast reference (odd shapes, per-row bases)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["tri", "mxu"])
@pytest.mark.parametrize("n,m", [(5, 300), (16, 64), (33, 129), (9, 1000)])
def test_packed_engines_match_reference(engine, n, m):
    resid = jnp.asarray(RNG.integers(0, 9, (n, m)), jnp.int32)
    bases = jnp.asarray(RNG.integers(0, 5, (n,)), jnp.int32)
    resid = resid.at[1].set(resid[0])
    bases = bases.at[1].set(bases[0])          # row 1 == row 0
    logical = resid + bases[:, None]
    u8, pb, ok = pack.pack_rows(resid, bases)
    assert bool(ok.all())
    ref = bc.comparability_matrix(
        bc.BloomClock(logical, jnp.zeros((n,), jnp.int32), 3))
    got = causal.CausalEngine().pairs(
        causal.PackedSlab(u8, pb), engine=engine)
    np.testing.assert_array_equal(np.asarray(got["a_le_b"]),
                                  np.asarray(ref["a_le_b"]))
    np.testing.assert_array_equal(np.asarray(got["b_le_a"]),
                                  np.asarray(ref["a_le_b"]).T)
    np.testing.assert_array_equal(np.asarray(got["concurrent"]),
                                  np.asarray(ref["concurrent"]))
    np.testing.assert_allclose(np.asarray(got["fp"]), np.asarray(ref["fp"]),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(got["row_sums"]),
                               np.asarray(jnp.sum(logical, axis=1)))


def test_packed_rect_engine_matches_reference():
    n, m, mm = 12, 17, 200
    a = jnp.asarray(RNG.integers(0, 9, (n, mm)), jnp.int32)
    b = jnp.asarray(RNG.integers(0, 9, (m, mm)), jnp.int32)
    b = b.at[0].set(a[0])
    au8, ab, _ = pack.pack_rows(a)
    bu8, bb, _ = pack.pack_rows(b)
    got = ops._compare_matrix_packed(au8, ab, bu8, bb)
    le = jnp.all(a[:, None, :] <= b[None, :, :], axis=2)
    ge = jnp.all(a[:, None, :] >= b[None, :, :], axis=2)
    np.testing.assert_array_equal(np.asarray(got["a_le_b"]), np.asarray(le))
    np.testing.assert_array_equal(np.asarray(got["b_le_a"]), np.asarray(ge))


def test_multi_tile_accumulation_packed():
    """Dominance violated ONLY in the last m-tile: catches bad cross-tile
    accumulation in the packed triangle engine (pads + revisits)."""
    n, m = 9, 1000
    a = jnp.zeros((n, m), jnp.int32)
    a = a.at[0, m - 1].set(5)
    got = causal.CausalEngine().pairs(a)      # auto -> packed triangle
    le = np.asarray(got["a_le_b"])
    assert not le[0, 1] and le[1, 0]
    assert float(np.asarray(got["row_sums"])[0]) == 5.0


def test_compare_matrix_wide_span_falls_back():
    """Value span > 255 silently uses the int32 engine, same results."""
    n, m = 6, 100
    c = _cells(n, m, hi=5)
    c = c.at[0, 0].set(100000)
    ref = bc.comparability_matrix(
        bc.BloomClock(c, jnp.zeros((n,), jnp.int32), 3))
    got = causal.CausalEngine().pairs(c)
    np.testing.assert_array_equal(np.asarray(got["a_le_b"]),
                                  np.asarray(ref["a_le_b"]))


# ---------------------------------------------------------------------------
# alive-masked all_pairs
# ---------------------------------------------------------------------------

def test_all_pairs_masks_dead_slots():
    m, k = 128, 3
    reg = ClockRegistry(capacity=8, m=m, k=k)
    base_clock = _ticked(bc.zeros(m, k), range(10))
    reg.admit_many({
        "a": base_clock,
        "b": _ticked(base_clock, [77]),
        "dead": _ticked(bc.zeros(m, k), range(500, 505)),
    })
    dead_slot = reg.slot_of("dead")
    reg.evict("dead")
    mats = {kk: np.asarray(v) for kk, v in reg.all_pairs().items()}
    sa, sb = reg.slot_of("a"), reg.slot_of("b")
    assert mats["a_le_b"][sa, sb] and not mats["a_le_b"][sb, sa]
    # dead rows/cols report nothing, not stale verdicts
    for key in ("a_le_b", "b_le_a", "concurrent"):
        assert not mats[key][dead_slot].any()
        assert not mats[key][:, dead_slot].any()
    assert mats["fp"][dead_slot].max() == 0.0
    assert mats["row_sums"][dead_slot] == 0.0
    # never-admitted capacity slots behave the same
    empty = [s for s in range(8) if s not in (sa, sb, dead_slot)]
    assert not mats["a_le_b"][empty].any()


# ---------------------------------------------------------------------------
# wire compression
# ---------------------------------------------------------------------------

def test_wire_roundtrip_u8():
    c = _ticked(bc.zeros(256, 4), range(30))
    snap = bc.to_wire(c)
    assert snap["cells"].dtype == np.uint8     # §4 window fits a byte
    back = bc.from_wire(snap)
    np.testing.assert_array_equal(np.asarray(back.logical_cells()),
                                  np.asarray(c.logical_cells()))


def test_wire_falls_back_to_int32():
    c = bc.BloomClock(
        jnp.zeros((64,), jnp.int32).at[0].set(1000),
        jnp.zeros((), jnp.int32), 3)
    snap = bc.to_wire(c)
    assert snap["cells"].dtype != np.uint8
    np.testing.assert_array_equal(
        np.asarray(bc.from_wire(snap).logical_cells()),
        np.asarray(c.logical_cells()))


def test_gossip_pushback_reports_u8_wire_cost():
    from repro.core import wire

    m, k = 128, 3
    reg = ClockRegistry(capacity=4, m=m, k=k)
    local = _ticked(bc.zeros(m, k), range(20))
    reg.admit_many({"p1": _ticked(bc.zeros(m, k), range(10)), "p2": local})
    merged, report = gossip_round(reg, local)
    assert report.n_accepted == 2
    # MEASURED: the length of the encoded §4 frame that ships per peer
    # (u8 residuals here), not the old m * cell_bytes model
    frame = wire.encode_clock(bc.to_wire(merged))
    assert len(frame) == wire.clock_frame_nbytes(m, packed=True)
    assert report.pushback_bytes == 2 * len(frame)
    view = reg.classify_all(merged)
    for pid in ("p1", "p2"):
        assert view.status[reg.slot_of(pid)] == SAME


# ---------------------------------------------------------------------------
# batched checkpoint lineage
# ---------------------------------------------------------------------------

def test_classify_checkpoints_directory(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    from repro.runtime.clock_runtime import ClockConfig, ClockRuntime, LineageStatus

    rt = ClockRuntime(ClockConfig(m=128, k=3, fp_threshold=1.0))
    mgr = CheckpointManager(str(tmp_path), keep=0)
    state = {"w": np.zeros(2)}
    for step in (1, 2, 3):
        rt.tick_step(step)
        mgr.save(step, state, rt.snapshot(), block=True)
    # move past the checkpoints, then fork an alternate history
    rt.tick_step(99)
    forked = ClockRuntime(ClockConfig(m=128, k=3), run_id="other")
    forked.tick_step(1)
    mgr.save(4, state, forked.snapshot(), block=True)

    lineage = rt.classify_checkpoints(mgr)
    np.testing.assert_array_equal(lineage.steps, [1, 2, 3, 4])
    assert lineage.status[:3] == [LineageStatus.ANCESTOR] * 3
    assert lineage.status[3] == LineageStatus.FORKED
    np.testing.assert_array_equal(lineage.safe, [True, True, True, False])
    assert lineage.latest_safe() == 3

    step, _ = rt.admit_restore_latest(mgr)
    assert step == 3
    # batch verdicts agree with the one-at-a-time path
    for s, status, ok in zip(lineage.steps, lineage.status, lineage.safe):
        _, man = [e for e in mgr.clock_manifests() if e[0] == s][0]
        ok1, st1, _ = rt.admit_restore(rt.clock_from_snapshot(man["clock"]))
        assert (st1, ok1) == (status, ok)


def test_classify_checkpoints_empty(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    from repro.runtime.clock_runtime import ClockConfig, ClockRuntime

    rt = ClockRuntime(ClockConfig(m=64, k=3))
    lineage = rt.classify_checkpoints(CheckpointManager(str(tmp_path)))
    assert lineage.latest_safe() is None and len(lineage.status) == 0


# ---------------------------------------------------------------------------
# autotune plumbing
# ---------------------------------------------------------------------------

def test_autotune_vmem_model_scales():
    small = autotune.vmem_bytes("tri", 8, 8, 128)
    big = autotune.vmem_bytes("tri", 128, 128, 512)
    assert small < big
    assert autotune.vmem_bytes("mxu", 8, 8, 128, n_thresholds=32) > \
        autotune.vmem_bytes("mxu", 8, 8, 128, n_thresholds=8)


def test_autotune_table_roundtrip(tmp_path, monkeypatch):
    path = tmp_path / "table.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE", str(path))
    key = autotune.key_for("matrix", 1000, 1000, 1000, True)
    autotune.save_table({key: {"engine": "tri", "bi": 64, "bj": 64, "bm": 256}})
    # bucketed lookup: any shape in the same pow2 band hits the entry
    cfg = autotune.lookup("matrix", 700, 700, 600, True)
    assert cfg == {"engine": "tri", "bi": 64, "bj": 64, "bm": 256}
    assert autotune.lookup("matrix", 2000, 2000, 600, True) is None


def test_autotune_measured_sweep_small():
    best = autotune.autotune_matrix(16, 128, span=10, interpret=True)
    assert best["engine"] in ("tri", "i32", "mxu")
    assert best["us"] > 0

# ---------------------------------------------------------------------------
# sparse promoted-row dispatch (one wide row must NOT sink the slab)
# ---------------------------------------------------------------------------

def _one_wide_registry(cap=8, m=128, k=3):
    reg = ClockRegistry(capacity=cap, m=m, k=k)
    rows = {f"p{i}": _ticked(bc.zeros(m, k), range(3 * i, 3 * i + 6))
            for i in range(cap - 1)}
    wide = bc.BloomClock(
        jnp.zeros((m,), jnp.int32).at[2].set(4000),
        jnp.zeros((), jnp.int32), k)
    rows["wide"] = wide
    reg.admit_many(rows)
    assert not reg.packed
    return reg


def test_sparse_promoted_classify_dispatch(monkeypatch):
    """Regression pin: with ONE promoted row, classify_all keeps the
    O(N) bulk on the packed kernel and runs the int32 kernel on just the
    [1, m] promoted handful — never on the whole materialized slab.

    Spies on the INTERNAL impls the CausalEngine front-door dispatches
    to (the public ``ops.*`` names are deprecation shims now)."""
    reg = _one_wide_registry()
    calls = {"packed": [], "i32": []}
    orig_packed = ops._classify_vs_many_packed
    orig_i32 = ops._classify_vs_many
    monkeypatch.setattr(
        ops, "_classify_vs_many_packed",
        lambda q, p, b, **kw: calls["packed"].append(p.shape)
        or orig_packed(q, p, b, **kw))
    monkeypatch.setattr(
        ops, "_classify_vs_many",
        lambda q, p, **kw: calls["i32"].append(p.shape)
        or orig_i32(q, p, **kw))
    local = reg.get("p0")
    view = reg.classify_all(local)
    assert calls["packed"] == [(8, 128)]       # bulk stayed packed
    assert calls["i32"] == [(1, 128)]          # only the promoted handful
    # verdicts stay exact through the overlay
    assert view.status[reg.slot_of("p0")] == SAME
    assert view.status[reg.slot_of("wide")] != DEAD
    assert float(view.sums[reg.slot_of("wide")]) == 4000.0


def test_sparse_promoted_all_pairs_dispatch(monkeypatch):
    """Regression pin: all_pairs with one promoted row sweeps the packed
    engine over the packed rows and the int32 rim over [1, m] x alive."""
    reg = _one_wide_registry()
    calls = {"packed": [], "i32": []}
    orig_packed = ops._compare_matrix_packed
    orig_i32 = ops._compare_matrix
    monkeypatch.setattr(
        ops, "_compare_matrix_packed",
        lambda c, b, *a, **kw: calls["packed"].append(c.shape)
        or orig_packed(c, b, *a, **kw))
    monkeypatch.setattr(
        ops, "_compare_matrix",
        lambda r, c, **kw: calls["i32"].append((r.shape, c.shape))
        or orig_i32(r, c, **kw))
    mats = {kk: np.asarray(v) for kk, v in reg.all_pairs().items()}
    assert calls["packed"] == [(7, 128)]               # bulk: packed rows only
    assert calls["i32"] == [((1, 128), (8, 128))]      # rim: wide vs alive
    # exactness vs a host reference over the logical cells
    logical = np.asarray(reg.cells)
    le_ref = np.all(logical[:, None, :] <= logical[None, :, :], axis=2)
    np.testing.assert_array_equal(mats["a_le_b"], le_ref)
    np.testing.assert_array_equal(mats["b_le_a"], le_ref.T)
    np.testing.assert_array_equal(mats["concurrent"], ~(le_ref | le_ref.T))
    np.testing.assert_array_equal(mats["row_sums"], logical.sum(1))


def test_sparse_promoted_all_pairs_masks_dead(monkeypatch):
    """Dead slots stay silent on the sparse promoted path too."""
    reg = _one_wide_registry()
    dead = reg.slot_of("p3")
    reg.evict("p3")
    mats = {kk: np.asarray(v) for kk, v in reg.all_pairs().items()}
    for key in ("a_le_b", "b_le_a", "concurrent"):
        assert not mats[key][dead].any() and not mats[key][:, dead].any()
    assert mats["fp"][dead].max() == 0.0 and mats["row_sums"][dead] == 0.0


# ---------------------------------------------------------------------------
# autotune fallback: table miss and corrupted cache file
# ---------------------------------------------------------------------------

def test_autotune_table_miss_falls_back(tmp_path, monkeypatch):
    """No row for this backend/shape bucket: lookup reports the miss and
    compare_matrix falls back to the built-in defaults deterministically."""
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE", str(tmp_path / "missing.json"))
    assert autotune.load_table() == {}
    assert autotune.lookup("matrix", 16, 16, 128, True) is None
    c = _cells(16, 128, hi=9)
    got1 = causal.CausalEngine().pairs(c)
    got2 = causal.CausalEngine().pairs(c)
    ref = bc.comparability_matrix(
        bc.BloomClock(c, jnp.zeros((16,), jnp.int32), 3))
    np.testing.assert_array_equal(np.asarray(got1["a_le_b"]),
                                  np.asarray(ref["a_le_b"]))
    np.testing.assert_array_equal(np.asarray(got1["a_le_b"]),
                                  np.asarray(got2["a_le_b"]))
    assert (np.asarray(got1["fp"]) == np.asarray(got2["fp"])).all()


def test_autotune_corrupted_cache_file(tmp_path, monkeypatch):
    """A truncated/garbage cache file must read as an empty table (miss
    everywhere), not crash the compare path."""
    path = tmp_path / "corrupt.json"
    path.write_text('{"matrix|interpret|N16|M16|m128": {"engine": "tr')
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE", str(path))
    assert autotune.load_table() == {}
    assert autotune.lookup("matrix", 16, 16, 128, True) is None
    c = _cells(12, 128, hi=9)
    got = causal.CausalEngine().pairs(c)
    ref = bc.comparability_matrix(
        bc.BloomClock(c, jnp.zeros((12,), jnp.int32), 3))
    np.testing.assert_array_equal(np.asarray(got["a_le_b"]),
                                  np.asarray(ref["a_le_b"]))
    np.testing.assert_allclose(np.asarray(got["fp"]), np.asarray(ref["fp"]),
                               atol=1e-6)
